"""Repo-root pytest config.

Makes ``compile.*`` importable when pytest runs from the repository root
(``pytest python/tests/``), matching the Makefile's ``cd python && pytest
tests/`` invocation.

Also guards collection: the Python test suite needs the JAX/Pallas
toolchain (jax, numpy) and hypothesis, none of which exist on the Rust CI
runners, and the AOT artifacts are likewise absent there. Without this
guard a missing dependency turns into a *collection error* (pytest exits
red before running anything); with it the suite is skipped gracefully and
CI stays green.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

_REQUIRED = ("numpy", "jax", "hypothesis")
_missing = [mod for mod in _REQUIRED if importlib.util.find_spec(mod) is None]

collect_ignore_glob = []
if _missing:
    collect_ignore_glob.append("python/tests/*")
    sys.stderr.write(
        "conftest: skipping python/tests (missing: {}); the Rust tier-1 "
        "suite does not need the Python stack\n".format(", ".join(_missing))
    )
