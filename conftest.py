"""Repo-root pytest config: make `compile.*` importable when pytest runs
from the repository root (`pytest python/tests/`), matching the Makefile's
`cd python && pytest tests/` invocation."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
