"""Column-split ELL kernel vs oracle: packing round trip + kernel numerics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, spmv_ell_colsplit as cs


def make_problem(rng, rows, width, n, pad_frac=0.3):
    vals = rng.standard_normal((rows, width)).astype(np.float32)
    cols = rng.integers(0, n, size=(rows, width)).astype(np.int32)
    pad = rng.random((rows, width)) < pad_frac
    vals[pad] = 0.0
    cols[pad] = 0
    v = rng.standard_normal(n).astype(np.float32)
    return vals, cols, v


class TestPacking:
    def test_pack_preserves_product(self):
        rng = np.random.default_rng(0)
        vals, cols, v = make_problem(rng, 32, 8, 64)
        want = np.asarray(ref.ell_spmv(vals, cols, v))
        pv, pc, cw = cs.pack_colsplit(vals, cols, 64, 4)
        assert pv.shape == (32, 4 * cw)
        got = np.asarray(cs.ell_spmv_colsplit(pv, pc, v, 4))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_window_relative_indices_bounded(self):
        rng = np.random.default_rng(1)
        vals, cols, _ = make_problem(rng, 16, 4, 32)
        _, pc, cw = cs.pack_colsplit(vals, cols, 32, 4)
        win = 32 // 4
        assert pc.max() < win
        assert pc.min() >= 0

    def test_single_chunk_equals_plain(self):
        rng = np.random.default_rng(2)
        vals, cols, v = make_problem(rng, 24, 6, 48)
        pv, pc, _ = cs.pack_colsplit(vals, cols, 48, 1)
        got = np.asarray(cs.ell_spmv_colsplit(pv, pc, v, 1))
        want = np.asarray(ref.ell_spmv(vals, cols, v))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestKernel:
    @pytest.mark.parametrize("n_chunks", [1, 2, 4, 8])
    def test_chunk_counts(self, n_chunks):
        rng = np.random.default_rng(3)
        n = 64
        vals, cols, v = make_problem(rng, 32, 8, n)
        pv, pc, _ = cs.pack_colsplit(vals, cols, n, n_chunks)
        got = np.asarray(cs.ell_spmv_colsplit(pv, pc, v, n_chunks))
        want = np.asarray(ref.ell_spmv(vals, cols, v))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.integers(1, 48),
        width=st.integers(1, 8),
        win=st.integers(1, 24),
        n_chunks=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_sweep(self, rows, width, win, n_chunks, seed):
        rng = np.random.default_rng(seed)
        n = win * n_chunks
        vals, cols, v = make_problem(rng, rows, width, n)
        pv, pc, _ = cs.pack_colsplit(vals, cols, n, n_chunks)
        got = np.asarray(cs.ell_spmv_colsplit(pv, pc, v, n_chunks))
        want = np.asarray(ref.ell_spmv(vals, cols, v))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_vmem_shrinks_with_chunks(self):
        # the point of the variant: the vector term scales down by n_chunks
        full = cs.vmem_bytes(1024, 32, 65536)
        split = cs.vmem_bytes(1024, 8, 65536 // 8)
        assert split < full
