"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes and values; fixed cases pin the artifact shapes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import gather, ref, spmv_ell


def make_ell(rng, rows, width, n, pad_frac=0.3):
    """Random padded ELL block: (vals, cols) with ~pad_frac zero slots."""
    vals = rng.standard_normal((rows, width)).astype(np.float32)
    cols = rng.integers(0, n, size=(rows, width)).astype(np.int32)
    pad = rng.random((rows, width)) < pad_frac
    vals[pad] = 0.0
    cols[pad] = 0
    return vals, cols


class TestEllSpmv:
    @pytest.mark.parametrize("rows,width,n", [(8, 4, 8), (128, 32, 128), (256, 16, 512), (1, 1, 1)])
    def test_matches_ref(self, rows, width, n):
        rng = np.random.default_rng(42)
        vals, cols = make_ell(rng, rows, width, n)
        v = rng.standard_normal(n).astype(np.float32)
        got = spmv_ell.ell_spmv(vals, cols, v)
        want = ref.ell_spmv(vals, cols, v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_matrix_zero_result(self):
        vals = np.zeros((16, 4), np.float32)
        cols = np.zeros((16, 4), np.int32)
        v = np.ones(16, np.float32)
        np.testing.assert_array_equal(np.asarray(spmv_ell.ell_spmv(vals, cols, v)), 0.0)

    def test_identity_matrix(self):
        n = 64
        vals = np.zeros((n, 4), np.float32)
        cols = np.zeros((n, 4), np.int32)
        vals[:, 0] = 1.0
        cols[:, 0] = np.arange(n)
        v = np.random.default_rng(0).standard_normal(n).astype(np.float32)
        np.testing.assert_allclose(np.asarray(spmv_ell.ell_spmv(vals, cols, v)), v, rtol=1e-6)

    def test_padding_does_not_contribute(self):
        # Padding points at column 0 with value 0; poison v[0] and check
        # the result is unchanged.
        rng = np.random.default_rng(7)
        vals, cols = make_ell(rng, 32, 8, 32, pad_frac=0.5)
        v = rng.standard_normal(32).astype(np.float32)
        base = np.asarray(spmv_ell.ell_spmv(vals, cols, v))
        v2 = v.copy()
        v2[0] = 1e6  # only padded slots and genuine col-0 entries see this
        # recompute reference difference: the kernel and ref must still agree
        got = np.asarray(spmv_ell.ell_spmv(vals, cols, v2))
        want = np.asarray(ref.ell_spmv(vals, cols, v2))
        np.testing.assert_allclose(got, want, rtol=1e-4)
        del base

    @settings(max_examples=30, deadline=None)
    @given(
        rows=st.integers(1, 96),
        width=st.integers(1, 24),
        n=st.integers(1, 200),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_hypothesis_sweep(self, rows, width, n, seed):
        rng = np.random.default_rng(seed)
        vals, cols = make_ell(rng, rows, width, n)
        v = rng.standard_normal(n).astype(np.float32)
        got = np.asarray(spmv_ell.ell_spmv(vals, cols, v))
        want = np.asarray(ref.ell_spmv(vals, cols, v))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_tile_boundary_rows(self):
        # rows exactly at, below and above TILE_M exercise both grid paths.
        rng = np.random.default_rng(3)
        for rows in [spmv_ell.TILE_M - 1, spmv_ell.TILE_M, spmv_ell.TILE_M * 2, spmv_ell.TILE_M + 1]:
            vals, cols = make_ell(rng, rows, 8, rows)
            v = rng.standard_normal(rows).astype(np.float32)
            got = np.asarray(spmv_ell.ell_spmv(vals, cols, v))
            want = np.asarray(ref.ell_spmv(vals, cols, v))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestGather:
    @pytest.mark.parametrize("n,m", [(8, 4), (256, 256), (512, 100), (1, 1)])
    def test_matches_ref(self, n, m):
        rng = np.random.default_rng(1)
        v = rng.standard_normal(n).astype(np.float32)
        idx = rng.integers(0, n, size=m).astype(np.int32)
        got = np.asarray(gather.gather(v, idx))
        want = np.asarray(ref.gather(v, idx))
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 300), m=st.integers(1, 300), seed=st.integers(0, 2**32 - 1))
    def test_hypothesis_sweep(self, n, m, seed):
        rng = np.random.default_rng(seed)
        v = rng.standard_normal(n).astype(np.float32)
        idx = rng.integers(0, n, size=m).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(gather.gather(v, idx)), np.asarray(ref.gather(v, idx))
        )

    def test_duplicate_indices(self):
        v = np.array([1.0, 2.0, 3.0], np.float32)
        idx = np.array([2, 2, 0, 2], np.int32)
        np.testing.assert_array_equal(np.asarray(gather.gather(v, idx)), [3.0, 3.0, 1.0, 3.0])


class TestVmemEstimate:
    def test_within_budget_for_artifact_shapes(self):
        # All canonical shapes must fit the ~16 MiB VMEM budget.
        from compile.aot import SHAPES

        for rows, dw, ow, ghost in SHAPES:
            diag = spmv_ell.vmem_bytes(rows, dw, rows)
            offd = spmv_ell.vmem_bytes(rows, ow, ghost)
            assert diag + offd < 16 * 2**20, f"shape {(rows, dw, ow, ghost)} exceeds VMEM"

    def test_scales_with_tile(self):
        assert spmv_ell.vmem_bytes(1024, 32, 1024, tile=64) < spmv_ell.vmem_bytes(
            1024, 32, 1024, tile=128
        )
