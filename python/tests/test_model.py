"""L2 model tests: local_spmv composition, shapes, and the halo-pack path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def random_local_problem(rng, rows, dw, ow, ghost):
    diag_vals = rng.standard_normal((rows, dw)).astype(np.float32)
    diag_cols = rng.integers(0, rows, size=(rows, dw)).astype(np.int32)
    offd_vals = rng.standard_normal((rows, ow)).astype(np.float32)
    offd_cols = rng.integers(0, ghost, size=(rows, ow)).astype(np.int32)
    v_local = rng.standard_normal(rows).astype(np.float32)
    v_ghost = rng.standard_normal(ghost).astype(np.float32)
    return diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost


class TestLocalSpmv:
    def test_matches_ref_composition(self):
        rng = np.random.default_rng(5)
        args = random_local_problem(rng, 64, 8, 4, 32)
        (got,) = model.local_spmv(*args)
        want = ref.local_spmv(*args)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_returns_tuple(self):
        rng = np.random.default_rng(6)
        out = model.local_spmv(*random_local_problem(rng, 16, 4, 2, 8))
        assert isinstance(out, tuple) and len(out) == 1

    def test_zero_ghost_contribution(self):
        rng = np.random.default_rng(7)
        diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost = random_local_problem(
            rng, 32, 4, 4, 16
        )
        offd_vals[:] = 0.0
        (w,) = model.local_spmv(diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost)
        want = ref.ell_spmv(diag_vals, diag_cols, v_local)
        np.testing.assert_allclose(np.asarray(w), np.asarray(want), rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.integers(1, 64),
        dw=st.integers(1, 8),
        ow=st.integers(1, 8),
        ghost=st.integers(1, 64),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_composition(self, rows, dw, ow, ghost, seed):
        rng = np.random.default_rng(seed)
        args = random_local_problem(rng, rows, dw, ow, ghost)
        (got,) = model.local_spmv(*args)
        want = ref.local_spmv(*args)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


class TestHaloPack:
    def test_pack_matches_ref(self):
        rng = np.random.default_rng(8)
        v = rng.standard_normal(100).astype(np.float32)
        idx = rng.integers(0, 100, size=40).astype(np.int32)
        (got,) = model.halo_pack(v, idx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gather(v, idx)))


class TestSpmvStep:
    def test_normalized_output(self):
        rng = np.random.default_rng(9)
        args = random_local_problem(rng, 32, 4, 2, 16)
        w, scale = model.spmv_step(*args)
        assert float(np.max(np.abs(np.asarray(w)))) == pytest.approx(1.0, rel=1e-5)
        assert float(scale) > 0
