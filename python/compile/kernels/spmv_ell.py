"""L1 Pallas kernel: row-tiled ELL SpMV.

TPU adaptation of the paper's GPU SpMV (DESIGN.md §Hardware-Adaptation):
the CUDA warp-per-row CSR loop becomes a dense (TILE_M, W) block over the
padded ELL layout — fixed row width removes divergence and gives the VPU
contiguous vector work. BlockSpec tiles rows for the HBM→VMEM schedule the
CUDA code expressed with threadblocks; the source vector is broadcast into
VMEM per tile (SpMV is bandwidth-bound — the MXU is not the target, the
VPU is).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is exactly what the
Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 8 sublanes x f32 is the natural VPU tile height; 128
# rows amortizes grid overhead while keeping VMEM well under budget (see
# vmem_bytes()).
TILE_M = 128


def _ell_kernel(vals_ref, cols_ref, v_ref, o_ref):
    """One row tile: o[r] = sum_k vals[r, k] * v[cols[r, k]]."""
    vals = vals_ref[...]  # (TILE_M, W)
    cols = cols_ref[...]  # (TILE_M, W) int32
    v = v_ref[...]  # (n,) broadcast into VMEM for the tile
    gathered = v[cols]  # vectorized gather, (TILE_M, W)
    o_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=())
def ell_spmv(vals, cols, v):
    """Pallas ELL SpMV; mirrors kernels.ref.ell_spmv.

    Args:
      vals: (rows, width) f32, zero-padded.
      cols: (rows, width) i32 indices into v (padding points at 0).
      v: (n,) f32.

    Returns:
      (rows,) f32.
    """
    rows, width = vals.shape
    n = v.shape[0]
    tile = min(TILE_M, rows)
    if rows % tile != 0:
        # Static shapes only — callers pad rows to a multiple of TILE_M (the
        # AOT shapes do); fall back to one big tile otherwise.
        tile = rows
    grid = (rows // tile,)
    return pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # whole vector per tile
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), vals.dtype),
        interpret=True,
    )(vals, cols, v)


def vmem_bytes(rows, width, n, tile=TILE_M):
    """Estimated VMEM footprint of one grid step in bytes.

    vals + cols tiles, the broadcast vector, and the output tile. Used by
    DESIGN.md §Perf to check the schedule against the ~16 MiB VMEM budget.
    """
    t = min(tile, rows)
    return t * width * 4 + t * width * 4 + n * 4 + t * 4
