"""Pure-jnp oracle for the L1 kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest asserts allclose between the
kernel (interpret mode) and these functions across shapes and dtypes.
"""

import jax.numpy as jnp


def ell_spmv(vals, cols, v):
    """ELL SpMV: w[r] = sum_k vals[r, k] * v[cols[r, k]].

    Padding slots carry vals == 0 (their cols point at 0), so they
    contribute nothing.

    Args:
      vals: (rows, width) float values.
      cols: (rows, width) int32 column indices into v.
      v: (n,) float vector.

    Returns:
      (rows,) float result.
    """
    gathered = v[cols]  # (rows, width)
    return jnp.sum(vals * gathered, axis=1)


def local_spmv(diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost):
    """The distributed-SpMV local compute (Section 2.4.1):

    w = A_diag . v_local + A_offd . v_ghost
    """
    return ell_spmv(diag_vals, diag_cols, v_local) + ell_spmv(
        offd_vals, offd_cols, v_ghost
    )


def gather(v, idx):
    """Halo pack: out[i] = v[idx[i]] — the communication-buffer gather."""
    return v[idx]
