"""L1 Pallas kernels (interpret mode) + the pure-jnp oracle in ref.py."""

from . import gather, ref, spmv_ell, spmv_ell_colsplit  # noqa: F401
