"""L1 Pallas kernel: column-split ELL SpMV for vectors that exceed VMEM.

The row-tiled kernel (spmv_ell.py) broadcasts the whole source vector into
VMEM per tile — fine for the canonical artifact shapes (<= 4 KiB vectors)
but not for large partitions. This variant additionally tiles the *columns*:
the ELL width dimension is cut into column-chunks whose indices are
guaranteed (by the packing convention below) to fall in a bounded vector
window, so each grid step loads only a vector slice.

Packing convention: callers sort each row's entries by column and split the
vector into `n_chunks` equal windows; `chunk_width` slots per row are
reserved per window (padded with (0, win_start) pointing at the window's
first element with value 0). This is the TPU analog of the CUDA
"sliced ELLPACK" format — the HBM->VMEM schedule is expressed with a 2D
grid in BlockSpec instead of threadblock tiling.

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128


def _colsplit_kernel(vals_ref, cols_ref, v_ref, o_ref):
    """One (row-tile, column-window) step: accumulate the window's partial
    products. `cols_ref` holds indices *relative to the window start*."""
    j = pl.program_id(1)
    vals = vals_ref[...]  # (tile, chunk_width)
    cols = cols_ref[...]  # (tile, chunk_width), window-relative
    v = v_ref[...]  # (win,) — only this window's slice of the vector
    partial = jnp.sum(vals * v[cols], axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def ell_spmv_colsplit(vals, cols, v, n_chunks: int):
    """Column-split ELL SpMV.

    Args:
      vals: (rows, n_chunks * chunk_width) f32, zero-padded, entries for
        window j in slots [j*chunk_width, (j+1)*chunk_width).
      cols: same shape i32; entries are *window-relative* indices.
      v: (n,) f32 with n divisible by n_chunks.
      n_chunks: number of column windows.

    Returns:
      (rows,) f32.
    """
    rows, total_w = vals.shape
    (n,) = v.shape
    assert total_w % n_chunks == 0, "width must divide into chunks"
    assert n % n_chunks == 0, "vector must divide into windows"
    chunk_width = total_w // n_chunks
    win = n // n_chunks
    tile = min(TILE_M, rows)
    if rows % tile != 0:
        tile = rows
    grid = (rows // tile, n_chunks)
    return pl.pallas_call(
        _colsplit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, chunk_width), lambda i, j: (i, j)),
            pl.BlockSpec((tile, chunk_width), lambda i, j: (i, j)),
            pl.BlockSpec((win,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), vals.dtype),
        interpret=True,
    )(vals, cols, v)


def pack_colsplit(vals_full, cols_full, n, n_chunks):
    """Re-pack a plain ELL block (global column indices) into the
    column-split layout. Pure numpy-style; build-time only.

    Returns (vals, cols, chunk_width) in the kernel's convention.
    """
    import numpy as np

    vals_full = np.asarray(vals_full)
    cols_full = np.asarray(cols_full)
    rows, width = vals_full.shape
    assert n % n_chunks == 0
    win = n // n_chunks
    # count entries per (row, window) to size chunk_width
    per = np.zeros((rows, n_chunks), dtype=np.int64)
    for r in range(rows):
        for k in range(width):
            if vals_full[r, k] != 0.0:
                per[r, cols_full[r, k] // win] += 1
    chunk_width = max(1, int(per.max()))
    vals = np.zeros((rows, n_chunks * chunk_width), dtype=np.float32)
    cols = np.zeros((rows, n_chunks * chunk_width), dtype=np.int32)
    fill = np.zeros((rows, n_chunks), dtype=np.int64)
    for r in range(rows):
        for k in range(width):
            if vals_full[r, k] == 0.0:
                continue
            c = int(cols_full[r, k])
            j = c // win
            slot = j * chunk_width + int(fill[r, j])
            vals[r, slot] = vals_full[r, k]
            cols[r, slot] = c - j * win  # window-relative
            fill[r, j] += 1
    return vals, cols, chunk_width


def vmem_bytes(rows, chunk_width, win, tile=TILE_M):
    """VMEM per grid step: two (tile, chunk_width) blocks + one window +
    the output tile. Compare with spmv_ell.vmem_bytes: the n-dependent term
    shrinks by n_chunks."""
    t = min(tile, rows)
    return 2 * t * chunk_width * 4 + win * 4 + t * 4
