"""L1 Pallas kernel: halo-pack gather.

Packing the communication buffer (out[i] = v[idx[i]]) is the second
per-iteration hot-spot of the distributed SpMV (Section 2.4: "packing and
unpacking communication buffers"). On GPU this is a strided-gather CUDA
kernel; on TPU it is a statically shaped vectorized gather in VMEM.

interpret=True for CPU-PJRT executability (see spmv_ell.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(v_ref, idx_ref, o_ref):
    v = v_ref[...]
    idx = idx_ref[...]
    o_ref[...] = v[idx]


@jax.jit
def gather(v, idx):
    """Pallas halo pack; mirrors kernels.ref.gather.

    Args:
      v: (n,) f32 source vector (the owned partition slice).
      idx: (m,) i32 indices to pack.

    Returns:
      (m,) f32 packed buffer.
    """
    (n,) = v.shape
    (m,) = idx.shape
    return pl.pallas_call(
        _gather_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), v.dtype),
        interpret=True,
    )(v, idx)
