"""L2: the distributed-SpMV local compute graph, calling the L1 kernels.

The local step of the distributed SpMV (Section 2.4.1) on each GPU is

    w = A_diag . v_local + A_offd . v_ghost

with both blocks in padded ELL layout. This module is the single source of
truth for the artifact calling convention:

    local_spmv(diag_vals f32[r,dw], diag_cols i32[r,dw],
               offd_vals f32[r,ow], offd_cols i32[r,ow],
               v_local f32[r], v_ghost f32[g]) -> (w f32[r],)

which `rust/src/runtime/mod.rs::Executable::run_spmv` mirrors exactly.
"""

import jax.numpy as jnp

from .kernels import gather as gather_kernel
from .kernels import spmv_ell


def local_spmv(diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost):
    """One GPU's local SpMV: diag and offd ELL products fused in one
    lowered module. Returns a 1-tuple so the AOT path always emits a tuple
    root (matching `to_tuple1` on the Rust side)."""
    w = spmv_ell.ell_spmv(diag_vals, diag_cols, v_local) + spmv_ell.ell_spmv(
        offd_vals, offd_cols, v_ghost
    )
    return (w,)


def halo_pack(v_local, send_idx):
    """Pack the halo send buffer: the L1 gather kernel."""
    return (gather_kernel.gather(v_local, send_idx),)


def spmv_step(diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost):
    """Power-iteration step: local SpMV followed by infinity normalization
    of the *local* block (the global normalization is the coordinator's
    reduction; this fused variant is used when a single GPU owns the whole
    problem)."""
    (w,) = local_spmv(diag_vals, diag_cols, offd_vals, offd_cols, v_local, v_ghost)
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    return (w / scale, scale)
