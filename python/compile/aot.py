"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Run once at build time (`make artifacts`); Python never runs on the request
path. Interchange format is HLO *text*, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Canonical artifact shapes: (rows, diag_width, offd_width, ghost).
# Keep in sync with rust/src/runtime/artifact.rs::SPMV_SHAPES.
SHAPES = [
    (256, 32, 16, 256),
    (512, 32, 16, 512),
    (1024, 32, 16, 1024),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spmv_artifact_name(rows: int, dw: int, ow: int, ghost: int) -> str:
    # Must match rust/src/runtime/artifact.rs::ArtifactSpec::new.
    return f"spmv_local_r{rows}_d{dw}_o{ow}_g{ghost}"


def lower_spmv(rows: int, dw: int, ow: int, ghost: int) -> str:
    f32 = jnp.float32
    i32 = jnp.int32
    args = (
        jax.ShapeDtypeStruct((rows, dw), f32),  # diag_vals
        jax.ShapeDtypeStruct((rows, dw), i32),  # diag_cols
        jax.ShapeDtypeStruct((rows, ow), f32),  # offd_vals
        jax.ShapeDtypeStruct((rows, ow), i32),  # offd_cols
        jax.ShapeDtypeStruct((rows,), f32),  # v_local
        jax.ShapeDtypeStruct((ghost,), f32),  # v_ghost
    )
    lowered = jax.jit(model.local_spmv).lower(*args)
    return to_hlo_text(lowered)


def lower_gather(n: int, m: int) -> str:
    args = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
    )
    lowered = jax.jit(model.halo_pack).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker path")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    written = []
    for rows, dw, ow, ghost in SHAPES:
        name = spmv_artifact_name(rows, dw, ow, ghost)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_spmv(rows, dw, ow, ghost)
        with open(path, "w") as f:
            f.write(text)
        written.append((path, len(text)))

    # Halo-pack artifacts matching the SpMV shapes.
    for rows, _, _, ghost in SHAPES:
        name = f"halo_pack_n{rows}_m{ghost}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_gather(rows, ghost)
        with open(path, "w") as f:
            f.write(text)
        written.append((path, len(text)))

    # Marker file so `make artifacts` has a single dependency target.
    marker = args.out or os.path.join(out_dir, "model.hlo.txt")
    with open(marker, "w") as f:
        f.write("\n".join(p for p, _ in written) + "\n")

    for path, size in written:
        print(f"wrote {size:>9} chars  {path}")
    print(f"marker: {marker}")


if __name__ == "__main__":
    main()
