"""Pytest config for ``cd python && pytest tests/`` invocations.

Mirrors the repo-root conftest's dependency guard: when the JAX/Pallas
toolchain (jax, numpy) or hypothesis is unavailable, skip collection of
the test tree gracefully instead of erroring at import time.
"""

import importlib.util
import sys

_REQUIRED = ("numpy", "jax", "hypothesis")
_missing = [mod for mod in _REQUIRED if importlib.util.find_spec(mod) is None]

collect_ignore_glob = []
if _missing:
    collect_ignore_glob.append("tests/*")
    sys.stderr.write(
        "conftest: skipping tests/ (missing: {}); the Rust tier-1 suite "
        "does not need the Python stack\n".format(", ".join(_missing))
    )
