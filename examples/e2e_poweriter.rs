//! End-to-end driver: power iteration on a 3D 27-point stencil partitioned
//! over 8 simulated GPUs (2 Lassen nodes), with every layer engaged:
//!
//! - L1/L2: the local SpMV runs through the **PJRT-loaded AOT artifact**
//!   (Pallas ELL kernel lowered by `python/compile/aot.py`);
//! - L3: the Rust coordinator moves real halo bytes between worker threads
//!   using the Split+MD strategy and reports Lassen-calibrated simulated
//!   communication times for all strategies.
//!
//! Requires `make artifacts` first (falls back to the in-Rust kernel with a
//! warning otherwise).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_poweriter
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::sparse::gen;
use hetcomm::topology::machines;

fn main() -> anyhow::Result<()> {
    // 8x8x16 -> 1024 rows over 8 GPUs = 128 rows (two z-layers) per part:
    // slab thickness 2 keeps the offd ELL width within the artifact's
    // static width (single remote face, <= 9 entries).
    let side = 8;
    let a = gen::stencil_27pt(side, side, 2 * side);
    let machine = machines::lassen(2);
    let gpus = 8;
    let iters = 25;

    let artifacts = std::path::PathBuf::from("artifacts");
    let have_artifacts = {
        let specs = hetcomm::runtime::spmv_specs();
        specs.iter().any(|s| artifacts.join(s.file_name()).exists())
    };
    if !have_artifacts {
        eprintln!("WARNING: no artifacts found in ./artifacts — run `make artifacts`; using the in-Rust kernel");
    }

    println!(
        "e2e: power iteration on 27-pt stencil ({} rows, {} nnz), {gpus} GPUs / 2 nodes, {iters} iters, PJRT={}",
        a.nrows,
        a.nnz(),
        have_artifacts
    );

    // Run the full workload with Split+MD (the paper's winner) through the
    // persistent engine: workers + PJRT executables built once, reused
    // every iteration (see EXPERIMENTS.md §Perf for the before/after vs
    // the one-shot path).
    let strategy = Strategy::new(StrategyKind::SplitMd, Transport::Staged)?;
    let cfg = SpmvConfig { use_pjrt: have_artifacts, artifacts_dir: artifacts.clone(), ..Default::default() };
    let eng_cfg =
        hetcomm::coordinator::EngineConfig { use_pjrt: have_artifacts, artifacts_dir: artifacts, ..Default::default() };
    let v0 = vec![1f32; a.nrows];
    let t0 = std::time::Instant::now();
    let mut engine = hetcomm::coordinator::Engine::new(&a, gpus, &machine, strategy, &v0, eng_cfg)?;
    let (v, lambda) = engine.power_iterate(&v0, iters)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    let (t_ex, t_cp) = (stats.wall_exchange, stats.wall_compute);

    // Residual check against the serial oracle.
    let av = a.spmv(&v);
    let mut resid = 0f32;
    for (x, y) in av.iter().zip(&v) {
        resid = resid.max((x - lambda * y).abs());
    }
    let rel = resid / lambda;
    println!("\nlambda = {lambda:.5}   residual(inf) = {resid:.4} (relative {rel:.4})   wall = {wall:.3}s");
    println!("exchange wall = {t_ex:.4}s   compute wall = {t_cp:.4}s");
    anyhow::ensure!(rel < 0.05, "power iteration failed to converge (relative residual {rel})");

    // Per-strategy simulated communication for the same workload — the
    // headline comparison.
    let mut t = Table::new(
        "Simulated (Lassen-calibrated) halo-exchange time per iteration",
        &["strategy", "sim comm [s]", "inter-node msgs"],
    );
    let mut best = ("", f64::INFINITY);
    for s in Strategy::all() {
        let d = DistSpmv::new(&a, gpus, &machine, s, SpmvConfig { verify: false, ..cfg.clone() })?;
        let sim = d.sim_report.total;
        t.row(vec![s.label().to_string(), fmt_secs(sim), d.sim_report.internode_msgs.to_string()]);
        if sim < best.1 {
            best = (s.label(), sim);
        }
    }
    t.print();
    println!("\nheadline: fastest strategy for this workload = {} ({})", best.0, fmt_secs(best.1));
    Ok(())
}
