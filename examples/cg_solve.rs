//! Conjugate-gradient solve over the distributed SpMV engine — the sparse
//! iterative-solver workload the paper's introduction motivates (and the
//! setting of the companion enlarged-CG paper [16]).
//!
//! Each CG iteration performs exactly one distributed SpMV (`w = A·p`)
//! through the persistent engine's strategy-shaped halo exchange; vector
//! updates and dot products run on the leader. The example solves a 2D
//! Poisson problem to 1e-6 relative residual per strategy and reports
//! iteration counts (identical — the exchange is exact) plus wall and
//! simulated communication time.
//!
//! ```bash
//! cargo run --release --example cg_solve
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, Engine, EngineConfig, SpmvConfig};
use hetcomm::sparse::gen;
use hetcomm::topology::machines;

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// CG on SPD `A` with the matvec routed through the engine. Returns
/// (iterations, final relative residual).
fn cg(engine: &mut Engine, b: &[f32], tol: f64, max_iters: usize) -> anyhow::Result<(usize, f64)> {
    let n = b.len();
    let mut x = vec![0f32; n];
    let mut r = b.to_vec(); // r = b - A*0
    let mut p = r.clone();
    let mut rr = dot(&r, &r);
    let b_norm = dot(b, b).sqrt().max(1e-30);
    for k in 0..max_iters {
        if rr.sqrt() / b_norm < tol {
            return Ok((k, rr.sqrt() / b_norm));
        }
        let ap = engine.iterate(Some(&p))?;
        let alpha = rr / dot(&p, &ap).max(1e-300);
        for i in 0..n {
            x[i] += (alpha * p[i] as f64) as f32;
            r[i] -= (alpha * ap[i] as f64) as f32;
        }
        let rr_new = dot(&r, &r);
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + (beta * p[i] as f64) as f32;
        }
        rr = rr_new;
    }
    Ok((max_iters, rr.sqrt() / b_norm))
}

fn main() -> anyhow::Result<()> {
    // 2D Poisson (5-pt Laplacian) — SPD, the canonical CG target.
    let a = gen::stencil_5pt(48, 48);
    let machine = machines::lassen(2);
    let gpus = 8;
    let mut b = vec![0f32; a.nrows];
    for (i, x) in b.iter_mut().enumerate() {
        *x = ((i % 23) as f32 - 11.0) / 11.0;
    }
    println!("CG solve: 5-pt Poisson, {} unknowns, {gpus} GPUs / 2 nodes, tol 1e-6", a.nrows);

    let mut t = Table::new(
        "Distributed CG per communication strategy",
        &["strategy", "iters", "rel resid", "wall [s]", "sim comm/iter [s]"],
    );
    for kind in StrategyKind::ALL {
        let strategy = Strategy::new(kind, Transport::Staged)?;
        // Simulated per-iteration comm time for the same pattern.
        let sim = DistSpmv::new(&a, gpus, &machine, strategy, SpmvConfig { verify: false, ..Default::default() })?
            .sim_report
            .total;
        let t0 = std::time::Instant::now();
        let mut engine = Engine::new(&a, gpus, &machine, strategy, &b, EngineConfig::default())?;
        let (iters, resid) = cg(&mut engine, &b, 1e-6, 500)?;
        let wall = t0.elapsed().as_secs_f64();
        anyhow::ensure!(resid < 1e-6, "{}: CG did not converge (resid {resid})", strategy.label());
        t.row(vec![strategy.label().to_string(), iters.to_string(), format!("{resid:.2e}"), format!("{wall:.3}"), fmt_secs(sim)]);
    }
    t.print();
    println!("\nAll strategies take the same iteration count: the halo exchange is exact,\nonly the (simulated) communication cost differs.");
    Ok(())
}
