//! Strategy sweep: reproduce the shape of Figure 4.3 from the command line —
//! modeled time for every strategy across message sizes, for small/large
//! message counts and destination-node counts, with and without duplicate
//! data.
//!
//! ```bash
//! cargo run --release --example strategy_sweep
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::Strategy;
use hetcomm::model::StrategyModel;
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::{Scenario, TwoStepCase};
use hetcomm::topology::machines;

fn main() {
    let machine = machines::lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    let sizes: Vec<usize> = (0..=20).step_by(2).map(|e| 1usize << e).collect();

    for &n_msgs in &[32usize, 256] {
        for &n_dest in &[4usize, 16] {
            for &dup in &[0.0f64, 0.25] {
                let strategies = Strategy::all();
                let mut header: Vec<String> = vec!["size[B]".into()];
                header.extend(strategies.iter().map(|s| s.label()));
                header.push("2-Step 1 (DA)".into());
                header.push("best".into());
                let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
                let mut t = Table::new(
                    format!("{n_msgs} inter-node msgs -> {n_dest} nodes, dup {:.0}%", dup * 100.0),
                    &hdr,
                );
                for &size in &sizes {
                    let sc = Scenario { n_msgs, msg_size: size, n_dest, dup_frac: dup };
                    let inputs = sc.inputs(&machine, machine.cores_per_node());
                    let mut row = vec![size.to_string()];
                    let mut best = (String::new(), f64::INFINITY);
                    for &s in &strategies {
                        let time = sm.time(s, &inputs);
                        row.push(fmt_secs(time));
                        if time < best.1 {
                            best = (s.label(), time);
                        }
                    }
                    // The 2-Step best case ("2-Step 1") of Section 4.6.
                    let one = sc.inputs_two_step(&machine, machine.cores_per_node(), TwoStepCase::One);
                    let two_da = Strategy::new(
                        hetcomm::comm::StrategyKind::TwoStep,
                        hetcomm::comm::Transport::DeviceAware,
                    )
                    .unwrap();
                    row.push(fmt_secs(sm.time(two_da, &one)));
                    row.push(best.0);
                    t.row(row);
                }
                t.print();
            }
        }
    }
    println!("\n(compare the `best` column with the circled minima of Figure 4.3)");
}
