//! Strategy sweep: reproduce the shape of Figure 4.3 from the command line
//! through the parallel sweep engine — modeled time for every strategy
//! across message sizes, for small/large message counts and
//! destination-node counts, with and without duplicate data, plus the
//! derived crossover and regime-winner report.
//!
//! ```bash
//! cargo run --release --example strategy_sweep
//! ```

use hetcomm::sweep::{emit, run_sweep, GridSpec, PatternGen, SweepConfig};

fn main() {
    let sizes: Vec<usize> = (0..=20).step_by(2).map(|e| 1usize << e).collect();
    for &n_msgs in &[32usize, 256] {
        for &dup in &[0.0f64, 0.25] {
            let config = SweepConfig {
                grid: GridSpec {
                    gens: vec![PatternGen::Uniform],
                    dest_nodes: vec![4, 16],
                    gpus_per_node: vec![4],
                    nics: vec![1],
                    sizes: sizes.clone(),
                    n_msgs,
                    dup_frac: dup,
                },
                // Figure 4.3 is a pure model study: skip the simulator so
                // the example stays instant.
                sim: false,
                ..Default::default()
            };
            let result = run_sweep(&config).expect("valid sweep config");
            print!("{}", emit::render_tables(&result));
        }
    }
    println!("\n(compare the `model winner` column with the circled minima of Figure 4.3)");
}
