//! SpMV communication benchmark across the Section 5 matrix set — the
//! Figure 5.1 experiment: for each SuiteSparse proxy and GPU count, the
//! simulated communication time of every strategy, with the minimum marked.
//!
//! ```bash
//! cargo run --release --example spmv_bench [-- --scale 64]
//! ```

use hetcomm::bench::{fmt_bytes, fmt_secs, Table};
use hetcomm::comm::{build_schedule, Strategy, StrategyKind};
use hetcomm::params::lassen_params;
use hetcomm::sim;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines;
use hetcomm::util::cli::Cli;

fn main() {
    let cli = Cli::new("spmv_bench", "Figure 5.1: SpMV communication across SuiteSparse proxies")
        .flag("scale", "64", "proxy row divisor")
        .flag("gpus", "8,16,32", "GPU counts (comma list)");
    let args = cli.parse_env();
    let scale = args.get_usize("scale").unwrap();
    let gpu_counts = args.get_usize_list("gpus").unwrap();
    let params = lassen_params();

    for info in &suite::MATRICES {
        let mat = suite::proxy(info, scale);
        let strategies = Strategy::all();
        let mut header: Vec<String> = vec!["gpus".into(), "recv-nodes".into(), "msg-vol".into()];
        header.extend(strategies.iter().map(|s| s.label().to_string()));
        header.push("best".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            format!("{} proxy ({} rows, {} nnz)", info.name, mat.nrows, mat.nnz()),
            &hdr,
        );

        for &gpus in &gpu_counts {
            if gpus > mat.nrows {
                continue;
            }
            let nodes = gpus.div_ceil(4).max(2);
            let machine = machines::lassen(nodes);
            let pm = PartitionedMatrix::build(&mat, gpus);
            let pattern = pm.comm_pattern(&machine, 8);
            let stats = pattern.stats(&machine);

            let mut row = vec![
                gpus.to_string(),
                stats.num_in_nodes.to_string(),
                fmt_bytes(stats.total_internode_bytes),
            ];
            let mut best = ("", f64::INFINITY);
            for &s in &strategies {
                let ppn = match s.kind {
                    StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
                    _ => machine.gpus_per_node() * s.kind.ppg(),
                };
                let sched = build_schedule(s, &machine, &pattern);
                let time = sim::run(&machine, &params, &sched, ppn).total;
                row.push(fmt_secs(time));
                if time < best.1 {
                    best = (s.label(), time);
                }
            }
            row.push(best.0.to_string());
            t.row(row);
        }
        t.print();
    }
    println!("\n(the `best` column should be dominated by staged node-aware strategies,\n typically Split+MD — compare with Figure 5.1's circled minima)");
}
