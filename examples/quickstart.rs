//! Quickstart: partition a small sparse matrix over simulated GPUs, run one
//! distributed SpMV with each communication strategy, and print the
//! Lassen-calibrated communication times next to the real data-plane wall
//! time.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::sparse::gen;
use hetcomm::topology::machines;

fn main() -> anyhow::Result<()> {
    // A 3D 27-point stencil: the unstructured-mesh-like workload the paper's
    // introduction motivates.
    let a = gen::stencil_27pt(8, 8, 8);
    println!("matrix: 27-pt stencil, {} rows, {} nnz", a.nrows, a.nnz());

    // Two Lassen nodes, four GPUs each.
    let machine = machines::lassen(2);
    let gpus = 8;

    let mut v = vec![0f32; a.nrows];
    for (i, x) in v.iter_mut().enumerate() {
        *x = (i as f32).sin();
    }

    let mut table = Table::new(
        format!("Distributed SpMV halo exchange over {gpus} GPUs / 2 nodes"),
        &["strategy", "sim comm [s]", "wall comm [s]", "msgs", "verified"],
    );

    for kind in StrategyKind::ALL {
        let strategy = Strategy::new(kind, Transport::Staged)?;
        let dist = DistSpmv::new(&a, gpus, &machine, strategy, SpmvConfig::default())?;
        let report = dist.run(&v, 1)?;
        anyhow::ensure!(report.verified == Some(true), "{} failed verification", strategy.label());
        table.row(vec![
            strategy.label().to_string(),
            fmt_secs(report.sim_exchange_per_iter),
            fmt_secs(report.wall_exchange),
            report.msgs_per_iter.to_string(),
            "yes".into(),
        ]);
    }
    table.print();

    println!("\nAll strategies delivered the exact same SpMV result as the serial oracle.");
    Ok(())
}
