//! Bench: the win from online strategy adaptation on evolving workloads.
//!
//! Every built-in trace scenario is synthesized and replayed three ways —
//! adaptively (exact Table 6 advisor), adaptively through a compiled
//! decision surface, and under the best single static strategy — and the
//! cumulative modeled times are compared. Wall-clock for the replay engine
//! itself is reported per scenario (epochs x 8 strategies of model
//! evaluation plus advice).
//!
//! ```bash
//! cargo bench --bench replay
//! ```

use hetcomm::advisor::{DecisionSurface, SurfaceAxes};
use hetcomm::bench::{fmt_secs, Table};
use hetcomm::trace::replay::{replay, ReplayConfig, ReplayMode, ReplayReport};
use hetcomm::trace::scenarios::{synthesize, TraceScenario};
use std::time::Instant;

fn main() {
    let surface = DecisionSurface::compile("lassen", SurfaceAxes::default_axes(), 0.0).expect("default axes compile");
    let config = ReplayConfig::default();
    let mut t = Table::new("Adaptive replay vs static baselines (modeled, lassen)", &[
        "scenario", "epochs", "iters", "switches", "adaptive", "best static", "worst static", "win best",
        "win worst", "wall[ms]",
    ]);
    for scenario in TraceScenario::ALL {
        let trace = synthesize(scenario, "lassen", 5, 0, 42).expect("registry scenario");
        let t0 = Instant::now();
        let exact = replay(&trace, &ReplayMode::Adaptive { surface: None }, &config).expect("replay");
        let wall = t0.elapsed().as_secs_f64();
        let surf = replay(&trace, &ReplayMode::Adaptive { surface: Some(&surface) }, &config).expect("replay");
        if scenario == TraceScenario::AmrDrift {
            // every amr-drift plateau sits on the default lattice, so the
            // surface and the exact ranking must pick identically
            let picks = |r: &ReplayReport| r.rows.iter().map(|x| x.strategy.label()).collect::<Vec<_>>();
            assert_eq!(picks(&exact), picks(&surf), "on-lattice advice must agree");
            assert_eq!(exact.total_s.to_bits(), surf.total_s.to_bits());
        }
        t.row(vec![
            scenario.label().to_string(),
            trace.epochs.len().to_string(),
            exact.iterations.to_string(),
            exact.switches.len().to_string(),
            fmt_secs(exact.total_s),
            fmt_secs(exact.best_static.total_s),
            fmt_secs(exact.worst_static.total_s),
            format!("{:+.2}%", exact.win_vs_best_static * 100.0),
            format!("{:+.2}%", exact.win_vs_worst_static * 100.0),
            format!("{:.2}", wall * 1e3),
        ]);
    }
    t.print();
    println!(
        "\nClaims to check:\n  - amr-drift / sparsify / halo-burst cross regimes: switches > 0 and a positive win\n  - stationary / rebalance stay on one winner: win vs best static is exactly 0\n  - adaptive never loses to the best static strategy on any scenario"
    );
}
