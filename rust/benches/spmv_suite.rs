//! Bench: distributed SpMV communication across the Section 5 matrix set —
//! **Figure 5.1**: per matrix and GPU count, the simulated communication
//! time of every strategy (staged solid / device-aware dashed in the paper;
//! columns here), plus the real data-plane verification through the
//! coordinator for one strategy per matrix.
//!
//! ```bash
//! cargo bench --bench spmv_suite
//! ```

use hetcomm::bench::{fmt_bytes, fmt_secs, Table};
use hetcomm::comm::{build_schedule, Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::params::lassen_params;
use hetcomm::sim;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines::lassen;

fn main() {
    let params = lassen_params();
    let scale = 64;
    let gpu_counts = [8usize, 16, 32, 64];
    let mut split_md_wins = 0usize;
    let mut staged_wins = 0usize;
    let mut rows = 0usize;

    for info in &suite::MATRICES {
        let mat = suite::proxy(info, scale);
        let strategies = Strategy::all();
        let mut header: Vec<String> = vec!["gpus".into(), "recv-nodes".into(), "IN vol".into()];
        header.extend(strategies.iter().map(|s| s.label().to_string()));
        header.push("min".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(format!("Figure 5.1 — {} proxy ({} rows, {} nnz)", info.name, mat.nrows, mat.nnz()), &hdr);

        for &gpus in &gpu_counts {
            if gpus * 8 > mat.nrows {
                continue;
            }
            let nodes = gpus.div_ceil(4).max(2);
            let machine = lassen(nodes);
            let pm = PartitionedMatrix::build(&mat, gpus);
            let pattern = pm.comm_pattern(&machine, 8);
            let stats = pattern.stats(&machine);
            let mut row =
                vec![gpus.to_string(), stats.num_in_nodes.to_string(), fmt_bytes(stats.total_internode_bytes)];
            let mut best = ("", f64::INFINITY, Transport::Staged, StrategyKind::Standard);
            for &s in &strategies {
                let ppn = match s.kind {
                    StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
                    _ => machine.gpus_per_node() * s.kind.ppg(),
                };
                let sched = build_schedule(s, &machine, &pattern);
                let time = sim::run(&machine, &params, &sched, ppn).total;
                row.push(fmt_secs(time));
                if time < best.1 {
                    best = (s.label(), time, s.transport, s.kind);
                }
            }
            row.push(best.0.to_string());
            t.row(row);
            rows += 1;
            if best.3 == StrategyKind::SplitMd {
                split_md_wins += 1;
            }
            if best.2 == Transport::Staged {
                staged_wins += 1;
            }
        }
        t.print();

        // Real data-plane spot check: run the winner through the
        // coordinator and verify against the serial oracle.
        let machine = lassen(2);
        let strategy = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        let d = DistSpmv::new(&mat, 8, &machine, strategy, SpmvConfig::default()).expect("setup");
        let mut v = vec![0f32; mat.nrows];
        for (i, x) in v.iter_mut().enumerate() {
            *x = ((i * 31 % 97) as f32 - 48.0) / 48.0;
        }
        let rep = d.run(&v, 1).expect("run");
        println!(
            "  data-plane check ({}): verified={:?} max_err={:.2e} wall_exchange={:.4}s",
            info.name, rep.verified, rep.max_abs_err, rep.wall_exchange
        );
        assert_eq!(rep.verified, Some(true), "{} data plane diverged", info.name);
    }

    println!(
        "\nsummary over {rows} (matrix, gpu-count) cells:\n  staged strategy fastest: {staged_wins}/{rows}\n  Split+MD fastest:        {split_md_wins}/{rows}\n(the paper reports staged node-aware — typically Split+MD — fastest in most cases)"
    );
}
