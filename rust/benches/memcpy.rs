//! Bench: cudaMemcpyAsync splitting — copy a volume off one GPU with NP
//! simultaneous host processes. Regenerates **Figure 3.1** (H2D + D2H vs
//! size per NP) and prints the **Table 3** parameter classes behind it.
//!
//! ```bash
//! cargo bench --bench memcpy
//! ```

use hetcomm::bench::{fmt_bytes, fmt_secs, Table};
use hetcomm::comm::CopyKind;
use hetcomm::params::lassen_params;
use hetcomm::sim::network::memcpy_split;
use hetcomm::topology::machines::lassen;

fn main() {
    let machine = lassen(1);
    let params = lassen_params();
    let nps = [1usize, 2, 4];
    let sizes: Vec<usize> = (10..=26).step_by(2).map(|e| 1usize << e).collect();

    for (dir, name) in [(CopyKind::D2H, "DeviceToHost (D2H)"), (CopyKind::H2D, "HostToDevice (H2D)")] {
        let mut header: Vec<String> = vec!["size".into()];
        header.extend(nps.iter().map(|np| format!("NP={np}")));
        header.push("best NP".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(format!("Figure 3.1 — {name} copy time vs size (simulated)"), &hdr);
        for &s in &sizes {
            let mut row = vec![fmt_bytes(s)];
            let mut best = (0usize, f64::INFINITY);
            for &np in &nps {
                let time = memcpy_split(&machine, &params, dir, s, np);
                row.push(fmt_secs(time));
                if time < best.1 {
                    best = (np, time);
                }
            }
            row.push(format!("NP={}", best.0));
            t.row(row);
        }
        t.print();
    }

    println!(
        "\nTable 3 (the parameter classes behind the curves):\n  1 proc: H2D a=1.30e-5 b=1.85e-11 | D2H a=1.27e-5 b=1.96e-11\n  4 proc: H2D a=1.52e-5 b=5.52e-10 | D2H a=1.47e-5 b=1.50e-10\n(the paper observed no benefit beyond 4 processes — NP>4 reuses the 4-proc class)"
    );
    let _ = params;
}
