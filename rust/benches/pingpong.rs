//! Bench: ping-pong times per locality and protocol — regenerates
//! **Figure 2.5** and re-fits the **Table 2** parameters from simulated
//! measurements (the BenchPress pipeline of Section 3).
//!
//! ```bash
//! cargo bench --bench pingpong
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::params::fit::{fit_protocol_bands, Sample};
use hetcomm::params::{lassen_params, Endpoint};
use hetcomm::sim::network::pingpong;
use hetcomm::topology::Locality;

fn main() {
    let params = lassen_params();
    let sizes: Vec<usize> = (0..=24).map(|e| 1usize << e).collect();
    let locs = [Locality::OnSocket, Locality::OnNode, Locality::OffNode];

    // -------- Figure 2.5: time vs size per locality (CPU and GPU) --------
    let mut fig = Table::new(
        "Figure 2.5 — ping-pong time vs size (simulated, Lassen parameters)",
        &["size[B]", "cpu on-socket", "cpu on-node", "cpu off-node", "gpu on-socket", "gpu on-node", "gpu off-node"],
    );
    for &s in &sizes {
        let mut row = vec![s.to_string()];
        for ep in [Endpoint::Cpu, Endpoint::Gpu] {
            for loc in locs {
                row.push(fmt_secs(pingpong(&params, ep, loc, s)));
            }
        }
        fig.row(row);
    }
    fig.print();

    // The paper's observation: the network beats on-node for large sizes.
    let big = 1 << 20;
    let on = pingpong(&params, Endpoint::Cpu, Locality::OnNode, big);
    let off = pingpong(&params, Endpoint::Cpu, Locality::OffNode, big);
    println!("\nlarge-message crossover (1 MiB): on-node {} vs off-node {} -> network {}", fmt_secs(on), fmt_secs(off), if off < on { "WINS (matches Fig 2.5)" } else { "loses (MISMATCH)" });

    // -------- Table 2 round-trip: re-fit alpha/beta from the samples ------
    let mut t2 = Table::new(
        "Table 2 round-trip — least-squares fit of simulated ping-pong vs measured constants",
        &["path", "protocol", "alpha fit", "alpha ref", "beta fit", "beta ref", "r2"],
    );
    for (ep, ep_name) in [(Endpoint::Cpu, "CPU"), (Endpoint::Gpu, "GPU")] {
        for loc in locs {
            let samples: Vec<Sample> =
                sizes.iter().map(|&s| Sample { bytes: s, seconds: pingpong(&params, ep, loc, s) }).collect();
            let (short_max, eager_max) = match ep {
                Endpoint::Cpu => (params.short_max, params.eager_max + 1),
                Endpoint::Gpu => (0, params.gpu_eager_max + 1),
            };
            let fits = fit_protocol_bands(&samples, short_max, eager_max);
            for (fit, proto) in fits.iter().zip(["short", "eager", "rend"]) {
                let Some(fit) = fit else { continue };
                let reference = match (ep, proto) {
                    (Endpoint::Cpu, "short") => params.cpu_ab(hetcomm::params::Protocol::Short, loc),
                    (Endpoint::Cpu, "eager") => params.cpu_ab(hetcomm::params::Protocol::Eager, loc),
                    (Endpoint::Cpu, _) => params.cpu_ab(hetcomm::params::Protocol::Rendezvous, loc),
                    (Endpoint::Gpu, "eager") => params.gpu_ab(hetcomm::params::Protocol::Eager, loc),
                    (Endpoint::Gpu, _) => params.gpu_ab(hetcomm::params::Protocol::Rendezvous, loc),
                };
                t2.row(vec![
                    format!("{ep_name} {loc}"),
                    proto.into(),
                    format!("{:.3e}", fit.ab.alpha),
                    format!("{:.3e}", reference.alpha),
                    format!("{:.3e}", fit.ab.beta),
                    format!("{:.3e}", reference.beta),
                    format!("{:.4}", fit.r2),
                ]);
            }
        }
    }
    t2.print();
    println!("\n(fitted parameters should round-trip to the Table 2 constants: the simulator\n is calibrated from exactly these values)");
}
