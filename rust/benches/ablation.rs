//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Message cap sweep** — Split's `message_cap` is set to the
//!    rendezvous switch point (8 KiB) in the paper [16]; sweep caps to show
//!    that choice is (near-)optimal.
//! 2. **ppn sweep** — Split enlists all 40 cores on Lassen; sweep the
//!    process count to show where the benefit saturates.
//! 3. **Block-vector scaling** — sparse matrix-*block*-vector products
//!    multiply every payload by the block size; the Split-vs-standard gap
//!    grows with block size (the regime where [16] reports up to 60×).
//! 4. **Exascale outlook (Section 6)** — query the advisor's *compiled*
//!    decision surfaces for Frontier-like (single socket, 64 cores) and
//!    Delta-like (128 cores) nodes with scaled interconnect bandwidth,
//!    instead of re-evaluating the Table 6 models inline: Split strategies
//!    should remain the most efficient.
//!
//! ```bash
//! cargo bench --bench ablation
//! ```

use hetcomm::advisor::{DecisionSurface, Pattern, SurfaceAxes};
use hetcomm::bench::{fmt_bytes, fmt_secs, Table};
use hetcomm::comm::{build_schedule, Strategy, StrategyKind, Transport};
use hetcomm::params::lassen_params;
use hetcomm::sim;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines::{self, lassen};

fn main() {
    cap_sweep();
    ppn_sweep();
    block_vector_scaling();
    exascale_outlook();
}

/// 1. message_cap sweep on the audikw_1 pattern.
fn cap_sweep() {
    let params = lassen_params();
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, 64);
    let machine = lassen(8);
    let pm = PartitionedMatrix::build(&mat, 32);
    let pattern = pm.comm_pattern(&machine, 8);

    let mut t = Table::new(
        "Ablation 1 — Split+MD message cap sweep (audikw_1, 32 GPUs)",
        &["cap", "sim[s]", "inter-node msgs"],
    );
    let mut best = (0usize, f64::INFINITY);
    for cap in [512usize, 1024, 2048, 4096, 8192, 16384, 32768, 65536] {
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap().with_cap(cap);
        let sched = build_schedule(s, &machine, &pattern);
        let rep = sim::run(&machine, &params, &sched, machine.cores_per_node());
        t.row(vec![fmt_bytes(cap), fmt_secs(rep.total), rep.internode_msgs.to_string()]);
        if rep.total < best.1 {
            best = (cap, rep.total);
        }
    }
    t.print();
    println!(
        "best cap: {} — the paper [16] uses the 8 KiB rendezvous switch; within noise of optimal here",
        fmt_bytes(best.0)
    );
}

/// 2. How many on-node cores does Split actually need? Simulated on
/// Lassen-like machines whose core count varies (the schedule builder
/// enlists every core): the off-node term is NIC-floored for >= 2 active
/// senders, so the core-count benefit comes from chunk distribution.
fn ppn_sweep() {
    let params = lassen_params();
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, 64);

    let mut t = Table::new(
        "Ablation 2 — Split+MD simulated time vs cores per node (audikw_1, 32 GPUs)",
        &["cores/node", "sim[s]", "inter-node msgs"],
    );
    let mut rows = Vec::new();
    for cores_per_socket in [2usize, 4, 8, 12, 16, 20] {
        let mut machine = lassen(8);
        machine.cores_per_socket = cores_per_socket;
        let pm = PartitionedMatrix::build(&mat, 32);
        let pattern = pm.comm_pattern(&machine, 8);
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        let sched = build_schedule(s, &machine, &pattern);
        let rep = sim::run(&machine, &params, &sched, machine.cores_per_node());
        t.row(vec![machine.cores_per_node().to_string(), fmt_secs(rep.total), rep.internode_msgs.to_string()]);
        rows.push((machine.cores_per_node(), rep.total));
    }
    t.print();
    let (best_cores, _) = rows.iter().fold((0, f64::INFINITY), |acc, &(c, t)| if t < acc.1 { (c, t) } else { acc });
    println!("fastest at {best_cores} cores/node — Section 6: higher core counts favor Split");
}

/// 3. Block-vector products: payloads scale by block size.
fn block_vector_scaling() {
    let params = lassen_params();
    let info = suite::info("thermal2").unwrap();
    let mat = suite::proxy(info, 64);
    let machine = lassen(8);
    let pm = PartitionedMatrix::build(&mat, 32);

    let mut t = Table::new(
        "Ablation 3 — SpM-block-vector: Split+MD speedup over standard staged vs block size",
        &["block", "standard[s]", "split+md[s]", "speedup"],
    );
    for block in [1usize, 2, 4, 8, 16, 32] {
        let pattern = pm.comm_pattern(&machine, 8 * block);
        let t_std = {
            let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
            sim::run(&machine, &params, &build_schedule(s, &machine, &pattern), machine.gpus_per_node()).total
        };
        let t_split = {
            let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
            sim::run(&machine, &params, &build_schedule(s, &machine, &pattern), machine.cores_per_node()).total
        };
        t.row(vec![
            block.to_string(),
            fmt_secs(t_std),
            fmt_secs(t_split),
            format!("{:.2}x", t_std / t_split),
        ]);
    }
    t.print();
    println!("(the Split advantage grows with block size — the regime where [16] reports up to 60x)");
}

/// 4. Section 6 outlook: exascale-like nodes, answered by the advisor's
/// compiled surfaces (the registry scales the Lassen baseline per machine:
/// frontier-like 0.8x latency / 4x bandwidth, delta-like 2x bandwidth).
fn exascale_outlook() {
    let sizes = [1024usize, 16384, 262144];
    let axes = SurfaceAxes {
        msgs: vec![64, 256],
        sizes: sizes.to_vec(),
        dest_nodes: vec![16],
        gpus_per_node: vec![4],
    };
    let mut t = Table::new(
        "Ablation 4 — Section 6 outlook: advisor surface winners on future nodes (256 msgs -> 16 nodes)",
        &["machine", "cores/node", "size[B]", "best strategy", "modeled[s]"],
    );
    for name in ["lassen", "frontier-like", "frontier-4nic", "delta-like"] {
        let surface = DecisionSurface::compile(name, axes.clone(), 0.0).expect("registry machine compiles");
        let (arch, _) = machines::parse(name, 1).expect("registry machine resolves");
        for size in sizes {
            let query = Pattern { n_msgs: 256, msg_size: size, dest_nodes: 16, gpus_per_node: 4 };
            let (best, secs) = surface.lookup(&query).best();
            t.row(vec![
                name.to_string(),
                arch.cores_per_node().to_string(),
                size.to_string(),
                best.label().to_string(),
                fmt_secs(secs),
            ]);
        }
    }
    t.print();
    println!(
        "(Section 6 prediction: Split strategies exploit high core counts + high-bandwidth\n interconnects on Frontier/El Capitan/Delta-class nodes)"
    );
}
