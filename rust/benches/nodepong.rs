//! Bench: node-pong — total volume split across ppn process pairs between
//! two nodes. Regenerates **Figure 2.6** (with circled minima) and re-fits
//! the **Table 4** injection-bandwidth limit.
//!
//! ```bash
//! cargo bench --bench nodepong
//! ```

use hetcomm::bench::{fmt_bytes, fmt_secs, Table};
use hetcomm::params::fit::{fit_inv_rn, Sample};
use hetcomm::params::lassen_params;
use hetcomm::sim::network::{best_ppn, nodepong};
use hetcomm::topology::machines::lassen;

fn main() {
    let machine = lassen(2);
    let params = lassen_params();
    let ppns = [1usize, 2, 4, 8, 16, 32, 40];
    let volumes: Vec<usize> = (10..=24).step_by(2).map(|e| 1usize << e).collect();

    let mut header: Vec<String> = vec!["volume".into()];
    header.extend(ppns.iter().map(|p| format!("ppn={p}")));
    header.push("best".into());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut fig = Table::new("Figure 2.6 — node-pong: volume split across ppn pairs (simulated)", &hdr);

    for &vol in &volumes {
        let mut row = vec![fmt_bytes(vol)];
        for &ppn in &ppns {
            row.push(fmt_secs(nodepong(&machine, &params, vol, ppn)));
        }
        let best = best_ppn(&machine, &params, vol, &ppns);
        row.push(format!("ppn={best}")); // the circled minimum
        fig.row(row);
    }
    fig.print();

    // -------- Table 4 round-trip: fit 1/R_N at saturation ---------------
    // At ppn=40 and large volumes the NIC injection limit dominates; the
    // slope of time vs volume recovers 1/R_N.
    let samples: Vec<Sample> = (20..=26)
        .map(|e| {
            let v = 1usize << e;
            Sample { bytes: v, seconds: nodepong(&machine, &params, v, 40) }
        })
        .collect();
    let inv_rn = fit_inv_rn(&samples);
    println!(
        "\nTable 4 round-trip: fitted 1/R_N = {:.3e} s/B vs measured {:.3e} s/B (x{:.3})",
        inv_rn,
        params.inv_rn,
        inv_rn / params.inv_rn
    );
}
