//! L3 hot-path wall-clock benches (§Perf): schedule compilation,
//! discrete-event execution, exchange-plan compilation, and the real data
//! plane — one-shot vs the persistent engine, with and without overlap.
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```

use hetcomm::bench::{bench, fmt_secs, Table};
use hetcomm::comm::{build_schedule, Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, Engine, EngineConfig, ExchangePlan, SpmvConfig};
use hetcomm::params::lassen_params;
use hetcomm::sim;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines::lassen;

fn main() {
    let params = lassen_params();
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, 64);
    let machine = lassen(8);
    let pm = PartitionedMatrix::build(&mat, 32);
    let pattern = pm.comm_pattern(&machine, 8);
    let split = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();

    let mut t = Table::new("L3 hot paths (real wall-clock, audikw_1 proxy, 32 GPUs)", &[
        "path", "median[s]", "p95[s]", "n",
    ]);

    // pattern extraction
    let s1 = bench(2, 10, || {
        std::hint::black_box(pm.comm_pattern(&machine, 8));
    });
    t.row(vec!["comm_pattern extraction".into(), fmt_secs(s1.median), fmt_secs(s1.p95), s1.n.to_string()]);

    // schedule build per strategy
    for s in [Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap(), split] {
        let st = bench(2, 10, || {
            std::hint::black_box(build_schedule(s, &machine, &pattern));
        });
        t.row(vec![format!("schedule build [{}]", s.label()), fmt_secs(st.median), fmt_secs(st.p95), st.n.to_string()]);
    }

    // simulator execution: one-call wrapper vs the scratch-reusing hot path
    let sched = build_schedule(split, &machine, &pattern);
    let ss = bench(2, 10, || {
        std::hint::black_box(sim::run(&machine, &params, &sched, machine.cores_per_node()));
    });
    t.row(vec!["sim::run (split schedule)".into(), fmt_secs(ss.median), fmt_secs(ss.p95), ss.n.to_string()]);
    let compiled_params = params.compile();
    let mut scratch = sim::Scratch::new();
    let sc = bench(2, 10, || {
        std::hint::black_box(scratch.run_total(&machine, &compiled_params, &sched, machine.cores_per_node()));
    });
    t.row(vec!["sim scratch.run_total (reused buffers)".into(), fmt_secs(sc.median), fmt_secs(sc.p95), sc.n.to_string()]);
    let sr = bench(2, 10, || {
        std::hint::black_box(sim::run_reference(&machine, &params, &sched, machine.cores_per_node()));
    });
    t.row(vec!["sim::run_reference (hash-map executor)".into(), fmt_secs(sr.median), fmt_secs(sr.p95), sr.n.to_string()]);

    // exchange-plan compilation
    let sp = bench(1, 5, || {
        std::hint::black_box(ExchangePlan::build(&pm, &machine, split));
    });
    t.row(vec!["ExchangePlan::build".into(), fmt_secs(sp.median), fmt_secs(sp.p95), sp.n.to_string()]);

    // data plane: one-shot vs persistent engine (8 workers, smaller matrix
    // for thread-spawn fairness)
    let small = suite::proxy(suite::info("thermal2").unwrap(), 256);
    let machine2 = lassen(2);
    let mut v = vec![0f32; small.nrows];
    for (i, x) in v.iter_mut().enumerate() {
        *x = (i as f32).sin();
    }
    let d = DistSpmv::new(&small, 8, &machine2, split, SpmvConfig { verify: false, ..Default::default() }).unwrap();
    let so = bench(1, 8, || {
        d.run(&v, 1).unwrap();
    });
    t.row(vec!["data plane: one-shot run()".into(), fmt_secs(so.median), fmt_secs(so.p95), so.n.to_string()]);

    for overlap in [false, true] {
        let mut eng = Engine::new(&small, 8, &machine2, split, &v, EngineConfig { overlap, ..Default::default() }).unwrap();
        let se = bench(2, 20, || {
            eng.iterate(None).unwrap();
        });
        t.row(vec![
            format!("data plane: engine iterate (overlap={overlap})"),
            fmt_secs(se.median),
            fmt_secs(se.p95),
            se.n.to_string(),
        ]);
        drop(eng);
    }

    t.print();
    println!("\n(§Perf targets: engine iterate well below one-shot run; schedule build and\n sim::run linear in message count — see EXPERIMENTS.md §Perf)");
}
