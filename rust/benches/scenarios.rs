//! Bench: modeled performance for the common irregular scenarios —
//! **Figure 4.3** (both panels × dedup rows) and the **Table 6** composite
//! models that generate them — driven through the parallel sweep engine,
//! with the engine's wall-clock scaling (1 thread vs all cores) reported.
//!
//! A node sends 256 messages, spread evenly over its 4 GPUs, to 4 or 16
//! destination nodes; message size sweeps 2^0..2^20 B; the dup rows remove
//! 25% duplicate data from the node-aware strategies.
//!
//! ```bash
//! cargo bench --bench scenarios
//! ```

use hetcomm::sweep::{emit, run_sweep, GridSpec, PatternGen, SweepConfig};

fn grid(dup: f64) -> GridSpec {
    GridSpec {
        gens: vec![PatternGen::Uniform, PatternGen::Random],
        dest_nodes: vec![4, 16],
        gpus_per_node: vec![4],
        nics: vec![1],
        sizes: (0..=20).step_by(2).map(|e| 1usize << e).collect(),
        n_msgs: 256,
        dup_frac: dup,
    }
}

fn main() {
    let mut winners: Vec<(String, String)> = Vec::new();

    for &dup in &[0.0f64, 0.25] {
        let config = SweepConfig { grid: grid(dup), sim: true, ..Default::default() };
        let result = run_sweep(&config).expect("valid sweep config");
        print!("{}", emit::render_tables(&result));
        for w in &result.report.winners {
            if w.size == 1024 && w.gen == PatternGen::Uniform {
                winners.push((format!("256 msgs/{} nodes/dup {dup:.2} @1KiB", w.dest_nodes), w.winner.to_string()));
            }
        }

        // Engine scaling: the same grid with one worker thread.
        let serial = SweepConfig { threads: 1, ..config.clone() };
        let serial_result = run_sweep(&serial).expect("valid sweep config");
        println!(
            "\nsweep wall-clock (dup {:.0}%): {} threads {:.3}s vs 1 thread {:.3}s ({:.2}x)",
            dup * 100.0,
            result.threads_used,
            result.elapsed_s,
            serial_result.elapsed_s,
            serial_result.elapsed_s / result.elapsed_s.max(1e-9)
        );
    }

    println!("\nHeadline winners at 1 KiB messages (compare with the circled minima of Fig 4.3):");
    for (scenario, winner) in winners {
        println!("  {scenario:40} -> {winner}");
    }
    println!(
        "\nPaper's qualitative claims to check:\n  - staged node-aware strategies win for high message counts up to ~10^4 B\n  - Split+MD takes over for 16 destination nodes\n  - device-aware standard only wins at very large message sizes"
    );
}
