//! Bench: modeled performance for the common irregular scenarios —
//! **Figure 4.3** (all four panels × dedup rows) and the **Table 6**
//! composite models that generate them.
//!
//! A node sends 32 or 256 messages, spread evenly over its 4 GPUs, to 4 or
//! 16 destination nodes; message size sweeps 2^0..2^20 B; the bottom rows
//! remove 25% duplicate data from the node-aware strategies.
//!
//! ```bash
//! cargo bench --bench scenarios
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::model::StrategyModel;
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::{Scenario, TwoStepCase};
use hetcomm::topology::machines::lassen;

fn main() {
    let machine = lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    let sizes: Vec<usize> = (0..=20).step_by(2).map(|e| 1usize << e).collect();
    let strategies = Strategy::all();

    let mut winners: Vec<(String, String)> = Vec::new();

    for &n_msgs in &[32usize, 256] {
        for &n_dest in &[4usize, 16] {
            for &dup in &[0.0f64, 0.25] {
                let mut header: Vec<String> = vec!["size[B]".into()];
                header.extend(strategies.iter().map(|s| s.label()));
                header.push("2-Step 1 (DA)".into());
                header.push("min (excl 2-Step 1)".into());
                let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
                let mut t = Table::new(
                    format!(
                        "Figure 4.3 — {n_msgs} inter-node msgs -> {n_dest} nodes{}",
                        if dup > 0.0 { ", 25% duplicate data removed" } else { "" }
                    ),
                    &hdr,
                );
                for &size in &sizes {
                    let sc = Scenario { n_msgs, msg_size: size, n_dest, dup_frac: dup };
                    let inputs = sc.inputs(&machine, machine.cores_per_node());
                    let mut row = vec![size.to_string()];
                    let mut best = (String::new(), f64::INFINITY);
                    for &s in &strategies {
                        let time = sm.time(s, &inputs);
                        row.push(fmt_secs(time));
                        if time < best.1 {
                            best = (s.label(), time);
                        }
                    }
                    let one = sc.inputs_two_step(&machine, machine.cores_per_node(), TwoStepCase::One);
                    let two_da = Strategy::new(StrategyKind::TwoStep, Transport::DeviceAware).unwrap();
                    row.push(fmt_secs(sm.time(two_da, &one)));
                    row.push(best.0.clone());
                    t.row(row);
                    if size == 1024 {
                        winners.push((format!("{n_msgs} msgs/{n_dest} nodes/dup {dup:.2} @1KiB"), best.0));
                    }
                }
                t.print();
            }
        }
    }

    println!("\nHeadline winners at 1 KiB messages (compare with the circled minima of Fig 4.3):");
    for (scenario, winner) in winners {
        println!("  {scenario:40} -> {winner}");
    }
    println!(
        "\nPaper's qualitative claims to check:\n  - staged node-aware strategies win for high message counts up to ~10^4 B\n  - Split+MD takes over for 16 destination nodes\n  - device-aware standard only wins at very large message sizes"
    );
}
