//! Bench: model validation — **Figure 4.2**: Table 6 model predictions vs
//! the simulated communication time of the audikw_1 SpMV pattern, per
//! strategy, across GPU counts.
//!
//! The paper's criterion: standard models overshoot by about an order of
//! magnitude; node-aware models are tight upper bounds (same order of
//! magnitude).
//!
//! ```bash
//! cargo bench --bench validation
//! ```

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{build_schedule, Strategy, StrategyKind};
use hetcomm::model::StrategyModel;
use hetcomm::params::lassen_params;
use hetcomm::sim;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines::lassen;

fn main() {
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, 64);
    let params = lassen_params();
    println!("audikw_1 proxy: {} rows, {} nnz (density {:.2e})", mat.nrows, mat.nnz(), mat.density());

    let mut t = Table::new(
        "Figure 4.2 — model prediction vs simulated SpMV communication (audikw_1)",
        &["gpus", "strategy", "model[s]", "simulated[s]", "model/sim"],
    );
    let mut tight = 0usize;
    let mut total = 0usize;
    for gpus in [8usize, 16, 32] {
        let nodes = gpus.div_ceil(4).max(2);
        let machine = lassen(nodes);
        let pm = PartitionedMatrix::build(&mat, gpus);
        let pattern = pm.comm_pattern(&machine, 8);
        let dup = pattern.duplicate_fraction(&machine);
        let sm = StrategyModel::new(&machine, &params);
        for s in Strategy::all() {
            let ppn = match s.kind {
                StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
                _ => machine.gpus_per_node() * s.kind.ppg(),
            };
            let inputs = pattern.model_inputs(&machine, ppn, dup);
            let model = sm.time(s, &inputs);
            let sched = build_schedule(s, &machine, &pattern);
            let simd = sim::run(&machine, &params, &sched, ppn).total;
            let ratio = model / simd;
            t.row(vec![gpus.to_string(), s.label().to_string(), fmt_secs(model), fmt_secs(simd), format!("{ratio:.2}")]);
            total += 1;
            // "tight upper bound, generally same order of magnitude"
            if ratio >= 0.3 && ratio <= 12.0 {
                tight += 1;
            }
        }
    }
    t.print();
    println!(
        "\n{tight}/{total} model predictions within one order of magnitude of simulation\n(the paper reports standard models ~10x above measurements and node-aware models tight)"
    );
}
