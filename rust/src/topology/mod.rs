//! Machine topology: nodes, sockets, cores, GPUs and NICs, plus the
//! process/GPU naming scheme used throughout the crate.
//!
//! The paper's testbed (Section 2.1) is Lassen: 2 sockets per node, one
//! IBM Power9 (20 cores) + 2 NVIDIA V100s per socket, EDR InfiniBand.
//! [`machines`] provides that description plus Summit-, Frontier- and
//! Delta-like systems for the Section 6 forward-looking discussion.
//! Every machine carries a [`NodeShape`] — the resource graph of its NIC
//! rails ([`shape`]) — defaulting to the legacy single-rail node.

pub mod machines;
pub mod shape;

pub use shape::NodeShape;

use crate::util::config::{Config, ConfigError};

/// Static description of a (homogeneous) cluster.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Machine {
    pub name: String,
    pub num_nodes: usize,
    pub sockets_per_node: usize,
    /// CPU cores per socket — the upper bound on host processes per socket.
    pub cores_per_socket: usize,
    pub gpus_per_socket: usize,
    /// The node's injection resource graph: NIC rails per socket and the
    /// GPU↔NIC affinity map. [`NodeShape::single_rail`] (the default built
    /// by every preset) reproduces the pre-shape-layer single-NIC node.
    pub shape: NodeShape,
}

/// Relative physical location of two processes or devices — the key that
/// selects an (α, β) row in Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Locality {
    /// Same socket (fastest path).
    OnSocket,
    /// Same node, different sockets.
    OnNode,
    /// Different nodes — traverses the NIC and network.
    OffNode,
}

impl std::fmt::Display for Locality {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Locality::OnSocket => write!(f, "on-socket"),
            Locality::OnNode => write!(f, "on-node"),
            Locality::OffNode => write!(f, "off-node"),
        }
    }
}

/// Identifier of one GPU in the cluster (globally dense numbering:
/// node-major, then socket, then local GPU index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId(pub usize);

/// Identifier of one host process (CPU rank). Globally dense: node-major,
/// then socket, then core.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProcId(pub usize);

/// Identifier of one node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

impl Machine {
    /// GPUs per node.
    pub fn gpus_per_node(&self) -> usize {
        self.sockets_per_node * self.gpus_per_socket
    }

    /// Total GPUs in the cluster.
    pub fn total_gpus(&self) -> usize {
        self.num_nodes * self.gpus_per_node()
    }

    /// CPU cores per node — the maximum `ppn` usable by Split strategies
    /// (40 on Lassen).
    pub fn cores_per_node(&self) -> usize {
        self.sockets_per_node * self.cores_per_socket
    }

    /// Total host processes when running `ppn` processes per node.
    pub fn total_procs(&self, ppn: usize) -> usize {
        assert!(ppn <= self.cores_per_node(), "ppn {ppn} exceeds cores/node {}", self.cores_per_node());
        self.num_nodes * ppn
    }

    /// Node that hosts a GPU.
    pub fn gpu_node(&self, g: GpuId) -> NodeId {
        assert!(g.0 < self.total_gpus(), "gpu {} out of range", g.0);
        NodeId(g.0 / self.gpus_per_node())
    }

    /// Socket (global index across the cluster) that hosts a GPU.
    pub fn gpu_socket(&self, g: GpuId) -> usize {
        assert!(g.0 < self.total_gpus(), "gpu {} out of range", g.0);
        g.0 / self.gpus_per_socket
    }

    /// Local index of a GPU within its node.
    pub fn gpu_local(&self, g: GpuId) -> usize {
        g.0 % self.gpus_per_node()
    }

    /// Node of a host process under `ppn` processes per node.
    pub fn proc_node(&self, p: ProcId, ppn: usize) -> NodeId {
        NodeId(p.0 / ppn)
    }

    /// Global socket index of a host process under `ppn` processes per node
    /// (processes are distributed round-robin blocks over sockets: the first
    /// `ppn / sockets_per_node` on socket 0, etc. — matching MPI's default
    /// block mapping on Lassen).
    pub fn proc_socket(&self, p: ProcId, ppn: usize) -> usize {
        let node = p.0 / ppn;
        let local = p.0 % ppn;
        let per_socket = ppn.div_ceil(self.sockets_per_node);
        node * self.sockets_per_node + (local / per_socket).min(self.sockets_per_node - 1)
    }

    /// The canonical host process of a GPU when each GPU has `ppg` host
    /// processes and the node runs `ppn = gpus_per_node * ppg` processes:
    /// host processes of GPU g are the block `[local_gpu * ppg, ...)` on its
    /// node, co-located on the GPU's socket.
    pub fn gpu_host_proc(&self, g: GpuId, ppg: usize) -> ProcId {
        let node = self.gpu_node(g).0;
        let local = self.gpu_local(g);
        let ppn = self.gpus_per_node() * ppg;
        ProcId(node * ppn + local * ppg)
    }

    /// All `ppg` host processes of a GPU (see [`Machine::gpu_host_proc`]).
    pub fn gpu_host_procs(&self, g: GpuId, ppg: usize) -> Vec<ProcId> {
        let first = self.gpu_host_proc(g, ppg).0;
        (first..first + ppg).map(ProcId).collect()
    }

    /// Locality of two host processes under `ppn` processes per node.
    pub fn proc_locality(&self, a: ProcId, b: ProcId, ppn: usize) -> Locality {
        if self.proc_node(a, ppn) != self.proc_node(b, ppn) {
            Locality::OffNode
        } else if self.proc_socket(a, ppn) != self.proc_socket(b, ppn) {
            Locality::OnNode
        } else {
            Locality::OnSocket
        }
    }

    /// Locality of two GPUs.
    pub fn gpu_locality(&self, a: GpuId, b: GpuId) -> Locality {
        if self.gpu_node(a) != self.gpu_node(b) {
            Locality::OffNode
        } else if self.gpu_socket(a) != self.gpu_socket(b) {
            Locality::OnNode
        } else {
            Locality::OnSocket
        }
    }

    /// All GPUs on a node.
    pub fn node_gpus(&self, n: NodeId) -> Vec<GpuId> {
        let first = n.0 * self.gpus_per_node();
        (first..first + self.gpus_per_node()).map(GpuId).collect()
    }

    /// NIC rails per node (the shape's total).
    pub fn nics_per_node(&self) -> usize {
        self.shape.nics_per_node()
    }

    /// Node-local rail a GPU injects through on device-aware transfers
    /// (the shape's affinity map).
    pub fn gpu_rail(&self, g: GpuId) -> usize {
        self.shape.gpu_rail(self.gpu_local(g))
    }

    /// Node-local rail a host process uses for staged traffic to `dst`:
    /// round-robin by node pair over the process's own socket's rails. The
    /// remote node index is folded into `[0, num_nodes - 1)` relative to the
    /// source node (the same folding as `comm::plan::paired_proc`), so a
    /// node spreading over many destinations cycles its rails evenly. A pure
    /// function of `(machine, proc, dst)` — deterministic and independent of
    /// message order.
    pub fn proc_rail(&self, p: ProcId, ppn: usize, dst: NodeId) -> usize {
        let k = self.proc_node(p, ppn).0;
        let rel = if dst.0 > k { dst.0 - 1 } else { dst.0 };
        let socket_local = self.proc_socket(p, ppn) % self.sockets_per_node;
        self.shape.host_rail(socket_local, rel)
    }

    /// Parse a machine from a `[machine]` config section. The optional
    /// `nics` key gives the per-node NIC rail count (default 1, the legacy
    /// single-rail shape), distributed over the sockets as in
    /// [`NodeShape::spread`].
    pub fn from_config(cfg: &Config) -> Result<Machine, ConfigError> {
        let m = cfg.section("machine")?;
        let sockets_per_node = m.usize("machine", "sockets_per_node")?;
        let gpus_per_socket = m.usize("machine", "gpus_per_socket")?;
        let nics = m.usize_or("nics", 1)?;
        Ok(Machine {
            name: m.str_or("name", "custom").to_string(),
            num_nodes: m.usize("machine", "num_nodes")?,
            sockets_per_node,
            cores_per_socket: m.usize("machine", "cores_per_socket")?,
            gpus_per_socket,
            shape: NodeShape::spread(sockets_per_node, nics.max(1), sockets_per_node * gpus_per_socket),
        })
    }

    /// Resize the cluster (same node architecture, different node count).
    pub fn with_nodes(&self, num_nodes: usize) -> Machine {
        Machine { num_nodes, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::machines::lassen;
    use super::*;

    #[test]
    fn lassen_shape() {
        let m = lassen(4);
        assert_eq!(m.gpus_per_node(), 4);
        assert_eq!(m.cores_per_node(), 40);
        assert_eq!(m.total_gpus(), 16);
    }

    #[test]
    fn gpu_placement() {
        let m = lassen(2);
        // Node 0: gpus 0..4 (sockets 0,0,1,1); node 1: gpus 4..8.
        assert_eq!(m.gpu_node(GpuId(3)), NodeId(0));
        assert_eq!(m.gpu_node(GpuId(4)), NodeId(1));
        assert_eq!(m.gpu_socket(GpuId(0)), 0);
        assert_eq!(m.gpu_socket(GpuId(1)), 0);
        assert_eq!(m.gpu_socket(GpuId(2)), 1);
        assert_eq!(m.gpu_socket(GpuId(5)), 2);
    }

    #[test]
    fn gpu_locality_cases() {
        let m = lassen(2);
        assert_eq!(m.gpu_locality(GpuId(0), GpuId(1)), Locality::OnSocket);
        assert_eq!(m.gpu_locality(GpuId(0), GpuId(2)), Locality::OnNode);
        assert_eq!(m.gpu_locality(GpuId(0), GpuId(4)), Locality::OffNode);
    }

    #[test]
    fn proc_locality_cases() {
        let m = lassen(2);
        let ppn = 40;
        // procs 0..20 socket 0, 20..40 socket 1 of node 0
        assert_eq!(m.proc_locality(ProcId(0), ProcId(19), ppn), Locality::OnSocket);
        assert_eq!(m.proc_locality(ProcId(0), ProcId(20), ppn), Locality::OnNode);
        assert_eq!(m.proc_locality(ProcId(0), ProcId(40), ppn), Locality::OffNode);
    }

    #[test]
    fn host_proc_blocks() {
        let m = lassen(2);
        // ppg=1: gpu g -> proc g
        for g in 0..m.total_gpus() {
            assert_eq!(m.gpu_host_proc(GpuId(g), 1), ProcId(g));
        }
        // ppg=4: gpu 1 -> procs 4..8 on node 0
        assert_eq!(m.gpu_host_procs(GpuId(1), 4), vec![ProcId(4), ProcId(5), ProcId(6), ProcId(7)]);
        // gpu 4 (node 1, first gpu) -> procs 16..20
        assert_eq!(m.gpu_host_proc(GpuId(4), 4), ProcId(16));
    }

    #[test]
    fn host_procs_on_gpu_socket() {
        let m = lassen(2);
        let ppg = 4;
        let ppn = m.gpus_per_node() * ppg; // 16
        for g in 0..m.total_gpus() {
            let g = GpuId(g);
            for p in m.gpu_host_procs(g, ppg) {
                assert_eq!(m.proc_node(p, ppn), m.gpu_node(g), "proc node mismatch for {g:?}");
                assert_eq!(m.proc_socket(p, ppn), m.gpu_socket(g), "proc socket mismatch for {g:?}");
            }
        }
    }

    #[test]
    fn node_gpus_roundtrip() {
        let m = lassen(3);
        for n in 0..3 {
            for g in m.node_gpus(NodeId(n)) {
                assert_eq!(m.gpu_node(g), NodeId(n));
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds cores/node")]
    fn ppn_bound_enforced() {
        lassen(1).total_procs(41);
    }

    #[test]
    fn default_shape_is_single_rail() {
        let m = lassen(2);
        assert!(m.shape.is_single_rail());
        assert_eq!(m.nics_per_node(), 1);
        // every endpoint and every destination lands on rail 0
        for g in 0..m.total_gpus() {
            assert_eq!(m.gpu_rail(GpuId(g)), 0);
        }
        for p in 0..8 {
            assert_eq!(m.proc_rail(ProcId(p), 4, NodeId(1 - p / 4)), 0);
        }
    }

    #[test]
    fn multi_rail_proc_rail_round_robins_socket_rails() {
        let mut m = lassen(5);
        m.shape = NodeShape::spread(2, 4, 4); // 2 rails per socket
        // proc 0 (node 0, socket 0) cycles rails {0, 1} over destinations
        let rails: Vec<usize> = (1..5).map(|l| m.proc_rail(ProcId(0), 4, NodeId(l))).collect();
        assert!(rails.iter().all(|&r| r < 2));
        assert_eq!(rails.iter().collect::<std::collections::BTreeSet<_>>().len(), 2);
        // proc 2 (socket 1) stays on socket 1's rails {2, 3}
        let rails: Vec<usize> = (1..5).map(|l| m.proc_rail(ProcId(2), 4, NodeId(l))).collect();
        assert!(rails.iter().all(|&r| (2..4).contains(&r)));
        // GPU affinity follows the shape map
        assert_eq!(m.gpu_rail(GpuId(0)), 0);
        assert_eq!(m.gpu_rail(GpuId(3)), 3);
        assert_eq!(m.gpu_rail(GpuId(7)), 3); // node 1, local 3
    }

    #[test]
    fn config_machine_reads_nics() {
        let cfg = crate::util::config::Config::parse(
            "[machine]\nnum_nodes = 2\nsockets_per_node = 2\ncores_per_socket = 20\ngpus_per_socket = 2\nnics = 4\n",
        )
        .unwrap();
        let m = Machine::from_config(&cfg).unwrap();
        assert_eq!(m.nics_per_node(), 4);
        let cfg = crate::util::config::Config::parse(
            "[machine]\nnum_nodes = 2\nsockets_per_node = 2\ncores_per_socket = 20\ngpus_per_socket = 2\n",
        )
        .unwrap();
        assert!(Machine::from_config(&cfg).unwrap().shape.is_single_rail());
    }
}
