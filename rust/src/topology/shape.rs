//! Node resource-graph shapes: NIC rails per socket and GPU↔NIC affinity.
//!
//! The paper's §6 outlook argues that strategy crossover points move with
//! *node shape* — NIC count, injection bandwidth and GPU↔NIC affinity decide
//! when node-aware staging with all CPU cores keeps winning. [`NodeShape`]
//! makes that an explicit, sweepable dimension: every [`super::Machine`]
//! carries one, the models divide the injection term over the rails
//! ([`crate::model::maxrate`]), and the simulator runs one occupancy
//! timeline per rail ([`crate::sim`]).
//!
//! The default is the *legacy single-rail* shape — one NIC serving the whole
//! node, as on the paper's Lassen testbed (a single EDR HCA per node) —
//! which reproduces the pre-shape-layer outputs bit for bit. Multi-rail
//! shapes (e.g. the Frontier-like 4-NIC node) are built with
//! [`NodeShape::spread`] or loaded from presets
//! ([`super::machines::frontier_4nic`]).

/// Resource-graph description of one node's injection fabric.
///
/// Rails carry node-local ids in socket-major order: socket 0's rails come
/// first, then socket 1's, and so on. A socket may own zero rails (the
/// legacy shape places the node's single NIC on socket 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeShape {
    /// NIC rails attached to each socket; `nics_per_socket[s]` rails belong
    /// to socket `s`. The node total is the sum.
    pub nics_per_socket: Vec<usize>,
    /// Node-local rail each local GPU injects through on device-aware
    /// transfers (the GPU↔NIC affinity map); `gpu_nic[g]` for local GPU `g`.
    pub gpu_nic: Vec<usize>,
}

impl NodeShape {
    /// The legacy shape: one NIC on socket 0 serving the whole node (the
    /// paper's Lassen testbed). Reproduces pre-shape-layer behavior bit for
    /// bit: every inter-node transfer occupies the same single rail.
    pub fn single_rail(sockets_per_node: usize, gpus_per_node: usize) -> NodeShape {
        assert!(sockets_per_node >= 1, "node needs at least one socket");
        let mut nics_per_socket = vec![0usize; sockets_per_node];
        nics_per_socket[0] = 1;
        NodeShape { nics_per_socket, gpu_nic: vec![0; gpus_per_node] }
    }

    /// Distribute `nics` rails over the sockets (the first
    /// `nics % sockets` sockets take one extra) and affine each GPU to its
    /// own socket's rails round-robin; GPUs on a rail-less socket fall back
    /// to the node's rails round-robin by local index.
    pub fn spread(sockets_per_node: usize, nics: usize, gpus_per_node: usize) -> NodeShape {
        assert!(sockets_per_node >= 1, "node needs at least one socket");
        assert!(nics >= 1, "node needs at least one NIC rail");
        if nics == 1 {
            return NodeShape::single_rail(sockets_per_node, gpus_per_node);
        }
        let base = nics / sockets_per_node;
        let extra = nics % sockets_per_node;
        let nics_per_socket: Vec<usize> = (0..sockets_per_node).map(|s| base + usize::from(s < extra)).collect();
        let gps = gpus_per_node.div_ceil(sockets_per_node).max(1);
        let mut gpu_nic = Vec::with_capacity(gpus_per_node);
        for g in 0..gpus_per_node {
            let socket = (g / gps).min(sockets_per_node - 1);
            let rail_base: usize = nics_per_socket[..socket].iter().sum();
            let count = nics_per_socket[socket];
            let within = g % gps;
            gpu_nic.push(if count > 0 { rail_base + within % count } else { g % nics });
        }
        NodeShape { nics_per_socket, gpu_nic }
    }

    /// Total NIC rails on the node.
    pub fn nics_per_node(&self) -> usize {
        self.nics_per_socket.iter().sum()
    }

    /// Whether this is the legacy single-rail shape.
    pub fn is_single_rail(&self) -> bool {
        self.nics_per_node() == 1
    }

    /// `(first node-local rail id, rail count)` of one socket.
    pub fn socket_rails(&self, socket: usize) -> (usize, usize) {
        let s = socket.min(self.nics_per_socket.len().saturating_sub(1));
        let base: usize = self.nics_per_socket[..s].iter().sum();
        (base, self.nics_per_socket[s])
    }

    /// Rail used by a host process on local socket `socket` for traffic to
    /// the remote node with folded relative index `rel` (see
    /// [`super::Machine::proc_rail`]): round-robin by node pair over the
    /// socket's own rails, falling back to the node's rails when the socket
    /// has none. Deterministic and independent of message order.
    pub fn host_rail(&self, socket: usize, rel: usize) -> usize {
        let (base, count) = self.socket_rails(socket);
        if count > 0 {
            base + rel % count
        } else {
            rel % self.nics_per_node().max(1)
        }
    }

    /// Rail a local GPU injects through (device-aware affinity).
    pub fn gpu_rail(&self, gpu_local: usize) -> usize {
        self.gpu_nic[gpu_local]
    }

    /// The shape after the rails in `down` (node-local ids of *this* shape)
    /// fail: survivors keep their socket-major order and are renumbered
    /// densely, and the GPU↔NIC affinity remaps onto the survivors — a GPU
    /// whose rail failed falls back to its rail's socket survivors
    /// (round-robin by local GPU index), or the node's survivors when the
    /// socket lost every rail. Host round-robin needs no remap of its own:
    /// [`NodeShape::host_rail`] reads `nics_per_socket`, so the shared
    /// policy home follows the degraded shape automatically.
    ///
    /// Errors when `down` names a rail this shape does not have or leaves
    /// no survivor. The result always passes [`NodeShape::validate`] for
    /// the same node.
    pub fn degraded(&self, down: &[usize]) -> Result<NodeShape, String> {
        let total = self.nics_per_node();
        let down: std::collections::BTreeSet<usize> = down.iter().copied().collect();
        if let Some(&r) = down.iter().find(|&&r| r >= total) {
            return Err(format!("cannot fail rail {r}: node has {total}"));
        }
        if down.len() >= total {
            return Err(format!("cannot fail all {total} rails: at least one must survive"));
        }
        // dense renumbering of survivors, socket-major order preserved
        let mut remap = vec![usize::MAX; total];
        let mut next = 0usize;
        for (r, slot) in remap.iter_mut().enumerate() {
            if !down.contains(&r) {
                *slot = next;
                next += 1;
            }
        }
        let mut socket_of = vec![0usize; total];
        let mut nics_per_socket = Vec::with_capacity(self.nics_per_socket.len());
        let mut base = 0usize;
        for (s, &k) in self.nics_per_socket.iter().enumerate() {
            for r in base..base + k {
                socket_of[r] = s;
            }
            nics_per_socket.push((base..base + k).filter(|r| !down.contains(r)).count());
            base += k;
        }
        let socket_survivors = |s: usize| -> Vec<usize> {
            (0..total).filter(|r| socket_of[*r] == s && !down.contains(r)).map(|r| remap[r]).collect()
        };
        let gpu_nic = self
            .gpu_nic
            .iter()
            .enumerate()
            .map(|(g, &r)| {
                if remap[r] != usize::MAX {
                    return remap[r];
                }
                let local = socket_survivors(socket_of[r]);
                if local.is_empty() {
                    g % next // new ids are dense 0..next
                } else {
                    local[g % local.len()]
                }
            })
            .collect();
        Ok(NodeShape { nics_per_socket, gpu_nic })
    }

    /// Structural sanity against the owning node's socket and GPU counts;
    /// returns a user-facing message on failure.
    pub fn validate(&self, sockets_per_node: usize, gpus_per_node: usize) -> Result<(), String> {
        if self.nics_per_socket.len() != sockets_per_node {
            return Err(format!(
                "shape lists {} sockets, node has {sockets_per_node}",
                self.nics_per_socket.len()
            ));
        }
        let total = self.nics_per_node();
        if total == 0 {
            return Err("node shape has no NIC rails".into());
        }
        if self.gpu_nic.len() != gpus_per_node {
            return Err(format!("shape maps {} GPUs, node has {gpus_per_node}", self.gpu_nic.len()));
        }
        if let Some(&r) = self.gpu_nic.iter().find(|&&r| r >= total) {
            return Err(format!("GPU affinity names rail {r}, node has {total}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rail_is_legacy() {
        let s = NodeShape::single_rail(2, 4);
        assert_eq!(s.nics_per_socket, vec![1, 0]);
        assert_eq!(s.gpu_nic, vec![0, 0, 0, 0]);
        assert!(s.is_single_rail());
        assert_eq!(s.nics_per_node(), 1);
        s.validate(2, 4).unwrap();
        // every socket and every pair index lands on the one rail
        for socket in 0..2 {
            for rel in 0..7 {
                assert_eq!(s.host_rail(socket, rel), 0);
            }
        }
        for g in 0..4 {
            assert_eq!(s.gpu_rail(g), 0);
        }
    }

    #[test]
    fn spread_one_is_single_rail() {
        assert_eq!(NodeShape::spread(2, 1, 4), NodeShape::single_rail(2, 4));
    }

    #[test]
    fn frontier_like_four_rails() {
        // single socket, 4 NICs, 4 GPUs: one rail per GPU
        let s = NodeShape::spread(1, 4, 4);
        assert_eq!(s.nics_per_socket, vec![4]);
        assert_eq!(s.gpu_nic, vec![0, 1, 2, 3]);
        s.validate(1, 4).unwrap();
        // host round-robin covers all four rails
        let rails: std::collections::BTreeSet<usize> = (0..8).map(|rel| s.host_rail(0, rel)).collect();
        assert_eq!(rails.len(), 4);
    }

    #[test]
    fn two_socket_spread_keeps_affinity_on_socket() {
        // 2 sockets x 2 rails, 4 GPUs: GPUs 0,1 on socket 0 rails {0,1},
        // GPUs 2,3 on socket 1 rails {2,3}
        let s = NodeShape::spread(2, 4, 4);
        assert_eq!(s.nics_per_socket, vec![2, 2]);
        assert_eq!(s.gpu_nic, vec![0, 1, 2, 3]);
        assert_eq!(s.socket_rails(0), (0, 2));
        assert_eq!(s.socket_rails(1), (2, 2));
        // socket-local round robin stays within the socket's rails
        for rel in 0..5 {
            assert!(s.host_rail(0, rel) < 2);
            assert!((2..4).contains(&s.host_rail(1, rel)));
        }
    }

    #[test]
    fn odd_spread_front_loads() {
        let s = NodeShape::spread(2, 3, 4);
        assert_eq!(s.nics_per_socket, vec![2, 1]);
        assert_eq!(s.nics_per_node(), 3);
        s.validate(2, 4).unwrap();
    }

    #[test]
    fn degraded_renumbers_densely_and_remaps_affinity() {
        // 2 sockets x 2 rails, gpu_nic [0,1,2,3]; rail 1 fails
        let s = NodeShape::spread(2, 4, 4);
        let d = s.degraded(&[1]).unwrap();
        assert_eq!(d.nics_per_socket, vec![1, 2]);
        assert_eq!(d.nics_per_node(), 3);
        // survivors 0,2,3 -> new ids 0,1,2; GPU 1 (failed rail, socket 0
        // survivor {0}) falls back to rail 0
        assert_eq!(d.gpu_nic, vec![0, 0, 1, 2]);
        d.validate(2, 4).unwrap();
        // host round-robin follows the shrunken socket tables
        for rel in 0..5 {
            assert_eq!(d.host_rail(0, rel), 0);
            assert!((1..3).contains(&d.host_rail(1, rel)));
        }
    }

    #[test]
    fn degraded_socket_losing_all_rails_falls_back_to_node() {
        // socket 0 loses both rails: its GPUs round-robin the node survivors
        let s = NodeShape::spread(2, 4, 4);
        let d = s.degraded(&[0, 1]).unwrap();
        assert_eq!(d.nics_per_socket, vec![0, 2]);
        assert_eq!(d.gpu_nic, vec![0, 1, 0, 1]);
        d.validate(2, 4).unwrap();
        // the rail-less socket's hosts spread over the node's rails
        let rails: std::collections::BTreeSet<usize> = (0..4).map(|rel| d.host_rail(0, rel)).collect();
        assert_eq!(rails, [0, 1].into_iter().collect());
    }

    #[test]
    fn degraded_single_survivor_is_single_rail() {
        let s = NodeShape::spread(1, 4, 4);
        let d = s.degraded(&[0, 2, 3]).unwrap();
        assert!(d.is_single_rail());
        assert_eq!(d.gpu_nic, vec![0, 0, 0, 0]);
        d.validate(1, 4).unwrap();
        // duplicate ids in `down` collapse; empty `down` is the identity
        assert_eq!(s.degraded(&[2, 2]).unwrap(), s.degraded(&[2]).unwrap());
        assert_eq!(s.degraded(&[]).unwrap(), s);
    }

    #[test]
    fn degraded_rejects_bad_rails() {
        let s = NodeShape::spread(1, 2, 4);
        assert!(s.degraded(&[5]).unwrap_err().contains("rail 5"));
        assert!(s.degraded(&[0, 1]).unwrap_err().contains("survive"));
        assert!(NodeShape::single_rail(2, 4).degraded(&[0]).is_err());
    }

    #[test]
    fn validate_rejects_mismatches() {
        let s = NodeShape::single_rail(2, 4);
        assert!(s.validate(1, 4).is_err());
        assert!(s.validate(2, 6).is_err());
        let bad = NodeShape { nics_per_socket: vec![0, 0], gpu_nic: vec![0; 4] };
        assert!(bad.validate(2, 4).unwrap_err().contains("no NIC"));
        let bad = NodeShape { nics_per_socket: vec![1, 0], gpu_nic: vec![0, 0, 0, 5] };
        assert!(bad.validate(2, 4).unwrap_err().contains("rail 5"));
    }
}
