//! Node resource-graph shapes: NIC rails per socket and GPU↔NIC affinity.
//!
//! The paper's §6 outlook argues that strategy crossover points move with
//! *node shape* — NIC count, injection bandwidth and GPU↔NIC affinity decide
//! when node-aware staging with all CPU cores keeps winning. [`NodeShape`]
//! makes that an explicit, sweepable dimension: every [`super::Machine`]
//! carries one, the models divide the injection term over the rails
//! ([`crate::model::maxrate`]), and the simulator runs one occupancy
//! timeline per rail ([`crate::sim`]).
//!
//! The default is the *legacy single-rail* shape — one NIC serving the whole
//! node, as on the paper's Lassen testbed (a single EDR HCA per node) —
//! which reproduces the pre-shape-layer outputs bit for bit. Multi-rail
//! shapes (e.g. the Frontier-like 4-NIC node) are built with
//! [`NodeShape::spread`] or loaded from presets
//! ([`super::machines::frontier_4nic`]).

/// Resource-graph description of one node's injection fabric.
///
/// Rails carry node-local ids in socket-major order: socket 0's rails come
/// first, then socket 1's, and so on. A socket may own zero rails (the
/// legacy shape places the node's single NIC on socket 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeShape {
    /// NIC rails attached to each socket; `nics_per_socket[s]` rails belong
    /// to socket `s`. The node total is the sum.
    pub nics_per_socket: Vec<usize>,
    /// Node-local rail each local GPU injects through on device-aware
    /// transfers (the GPU↔NIC affinity map); `gpu_nic[g]` for local GPU `g`.
    pub gpu_nic: Vec<usize>,
}

impl NodeShape {
    /// The legacy shape: one NIC on socket 0 serving the whole node (the
    /// paper's Lassen testbed). Reproduces pre-shape-layer behavior bit for
    /// bit: every inter-node transfer occupies the same single rail.
    pub fn single_rail(sockets_per_node: usize, gpus_per_node: usize) -> NodeShape {
        assert!(sockets_per_node >= 1, "node needs at least one socket");
        let mut nics_per_socket = vec![0usize; sockets_per_node];
        nics_per_socket[0] = 1;
        NodeShape { nics_per_socket, gpu_nic: vec![0; gpus_per_node] }
    }

    /// Distribute `nics` rails over the sockets (the first
    /// `nics % sockets` sockets take one extra) and affine each GPU to its
    /// own socket's rails round-robin; GPUs on a rail-less socket fall back
    /// to the node's rails round-robin by local index.
    pub fn spread(sockets_per_node: usize, nics: usize, gpus_per_node: usize) -> NodeShape {
        assert!(sockets_per_node >= 1, "node needs at least one socket");
        assert!(nics >= 1, "node needs at least one NIC rail");
        if nics == 1 {
            return NodeShape::single_rail(sockets_per_node, gpus_per_node);
        }
        let base = nics / sockets_per_node;
        let extra = nics % sockets_per_node;
        let nics_per_socket: Vec<usize> = (0..sockets_per_node).map(|s| base + usize::from(s < extra)).collect();
        let gps = gpus_per_node.div_ceil(sockets_per_node).max(1);
        let mut gpu_nic = Vec::with_capacity(gpus_per_node);
        for g in 0..gpus_per_node {
            let socket = (g / gps).min(sockets_per_node - 1);
            let rail_base: usize = nics_per_socket[..socket].iter().sum();
            let count = nics_per_socket[socket];
            let within = g % gps;
            gpu_nic.push(if count > 0 { rail_base + within % count } else { g % nics });
        }
        NodeShape { nics_per_socket, gpu_nic }
    }

    /// Total NIC rails on the node.
    pub fn nics_per_node(&self) -> usize {
        self.nics_per_socket.iter().sum()
    }

    /// Whether this is the legacy single-rail shape.
    pub fn is_single_rail(&self) -> bool {
        self.nics_per_node() == 1
    }

    /// `(first node-local rail id, rail count)` of one socket.
    pub fn socket_rails(&self, socket: usize) -> (usize, usize) {
        let s = socket.min(self.nics_per_socket.len().saturating_sub(1));
        let base: usize = self.nics_per_socket[..s].iter().sum();
        (base, self.nics_per_socket[s])
    }

    /// Rail used by a host process on local socket `socket` for traffic to
    /// the remote node with folded relative index `rel` (see
    /// [`super::Machine::proc_rail`]): round-robin by node pair over the
    /// socket's own rails, falling back to the node's rails when the socket
    /// has none. Deterministic and independent of message order.
    pub fn host_rail(&self, socket: usize, rel: usize) -> usize {
        let (base, count) = self.socket_rails(socket);
        if count > 0 {
            base + rel % count
        } else {
            rel % self.nics_per_node().max(1)
        }
    }

    /// Rail a local GPU injects through (device-aware affinity).
    pub fn gpu_rail(&self, gpu_local: usize) -> usize {
        self.gpu_nic[gpu_local]
    }

    /// Structural sanity against the owning node's socket and GPU counts;
    /// returns a user-facing message on failure.
    pub fn validate(&self, sockets_per_node: usize, gpus_per_node: usize) -> Result<(), String> {
        if self.nics_per_socket.len() != sockets_per_node {
            return Err(format!(
                "shape lists {} sockets, node has {sockets_per_node}",
                self.nics_per_socket.len()
            ));
        }
        let total = self.nics_per_node();
        if total == 0 {
            return Err("node shape has no NIC rails".into());
        }
        if self.gpu_nic.len() != gpus_per_node {
            return Err(format!("shape maps {} GPUs, node has {gpus_per_node}", self.gpu_nic.len()));
        }
        if let Some(&r) = self.gpu_nic.iter().find(|&&r| r >= total) {
            return Err(format!("GPU affinity names rail {r}, node has {total}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rail_is_legacy() {
        let s = NodeShape::single_rail(2, 4);
        assert_eq!(s.nics_per_socket, vec![1, 0]);
        assert_eq!(s.gpu_nic, vec![0, 0, 0, 0]);
        assert!(s.is_single_rail());
        assert_eq!(s.nics_per_node(), 1);
        s.validate(2, 4).unwrap();
        // every socket and every pair index lands on the one rail
        for socket in 0..2 {
            for rel in 0..7 {
                assert_eq!(s.host_rail(socket, rel), 0);
            }
        }
        for g in 0..4 {
            assert_eq!(s.gpu_rail(g), 0);
        }
    }

    #[test]
    fn spread_one_is_single_rail() {
        assert_eq!(NodeShape::spread(2, 1, 4), NodeShape::single_rail(2, 4));
    }

    #[test]
    fn frontier_like_four_rails() {
        // single socket, 4 NICs, 4 GPUs: one rail per GPU
        let s = NodeShape::spread(1, 4, 4);
        assert_eq!(s.nics_per_socket, vec![4]);
        assert_eq!(s.gpu_nic, vec![0, 1, 2, 3]);
        s.validate(1, 4).unwrap();
        // host round-robin covers all four rails
        let rails: std::collections::BTreeSet<usize> = (0..8).map(|rel| s.host_rail(0, rel)).collect();
        assert_eq!(rails.len(), 4);
    }

    #[test]
    fn two_socket_spread_keeps_affinity_on_socket() {
        // 2 sockets x 2 rails, 4 GPUs: GPUs 0,1 on socket 0 rails {0,1},
        // GPUs 2,3 on socket 1 rails {2,3}
        let s = NodeShape::spread(2, 4, 4);
        assert_eq!(s.nics_per_socket, vec![2, 2]);
        assert_eq!(s.gpu_nic, vec![0, 1, 2, 3]);
        assert_eq!(s.socket_rails(0), (0, 2));
        assert_eq!(s.socket_rails(1), (2, 2));
        // socket-local round robin stays within the socket's rails
        for rel in 0..5 {
            assert!(s.host_rail(0, rel) < 2);
            assert!((2..4).contains(&s.host_rail(1, rel)));
        }
    }

    #[test]
    fn odd_spread_front_loads() {
        let s = NodeShape::spread(2, 3, 4);
        assert_eq!(s.nics_per_socket, vec![2, 1]);
        assert_eq!(s.nics_per_node(), 3);
        s.validate(2, 4).unwrap();
    }

    #[test]
    fn validate_rejects_mismatches() {
        let s = NodeShape::single_rail(2, 4);
        assert!(s.validate(1, 4).is_err());
        assert!(s.validate(2, 6).is_err());
        let bad = NodeShape { nics_per_socket: vec![0, 0], gpu_nic: vec![0; 4] };
        assert!(bad.validate(2, 4).unwrap_err().contains("no NIC"));
        let bad = NodeShape { nics_per_socket: vec![1, 0], gpu_nic: vec![0, 0, 0, 5] };
        assert!(bad.validate(2, 4).unwrap_err().contains("rail 5"));
    }
}
