//! Canonical machine descriptions (Section 2.1 and the Section 6 outlook).

use super::Machine;

/// Lassen (LLNL): 2 sockets/node, IBM Power9 (20 cores) + 2 V100s per
/// socket, EDR InfiniBand. The paper's measurement testbed.
pub fn lassen(num_nodes: usize) -> Machine {
    Machine {
        name: "lassen".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 2,
    }
}

/// Summit (ORNL): 2 sockets/node, Power9 (20 usable cores) + 3 V100s per
/// socket. Same interconnect family as Lassen; the paper notes Spectrum MPI
/// performs similarly on both.
pub fn summit(num_nodes: usize) -> Machine {
    Machine {
        name: "summit".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 3,
    }
}

/// Frontier-like exascale node (Section 6): single socket, 64-core AMD EPYC,
/// 4 MI250X GPUs (8 GCDs; we model the 4 physical packages), Slingshot.
pub fn frontier_like(num_nodes: usize) -> Machine {
    Machine {
        name: "frontier-like".into(),
        num_nodes,
        sockets_per_node: 1,
        cores_per_socket: 64,
        gpus_per_socket: 4,
    }
}

/// Delta-like node (Section 6): dual 64-core AMD Milan + 4 A100s per node.
pub fn delta_like(num_nodes: usize) -> Machine {
    Machine {
        name: "delta-like".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 64,
        gpus_per_socket: 2,
    }
}

/// Look up a machine preset by name.
pub fn by_name(name: &str, num_nodes: usize) -> Option<Machine> {
    match name {
        "lassen" => Some(lassen(num_nodes)),
        "summit" => Some(summit(num_nodes)),
        "frontier" | "frontier-like" => Some(frontier_like(num_nodes)),
        "delta" | "delta-like" => Some(delta_like(num_nodes)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["lassen", "summit", "frontier", "delta"] {
            let m = by_name(name, 2).unwrap();
            assert_eq!(m.num_nodes, 2);
            assert!(m.total_gpus() >= 8);
        }
        assert!(by_name("bogus", 1).is_none());
    }

    #[test]
    fn frontier_single_socket_high_cores() {
        let m = frontier_like(1);
        assert_eq!(m.sockets_per_node, 1);
        assert_eq!(m.cores_per_node(), 64);
        assert_eq!(m.gpus_per_node(), 4);
    }

    #[test]
    fn summit_six_gpus() {
        assert_eq!(summit(1).gpus_per_node(), 6);
    }
}
