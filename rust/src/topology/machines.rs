//! Canonical machine descriptions (Section 2.1 and the Section 6 outlook).
//!
//! Every preset carries a [`NodeShape`]: the measured machines and the
//! legacy forward-looking presets expose the single-rail node the paper's
//! models assume, while [`frontier_4nic`] describes the Frontier-like node
//! as a resource graph — four Slingshot rails, one per GPU package — whose
//! NIC count is *pinned* (it cannot be overridden by `--nics`).

use super::{Machine, NodeShape};
use crate::params::{lassen_params, MachineParams};

/// Lassen (LLNL): 2 sockets/node, IBM Power9 (20 cores) + 2 V100s per
/// socket, EDR InfiniBand (one HCA per node — the single-rail shape). The
/// paper's measurement testbed.
pub fn lassen(num_nodes: usize) -> Machine {
    Machine {
        name: "lassen".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 2,
        shape: NodeShape::single_rail(2, 4),
    }
}

/// Summit (ORNL): 2 sockets/node, Power9 (20 usable cores) + 3 V100s per
/// socket. Same interconnect family as Lassen; the paper notes Spectrum MPI
/// performs similarly on both.
pub fn summit(num_nodes: usize) -> Machine {
    Machine {
        name: "summit".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 3,
        shape: NodeShape::single_rail(2, 6),
    }
}

/// Frontier-like exascale node (Section 6): single socket, 64-core AMD EPYC,
/// 4 MI250X GPUs (8 GCDs; we model the 4 physical packages), Slingshot.
/// This legacy preset keeps the aggregate-bandwidth view: a single rail
/// whose parameters are scaled 4× ([`parse`]); [`frontier_4nic`] is the
/// resource-graph view of the same node.
pub fn frontier_like(num_nodes: usize) -> Machine {
    Machine {
        name: "frontier-like".into(),
        num_nodes,
        sockets_per_node: 1,
        cores_per_socket: 64,
        gpus_per_socket: 4,
        shape: NodeShape::single_rail(1, 4),
    }
}

/// Frontier-like node as a resource graph: the same socket/core/GPU layout
/// as [`frontier_like`], but with its 4 Slingshot NICs modeled as explicit
/// rails, one affine to each GPU package. Each rail injects at the Lassen
/// `R_N` (EDR ≈ Slingshot-per-NIC), so the node's aggregate injection
/// bandwidth is 4× — reached only when traffic actually spreads over the
/// rails. The NIC count is pinned ([`shape_pinned`]).
pub fn frontier_4nic(num_nodes: usize) -> Machine {
    Machine {
        name: "frontier-4nic".into(),
        num_nodes,
        sockets_per_node: 1,
        cores_per_socket: 64,
        gpus_per_socket: 4,
        shape: NodeShape::spread(1, 4, 4),
    }
}

/// Delta-like node (Section 6): dual 64-core AMD Milan + 4 A100s per node.
pub fn delta_like(num_nodes: usize) -> Machine {
    Machine {
        name: "delta-like".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 64,
        gpus_per_socket: 2,
        shape: NodeShape::single_rail(2, 4),
    }
}

/// Look up a machine preset by name.
pub fn by_name(name: &str, num_nodes: usize) -> Option<Machine> {
    match name {
        "lassen" => Some(lassen(num_nodes)),
        "summit" => Some(summit(num_nodes)),
        "frontier" | "frontier-like" => Some(frontier_like(num_nodes)),
        "frontier-4nic" | "frontier4nic" => Some(frontier_4nic(num_nodes)),
        "delta" | "delta-like" => Some(delta_like(num_nodes)),
        _ => None,
    }
}

/// Canonical registry names accepted by [`parse`] (CLI help text).
pub const NAMES: [&str; 5] = ["lassen", "summit", "frontier-like", "frontier-4nic", "delta-like"];

/// Whether a preset's shape pins its NIC count: `--nics` overrides are
/// rejected for such machines (the shape *is* the machine description).
pub fn shape_pinned(name: &str) -> bool {
    matches!(name.trim().to_ascii_lowercase().as_str(), "frontier-4nic" | "frontier4nic")
}

/// The single registry helper behind every `--machine` CLI flag: resolve a
/// preset name (case-insensitive, aliases allowed) to the machine
/// description plus its modeling parameters; unknown names error with the
/// valid [`NAMES`] list. Lassen and Summit use the measured tables; the
/// Section 6 forward-looking machines scale the Lassen baseline
/// (frontier-like: 0.8× latency, 4× aggregate bandwidth; frontier-4nic:
/// 0.8× latency with 4 explicit rails at 1× each; delta-like: 2×
/// bandwidth), matching `hetcomm study` and the ablation bench.
pub fn parse(name: &str, num_nodes: usize) -> Result<(Machine, MachineParams), String> {
    let machine = by_name(name.trim().to_ascii_lowercase().as_str(), num_nodes)
        .ok_or_else(|| format!("unknown machine preset {name:?}; known: {}", NAMES.join(", ")))?;
    let base = lassen_params();
    let params = match machine.name.as_str() {
        "frontier-like" => base.scaled(0.8, 4.0),
        // rails carry the 4x: each of the 4 NICs injects at the base R_N
        "frontier-4nic" => base.scaled(0.8, 1.0),
        "delta-like" => base.scaled(1.0, 2.0),
        _ => base,
    };
    Ok((machine, params))
}

/// Resize a preset's node architecture to a specific node count and GPU
/// count per node (GPUs spread evenly over the preset's sockets). The
/// shape is rebuilt for the new GPU count, keeping the preset's per-node
/// NIC rail count ([`with_shape_nics`] overrides it).
pub fn with_shape(arch: &Machine, num_nodes: usize, gpus_per_node: usize) -> Machine {
    with_shape_nics(arch, num_nodes, gpus_per_node, arch.shape.nics_per_node())
}

/// [`with_shape`] with an explicit per-node NIC rail count — the hook
/// behind the `--nics` grid axis.
pub fn with_shape_nics(arch: &Machine, num_nodes: usize, gpus_per_node: usize, nics: usize) -> Machine {
    let gpus_per_socket = gpus_per_node.div_ceil(arch.sockets_per_node.max(1)).max(1);
    Machine {
        name: arch.name.clone(),
        num_nodes,
        sockets_per_node: arch.sockets_per_node,
        cores_per_socket: arch.cores_per_socket,
        gpus_per_socket,
        shape: NodeShape::spread(arch.sockets_per_node.max(1), nics.max(1), arch.sockets_per_node * gpus_per_socket),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["lassen", "summit", "frontier", "delta", "frontier-4nic"] {
            let m = by_name(name, 2).unwrap();
            assert_eq!(m.num_nodes, 2);
            assert!(m.total_gpus() >= 8);
        }
        assert!(by_name("bogus", 1).is_none());
    }

    #[test]
    fn frontier_single_socket_high_cores() {
        let m = frontier_like(1);
        assert_eq!(m.sockets_per_node, 1);
        assert_eq!(m.cores_per_node(), 64);
        assert_eq!(m.gpus_per_node(), 4);
        assert!(m.shape.is_single_rail());
    }

    #[test]
    fn frontier_4nic_rails_and_affinity() {
        let m = frontier_4nic(2);
        assert_eq!(m.nics_per_node(), 4);
        assert_eq!(m.shape.gpu_nic, vec![0, 1, 2, 3]);
        assert!(shape_pinned("frontier-4nic"));
        assert!(shape_pinned("Frontier-4NIC"));
        assert!(!shape_pinned("lassen"));
        assert!(!shape_pinned("frontier-like"));
    }

    #[test]
    fn summit_six_gpus() {
        assert_eq!(summit(1).gpus_per_node(), 6);
    }

    #[test]
    fn parse_registry_resolves_params() {
        use crate::params::lassen_params;
        let (m, p) = parse("lassen", 4).unwrap();
        assert_eq!(m.name, "lassen");
        assert_eq!(p, lassen_params());
        let (m, p) = parse("Frontier", 4).unwrap();
        assert_eq!(m.name, "frontier-like");
        assert!((p.rn() - lassen_params().rn() * 4.0).abs() / p.rn() < 1e-12);
        let (m, p) = parse("delta-like", 4).unwrap();
        assert_eq!(m.name, "delta-like");
        assert!((p.rn() - lassen_params().rn() * 2.0).abs() / p.rn() < 1e-12);
        // frontier-4nic: per-rail rate stays 1x; the 4x lives in the rails
        let (m, p) = parse("frontier-4nic", 4).unwrap();
        assert_eq!((m.name.as_str(), m.nics_per_node()), ("frontier-4nic", 4));
        assert!((p.rn() - lassen_params().rn()).abs() / p.rn() < 1e-12);
        let err = parse("bogus", 1).unwrap_err();
        for name in NAMES {
            assert!(err.contains(name), "error must list {name}: {err}");
            assert!(parse(name, 2).is_ok(), "registry name {name} must resolve");
        }
    }

    #[test]
    fn with_shape_spreads_gpus_over_sockets() {
        let two_socket = with_shape(&lassen(1), 5, 8);
        assert_eq!((two_socket.num_nodes, two_socket.gpus_per_node(), two_socket.cores_per_node()), (5, 8, 40));
        assert!(two_socket.shape.is_single_rail());
        two_socket.shape.validate(2, 8).unwrap();
        let one_socket = with_shape(&frontier_like(1), 3, 4);
        assert_eq!((one_socket.num_nodes, one_socket.gpus_per_node()), (3, 4));
        assert_eq!(one_socket.gpus_per_socket, 4);
        // pinned preset keeps its rail count through reshaping
        let four = with_shape(&frontier_4nic(1), 3, 8);
        assert_eq!(four.nics_per_node(), 4);
        four.shape.validate(1, 8).unwrap();
        // explicit rail override
        let two = with_shape_nics(&lassen(1), 3, 4, 2);
        assert_eq!(two.nics_per_node(), 2);
        two.shape.validate(2, 4).unwrap();
    }
}
