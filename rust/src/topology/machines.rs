//! Canonical machine descriptions (Section 2.1 and the Section 6 outlook).

use super::Machine;
use crate::params::{lassen_params, MachineParams};

/// Lassen (LLNL): 2 sockets/node, IBM Power9 (20 cores) + 2 V100s per
/// socket, EDR InfiniBand. The paper's measurement testbed.
pub fn lassen(num_nodes: usize) -> Machine {
    Machine {
        name: "lassen".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 2,
    }
}

/// Summit (ORNL): 2 sockets/node, Power9 (20 usable cores) + 3 V100s per
/// socket. Same interconnect family as Lassen; the paper notes Spectrum MPI
/// performs similarly on both.
pub fn summit(num_nodes: usize) -> Machine {
    Machine {
        name: "summit".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 20,
        gpus_per_socket: 3,
    }
}

/// Frontier-like exascale node (Section 6): single socket, 64-core AMD EPYC,
/// 4 MI250X GPUs (8 GCDs; we model the 4 physical packages), Slingshot.
pub fn frontier_like(num_nodes: usize) -> Machine {
    Machine {
        name: "frontier-like".into(),
        num_nodes,
        sockets_per_node: 1,
        cores_per_socket: 64,
        gpus_per_socket: 4,
    }
}

/// Delta-like node (Section 6): dual 64-core AMD Milan + 4 A100s per node.
pub fn delta_like(num_nodes: usize) -> Machine {
    Machine {
        name: "delta-like".into(),
        num_nodes,
        sockets_per_node: 2,
        cores_per_socket: 64,
        gpus_per_socket: 2,
    }
}

/// Look up a machine preset by name.
pub fn by_name(name: &str, num_nodes: usize) -> Option<Machine> {
    match name {
        "lassen" => Some(lassen(num_nodes)),
        "summit" => Some(summit(num_nodes)),
        "frontier" | "frontier-like" => Some(frontier_like(num_nodes)),
        "delta" | "delta-like" => Some(delta_like(num_nodes)),
        _ => None,
    }
}

/// Canonical registry names accepted by [`parse`] (CLI help text).
pub const NAMES: [&str; 4] = ["lassen", "summit", "frontier-like", "delta-like"];

/// The single registry helper behind every `--machine` CLI flag: resolve a
/// preset name (case-insensitive, aliases allowed) to the machine
/// description plus its modeling parameters. Lassen and Summit use the
/// measured tables; the Section 6 forward-looking machines scale the Lassen
/// baseline (frontier-like: 0.8× latency, 4× bandwidth; delta-like:
/// 2× bandwidth), matching `hetcomm study` and the ablation bench.
pub fn parse(name: &str, num_nodes: usize) -> Option<(Machine, MachineParams)> {
    let machine = by_name(name.trim().to_ascii_lowercase().as_str(), num_nodes)?;
    let base = lassen_params();
    let params = match machine.name.as_str() {
        "frontier-like" => base.scaled(0.8, 4.0),
        "delta-like" => base.scaled(1.0, 2.0),
        _ => base,
    };
    Some((machine, params))
}

/// Resize a preset's node architecture to a specific node count and GPU
/// count per node (GPUs spread evenly over the preset's sockets).
pub fn with_shape(arch: &Machine, num_nodes: usize, gpus_per_node: usize) -> Machine {
    Machine {
        name: arch.name.clone(),
        num_nodes,
        sockets_per_node: arch.sockets_per_node,
        cores_per_socket: arch.cores_per_socket,
        gpus_per_socket: gpus_per_node.div_ceil(arch.sockets_per_node.max(1)).max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["lassen", "summit", "frontier", "delta"] {
            let m = by_name(name, 2).unwrap();
            assert_eq!(m.num_nodes, 2);
            assert!(m.total_gpus() >= 8);
        }
        assert!(by_name("bogus", 1).is_none());
    }

    #[test]
    fn frontier_single_socket_high_cores() {
        let m = frontier_like(1);
        assert_eq!(m.sockets_per_node, 1);
        assert_eq!(m.cores_per_node(), 64);
        assert_eq!(m.gpus_per_node(), 4);
    }

    #[test]
    fn summit_six_gpus() {
        assert_eq!(summit(1).gpus_per_node(), 6);
    }

    #[test]
    fn parse_registry_resolves_params() {
        use crate::params::lassen_params;
        let (m, p) = parse("lassen", 4).unwrap();
        assert_eq!(m.name, "lassen");
        assert_eq!(p, lassen_params());
        let (m, p) = parse("Frontier", 4).unwrap();
        assert_eq!(m.name, "frontier-like");
        assert!((p.rn() - lassen_params().rn() * 4.0).abs() / p.rn() < 1e-12);
        let (m, p) = parse("delta-like", 4).unwrap();
        assert_eq!(m.name, "delta-like");
        assert!((p.rn() - lassen_params().rn() * 2.0).abs() / p.rn() < 1e-12);
        assert!(parse("bogus", 1).is_none());
        for name in NAMES {
            assert!(parse(name, 2).is_some(), "registry name {name} must resolve");
        }
    }

    #[test]
    fn with_shape_spreads_gpus_over_sockets() {
        let two_socket = with_shape(&lassen(1), 5, 8);
        assert_eq!((two_socket.num_nodes, two_socket.gpus_per_node(), two_socket.cores_per_node()), (5, 8, 40));
        let one_socket = with_shape(&frontier_like(1), 3, 4);
        assert_eq!((one_socket.num_nodes, one_socket.gpus_per_node()), (3, 4));
        assert_eq!(one_socket.gpus_per_socket, 4);
    }
}
