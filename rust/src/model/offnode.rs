//! Off-node phase models (Section 4.3).
//!
//! Staged-through-host traffic uses the max-rate form (Eq. 4.3),
//! generalized to the node shape's NIC rail count (§6):
//!
//! `T_off(m, s, n) = α_off·m + max( s_node / (n·R_N) , s_proc·β_off )`
//!
//! Device-aware traffic uses the postal form (Eq. 4.4):
//!
//! `T_off_DA(m, s) = α_off·m + s·β_off`
//!
//! Protocol selection follows the *per-message* size (total volume divided
//! by message count), matching how an MPI library would treat each send.

use crate::params::{Endpoint, MachineParams};
use crate::topology::Locality;

/// Eq. (4.3) over `nics` injecting NIC rails: staged-through-host off-node
/// time. `m` = number of inter-node messages sent by the worst process,
/// `s_proc` = max bytes sent by a single process, `s_node` = max bytes
/// injected by any single node; the node's injection limit is
/// `nics · R_N`. At `nics = 1` this is bit-identical to the single-NIC
/// Eq. (4.3) (`x / 1.0 == x`).
pub fn t_off(params: &MachineParams, m: usize, s_proc: usize, s_node: usize, nics: usize) -> f64 {
    let per_msg = if m > 0 { s_proc.div_ceil(m) } else { 0 };
    let ab = params.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
    let nic_term = s_node as f64 * params.inv_rn / nics.max(1) as f64;
    ab.alpha * m as f64 + nic_term.max(s_proc as f64 * ab.beta)
}

/// Eq. (4.4): device-aware off-node time (postal; GPUs per node are too few
/// to reach the injection limit — Section 2.2).
pub fn t_off_da(params: &MachineParams, m: usize, s: usize) -> f64 {
    let per_msg = if m > 0 { s.div_ceil(m) } else { 0 };
    let ab = params.ab_for(Endpoint::Gpu, Locality::OffNode, per_msg);
    ab.alpha * m as f64 + s as f64 * ab.beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;

    #[test]
    fn staged_matches_formula_bw_limited() {
        let p = lassen_params();
        let (m, s_proc) = (4, 1 << 18);
        let s_node = 40 * s_proc; // heavy node injection -> NIC limited
        let per_msg = s_proc / m;
        let ab = p.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
        let expect = ab.alpha * 4.0 + s_node as f64 * p.inv_rn;
        assert!((t_off(&p, m, s_proc, s_node, 1) - expect).abs() < 1e-12);
        // 4 rails quarter the NIC term (still injection-limited here)
        let expect4 = ab.alpha * 4.0 + s_node as f64 * p.inv_rn / 4.0;
        assert!((t_off(&p, m, s_proc, s_node, 4) - expect4).abs() < 1e-12);
    }

    #[test]
    fn staged_proc_limited_when_node_light() {
        let p = lassen_params();
        let (m, s_proc) = (2, 1 << 20);
        let s_node = s_proc; // only one sending process on the node
        let per_msg = s_proc / m;
        let ab = p.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
        let expect = ab.alpha * 2.0 + s_proc as f64 * ab.beta;
        assert!((t_off(&p, m, s_proc, s_node, 1) - expect).abs() < 1e-12);
        // a proc-limited node gains nothing from extra rails
        assert_eq!(t_off(&p, m, s_proc, s_node, 4).to_bits(), t_off(&p, m, s_proc, s_node, 1).to_bits());
    }

    #[test]
    fn one_rail_division_is_exact_identity() {
        // the refactor's safety rail: /1.0 must never move a bit
        let p = lassen_params();
        for (m, s_proc, s_node) in [(1usize, 3usize, 7usize), (5, 1 << 13, 40 << 13), (16, 1 << 20, 1 << 26)] {
            let legacy = {
                let per_msg = s_proc.div_ceil(m);
                let ab = p.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
                ab.alpha * m as f64 + (s_node as f64 * p.inv_rn).max(s_proc as f64 * ab.beta)
            };
            assert_eq!(t_off(&p, m, s_proc, s_node, 1).to_bits(), legacy.to_bits());
        }
    }

    #[test]
    fn device_aware_is_postal() {
        let p = lassen_params();
        let (m, s) = (8, 1 << 16);
        let ab = p.ab_for(Endpoint::Gpu, Locality::OffNode, s / m);
        let expect = ab.alpha * 8.0 + s as f64 * ab.beta;
        assert!((t_off_da(&p, m, s) - expect).abs() < 1e-12);
    }

    #[test]
    fn zero_messages_zero_latency() {
        let p = lassen_params();
        assert_eq!(t_off(&p, 0, 0, 0, 1), 0.0);
        assert_eq!(t_off_da(&p, 0, 0), 0.0);
    }

    #[test]
    fn protocol_depends_on_per_message_size() {
        let p = lassen_params();
        // 64 KiB total in 16 messages -> 4 KiB each -> eager;
        // in 2 messages -> 32 KiB each -> rendezvous.
        let s = 1 << 16;
        let t16 = t_off(&p, 16, s, s, 1);
        let t2 = t_off(&p, 2, s, s, 1);
        // eager beta (3.79e-10) > rend beta (7.97e-11): many small eager
        // messages pay more bandwidth cost + more latency.
        assert!(t16 > t2);
    }

    #[test]
    fn more_messages_more_latency_same_bytes() {
        let p = lassen_params();
        let s = 1 << 22; // rendezvous in both splits below
        let t4 = t_off_da(&p, 4, s);
        let t16 = t_off_da(&p, 16, s);
        assert!(t16 > t4);
    }
}
