//! Closed-form cost bounds for branch-and-bound sweep pruning.
//!
//! The sweep's expensive leg is the discrete-event simulator; the Table 6
//! models exist precisely so we do not have to pay it everywhere. This
//! module derives, for every strategy, a `[lower, upper]` interval such
//! that
//!
//! - `lower <= StrategyModel::time(strategy) <= upper`, and
//! - `lower <= simulated time` of the schedule the strategy builds,
//!
//! which makes pruning *winner-preserving*: a strategy whose `lower`
//! exceeds the best simulated time seen so far in a cell cannot be the
//! cell's simulated winner and may skip simulation entirely
//! (`rust/src/sweep/engine.rs`). The second inequality is the pruning
//! soundness oracle enforced by `rust/tests/prop_bounds.rs`.
//!
//! # Construction
//!
//! **Envelopes.** Every Table 6 term is monotone nondecreasing in the
//! `(α, β)` of the protocol row it reads, and the only size-dependent
//! discontinuity in the models is protocol selection. Folding the Table 2
//! rows per `(endpoint, locality)` into a component-wise min (resp. max)
//! envelope and re-evaluating the *exact* model dispatch with the envelope
//! coefficients therefore brackets the true model value from below (resp.
//! above) for every message size — no per-size protocol logic needed.
//!
//! **Simulator floor.** The min-envelope of the full model is a bound on
//! the *model*, not on the simulator, so the pruning-facing `lower` also
//! folds in a conservative floor built only from facts the executor
//! guarantees (`rust/src/sim/exec.rs`):
//!
//! - transfers from one source resource serialize, so the busiest
//!   inter-node sender pays at least `m · α_min + bytes · β_min`;
//! - every inter-node byte crosses some NIC rail of its source node, rails
//!   serialize at their band rate, and a node with `nics` rails has some
//!   rail carrying at least `1/nics` of its injected bytes (pigeonhole);
//! - staged transports bracket the exchange with `d2h` / `h2d` copy phases
//!   (phases are barriers), each costing at least one memcpy latency.
//!
//! The floor is further scaled by [`SAFETY`] (and inter-node volumes are
//! pre-shrunk by the duplicate fraction) so that schedule-construction
//! details the closed forms cannot see — conglomeration, dominant-sender
//! re-routing, duplicate-marking granularity — stay on the sound side.

use crate::comm::{Strategy, StrategyKind, Transport};
use crate::model::strategy::ModelInputs;
use crate::model::{copy, maxrate::MaxRate};
use crate::params::{AlphaBeta, CopyDir, Endpoint, MachineParams, Protocol};
use crate::topology::{Locality, Machine};

/// Margin applied to the simulator floor: `lower` uses `SAFETY × floor`.
/// The floor itself is built from per-resource occupancy arguments that
/// hold for every schedule builder; the margin covers integer effects the
/// closed-form inputs round differently from materialized patterns (e.g.
/// duplicate marking overshooting the requested fraction by one message).
pub const SAFETY: f64 = 0.5;

/// A `[lower, upper]` cost interval for one strategy in one cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostBounds {
    /// Sound lower bound on both the Table 6 model time and the simulated
    /// schedule time.
    pub lower: f64,
    /// Upper bound on the Table 6 model time (the branch-and-bound seed:
    /// the strategy with the least `upper` is simulated first).
    pub upper: f64,
}

/// Component-wise protocol envelope per `(endpoint, locality)`. Shared
/// with `collective::bounds`, which composes the same envelopes across
/// lowered collective stages.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Envelope {
    cpu: [AlphaBeta; 3],
    gpu: [AlphaBeta; 3],
}

fn li(l: Locality) -> usize {
    match l {
        Locality::OnSocket => 0,
        Locality::OnNode => 1,
        Locality::OffNode => 2,
    }
}

fn fold(abs: &[AlphaBeta], hi: bool) -> AlphaBeta {
    let mut alpha = abs[0].alpha;
    let mut beta = abs[0].beta;
    for ab in &abs[1..] {
        if hi {
            alpha = alpha.max(ab.alpha);
            beta = beta.max(ab.beta);
        } else {
            alpha = alpha.min(ab.alpha);
            beta = beta.min(ab.beta);
        }
    }
    AlphaBeta::new(alpha, beta)
}

impl Envelope {
    pub(crate) fn build(p: &MachineParams, hi: bool) -> Envelope {
        let locs = [Locality::OnSocket, Locality::OnNode, Locality::OffNode];
        let mut cpu = [AlphaBeta::new(0.0, 0.0); 3];
        let mut gpu = [AlphaBeta::new(0.0, 0.0); 3];
        for &l in &locs {
            cpu[li(l)] = fold(
                &[
                    p.cpu_ab(Protocol::Short, l),
                    p.cpu_ab(Protocol::Eager, l),
                    p.cpu_ab(Protocol::Rendezvous, l),
                ],
                hi,
            );
            // gpu_ab promotes Short to Eager: two rows cover every
            // reachable GPU coefficient pair.
            gpu[li(l)] = fold(&[p.gpu_ab(Protocol::Eager, l), p.gpu_ab(Protocol::Rendezvous, l)], hi);
        }
        Envelope { cpu, gpu }
    }

    pub(crate) fn ab(&self, ep: Endpoint, l: Locality) -> AlphaBeta {
        match ep {
            Endpoint::Cpu => self.cpu[li(l)],
            Endpoint::Gpu => self.gpu[li(l)],
        }
    }
}

/// Replicates [`ModelInputs`]'s private node-aware dedup adjustment
/// (Section 4.6): inter-node volumes scale by `1 - dup_frac`.
fn deduped(i: &ModelInputs) -> ModelInputs {
    let f = (1.0 - i.dup_frac).clamp(0.0, 1.0);
    let scale = |s: usize| ((s as f64) * f).ceil() as usize;
    ModelInputs { s_proc: scale(i.s_proc), s_node: scale(i.s_node), s_n2n: scale(i.s_n2n), ..*i }
}

/// Bound evaluator for one `(machine, params)` pair — the analogue of
/// [`crate::model::StrategyModel`] that returns intervals instead of
/// point estimates.
#[derive(Clone, Debug)]
pub struct BoundModel<'a> {
    machine: &'a Machine,
    params: &'a MachineParams,
    lo: Envelope,
    hi: Envelope,
}

impl<'a> BoundModel<'a> {
    pub fn new(machine: &'a Machine, params: &'a MachineParams) -> Self {
        BoundModel { machine, params, lo: Envelope::build(params, false), hi: Envelope::build(params, true) }
    }

    /// The `[lower, upper]` interval for `strategy` under `inputs`.
    pub fn bounds(&self, strategy: Strategy, inputs: &ModelInputs) -> CostBounds {
        let upper = self.envelope_time(&self.hi, strategy, inputs);
        let env_lower = self.envelope_time(&self.lo, strategy, inputs);
        let lower = env_lower.min(SAFETY * self.sim_floor(strategy, inputs));
        CostBounds { lower, upper }
    }

    /// Intervals for every valid strategy, in Table 5 order.
    pub fn all_bounds(&self, inputs: &ModelInputs) -> Vec<(Strategy, CostBounds)> {
        Strategy::all().into_iter().map(|s| (s, self.bounds(s, inputs))).collect()
    }

    /// The exact Table 6 dispatch of [`crate::model::StrategyModel::time`]
    /// with every `ab_for` lookup replaced by the envelope coefficients.
    fn envelope_time(&self, env: &Envelope, strategy: Strategy, inputs: &ModelInputs) -> f64 {
        let p = self.params;
        match (strategy.kind, strategy.transport) {
            (StrategyKind::Standard, Transport::Staged) => {
                let ab = env.ab(Endpoint::Cpu, Locality::OffNode);
                let mr = MaxRate { alpha: ab.alpha, rb: 1.0 / ab.beta, rn: p.rn() };
                mr.time_node_rails(inputs.m_std, inputs.s_proc, inputs.s_node, inputs.nics)
                    + copy::t_copy(p, inputs.s_proc, inputs.s_proc, 1)
            }
            (StrategyKind::Standard, Transport::DeviceAware) => {
                t_off_da_env(env.ab(Endpoint::Gpu, Locality::OffNode), inputs.m_std, inputs.s_proc)
            }
            (StrategyKind::ThreeStep, Transport::Staged) => {
                let i = deduped(inputs);
                self.t_off_env(env.ab(Endpoint::Cpu, Locality::OffNode), 1, i.s_n2n, i.s_node, i.nics)
                    + 2.0 * self.t_on_env(env, Endpoint::Cpu, i.s_n2n)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, 1)
            }
            (StrategyKind::ThreeStep, Transport::DeviceAware) => {
                let i = deduped(inputs);
                t_off_da_env(env.ab(Endpoint::Gpu, Locality::OffNode), 1, i.s_n2n)
                    + 2.0 * self.t_on_env(env, Endpoint::Gpu, i.s_n2n)
            }
            (StrategyKind::TwoStep, Transport::Staged) => {
                let i = deduped(inputs);
                self.t_off_env(env.ab(Endpoint::Cpu, Locality::OffNode), i.m_p2n, i.s_proc, i.s_node, i.nics)
                    + self.t_on_env(env, Endpoint::Cpu, i.s_proc)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, 1)
            }
            (StrategyKind::TwoStep, Transport::DeviceAware) => {
                let i = deduped(inputs);
                t_off_da_env(env.ab(Endpoint::Gpu, Locality::OffNode), i.m_p2n, i.s_proc)
                    + self.t_on_env(env, Endpoint::Gpu, i.s_proc)
            }
            (StrategyKind::SplitMd, Transport::Staged) | (StrategyKind::SplitDd, Transport::Staged) => {
                let i = deduped(inputs);
                let ppg = strategy.kind.ppg();
                let cap = strategy.message_cap.max(1);
                let (m_split, chunk) = split_chunks(&i, cap);
                self.t_off_env(env.ab(Endpoint::Cpu, Locality::OffNode), m_split, m_split * chunk, i.s_node, i.nics)
                    + 2.0 * self.t_on_split_env(env, i.s_proc, ppg, cap)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, ppg.min(4))
            }
            (k, Transport::DeviceAware) => unreachable!("{k} device-aware rejected at Strategy::new"),
        }
    }

    /// `offnode::t_off` with a fixed coefficient pair.
    fn t_off_env(&self, ab: AlphaBeta, m: usize, s_proc: usize, s_node: usize, nics: usize) -> f64 {
        let nic_term = s_node as f64 * self.params.inv_rn / nics.max(1) as f64;
        ab.alpha * m as f64 + nic_term.max(s_proc as f64 * ab.beta)
    }

    /// `onnode::t_on` with fixed coefficients.
    fn t_on_env(&self, env: &Envelope, ep: Endpoint, s: usize) -> f64 {
        let gps = self.machine.gpus_per_socket as f64;
        let sock = env.ab(ep, Locality::OnSocket);
        let node = env.ab(ep, Locality::OnNode);
        (gps - 1.0) * sock.time(s) + gps * node.time(s)
    }

    /// `onnode::t_on_split` with fixed coefficients (the chunk counting is
    /// size-driven and replicated exactly).
    fn t_on_split_env(&self, env: &Envelope, s_total: usize, ppg: usize, message_cap: usize) -> f64 {
        let cap = message_cap.max(1);
        let pps_ppg = (self.machine.cores_per_socket / ppg).max(1);
        let max_chunks = (self.machine.cores_per_node() / ppg).max(1);
        let mut chunks = s_total.div_ceil(cap).max(1);
        if chunks > max_chunks {
            chunks = max_chunks;
        }
        let s = s_total.div_ceil(chunks);
        let outgoing = chunks - 1;
        let sock_msgs = outgoing.min(pps_ppg.saturating_sub(1));
        let node_msgs = (outgoing - sock_msgs).min(pps_ppg);
        let sock = env.ab(Endpoint::Cpu, Locality::OnSocket);
        let node = env.ab(Endpoint::Cpu, Locality::OnNode);
        sock_msgs as f64 * sock.time(s) + node_msgs as f64 * node.time(s)
    }

    /// Occupancy floor on the simulated time of the schedule `strategy`
    /// builds — see the module docs for the three executor facts it rests
    /// on. Deliberately conservative: volumes are pre-deduped even for
    /// standard communication (which ships duplicates), message counts use
    /// only what every builder provably emits, and the caller scales the
    /// result by [`SAFETY`].
    fn sim_floor(&self, strategy: Strategy, inputs: &ModelInputs) -> f64 {
        let p = self.params;
        let i = deduped(inputs);
        let nics = i.nics.max(1);

        // Pigeonhole rail floor: the busiest node's bytes over its rails,
        // at the slower of the rail band and the cheapest message rate
        // (sound whichever of the two the executor's chain ends on).
        let band_beta = (0..nics).map(|r| p.nic_band(r).beta).fold(f64::INFINITY, f64::min);
        let msg_beta = self
            .lo
            .ab(Endpoint::Cpu, Locality::OffNode)
            .beta
            .min(self.lo.ab(Endpoint::Gpu, Locality::OffNode).beta);
        let vol = i.s_node as f64 * band_beta.min(msg_beta) / nics as f64;

        // Serialization floor on the busiest inter-node sender. Standard
        // builders emit one transfer per logical message, so the worst
        // sender pays m_std latencies and the worst byte-sender pays
        // s_proc at the envelope rate; conglomerating builders only
        // provably emit a single off-node transfer.
        let ep = match strategy.transport {
            Transport::DeviceAware => Endpoint::Gpu,
            Transport::Staged => Endpoint::Cpu,
        };
        let ab = self.lo.ab(ep, Locality::OffNode);
        let msgs = match strategy.kind {
            StrategyKind::Standard => (i.m_std as f64 * ab.alpha).max(i.s_proc as f64 * ab.beta),
            _ => {
                if i.s_n2n > 0 {
                    ab.alpha
                } else {
                    0.0
                }
            }
        };

        let mut floor = vol.max(msgs);

        // Staged transports run dedicated d2h / h2d copy phases around the
        // exchange whenever any data leaves the node; phases are barriers,
        // so each contributes at least one memcpy latency.
        if strategy.transport == Transport::Staged && i.s_n2n > 0 {
            let a_min = |dir| {
                let a1: AlphaBeta = p.memcpy_ab(dir, 1);
                let a4: AlphaBeta = p.memcpy_ab(dir, 4);
                a1.alpha.min(a4.alpha)
            };
            floor += a_min(CopyDir::D2H) + a_min(CopyDir::H2D);
        }
        floor
    }
}

fn t_off_da_env(ab: AlphaBeta, m: usize, s: usize) -> f64 {
    ab.alpha * m as f64 + s as f64 * ab.beta
}

/// The Split chunking of Algorithm 1 as `StrategyModel::time` applies it
/// (worst process injects `m_split` messages of `chunk` bytes).
fn split_chunks(i: &ModelInputs, cap: usize) -> (usize, usize) {
    let mut chunks = i.s_node.div_ceil(cap).max(1);
    if chunks > i.ppn.max(1) {
        chunks = i.ppn.max(1);
    }
    let chunk = i.s_node.div_ceil(chunks);
    let m_split = chunks.div_ceil(i.ppn.max(1)).max(1);
    (m_split, chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::StrategyModel;
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    fn scenario(n_msgs: usize, s: usize, n_dest: usize) -> ModelInputs {
        let gpn = 4;
        ModelInputs {
            s_proc: n_msgs / gpn * s,
            s_node: n_msgs * s,
            s_n2n: n_msgs / n_dest * s,
            m_p2n: n_dest,
            m_n2n: n_msgs / n_dest,
            m_std: n_msgs / gpn,
            ppn: 40,
            nics: 1,
            dup_frac: 0.0,
        }
    }

    #[test]
    fn envelope_brackets_the_model_everywhere() {
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let bm = BoundModel::new(&machine, &params);
        for n_msgs in [32, 256] {
            for n_dest in [4, 16] {
                for exp in 0..21 {
                    let mut inputs = scenario(n_msgs, 1 << exp, n_dest);
                    for dup in [0.0, 0.3] {
                        inputs.dup_frac = dup;
                        for (s, t) in sm.all_times(&inputs) {
                            let b = bm.bounds(s, &inputs);
                            assert!(
                                b.lower <= t && t <= b.upper,
                                "{}: {} not in [{}, {}] (msgs {n_msgs} dest {n_dest} exp {exp} dup {dup})",
                                s.label(),
                                t,
                                b.lower,
                                b.upper,
                            );
                            assert!(b.lower.is_finite() && b.upper.is_finite());
                            assert!(b.lower > 0.0, "{}: nonzero traffic must have a positive floor", s.label());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn standard_lower_scales_with_message_count() {
        // The branch-and-bound lever: per-message latency makes standard
        // communication's floor grow linearly in m_std at small sizes.
        let machine = lassen(16);
        let params = lassen_params();
        let bm = BoundModel::new(&machine, &params);
        let s = Strategy::all()[1]; // standard device-aware (Table 5 order)
        assert_eq!(s.kind, StrategyKind::Standard);
        assert_eq!(s.transport, Transport::DeviceAware);
        let few = bm.bounds(s, &scenario(32, 256, 4));
        let many = bm.bounds(s, &scenario(256, 256, 4));
        assert!(many.lower > 4.0 * few.lower, "floor must scale with m_std: {} vs {}", many.lower, few.lower);
    }

    #[test]
    fn gap_is_monotone_in_size() {
        // Envelopes have no size-dependent protocol switching, so both ends
        // of the interval are piecewise-linear in the message size and the
        // gap never shrinks as sizes grow.
        let machine = lassen(16);
        let params = lassen_params();
        let bm = BoundModel::new(&machine, &params);
        for s in Strategy::all() {
            let mut prev = 0.0f64;
            for exp in 0..21 {
                let b = bm.bounds(s, &scenario(256, 1 << exp, 4));
                let gap = b.upper - b.lower;
                assert!(gap >= prev - 1e-15, "{}: gap shrank at exp {exp}: {gap} < {prev}", s.label());
                prev = gap;
            }
        }
    }

    #[test]
    fn standard_upper_ignores_dup_fraction() {
        // Standard ships duplicates: its model (and hence the envelope
        // upper bound) must not move with dup_frac.
        let machine = lassen(16);
        let params = lassen_params();
        let bm = BoundModel::new(&machine, &params);
        for s in Strategy::all().into_iter().filter(|s| s.kind == StrategyKind::Standard) {
            let mut inputs = scenario(128, 4096, 8);
            let base = bm.bounds(s, &inputs);
            inputs.dup_frac = 0.4;
            let dup = bm.bounds(s, &inputs);
            assert_eq!(base.upper.to_bits(), dup.upper.to_bits(), "{}", s.label());
        }
    }

    #[test]
    fn zero_traffic_has_zero_floor() {
        let machine = lassen(4);
        let params = lassen_params();
        let bm = BoundModel::new(&machine, &params);
        let inputs = ModelInputs {
            s_proc: 0,
            s_node: 0,
            s_n2n: 0,
            m_p2n: 0,
            m_n2n: 0,
            m_std: 0,
            ppn: 40,
            nics: 1,
            dup_frac: 0.0,
        };
        for (s, b) in bm.all_bounds(&inputs) {
            assert!(b.lower >= 0.0 && b.lower <= b.upper, "{}", s.label());
        }
    }
}
