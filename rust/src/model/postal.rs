//! The postal model, Eq. (2.1): `T = α + β·s`.
//!
//! Used directly for device-aware transfers (the low GPU count per node
//! never saturates the NIC — Section 2.2) and as the building block of every
//! composite model.

use crate::params::AlphaBeta;

/// Time to send one `s`-byte message with parameters `ab` (Eq. 2.1).
pub fn time(ab: AlphaBeta, s: usize) -> f64 {
    ab.alpha + ab.beta * s as f64
}

/// Time to send `m` equally-sized messages of `s` bytes sequentially from
/// one process: latency is paid per message, bandwidth per byte.
pub fn time_m(ab: AlphaBeta, m: usize, s: usize) -> f64 {
    ab.alpha * m as f64 + ab.beta * (m * s) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;
    use crate::params::Protocol;
    use crate::topology::Locality;

    #[test]
    fn zero_bytes_is_latency() {
        let ab = AlphaBeta::new(2e-6, 4e-10);
        assert_eq!(time(ab, 0), 2e-6);
    }

    #[test]
    fn linear_in_bytes() {
        let ab = AlphaBeta::new(1e-6, 1e-9);
        assert!((time(ab, 1000) - (1e-6 + 1e-6)).abs() < 1e-18);
    }

    #[test]
    fn m_messages_pay_m_latencies() {
        let ab = AlphaBeta::new(1e-6, 1e-9);
        let t = time_m(ab, 8, 1024);
        assert!((t - (8e-6 + 8.0 * 1024.0 * 1e-9)).abs() < 1e-15);
    }

    #[test]
    fn paper_example_off_node_rendezvous() {
        // Table 2 off-node rendezvous CPU: alpha 7.76e-6, beta 7.97e-11.
        // A 1 MiB message: T = 7.76e-6 + 7.97e-11 * 2^20 ≈ 9.13e-5 s.
        let p = lassen_params();
        let ab = p.cpu_ab(Protocol::Rendezvous, Locality::OffNode);
        let t = time(ab, 1 << 20);
        assert!((t - (7.76e-6 + 7.97e-11 * (1u64 << 20) as f64)).abs() < 1e-15);
        assert!(t > 8e-5 && t < 1e-4);
    }
}
