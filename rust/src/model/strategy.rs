//! Composite strategy models — Table 6.
//!
//! | Strategy | Transport | Model |
//! |---|---|---|
//! | Standard | staged | max-rate (2.2) |
//! | Standard | device-aware | postal (2.1) |
//! | 3-Step | staged | `T_off(m_n2n, s_n2n) + 2·T_on(s_n2n) + T_copy(s_proc, s_n2n)` |
//! | 3-Step | device-aware | `T_off_DA(m_n2n, s_n2n) + 2·T_on(s_n2n)` |
//! | 2-Step | staged | `T_off(m_p2n, s_proc) + T_on(s_proc) + T_copy(s_proc, s_n2n)` |
//! | 2-Step | device-aware | `T_off_DA(m_p2n, s_proc) + T_on(s_proc)` |
//! | Split+MD | staged | `T_off(m_p2n, s_node/ppn) + 2·T_on_split(s_node, 1) + T_copy(s_proc, s_n2n)` |
//! | Split+DD | staged | `T_off(m_p2n, s_node/ppn) + 2·T_on_split(s_node, 4) + T_copy(s_proc, s_n2n)` |
//!
//! Inputs are the Table 7 pattern statistics. Duplicate-data removal
//! (Section 4.6, bottom rows of Figure 4.3) rescales the inter-node volumes
//! of the node-aware strategies only — standard communication still ships
//! the duplicates.

use crate::comm::{Strategy, StrategyKind, Transport};
use crate::model::{copy, maxrate::MaxRate, offnode, onnode};
use crate::params::{Endpoint, MachineParams};
use crate::topology::{Locality, Machine};

/// Table 7 pattern statistics plus run configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelInputs {
    /// `s_proc`: max bytes sent by a single process / GPU.
    pub s_proc: usize,
    /// `s_node`: max bytes injected by a single node.
    pub s_node: usize,
    /// `s_node→node`: max bytes sent between any two nodes.
    pub s_n2n: usize,
    /// `m_proc→node`: max number of nodes to which a process sends.
    pub m_p2n: usize,
    /// `m_node→node`: max number of messages between any two nodes.
    pub m_n2n: usize,
    /// Messages sent by the worst single process under *standard*
    /// communication (the `m` of Eq. 2.2).
    pub m_std: usize,
    /// Actively-communicating processes per node (`ppn` of Eq. 2.2 for
    /// standard staged; the Split off-node divisor).
    pub ppn: usize,
    /// NIC rails per node (the machine shape's
    /// [`crate::topology::NodeShape::nics_per_node`]): the staged off-node
    /// models divide the injection term over the rails (§6). 1 reproduces
    /// the paper's single-NIC Lassen models bit for bit.
    pub nics: usize,
    /// Fraction of inter-node data that is duplicated across destination
    /// processes on a node (removed by node-aware strategies).
    pub dup_frac: f64,
}

impl ModelInputs {
    /// Scale the inter-node volume statistics by `(1 - dup_frac)` — the
    /// node-aware adjustment of Section 4.6.
    fn deduped(&self) -> ModelInputs {
        let f = (1.0 - self.dup_frac).clamp(0.0, 1.0);
        let scale = |s: usize| ((s as f64) * f).ceil() as usize;
        ModelInputs { s_proc: scale(self.s_proc), s_node: scale(self.s_node), s_n2n: scale(self.s_n2n), ..*self }
    }
}

/// Evaluator for the Table 6 models on a given machine + parameter set.
#[derive(Clone, Debug)]
pub struct StrategyModel<'a> {
    pub machine: &'a Machine,
    pub params: &'a MachineParams,
}

impl<'a> StrategyModel<'a> {
    pub fn new(machine: &'a Machine, params: &'a MachineParams) -> Self {
        StrategyModel { machine, params }
    }

    /// Predicted time for `strategy` under `inputs` (Table 6).
    pub fn time(&self, strategy: Strategy, inputs: &ModelInputs) -> f64 {
        let p = self.params;
        let m = self.machine;
        match (strategy.kind, strategy.transport) {
            (StrategyKind::Standard, Transport::Staged) => {
                // Max-rate model (2.2) + the staging copies the transport
                // physically requires (Table 6 lists the network term; the
                // copy legs are shared by all staged strategies).
                let per_msg = if inputs.m_std > 0 { inputs.s_proc.div_ceil(inputs.m_std) } else { 0 };
                let ab = p.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
                let mr = MaxRate { alpha: ab.alpha, rb: 1.0 / ab.beta, rn: p.rn() };
                mr.time_node_rails(inputs.m_std, inputs.s_proc, inputs.s_node, inputs.nics)
                    + copy::t_copy(p, inputs.s_proc, inputs.s_proc, 1)
            }
            (StrategyKind::Standard, Transport::DeviceAware) => {
                // Postal model (2.1) with device-aware off-node parameters.
                offnode::t_off_da(p, inputs.m_std, inputs.s_proc)
            }
            (StrategyKind::ThreeStep, Transport::Staged) => {
                // `m_node→node` in the 3-Step schedule: conglomeration
                // leaves ONE buffer per node pair (Section 2.3.1) — this is
                // the "reduction in messages sent" of Section 4.6. The raw
                // m_n2n of the standard pattern only drives the standard
                // model.
                let i = inputs.deduped();
                offnode::t_off(p, 1, i.s_n2n, i.s_node, i.nics)
                    + 2.0 * onnode::t_on(m, p, Endpoint::Cpu, i.s_n2n)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, 1)
            }
            (StrategyKind::ThreeStep, Transport::DeviceAware) => {
                let i = inputs.deduped();
                offnode::t_off_da(p, 1, i.s_n2n) + 2.0 * onnode::t_on(m, p, Endpoint::Gpu, i.s_n2n)
            }
            (StrategyKind::TwoStep, Transport::Staged) => {
                let i = inputs.deduped();
                offnode::t_off(p, i.m_p2n, i.s_proc, i.s_node, i.nics)
                    + onnode::t_on(m, p, Endpoint::Cpu, i.s_proc)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, 1)
            }
            (StrategyKind::TwoStep, Transport::DeviceAware) => {
                let i = inputs.deduped();
                offnode::t_off_da(p, i.m_p2n, i.s_proc) + onnode::t_on(m, p, Endpoint::Gpu, i.s_proc)
            }
            (StrategyKind::SplitMd, Transport::Staged) | (StrategyKind::SplitDd, Transport::Staged) => {
                let i = inputs.deduped();
                let ppg = strategy.kind.ppg();
                let cap = strategy.message_cap.max(1);
                // Algorithm 1: the node's volume splits into <= cap chunks
                // spread over the ppn on-node processes; the worst process
                // injects ceil(chunks/ppn) messages of ~chunk size
                // (~s_node/ppn once the cap rises).
                let mut chunks = i.s_node.div_ceil(cap).max(1);
                if chunks > i.ppn.max(1) {
                    chunks = i.ppn.max(1);
                }
                let chunk = i.s_node.div_ceil(chunks);
                let m_split = chunks.div_ceil(i.ppn.max(1)).max(1);
                offnode::t_off(p, m_split, m_split * chunk, i.s_node, i.nics)
                    + 2.0 * onnode::t_on_split(m, p, i.s_proc, ppg, cap)
                    + copy::t_copy(p, i.s_proc, i.s_n2n, ppg.min(4))
            }
            (k, Transport::DeviceAware) => {
                unreachable!("{k} device-aware rejected at Strategy::new")
            }
        }
    }

    /// Evaluate every valid strategy; returns `(strategy, seconds)` in
    /// Table 5 order.
    pub fn all_times(&self, inputs: &ModelInputs) -> Vec<(Strategy, f64)> {
        Strategy::all().into_iter().map(|s| (s, self.time(s, inputs))).collect()
    }

    /// The fastest strategy for these inputs.
    pub fn best(&self, inputs: &ModelInputs) -> (Strategy, f64) {
        self.all_times(inputs)
            .into_iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("at least one strategy")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    /// Figure 4.3-style inputs: a node sends `n_msgs` messages of `s` bytes
    /// each, spread evenly over its 4 GPUs, to `n_dest` destination nodes.
    fn scenario(n_msgs: usize, s: usize, n_dest: usize) -> ModelInputs {
        let gpn = 4;
        ModelInputs {
            s_proc: n_msgs / gpn * s,
            s_node: n_msgs * s,
            s_n2n: n_msgs / n_dest * s,
            m_p2n: n_dest,
            m_n2n: n_msgs / n_dest,
            m_std: n_msgs / gpn,
            ppn: 40,
            nics: 1,
            dup_frac: 0.0,
        }
    }

    #[test]
    fn all_models_positive_finite() {
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        for n_msgs in [32, 256] {
            for n_dest in [4, 16] {
                for exp in 0..20 {
                    let inputs = scenario(n_msgs, 1 << exp, n_dest);
                    for (s, t) in sm.all_times(&inputs) {
                        assert!(t.is_finite() && t > 0.0, "{} -> {t}", s.label());
                    }
                }
            }
        }
    }

    #[test]
    fn node_aware_beats_standard_da_high_message_count() {
        // Section 4.6: with 256 inter-node messages, device-aware 3-Step /
        // 2-Step beat standard device-aware due to message reduction.
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let inputs = scenario(256, 2048, 16);
        let std_da = sm.time(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &inputs);
        let three_da = sm.time(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap(), &inputs);
        assert!(three_da < std_da, "3-step DA {three_da} !< standard DA {std_da}");
    }

    #[test]
    fn split_md_wins_many_nodes_moderate_sizes() {
        // Figure 4.3b headline: Split+MD is most performant for 16
        // destination nodes at moderate message sizes among staged
        // strategies.
        let machine = lassen(32);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let inputs = scenario(256, 1024, 16);
        let split_md = sm.time(Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap(), &inputs);
        let three = sm.time(Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap(), &inputs);
        let two = sm.time(Strategy::new(StrategyKind::TwoStep, Transport::Staged).unwrap(), &inputs);
        assert!(split_md < three, "Split+MD {split_md} !< 3-Step {three}");
        assert!(split_md < two, "Split+MD {split_md} !< 2-Step {two}");
    }

    #[test]
    fn split_dd_on_node_cheaper_but_copy_heavier() {
        // DD quarters the distribution messages but pays the 4-proc copy
        // latency; for small volumes MD wins overall (Section 5.1).
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let inputs = scenario(32, 256, 4);
        let md = sm.time(Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap(), &inputs);
        let dd = sm.time(Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap(), &inputs);
        assert!(md < dd, "MD {md} !< DD {dd} for small volumes");
    }

    #[test]
    fn dedup_reduces_node_aware_not_standard() {
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let mut inputs = scenario(256, 4096, 16);
        let base_3 = sm.time(Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap(), &inputs);
        let base_std = sm.time(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &inputs);
        inputs.dup_frac = 0.25;
        let dedup_3 = sm.time(Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap(), &inputs);
        let dedup_std = sm.time(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &inputs);
        assert!(dedup_3 < base_3);
        assert_eq!(dedup_std, base_std);
    }

    #[test]
    fn best_returns_minimum() {
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let inputs = scenario(256, 1024, 16);
        let (best, t) = sm.best(&inputs);
        for (s, ts) in sm.all_times(&inputs) {
            assert!(t <= ts, "best {} {t} > {} {ts}", best.label(), s.label());
        }
    }

    #[test]
    fn extra_rails_relieve_staged_models_only() {
        // §6: NIC rails divide the staged injection term; the device-aware
        // postal models never touch the NIC term, so their times hold still.
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let mut inputs = scenario(256, 1 << 14, 16); // injection-heavy
        let base = sm.all_times(&inputs);
        inputs.nics = 4;
        let railed = sm.all_times(&inputs);
        for ((s, t1), (_, t4)) in base.iter().zip(&railed) {
            match s.transport {
                Transport::DeviceAware => {
                    assert_eq!(t1.to_bits(), t4.to_bits(), "{} must ignore rails", s.label())
                }
                Transport::Staged => assert!(t4 <= t1, "{} must not slow down with rails", s.label()),
            }
        }
        // at least one staged strategy is genuinely injection-limited here
        assert!(
            base.iter().zip(&railed).any(|((s, t1), (_, t4))| s.transport == Transport::Staged && t4 < t1),
            "expected an injection-limited staged strategy at 16 KiB x 256 msgs"
        );
    }

    #[test]
    fn staged_nodeaware_beats_deviceaware_moderate_sizes() {
        // Core conclusion: staged-through-host node-aware wins for high
        // message counts at moderate sizes (the paper puts the crossover
        // near 10^4 B; our calibration lands it between 2 KiB and 4 KiB —
        // see EXPERIMENTS.md).
        let machine = lassen(16);
        let params = lassen_params();
        let sm = StrategyModel::new(&machine, &params);
        let inputs = scenario(256, 2048, 16);
        let best_staged = Strategy::all()
            .into_iter()
            .filter(|s| s.transport == Transport::Staged && s.kind != StrategyKind::Standard)
            .map(|s| sm.time(s, &inputs))
            .fold(f64::INFINITY, f64::min);
        let best_da = Strategy::all()
            .into_iter()
            .filter(|s| s.transport == Transport::DeviceAware)
            .map(|s| sm.time(s, &inputs))
            .fold(f64::INFINITY, f64::min);
        assert!(best_staged < best_da, "staged {best_staged} !< DA {best_da}");
    }
}
