//! On-node phase models (Sections 4.1–4.2).
//!
//! Eq. (4.1) — 3-Step/2-Step worst-case gather/redistribution:
//!
//! `T_on(s) = (gps − 1)(α_sock + β_sock·s) + gps·(α_node + β_node·s)`
//!
//! Eq. (4.2) — Split distribution across host processes:
//!
//! `T_on_split(s, ppg) = (pps/ppg − 1)(α_sock + β_sock·s) + (pps/ppg)(α_node + β_node·s)`
//!
//! Message sizes select the MPI protocol (and thus the Table 2 row), exactly
//! as a real Spectrum MPI run would.

use crate::params::{Endpoint, MachineParams};
use crate::topology::{Locality, Machine};

/// Eq. (4.1): worst-case on-node gather (or redistribution) time for
/// 3-Step / 2-Step, where `s` is the max bytes sent by any single GPU
/// (gather) or the max received inter-node message size (redistribution).
///
/// `ep` selects whether the hops are CPU messages (staged-through-host) or
/// device-aware GPU messages — the paper applies (4.1) with GPU parameters
/// for device-aware node-aware strategies.
pub fn t_on(machine: &Machine, params: &MachineParams, ep: Endpoint, s: usize) -> f64 {
    let gps = machine.gpus_per_socket as f64;
    let sock = params.ab_for(ep, Locality::OnSocket, s);
    let node = params.ab_for(ep, Locality::OnNode, s);
    (gps - 1.0) * sock.time(s) + gps * node.time(s)
}

/// Eq. (4.2): worst-case Split on-node distribution (or redistribution)
/// time. `s_total` is the inter-node volume held by the worst GPU (equal to
/// the node's entire volume in the paper's worst case, where a single GPU
/// contains all data to be sent off-node); `ppg` is host processes per GPU
/// (1 for Split+MD; up to 4 for Split+DD); `message_cap` is the Algorithm 1
/// chunk size.
///
/// The distribution message count follows Algorithm 1: `s_total` splits
/// into `⌈s_total / cap⌉` chunks (conglomeration keeps small volumes in few
/// messages; the cap rises when chunks would exceed the core count). Only
/// when the chunk count reaches `2·pps/ppg − 1` does this saturate to the
/// paper's stated worst case of `(pps/ppg − 1)` on-socket plus `pps/ppg`
/// on-node messages.
///
/// The hops are CPU messages (Split is staged-through-host only).
pub fn t_on_split(machine: &Machine, params: &MachineParams, s_total: usize, ppg: usize, message_cap: usize) -> f64 {
    assert!(ppg >= 1, "ppg must be >= 1");
    let cap = message_cap.max(1);
    let pps_ppg = (machine.cores_per_socket / ppg).max(1);
    let max_chunks = (machine.cores_per_node() / ppg).max(1);
    let mut chunks = s_total.div_ceil(cap).max(1);
    if chunks > max_chunks {
        chunks = max_chunks; // Algorithm 1 lines 14-17: raise the cap
    }
    let s = s_total.div_ceil(chunks);
    // One chunk stays with the staging process; the rest are distributed,
    // on-socket first.
    let outgoing = chunks - 1;
    let sock_msgs = outgoing.min(pps_ppg.saturating_sub(1));
    let node_msgs = (outgoing - sock_msgs).min(pps_ppg);
    let sock = params.ab_for(Endpoint::Cpu, Locality::OnSocket, s);
    let node = params.ab_for(Endpoint::Cpu, Locality::OnNode, s);
    sock_msgs as f64 * sock.time(s) + node_msgs as f64 * node.time(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    #[test]
    fn t_on_matches_formula() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 14; // rendezvous regime
        let sock = p.ab_for(Endpoint::Cpu, Locality::OnSocket, s);
        let node = p.ab_for(Endpoint::Cpu, Locality::OnNode, s);
        let expect = 1.0 * sock.time(s) + 2.0 * node.time(s); // gps=2
        assert!((t_on(&m, &p, Endpoint::Cpu, s) - expect).abs() < 1e-15);
    }

    #[test]
    fn t_on_gpu_params_heavier() {
        // Device-aware on-node hops cost more than CPU hops (Table 2 GPU
        // alphas dominate) for moderate sizes.
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        assert!(t_on(&m, &p, Endpoint::Gpu, s) > t_on(&m, &p, Endpoint::Cpu, s));
    }

    #[test]
    fn t_on_split_saturates_to_lassen_counts() {
        // Section 4.2: on Lassen with ppg=1, a fully-split volume requires
        // 19 on-socket + 20 on-node/off-socket messages.
        let m = lassen(2);
        let p = lassen_params();
        let cap: usize = 8192;
        let s_total = 40 * cap; // exactly 40 chunks
        let share = cap;
        let sock = p.ab_for(Endpoint::Cpu, Locality::OnSocket, share);
        let node = p.ab_for(Endpoint::Cpu, Locality::OnNode, share);
        let expect = 19.0 * sock.time(share) + 20.0 * node.time(share);
        assert!((t_on_split(&m, &p, s_total, 1, cap) - expect).abs() < 1e-15);
    }

    #[test]
    fn t_on_split_conglomerates_small_volumes() {
        // A volume under the cap stays with the staging proc: no
        // distribution messages at all (Algorithm 1 lines 12-13).
        let m = lassen(2);
        let p = lassen_params();
        assert_eq!(t_on_split(&m, &p, 4096, 1, 8192), 0.0);
    }

    #[test]
    fn t_on_split_partial_chunking() {
        // 3 chunks -> 2 outgoing messages, both on-socket.
        let m = lassen(2);
        let p = lassen_params();
        let cap: usize = 8192;
        let s_total = 3 * cap;
        let sock = p.ab_for(Endpoint::Cpu, Locality::OnSocket, cap);
        let expect = 2.0 * sock.time(cap);
        assert!((t_on_split(&m, &p, s_total, 1, cap) - expect).abs() < 1e-15);
    }

    #[test]
    fn t_on_split_cap_raised_beyond_cores() {
        // 100 x cap volume would be 100 chunks > 40 cores: cap rises so the
        // chunk count is bounded by the core count.
        let m = lassen(2);
        let p = lassen_params();
        let cap: usize = 8192;
        let s_total = 100 * cap;
        let chunks = 40;
        let s = s_total.div_ceil(chunks);
        let sock = p.ab_for(Endpoint::Cpu, Locality::OnSocket, s);
        let node = p.ab_for(Endpoint::Cpu, Locality::OnNode, s);
        let expect = 19.0 * sock.time(s) + 20.0 * node.time(s);
        assert!((t_on_split(&m, &p, s_total, 1, cap) - expect).abs() < 1e-15);
    }

    #[test]
    fn t_on_split_dd_fewer_messages() {
        // ppg=4 quarters the per-proc share count; for a fully split volume
        // DD's distribution phase is cheaper.
        let m = lassen(2);
        let p = lassen_params();
        let s_total = 80 * 8192;
        assert!(t_on_split(&m, &p, s_total, 4, 8192) < t_on_split(&m, &p, s_total, 1, 8192));
    }

    #[test]
    fn zero_bytes_free_split_but_not_gather() {
        let m = lassen(2);
        let p = lassen_params();
        assert!(t_on(&m, &p, Endpoint::Cpu, 0) > 0.0);
        assert_eq!(t_on_split(&m, &p, 0, 1, 8192), 0.0);
    }
}
