//! Host↔device staging cost, Eq. (4.5):
//!
//! `T_copy(s_send, s_recv) = α_H2D + β_H2D·s_send + α_D2H + β_D2H·s_recv`
//!
//! Note the paper's (4.5) is written from the *host staging* perspective of
//! one endpoint pair: the sender D2H-copies `s_send` off its GPU and the
//! receiver H2D-copies `s_recv` onto its GPU; both legs appear in the
//! end-to-end critical path. With duplicate device pointers (Split+DD),
//! four host processes copy concurrently and the 4-proc parameter class of
//! Table 3 applies.

use crate::params::{CopyDir, MachineParams};

/// Eq. (4.5) with `nprocs` host processes per GPU performing the copies
/// (1 for every strategy except Split+DD, which uses 4).
pub fn t_copy(params: &MachineParams, s_send: usize, s_recv: usize, nprocs: usize) -> f64 {
    params.memcpy_time(CopyDir::D2H, s_send, nprocs) + params.memcpy_time(CopyDir::H2D, s_recv, nprocs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;

    #[test]
    fn matches_formula_single_proc() {
        let p = lassen_params();
        let (ss, sr) = (1 << 16, 1 << 14);
        let expect = (1.27e-5 + 1.96e-11 * ss as f64) + (1.30e-5 + 1.85e-11 * sr as f64);
        assert!((t_copy(&p, ss, sr, 1) - expect).abs() < 1e-15);
    }

    #[test]
    fn four_proc_splits_bytes() {
        let p = lassen_params();
        let s = 1 << 20;
        let expect = (1.47e-5 + 1.50e-10 * (s as f64 / 4.0)) + (1.52e-5 + 5.52e-10 * (s as f64 / 4.0));
        assert!((t_copy(&p, s, s, 4) - expect).abs() < 1e-12);
    }

    #[test]
    fn dd_latency_penalty_small_messages() {
        // The paper (Section 5.1): DD's duplicate-pointer latency
        // (~1.5e-5) exceeds MD's path for small copies.
        let p = lassen_params();
        assert!(t_copy(&p, 64, 64, 4) > t_copy(&p, 64, 64, 1));
    }

    #[test]
    fn zero_copy_pays_latency_only() {
        let p = lassen_params();
        assert!((t_copy(&p, 0, 0, 1) - (1.27e-5 + 1.30e-5)).abs() < 1e-15);
    }
}
