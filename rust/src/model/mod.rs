//! Performance models (Sections 2.2, 4).
//!
//! - [`postal`] — the postal model, Eq. (2.1).
//! - [`maxrate`] — the max-rate model with NIC injection limits, Eq. (2.2).
//! - [`onnode`] — on-node phases: `T_on` (4.1) for 3-Step/2-Step gathers and
//!   redistributions, `T_on_split` (4.2) for the Split strategies.
//! - [`offnode`] — off-node phases: `T_off` (4.3, staged max-rate) and
//!   `T_off_DA` (4.4, device-aware postal).
//! - [`copy`] — host↔device staging cost `T_copy` (4.5).
//! - [`strategy`] — the composite models of Table 6 plus duplicate-data
//!   adjustment, evaluated either from explicit Table 7 parameters or from a
//!   [`crate::pattern::CommPattern`].
//! - [`bounds`] — per-strategy `[lower, upper]` cost intervals derived from
//!   the Table 6 closed forms; the branch-and-bound oracle behind
//!   `sweep --prune`.

pub mod bounds;
pub mod copy;
pub mod maxrate;
pub mod offnode;
pub mod onnode;
pub mod postal;
pub mod strategy;

pub use bounds::{BoundModel, CostBounds};
pub use strategy::{ModelInputs, StrategyModel};
