//! The max-rate model, Eq. (2.2):
//!
//! `T = α·m + max( ppn·s / R_N , s / R_b )`
//!
//! where `m` is the max messages sent by one process on the node, `s` the
//! max bytes sent by one process, `ppn` the actively-communicating processes
//! per node, `R_N` the NIC injection rate, and `R_b` the per-process
//! transport rate. When `ppn·R_b < R_N` it reduces to the postal model.

/// Max-rate model inputs.
#[derive(Clone, Copy, Debug)]
pub struct MaxRate {
    /// Latency per message [s].
    pub alpha: f64,
    /// Per-process transport rate R_b [B/s] (i.e. `1/β`).
    pub rb: f64,
    /// NIC injection rate R_N [B/s].
    pub rn: f64,
}

impl MaxRate {
    /// Eq. (2.2) exactly as written: `m` messages, `s` max bytes per
    /// process, `ppn` active processes per node.
    pub fn time(&self, m: usize, s: usize, ppn: usize) -> f64 {
        let s = s as f64;
        self.alpha * m as f64 + ((ppn as f64 * s) / self.rn).max(s / self.rb)
    }

    /// The generalized form used in Eq. (4.3), where the node-injected bytes
    /// `s_node` need not equal `ppn * s_proc` for irregular patterns:
    /// `T = α·m + max(s_node / R_N, s_proc / R_b)`.
    pub fn time_node(&self, m: usize, s_proc: usize, s_node: usize) -> f64 {
        self.alpha * m as f64 + (s_node as f64 / self.rn).max(s_proc as f64 / self.rb)
    }

    /// [`MaxRate::time_node`] generalized to `nics` injecting NICs — the
    /// paper's §6 multi-rail form, where the node's injection limit is
    /// `min(ppn·R_b, nic_count·R_N)` expressed as
    /// `T = α·m + max(s_node / (nics·R_N), s_proc / R_b)`.
    /// At `nics = 1` this is bit-identical to [`MaxRate::time_node`]
    /// (`R_N · 1.0 == R_N`).
    pub fn time_node_rails(&self, m: usize, s_proc: usize, s_node: usize, nics: usize) -> f64 {
        let rn_node = self.rn * nics.max(1) as f64;
        self.alpha * m as f64 + (s_node as f64 / rn_node).max(s_proc as f64 / self.rb)
    }

    /// True when this configuration is injection-bandwidth limited (the NIC
    /// term dominates the per-process term).
    pub fn nic_limited(&self, s_proc: usize, s_node: usize) -> bool {
        s_node as f64 / self.rn > s_proc as f64 / self.rb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{lassen_params, Protocol};
    use crate::topology::Locality;

    fn lassen_maxrate() -> MaxRate {
        let p = lassen_params();
        let ab = p.cpu_ab(Protocol::Rendezvous, Locality::OffNode);
        MaxRate { alpha: ab.alpha, rb: 1.0 / ab.beta, rn: p.rn() }
    }

    #[test]
    fn reduces_to_postal_for_one_process() {
        let mr = lassen_maxrate();
        let s = 1 << 16;
        // One process on the node: ppn*Rb vs RN — on Lassen Rb ≈ 1.25e10,
        // RN ≈ 2.39e10, so a single process cannot saturate the NIC.
        let t = mr.time(1, s, 1);
        let postal = mr.alpha + s as f64 / mr.rb;
        assert!((t - postal).abs() < 1e-15);
    }

    #[test]
    fn saturates_with_many_processes() {
        let mr = lassen_maxrate();
        let s = 1 << 20;
        // 40 processes all sending s bytes: NIC term dominates.
        let t40 = mr.time(1, s, 40);
        let nic = mr.alpha + 40.0 * s as f64 / mr.rn;
        assert!((t40 - nic).abs() < 1e-12);
        assert!(mr.nic_limited(s, 40 * s));
    }

    #[test]
    fn crossover_ppn() {
        // ppn where ppn/RN > 1/Rb: ppn > RN/Rb = RN*beta.
        let mr = lassen_maxrate();
        let crossover = mr.rn / mr.rb; // ≈ 2.39e10 * 7.97e-11 ≈ 1.9
        assert!(crossover > 1.0 && crossover < 3.0, "crossover {crossover}");
        let s = 1 << 20;
        assert!(!mr.nic_limited(s, s)); // ppn=1
        assert!(mr.nic_limited(s, 3 * s)); // ppn=3
    }

    #[test]
    fn latency_scales_with_messages() {
        let mr = lassen_maxrate();
        let t1 = mr.time(1, 1024, 1);
        let t10 = mr.time(10, 1024, 1);
        assert!((t10 - t1 - 9.0 * mr.alpha).abs() < 1e-15);
    }

    #[test]
    fn time_node_generalizes_time() {
        let mr = lassen_maxrate();
        let (m, s, ppn) = (4, 1 << 18, 8);
        assert!((mr.time(m, s, ppn) - mr.time_node(m, s, ppn * s)).abs() < 1e-15);
    }

    #[test]
    fn one_rail_is_bit_identical_to_time_node() {
        let mr = lassen_maxrate();
        for (m, s_proc, s_node) in [(1usize, 1usize << 10, 1usize << 12), (7, 1 << 18, 40 << 18), (16, 1, 1)] {
            assert_eq!(
                mr.time_node_rails(m, s_proc, s_node, 1).to_bits(),
                mr.time_node(m, s_proc, s_node).to_bits(),
                "{m} {s_proc} {s_node}"
            );
        }
    }

    #[test]
    fn rails_relieve_only_the_nic_term() {
        let mr = lassen_maxrate();
        let (m, s_proc) = (4, 1 << 18);
        let s_node = 40 * s_proc; // heavily NIC-limited at 1 rail
        let t1 = mr.time_node_rails(m, s_proc, s_node, 1);
        let t4 = mr.time_node_rails(m, s_proc, s_node, 4);
        assert!(t4 < t1, "4 rails must relieve an injection-limited node: {t4} !< {t1}");
        // once the per-process term dominates, more rails stop helping
        let light = mr.time_node_rails(m, s_proc, s_proc, 1);
        assert_eq!(light.to_bits(), mr.time_node_rails(m, s_proc, s_proc, 16).to_bits());
    }
}
