//! Irregular point-to-point communication patterns.
//!
//! A [`CommPattern`] is the multiset of GPU→GPU payloads an operation must
//! deliver — for a distributed SpMV, exactly the off-GPU vector values each
//! owner must ship to each consumer. Strategies consume the pattern;
//! [`CommPattern::stats`] derives the Table 7 parameters that feed the
//! Table 6 models.

pub mod generators;

use crate::model::ModelInputs;
use crate::topology::{GpuId, Machine, NodeId};
use std::collections::BTreeMap;

/// One logical message: `bytes` of payload owned by GPU `src` required by
/// GPU `dst`. `dup_group` marks payloads that carry identical data: messages
/// sharing a (src, dup_group) pair with dup_group != NONE duplicate the same
/// source bytes (Section 2.3's "data redundancy"), which node-aware
/// strategies may send across the network only once per destination node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Msg {
    pub src: GpuId,
    pub dst: GpuId,
    pub bytes: usize,
    pub dup_group: u32,
}

impl Msg {
    pub const NO_DUP: u32 = u32::MAX;

    pub fn new(src: GpuId, dst: GpuId, bytes: usize) -> Msg {
        Msg { src, dst, bytes, dup_group: Msg::NO_DUP }
    }
}

/// The communication pattern of one operation instance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommPattern {
    pub msgs: Vec<Msg>,
}

/// Table 7 statistics plus derived counts, computed against a machine.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PatternStats {
    /// Max bytes sent inter-node by a single GPU (`s_proc`).
    pub s_proc: usize,
    /// Max bytes injected into the network by a single node (`s_node`).
    pub s_node: usize,
    /// Max bytes between any ordered node pair (`s_node→node`).
    pub s_n2n: usize,
    /// Max number of distinct destination nodes for any single GPU
    /// (`m_proc→node`).
    pub m_p2n: usize,
    /// Max number of messages between any ordered node pair
    /// (`m_node→node`).
    pub m_n2n: usize,
    /// Max inter-node messages sent by a single GPU (standard `m`).
    pub m_std: usize,
    /// Max number of distinct source nodes any node receives from
    /// (`num_IN_nodes` of Table 1).
    pub num_in_nodes: usize,
    /// Total inter-node bytes received by the heaviest node
    /// (`total_IN_recv_vol` of Table 1).
    pub total_in_recv_vol: usize,
    /// Max bytes received by a node from one other node
    /// (`max_IN_recv_size` of Table 1).
    pub max_in_recv_size: usize,
    /// Total inter-node message count across the whole pattern.
    pub total_internode_msgs: usize,
    /// Total inter-node bytes across the whole pattern.
    pub total_internode_bytes: usize,
}

impl CommPattern {
    pub fn new(msgs: Vec<Msg>) -> CommPattern {
        CommPattern { msgs }
    }

    pub fn push(&mut self, msg: Msg) {
        self.msgs.push(msg);
    }

    pub fn is_empty(&self) -> bool {
        self.msgs.is_empty()
    }

    /// Total payload bytes (all localities).
    pub fn total_bytes(&self) -> usize {
        self.msgs.iter().map(|m| m.bytes).sum()
    }

    /// Messages crossing node boundaries.
    pub fn internode<'a>(&'a self, machine: &'a Machine) -> impl Iterator<Item = &'a Msg> + 'a {
        self.msgs.iter().filter(move |m| machine.gpu_node(m.src) != machine.gpu_node(m.dst))
    }

    /// Messages staying within a node.
    pub fn intranode<'a>(&'a self, machine: &'a Machine) -> impl Iterator<Item = &'a Msg> + 'a {
        self.msgs.iter().filter(move |m| machine.gpu_node(m.src) == machine.gpu_node(m.dst))
    }

    /// Compute the Table 7 / Table 1 statistics.
    pub fn stats(&self, machine: &Machine) -> PatternStats {
        let mut per_gpu_bytes: BTreeMap<GpuId, usize> = BTreeMap::new();
        let mut per_gpu_msgs: BTreeMap<GpuId, usize> = BTreeMap::new();
        let mut per_gpu_dests: BTreeMap<GpuId, std::collections::BTreeSet<NodeId>> = BTreeMap::new();
        let mut per_node_bytes: BTreeMap<NodeId, usize> = BTreeMap::new();
        let mut per_pair_bytes: BTreeMap<(NodeId, NodeId), usize> = BTreeMap::new();
        let mut per_pair_msgs: BTreeMap<(NodeId, NodeId), usize> = BTreeMap::new();
        let mut recv_vol: BTreeMap<NodeId, usize> = BTreeMap::new();
        let mut recv_srcs: BTreeMap<NodeId, std::collections::BTreeSet<NodeId>> = BTreeMap::new();
        let mut total_msgs = 0usize;
        let mut total_bytes = 0usize;

        for m in self.internode(machine) {
            let sn = machine.gpu_node(m.src);
            let dn = machine.gpu_node(m.dst);
            *per_gpu_bytes.entry(m.src).or_default() += m.bytes;
            *per_gpu_msgs.entry(m.src).or_default() += 1;
            per_gpu_dests.entry(m.src).or_default().insert(dn);
            *per_node_bytes.entry(sn).or_default() += m.bytes;
            *per_pair_bytes.entry((sn, dn)).or_default() += m.bytes;
            *per_pair_msgs.entry((sn, dn)).or_default() += 1;
            *recv_vol.entry(dn).or_default() += m.bytes;
            recv_srcs.entry(dn).or_default().insert(sn);
            total_msgs += 1;
            total_bytes += m.bytes;
        }

        PatternStats {
            s_proc: per_gpu_bytes.values().copied().max().unwrap_or(0),
            s_node: per_node_bytes.values().copied().max().unwrap_or(0),
            s_n2n: per_pair_bytes.values().copied().max().unwrap_or(0),
            m_p2n: per_gpu_dests.values().map(|s| s.len()).max().unwrap_or(0),
            m_n2n: per_pair_msgs.values().copied().max().unwrap_or(0),
            m_std: per_gpu_msgs.values().copied().max().unwrap_or(0),
            num_in_nodes: recv_srcs.values().map(|s| s.len()).max().unwrap_or(0),
            total_in_recv_vol: recv_vol.values().copied().max().unwrap_or(0),
            max_in_recv_size: per_pair_bytes.values().copied().max().unwrap_or(0),
            total_internode_msgs: total_msgs,
            total_internode_bytes: total_bytes,
        }
    }

    /// Model inputs for the Table 6 evaluator. `ppn` is the active host
    /// process count per node; `dup_frac` the duplicate-data fraction
    /// (computed from `dup_group`s by [`CommPattern::duplicate_fraction`] or
    /// supplied for synthetic scenarios).
    pub fn model_inputs(&self, machine: &Machine, ppn: usize, dup_frac: f64) -> ModelInputs {
        let st = self.stats(machine);
        ModelInputs {
            s_proc: st.s_proc,
            s_node: st.s_node,
            s_n2n: st.s_n2n,
            m_p2n: st.m_p2n,
            m_n2n: st.m_n2n,
            m_std: st.m_std,
            ppn,
            nics: machine.nics_per_node(),
            dup_frac,
        }
    }

    /// Fraction of inter-node bytes that are duplicates: for each
    /// (src GPU, dup_group, destination node) the first copy is unique and
    /// the rest are redundant.
    pub fn duplicate_fraction(&self, machine: &Machine) -> f64 {
        let mut total = 0usize;
        let mut dup = 0usize;
        let mut seen: std::collections::BTreeSet<(GpuId, u32, NodeId)> = std::collections::BTreeSet::new();
        for m in self.internode(machine) {
            total += m.bytes;
            if m.dup_group != Msg::NO_DUP {
                let key = (m.src, m.dup_group, machine.gpu_node(m.dst));
                if !seen.insert(key) {
                    dup += m.bytes;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            dup as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::machines::lassen;

    fn g(i: usize) -> GpuId {
        GpuId(i)
    }

    #[test]
    fn empty_pattern_zero_stats() {
        let m = lassen(2);
        let st = CommPattern::default().stats(&m);
        assert_eq!(st, PatternStats::default());
    }

    #[test]
    fn intranode_excluded_from_stats() {
        let m = lassen(2);
        let p = CommPattern::new(vec![
            Msg::new(g(0), g(1), 100), // on-socket
            Msg::new(g(0), g(2), 100), // on-node
        ]);
        let st = p.stats(&m);
        assert_eq!(st.total_internode_msgs, 0);
        assert_eq!(st.s_proc, 0);
    }

    #[test]
    fn table7_stats_basic() {
        let m = lassen(3); // nodes: gpus 0-3, 4-7, 8-11
        let p = CommPattern::new(vec![
            Msg::new(g(0), g(4), 100),
            Msg::new(g(0), g(5), 200),
            Msg::new(g(0), g(8), 50),
            Msg::new(g(1), g(9), 400),
        ]);
        let st = p.stats(&m);
        assert_eq!(st.s_proc, 400); // gpu1 sends 400 > gpu0's 350
        assert_eq!(st.s_node, 750); // node0 injects everything
        assert_eq!(st.s_n2n, 450); // node0->node2: 50+400
        assert_eq!(st.m_p2n, 2); // gpu0 sends to nodes 1 and 2
        assert_eq!(st.m_n2n, 2); // node0->node1 two msgs
        assert_eq!(st.m_std, 3); // gpu0 sends 3 msgs
        assert_eq!(st.num_in_nodes, 1);
        assert_eq!(st.total_in_recv_vol, 450); // node2 receives 450
        assert_eq!(st.max_in_recv_size, 450);
        assert_eq!(st.total_internode_bytes, 750);
    }

    #[test]
    fn duplicate_fraction_counts_repeats_per_node() {
        let m = lassen(2);
        let mut a = Msg::new(g(0), g(4), 100);
        a.dup_group = 7;
        let mut b = Msg::new(g(0), g(5), 100); // same data, same dest node -> dup
        b.dup_group = 7;
        let mut c = Msg::new(g(0), g(6), 100); // same data, same node -> dup
        c.dup_group = 7;
        let d = Msg::new(g(0), g(7), 100); // unique payload
        let p = CommPattern::new(vec![a, b, c, d]);
        let f = p.duplicate_fraction(&m);
        assert!((f - 0.5).abs() < 1e-12, "got {f}"); // 200 of 400 bytes redundant
    }

    #[test]
    fn model_inputs_carry_stats() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(g(0), g(4), 1024)]);
        let mi = p.model_inputs(&m, 40, 0.1);
        assert_eq!(mi.s_proc, 1024);
        assert_eq!(mi.ppn, 40);
        assert_eq!(mi.dup_frac, 0.1);
        assert_eq!(mi.m_std, 1);
    }
}
