//! Scenario generators for the modeled-performance study (Figure 4.3) and
//! random irregular patterns for property tests.

use super::{CommPattern, Msg};
use crate::model::ModelInputs;
use crate::topology::{GpuId, Machine};
use crate::util::rng::Rng;

/// The 2-Step sub-scenarios of Section 4.6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TwoStepCase {
    /// "2-Step All": every GPU on the source node sends to every GPU on the
    /// destination node.
    All,
    /// "2-Step 1": all messages to a destination node originate from a
    /// single active GPU — the best case, where pairing is perfect.
    One,
}

/// Figure 4.3 scenario: one node sends `n_msgs` messages of `msg_size`
/// bytes, spread evenly across its GPUs, to `n_dest` destination nodes.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub n_msgs: usize,
    pub msg_size: usize,
    pub n_dest: usize,
    /// Fraction of data that is duplicated (0.25 in the figure's bottom
    /// rows).
    pub dup_frac: f64,
}

impl Scenario {
    /// Model inputs for the standard / 3-Step / Split models and the
    /// "2-Step All" case.
    pub fn inputs(&self, machine: &Machine, ppn: usize) -> ModelInputs {
        let gpn = machine.gpus_per_node();
        let per_gpu = self.n_msgs.div_ceil(gpn);
        let per_pair = self.n_msgs.div_ceil(self.n_dest);
        ModelInputs {
            s_proc: per_gpu * self.msg_size,
            s_node: self.n_msgs * self.msg_size,
            s_n2n: per_pair * self.msg_size,
            m_p2n: self.n_dest.min(per_gpu),
            m_n2n: per_pair,
            m_std: per_gpu,
            ppn,
            nics: machine.nics_per_node(),
            dup_frac: self.dup_frac,
        }
    }

    /// Model inputs for the 2-Step sub-cases: `All` matches
    /// [`Scenario::inputs`]; `One` concentrates each destination node's
    /// traffic on a single source GPU, so the active GPU pairs with exactly
    /// one destination (m_p2n = 1) and carries that node-pair's volume.
    pub fn inputs_two_step(&self, machine: &Machine, ppn: usize, case: TwoStepCase) -> ModelInputs {
        let mut mi = self.inputs(machine, ppn);
        if case == TwoStepCase::One {
            let per_pair = self.n_msgs.div_ceil(self.n_dest);
            mi.s_proc = per_pair * self.msg_size;
            mi.m_p2n = 1;
            mi.m_std = per_pair;
        }
        mi
    }

    /// Materialize the scenario as an explicit [`CommPattern`] (used to
    /// cross-check the closed-form inputs against `CommPattern::stats` and
    /// to drive the simulator on the same workload).
    ///
    /// Node 0 is the sender; destinations rotate over nodes `1..=n_dest` and
    /// their GPUs. Requires `machine.num_nodes > n_dest`.
    pub fn materialize(&self, machine: &Machine) -> CommPattern {
        assert!(machine.num_nodes > self.n_dest, "need {} nodes, machine has {}", self.n_dest + 1, machine.num_nodes);
        let gpn = machine.gpus_per_node();
        let mut msgs = Vec::with_capacity(self.n_msgs);
        for i in 0..self.n_msgs {
            let src = GpuId(i % gpn); // even spread over node-0 GPUs
            let dest_node = 1 + (i % self.n_dest);
            let dst = GpuId(dest_node * gpn + (i / self.n_dest) % gpn);
            msgs.push(Msg::new(src, dst, self.msg_size));
        }
        CommPattern::new(msgs)
    }
}

/// Random irregular pattern over a machine: `n_msgs` messages with sizes
/// log-uniform in `[1, max_bytes]`, endpoints uniform over distinct GPUs.
/// With probability `dup_p`, a message reuses the previous message's source
/// and duplicate group (modeling the data redundancy of Section 2.3).
pub fn random_pattern(machine: &Machine, rng: &mut Rng, n_msgs: usize, max_bytes: usize, dup_p: f64) -> CommPattern {
    let total = machine.total_gpus();
    assert!(total >= 2, "need at least 2 GPUs");
    let mut msgs: Vec<Msg> = Vec::with_capacity(n_msgs);
    let mut next_group: u32 = 0;
    for _ in 0..n_msgs {
        let reuse = !msgs.is_empty() && rng.bool(dup_p);
        let (src, bytes, group) = if reuse {
            let prev = *msgs.last().unwrap();
            let g = if prev.dup_group == Msg::NO_DUP {
                let g = next_group;
                next_group += 1;
                msgs.last_mut().unwrap().dup_group = g;
                g
            } else {
                prev.dup_group
            };
            (prev.src, prev.bytes, g)
        } else {
            let src = GpuId(rng.usize_in(0, total));
            let exp = rng.usize_in(0, (max_bytes.max(2) as f64).log2() as usize + 1);
            let bytes = (1usize << exp).min(max_bytes).max(1);
            (src, bytes, Msg::NO_DUP)
        };
        let mut dst = GpuId(rng.usize_in(0, total));
        while dst == src {
            dst = GpuId(rng.usize_in(0, total));
        }
        msgs.push(Msg { src, dst, bytes, dup_group: group });
    }
    CommPattern::new(msgs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::machines::lassen;

    #[test]
    fn scenario_inputs_match_materialized_stats() {
        let machine = lassen(17);
        for (n_msgs, n_dest) in [(32, 4), (256, 4), (32, 16), (256, 16)] {
            let sc = Scenario { n_msgs, msg_size: 2048, n_dest, dup_frac: 0.0 };
            let mi = sc.inputs(&machine, 40);
            let st = sc.materialize(&machine).stats(&machine);
            assert_eq!(mi.s_node, st.s_node, "{n_msgs}/{n_dest} s_node");
            assert_eq!(mi.s_proc, st.s_proc, "{n_msgs}/{n_dest} s_proc");
            assert_eq!(mi.s_n2n, st.s_n2n, "{n_msgs}/{n_dest} s_n2n");
            assert_eq!(mi.m_n2n, st.m_n2n, "{n_msgs}/{n_dest} m_n2n");
            assert_eq!(mi.m_std, st.m_std, "{n_msgs}/{n_dest} m_std");
        }
    }

    #[test]
    fn two_step_one_is_lighter_per_proc() {
        let machine = lassen(17);
        let sc = Scenario { n_msgs: 256, msg_size: 1024, n_dest: 16, dup_frac: 0.0 };
        let all = sc.inputs_two_step(&machine, 40, TwoStepCase::All);
        let one = sc.inputs_two_step(&machine, 40, TwoStepCase::One);
        assert_eq!(one.m_p2n, 1);
        assert!(one.s_proc <= all.s_proc * 16);
        assert_eq!(one.s_node, all.s_node); // node volume unchanged
    }

    #[test]
    fn materialize_counts() {
        let machine = lassen(5);
        let sc = Scenario { n_msgs: 32, msg_size: 64, n_dest: 4, dup_frac: 0.0 };
        let p = sc.materialize(&machine);
        assert_eq!(p.msgs.len(), 32);
        // All messages leave node 0.
        assert!(p.msgs.iter().all(|m| machine.gpu_node(m.src).0 == 0));
        assert!(p.msgs.iter().all(|m| machine.gpu_node(m.dst).0 != 0));
    }

    #[test]
    fn random_pattern_valid() {
        let machine = lassen(4);
        let mut rng = Rng::new(1);
        let p = random_pattern(&machine, &mut rng, 500, 1 << 16, 0.3);
        assert_eq!(p.msgs.len(), 500);
        for m in &p.msgs {
            assert_ne!(m.src, m.dst);
            assert!(m.bytes >= 1 && m.bytes <= 1 << 16);
            assert!(m.src.0 < machine.total_gpus());
            assert!(m.dst.0 < machine.total_gpus());
        }
        // some duplicates should exist at dup_p = 0.3
        assert!(p.duplicate_fraction(&machine) > 0.0);
    }
}
