//! The serving layer: cached, thread-pooled `advise` queries over compiled
//! decision surfaces, plus the deterministic synthetic burst benchmark the
//! CI uses to hold the cache to a hit-rate floor.
//!
//! Answers are deterministic: a query resolves against an immutable surface
//! and the cache only memoizes, so a seeded burst produces the same winner
//! histogram at any thread count (only measured latencies vary).

use super::cache::{CacheKey, CacheStats, ShardedLru};
use super::surface::{DecisionSurface, Pattern, RankedStrategies};
use crate::params::MachineParams;
use crate::util::pool::{self, effective_threads};
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One advise query: a pattern plus the surface (machine) it targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub pattern: Pattern,
    /// Index into the service's surface list ([`AdvisorService::surface_index`]).
    pub surface: usize,
}

/// Outcome of a synthetic burst.
#[derive(Clone, Debug)]
pub struct BurstReport {
    pub queries: usize,
    /// Distinct patterns in the seeded pool.
    pub distinct: usize,
    pub threads: usize,
    /// Cache counter deltas over the burst.
    pub cache: CacheStats,
    /// Winner label → count over the whole burst (seed-deterministic).
    pub winners: BTreeMap<&'static str, usize>,
    /// Measured per-query lookup latency percentiles [s].
    pub p50_s: f64,
    pub p99_s: f64,
    pub elapsed_s: f64,
}

/// The advisor service: one surface per machine behind a shared cache.
pub struct AdvisorService {
    surfaces: Vec<RwLock<DecisionSurface>>,
    names: Vec<String>,
    cache: ShardedLru,
}

impl AdvisorService {
    /// Default cache geometry: 16 shards, 4096 answers total.
    pub fn new(surfaces: Vec<DecisionSurface>) -> AdvisorService {
        AdvisorService::with_cache(surfaces, ShardedLru::new(16, 4096))
    }

    pub fn with_cache(surfaces: Vec<DecisionSurface>, cache: ShardedLru) -> AdvisorService {
        let names = surfaces.iter().map(|s| s.machine.clone()).collect();
        AdvisorService { surfaces: surfaces.into_iter().map(RwLock::new).collect(), names, cache }
    }

    /// Machines served, in surface order.
    pub fn machines(&self) -> &[String] {
        &self.names
    }

    /// Index of a machine's surface.
    pub fn surface_index(&self, machine: &str) -> Option<usize> {
        self.names.iter().position(|n| n == machine)
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Answer one query: a cache probe, falling back to an interpolated
    /// surface lookup that is then memoized.
    pub fn advise(&self, q: &Query) -> Result<Arc<RankedStrategies>, String> {
        let key = CacheKey {
            surface: q.surface,
            n_msgs: q.pattern.n_msgs,
            msg_size: q.pattern.msg_size,
            dest_nodes: q.pattern.dest_nodes,
            gpus_per_node: q.pattern.gpus_per_node,
        };
        if let Some(hit) = self.cache.get(&key) {
            return Ok(hit);
        }
        let generation = self.cache.generation_of(&key);
        let surface = self.surfaces.get(q.surface).ok_or_else(|| format!("no surface with index {}", q.surface))?;
        let value = Arc::new(surface.read().expect("surface lock poisoned").lookup(&q.pattern));
        // Memoize generation-guarded: a recalibration that cleared the cache
        // while this ranking was being computed bumps the shard generation
        // (under the same lock), so the stale answer is dropped instead of
        // being re-inserted — at worst one extra future miss.
        self.cache.put_if_generation(key, Arc::clone(&value), generation);
        Ok(value)
    }

    /// Convenience: advise against a machine by registry name.
    pub fn advise_for(&self, machine: &str, pattern: &Pattern) -> Result<Arc<RankedStrategies>, String> {
        let surface =
            self.surface_index(machine).ok_or_else(|| format!("no surface compiled for machine {machine:?}"))?;
        self.advise(&Query { pattern: *pattern, surface })
    }

    /// Batched advise over the shared worker pool
    /// ([`crate::util::pool::map`]); results come back in query order
    /// regardless of thread scheduling.
    pub fn advise_batch(&self, queries: &[Query], threads: usize) -> Vec<Result<Arc<RankedStrategies>, String>> {
        let threads = effective_threads(threads, queries.len());
        pool::map(queries.len(), threads, |i| self.advise(&queries[i]))
    }

    /// Apply a recalibration to one machine's surface: mark the refit size
    /// band stale, recompile those cells against the refit parameters, and
    /// drop every cached answer. Returns the recompiled cell count.
    pub fn recalibrate(&self, machine: &str, params: &MachineParams, lo: usize, hi: usize) -> Result<usize, String> {
        let idx =
            self.surface_index(machine).ok_or_else(|| format!("no surface compiled for machine {machine:?}"))?;
        let mut surface = self.surfaces[idx].write().expect("surface lock poisoned");
        surface.mark_stale_sizes(lo, hi);
        let recompiled = surface.recompile_stale(params)?;
        // clear() also advances the cache generations, which invalidates any
        // advise still computing from the pre-recalibration surface.
        self.cache.clear();
        Ok(recompiled)
    }

    /// One seeded query over the service's surfaces: axis-interior values
    /// (log-uniform) so interpolation paths are exercised too.
    fn random_query(&self, rng: &mut Rng) -> Query {
        let surface_idx = rng.usize_in(0, self.surfaces.len());
        let s = self.surfaces[surface_idx].read().expect("surface lock poisoned");
        let span = |rng: &mut Rng, axis: &[usize]| -> usize {
            let lo = *axis.first().expect("validated axis");
            let hi = *axis.last().expect("validated axis");
            if lo == hi {
                return lo;
            }
            let x = rng.f64_in((lo as f64).log2(), (hi as f64).log2());
            (x.exp2().round() as usize).clamp(lo, hi)
        };
        let pattern = Pattern {
            n_msgs: span(rng, &s.axes.msgs),
            msg_size: span(rng, &s.axes.sizes),
            dest_nodes: s.axes.dest_nodes[rng.usize_in(0, s.axes.dest_nodes.len())],
            gpus_per_node: s.axes.gpus_per_node[rng.usize_in(0, s.axes.gpus_per_node.len())],
        };
        Query { pattern, surface: surface_idx }
    }

    /// Deterministic synthetic burst: `n` seeded queries drawn from a small
    /// pool of distinct patterns (so steady-state traffic repeats, as real
    /// callers do), answered through the cache over `threads` workers.
    pub fn bench_burst(&self, n: usize, seed: u64, threads: usize) -> Result<BurstReport, String> {
        if self.surfaces.is_empty() {
            return Err("no surfaces loaded".into());
        }
        let n = n.max(1);
        let distinct = (n / 16).clamp(1, 1024);
        let mut rng = Rng::new(seed);
        let pool: Vec<Query> = (0..distinct).map(|_| self.random_query(&mut rng)).collect();
        let queries: Vec<Query> = (0..n).map(|_| pool[rng.usize_in(0, pool.len())]).collect();

        let threads = effective_threads(threads, n);
        let stats_before = self.cache.stats();
        let histogram = Mutex::new(BTreeMap::<&'static str, usize>::new());
        let latencies = Mutex::new(Vec::with_capacity(n));
        let histogram_ref = &histogram;
        let latencies_ref = &latencies;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for chunk in queries.chunks(n.div_ceil(threads)) {
                scope.spawn(move || {
                    let mut local_hist = BTreeMap::<&'static str, usize>::new();
                    let mut local_lat = Vec::with_capacity(chunk.len());
                    for q in chunk {
                        let t = Instant::now();
                        let answer = self.advise(q).expect("burst queries target loaded surfaces");
                        local_lat.push(t.elapsed().as_secs_f64());
                        *local_hist.entry(answer.best().0.label()).or_insert(0) += 1;
                    }
                    let mut hist = histogram_ref.lock().expect("burst histogram poisoned");
                    for (k, v) in local_hist {
                        *hist.entry(k).or_insert(0) += v;
                    }
                    latencies_ref.lock().expect("burst latencies poisoned").extend(local_lat);
                });
            }
        });
        let elapsed_s = t0.elapsed().as_secs_f64();

        let mut latencies = latencies.into_inner().expect("burst latencies poisoned");
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        Ok(BurstReport {
            queries: n,
            distinct,
            threads,
            cache: self.cache.stats().since(&stats_before),
            winners: histogram.into_inner().expect("burst histogram poisoned"),
            p50_s: percentile_sorted(&latencies, 50.0),
            p99_s: percentile_sorted(&latencies, 99.0),
            elapsed_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::advisor::surface::SurfaceAxes;

    fn tiny_service() -> AdvisorService {
        let axes = SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        };
        AdvisorService::new(vec![DecisionSurface::compile("lassen", axes, 0.0).unwrap()])
    }

    fn q(n_msgs: usize, msg_size: usize) -> Query {
        Query { pattern: Pattern { n_msgs, msg_size, dest_nodes: 16, gpus_per_node: 4 }, surface: 0 }
    }

    #[test]
    fn advise_caches_repeat_queries() {
        let svc = tiny_service();
        let a = svc.advise(&q(256, 1024)).unwrap();
        let b = svc.advise(&q(256, 1024)).unwrap();
        assert_eq!(*a, *b);
        let stats = svc.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!(svc.advise(&Query { surface: 9, ..q(256, 1024) }).is_err());
    }

    #[test]
    fn advise_for_resolves_machine_names() {
        let svc = tiny_service();
        assert_eq!(svc.machines(), ["lassen".to_string()]);
        let pattern = Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 };
        assert!(svc.advise_for("lassen", &pattern).is_ok());
        assert!(svc.advise_for("frontier-like", &pattern).is_err());
    }

    #[test]
    fn batch_preserves_query_order() {
        let svc = tiny_service();
        let queries: Vec<Query> = (0..64).map(|i| q(64 + (i % 8) * 16, 256 << (i % 4))).collect();
        let serial = svc.advise_batch(&queries, 1);
        let parallel = svc.advise_batch(&queries, 4);
        assert_eq!(serial.len(), queries.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.as_ref().unwrap().ranked, b.as_ref().unwrap().ranked);
        }
    }

    #[test]
    fn burst_deterministic_and_cached() {
        let r1 = tiny_service().bench_burst(4000, 11, 4).unwrap();
        let r2 = tiny_service().bench_burst(4000, 11, 1).unwrap();
        assert_eq!(r1.winners, r2.winners, "burst answers must not depend on thread count");
        assert_eq!(r1.winners.values().sum::<usize>(), 4000);
        // single-threaded: misses are first touches only, bounded by the
        // pool size (concurrent first-touch misses can inflate r1's count)
        assert!(r2.cache.misses as usize <= r2.distinct, "misses {} > pool {}", r2.cache.misses, r2.distinct);
        assert!(r2.cache.hit_rate() > 0.9, "hit rate {}", r2.cache.hit_rate());
        assert!(r1.p99_s >= r1.p50_s);
        assert_eq!(r1.distinct, (4000 / 16).clamp(1, 1024));
    }

    #[test]
    fn recalibrate_invalidates_cache() {
        let svc = tiny_service();
        svc.advise(&q(256, 4096)).unwrap();
        let (_, params) = crate::topology::machines::parse("lassen", 1).unwrap();
        let n = svc.recalibrate("lassen", &params.scaled(2.0, 0.5), 512, 8192).unwrap();
        assert!(n > 0);
        // the next probe misses (cache was cleared) and sees the refit times
        let before = svc.cache_stats();
        svc.advise(&q(256, 4096)).unwrap();
        let after = svc.cache_stats();
        assert_eq!(after.misses, before.misses + 1);
    }
}
