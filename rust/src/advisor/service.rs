//! The serving layer: lock-free snapshot reads over compiled decision
//! surfaces, batched interpolation, and per-tenant recalibration that
//! republishes one machine's snapshot without stalling the others.
//!
//! Each tenant — a `(machine, shape)` pair — owns one
//! [`Published<SurfaceSnapshot>`] cell: the read path loads the current
//! immutable snapshot (an atomic pin/validate, no locks, no inline
//! recompiles) and answers from its memo or an interpolated lattice read.
//! [`AdvisorService::recalibrate`] compiles a *fresh* snapshot off-path
//! under a per-tenant rebuild lock and publishes it atomically; the old
//! snapshot is retired once its last in-flight reader leaves, so a query
//! always sees one coherent epoch end to end.
//!
//! Answers are deterministic: a query resolves against an immutable
//! snapshot and the memo only memoizes, so a seeded burst produces the
//! same winner histogram at any thread count (only measured latencies
//! vary).

use super::cache::CacheStats;
use super::snapshot::SurfaceSnapshot;
use super::surface::{DecisionSurface, Pattern, RankedStrategies};
use crate::params::MachineParams;
use crate::util::pool::{self, effective_threads};
use crate::util::publish::Published;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default memo capacity per snapshot (slots, rounded up to a power of 2).
const DEFAULT_MEMO_CAPACITY: usize = 8192;

/// One advise query: a pattern plus the surface (machine) it targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub pattern: Pattern,
    /// Index into the service's tenant list ([`AdvisorService::surface_index`]).
    pub surface: usize,
}

/// Outcome of a synthetic burst.
#[derive(Clone, Debug)]
pub struct BurstReport {
    pub queries: usize,
    /// Distinct patterns in the seeded pool.
    pub distinct: usize,
    pub threads: usize,
    /// Memo counter deltas over the burst.
    pub cache: CacheStats,
    /// Winner label → count over the whole burst (seed-deterministic).
    pub winners: BTreeMap<&'static str, usize>,
    /// Measured per-query lookup latency percentiles [s].
    pub p50_s: f64,
    pub p99_s: f64,
    pub elapsed_s: f64,
}

/// One served `(machine, shape)` surface and its publication machinery.
struct Tenant {
    name: String,
    slot: Published<SurfaceSnapshot>,
    /// Last published epoch; bumped under `rebuild` before each publish.
    epoch: AtomicU64,
    /// Serializes rebuilds of this tenant only — readers never take it,
    /// and other tenants' rebuilds proceed concurrently.
    rebuild: Mutex<()>,
}

/// The advisor service: a multi-tenant front end over published snapshots.
pub struct AdvisorService {
    tenants: Vec<Tenant>,
    names: Vec<String>,
    hits: AtomicU64,
    misses: AtomicU64,
    memo_capacity: usize,
}

impl AdvisorService {
    /// Serve `surfaces` with the default per-snapshot memo capacity.
    pub fn new(surfaces: Vec<DecisionSurface>) -> AdvisorService {
        AdvisorService::with_memo_capacity(surfaces, DEFAULT_MEMO_CAPACITY)
    }

    pub fn with_memo_capacity(surfaces: Vec<DecisionSurface>, memo_capacity: usize) -> AdvisorService {
        let names: Vec<String> = surfaces.iter().map(|s| s.machine.clone()).collect();
        let tenants = surfaces
            .into_iter()
            .map(|surface| Tenant {
                name: surface.machine.clone(),
                slot: Published::new(SurfaceSnapshot::compile(surface, 0, memo_capacity)),
                epoch: AtomicU64::new(0),
                rebuild: Mutex::new(()),
            })
            .collect();
        AdvisorService { tenants, names, hits: AtomicU64::new(0), misses: AtomicU64::new(0), memo_capacity }
    }

    /// Machines served, in tenant order.
    pub fn machines(&self) -> &[String] {
        &self.names
    }

    /// Index of a machine's tenant.
    pub fn surface_index(&self, machine: &str) -> Option<usize> {
        self.names.iter().position(|n| n == machine)
    }

    /// Service-lifetime memo hit/miss counters (across all tenants).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats { hits: self.hits.load(Ordering::Relaxed), misses: self.misses.load(Ordering::Relaxed) }
    }

    /// The tenant's current snapshot — a lock-free load; the returned
    /// `Arc` stays coherent (one epoch) however long the caller holds it.
    pub fn snapshot(&self, surface: usize) -> Result<Arc<SurfaceSnapshot>, String> {
        self.tenants.get(surface).map(|t| t.slot.load()).ok_or_else(|| format!("no surface with index {surface}"))
    }

    /// Answer one query against the tenant's current snapshot: a memo
    /// probe, falling back to an interpolated lattice read that is then
    /// memoized. Never takes a lock, never recompiles inline.
    pub fn advise(&self, q: &Query) -> Result<Arc<RankedStrategies>, String> {
        let snapshot = self.snapshot(q.surface)?;
        let (answer, hit) = snapshot.advise(&q.pattern);
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        Ok(answer)
    }

    /// Convenience: advise against a machine by registry name.
    pub fn advise_for(&self, machine: &str, pattern: &Pattern) -> Result<Arc<RankedStrategies>, String> {
        let surface =
            self.surface_index(machine).ok_or_else(|| format!("no surface compiled for machine {machine:?}"))?;
        self.advise(&Query { pattern: *pattern, surface })
    }

    /// Batched advise: queries are split into contiguous per-worker chunks
    /// ([`crate::util::pool::map`]); each worker loads one snapshot per
    /// tenant per chunk (so a chunk's answers are never torn across a
    /// mid-batch publish), resolves memo hits, and sends the misses
    /// through the grouped [`DecisionSurface::lookup_batch`] interpolator.
    /// Results come back in query order and bit-identical to per-query
    /// [`AdvisorService::advise`] calls.
    pub fn advise_batch(&self, queries: &[Query], threads: usize) -> Vec<Result<Arc<RankedStrategies>, String>> {
        self.advise_batch_with(queries, threads, cfg!(feature = "simd"))
    }

    /// [`AdvisorService::advise_batch`] with the miss-path interpolator's
    /// lane selection pinned: `lanes` forces
    /// [`DecisionSurface::lookup_batch_lanes`] (four-wide, bit-identical)
    /// instead of following the `simd` feature — the `advise-simd` perf leg
    /// and the lane-identity property test drive it from default builds.
    pub fn advise_batch_with(
        &self,
        queries: &[Query],
        threads: usize,
        lanes: bool,
    ) -> Vec<Result<Arc<RankedStrategies>, String>> {
        let threads = effective_threads(threads, queries.len());
        let chunk_size = queries.len().div_ceil(threads).max(1);
        let chunks: Vec<&[Query]> = queries.chunks(chunk_size).collect();
        pool::map(chunks.len(), threads, |ci| self.advise_chunk(chunks[ci], lanes)).into_iter().flatten().collect()
    }

    fn advise_chunk(&self, chunk: &[Query], lanes: bool) -> Vec<Result<Arc<RankedStrategies>, String>> {
        let mut out: Vec<Option<Result<Arc<RankedStrategies>, String>>> = Vec::with_capacity(chunk.len());
        out.resize_with(chunk.len(), || None);
        let mut by_tenant: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, q) in chunk.iter().enumerate() {
            if q.surface < self.tenants.len() {
                by_tenant.entry(q.surface).or_default().push(i);
            } else {
                out[i] = Some(Err(format!("no surface with index {}", q.surface)));
            }
        }
        for (tenant, idxs) in by_tenant {
            let snapshot = self.tenants[tenant].slot.load();
            let mut miss_at: Vec<usize> = Vec::new();
            let mut miss_patterns: Vec<Pattern> = Vec::new();
            for &i in &idxs {
                match snapshot.probe(&chunk[i].pattern) {
                    Some(hit) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        out[i] = Some(Ok(hit));
                    }
                    None => {
                        miss_at.push(i);
                        miss_patterns.push(chunk[i].pattern);
                    }
                }
            }
            if !miss_patterns.is_empty() {
                self.misses.fetch_add(miss_patterns.len() as u64, Ordering::Relaxed);
                let answers = snapshot.surface.lookup_batch_impl(&miss_patterns, lanes);
                for (&i, answer) in miss_at.iter().zip(answers) {
                    let answer = Arc::new(answer);
                    snapshot.memoize(&chunk[i].pattern, Arc::clone(&answer));
                    out[i] = Some(Ok(answer));
                }
            }
        }
        out.into_iter().map(|r| r.expect("every query answered")).collect()
    }

    /// Apply a recalibration to one tenant: compile a fresh surface with
    /// the refit size band re-derived from `params` (off-path — readers
    /// keep answering from the current snapshot) and publish it as the
    /// next epoch. Other tenants are untouched and never stall. Returns
    /// the recompiled cell count.
    pub fn recalibrate(&self, machine: &str, params: &MachineParams, lo: usize, hi: usize) -> Result<usize, String> {
        let idx =
            self.surface_index(machine).ok_or_else(|| format!("no surface compiled for machine {machine:?}"))?;
        let tenant = &self.tenants[idx];
        let _rebuild = tenant.rebuild.lock().expect("rebuild lock poisoned");
        let base = tenant.slot.load();
        let (next, recompiled) = base.surface.recalibrated(params, lo, hi)?;
        let epoch = tenant.epoch.load(Ordering::Relaxed) + 1;
        tenant.epoch.store(epoch, Ordering::Relaxed);
        tenant.slot.publish(SurfaceSnapshot::compile(next, epoch, self.memo_capacity));
        Ok(recompiled)
    }

    /// One seeded query over the service's tenants: axis-interior values
    /// (log-uniform) so interpolation paths are exercised too.
    fn random_query(&self, rng: &mut Rng) -> Query {
        let surface = rng.usize_in(0, self.tenants.len());
        let snapshot = self.tenants[surface].slot.load();
        let axes = &snapshot.surface.axes;
        let span = |rng: &mut Rng, axis: &[usize]| -> usize {
            let lo = *axis.first().expect("validated axis");
            let hi = *axis.last().expect("validated axis");
            if lo == hi {
                return lo;
            }
            let x = rng.f64_in((lo as f64).log2(), (hi as f64).log2());
            (x.exp2().round() as usize).clamp(lo, hi)
        };
        let pattern = Pattern {
            n_msgs: span(rng, &axes.msgs),
            msg_size: span(rng, &axes.sizes),
            dest_nodes: axes.dest_nodes[rng.usize_in(0, axes.dest_nodes.len())],
            gpus_per_node: axes.gpus_per_node[rng.usize_in(0, axes.gpus_per_node.len())],
        };
        Query { pattern, surface }
    }

    /// The seeded steady-state burst workload: `n` queries drawn from a
    /// small pool of distinct patterns (so traffic repeats, as real
    /// callers do). Returns the queries and the pool size.
    pub fn seeded_pool_queries(&self, n: usize, seed: u64) -> (Vec<Query>, usize) {
        let n = n.max(1);
        let distinct = (n / 16).clamp(1, 1024);
        let mut rng = Rng::new(seed);
        let pool: Vec<Query> = (0..distinct).map(|_| self.random_query(&mut rng)).collect();
        ((0..n).map(|_| pool[rng.usize_in(0, pool.len())]).collect(), distinct)
    }

    /// A seeded distinct-heavy workload: every query drawn fresh, no
    /// repeat pool — the all-miss reference the perf harness uses to
    /// price uncached interpolation.
    pub fn seeded_queries(&self, n: usize, seed: u64) -> Vec<Query> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| self.random_query(&mut rng)).collect()
    }

    /// Deterministic synthetic burst: the [`AdvisorService::seeded_pool_queries`]
    /// workload answered through the snapshot read path over `threads`
    /// workers, with per-query latencies and the winner histogram.
    pub fn bench_burst(&self, n: usize, seed: u64, threads: usize) -> Result<BurstReport, String> {
        if self.tenants.is_empty() {
            return Err("no surfaces loaded".into());
        }
        let (queries, distinct) = self.seeded_pool_queries(n, seed);
        let n = queries.len();

        let threads = effective_threads(threads, n);
        let stats_before = self.cache_stats();
        let histogram = Mutex::new(BTreeMap::<&'static str, usize>::new());
        let latencies = Mutex::new(Vec::with_capacity(n));
        let histogram_ref = &histogram;
        let latencies_ref = &latencies;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for chunk in queries.chunks(n.div_ceil(threads)) {
                scope.spawn(move || {
                    let mut local_hist = BTreeMap::<&'static str, usize>::new();
                    let mut local_lat = Vec::with_capacity(chunk.len());
                    for q in chunk {
                        let t = Instant::now();
                        let answer = self.advise(q).expect("burst queries target loaded surfaces");
                        local_lat.push(t.elapsed().as_secs_f64());
                        *local_hist.entry(answer.best().0.label()).or_insert(0) += 1;
                    }
                    let mut hist = histogram_ref.lock().expect("burst histogram poisoned");
                    for (k, v) in local_hist {
                        *hist.entry(k).or_insert(0) += v;
                    }
                    latencies_ref.lock().expect("burst latencies poisoned").extend(local_lat);
                });
            }
        });
        let elapsed_s = t0.elapsed().as_secs_f64();

        let mut latencies = latencies.into_inner().expect("burst latencies poisoned");
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        Ok(BurstReport {
            queries: n,
            distinct,
            threads,
            cache: self.cache_stats().since(&stats_before),
            winners: histogram.into_inner().expect("burst histogram poisoned"),
            p50_s: percentile_sorted(&latencies, 50.0),
            p99_s: percentile_sorted(&latencies, 99.0),
            elapsed_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::advisor::surface::SurfaceAxes;
    use crate::topology::machines;

    fn tiny_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    }

    fn tiny_service() -> AdvisorService {
        AdvisorService::new(vec![DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap()])
    }

    fn q(n_msgs: usize, msg_size: usize) -> Query {
        Query { pattern: Pattern { n_msgs, msg_size, dest_nodes: 16, gpus_per_node: 4 }, surface: 0 }
    }

    #[test]
    fn advise_memoizes_repeat_queries() {
        let svc = tiny_service();
        // off-lattice: size 1024 sits between lattice sizes 256 and 4096,
        // so the first touch misses even on the pre-warmed memo
        let a = svc.advise(&q(256, 1024)).unwrap();
        let b = svc.advise(&q(256, 1024)).unwrap();
        assert_eq!(*a, *b);
        let stats = svc.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!(svc.advise(&Query { surface: 9, ..q(256, 1024) }).is_err());
    }

    #[test]
    fn prewarmed_lattice_points_hit_on_first_touch() {
        let svc = tiny_service();
        let before = svc.cache_stats();
        svc.advise(&q(256, 4096)).unwrap(); // exact lattice point
        let after = svc.cache_stats();
        assert_eq!((after.hits - before.hits, after.misses - before.misses), (1, 0));
    }

    #[test]
    fn advise_for_resolves_machine_names() {
        let svc = tiny_service();
        assert_eq!(svc.machines(), ["lassen".to_string()]);
        let pattern = Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 };
        assert!(svc.advise_for("lassen", &pattern).is_ok());
        assert!(svc.advise_for("frontier-like", &pattern).is_err());
    }

    #[test]
    fn batch_preserves_query_order_and_matches_single() {
        let svc = tiny_service();
        let queries: Vec<Query> = (0..64).map(|i| q(64 + (i % 8) * 16, 256 << (i % 4))).collect();
        let serial = svc.advise_batch(&queries, 1);
        let parallel = svc.advise_batch(&queries, 4);
        assert_eq!(serial.len(), queries.len());
        for ((query, a), b) in queries.iter().zip(&serial).zip(&parallel) {
            let single = svc.advise(query).unwrap();
            for pair in [a, b] {
                let got = &pair.as_ref().unwrap().ranked;
                assert_eq!(got.len(), single.ranked.len());
                for ((gs, gt), (ss, st)) in got.iter().zip(&single.ranked) {
                    assert_eq!(gs, ss);
                    assert_eq!(gt.to_bits(), st.to_bits(), "batched bits must match single lookups");
                }
            }
        }
        // out-of-range tenant indices error per query, not per batch
        let mixed = vec![q(64, 256), Query { surface: 9, ..q(64, 256) }];
        let answers = svc.advise_batch(&mixed, 2);
        assert!(answers[0].is_ok() && answers[1].is_err());
    }

    #[test]
    fn burst_deterministic_and_memoized() {
        let r1 = tiny_service().bench_burst(4000, 11, 4).unwrap();
        let r2 = tiny_service().bench_burst(4000, 11, 1).unwrap();
        assert_eq!(r1.winners, r2.winners, "burst answers must not depend on thread count");
        assert_eq!(r1.winners.values().sum::<usize>(), 4000);
        // single-threaded: misses are first touches only, bounded by the
        // pool size (concurrent first-touch misses can inflate r1's count)
        assert!(r2.cache.misses as usize <= r2.distinct, "misses {} > pool {}", r2.cache.misses, r2.distinct);
        assert!(r2.cache.hit_rate() > 0.9, "hit rate {}", r2.cache.hit_rate());
        assert!(r1.p99_s >= r1.p50_s);
        assert_eq!(r1.distinct, (4000 / 16).clamp(1, 1024));
    }

    #[test]
    fn recalibrate_publishes_a_fresh_epoch() {
        let svc = tiny_service();
        let off = q(256, 1000); // brackets lattice sizes 256 and 4096
        let before = svc.advise(&off).unwrap();
        assert_eq!(svc.snapshot(0).unwrap().epoch, 0);

        let (_, params) = machines::parse("lassen", 1).unwrap();
        let n = svc.recalibrate("lassen", &params.scaled(2.0, 0.5), 512, 8192).unwrap();
        assert!(n > 0, "size 4096 falls in the refit band");
        assert_eq!(svc.snapshot(0).unwrap().epoch, 1);

        // the published snapshot serves refit answers; the Arc held from
        // before the publish keeps its old bits (snapshots are immutable)
        let after = svc.advise(&off).unwrap();
        assert_ne!(before.ranked, after.ranked, "refit must reach served answers");
        assert_eq!(after.ranked, svc.snapshot(0).unwrap().surface.lookup(&off.pattern).ranked);
        assert!(svc.recalibrate("bogus", &params, 512, 8192).is_err());
    }

    #[test]
    fn recalibrating_one_tenant_leaves_others_untouched() {
        let surfaces = vec![
            DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap(),
            DecisionSurface::compile("frontier-like", tiny_axes(), 0.0).unwrap(),
        ];
        let svc = AdvisorService::new(surfaces);
        let pattern = Pattern { n_msgs: 100, msg_size: 1000, dest_nodes: 4, gpus_per_node: 4 };
        let control = Query { pattern, surface: 1 };
        let before = svc.advise(&control).unwrap();

        let (_, params) = machines::parse("lassen", 1).unwrap();
        svc.recalibrate("lassen", &params.scaled(3.0, 0.25), 16, 1 << 20).unwrap();

        assert_eq!(svc.snapshot(0).unwrap().epoch, 1);
        assert_eq!(svc.snapshot(1).unwrap().epoch, 0, "tenant B keeps its epoch");
        let after = svc.advise(&control).unwrap();
        for ((bs, bt), (as_, at)) in before.ranked.iter().zip(&after.ranked) {
            assert_eq!(bs, as_);
            assert_eq!(bt.to_bits(), at.to_bits(), "tenant B's answers must keep their bits");
        }
    }

    #[test]
    fn seeded_workloads_are_reproducible() {
        let svc = tiny_service();
        let (a, da) = svc.seeded_pool_queries(1000, 42);
        let (b, db) = svc.seeded_pool_queries(1000, 42);
        assert_eq!(a, b);
        assert_eq!(da, db);
        assert_eq!(svc.seeded_queries(100, 9), svc.seeded_queries(100, 9));
        assert_ne!(svc.seeded_queries(100, 9), svc.seeded_queries(100, 10));
    }
}
