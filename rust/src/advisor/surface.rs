//! Compiled decision surfaces: the Table 6 models evaluated once over a
//! regime lattice (messages × size × destination nodes × GPUs per node) so
//! that answering "which strategy is fastest for this pattern?" costs an
//! interpolated lattice read instead of a model evaluation.
//!
//! A [`DecisionSurface`] is compiled per machine preset
//! ([`crate::topology::machines::parse`]): every lattice point stores the
//! modeled seconds of all Table 5 strategies, queries interpolate in
//! log₂-space along the message-count and message-size axes (and snap to
//! the nearest lattice value on the destination-node and GPUs-per-node
//! axes), and [`DecisionSurface::crossovers`] solves the interpolants for
//! the exact sizes where the winning strategy changes — the boundaries the
//! sweep report only brackets. Recalibration ([`crate::advisor::calibrate`])
//! marks cells stale; [`DecisionSurface::recompile_stale`] lazily re-derives
//! only those cells from a refit parameter set.

use crate::comm::Strategy;
use crate::model::StrategyModel;
use crate::params::MachineParams;
use crate::pattern::generators::Scenario;
use crate::pattern::PatternStats;
use crate::topology::{machines, Machine};

/// A strategy query: the communication pattern one node is about to issue
/// (the Figure 4.3 scenario shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pattern {
    /// Inter-node messages sent by the node.
    pub n_msgs: usize,
    /// Bytes per message.
    pub msg_size: usize,
    /// Destination-node count.
    pub dest_nodes: usize,
    /// GPUs per node.
    pub gpus_per_node: usize,
}

impl Pattern {
    /// Derive a lattice query from a concrete pattern's Table 7 statistics:
    /// message size ≈ the heaviest node pair's mean message size, node
    /// message count ≈ node volume / size, destinations ≈ node volume /
    /// heaviest pair volume. This is how `coordinator`'s auto mode maps a
    /// partitioned matrix's halo pattern onto the surface.
    pub fn from_stats(stats: &PatternStats, machine: &Machine) -> Pattern {
        let msg_size = if stats.m_n2n > 0 { (stats.s_n2n / stats.m_n2n).max(1) } else { 1 };
        let dest_nodes = if stats.s_n2n > 0 { (stats.s_node / stats.s_n2n).max(1) } else { 1 };
        Pattern {
            n_msgs: (stats.s_node / msg_size).max(1),
            msg_size,
            dest_nodes,
            gpus_per_node: machine.gpus_per_node(),
        }
    }
}

/// The axes of a decision surface's regime lattice (each sorted ascending).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SurfaceAxes {
    /// Node message-count axis.
    pub msgs: Vec<usize>,
    /// Message-size axis [bytes].
    pub sizes: Vec<usize>,
    /// Destination-node axis.
    pub dest_nodes: Vec<usize>,
    /// GPUs-per-node axis.
    pub gpus_per_node: Vec<usize>,
}

impl SurfaceAxes {
    /// The default serving lattice: the paper's characterization ranges.
    pub fn default_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![32, 64, 128, 256, 512],
            sizes: (4..=20).step_by(2).map(|e| 1usize << e).collect(),
            dest_nodes: vec![4, 8, 16],
            gpus_per_node: vec![4],
        }
    }

    /// Sort and deduplicate every axis (compile normalizes before use).
    pub fn normalize(&mut self) {
        for axis in [&mut self.msgs, &mut self.sizes, &mut self.dest_nodes, &mut self.gpus_per_node] {
            axis.sort_unstable();
            axis.dedup();
        }
    }

    /// Check axis sanity; returns a user-facing message on failure.
    pub fn validate(&self) -> Result<(), String> {
        for (name, axis) in [
            ("msgs", &self.msgs),
            ("sizes", &self.sizes),
            ("dest_nodes", &self.dest_nodes),
            ("gpus_per_node", &self.gpus_per_node),
        ] {
            if axis.is_empty() {
                return Err(format!("surface axis {name:?} is empty"));
            }
            if axis.iter().any(|&v| v == 0) {
                return Err(format!("surface axis {name:?} has a zero value"));
            }
            if axis.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("surface axis {name:?} must be strictly ascending"));
            }
        }
        Ok(())
    }

    /// Number of lattice cells.
    pub fn len(&self) -> usize {
        self.msgs.len() * self.sizes.len() * self.dest_nodes.len() * self.gpus_per_node.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat cell index; size is the fastest axis so crossover walks along a
    /// regime line touch contiguous memory.
    fn index(&self, mi: usize, di: usize, gi: usize, si: usize) -> usize {
        ((mi * self.dest_nodes.len() + di) * self.gpus_per_node.len() + gi) * self.sizes.len() + si
    }
}

/// Ranked strategies for one query, fastest first (ties keep Table 5 order).
#[derive(Clone, Debug, PartialEq)]
pub struct RankedStrategies {
    /// `(strategy, predicted seconds)`, ascending by time.
    pub ranked: Vec<(Strategy, f64)>,
}

impl RankedStrategies {
    /// The winning strategy and its predicted time.
    pub fn best(&self) -> (Strategy, f64) {
        self.ranked[0]
    }

    /// Predicted time of a specific strategy, if it was ranked.
    pub fn time_of(&self, strategy: Strategy) -> Option<f64> {
        self.ranked.iter().find(|(s, _)| *s == strategy).map(|&(_, t)| t)
    }
}

/// A winner change along the size axis of one (msgs, dest, gpn) regime
/// line, with the exact size where the two interpolated curves intersect.
#[derive(Clone, Debug, PartialEq)]
pub struct SurfaceCrossover {
    pub n_msgs: usize,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// Largest lattice size still won by `from`.
    pub size_before: usize,
    /// Smallest lattice size won by `to`.
    pub size_after: usize,
    /// Size [bytes] where the interpolated model curves cross.
    pub size_exact: f64,
    pub from: Strategy,
    pub to: Strategy,
}

/// A compiled per-machine decision surface.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionSurface {
    /// Canonical registry name of the machine ([`machines::parse`]).
    pub machine: String,
    /// NIC rails per node the lattice was evaluated at — the shape key of
    /// the surface (§6): 1 is the legacy single-rail node (persisted as
    /// `hetcomm.surface.v1` for byte compatibility), anything else is a
    /// multi-rail shape (persisted as `hetcomm.surface.v2`).
    pub nics: usize,
    /// Duplicate-data fraction the lattice was evaluated at.
    pub dup_frac: f64,
    pub axes: SurfaceAxes,
    /// Strategies evaluated per cell, in Table 5 order.
    pub strategies: Vec<Strategy>,
    /// Modeled seconds per lattice cell × strategy; cells are in row-major
    /// (msgs, dest, gpn, size) order.
    pub cells: Vec<Vec<f64>>,
    /// Cells invalidated by recalibration, awaiting
    /// [`DecisionSurface::recompile_stale`].
    pub stale: Vec<bool>,
}

/// Modeled times of every strategy at one lattice point — exactly the path
/// `hetcomm sweep` takes for a uniform-scenario cell (including the NIC
/// rail count), so surface lattice values and sweep model values agree bit
/// for bit.
fn cell_times(
    arch: &Machine,
    params: &MachineParams,
    nics: usize,
    strategies: &[Strategy],
    q: &Pattern,
    dup_frac: f64,
) -> Vec<f64> {
    let node = machines::with_shape_nics(arch, q.dest_nodes + 1, q.gpus_per_node, nics);
    let sc = Scenario { n_msgs: q.n_msgs, msg_size: q.msg_size, n_dest: q.dest_nodes, dup_frac };
    let inputs = sc.inputs(&node, node.cores_per_node());
    let sm = StrategyModel::new(&node, params);
    strategies.iter().map(|&s| sm.time(s, &inputs)).collect()
}

/// Index of the minimum time, first-wins on ties (Table 5 order).
fn best_index(times: &[f64]) -> usize {
    let mut best = 0;
    for (k, &t) in times.iter().enumerate() {
        if t < times[best] {
            best = k;
        }
    }
    best
}

/// Log-space linear interpolation that returns the endpoints bit-exactly at
/// the boundary weights (so lattice-point lookups reproduce stored values).
fn lerp_log(a: f64, b: f64, w: f64) -> f64 {
    if w <= 0.0 {
        a
    } else if w >= 1.0 {
        b
    } else {
        (a.ln() * (1.0 - w) + b.ln() * w).exp()
    }
}

/// Bracketing indices for `v` on a sorted axis; clamps outside the range
/// and degenerates to a single index (`lo == hi`) on exact hits.
fn bracket_idx(axis: &[usize], v: usize) -> (usize, usize) {
    if v <= axis[0] {
        return (0, 0);
    }
    if v >= *axis.last().expect("validated axis") {
        let i = axis.len() - 1;
        return (i, i);
    }
    let hi = axis.partition_point(|&a| a < v);
    if axis[hi] == v {
        (hi, hi)
    } else {
        (hi - 1, hi)
    }
}

/// Log₂-space interpolation weight of `v` between axis endpoints whose
/// log₂ values are `x0 < x1`. Both the single-query and batched paths fund
/// their weights through this one expression — that is what makes batched
/// answers bit-identical to single lookups.
fn axis_weight(x0: f64, x1: f64, v: usize) -> f64 {
    ((v as f64).log2() - x0) / (x1 - x0)
}

/// Bracketing indices and log₂-space weight for `v` on a sorted axis.
fn bracket(axis: &[usize], v: usize) -> (usize, usize, f64) {
    let (lo, hi) = bracket_idx(axis, v);
    if lo == hi {
        return (lo, hi, 0.0);
    }
    (lo, hi, axis_weight((axis[lo] as f64).log2(), (axis[hi] as f64).log2(), v))
}

/// [`nearest`] against a precomputed `log₂(axis)` table — the batched path
/// amortizes the per-element logs across a whole query group. Must keep the
/// exact comparison sequence of [`nearest`] so both paths pick identical
/// indices.
fn nearest_in(logs: &[f64], v: usize) -> usize {
    let lv = (v.max(1) as f64).log2();
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &la) in logs.iter().enumerate() {
        let d = (la - lv).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// Index of the axis value nearest `v` in log₂ space (ties toward smaller).
fn nearest(axis: &[usize], v: usize) -> usize {
    let lv = (v.max(1) as f64).log2();
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &a) in axis.iter().enumerate() {
        let d = ((a as f64).log2() - lv).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// The shared bilinear interpolation core: one strategy's time from its
/// four corner values and the (size, msgs) weights. Every lookup path —
/// single, batched, lattice-precomputed — reduces to this chain, so their
/// answers agree bit for bit.
fn interp_corner(t00: f64, t01: f64, t10: f64, t11: f64, ws: f64, wm: f64) -> f64 {
    lerp_log(lerp_log(t00, t01, ws), lerp_log(t10, t11, ws), wm)
}

/// Four-lane [`lerp_log`]. The weight branch is uniform across lanes (the
/// batched path interpolates four *strategies* of one query, which share
/// `ws`/`wm`), so it hoists out of the lane arithmetic; each lane then runs
/// the scalar op chain `(a.ln()*(1-w) + b.ln()*w).exp()` verbatim, which is
/// what keeps lane answers bit-identical to scalar ones. Stable Rust has no
/// portable f64x4, so the lanes are hand-unrolled — the fixed-width arrays
/// are what lets LLVM emit packed SIMD for the bodies.
#[inline]
fn lerp_log4(a: [f64; 4], b: [f64; 4], w: f64) -> [f64; 4] {
    if w <= 0.0 {
        a
    } else if w >= 1.0 {
        b
    } else {
        let iw = 1.0 - w;
        [
            (a[0].ln() * iw + b[0].ln() * w).exp(),
            (a[1].ln() * iw + b[1].ln() * w).exp(),
            (a[2].ln() * iw + b[2].ln() * w).exp(),
            (a[3].ln() * iw + b[3].ln() * w).exp(),
        ]
    }
}

/// Four-lane [`interp_corner`]: the same two-level [`lerp_log4`] chain,
/// bit-identical per lane to the scalar core.
#[inline]
fn interp_corner4(t00: [f64; 4], t01: [f64; 4], t10: [f64; 4], t11: [f64; 4], ws: f64, wm: f64) -> [f64; 4] {
    lerp_log4(lerp_log4(t00, t01, ws), lerp_log4(t10, t11, ws), wm)
}

/// Stable argsort of one cell's strategy times, fastest first — exactly the
/// permutation [`DecisionSurface::lookup`]'s stable sort produces at a
/// lattice point. Shared by the snapshot layer (precomputed lattice
/// answers) and the v3 quantized encoding (per-cell rank nibbles).
pub(crate) fn cell_ranking(times: &[f64]) -> Vec<u8> {
    debug_assert!(times.len() <= u8::MAX as usize + 1);
    let mut idx: Vec<u8> = (0..times.len() as u8).collect();
    idx.sort_by(|&a, &b| times[a as usize].partial_cmp(&times[b as usize]).expect("finite surface times"));
    idx
}

/// Size [bytes] where the log-space interpolants of the outgoing and
/// incoming winner cross between adjacent lattice sizes `s0 < s1`.
fn cross_size(s0: usize, s1: usize, a0: f64, a1: f64, b0: f64, b1: f64) -> f64 {
    let d0 = a0.ln() - b0.ln();
    let d1 = a1.ln() - b1.ln();
    let w = if (d0 - d1).abs() < f64::EPSILON { 0.5 } else { (d0 / (d0 - d1)).clamp(0.0, 1.0) };
    let x0 = (s0 as f64).log2();
    let x1 = (s1 as f64).log2();
    (x0 + w * (x1 - x0)).exp2()
}

impl DecisionSurface {
    /// Compile a surface at the machine preset's own NIC rail count:
    /// evaluate the Table 6 models of the registry machine at every lattice
    /// point. Deterministic — two compiles of the same spec produce
    /// bit-identical surfaces.
    pub fn compile(machine: &str, axes: SurfaceAxes, dup_frac: f64) -> Result<DecisionSurface, String> {
        DecisionSurface::compile_shaped(machine, 0, axes, dup_frac)
    }

    /// [`DecisionSurface::compile`] with an explicit NIC rail count — the
    /// shape key of the surface. `nics = 0` means "the preset's own count";
    /// presets whose shape pins the count ([`machines::shape_pinned`])
    /// reject any other value.
    pub fn compile_shaped(
        machine: &str,
        nics: usize,
        mut axes: SurfaceAxes,
        dup_frac: f64,
    ) -> Result<DecisionSurface, String> {
        let (arch, params) = machines::parse(machine, 1)?;
        // A pinned preset's shape IS its NIC count: any explicit override —
        // even the matching value — is rejected, the same policy as the
        // `--nics` CLI flag on `sweep` and `model`.
        if nics != 0 && machines::shape_pinned(&arch.name) {
            return Err(format!(
                "--nics conflicts with machine {:?}, whose shape pins {} NICs/node",
                arch.name,
                arch.nics_per_node()
            ));
        }
        let nics = if nics == 0 { arch.nics_per_node() } else { nics };
        axes.normalize();
        axes.validate()?;
        if let Some(&g) = axes.gpus_per_node.iter().find(|&&g| g % arch.sockets_per_node != 0) {
            // `with_shape` would silently round up to a socket multiple,
            // mislabeling the lattice cell — reject instead.
            let sockets = arch.sockets_per_node;
            return Err(format!("{g} GPUs/node does not divide over the {sockets} sockets of {}", arch.name));
        }
        if !(0.0..1.0).contains(&dup_frac) {
            return Err(format!("dup_frac {dup_frac} outside [0, 1)"));
        }
        let strategies = Strategy::all();
        let mut cells = Vec::with_capacity(axes.len());
        for &m in &axes.msgs {
            for &d in &axes.dest_nodes {
                for &g in &axes.gpus_per_node {
                    for &s in &axes.sizes {
                        let q = Pattern { n_msgs: m, msg_size: s, dest_nodes: d, gpus_per_node: g };
                        cells.push(cell_times(&arch, &params, nics, &strategies, &q, dup_frac));
                    }
                }
            }
        }
        let stale = vec![false; cells.len()];
        Ok(DecisionSurface { machine: arch.name.clone(), nics, dup_frac, axes, strategies, cells, stale })
    }

    /// Recompile this surface's lattice at a different NIC rail count — the
    /// degraded-shape sibling the fault layer re-advises against after a
    /// rail failure ([`crate::trace::replay`]). Same machine, axes, dup
    /// fraction and strategy set; only the shape key changes. Deliberately
    /// bypasses the pinned-preset guard of [`DecisionSurface::compile_shaped`]:
    /// a rail failure is exactly the case where a pinned shape's count
    /// changes underneath the advisor. The sibling is an in-memory serving
    /// object — persisting one compiled against a pinned preset would fail
    /// [`DecisionSurface::validate`]'s shape check, by design.
    pub fn resized_nics(&self, nics: usize) -> Result<DecisionSurface, String> {
        if nics == 0 {
            return Err("a degraded surface needs at least one surviving rail".into());
        }
        if nics == self.nics {
            return Ok(self.clone());
        }
        let (arch, params) = machines::parse(&self.machine, 1)?;
        let mut cells = Vec::with_capacity(self.axes.len());
        for &m in &self.axes.msgs {
            for &d in &self.axes.dest_nodes {
                for &g in &self.axes.gpus_per_node {
                    for &s in &self.axes.sizes {
                        let q = Pattern { n_msgs: m, msg_size: s, dest_nodes: d, gpus_per_node: g };
                        cells.push(cell_times(&arch, &params, nics, &self.strategies, &q, self.dup_frac));
                    }
                }
            }
        }
        let stale = vec![false; cells.len()];
        Ok(DecisionSurface {
            machine: self.machine.clone(),
            nics,
            dup_frac: self.dup_frac,
            axes: self.axes.clone(),
            strategies: self.strategies.clone(),
            cells,
            stale,
        })
    }

    /// Structural sanity (used after artifact loads); returns a user-facing
    /// message on failure.
    pub fn validate(&self) -> Result<(), String> {
        self.axes.validate()?;
        if self.strategies.is_empty() {
            return Err("surface has no strategies".into());
        }
        if self.cells.len() != self.axes.len() {
            return Err(format!("surface has {} cells, axes imply {}", self.cells.len(), self.axes.len()));
        }
        if self.stale.len() != self.cells.len() {
            return Err("stale flags out of sync with cells".into());
        }
        for (i, cell) in self.cells.iter().enumerate() {
            if cell.len() != self.strategies.len() {
                return Err(format!("cell {i} has {} times, expected {}", cell.len(), self.strategies.len()));
            }
            if cell.iter().any(|t| !t.is_finite() || *t <= 0.0) {
                return Err(format!("cell {i} holds a non-positive or non-finite time"));
            }
        }
        let (arch, _) = machines::parse(&self.machine, 1)?;
        if self.nics == 0 {
            return Err("surface has a zero NIC rail count".into());
        }
        if machines::shape_pinned(&arch.name) && self.nics != arch.nics_per_node() {
            return Err(format!(
                "surface claims {} NICs/node but machine {:?} pins {}",
                self.nics,
                arch.name,
                arch.nics_per_node()
            ));
        }
        Ok(())
    }

    /// Interpolated lookup: log₂-space bilinear over the message-count and
    /// size axes, nearest lattice value on the destination-node and
    /// GPUs-per-node axes; queries outside the lattice clamp to the
    /// boundary. At lattice points the stored model times are returned
    /// bit-for-bit.
    pub fn lookup(&self, q: &Pattern) -> RankedStrategies {
        let di = nearest(&self.axes.dest_nodes, q.dest_nodes);
        let gi = nearest(&self.axes.gpus_per_node, q.gpus_per_node);
        let (m0, m1, wm) = bracket(&self.axes.msgs, q.n_msgs);
        let (s0, s1, ws) = bracket(&self.axes.sizes, q.msg_size);
        let mut ranked = Vec::with_capacity(self.strategies.len());
        for (k, &strategy) in self.strategies.iter().enumerate() {
            let t00 = self.cells[self.axes.index(m0, di, gi, s0)][k];
            let t01 = self.cells[self.axes.index(m0, di, gi, s1)][k];
            let t10 = self.cells[self.axes.index(m1, di, gi, s0)][k];
            let t11 = self.cells[self.axes.index(m1, di, gi, s1)][k];
            ranked.push((strategy, interp_corner(t00, t01, t10, t11, ws, wm)));
        }
        // stable sort: equal times keep Table 5 order
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite surface times"));
        RankedStrategies { ranked }
    }

    /// Batched [`DecisionSurface::lookup`]: queries are sorted into lattice
    /// cell groups so the per-group work — the four corner rows, the axis
    /// endpoint logs, the log₂ tables behind the nearest-axis snaps — is
    /// paid once per group instead of once per query. Answers come back in
    /// query order and are **bit-identical** to calling `lookup` per query
    /// (property-tested): the per-query weight and interpolation chain runs
    /// through exactly the same [`axis_weight`]/[`interp_corner`]
    /// expressions the single path uses.
    ///
    /// The inner strategy loop runs over explicit four-wide lanes
    /// ([`interp_corner4`]) when the `simd` cargo feature is on, and in
    /// scalar order otherwise; both paths are always compiled and produce
    /// identical bits ([`DecisionSurface::lookup_batch_lanes`] pins the
    /// lanes path in default builds for tests and the perf harness).
    pub fn lookup_batch(&self, queries: &[Pattern]) -> Vec<RankedStrategies> {
        self.lookup_batch_impl(queries, cfg!(feature = "simd"))
    }

    /// [`DecisionSurface::lookup_batch`] forced through the four-wide lane
    /// path regardless of the `simd` feature — the bit-identity oracle and
    /// the `advise-simd` perf leg exercise it from default builds.
    pub fn lookup_batch_lanes(&self, queries: &[Pattern]) -> Vec<RankedStrategies> {
        self.lookup_batch_impl(queries, true)
    }

    pub(crate) fn lookup_batch_impl(&self, queries: &[Pattern], lanes: bool) -> Vec<RankedStrategies> {
        let dest_logs: Vec<f64> = self.axes.dest_nodes.iter().map(|&a| (a as f64).log2()).collect();
        let gpn_logs: Vec<f64> = self.axes.gpus_per_node.iter().map(|&a| (a as f64).log2()).collect();
        let coords: Vec<(usize, usize, usize, usize, usize, usize)> = queries
            .iter()
            .map(|q| {
                let (m0, m1) = bracket_idx(&self.axes.msgs, q.n_msgs);
                let (s0, s1) = bracket_idx(&self.axes.sizes, q.msg_size);
                let di = nearest_in(&dest_logs, q.dest_nodes);
                let gi = nearest_in(&gpn_logs, q.gpus_per_node);
                (m0, m1, s0, s1, di, gi)
            })
            .collect();
        let mut order: Vec<usize> = (0..queries.len()).collect();
        order.sort_by_key(|&i| coords[i]);
        let mut out: Vec<Option<RankedStrategies>> = Vec::with_capacity(queries.len());
        out.resize_with(queries.len(), || None);
        let mut at = 0;
        while at < order.len() {
            let (m0, m1, s0, s1, di, gi) = coords[order[at]];
            let mut end = at + 1;
            while end < order.len() && coords[order[end]] == (m0, m1, s0, s1, di, gi) {
                end += 1;
            }
            // group-shared state: corner rows and axis endpoint logs
            let r00 = &self.cells[self.axes.index(m0, di, gi, s0)];
            let r01 = &self.cells[self.axes.index(m0, di, gi, s1)];
            let r10 = &self.cells[self.axes.index(m1, di, gi, s0)];
            let r11 = &self.cells[self.axes.index(m1, di, gi, s1)];
            let (xm0, xm1) = ((self.axes.msgs[m0] as f64).log2(), (self.axes.msgs[m1] as f64).log2());
            let (xs0, xs1) = ((self.axes.sizes[s0] as f64).log2(), (self.axes.sizes[s1] as f64).log2());
            for &qi in &order[at..end] {
                let q = &queries[qi];
                let wm = if m0 == m1 { 0.0 } else { axis_weight(xm0, xm1, q.n_msgs) };
                let ws = if s0 == s1 { 0.0 } else { axis_weight(xs0, xs1, q.msg_size) };
                let n = self.strategies.len();
                let mut ranked = Vec::with_capacity(n);
                let mut k = 0;
                if lanes {
                    while k + 4 <= n {
                        let t = interp_corner4(
                            [r00[k], r00[k + 1], r00[k + 2], r00[k + 3]],
                            [r01[k], r01[k + 1], r01[k + 2], r01[k + 3]],
                            [r10[k], r10[k + 1], r10[k + 2], r10[k + 3]],
                            [r11[k], r11[k + 1], r11[k + 2], r11[k + 3]],
                            ws,
                            wm,
                        );
                        for (l, &time) in t.iter().enumerate() {
                            ranked.push((self.strategies[k + l], time));
                        }
                        k += 4;
                    }
                }
                // scalar path, and the lanes path's < 4 remainder
                while k < n {
                    ranked.push((self.strategies[k], interp_corner(r00[k], r01[k], r10[k], r11[k], ws, wm)));
                    k += 1;
                }
                ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite surface times"));
                out[qi] = Some(RankedStrategies { ranked });
            }
            at = end;
        }
        out.into_iter().map(|r| r.expect("every query answered")).collect()
    }

    /// Exact crossover boundaries: for every regime line, the sizes where
    /// the winning strategy changes, with the interpolated crossing point.
    pub fn crossovers(&self) -> Vec<SurfaceCrossover> {
        let mut out = Vec::new();
        for (mi, &m) in self.axes.msgs.iter().enumerate() {
            for (di, &d) in self.axes.dest_nodes.iter().enumerate() {
                for (gi, &g) in self.axes.gpus_per_node.iter().enumerate() {
                    for si in 1..self.axes.sizes.len() {
                        let prev = &self.cells[self.axes.index(mi, di, gi, si - 1)];
                        let cur = &self.cells[self.axes.index(mi, di, gi, si)];
                        let (pk, ck) = (best_index(prev), best_index(cur));
                        if pk == ck {
                            continue;
                        }
                        let (s0, s1) = (self.axes.sizes[si - 1], self.axes.sizes[si]);
                        out.push(SurfaceCrossover {
                            n_msgs: m,
                            dest_nodes: d,
                            gpus_per_node: g,
                            size_before: s0,
                            size_after: s1,
                            size_exact: cross_size(s0, s1, prev[pk], cur[pk], prev[ck], cur[ck]),
                            from: self.strategies[pk],
                            to: self.strategies[ck],
                        });
                    }
                }
            }
        }
        out
    }

    /// Mark every cell whose lattice size falls in `[lo, hi]` bytes stale
    /// (a refit protocol band covers a size range). Returns newly marked.
    pub fn mark_stale_sizes(&mut self, lo: usize, hi: usize) -> usize {
        let mut marked = 0;
        let sizes = self.axes.sizes.clone();
        for mi in 0..self.axes.msgs.len() {
            for di in 0..self.axes.dest_nodes.len() {
                for gi in 0..self.axes.gpus_per_node.len() {
                    for (si, &s) in sizes.iter().enumerate() {
                        if s >= lo && s <= hi {
                            let idx = self.axes.index(mi, di, gi, si);
                            if !self.stale[idx] {
                                self.stale[idx] = true;
                                marked += 1;
                            }
                        }
                    }
                }
            }
        }
        marked
    }

    /// Number of cells awaiting recompile.
    pub fn stale_count(&self) -> usize {
        self.stale.iter().filter(|&&s| s).count()
    }

    /// Lazily recompile only the stale cells against `params` (a refit
    /// parameter set); fresh cells keep their bits. Returns the recompiled
    /// cell count.
    pub fn recompile_stale(&mut self, params: &MachineParams) -> Result<usize, String> {
        if self.stale_count() == 0 {
            return Ok(0);
        }
        let (arch, _) = machines::parse(&self.machine, 1)?;
        let mut recompiled = 0;
        for (mi, &m) in self.axes.msgs.iter().enumerate() {
            for (di, &d) in self.axes.dest_nodes.iter().enumerate() {
                for (gi, &g) in self.axes.gpus_per_node.iter().enumerate() {
                    for (si, &s) in self.axes.sizes.iter().enumerate() {
                        let idx = self.axes.index(mi, di, gi, si);
                        if !self.stale[idx] {
                            continue;
                        }
                        let q = Pattern { n_msgs: m, msg_size: s, dest_nodes: d, gpus_per_node: g };
                        self.cells[idx] = cell_times(&arch, params, self.nics, &self.strategies, &q, self.dup_frac);
                        self.stale[idx] = false;
                        recompiled += 1;
                    }
                }
            }
        }
        Ok(recompiled)
    }

    /// Out-of-place recalibration for the snapshot serving path: clone the
    /// surface, mark every cell whose lattice size falls in `[lo, hi]`
    /// stale, and recompile those cells against `params`. `self` is never
    /// mutated — in-flight readers of the current snapshot keep their bits
    /// while the fresh surface compiles. Returns the new surface and the
    /// recompiled cell count.
    pub fn recalibrated(
        &self,
        params: &MachineParams,
        lo: usize,
        hi: usize,
    ) -> Result<(DecisionSurface, usize), String> {
        let mut next = self.clone();
        next.mark_stale_sizes(lo, hi);
        let recompiled = next.recompile_stale(params)?;
        Ok((next, recompiled))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};

    fn tiny_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 1024, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    }

    #[test]
    fn compile_shape_and_determinism() {
        let a = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        assert_eq!(a.cells.len(), 2 * 4 * 2);
        assert_eq!(a.strategies.len(), Strategy::all().len());
        a.validate().unwrap();
        let b = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        assert_eq!(a, b, "compile must be deterministic");
    }

    #[test]
    fn aliases_resolve_to_canonical_name() {
        let s = DecisionSurface::compile("frontier", tiny_axes(), 0.0).unwrap();
        assert_eq!(s.machine, "frontier-like");
        assert!(DecisionSurface::compile("bogus", tiny_axes(), 0.0).is_err());
    }

    #[test]
    fn shape_keyed_compiles() {
        // default key: the preset's own rail count
        let legacy = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        assert_eq!(legacy.nics, 1);
        let pinned = DecisionSurface::compile("frontier-4nic", tiny_axes(), 0.0).unwrap();
        assert_eq!(pinned.nics, 4);
        pinned.validate().unwrap();
        // explicit key on an unpinned machine
        let railed = DecisionSurface::compile_shaped("lassen", 4, tiny_axes(), 0.0).unwrap();
        assert_eq!(railed.nics, 4);
        railed.validate().unwrap();
        // rails relieve injection-limited staged cells and never hurt
        let mut moved = false;
        for (a, b) in legacy.cells.iter().zip(&railed.cells) {
            for (x, y) in a.iter().zip(b) {
                assert!(y <= &(x * (1.0 + 1e-12)));
                moved |= y < x;
            }
        }
        assert!(moved, "4 rails must move at least one lattice cell");
        // pinned presets reject contradicting keys
        let err = DecisionSurface::compile_shaped("frontier-4nic", 1, tiny_axes(), 0.0).unwrap_err();
        assert!(err.contains("pins"), "{err}");
        // validation rejects a tampered pinned surface
        let mut bad = pinned.clone();
        bad.nics = 2;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn resized_nics_builds_the_degraded_sibling() {
        // unpinned machine: the sibling equals a direct shaped compile
        let base = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let sibling = base.resized_nics(4).unwrap();
        let direct = DecisionSurface::compile_shaped("lassen", 4, tiny_axes(), 0.0).unwrap();
        assert_eq!(sibling, direct);
        // same count returns an identical surface
        assert_eq!(base.resized_nics(base.nics).unwrap(), base);
        // pinned preset: the degraded sibling compiles (the whole point),
        // serves lookups, but is not a persistable artifact
        let pinned = DecisionSurface::compile("frontier-4nic", tiny_axes(), 0.0).unwrap();
        let degraded = pinned.resized_nics(3).unwrap();
        assert_eq!(degraded.nics, 3);
        assert!(degraded.validate().is_err(), "pinned siblings are in-memory only");
        let q = Pattern { n_msgs: 64, msg_size: 4096, dest_nodes: 4, gpus_per_node: 4 };
        // fewer rails can only slow lattice cells down, never speed them up
        for (a, b) in pinned.cells.iter().zip(&degraded.cells) {
            for (x, y) in a.iter().zip(b) {
                assert!(y >= x, "losing a rail must not speed a cell up");
            }
        }
        let _ = degraded.lookup(&q);
        assert!(base.resized_nics(0).is_err());
    }

    #[test]
    fn default_axes_compile_and_sub_socket_gpn_rejected() {
        let axes = SurfaceAxes::default_axes();
        axes.validate().unwrap();
        let s = DecisionSurface::compile("lassen", axes.clone(), 0.0).unwrap();
        assert_eq!(s.cells.len(), axes.len());
        // odd GPU counts cannot spread over Lassen's two sockets
        let odd = SurfaceAxes { gpus_per_node: vec![1, 4], ..tiny_axes() };
        let err = DecisionSurface::compile("lassen", odd.clone(), 0.0).unwrap_err();
        assert!(err.contains("sockets"), "{err}");
        // ...but a single-socket machine takes any count
        assert!(DecisionSurface::compile("frontier-like", odd, 0.0).is_ok());
    }

    #[test]
    fn axes_normalize_and_validate() {
        let mut axes = SurfaceAxes { msgs: vec![256, 64, 64], ..tiny_axes() };
        axes.normalize();
        assert_eq!(axes.msgs, vec![64, 256]);
        axes.validate().unwrap();
        let bad = SurfaceAxes { sizes: vec![], ..tiny_axes() };
        assert!(bad.validate().is_err());
        let zero = SurfaceAxes { dest_nodes: vec![0, 4], ..tiny_axes() };
        assert!(zero.validate().is_err());
    }

    #[test]
    fn lattice_lookup_is_exact() {
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let q = Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 };
        let ranked = s.lookup(&q);
        let idx = s.axes.index(1, 1, 0, 1); // msgs=256, dest=16, gpn=4, size=1024
        for (strategy, t) in &ranked.ranked {
            let k = s.strategies.iter().position(|x| x == strategy).unwrap();
            assert_eq!(t.to_bits(), s.cells[idx][k].to_bits(), "{}", strategy.label());
        }
        // ranked ascending
        assert!(ranked.ranked.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(ranked.best().1, ranked.ranked[0].1);
    }

    #[test]
    fn off_lattice_lookup_between_brackets() {
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let q = Pattern { n_msgs: 128, msg_size: 2048, dest_nodes: 16, gpus_per_node: 4 };
        let ranked = s.lookup(&q);
        for (strategy, t) in &ranked.ranked {
            assert!(t.is_finite() && *t > 0.0, "{} -> {t}", strategy.label());
            // within the envelope of the four (msgs, size) corners
            let k = s.strategies.iter().position(|x| x == strategy).unwrap();
            let mut lo = f64::INFINITY;
            let mut hi = 0f64;
            for (mi, si) in [(0, 1), (0, 2), (1, 1), (1, 2)] {
                let v = s.cells[s.axes.index(mi, 1, 0, si)][k];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let label = strategy.label();
            assert!(*t >= lo * (1.0 - 1e-12) && *t <= hi * (1.0 + 1e-12), "{label} {t} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn queries_clamp_outside_lattice() {
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let lo = s.lookup(&Pattern { n_msgs: 1, msg_size: 1, dest_nodes: 1, gpus_per_node: 1 });
        let corner = s.lookup(&Pattern { n_msgs: 64, msg_size: 256, dest_nodes: 4, gpus_per_node: 4 });
        assert_eq!(lo, corner, "below-range queries clamp to the low corner");
        let hi = s.lookup(&Pattern { n_msgs: 1 << 20, msg_size: 1 << 30, dest_nodes: 999, gpus_per_node: 64 });
        let top = s.lookup(&Pattern { n_msgs: 256, msg_size: 1 << 18, dest_nodes: 16, gpus_per_node: 4 });
        assert_eq!(hi, top, "above-range queries clamp to the high corner");
    }

    #[test]
    fn crossover_staged_split_to_device_aware() {
        // The Figure 4.3b line: 256 msgs to 16 nodes flips from staged Split
        // to device-aware node-aware communication past the moderate sizes.
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let xs: Vec<_> = s.crossovers().into_iter().filter(|x| x.n_msgs == 256 && x.dest_nodes == 16).collect();
        assert!(!xs.is_empty(), "expected a crossover on the 16-node line");
        let last = xs.last().unwrap();
        assert_eq!(last.to.transport, Transport::DeviceAware);
        assert!(matches!(xs[0].from.kind, StrategyKind::SplitMd | StrategyKind::SplitDd));
        for x in &xs {
            assert!(
                x.size_exact >= x.size_before as f64 && x.size_exact <= x.size_after as f64,
                "exact crossing {} outside [{}, {}]",
                x.size_exact,
                x.size_before,
                x.size_after
            );
        }
    }

    #[test]
    fn stale_marking_and_lazy_recompile() {
        let (_, params) = machines::parse("lassen", 1).unwrap();
        let mut s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let baseline = s.clone();
        let marked = s.mark_stale_sizes(512, 8192); // sizes 1024 and 4096
        assert_eq!(marked, 2 * 2 * 2);
        assert_eq!(s.stale_count(), marked);
        // marking again is idempotent
        assert_eq!(s.mark_stale_sizes(512, 8192), 0);
        // recompiling against the unchanged params restores identical bits
        let recompiled = s.recompile_stale(&params).unwrap();
        assert_eq!(recompiled, marked);
        assert_eq!(s.stale_count(), 0);
        assert_eq!(s, baseline);
        // recompiling against slower params moves only the stale sizes
        let mut s2 = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        s2.mark_stale_sizes(512, 8192);
        s2.recompile_stale(&params.scaled(2.0, 0.5)).unwrap();
        for (idx, (a, b)) in baseline.cells.iter().zip(&s2.cells).enumerate() {
            let si = idx % baseline.axes.sizes.len();
            let size = baseline.axes.sizes[si];
            if (512..=8192).contains(&size) {
                assert_ne!(a, b, "stale cell {idx} (size {size}) must be recompiled");
            } else {
                assert_eq!(a, b, "fresh cell {idx} (size {size}) must keep its bits");
            }
        }
    }

    #[test]
    fn batched_lookup_matches_single_bit_for_bit() {
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        // a mix of lattice points, off-lattice interiors, clamped extremes,
        // and repeats that land in the same cell group
        let queries = vec![
            Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 },
            Pattern { n_msgs: 100, msg_size: 3000, dest_nodes: 10, gpus_per_node: 4 },
            Pattern { n_msgs: 1, msg_size: 1, dest_nodes: 1, gpus_per_node: 1 },
            Pattern { n_msgs: 1 << 20, msg_size: 1 << 30, dest_nodes: 999, gpus_per_node: 64 },
            Pattern { n_msgs: 90, msg_size: 2900, dest_nodes: 10, gpus_per_node: 4 },
            Pattern { n_msgs: 64, msg_size: 256, dest_nodes: 4, gpus_per_node: 4 },
            Pattern { n_msgs: 100, msg_size: 3000, dest_nodes: 10, gpus_per_node: 4 },
        ];
        let batched = s.lookup_batch(&queries);
        assert_eq!(batched.len(), queries.len());
        for (q, b) in queries.iter().zip(&batched) {
            let single = s.lookup(q);
            assert_eq!(single.ranked.len(), b.ranked.len());
            for ((ss, st), (bs, bt)) in single.ranked.iter().zip(&b.ranked) {
                assert_eq!(ss, bs, "strategy order must match for {q:?}");
                assert_eq!(st.to_bits(), bt.to_bits(), "time bits must match for {q:?}");
            }
        }
        // empty batch is fine
        assert!(s.lookup_batch(&[]).is_empty());
    }

    #[test]
    fn lanes_path_matches_scalar_bit_for_bit() {
        // the `simd` feature contract: forcing the four-wide lanes must not
        // move a single bit relative to the scalar inner loop (Table 5 has
        // 8 strategies: two full lane groups, empty remainder; a filtered
        // strategy set exercises the scalar remainder too)
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let queries = vec![
            Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 },
            Pattern { n_msgs: 100, msg_size: 3000, dest_nodes: 10, gpus_per_node: 4 },
            Pattern { n_msgs: 1, msg_size: 1, dest_nodes: 1, gpus_per_node: 1 },
            Pattern { n_msgs: 1 << 20, msg_size: 1 << 30, dest_nodes: 999, gpus_per_node: 64 },
            Pattern { n_msgs: 77, msg_size: 100_000, dest_nodes: 7, gpus_per_node: 4 },
        ];
        let scalar = s.lookup_batch_impl(&queries, false);
        let lanes = s.lookup_batch_lanes(&queries);
        for (a, b) in scalar.iter().zip(&lanes) {
            for ((sa, ta), (sb, tb)) in a.ranked.iter().zip(&b.ranked) {
                assert_eq!(sa, sb);
                assert_eq!(ta.to_bits(), tb.to_bits(), "lane arithmetic drifted from scalar");
            }
        }
        // remainder coverage: a 6-strategy surface leaves 2 scalar stragglers
        let mut small = s.clone();
        small.strategies.truncate(6);
        for cell in &mut small.cells {
            cell.truncate(6);
        }
        let a = small.lookup_batch_impl(&queries, false);
        let b = small.lookup_batch_lanes(&queries);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ranked.len(), 6);
            for ((sx, tx), (sy, ty)) in x.ranked.iter().zip(&y.ranked) {
                assert_eq!((sx, tx.to_bits()), (sy, ty.to_bits()));
            }
        }
    }

    #[test]
    fn cell_ranking_matches_lookup_order_at_lattice_points() {
        let s = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        for (idx, times) in s.cells.iter().enumerate() {
            let ranking = cell_ranking(times);
            assert_eq!(ranking.len(), s.strategies.len());
            // the ranking is the stable argsort the lookup sort produces
            let mut expect: Vec<(Strategy, f64)> =
                s.strategies.iter().zip(times).map(|(&st, &t)| (st, t)).collect();
            expect.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            for (pos, &k) in ranking.iter().enumerate() {
                assert_eq!(s.strategies[k as usize], expect[pos].0, "cell {idx} rank {pos}");
                assert_eq!(times[k as usize].to_bits(), expect[pos].1.to_bits());
            }
        }
        // stability: ties keep index order
        assert_eq!(cell_ranking(&[2.0, 1.0, 1.0, 3.0]), vec![1, 2, 0, 3]);
    }

    #[test]
    fn recalibrated_builds_fresh_surface_without_mutating_base() {
        let (_, params) = machines::parse("lassen", 1).unwrap();
        let base = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let before = base.clone();
        let (next, recompiled) = base.recalibrated(&params.scaled(2.0, 0.5), 512, 8192).unwrap();
        assert_eq!(base, before, "recalibrated must not touch the base surface");
        assert_eq!(recompiled, 2 * 2 * 2, "sizes 1024 and 4096 across 2 msgs x 2 dest lines");
        assert_eq!(next.stale_count(), 0, "the fresh surface ships fully compiled");
        assert_ne!(next, base);
        // identical params round-trip to identical bits
        let (same, n) = base.recalibrated(&params, 512, 8192).unwrap();
        assert_eq!(n, recompiled);
        assert_eq!(same, base);
    }

    #[test]
    fn pattern_from_stats_maps_scenario() {
        let machine = machines::lassen(17);
        let sc = Scenario { n_msgs: 256, msg_size: 2048, n_dest: 16, dup_frac: 0.0 };
        let stats = sc.materialize(&machine).stats(&machine);
        let q = Pattern::from_stats(&stats, &machine);
        assert_eq!(q.msg_size, 2048);
        assert_eq!(q.n_msgs, 256);
        assert_eq!(q.dest_nodes, 16);
        assert_eq!(q.gpus_per_node, 4);
        // degenerate empty pattern stays in-range
        let empty = Pattern::from_stats(&PatternStats::default(), &machine);
        assert!(empty.n_msgs >= 1 && empty.msg_size >= 1 && empty.dest_nodes >= 1);
    }
}
