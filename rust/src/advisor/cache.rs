//! Sharded LRU cache fronting surface lookups: queries hash to one of N
//! independently-locked shards, so concurrent `advise` calls contend only
//! per shard and a repeated query costs a probe instead of an interpolated
//! lattice read. Answers are immutable [`RankedStrategies`] behind `Arc`s —
//! eviction order can vary under concurrency, but cached *answers* never
//! can (the surface is deterministic), so burst results stay reproducible.

use super::surface::RankedStrategies;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: the quantized query plus the owning surface's index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub surface: usize,
    pub n_msgs: usize,
    pub msg_size: usize,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
}

/// Hit/miss counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Fraction of probes served from the cache (0 when never probed).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas since an earlier snapshot.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats { hits: self.hits - earlier.hits, misses: self.misses - earlier.misses }
    }
}

struct Entry {
    value: Arc<RankedStrategies>,
    last_used: u64,
}

struct Shard {
    map: HashMap<CacheKey, Entry>,
    /// Monotonic access clock; unique per access within the shard, so the
    /// LRU victim is always unambiguous.
    tick: u64,
    /// Bumped by [`ShardedLru::clear`] under this shard's lock — the token
    /// that makes compute-then-insert safe against concurrent invalidation
    /// ([`ShardedLru::put_if_generation`]).
    generation: u64,
}

/// The sharded LRU.
pub struct ShardedLru {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ShardedLru {
    /// `capacity` is the total entry budget, split evenly over `shards`.
    pub fn new(shards: usize, capacity: usize) -> ShardedLru {
        let shards = shards.max(1);
        ShardedLru {
            per_shard_cap: capacity.div_ceil(shards).max(1),
            shards: (0..shards).map(|_| Mutex::new(Shard { map: HashMap::new(), tick: 0, generation: 0 })).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Deterministic shard placement (FNV-1a over the key fields) — shard
    /// choice must not depend on the process-random `HashMap` hasher.
    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [key.surface, key.n_msgs, key.msg_size, key.dest_nodes, key.gpus_per_node] {
            h ^= v as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    /// Probe; refreshes recency on a hit.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<RankedStrategies>> {
        let mut shard = self.shards[self.shard_of(key)].lock().expect("cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        match shard.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.value))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh), evicting the shard's least-recently-used entry
    /// when the shard is at capacity.
    pub fn put(&self, key: CacheKey, value: Arc<RankedStrategies>) {
        let mut shard = self.shards[self.shard_of(&key)].lock().expect("cache shard poisoned");
        put_locked(&mut shard, key, value, self.per_shard_cap);
    }

    /// Generation of the shard owning `key`; snapshot it before computing a
    /// value, then insert with [`ShardedLru::put_if_generation`].
    pub fn generation_of(&self, key: &CacheKey) -> u64 {
        self.shards[self.shard_of(key)].lock().expect("cache shard poisoned").generation
    }

    /// Insert only if the owning shard has not been [`ShardedLru::clear`]ed
    /// since `generation` was snapshotted. The check and the insert happen
    /// under the shard lock, so a value computed from a since-invalidated
    /// surface can never be re-inserted after the clear. Returns whether
    /// the value was stored.
    pub fn put_if_generation(&self, key: CacheKey, value: Arc<RankedStrategies>, generation: u64) -> bool {
        let mut shard = self.shards[self.shard_of(&key)].lock().expect("cache shard poisoned");
        if shard.generation != generation {
            return false;
        }
        put_locked(&mut shard, key, value, self.per_shard_cap);
        true
    }

    /// Drop every cached answer and advance each shard's generation
    /// (recalibration invalidates in-flight computations too); counters are
    /// preserved.
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut shard = shard.lock().expect("cache shard poisoned");
            shard.generation += 1;
            shard.map.clear();
        }
    }

    /// Entries currently cached across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("cache shard poisoned").map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { hits: self.hits.load(Ordering::Relaxed), misses: self.misses.load(Ordering::Relaxed) }
    }
}

/// Shared insert path: refresh recency and evict the LRU entry at capacity.
fn put_locked(shard: &mut Shard, key: CacheKey, value: Arc<RankedStrategies>, cap: usize) {
    shard.tick += 1;
    let tick = shard.tick;
    if shard.map.len() >= cap && !shard.map.contains_key(&key) {
        if let Some(victim) = shard.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k) {
            shard.map.remove(&victim);
        }
    }
    shard.map.insert(key, Entry { value, last_used: tick });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;

    fn key(i: usize) -> CacheKey {
        CacheKey { surface: 0, n_msgs: i, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 }
    }

    fn value(t: f64) -> Arc<RankedStrategies> {
        Arc::new(RankedStrategies { ranked: vec![(Strategy::all()[0], t)] })
    }

    #[test]
    fn hit_after_put_miss_before() {
        let cache = ShardedLru::new(4, 64);
        assert!(cache.get(&key(1)).is_none());
        cache.put(key(1), value(1.0));
        let got = cache.get(&key(1)).expect("hit");
        assert_eq!(got.ranked[0].1, 1.0);
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_oldest_within_shard() {
        // single shard, capacity 2: inserting a third key evicts the LRU
        let cache = ShardedLru::new(1, 2);
        cache.put(key(1), value(1.0));
        cache.put(key(2), value(2.0));
        assert!(cache.get(&key(1)).is_some()); // refresh key 1
        cache.put(key(3), value(3.0)); // evicts key 2
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key(2)).is_none());
        assert!(cache.get(&key(1)).is_some());
        assert!(cache.get(&key(3)).is_some());
    }

    #[test]
    fn clear_keeps_counters() {
        let cache = ShardedLru::new(2, 8);
        cache.put(key(1), value(1.0));
        assert!(cache.get(&key(1)).is_some());
        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.get(&key(1)).is_none());
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert_eq!(stats.since(&CacheStats { hits: 1, misses: 0 }), CacheStats { hits: 0, misses: 1 });
    }

    #[test]
    fn generation_gates_stale_inserts() {
        let cache = ShardedLru::new(2, 8);
        let gen = cache.generation_of(&key(1));
        // a clear between snapshot and insert must reject the stale value
        cache.clear();
        assert!(!cache.put_if_generation(key(1), value(1.0), gen));
        assert!(cache.get(&key(1)).is_none());
        // a fresh snapshot inserts normally
        let gen = cache.generation_of(&key(1));
        assert!(cache.put_if_generation(key(1), value(2.0), gen));
        assert_eq!(cache.get(&key(1)).unwrap().ranked[0].1, 2.0);
    }

    #[test]
    fn shard_placement_is_stable() {
        let cache = ShardedLru::new(16, 256);
        for i in 0..100 {
            assert_eq!(cache.shard_of(&key(i)), cache.shard_of(&key(i)));
        }
        // keys spread over more than one shard
        let shards: std::collections::BTreeSet<usize> = (0..100).map(|i| cache.shard_of(&key(i))).collect();
        assert!(shards.len() > 1);
    }

    #[test]
    fn capacity_bounds_total_size() {
        let cache = ShardedLru::new(4, 16);
        for i in 0..200 {
            cache.put(key(i), value(i as f64));
        }
        assert!(cache.len() <= 16 + 3, "len {} exceeds budget (+ rounding slack)", cache.len());
    }
}
