//! Per-snapshot fixed memo table fronting surface lookups.
//!
//! [`FixedMemo`] is an open-addressed table of write-once slots: a probe
//! is a handful of atomic loads, an insert is a single `OnceLock::set`,
//! and there is **no eviction, no clearing, and no locking** — the memo is
//! owned by one immutable [`super::SurfaceSnapshot`] and simply dies with
//! it. Recalibration never invalidates entries; it publishes a fresh
//! snapshot with a fresh (pre-warmed) memo, which is what structurally
//! rules out the torn-answer and stale-insert races the old sharded LRU
//! needed generation counters for.
//!
//! Because slots are write-once and inserts never skip an empty slot, a
//! probe may stop at the first empty slot it sees: if the key had been
//! inserted further along its probe sequence, every earlier position was
//! occupied at insert time — and occupied slots never empty out. A full
//! probe window simply means "don't memoize this one"; the surface lookup
//! is deterministic, so recomputing a crowded-out answer is always safe.

use super::surface::{Pattern, RankedStrategies};
use std::sync::Arc;
use std::sync::OnceLock;

/// Probe window: how many consecutive slots a key may land in before the
/// table declines to memoize it.
const PROBE: usize = 32;

/// Memo key: the quantized query. Snapshot-owned tables need no surface
/// or generation discriminator — one memo serves exactly one compiled
/// surface, forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub n_msgs: usize,
    pub msg_size: usize,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
}

impl CacheKey {
    pub fn from_pattern(q: &Pattern) -> CacheKey {
        CacheKey {
            n_msgs: q.n_msgs,
            msg_size: q.msg_size,
            dest_nodes: q.dest_nodes,
            gpus_per_node: q.gpus_per_node,
        }
    }
}

/// Hit/miss counters (monotonic over the owning service's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Fraction of probes served from the memo (0 when never probed).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas since an earlier snapshot.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats { hits: self.hits - earlier.hits, misses: self.misses - earlier.misses }
    }
}

/// The write-once open-addressed memo table (see the module docs).
pub struct FixedMemo {
    slots: Vec<OnceLock<(CacheKey, Arc<RankedStrategies>)>>,
    mask: usize,
}

impl FixedMemo {
    /// A memo with at least `capacity` slots, rounded up to a power of two
    /// (minimum 64) so probing can mask instead of divide.
    pub fn new(capacity: usize) -> FixedMemo {
        let cap = capacity.max(64).next_power_of_two();
        let mut slots = Vec::with_capacity(cap);
        slots.resize_with(cap, OnceLock::new);
        FixedMemo { slots, mask: cap - 1 }
    }

    /// Total slots in the table.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Deterministic home slot (FNV-1a over the key fields) — placement
    /// must not depend on the process-random `HashMap` hasher.
    fn home(&self, key: &CacheKey) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [key.n_msgs, key.msg_size, key.dest_nodes, key.gpus_per_node] {
            h ^= v as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
        (h & self.mask as u64) as usize
    }

    /// Probe for `key`. Stops at the first empty slot (sound because
    /// occupied slots never empty out) or after [`PROBE`] collisions.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<RankedStrategies>> {
        let home = self.home(key);
        for d in 0..PROBE {
            match self.slots[(home + d) & self.mask].get() {
                None => return None,
                Some((k, v)) if k == key => return Some(Arc::clone(v)),
                Some(_) => {}
            }
        }
        None
    }

    /// Insert `key -> value` at the first free slot in its probe window.
    /// Returns whether the answer is now memoized (either by this call or
    /// by a racing insert of the same key); `false` means the window was
    /// full of other keys and this answer will simply be recomputed.
    pub fn insert(&self, key: CacheKey, value: Arc<RankedStrategies>) -> bool {
        let home = self.home(&key);
        let mut pending = Some((key, value));
        for d in 0..PROBE {
            let slot = &self.slots[(home + d) & self.mask];
            match slot.set(pending.take().expect("pending value present until placed")) {
                Ok(()) => return true,
                Err(returned) => {
                    // lost the slot (to this key or another); re-read it
                    if slot.get().map(|(k, _)| *k == key).unwrap_or(false) {
                        return true;
                    }
                    pending = Some(returned);
                }
            }
        }
        false
    }

    /// Entries currently memoized (O(capacity); diagnostics and tests).
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;

    fn key(i: usize) -> CacheKey {
        CacheKey { n_msgs: i, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 }
    }

    fn value(t: f64) -> Arc<RankedStrategies> {
        Arc::new(RankedStrategies { ranked: vec![(Strategy::all()[0], t)] })
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let memo = FixedMemo::new(64);
        assert!(memo.get(&key(1)).is_none());
        assert!(memo.insert(key(1), value(1.0)));
        assert_eq!(memo.get(&key(1)).expect("hit").ranked[0].1, 1.0);
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(FixedMemo::new(0).capacity(), 64);
        assert_eq!(FixedMemo::new(65).capacity(), 128);
        assert_eq!(FixedMemo::new(8192).capacity(), 8192);
    }

    #[test]
    fn first_insert_wins_and_repeat_inserts_report_memoized() {
        let memo = FixedMemo::new(64);
        assert!(memo.insert(key(1), value(1.0)));
        // write-once: a second insert of the same key keeps the original
        assert!(memo.insert(key(1), value(2.0)));
        assert_eq!(memo.get(&key(1)).unwrap().ranked[0].1, 1.0);
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn colliding_keys_probe_past_each_other() {
        // minimum-size table + enough keys guarantees home collisions
        let memo = FixedMemo::new(64);
        for i in 0..48 {
            memo.insert(key(i), value(i as f64));
        }
        for i in 0..48 {
            if let Some(v) = memo.get(&key(i)) {
                assert_eq!(v.ranked[0].1, i as f64, "memo returned a different key's answer");
            }
        }
        assert!(memo.len() >= 40, "most of 48 inserts into 64 slots should land");
    }

    #[test]
    fn full_probe_window_declines_gracefully() {
        let memo = FixedMemo::new(64);
        let mut declined = 0;
        for i in 0..600 {
            if !memo.insert(key(i), value(i as f64)) {
                declined += 1;
            }
        }
        // 600 inserts into 64 slots: most decline, none panic, and every
        // memoized answer is still keyed correctly
        assert!(declined >= 600 - 64);
        assert!(memo.len() <= 64);
        for i in 0..600 {
            if let Some(v) = memo.get(&key(i)) {
                assert_eq!(v.ranked[0].1, i as f64);
            }
        }
    }

    #[test]
    fn placement_is_stable() {
        let memo = FixedMemo::new(256);
        for i in 0..100 {
            assert_eq!(memo.home(&key(i)), memo.home(&key(i)));
        }
        let homes: std::collections::BTreeSet<usize> = (0..100).map(|i| memo.home(&key(i))).collect();
        assert!(homes.len() > 1, "keys spread over more than one home slot");
    }

    #[test]
    fn concurrent_same_key_inserts_agree() {
        let memo = FixedMemo::new(256);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for i in 0..64 {
                        assert!(memo.insert(key(i), value(i as f64)));
                        assert_eq!(memo.get(&key(i)).unwrap().ranked[0].1, i as f64);
                    }
                });
            }
        });
        assert_eq!(memo.len(), 64);
    }

    #[test]
    fn stats_arithmetic() {
        let stats = CacheStats { hits: 3, misses: 1 };
        assert!((stats.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        assert_eq!(stats.since(&CacheStats { hits: 1, misses: 0 }), CacheStats { hits: 2, misses: 1 });
    }
}
