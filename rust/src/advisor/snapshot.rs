//! Immutable compiled-surface snapshots: everything the read path needs,
//! frozen at publish time.
//!
//! A [`SurfaceSnapshot`] bundles one compiled [`DecisionSurface`] with its
//! epoch, the precomputed fastest-first answer for every lattice cell, and
//! a write-once [`FixedMemo`]. All of it is built off the serving path by
//! whoever compiles the snapshot; after publication through
//! [`crate::util::publish::Published`] the snapshot is never mutated —
//! queries probe the memo and interpolate, and a recalibration builds a
//! *new* snapshot rather than touching this one. Small lattices are
//! pre-warmed into the memo at compile time, so lattice-point queries are
//! hits on first touch and a fresh snapshot starts with its steady-state
//! answers already memoized.

use super::cache::{CacheKey, FixedMemo};
use super::surface::{cell_ranking, DecisionSurface, Pattern, RankedStrategies};
use std::sync::Arc;

/// One published generation of a tenant's serving state (see module docs).
pub struct SurfaceSnapshot {
    /// The compiled surface this snapshot serves.
    pub surface: DecisionSurface,
    /// Publication epoch: bumped once per publish on the owning tenant.
    pub epoch: u64,
    /// Precomputed fastest-first answer per lattice cell, in cell order —
    /// bit-identical to `surface.lookup` at that lattice point.
    lattice: Vec<Arc<RankedStrategies>>,
    memo: FixedMemo,
}

impl SurfaceSnapshot {
    /// Freeze `surface` into a servable snapshot: rank every lattice cell
    /// and pre-warm the memo with the lattice answers when they fit
    /// comfortably (≤ a quarter of the table, leaving probe room for
    /// off-lattice traffic).
    pub fn compile(surface: DecisionSurface, epoch: u64, memo_capacity: usize) -> SurfaceSnapshot {
        let mut lattice = Vec::with_capacity(surface.cells.len());
        for times in &surface.cells {
            let order = cell_ranking(times);
            let ranked = order.iter().map(|&k| (surface.strategies[k as usize], times[k as usize])).collect();
            lattice.push(Arc::new(RankedStrategies { ranked }));
        }
        let memo = FixedMemo::new(memo_capacity);
        if surface.cells.len() <= memo.capacity() / 4 {
            let axes = &surface.axes;
            let mut cell = 0;
            for &m in &axes.msgs {
                for &d in &axes.dest_nodes {
                    for &g in &axes.gpus_per_node {
                        for &s in &axes.sizes {
                            let key = CacheKey { n_msgs: m, msg_size: s, dest_nodes: d, gpus_per_node: g };
                            memo.insert(key, Arc::clone(&lattice[cell]));
                            cell += 1;
                        }
                    }
                }
            }
        }
        SurfaceSnapshot { surface, epoch, lattice, memo }
    }

    /// Answer one query: memo probe, then an interpolated lattice read on a
    /// miss (memoized for the snapshot's remaining lifetime). No locks, no
    /// recompiles — the second element reports whether this was a hit.
    pub fn advise(&self, q: &Pattern) -> (Arc<RankedStrategies>, bool) {
        let key = CacheKey::from_pattern(q);
        if let Some(hit) = self.memo.get(&key) {
            return (hit, true);
        }
        let answer = Arc::new(self.surface.lookup(q));
        self.memo.insert(key, Arc::clone(&answer));
        (answer, false)
    }

    /// Memo probe only (the batched path resolves misses through
    /// [`DecisionSurface::lookup_batch`] instead of per-query lookups).
    pub fn probe(&self, q: &Pattern) -> Option<Arc<RankedStrategies>> {
        self.memo.get(&CacheKey::from_pattern(q))
    }

    /// Memoize an answer the batched path computed for `q`.
    pub fn memoize(&self, q: &Pattern, answer: Arc<RankedStrategies>) -> bool {
        self.memo.insert(CacheKey::from_pattern(q), answer)
    }

    /// The precomputed fastest-first answers, one per lattice cell.
    pub fn lattice_answers(&self) -> &[Arc<RankedStrategies>] {
        &self.lattice
    }

    /// Entries currently memoized (diagnostics).
    pub fn memoized(&self) -> usize {
        self.memo.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::advisor::SurfaceAxes;

    fn tiny_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 1024, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    }

    fn tiny_snapshot() -> SurfaceSnapshot {
        let surface = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        SurfaceSnapshot::compile(surface, 0, 8192)
    }

    #[test]
    fn lattice_answers_match_lookup_bit_for_bit() {
        let snap = tiny_snapshot();
        let axes = &snap.surface.axes;
        let mut cell = 0;
        for &m in &axes.msgs {
            for &d in &axes.dest_nodes {
                for &g in &axes.gpus_per_node {
                    for &s in &axes.sizes {
                        let q = Pattern { n_msgs: m, msg_size: s, dest_nodes: d, gpus_per_node: g };
                        let direct = snap.surface.lookup(&q);
                        let pre = &snap.lattice_answers()[cell];
                        assert_eq!(direct.ranked.len(), pre.ranked.len());
                        for ((ds, dt), (ps, pt)) in direct.ranked.iter().zip(&pre.ranked) {
                            assert_eq!(ds, ps, "cell {cell}: rank order");
                            assert_eq!(dt.to_bits(), pt.to_bits(), "cell {cell}: time bits");
                        }
                        cell += 1;
                    }
                }
            }
        }
        assert_eq!(cell, snap.lattice_answers().len());
    }

    #[test]
    fn small_lattices_prewarm_into_first_touch_hits() {
        let snap = tiny_snapshot();
        assert_eq!(snap.memoized(), snap.surface.cells.len());
        // a lattice point is a hit on first touch…
        let on = Pattern { n_msgs: 256, msg_size: 4096, dest_nodes: 16, gpus_per_node: 4 };
        let (_, hit) = snap.advise(&on);
        assert!(hit, "pre-warmed lattice point must hit on first touch");
        // …an off-lattice query misses once, then hits
        let off = Pattern { n_msgs: 256, msg_size: 3000, dest_nodes: 16, gpus_per_node: 4 };
        let (a1, hit1) = snap.advise(&off);
        let (a2, hit2) = snap.advise(&off);
        assert!(!hit1 && hit2);
        assert_eq!(a1.ranked, a2.ranked);
        assert_eq!(a1.ranked, snap.surface.lookup(&off).ranked);
    }

    #[test]
    fn oversized_lattices_skip_prewarming() {
        // 2 msgs x 5 sizes x 2 dest = 20 cells > 64/4: the memo starts cold
        let axes = SurfaceAxes { sizes: vec![256, 1024, 4096, 1 << 14, 1 << 18], ..tiny_axes() };
        let surface = DecisionSurface::compile("lassen", axes, 0.0).unwrap();
        let snap = SurfaceSnapshot::compile(surface, 3, 64);
        assert_eq!(snap.epoch, 3);
        assert_eq!(snap.memoized(), 0);
        let on = Pattern { n_msgs: 256, msg_size: 4096, dest_nodes: 16, gpus_per_node: 4 };
        let (_, hit) = snap.advise(&on);
        assert!(!hit, "cold memo: even lattice points miss on first touch");
        let (_, hit) = snap.advise(&on);
        assert!(hit);
    }

    #[test]
    fn probe_and_memoize_drive_the_batched_path() {
        let snap = tiny_snapshot();
        let off = Pattern { n_msgs: 100, msg_size: 3000, dest_nodes: 10, gpus_per_node: 4 };
        assert!(snap.probe(&off).is_none());
        let answer = Arc::new(snap.surface.lookup(&off));
        assert!(snap.memoize(&off, Arc::clone(&answer)));
        let got = snap.probe(&off).expect("memoized");
        assert!(Arc::ptr_eq(&got, &answer));
    }
}
