//! Measurement-driven recalibration: ingest observed (size, seconds)
//! samples for off-node transfers — from the discrete-event simulator, the
//! coordinator's wall clock, or a real machine — refit the off-node CPU
//! (α, β) rows via [`crate::params::fit`] (the paper's least-squares
//! pipeline, Section 3), and report which size band of a compiled surface
//! is now stale so only those cells are recompiled.

use super::surface::DecisionSurface;
use crate::comm::{Loc, Phase, Schedule, Xfer};
use crate::params::fit::{fit_protocol_bands, Sample};
use crate::params::MachineParams;
use crate::sim;
use crate::topology::{Machine, ProcId};

/// Column of the off-node locality in `MachineParams::cpu`.
const OFF_NODE: usize = 2;

/// Outcome of a refit: the updated parameter set plus the size band whose
/// surface cells must be recompiled.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// The base parameters with every refit off-node row replaced.
    pub params: MachineParams,
    /// Samples the fit consumed.
    pub samples: usize,
    /// Protocol bands actually refit (a band needs >= 2 samples).
    pub bands_refit: usize,
    /// Inclusive size range `[stale_lo, stale_hi]` covered by the refit
    /// bands — the cells a surface should mark stale.
    pub stale_lo: usize,
    pub stale_hi: usize,
}

impl CalibrationReport {
    /// Apply this refit to a compiled surface, out of place: returns a
    /// fresh surface with the stale size band recompiled against the refit
    /// parameters, plus the recompiled cell count. The serving layer
    /// compiles the result into the tenant's next published snapshot
    /// ([`crate::advisor::AdvisorService::recalibrate`]); `surface` itself
    /// keeps its bits for in-flight readers.
    pub fn rebuild(&self, surface: &DecisionSurface) -> Result<(DecisionSurface, usize), String> {
        surface.recalibrated(&self.params, self.stale_lo, self.stale_hi)
    }
}

/// Accumulates measured off-node samples and refits the postal model.
#[derive(Clone, Debug)]
pub struct Calibrator {
    base: MachineParams,
    samples: Vec<Sample>,
}

impl Calibrator {
    pub fn new(base: MachineParams) -> Calibrator {
        Calibrator { base, samples: Vec::new() }
    }

    /// Record one measured off-node transfer; silently drops non-finite or
    /// non-positive observations (a stalled timer, not a measurement).
    pub fn ingest(&mut self, bytes: usize, seconds: f64) {
        if bytes > 0 && seconds.is_finite() && seconds > 0.0 {
            self.samples.push(Sample { bytes, seconds });
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Run single-message off-node probes through the discrete-event
    /// simulator (standing in for the testbed, as in `params::fit`) and
    /// ingest the observed times: the ping-pong analog of Section 3, driven
    /// by whatever `truth` parameters the "hardware" really has.
    pub fn ingest_sim_probes(&mut self, machine: &Machine, truth: &MachineParams, sizes: &[usize]) {
        assert!(machine.num_nodes >= 2, "off-node probes need >= 2 nodes");
        let ppn = machine.gpus_per_node().max(1);
        for &bytes in sizes {
            let mut phase = Phase::new("probe");
            phase.xfers.push(Xfer { src: Loc::Host(ProcId(0)), dst: Loc::Host(ProcId(ppn)), bytes, tag: 0 });
            let schedule = Schedule { strategy_label: "calibration probe".into(), phases: vec![phase] };
            let observed = sim::run(machine, truth, &schedule, ppn).total;
            self.ingest(bytes, observed);
        }
    }

    /// Refit: partition the samples at the base parameters' protocol switch
    /// points, least-squares fit every band holding >= 2 samples, and
    /// replace those off-node rows. Bands without enough samples keep the
    /// base values. Errors when no band can be fit.
    pub fn refit(&self) -> Result<CalibrationReport, String> {
        if self.samples.len() < 2 {
            return Err(format!("need >= 2 samples to refit, have {}", self.samples.len()));
        }
        // `fit_protocol_bands` partitions with an exclusive eager bound, but
        // `cpu_protocol` sends sizes up to AND INCLUDING eager_max eagerly —
        // shift the split point so a probe at exactly eager_max lands in the
        // eager fit, not the rendezvous one.
        let fits = fit_protocol_bands(&self.samples, self.base.short_max, self.base.eager_max + 1);
        // Band size coverage: short < short_max <= eager <= eager_max < rend.
        let bounds = [
            (1usize, self.base.short_max.saturating_sub(1).max(1)),
            (self.base.short_max, self.base.eager_max),
            (self.base.eager_max + 1, usize::MAX / 2),
        ];
        let mut params = self.base.clone();
        let mut bands_refit = 0;
        let mut stale_lo = usize::MAX;
        let mut stale_hi = 0;
        for (pi, fit) in fits.iter().enumerate() {
            if let Some(f) = fit {
                params.cpu[pi][OFF_NODE] = f.ab;
                bands_refit += 1;
                stale_lo = stale_lo.min(bounds[pi].0);
                stale_hi = stale_hi.max(bounds[pi].1);
            }
        }
        if bands_refit == 0 {
            return Err("no protocol band holds >= 2 samples".into());
        }
        Ok(CalibrationReport { params, samples: self.samples.len(), bands_refit, stale_lo, stale_hi })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{lassen_params, Protocol};
    use crate::topology::machines::lassen;
    use crate::topology::Locality;

    #[test]
    fn synthetic_slowdown_refits_eager_band_only() {
        let base = lassen_params();
        let truth_ab = base.cpu_ab(Protocol::Eager, Locality::OffNode);
        let mut cal = Calibrator::new(base.clone());
        // "measured": the eager off-node path is exactly 2x slower
        for exp in 9..13 {
            let bytes = 1usize << exp; // 512 .. 4096: all eager
            cal.ingest(bytes, 2.0 * truth_ab.time(bytes));
        }
        assert_eq!(cal.len(), 4);
        let report = cal.refit().unwrap();
        assert_eq!(report.bands_refit, 1);
        assert_eq!((report.stale_lo, report.stale_hi), (base.short_max, base.eager_max));
        let refit_ab = report.params.cpu_ab(Protocol::Eager, Locality::OffNode);
        assert!((refit_ab.beta - 2.0 * truth_ab.beta).abs() / truth_ab.beta < 1e-6, "beta {}", refit_ab.beta);
        // untouched rows keep the base values
        assert_eq!(
            report.params.cpu_ab(Protocol::Rendezvous, Locality::OffNode),
            base.cpu_ab(Protocol::Rendezvous, Locality::OffNode)
        );
        assert_eq!(
            report.params.cpu_ab(Protocol::Eager, Locality::OnNode),
            base.cpu_ab(Protocol::Eager, Locality::OnNode)
        );
    }

    #[test]
    fn sim_probes_feed_a_full_refit() {
        let base = lassen_params();
        let machine = lassen(2);
        let mut cal = Calibrator::new(base);
        let sizes: Vec<usize> = (4..=20).map(|e| 1usize << e).collect();
        cal.ingest_sim_probes(&machine, &lassen_params(), &sizes);
        assert_eq!(cal.len(), sizes.len());
        let report = cal.refit().unwrap();
        assert_eq!(report.bands_refit, 3, "probe sizes span all three protocol bands");
        assert_eq!(report.stale_lo, 1);
        for proto in [Protocol::Short, Protocol::Eager, Protocol::Rendezvous] {
            let ab = report.params.cpu_ab(proto, Locality::OffNode);
            assert!(ab.alpha >= 0.0 && ab.beta >= 0.0 && ab.alpha.is_finite() && ab.beta.is_finite());
        }
    }

    #[test]
    fn rebuild_applies_refit_to_a_surface_out_of_place() {
        use crate::advisor::SurfaceAxes;
        let base = lassen_params();
        let truth_ab = base.cpu_ab(Protocol::Eager, Locality::OffNode);
        let mut cal = Calibrator::new(base);
        for exp in 9..13 {
            let bytes = 1usize << exp;
            cal.ingest(bytes, 2.0 * truth_ab.time(bytes));
        }
        let report = cal.refit().unwrap();
        let axes = SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 1024, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        };
        let surface = DecisionSurface::compile("lassen", axes, 0.0).unwrap();
        let before = surface.clone();
        let (next, recompiled) = report.rebuild(&surface).unwrap();
        assert!(recompiled > 0, "the eager band covers lattice sizes 1024 and 4096");
        assert_eq!(surface, before, "rebuild must not touch the base surface");
        assert_ne!(next, surface, "refit parameters must move the stale band");
        assert_eq!(next.stale_count(), 0, "the rebuilt surface ships fully compiled");
    }

    #[test]
    fn bad_samples_dropped_and_underflow_errors() {
        let mut cal = Calibrator::new(lassen_params());
        cal.ingest(0, 1.0);
        cal.ingest(1024, f64::NAN);
        cal.ingest(1024, -1.0);
        assert!(cal.is_empty());
        assert!(cal.refit().is_err());
        cal.ingest(1024, 1e-5);
        assert!(cal.refit().is_err(), "one sample cannot fit a line");
    }
}
