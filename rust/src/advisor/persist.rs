//! Versioned JSON artifacts for compiled decision surfaces.
//!
//! The writer is hand-rolled (no `serde` in the offline image) and emits
//! floats through Rust's shortest-round-trip `Display`, so a parsed
//! artifact reproduces the compiled surface bit for bit and two compiles of
//! the same spec serialize byte-identically. The reader is a minimal
//! recursive-descent JSON parser — enough for any well-formed JSON value —
//! followed by schema-checked extraction (unknown schema versions are
//! rejected, not guessed at).

use super::surface::{DecisionSurface, SurfaceAxes};
use crate::comm::Strategy;
use crate::sweep::emit::esc;
use std::fmt::Write as _;

/// Artifact schema identifier; bump on layout changes.
pub const SCHEMA: &str = "hetcomm.surface.v1";

fn usize_list(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Shortest-round-trip float formatting. Deliberately NOT the fixed-width
/// `{:.9e}` of `sweep::emit::num`: 10 significant digits cannot round-trip
/// an f64, and artifacts must parse back bit for bit.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Serialize a surface as a versioned JSON artifact. Stale flags are not
/// persisted: an artifact is always written fresh (recompile before save).
pub fn to_json(surface: &DecisionSurface) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    let _ = writeln!(out, "  \"dup_frac\": {},", num(surface.dup_frac));
    out.push_str("  \"axes\": {\n");
    let _ = writeln!(out, "    \"msgs\": {},", usize_list(&surface.axes.msgs));
    let _ = writeln!(out, "    \"sizes\": {},", usize_list(&surface.axes.sizes));
    let _ = writeln!(out, "    \"dest_nodes\": {},", usize_list(&surface.axes.dest_nodes));
    let _ = writeln!(out, "    \"gpus_per_node\": {}", usize_list(&surface.axes.gpus_per_node));
    out.push_str("  },\n");
    let strategies: Vec<String> = surface.strategies.iter().map(|s| format!("\"{}\"", esc(&s.label()))).collect();
    let _ = writeln!(out, "  \"strategies\": [{}],", strategies.join(", "));
    out.push_str("  \"cells\": [\n");
    for (i, cell) in surface.cells.iter().enumerate() {
        let times: Vec<String> = cell.iter().map(|&t| num(t)).collect();
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    [{}]{comma}", times.join(", "));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write an artifact to disk.
pub fn save(surface: &DecisionSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(surface)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate an artifact from disk.
pub fn load(path: &str) -> Result<DecisionSurface, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate an artifact.
pub fn parse_json(text: &str) -> Result<DecisionSurface, String> {
    let value = Parser::new(text).parse()?;
    let schema = value.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("unsupported surface schema {schema:?} (expected {SCHEMA:?})"));
    }
    let axes = value.field("axes")?;
    let axes = SurfaceAxes {
        msgs: axes.field("msgs")?.as_usize_list()?,
        sizes: axes.field("sizes")?.as_usize_list()?,
        dest_nodes: axes.field("dest_nodes")?.as_usize_list()?,
        gpus_per_node: axes.field("gpus_per_node")?.as_usize_list()?,
    };
    let strategies = value
        .field("strategies")?
        .as_arr()?
        .iter()
        .map(|s| {
            let label = s.as_str()?;
            Strategy::parse_label(label).ok_or_else(|| format!("unknown strategy label {label:?}"))
        })
        .collect::<Result<Vec<Strategy>, String>>()?;
    let cells = value
        .field("cells")?
        .as_arr()?
        .iter()
        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<f64>, String>>())
        .collect::<Result<Vec<Vec<f64>>, String>>()?;
    let stale = vec![false; cells.len()];
    let surface = DecisionSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        dup_frac: value.field("dup_frac")?.as_f64()?,
        axes,
        strategies,
        cells,
        stale,
    };
    surface.validate()?;
    Ok(surface)
}

// --- minimal JSON ---------------------------------------------------------

/// A parsed JSON value (object keys keep file order).
#[derive(Clone, Debug, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn field(&self, key: &str) -> Result<&Json, String> {
        match self {
            Json::Obj(pairs) => pairs
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("missing field {key:?}")),
            _ => Err(format!("expected an object holding {key:?}")),
        }
    }

    fn as_str(&self) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(format!("expected a string, found {other:?}")),
        }
    }

    fn as_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(format!("expected a number, found {other:?}")),
        }
    }

    fn as_arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(format!("expected an array, found {other:?}")),
        }
    }

    fn as_usize(&self) -> Result<usize, String> {
        let x = self.as_f64()?;
        if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
            Ok(x as usize)
        } else {
            Err(format!("expected a non-negative integer, found {x}"))
        }
    }

    fn as_usize_list(&self) -> Result<Vec<usize>, String> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Parser<'a> {
        Parser { bytes: text.as_bytes(), pos: 0 }
    }

    /// Parse one top-level value and require only whitespace after it.
    fn parse(mut self) -> Result<Json, String> {
        let value = self.value()?;
        self.skip_ws();
        if self.pos != self.bytes.len() {
            return Err(format!("trailing content at byte {}", self.pos));
        }
        Ok(value)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number bytes");
        text.parse::<f64>().map(Json::Num).map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut raw: Vec<u8> = Vec::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.pos += 1;
            match c {
                b'"' => break,
                b'\\' => {
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => raw.push(b'"'),
                        b'\\' => raw.push(b'\\'),
                        b'/' => raw.push(b'/'),
                        b'n' => raw.push(b'\n'),
                        b'r' => raw.push(b'\r'),
                        b't' => raw.push(b'\t'),
                        b'b' => raw.push(0x08),
                        b'f' => raw.push(0x0c),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "invalid \\u escape".to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| format!("invalid \\u escape {hex:?}"))?;
                            self.pos += 4;
                            let ch = char::from_u32(code).ok_or_else(|| format!("invalid codepoint {code:#x}"))?;
                            let mut buf = [0u8; 4];
                            raw.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        other => return Err(format!("unknown escape \\{}", other as char)),
                    }
                }
                other => raw.push(other),
            }
        }
        String::from_utf8(raw).map_err(|_| "invalid UTF-8 in string".to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.skip_ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
        Ok(Json::Arr(items))
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.skip_ws();
        let mut pairs = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
        Ok(Json::Obj(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_surface() -> DecisionSurface {
        let axes = SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        };
        DecisionSurface::compile("lassen", axes, 0.25).unwrap()
    }

    #[test]
    fn artifact_roundtrips_bit_for_bit() {
        let surface = tiny_surface();
        let json = to_json(&surface);
        assert!(json.contains(SCHEMA));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(surface, parsed);
        // serialization is stable: emit(parse(emit(s))) == emit(s)
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn save_load_roundtrip() {
        let surface = tiny_surface();
        let path = std::env::temp_dir().join("hetcomm-surface-test.json");
        let path = path.to_str().unwrap();
        save(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn wrong_schema_rejected() {
        let json = to_json(&tiny_surface()).replace(SCHEMA, "hetcomm.surface.v999");
        let err = parse_json(&json).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("{}").unwrap_err().contains("missing field"));
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        // structurally valid JSON, structurally invalid surface
        let truncated = to_json(&tiny_surface()).replace("\"msgs\": [64, 256]", "\"msgs\": [64, 256, 512]");
        assert!(parse_json(&truncated).is_err());
    }

    #[test]
    fn json_parser_handles_general_values() {
        let v = Parser::new(" { \"a\": [1, -2.5e3, true, false, null], \"b\\n\": \"x\\u0041\" } ").parse().unwrap();
        let a = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert_eq!(a[1].as_f64().unwrap(), -2500.0);
        assert_eq!(a[2], Json::Bool(true));
        assert_eq!(a[4], Json::Null);
        assert_eq!(v.field("b\n").unwrap().as_str().unwrap(), "xA");
        assert!(v.field("a").unwrap().as_usize_list().is_err(), "floats are not usizes");
    }

    #[test]
    fn float_display_roundtrips() {
        for x in [1.0, 2.44e-6, 3.79e-10, 0.25, 123456.789, 4.19e-11] {
            let shown = num(x);
            assert_eq!(shown.parse::<f64>().unwrap().to_bits(), x.to_bits(), "{shown}");
        }
    }
}
