//! Versioned JSON artifacts for compiled decision surfaces.
//!
//! The writer is hand-rolled (no `serde` in the offline image) and emits
//! floats through Rust's shortest-round-trip `Display`
//! ([`crate::util::json::fmt_f64`]), so a parsed artifact reproduces the
//! compiled surface bit for bit and two compiles of the same spec serialize
//! byte-identically. The reader is the shared minimal JSON parser
//! ([`crate::util::json`]) followed by schema-checked extraction (unknown
//! schema versions are rejected, not guessed at).
//!
//! Two schema versions coexist (docs/FORMATS.md):
//!
//! - `hetcomm.surface.v1` — the shape-less layout. *Written* for
//!   single-rail surfaces (`nics == 1`), keeping their bytes identical to
//!   the pre-shape-layer writer; *read* as `nics = 1`.
//! - `hetcomm.surface.v2` — v1 plus the `nics` shape key. Written for
//!   multi-rail surfaces; read verbatim.

use super::surface::{DecisionSurface, SurfaceAxes};
use crate::comm::Strategy;
use crate::sweep::emit::esc;
use crate::util::json::{fmt_f64 as num, fmt_usize_list as usize_list, Json};
use std::fmt::Write as _;

/// Artifact schema identifier of shape-less (single-rail) surfaces.
pub const SCHEMA: &str = "hetcomm.surface.v1";

/// Artifact schema identifier of shape-keyed (multi-rail) surfaces.
pub const SCHEMA_V2: &str = "hetcomm.surface.v2";

/// Serialize a surface as a versioned JSON artifact. Stale flags are not
/// persisted: an artifact is always written fresh (recompile before save).
/// Single-rail surfaces emit [`SCHEMA`] bytes (identical to the
/// pre-shape-layer writer); multi-rail surfaces emit [`SCHEMA_V2`] with
/// the `nics` shape key.
pub fn to_json(surface: &DecisionSurface) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    if surface.nics == 1 {
        let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
        let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    } else {
        let _ = writeln!(out, "  \"schema\": \"{SCHEMA_V2}\",");
        let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
        let _ = writeln!(out, "  \"nics\": {},", surface.nics);
    }
    let _ = writeln!(out, "  \"dup_frac\": {},", num(surface.dup_frac));
    out.push_str("  \"axes\": {\n");
    let _ = writeln!(out, "    \"msgs\": {},", usize_list(&surface.axes.msgs));
    let _ = writeln!(out, "    \"sizes\": {},", usize_list(&surface.axes.sizes));
    let _ = writeln!(out, "    \"dest_nodes\": {},", usize_list(&surface.axes.dest_nodes));
    let _ = writeln!(out, "    \"gpus_per_node\": {}", usize_list(&surface.axes.gpus_per_node));
    out.push_str("  },\n");
    let strategies: Vec<String> = surface.strategies.iter().map(|s| format!("\"{}\"", esc(&s.label()))).collect();
    let _ = writeln!(out, "  \"strategies\": [{}],", strategies.join(", "));
    out.push_str("  \"cells\": [\n");
    for (i, cell) in surface.cells.iter().enumerate() {
        let times: Vec<String> = cell.iter().map(|&t| num(t)).collect();
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    [{}]{comma}", times.join(", "));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write an artifact to disk.
pub fn save(surface: &DecisionSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(surface)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate an artifact from disk.
pub fn load(path: &str) -> Result<DecisionSurface, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate an artifact (either schema version; see the module
/// docs for the v1 read-compat rule).
pub fn parse_json(text: &str) -> Result<DecisionSurface, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    let nics = match schema {
        s if s == SCHEMA => 1, // v1 read-compat: shape-less means single-rail
        s if s == SCHEMA_V2 => value.field("nics")?.as_usize()?,
        other => {
            return Err(format!("unsupported surface schema {other:?} (expected {SCHEMA:?} or {SCHEMA_V2:?})"))
        }
    };
    let axes = value.field("axes")?;
    let axes = SurfaceAxes {
        msgs: axes.field("msgs")?.as_usize_list()?,
        sizes: axes.field("sizes")?.as_usize_list()?,
        dest_nodes: axes.field("dest_nodes")?.as_usize_list()?,
        gpus_per_node: axes.field("gpus_per_node")?.as_usize_list()?,
    };
    let strategies = value
        .field("strategies")?
        .as_arr()?
        .iter()
        .map(|s| {
            let label = s.as_str()?;
            Strategy::parse_label(label).ok_or_else(|| format!("unknown strategy label {label:?}"))
        })
        .collect::<Result<Vec<Strategy>, String>>()?;
    let cells = value
        .field("cells")?
        .as_arr()?
        .iter()
        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<f64>, String>>())
        .collect::<Result<Vec<Vec<f64>>, String>>()?;
    let stale = vec![false; cells.len()];
    let surface = DecisionSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        nics,
        dup_frac: value.field("dup_frac")?.as_f64()?,
        axes,
        strategies,
        cells,
        stale,
    };
    surface.validate()?;
    Ok(surface)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    }

    fn tiny_surface() -> DecisionSurface {
        DecisionSurface::compile("lassen", tiny_axes(), 0.25).unwrap()
    }

    #[test]
    fn artifact_roundtrips_bit_for_bit() {
        let surface = tiny_surface();
        let json = to_json(&surface);
        assert!(json.contains(SCHEMA));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(surface, parsed);
        // serialization is stable: emit(parse(emit(s))) == emit(s)
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn single_rail_surfaces_stay_on_v1_bytes() {
        // the v1 writer never learns about shapes: no `nics` key at all
        let json = to_json(&tiny_surface());
        assert!(json.contains("\"schema\": \"hetcomm.surface.v1\""));
        assert!(!json.contains("nics"), "v1 artifacts must not carry the shape key");
    }

    #[test]
    fn multi_rail_surfaces_roundtrip_as_v2() {
        for (machine, nics) in [("frontier-4nic", 0usize), ("lassen", 4)] {
            let surface = DecisionSurface::compile_shaped(machine, nics, tiny_axes(), 0.0).unwrap();
            let json = to_json(&surface);
            assert!(json.contains("\"schema\": \"hetcomm.surface.v2\""), "{machine}");
            assert!(json.contains(&format!("\"nics\": {}", surface.nics)));
            let parsed = parse_json(&json).unwrap();
            assert_eq!(surface, parsed);
            assert_eq!(json, to_json(&parsed));
        }
    }

    #[test]
    fn v1_artifacts_read_as_single_rail() {
        // a pre-shape-layer artifact (no nics key) loads with nics = 1
        let json = to_json(&tiny_surface());
        let parsed = parse_json(&json).unwrap();
        assert_eq!(parsed.nics, 1);
    }

    #[test]
    fn save_load_roundtrip() {
        let surface = tiny_surface();
        let path = std::env::temp_dir().join("hetcomm-surface-test.json");
        let path = path.to_str().unwrap();
        save(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn wrong_schema_rejected() {
        let json = to_json(&tiny_surface()).replace(SCHEMA, "hetcomm.surface.v999");
        let err = parse_json(&json).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
        // a v2 artifact missing its shape key is rejected too
        let surface = DecisionSurface::compile_shaped("lassen", 2, tiny_axes(), 0.0).unwrap();
        let json = to_json(&surface).replace("  \"nics\": 2,\n", "");
        assert!(parse_json(&json).is_err());
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("{}").unwrap_err().contains("missing field"));
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        // structurally valid JSON, structurally invalid surface
        let truncated = to_json(&tiny_surface()).replace("\"msgs\": [64, 256]", "\"msgs\": [64, 256, 512]");
        assert!(parse_json(&truncated).is_err());
    }
}
