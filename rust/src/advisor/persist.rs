//! Versioned JSON artifacts for compiled decision surfaces.
//!
//! The writer is hand-rolled (no `serde` in the offline image) and emits
//! floats through Rust's shortest-round-trip `Display`
//! ([`crate::util::json::fmt_f64`]), so a parsed artifact reproduces the
//! compiled surface bit for bit and two compiles of the same spec serialize
//! byte-identically. The reader is the shared minimal JSON parser
//! ([`crate::util::json`]) followed by schema-checked extraction (unknown
//! schema versions are rejected, not guessed at).

use super::surface::{DecisionSurface, SurfaceAxes};
use crate::comm::Strategy;
use crate::sweep::emit::esc;
use crate::util::json::{fmt_f64 as num, Json};
use std::fmt::Write as _;

/// Artifact schema identifier; bump on layout changes.
pub const SCHEMA: &str = "hetcomm.surface.v1";

fn usize_list(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Serialize a surface as a versioned JSON artifact. Stale flags are not
/// persisted: an artifact is always written fresh (recompile before save).
pub fn to_json(surface: &DecisionSurface) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    let _ = writeln!(out, "  \"dup_frac\": {},", num(surface.dup_frac));
    out.push_str("  \"axes\": {\n");
    let _ = writeln!(out, "    \"msgs\": {},", usize_list(&surface.axes.msgs));
    let _ = writeln!(out, "    \"sizes\": {},", usize_list(&surface.axes.sizes));
    let _ = writeln!(out, "    \"dest_nodes\": {},", usize_list(&surface.axes.dest_nodes));
    let _ = writeln!(out, "    \"gpus_per_node\": {}", usize_list(&surface.axes.gpus_per_node));
    out.push_str("  },\n");
    let strategies: Vec<String> = surface.strategies.iter().map(|s| format!("\"{}\"", esc(&s.label()))).collect();
    let _ = writeln!(out, "  \"strategies\": [{}],", strategies.join(", "));
    out.push_str("  \"cells\": [\n");
    for (i, cell) in surface.cells.iter().enumerate() {
        let times: Vec<String> = cell.iter().map(|&t| num(t)).collect();
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    [{}]{comma}", times.join(", "));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write an artifact to disk.
pub fn save(surface: &DecisionSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(surface)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate an artifact from disk.
pub fn load(path: &str) -> Result<DecisionSurface, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate an artifact.
pub fn parse_json(text: &str) -> Result<DecisionSurface, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("unsupported surface schema {schema:?} (expected {SCHEMA:?})"));
    }
    let axes = value.field("axes")?;
    let axes = SurfaceAxes {
        msgs: axes.field("msgs")?.as_usize_list()?,
        sizes: axes.field("sizes")?.as_usize_list()?,
        dest_nodes: axes.field("dest_nodes")?.as_usize_list()?,
        gpus_per_node: axes.field("gpus_per_node")?.as_usize_list()?,
    };
    let strategies = value
        .field("strategies")?
        .as_arr()?
        .iter()
        .map(|s| {
            let label = s.as_str()?;
            Strategy::parse_label(label).ok_or_else(|| format!("unknown strategy label {label:?}"))
        })
        .collect::<Result<Vec<Strategy>, String>>()?;
    let cells = value
        .field("cells")?
        .as_arr()?
        .iter()
        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<f64>, String>>())
        .collect::<Result<Vec<Vec<f64>>, String>>()?;
    let stale = vec![false; cells.len()];
    let surface = DecisionSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        dup_frac: value.field("dup_frac")?.as_f64()?,
        axes,
        strategies,
        cells,
        stale,
    };
    surface.validate()?;
    Ok(surface)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_surface() -> DecisionSurface {
        let axes = SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        };
        DecisionSurface::compile("lassen", axes, 0.25).unwrap()
    }

    #[test]
    fn artifact_roundtrips_bit_for_bit() {
        let surface = tiny_surface();
        let json = to_json(&surface);
        assert!(json.contains(SCHEMA));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(surface, parsed);
        // serialization is stable: emit(parse(emit(s))) == emit(s)
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn save_load_roundtrip() {
        let surface = tiny_surface();
        let path = std::env::temp_dir().join("hetcomm-surface-test.json");
        let path = path.to_str().unwrap();
        save(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn wrong_schema_rejected() {
        let json = to_json(&tiny_surface()).replace(SCHEMA, "hetcomm.surface.v999");
        let err = parse_json(&json).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("{}").unwrap_err().contains("missing field"));
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        // structurally valid JSON, structurally invalid surface
        let truncated = to_json(&tiny_surface()).replace("\"msgs\": [64, 256]", "\"msgs\": [64, 256, 512]");
        assert!(parse_json(&truncated).is_err());
    }

}
