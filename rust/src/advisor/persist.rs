//! Versioned JSON artifacts for compiled decision surfaces.
//!
//! The writer is hand-rolled (no `serde` in the offline image) and emits
//! floats through Rust's shortest-round-trip `Display`
//! ([`crate::util::json::fmt_f64`]), so a parsed artifact reproduces the
//! compiled surface bit for bit and two compiles of the same spec serialize
//! byte-identically. The reader is the shared minimal JSON parser
//! ([`crate::util::json`]) followed by schema-checked extraction (unknown
//! schema versions are rejected, not guessed at).
//!
//! Three schema versions coexist (docs/FORMATS.md):
//!
//! - `hetcomm.surface.v1` — the shape-less layout. *Written* for
//!   single-rail surfaces (`nics == 1`), keeping their bytes identical to
//!   the pre-shape-layer writer; *read* as `nics = 1`.
//! - `hetcomm.surface.v2` — v1 plus the `nics` shape key. Written for
//!   multi-rail surfaces; read verbatim.
//! - `hetcomm.surface.v3` — the compact quantized layout (`hetcomm advise
//!   --compile --quant`): per-cell fastest-first strategy ids packed as hex
//!   nibbles, per-cell times as one full bit pattern plus ascending hex
//!   bit-pattern deltas, and the crossover boundary table. Lossless — a v3
//!   artifact decodes to the bit-identical surface its v1/v2 sibling
//!   round-trips — and self-checking on load: the rank nibbles must be the
//!   stable argsort of the decoded times, and the boundary table must match
//!   the crossovers recomputed from the decoded cells.

use super::surface::{cell_ranking, DecisionSurface, SurfaceAxes};
use crate::comm::Strategy;
use crate::sweep::emit::esc;
use crate::util::json::{fmt_f64 as num, fmt_usize_list as usize_list, Json};
use std::fmt::Write as _;

/// Artifact schema identifier of shape-less (single-rail) surfaces.
pub const SCHEMA: &str = "hetcomm.surface.v1";

/// Artifact schema identifier of shape-keyed (multi-rail) surfaces.
pub const SCHEMA_V2: &str = "hetcomm.surface.v2";

/// Artifact schema identifier of compact quantized surfaces.
pub const SCHEMA_V3: &str = "hetcomm.surface.v3";

/// Serialize a surface as a versioned JSON artifact. Stale flags are not
/// persisted: an artifact is always written fresh (recompile before save).
/// Single-rail surfaces emit [`SCHEMA`] bytes (identical to the
/// pre-shape-layer writer); multi-rail surfaces emit [`SCHEMA_V2`] with
/// the `nics` shape key.
pub fn to_json(surface: &DecisionSurface) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    if surface.nics == 1 {
        let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
        let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    } else {
        let _ = writeln!(out, "  \"schema\": \"{SCHEMA_V2}\",");
        let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
        let _ = writeln!(out, "  \"nics\": {},", surface.nics);
    }
    let _ = writeln!(out, "  \"dup_frac\": {},", num(surface.dup_frac));
    out.push_str("  \"axes\": {\n");
    let _ = writeln!(out, "    \"msgs\": {},", usize_list(&surface.axes.msgs));
    let _ = writeln!(out, "    \"sizes\": {},", usize_list(&surface.axes.sizes));
    let _ = writeln!(out, "    \"dest_nodes\": {},", usize_list(&surface.axes.dest_nodes));
    let _ = writeln!(out, "    \"gpus_per_node\": {}", usize_list(&surface.axes.gpus_per_node));
    out.push_str("  },\n");
    let strategies: Vec<String> = surface.strategies.iter().map(|s| format!("\"{}\"", esc(&s.label()))).collect();
    let _ = writeln!(out, "  \"strategies\": [{}],", strategies.join(", "));
    out.push_str("  \"cells\": [\n");
    for (i, cell) in surface.cells.iter().enumerate() {
        let times: Vec<String> = cell.iter().map(|&t| num(t)).collect();
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    [{}]{comma}", times.join(", "));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write an artifact to disk.
pub fn save(surface: &DecisionSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(surface)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Index of a strategy inside the artifact's `strategies` table.
fn strategy_id(surface: &DecisionSurface, s: crate::comm::Strategy) -> usize {
    surface.strategies.iter().position(|&x| x == s).expect("crossover strategies come from the surface")
}

/// Serialize a surface as the compact quantized [`SCHEMA_V3`] artifact:
/// axes and strategy labels as in v2 (the `nics` shape key is always
/// explicit), then per cell a hex-nibble rank string (strategy ids,
/// fastest first) and a time string — the fastest time's full 16-hex f64
/// bit pattern followed by `.`-joined hex bit-pattern deltas up the
/// ranking (positive finite doubles order identically to their bit
/// patterns, so the deltas are non-negative and shorter than decimal
/// re-prints) — plus the crossover boundary table with integer strategy
/// ids. Lossless: parsing reproduces the surface bit for bit.
pub fn to_json_quant(surface: &DecisionSurface) -> Result<String, String> {
    if surface.strategies.len() > 16 {
        return Err(format!(
            "v3 packs strategy ids as hex nibbles; {} strategies exceed 16",
            surface.strategies.len()
        ));
    }
    let rankings: Vec<Vec<u8>> = surface.cells.iter().map(|cell| cell_ranking(cell)).collect();
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA_V3}\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    let _ = writeln!(out, "  \"nics\": {},", surface.nics);
    let _ = writeln!(out, "  \"dup_frac\": {},", num(surface.dup_frac));
    out.push_str("  \"axes\": {\n");
    let _ = writeln!(out, "    \"msgs\": {},", usize_list(&surface.axes.msgs));
    let _ = writeln!(out, "    \"sizes\": {},", usize_list(&surface.axes.sizes));
    let _ = writeln!(out, "    \"dest_nodes\": {},", usize_list(&surface.axes.dest_nodes));
    let _ = writeln!(out, "    \"gpus_per_node\": {}", usize_list(&surface.axes.gpus_per_node));
    out.push_str("  },\n");
    let strategies: Vec<String> = surface.strategies.iter().map(|s| format!("\"{}\"", esc(&s.label()))).collect();
    let _ = writeln!(out, "  \"strategies\": [{}],", strategies.join(", "));
    out.push_str("  \"ranks\": [\n");
    for (i, order) in rankings.iter().enumerate() {
        let nibbles: String =
            order.iter().map(|&k| char::from_digit(k as u32, 16).expect("ids fit a nibble")).collect();
        let comma = if i + 1 < rankings.len() { "," } else { "" };
        let _ = writeln!(out, "    \"{nibbles}\"{comma}");
    }
    out.push_str("  ],\n");
    out.push_str("  \"cells\": [\n");
    for (i, (cell, order)) in surface.cells.iter().zip(&rankings).enumerate() {
        let bits: Vec<u64> = order.iter().map(|&k| cell[k as usize].to_bits()).collect();
        let mut packed = format!("{:016x}", bits[0]);
        for w in bits.windows(2) {
            let delta = w[1]
                .checked_sub(w[0])
                .ok_or_else(|| format!("cell {i}: times are not positive-ascending under their ranking"))?;
            let _ = write!(packed, ".{delta:x}");
        }
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    \"{packed}\"{comma}");
    }
    out.push_str("  ],\n");
    let crossings = surface.crossovers();
    if crossings.is_empty() {
        out.push_str("  \"boundaries\": []\n");
    } else {
        out.push_str("  \"boundaries\": [\n");
        for (i, x) in crossings.iter().enumerate() {
            let comma = if i + 1 < crossings.len() { "," } else { "" };
            let _ = writeln!(
                out,
                "    [{}, {}, {}, {}, {}, {}, {}, {}]{comma}",
                x.n_msgs,
                x.dest_nodes,
                x.gpus_per_node,
                x.size_before,
                x.size_after,
                strategy_id(surface, x.from),
                strategy_id(surface, x.to),
                num(x.size_exact)
            );
        }
        out.push_str("  ]\n");
    }
    out.push_str("}\n");
    Ok(out)
}

/// Write a quantized v3 artifact to disk.
pub fn save_quant(surface: &DecisionSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json_quant(surface)?).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate an artifact from disk.
pub fn load(path: &str) -> Result<DecisionSurface, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate an artifact (any schema version; see the module
/// docs for the v1 read-compat rule and the v3 self-checks).
pub fn parse_json(text: &str) -> Result<DecisionSurface, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    let nics = match schema {
        s if s == SCHEMA => 1, // v1 read-compat: shape-less means single-rail
        s if s == SCHEMA_V2 => value.field("nics")?.as_usize()?,
        s if s == SCHEMA_V3 => return parse_v3(&value),
        other => {
            return Err(format!(
                "unsupported surface schema {other:?} (expected {SCHEMA:?}, {SCHEMA_V2:?}, or {SCHEMA_V3:?})"
            ))
        }
    };
    let axes = parse_axes(&value)?;
    let strategies = parse_strategies(&value)?;
    let cells = value
        .field("cells")?
        .as_arr()?
        .iter()
        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<f64>, String>>())
        .collect::<Result<Vec<Vec<f64>>, String>>()?;
    let stale = vec![false; cells.len()];
    let surface = DecisionSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        nics,
        dup_frac: value.field("dup_frac")?.as_f64()?,
        axes,
        strategies,
        cells,
        stale,
    };
    surface.validate()?;
    Ok(surface)
}

fn parse_axes(value: &Json) -> Result<SurfaceAxes, String> {
    let axes = value.field("axes")?;
    Ok(SurfaceAxes {
        msgs: axes.field("msgs")?.as_usize_list()?,
        sizes: axes.field("sizes")?.as_usize_list()?,
        dest_nodes: axes.field("dest_nodes")?.as_usize_list()?,
        gpus_per_node: axes.field("gpus_per_node")?.as_usize_list()?,
    })
}

fn parse_strategies(value: &Json) -> Result<Vec<Strategy>, String> {
    value
        .field("strategies")?
        .as_arr()?
        .iter()
        .map(|s| {
            let label = s.as_str()?;
            Strategy::parse_label(label).ok_or_else(|| format!("unknown strategy label {label:?}"))
        })
        .collect()
}

/// Decode one v3 rank string: `n` hex nibbles forming a permutation of the
/// strategy ids `0..n`.
fn decode_ranks(s: &str, n: usize) -> Result<Vec<u8>, String> {
    if s.len() != n {
        return Err(format!("rank string {s:?} must hold {n} nibbles"));
    }
    let mut seen = [false; 16];
    let mut order = Vec::with_capacity(n);
    for ch in s.chars() {
        let k = ch.to_digit(16).ok_or_else(|| format!("invalid rank nibble {ch:?}"))? as usize;
        if k >= n {
            return Err(format!("rank id {k} out of range (artifact has {n} strategies)"));
        }
        if seen[k] {
            return Err(format!("duplicate rank id {k}"));
        }
        seen[k] = true;
        order.push(k as u8);
    }
    Ok(order)
}

/// Decode one v3 cell string: the base 16-hex f64 bit pattern plus hex
/// bit-pattern deltas, back into `n` ranked-ascending times.
fn decode_times(s: &str, n: usize) -> Result<Vec<f64>, String> {
    let mut parts = s.split('.');
    let base = parts.next().expect("split yields at least one part");
    if base.len() != 16 {
        return Err(format!("base bit pattern {base:?} must be 16 hex digits"));
    }
    let mut bits = u64::from_str_radix(base, 16).map_err(|e| format!("bad base bit pattern {base:?}: {e}"))?;
    let mut times = Vec::with_capacity(n);
    times.push(f64::from_bits(bits));
    for d in parts {
        let delta = u64::from_str_radix(d, 16).map_err(|e| format!("bad bit delta {d:?}: {e}"))?;
        bits = bits.checked_add(delta).ok_or_else(|| format!("bit delta {d:?} overflows"))?;
        times.push(f64::from_bits(bits));
    }
    if times.len() != n {
        return Err(format!("cell holds {} times, artifact has {n} strategies", times.len()));
    }
    Ok(times)
}

/// The v3 read path: decode ranks and delta-packed times back into cells,
/// then self-check — the rank nibbles must be the stable argsort of the
/// decoded times, and the boundary table must match the crossovers
/// recomputed from the decoded cells (the same trust-but-verify pattern
/// `hetcomm.trace.v1` uses for its metadata).
fn parse_v3(value: &Json) -> Result<DecisionSurface, String> {
    let axes = parse_axes(value)?;
    let strategies = parse_strategies(value)?;
    if strategies.len() > 16 {
        return Err(format!("v3 packs strategy ids as hex nibbles; {} strategies exceed 16", strategies.len()));
    }
    let n = strategies.len();
    let ranks_raw = value.field("ranks")?.as_arr()?;
    let cells_raw = value.field("cells")?.as_arr()?;
    if ranks_raw.len() != cells_raw.len() {
        return Err(format!("v3 artifact has {} rank rows but {} cell rows", ranks_raw.len(), cells_raw.len()));
    }
    let mut cells = Vec::with_capacity(cells_raw.len());
    let mut rankings = Vec::with_capacity(cells_raw.len());
    for (i, (r, c)) in ranks_raw.iter().zip(cells_raw).enumerate() {
        let order = decode_ranks(r.as_str()?, n).map_err(|e| format!("v3 cell {i}: {e}"))?;
        let ranked_times = decode_times(c.as_str()?, n).map_err(|e| format!("v3 cell {i}: {e}"))?;
        let mut times = vec![0f64; n];
        for (pos, &k) in order.iter().enumerate() {
            times[k as usize] = ranked_times[pos];
        }
        rankings.push(order);
        cells.push(times);
    }
    let stale = vec![false; cells.len()];
    let surface = DecisionSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        nics: value.field("nics")?.as_usize()?,
        dup_frac: value.field("dup_frac")?.as_f64()?,
        axes,
        strategies,
        cells,
        stale,
    };
    surface.validate()?;
    for (i, (cell, order)) in surface.cells.iter().zip(&rankings).enumerate() {
        if &cell_ranking(cell) != order {
            return Err(format!("v3 cell {i}: rank nibbles disagree with the decoded times"));
        }
    }
    check_boundaries(&surface, value.field("boundaries")?.as_arr()?)?;
    Ok(surface)
}

/// Verify a v3 boundary table against the crossovers of the decoded cells.
fn check_boundaries(surface: &DecisionSurface, rows: &[Json]) -> Result<(), String> {
    let expect = surface.crossovers();
    if rows.len() != expect.len() {
        return Err(format!("v3 boundary table has {} rows, decoded cells imply {}", rows.len(), expect.len()));
    }
    for (i, (row, x)) in rows.iter().zip(&expect).enumerate() {
        let row = row.as_arr()?;
        if row.len() != 8 {
            return Err(format!("v3 boundary row {i} has {} fields, expected 8", row.len()));
        }
        let mut ints = [0usize; 7];
        for (slot, field) in ints.iter_mut().zip(row) {
            *slot = field.as_usize()?;
        }
        let from = *surface
            .strategies
            .get(ints[5])
            .ok_or_else(|| format!("v3 boundary row {i}: strategy id {} out of range", ints[5]))?;
        let to = *surface
            .strategies
            .get(ints[6])
            .ok_or_else(|| format!("v3 boundary row {i}: strategy id {} out of range", ints[6]))?;
        let matches = ints[0] == x.n_msgs
            && ints[1] == x.dest_nodes
            && ints[2] == x.gpus_per_node
            && ints[3] == x.size_before
            && ints[4] == x.size_after
            && from == x.from
            && to == x.to
            && row[7].as_f64()?.to_bits() == x.size_exact.to_bits();
        if !matches {
            return Err(format!("v3 boundary row {i} disagrees with the crossovers of the decoded cells"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_axes() -> SurfaceAxes {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![256, 4096, 1 << 18],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    }

    fn tiny_surface() -> DecisionSurface {
        DecisionSurface::compile("lassen", tiny_axes(), 0.25).unwrap()
    }

    #[test]
    fn artifact_roundtrips_bit_for_bit() {
        let surface = tiny_surface();
        let json = to_json(&surface);
        assert!(json.contains(SCHEMA));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(surface, parsed);
        // serialization is stable: emit(parse(emit(s))) == emit(s)
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn single_rail_surfaces_stay_on_v1_bytes() {
        // the v1 writer never learns about shapes: no `nics` key at all
        let json = to_json(&tiny_surface());
        assert!(json.contains("\"schema\": \"hetcomm.surface.v1\""));
        assert!(!json.contains("nics"), "v1 artifacts must not carry the shape key");
    }

    #[test]
    fn multi_rail_surfaces_roundtrip_as_v2() {
        for (machine, nics) in [("frontier-4nic", 0usize), ("lassen", 4)] {
            let surface = DecisionSurface::compile_shaped(machine, nics, tiny_axes(), 0.0).unwrap();
            let json = to_json(&surface);
            assert!(json.contains("\"schema\": \"hetcomm.surface.v2\""), "{machine}");
            assert!(json.contains(&format!("\"nics\": {}", surface.nics)));
            let parsed = parse_json(&json).unwrap();
            assert_eq!(surface, parsed);
            assert_eq!(json, to_json(&parsed));
        }
    }

    #[test]
    fn v1_artifacts_read_as_single_rail() {
        // a pre-shape-layer artifact (no nics key) loads with nics = 1
        let json = to_json(&tiny_surface());
        let parsed = parse_json(&json).unwrap();
        assert_eq!(parsed.nics, 1);
    }

    #[test]
    fn save_load_roundtrip() {
        let surface = tiny_surface();
        let path = std::env::temp_dir().join("hetcomm-surface-test.json");
        let path = path.to_str().unwrap();
        save(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn wrong_schema_rejected() {
        let json = to_json(&tiny_surface()).replace(SCHEMA, "hetcomm.surface.v999");
        let err = parse_json(&json).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
        // a v2 artifact missing its shape key is rejected too
        let surface = DecisionSurface::compile_shaped("lassen", 2, tiny_axes(), 0.0).unwrap();
        let json = to_json(&surface).replace("  \"nics\": 2,\n", "");
        assert!(parse_json(&json).is_err());
    }

    #[test]
    fn v3_roundtrips_bit_for_bit() {
        let surface = tiny_surface();
        let quant = to_json_quant(&surface).unwrap();
        assert!(quant.contains("\"schema\": \"hetcomm.surface.v3\""));
        let parsed = parse_json(&quant).unwrap();
        assert_eq!(surface, parsed);
        // quantized serialization is stable too
        assert_eq!(quant, to_json_quant(&parsed).unwrap());
    }

    #[test]
    fn v3_is_losslessly_interchangeable_with_v2() {
        for (machine, nics) in [("lassen", 4usize), ("frontier-4nic", 0)] {
            let surface = DecisionSurface::compile_shaped(machine, nics, tiny_axes(), 0.0).unwrap();
            let v2 = to_json(&surface);
            let quant = to_json_quant(&surface).unwrap();
            // v2 -> v3 -> v2 reproduces the exact v2 bytes
            let from_quant = parse_json(&quant).unwrap();
            assert_eq!(from_quant, parse_json(&v2).unwrap(), "{machine}");
            assert_eq!(to_json(&from_quant), v2, "{machine}: v3 must round-trip to identical v2 bytes");
        }
    }

    #[test]
    fn v3_always_carries_the_shape_key() {
        // unlike the v1 writer, v3 is explicit even for single-rail shapes
        let quant = to_json_quant(&tiny_surface()).unwrap();
        assert!(quant.contains("\"nics\": 1"));
        let pinned = DecisionSurface::compile("frontier-4nic", tiny_axes(), 0.0).unwrap();
        let quant = to_json_quant(&pinned).unwrap();
        assert!(quant.contains("\"nics\": 4"));
        assert_eq!(parse_json(&quant).unwrap().nics, 4);
    }

    #[test]
    fn v3_is_more_compact_than_v2() {
        let surface = DecisionSurface::compile("lassen", SurfaceAxes::default_axes(), 0.0).unwrap();
        let v2 = to_json(&surface);
        let quant = to_json_quant(&surface).unwrap();
        assert!(
            quant.len() < v2.len(),
            "quantized artifact ({} B) must undercut the decimal one ({} B)",
            quant.len(),
            v2.len()
        );
    }

    #[test]
    fn v3_save_load_roundtrip() {
        let surface = tiny_surface();
        let path = std::env::temp_dir().join("hetcomm-surface-v3-test.json");
        let path = path.to_str().unwrap();
        save_quant(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn v3_self_checks_reject_tampering() {
        let surface = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        assert!(!surface.crossovers().is_empty(), "precondition: the 16-node line flips winners");
        let quant = to_json_quant(&surface).unwrap();

        // a duplicate rank nibble is structurally invalid
        let marker = "\"ranks\": [\n    \"";
        let at = quant.find(marker).unwrap() + marker.len();
        let n = surface.strategies.len();
        let bad_ranks = format!("{}{}{}", &quant[..at], "0".repeat(n), &quant[at + n..]);
        assert!(parse_json(&bad_ranks).unwrap_err().contains("duplicate rank id"), "duplicate nibbles");

        // zeroing a base bit pattern decodes to a non-positive time
        let marker = "\"cells\": [\n    \"";
        let at = quant.find(marker).unwrap() + marker.len();
        let bad_cell = format!("{}{}{}", &quant[..at], "0".repeat(16), &quant[at + 16..]);
        assert!(parse_json(&bad_cell).is_err(), "zeroed base bit pattern");

        // an emptied boundary table no longer matches the decoded cells
        let at = quant.find("  \"boundaries\":").unwrap();
        let emptied = format!("{}  \"boundaries\": []\n}}\n", &quant[..at]);
        assert!(parse_json(&emptied).unwrap_err().contains("boundary"), "emptied boundaries");

        // the nibble guard refuses fleets of more than 16 strategies
        let mut wide = surface.clone();
        wide.strategies = [Strategy::all(), Strategy::all(), Strategy::all()].concat();
        assert!(to_json_quant(&wide).unwrap_err().contains("exceed 16"));
    }

    #[test]
    fn v3_nibble_guard_boundary() {
        // exactly 16 strategies is the hex-nibble encoding's capacity and
        // must still encode; 17 must fail with the explicit guard message
        let surface = DecisionSurface::compile("lassen", tiny_axes(), 0.0).unwrap();
        let mut s16 = surface.clone();
        s16.strategies = [Strategy::all(), Strategy::all()].concat();
        s16.cells = surface
            .cells
            .iter()
            .map(|c| {
                let mut widened = c.clone();
                widened.extend(c.iter().map(|&t| t * 2.0));
                widened
            })
            .collect();
        let quant = to_json_quant(&s16).expect("16 strategies fit the nibble encoding");
        let marker = "\"ranks\": [\n    \"";
        let at = quant.find(marker).unwrap() + marker.len();
        let width = quant[at..].find('"').unwrap();
        assert_eq!(width, 16, "each rank string carries one nibble per strategy");

        // one past capacity: a clear error instead of a corrupt artifact
        let mut s17 = s16.clone();
        s17.strategies.push(Strategy::all()[0]);
        s17.cells = s16
            .cells
            .iter()
            .map(|c| {
                let mut widened = c.clone();
                widened.push(c[0] * 4.0);
                widened
            })
            .collect();
        let err = to_json_quant(&s17).unwrap_err();
        assert!(err.contains("17 strategies exceed 16"), "{err}");
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("{}").unwrap_err().contains("missing field"));
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        // structurally valid JSON, structurally invalid surface
        let truncated = to_json(&tiny_surface()).replace("\"msgs\": [64, 256]", "\"msgs\": [64, 256, 512]");
        assert!(parse_json(&truncated).is_err());
    }
}
