//! The online strategy-advisor service — the paper's prescription
//! ("given this communication pattern on this machine, use that strategy",
//! Table 6 / Figure 4.3) packaged as a serving subsystem instead of an
//! offline report:
//!
//! - [`surface`] — compile a sweep grid into a compact per-machine
//!   *decision surface*: a regime lattice over messages × size ×
//!   destination nodes × GPUs-per-node with log-space interpolation and
//!   exact crossover boundaries;
//! - [`persist`] — versioned JSON artifacts (`hetcomm.surface.v1` for
//!   single-rail shapes, `hetcomm.surface.v2` with the `nics` shape key for
//!   multi-rail machines, compact quantized `hetcomm.surface.v3`) that
//!   round-trip surfaces bit for bit;
//! - [`snapshot`] — the immutable compiled-surface snapshot the read path
//!   serves from: precomputed lattice answers plus a pre-warmed memo;
//! - [`cache`] — the per-snapshot write-once memo table, so repeated
//!   queries cost a lock-free probe instead of a model evaluation;
//! - [`service`] — the multi-tenant snapshot front end: lock-free
//!   `advise` reads, batched grouped interpolation, per-tenant
//!   recalibration publishes, and the seeded deterministic burst benchmark;
//! - [`calibrate`] — measurement-driven recalibration: ingest observed
//!   timings, refit α/β via [`crate::params::fit`], rebuild the refit size
//!   band of a surface for the next published snapshot.
//!
//! Exposed on the CLI as `hetcomm advise` (`--compile`, `--query`,
//! `--bench-burst`, `--recalibrate`); `hetcomm sweep --emit-surface` writes
//! an artifact from a sweep grid, and `coordinator::engine`'s auto mode
//! asks the advisor to pick the exchange strategy for a partitioned
//! matrix's actual halo pattern — closing the loop from model to execution.

pub mod cache;
pub mod calibrate;
pub mod persist;
pub mod service;
pub mod snapshot;
pub mod surface;

pub use cache::{CacheKey, CacheStats, FixedMemo};
pub use calibrate::{CalibrationReport, Calibrator};
pub use service::{AdvisorService, BurstReport, Query};
pub use snapshot::SurfaceSnapshot;
pub use surface::{DecisionSurface, Pattern, RankedStrategies, SurfaceAxes, SurfaceCrossover};
