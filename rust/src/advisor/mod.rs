//! The online strategy-advisor service — the paper's prescription
//! ("given this communication pattern on this machine, use that strategy",
//! Table 6 / Figure 4.3) packaged as a serving subsystem instead of an
//! offline report:
//!
//! - [`surface`] — compile a sweep grid into a compact per-machine
//!   *decision surface*: a regime lattice over messages × size ×
//!   destination nodes × GPUs-per-node with log-space interpolation and
//!   exact crossover boundaries;
//! - [`persist`] — versioned JSON artifacts (`hetcomm.surface.v1` for
//!   single-rail shapes, `hetcomm.surface.v2` with the `nics` shape key for
//!   multi-rail machines) that round-trip surfaces bit for bit;
//! - [`cache`] — a sharded LRU so repeated queries cost a probe instead of
//!   a model evaluation;
//! - [`service`] — thread-pooled batched `advise` queries and the seeded
//!   deterministic burst benchmark;
//! - [`calibrate`] — measurement-driven recalibration: ingest observed
//!   timings, refit α/β via [`crate::params::fit`], mark stale surface
//!   cells for lazy recompile.
//!
//! Exposed on the CLI as `hetcomm advise` (`--compile`, `--query`,
//! `--bench-burst`, `--recalibrate`); `hetcomm sweep --emit-surface` writes
//! an artifact from a sweep grid, and `coordinator::engine`'s auto mode
//! asks the advisor to pick the exchange strategy for a partitioned
//! matrix's actual halo pattern — closing the loop from model to execution.

pub mod cache;
pub mod calibrate;
pub mod persist;
pub mod service;
pub mod surface;

pub use cache::{CacheKey, CacheStats, ShardedLru};
pub use calibrate::{CalibrationReport, Calibrator};
pub use service::{AdvisorService, BurstReport, Query};
pub use surface::{DecisionSurface, Pattern, RankedStrategies, SurfaceAxes, SurfaceCrossover};
