//! The parallel strategy-sweep engine — the characterization tool that
//! turns the crate's layers into the paper's headline result.
//!
//! A sweep evaluates the full grid of (strategy × pattern generator ×
//! destination-node count × GPUs-per-node × NIC-rails-per-node × message
//! size) through both the closed-form Table 6 models
//! ([`crate::model::StrategyModel`]) and the discrete-event simulator
//! ([`crate::sim`]), fanning cells out over an in-tree `std::thread`
//! worker pool:
//!
//! - [`grid`] — the axes and their flattening into deterministic cells;
//! - [`engine`] — the worker pool, per-cell seeding, model + sim evaluation,
//!   plus the opt-in scale levers (branch-and-bound pruning, pattern-lowering
//!   reuse, adaptive size-axis refinement — all winner-preserving);
//! - [`report`] — per-cell winners, per-regime winning strategies,
//!   crossover points, model-vs-simulation error aggregation, prune totals;
//! - [`emit`] — byte-deterministic JSON, CSV and table output.
//!
//! The derived report reproduces the paper's claim that staged node-aware
//! Split strategies win the high-node-count, moderate-size regime while
//! device-aware communication takes over at large message sizes
//! (Figure 4.3 / Table 6), and locates the crossover sizes in between.
//!
//! Exposed on the CLI as `hetcomm sweep`; `examples/strategy_sweep.rs` and
//! `rust/benches/scenarios.rs` are thin drivers over this module.

pub mod emit;
pub mod engine;
pub mod grid;
pub mod report;

pub use engine::{
    effective_threads, run_sweep, run_sweep_mode, run_sweep_trace, run_sweep_trace_mode, CellResult, ExecMode,
    SweepConfig, SweepResult,
};
pub use grid::{CellSpec, GridSpec, PatternGen};
pub use report::{analyze, CellWinner, Crossover, ErrorSummary, PruneSummary, RegimeWinner, SweepReport, SMALL_BAND_MAX};
