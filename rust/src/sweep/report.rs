//! Sweep analysis: per-cell winners, per-regime winning strategies, model
//! crossover points, and model-vs-simulation error aggregation — the
//! machinery behind the paper's Table 6 / Figure 4.3 narrative ("staged
//! node-aware split strategies win the high-message-count, moderate-size
//! regime; device-aware communication takes over at large sizes").

use super::engine::CellResult;
use super::grid::PatternGen;
use crate::comm::{StrategyKind, Transport};
use std::collections::BTreeMap;

/// Band boundary between the "small" and "large" message regimes: the
/// Lassen eager→rendezvous switch point (8 KiB), where the paper's staging
/// trade-offs change character.
pub const SMALL_BAND_MAX: usize = 8192;

/// The model-fastest strategy of one grid cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CellWinner {
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// NIC rails per node of the cell's shape.
    pub nics: usize,
    pub size: usize,
    /// Label of the model-fastest strategy.
    pub winner: &'static str,
    pub winner_kind: StrategyKind,
    pub winner_staged: bool,
    pub model_s: f64,
    /// Label of the simulator-fastest strategy, when the sweep simulated.
    /// Pruning-invariant: a strategy tying or beating the incumbent is
    /// never pruned, so the first-minimal survivor is the full run's.
    pub sim_winner: Option<&'static str>,
    /// Strategies whose simulation branch-and-bound pruning skipped in
    /// this cell (0 unless the sweep ran with `prune`).
    pub pruned: usize,
}

/// A model winner change between two adjacent sizes of one regime line.
#[derive(Clone, Debug, PartialEq)]
pub struct Crossover {
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// NIC rails per node of the regime line.
    pub nics: usize,
    /// Largest size still won by `from`.
    pub size_before: usize,
    /// Smallest size won by `to`.
    pub size_after: usize,
    pub from: &'static str,
    pub to: &'static str,
}

/// The strategy minimizing total modeled time over one band of one regime
/// line.
#[derive(Clone, Debug, PartialEq)]
pub struct RegimeWinner {
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// NIC rails per node of the regime line.
    pub nics: usize,
    /// `"small"` (size <= [`SMALL_BAND_MAX`]) or `"large"`.
    pub band: &'static str,
    pub winner: &'static str,
    pub winner_kind: StrategyKind,
    pub winner_staged: bool,
    pub total_model_s: f64,
}

/// Aggregate model-vs-simulation error over cells that ran both.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ErrorSummary {
    pub cells_with_sim: usize,
    pub mean: f64,
    pub max: f64,
}

/// Branch-and-bound pruning totals over the whole sweep (all zero unless
/// the sweep ran with `prune`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneSummary {
    /// Grid cells analyzed.
    pub cells: usize,
    /// (cell × strategy) pairs that ran the simulator.
    pub sim_evals: usize,
    /// (cell × strategy) pairs whose simulation was skipped by bounds.
    pub pruned: usize,
}

/// The derived sweep report.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    pub winners: Vec<CellWinner>,
    pub crossovers: Vec<Crossover>,
    pub regimes: Vec<RegimeWinner>,
    pub model_error: ErrorSummary,
    pub prune: PruneSummary,
}

fn same_line(a: &CellResult, b: &CellResult) -> bool {
    a.gen == b.gen && a.dest_nodes == b.dest_nodes && a.gpus_per_node == b.gpus_per_node && a.nics == b.nics
}

/// Analyze sweep cells (in engine output order: grid-cell major, strategies
/// within) into winners, crossovers, regime winners and error stats.
pub fn analyze(cells: &[CellResult]) -> SweepReport {
    let mut report = SweepReport::default();

    // --- Per-cell winners: min model time over each cell's strategies. ---
    let mut i = 0;
    while i < cells.len() {
        let mut j = i + 1;
        while j < cells.len() && cells[j].index == cells[i].index {
            j += 1;
        }
        let group = &cells[i..j];
        let best = group
            .iter()
            .min_by(|a, b| a.model_s.partial_cmp(&b.model_s).expect("finite model times"))
            .expect("non-empty cell group");
        let sim_winner = group
            .iter()
            .filter(|c| c.sim_s.is_some())
            .min_by(|a, b| a.sim_s.partial_cmp(&b.sim_s).expect("finite sim times"))
            .map(|c| c.label);
        report.winners.push(CellWinner {
            gen: best.gen,
            dest_nodes: best.dest_nodes,
            gpus_per_node: best.gpus_per_node,
            nics: best.nics,
            size: best.size,
            winner: best.label,
            winner_kind: best.strategy.kind,
            winner_staged: best.strategy.transport == Transport::Staged,
            model_s: best.model_s,
            sim_winner,
            pruned: group.iter().filter(|c| c.sim_pruned).count(),
        });
        i = j;
    }

    // --- Crossovers: winner changes along each regime line (ascending
    // size; the grid emits sizes sorted). ---
    let mut k = 0;
    while k < report.winners.len() {
        let mut j = k + 1;
        while j < report.winners.len() && winners_same_line(&report.winners[j], &report.winners[k]) {
            j += 1;
        }
        for w in report.winners[k..j].windows(2) {
            if w[0].winner != w[1].winner {
                report.crossovers.push(Crossover {
                    gen: w[0].gen,
                    dest_nodes: w[0].dest_nodes,
                    gpus_per_node: w[0].gpus_per_node,
                    nics: w[0].nics,
                    size_before: w[0].size,
                    size_after: w[1].size,
                    from: w[0].winner,
                    to: w[1].winner,
                });
            }
        }
        k = j;
    }

    // --- Regime winners: per line and band, min total modeled time. ---
    let mut i = 0;
    while i < cells.len() {
        let mut j = i + 1;
        while j < cells.len() && same_line(&cells[j], &cells[i]) {
            j += 1;
        }
        let line = &cells[i..j];
        for (band, want_small) in [("small", true), ("large", false)] {
            // label -> (total model s, kind, staged)
            let mut totals: BTreeMap<&'static str, (f64, StrategyKind, bool)> = BTreeMap::new();
            for c in line.iter().filter(|c| (c.size <= SMALL_BAND_MAX) == want_small) {
                let e = totals
                    .entry(c.label)
                    .or_insert((0.0, c.strategy.kind, c.strategy.transport == Transport::Staged));
                e.0 += c.model_s;
            }
            if totals.is_empty() {
                continue;
            }
            let (&winner, &(total, kind, staged)) = totals
                .iter()
                .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).expect("finite totals"))
                .expect("non-empty band");
            report.regimes.push(RegimeWinner {
                gen: line[0].gen,
                dest_nodes: line[0].dest_nodes,
                gpus_per_node: line[0].gpus_per_node,
                nics: line[0].nics,
                band,
                winner,
                winner_kind: kind,
                winner_staged: staged,
                total_model_s: total,
            });
        }
        i = j;
    }

    // --- Model-error aggregation. ---
    let errs: Vec<f64> = cells.iter().filter_map(|c| c.model_err).collect();
    if !errs.is_empty() {
        report.model_error = ErrorSummary {
            cells_with_sim: errs.len(),
            mean: errs.iter().sum::<f64>() / errs.len() as f64,
            max: errs.iter().fold(0.0f64, |m, &e| m.max(e)),
        };
    }

    // --- Prune accounting. ---
    report.prune = PruneSummary {
        cells: report.winners.len(),
        sim_evals: cells.iter().filter(|c| c.sim_s.is_some()).count(),
        pruned: cells.iter().filter(|c| c.sim_pruned).count(),
    };

    report
}

fn winners_same_line(a: &CellWinner, b: &CellWinner) -> bool {
    a.gen == b.gen && a.dest_nodes == b.dest_nodes && a.gpus_per_node == b.gpus_per_node && a.nics == b.nics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;

    /// Build a synthetic cell: two strategies with fixed model times.
    fn mk_cells(specs: &[(usize, usize, f64, f64)]) -> Vec<CellResult> {
        // (index, size, t_split_staged, t_std_da)
        let split = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        let std_da = Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap();
        let mut out = Vec::new();
        for &(index, size, t_split, t_std) in specs {
            for (s, t) in [(split, t_split), (std_da, t_std)] {
                out.push(CellResult {
                    index,
                    gen: PatternGen::Uniform,
                    dest_nodes: 16,
                    gpus_per_node: 4,
                    nics: 1,
                    size,
                    strategy: s,
                    label: s.label(),
                    model_s: t,
                    sim_s: Some(t * 1.1),
                    model_err: Some(0.1),
                    sim_pruned: false,
                });
            }
        }
        out
    }

    #[test]
    fn winners_and_crossover_detected() {
        // Split wins small sizes, standard DA wins the large one.
        let cells = mk_cells(&[(0, 256, 1.0, 2.0), (1, 4096, 2.0, 3.0), (2, 1 << 20, 9.0, 4.0)]);
        let r = analyze(&cells);
        assert_eq!(r.winners.len(), 3);
        assert_eq!(r.winners[0].winner_kind, StrategyKind::SplitMd);
        assert!(r.winners[0].winner_staged);
        assert_eq!(r.winners[2].winner_kind, StrategyKind::Standard);
        assert_eq!(r.crossovers.len(), 1);
        let x = &r.crossovers[0];
        assert_eq!((x.size_before, x.size_after), (4096, 1 << 20));
        assert!(x.from.starts_with("Split+MD"));
        assert!(x.to.starts_with("Standard"));
    }

    #[test]
    fn regime_winners_split_small_std_large() {
        let cells = mk_cells(&[(0, 256, 1.0, 2.0), (1, 4096, 2.0, 3.0), (2, 1 << 20, 9.0, 4.0)]);
        let r = analyze(&cells);
        assert_eq!(r.regimes.len(), 2);
        let small = r.regimes.iter().find(|g| g.band == "small").unwrap();
        assert_eq!(small.winner_kind, StrategyKind::SplitMd);
        assert!((small.total_model_s - 3.0).abs() < 1e-12);
        let large = r.regimes.iter().find(|g| g.band == "large").unwrap();
        assert_eq!(large.winner_kind, StrategyKind::Standard);
    }

    #[test]
    fn sim_winner_tracked_separately() {
        let mut cells = mk_cells(&[(0, 256, 1.0, 2.0)]);
        // make the simulator prefer the other strategy
        cells[0].sim_s = Some(5.0);
        cells[1].sim_s = Some(0.5);
        let r = analyze(&cells);
        assert!(r.winners[0].winner.starts_with("Split+MD"));
        assert!(r.winners[0].sim_winner.as_deref().unwrap().starts_with("Standard"));
    }

    #[test]
    fn error_summary_aggregates() {
        let cells = mk_cells(&[(0, 256, 1.0, 2.0), (1, 4096, 2.0, 3.0)]);
        let r = analyze(&cells);
        assert_eq!(r.model_error.cells_with_sim, 4);
        assert!((r.model_error.mean - 0.1).abs() < 1e-12);
        assert!((r.model_error.max - 0.1).abs() < 1e-12);
    }

    #[test]
    fn empty_input_empty_report() {
        let r = analyze(&[]);
        assert!(r.winners.is_empty() && r.crossovers.is_empty() && r.regimes.is_empty());
        assert_eq!(r.model_error.cells_with_sim, 0);
    }
}
