//! Grid specification for the parallel strategy sweep: the axes of the
//! paper's characterization (pattern generator × destination-node count ×
//! GPUs per node × message size), flattened into deterministic work cells.

use crate::topology::{machines, Machine};

/// How a cell's communication pattern is generated.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PatternGen {
    /// The Figure 4.3 scenario: one node sends `n_msgs` messages of `size`
    /// bytes, spread evenly over its GPUs, to `dest_nodes` other nodes.
    Uniform,
    /// Random irregular pattern over the whole machine: `n_msgs` messages
    /// with sizes log-uniform in `[1, size]`, seeded per cell; `dup_frac`
    /// acts as the duplicate-reuse probability.
    Random,
    /// A recorded workload epoch ([`crate::trace::Trace`]): the pattern is
    /// replayed verbatim, not generated from the grid axes — cells of this
    /// kind come from [`super::engine::run_sweep_trace`], never from a
    /// [`GridSpec`].
    Trace,
}

impl PatternGen {
    /// The generators constructible from grid axes ([`PatternGen::Trace`]
    /// patterns come from recorded traces instead).
    pub const ALL: [PatternGen; 2] = [PatternGen::Uniform, PatternGen::Random];

    pub fn label(&self) -> &'static str {
        match self {
            PatternGen::Uniform => "uniform",
            PatternGen::Random => "random",
            PatternGen::Trace => "trace",
        }
    }

    /// Parse a user-facing generator name.
    pub fn parse(s: &str) -> Option<PatternGen> {
        match s.trim().to_ascii_lowercase().as_str() {
            "uniform" | "scenario" => Some(PatternGen::Uniform),
            "random" | "irregular" => Some(PatternGen::Random),
            "trace" => Some(PatternGen::Trace),
            _ => None,
        }
    }
}

impl std::fmt::Display for PatternGen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// The sweep grid: every combination of the axes below is one cell, and
/// every cell is evaluated for every selected strategy.
#[derive(Clone, Debug, PartialEq)]
pub struct GridSpec {
    /// Pattern generators to sweep.
    pub gens: Vec<PatternGen>,
    /// Destination-node counts (the machine is built with one extra node to
    /// host the sender, so `dest` destinations need `dest + 1` nodes).
    pub dest_nodes: Vec<usize>,
    /// GPUs per node (even: the Lassen-like node keeps 2 sockets).
    pub gpus_per_node: Vec<usize>,
    /// NIC rails per node (the §6 shape axis). The default `[1]` is the
    /// legacy single-rail node and leaves every output byte-identical to
    /// the pre-shape-layer sweep; machines whose preset pins the NIC count
    /// ([`machines::shape_pinned`]) reject any other value.
    pub nics: Vec<usize>,
    /// Message sizes in bytes (uniform: exact size; random: max size).
    pub sizes: Vec<usize>,
    /// Inter-node messages per scenario.
    pub n_msgs: usize,
    /// Duplicate-data fraction (uniform: model + marked sim duplicates;
    /// random: per-message duplicate-reuse probability).
    pub dup_frac: f64,
}

impl Default for GridSpec {
    fn default() -> GridSpec {
        GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 8, 16],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: (4..=20).step_by(2).map(|e| 1usize << e).collect(),
            n_msgs: 256,
            dup_frac: 0.0,
        }
    }
}

/// One unit of sweep work: a fully-specified grid point (all strategies are
/// evaluated inside the cell so the pattern is built once).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellSpec {
    /// Position in [`GridSpec::cells`] — drives the per-cell seed and the
    /// deterministic output order.
    pub index: usize,
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// NIC rails per node at this grid point.
    pub nics: usize,
    pub size: usize,
}

impl GridSpec {
    /// A <10 s grid for CI smoke tests: small axes that still cross a
    /// model winner boundary (Split+MD at moderate sizes, device-aware
    /// standard at 256 KiB).
    pub fn tiny() -> GridSpec {
        GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![4],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1 << 10, 1 << 14, 1 << 18],
            n_msgs: 64,
            dup_frac: 0.0,
        }
    }

    /// Check axis sanity; returns a user-facing message on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.gens.is_empty() {
            return Err("no pattern generators selected".into());
        }
        if self.gens.contains(&PatternGen::Trace) {
            return Err("trace patterns replay recorded workloads (sweep --trace), they cannot be grid-generated".into());
        }
        if self.dest_nodes.is_empty() || self.dest_nodes.iter().any(|&d| d == 0) {
            return Err("destination-node counts must be non-empty and positive".into());
        }
        if self.gpus_per_node.is_empty() || self.gpus_per_node.iter().any(|&g| g < 2 || g % 2 != 0) {
            return Err("GPUs-per-node values must be even and >= 2 (2-socket nodes)".into());
        }
        if self.nics.is_empty() || self.nics.iter().any(|&n| n == 0) {
            return Err("NIC-rail counts must be non-empty and positive".into());
        }
        if self.sizes.is_empty() || self.sizes.iter().any(|&s| s == 0) {
            return Err("message sizes must be non-empty and positive".into());
        }
        if self.n_msgs == 0 {
            return Err("n_msgs must be positive".into());
        }
        if !(0.0..1.0).contains(&self.dup_frac) {
            return Err("dup_frac must be in [0, 1)".into());
        }
        Ok(())
    }

    /// Flatten the axes into cells, in deterministic generator-major order.
    /// Sizes are sorted (and deduplicated) so per-regime winner lines read
    /// in ascending size order, which is what crossover detection assumes.
    pub fn cells(&self) -> Vec<CellSpec> {
        let mut sizes = self.sizes.clone();
        sizes.sort_unstable();
        sizes.dedup();
        let mut out = Vec::with_capacity(
            self.gens.len() * self.dest_nodes.len() * self.gpus_per_node.len() * self.nics.len() * sizes.len(),
        );
        for &gen in &self.gens {
            for &dest in &self.dest_nodes {
                for &gpn in &self.gpus_per_node {
                    for &nics in &self.nics {
                        for &size in &sizes {
                            out.push(CellSpec {
                                index: out.len(),
                                gen,
                                dest_nodes: dest,
                                gpus_per_node: gpn,
                                nics,
                                size,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// The Lassen-like machine for one (dest, gpn, nics) grid point: 2
    /// sockets, 20 cores per socket, `gpn / 2` GPUs per socket, `nics` NIC
    /// rails spread over the sockets, and one node more than the
    /// destination count so the uniform scenario has a sender.
    pub fn machine_for(&self, dest_nodes: usize, gpus_per_node: usize, nics: usize) -> Machine {
        self.machine_for_arch(&machines::lassen(1), dest_nodes, gpus_per_node, nics)
    }

    /// Like [`GridSpec::machine_for`], but on an arbitrary preset node
    /// architecture (sockets and cores from `arch`, GPUs and NIC rails from
    /// the grid axes) — the hook behind the `sweep --machine` / `--nics`
    /// flags. Single-rail points keep the historical `{name}-g{gpn}` label;
    /// multi-rail points append `-n{nics}`.
    pub fn machine_for_arch(&self, arch: &Machine, dest_nodes: usize, gpus_per_node: usize, nics: usize) -> Machine {
        let mut machine = machines::with_shape_nics(arch, dest_nodes + 1, gpus_per_node, nics);
        machine.name = if nics == 1 {
            format!("{}-g{gpus_per_node}", arch.name)
        } else {
            format!("{}-g{gpus_per_node}-n{nics}", arch.name)
        };
        machine
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_validates() {
        let g = GridSpec::default();
        g.validate().unwrap();
        assert!(!g.cells().is_empty());
    }

    #[test]
    fn cells_cover_product_in_order() {
        let g = GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1024, 64], // unsorted on purpose
            n_msgs: 32,
            dup_frac: 0.0,
        };
        let cells = g.cells();
        assert_eq!(cells.len(), 2 * 2 * 1 * 2);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // sizes sorted ascending within each line
        assert_eq!(cells[0].size, 64);
        assert_eq!(cells[1].size, 1024);
        // generator-major order
        assert!(cells[..4].iter().all(|c| c.gen == PatternGen::Uniform));
        assert!(cells[4..].iter().all(|c| c.gen == PatternGen::Random));
    }

    #[test]
    fn machine_shape_follows_axes() {
        let g = GridSpec::default();
        let m = g.machine_for(16, 4, 1);
        assert_eq!(m.num_nodes, 17);
        assert_eq!(m.gpus_per_node(), 4);
        assert_eq!(m.cores_per_node(), 40);
        assert_eq!(m.name, "lassen-g4");
        assert!(m.shape.is_single_rail());
        let m8 = g.machine_for(4, 8, 1);
        assert_eq!(m8.gpus_per_node(), 8);
        // the nics axis reaches the shape and the label
        let m2 = g.machine_for(4, 4, 2);
        assert_eq!(m2.nics_per_node(), 2);
        assert_eq!(m2.name, "lassen-g4-n2");
        m2.shape.validate(2, 4).unwrap();
    }

    #[test]
    fn machine_for_arch_keeps_preset_sockets() {
        let g = GridSpec::default();
        let f = g.machine_for_arch(&machines::frontier_like(1), 16, 4, 1);
        assert_eq!((f.num_nodes, f.sockets_per_node, f.cores_per_node(), f.gpus_per_node()), (17, 1, 64, 4));
        assert_eq!(f.name, "frontier-like-g4");
        let d = g.machine_for_arch(&machines::delta_like(1), 4, 8, 1);
        assert_eq!((d.sockets_per_node, d.cores_per_node(), d.gpus_per_node()), (2, 128, 8));
        let f4 = g.machine_for_arch(&machines::frontier_4nic(1), 4, 4, 4);
        assert_eq!((f4.nics_per_node(), f4.name.as_str()), (4, "frontier-4nic-g4-n4"));
    }

    #[test]
    fn validation_rejects_bad_axes() {
        let mut g = GridSpec::default();
        g.gpus_per_node = vec![3];
        assert!(g.validate().is_err());
        let mut g = GridSpec::default();
        g.sizes.clear();
        assert!(g.validate().is_err());
        let mut g = GridSpec::default();
        g.dup_frac = 1.0;
        assert!(g.validate().is_err());
        let mut g = GridSpec::default();
        g.nics = vec![];
        assert!(g.validate().is_err());
        let mut g = GridSpec::default();
        g.nics = vec![0];
        assert!(g.validate().is_err());
    }

    #[test]
    fn nics_axis_multiplies_cells() {
        let mut g = GridSpec::tiny();
        assert_eq!(g.cells().len(), 3);
        g.nics = vec![1, 4];
        let cells = g.cells();
        assert_eq!(cells.len(), 6);
        // nics-major over sizes, indexes contiguous
        assert!(cells[..3].iter().all(|c| c.nics == 1));
        assert!(cells[3..].iter().all(|c| c.nics == 4));
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn tiny_grid_is_small() {
        let g = GridSpec::tiny();
        g.validate().unwrap();
        assert!(g.cells().len() <= 4);
    }

    #[test]
    fn pattern_gen_parse() {
        assert_eq!(PatternGen::parse("uniform"), Some(PatternGen::Uniform));
        assert_eq!(PatternGen::parse("Random"), Some(PatternGen::Random));
        assert_eq!(PatternGen::parse("trace"), Some(PatternGen::Trace));
        assert_eq!(PatternGen::parse("bogus"), None);
        for g in PatternGen::ALL {
            assert_eq!(PatternGen::parse(g.label()), Some(g));
        }
    }

    #[test]
    fn trace_gen_rejected_on_grids() {
        let mut g = GridSpec::default();
        g.gens.push(PatternGen::Trace);
        assert!(g.validate().unwrap_err().contains("trace"));
    }
}
