//! The parallel sweep engine: fan the grid cells out over an in-tree
//! `std::thread` worker pool, evaluate every (cell × strategy) pair through
//! both the Table 6 closed-form models and the discrete-event simulator,
//! and collect results in a deterministic order.
//!
//! Determinism contract: given the same [`SweepConfig`] (including `seed`),
//! two runs produce byte-identical emitter output regardless of thread
//! count or scheduling — cells are seeded by index and results are sorted
//! back into grid order after the pool drains.

use super::grid::{CellSpec, GridSpec, PatternGen};
use super::report::{analyze, SweepReport};
use crate::comm::{build_schedule, dedup, Strategy};
use crate::model::{ModelInputs, StrategyModel};
use crate::params::MachineParams;
use crate::pattern::generators::{random_pattern, Scenario};
use crate::pattern::CommPattern;
use crate::sim;
use crate::topology::{machines, Machine};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Full sweep configuration: the grid plus run controls.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub grid: GridSpec,
    /// Strategies evaluated in every cell (default: all 8 of Table 5).
    pub strategies: Vec<Strategy>,
    /// Base seed; each cell derives its own deterministic sub-seed.
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Run the discrete-event simulator next to the models.
    pub sim: bool,
    /// Machine preset evaluated at every grid point (a
    /// [`machines::parse`] registry name; the node's GPU count still comes
    /// from the grid axis).
    pub machine: String,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            grid: GridSpec::default(),
            strategies: Strategy::all(),
            seed: 42,
            threads: 0,
            sim: true,
            machine: "lassen".into(),
        }
    }
}

/// One evaluated (cell × strategy) pair.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Index of the owning grid cell (groups the strategies of one cell).
    pub index: usize,
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
    pub strategy: Strategy,
    /// `strategy.label()`, precomputed for emitters.
    pub label: String,
    /// Table 6 model prediction [s].
    pub model_s: f64,
    /// Discrete-event simulated time [s] (None when `sim` is off).
    pub sim_s: Option<f64>,
    /// Relative model error `|model - sim| / sim` when both are present.
    pub model_err: Option<f64>,
}

/// The sweep outcome: per-cell results plus the derived report.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config: SweepConfig,
    pub cells: Vec<CellResult>,
    pub report: SweepReport,
    /// Threads the pool actually used.
    pub threads_used: usize,
    /// Wall-clock seconds for the evaluation (excluded from emitter output
    /// so seeded runs stay byte-identical).
    pub elapsed_s: f64,
}

/// Resolve the worker count: 0 = available parallelism, always clamped to
/// `[1, work_items]`.
pub fn effective_threads(requested: usize, work_items: usize) -> usize {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { auto } else { requested };
    t.clamp(1, work_items.max(1))
}

/// Deterministic per-cell sub-seed (splitmix-style index mixing).
fn cell_seed(base: u64, index: usize) -> u64 {
    let mut z = base ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// Run the sweep: validate, fan out, aggregate, analyze.
pub fn run_sweep(config: &SweepConfig) -> Result<SweepResult, String> {
    config.grid.validate()?;
    if config.strategies.is_empty() {
        return Err("no strategies selected".into());
    }
    let (arch, params) = machines::parse(&config.machine, 1)
        .ok_or_else(|| format!("unknown machine preset {:?}", config.machine))?;
    let cells = config.grid.cells();
    let t0 = Instant::now();
    let threads = effective_threads(config.threads, cells.len());

    let next = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, Vec<CellResult>)>> = Mutex::new(Vec::with_capacity(cells.len()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let result = eval_cell(config, &arch, &params, &cells[i]);
                collected.lock().unwrap().push((i, result));
            });
        }
    });

    let mut collected = collected.into_inner().unwrap();
    collected.sort_unstable_by_key(|&(i, _)| i);
    let cells_out: Vec<CellResult> = collected.into_iter().flat_map(|(_, r)| r).collect();
    let report = analyze(&cells_out);
    Ok(SweepResult {
        config: config.clone(),
        cells: cells_out,
        report,
        threads_used: threads,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Sweep a recorded workload trace instead of a generated grid: every
/// epoch becomes one cell (`index` = epoch index), evaluated for every
/// strategy through the Table 6 models on the epoch's *measured* pattern
/// statistics — and optionally the discrete-event simulator — on the
/// trace's own machine. The cell's `size` / `dest_nodes` labels are the
/// epoch's dominant regime coordinates (mean message size of the heaviest
/// node pair, node volume over pair volume), so the winner/crossover
/// report reads as a regime timeline of the recorded run.
///
/// Deterministic like [`run_sweep`]: epochs are fanned out over the pool
/// and re-sorted into trace order, so thread count never changes bits.
pub fn run_sweep_trace(
    trace: &crate::trace::Trace,
    strategies: &[Strategy],
    threads: usize,
    with_sim: bool,
) -> Result<SweepResult, String> {
    trace.validate()?;
    if strategies.is_empty() {
        return Err("no strategies selected".into());
    }
    let params = trace
        .params()
        .ok_or_else(|| format!("trace machine {:?} resolves to no registry parameters", trace.machine.name))?;
    let machine = &trace.machine;
    let t0 = Instant::now();
    let threads = effective_threads(threads, trace.epochs.len());
    // one stats pass serves the workers and the config echo below
    let epoch_stats = trace.epoch_stats();

    let next = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, Vec<CellResult>)>> = Mutex::new(Vec::with_capacity(trace.epochs.len()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= trace.epochs.len() {
                    break;
                }
                let result = eval_epoch(machine, &params, strategies, &trace.epochs[i], &epoch_stats[i], with_sim);
                collected.lock().unwrap().push((i, result));
            });
        }
    });
    let mut collected = collected.into_inner().unwrap();
    collected.sort_unstable_by_key(|&(i, _)| i);
    let cells_out: Vec<CellResult> = collected.into_iter().flat_map(|(_, r)| r).collect();
    let report = analyze(&cells_out);

    // Echo a synthetic config so the emitters can label the run; the grid
    // axes summarize the epochs (never validated or re-swept).
    let mut sizes: Vec<usize> = cells_out.iter().map(|c| c.size).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut dest_nodes: Vec<usize> = cells_out.iter().map(|c| c.dest_nodes).collect();
    dest_nodes.sort_unstable();
    dest_nodes.dedup();
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Trace],
            dest_nodes,
            gpus_per_node: vec![machine.gpus_per_node()],
            sizes,
            n_msgs: epoch_stats.iter().map(|s| s.total_internode_msgs).max().unwrap_or(0),
            dup_frac: 0.0,
        },
        strategies: strategies.to_vec(),
        seed: trace.seed,
        threads,
        sim: with_sim,
        machine: trace.machine.name.clone(),
    };
    Ok(SweepResult { config, cells: cells_out, report, threads_used: threads, elapsed_s: t0.elapsed().as_secs_f64() })
}

/// Evaluate one trace epoch against every strategy (the trace analogue of
/// [`eval_cell`], with measured stats instead of grid-derived inputs).
/// `stats` must be the epoch's own precomputed pattern statistics.
fn eval_epoch(
    machine: &Machine,
    params: &MachineParams,
    strategies: &[Strategy],
    epoch: &crate::trace::Epoch,
    stats: &crate::pattern::PatternStats,
    with_sim: bool,
) -> Vec<CellResult> {
    let sm = StrategyModel::new(machine, params);
    let dup = epoch.pattern.duplicate_fraction(machine);
    let inputs = ModelInputs {
        s_proc: stats.s_proc,
        s_node: stats.s_node,
        s_n2n: stats.s_n2n,
        m_p2n: stats.m_p2n,
        m_n2n: stats.m_n2n,
        m_std: stats.m_std,
        ppn: machine.cores_per_node(),
        dup_frac: dup,
    };
    let size = if stats.m_n2n > 0 { (stats.s_n2n / stats.m_n2n).max(1) } else { 1 };
    let dest_nodes = if stats.s_n2n > 0 { (stats.s_node / stats.s_n2n).max(1) } else { 1 };
    let mut out = Vec::with_capacity(strategies.len());
    for &strategy in strategies {
        let model_s = sm.time(strategy, &inputs);
        let sim_s = with_sim.then(|| {
            let schedule = build_schedule(strategy, machine, &epoch.pattern);
            sim::run(machine, params, &schedule, strategy.sim_ppn(machine)).total
        });
        let model_err = sim_s.and_then(|t| if t > 0.0 { Some((model_s - t).abs() / t) } else { None });
        out.push(CellResult {
            index: epoch.index,
            gen: PatternGen::Trace,
            dest_nodes,
            gpus_per_node: machine.gpus_per_node(),
            size,
            strategy,
            label: strategy.label(),
            model_s,
            sim_s,
            model_err,
        });
    }
    out
}

/// Evaluate one grid cell: build the pattern once, then model (and
/// optionally simulate) every strategy against it.
fn eval_cell(cfg: &SweepConfig, arch: &Machine, params: &MachineParams, cell: &CellSpec) -> Vec<CellResult> {
    let machine = cfg.grid.machine_for_arch(arch, cell.dest_nodes, cell.gpus_per_node);
    let sm = StrategyModel::new(&machine, params);
    // Model inputs use the full core count: only the Split models read
    // `ppn`, and Split enlists every core (matching `hetcomm model`).
    let ppn = machine.cores_per_node();

    let (inputs, pattern): (ModelInputs, Option<CommPattern>) = match cell.gen {
        PatternGen::Uniform => {
            let sc = Scenario {
                n_msgs: cfg.grid.n_msgs,
                msg_size: cell.size,
                n_dest: cell.dest_nodes,
                dup_frac: cfg.grid.dup_frac,
            };
            let pattern = cfg.sim.then(|| {
                let base = sc.materialize(&machine);
                if cfg.grid.dup_frac > 0.0 {
                    dedup::with_duplicate_fraction(&machine, &base, cfg.grid.dup_frac)
                } else {
                    base
                }
            });
            (sc.inputs(&machine, ppn), pattern)
        }
        PatternGen::Random => {
            let mut rng = Rng::new(cell_seed(cfg.seed, cell.index));
            let pattern = random_pattern(&machine, &mut rng, cfg.grid.n_msgs, cell.size, cfg.grid.dup_frac);
            let dup = pattern.duplicate_fraction(&machine);
            (pattern.model_inputs(&machine, ppn, dup), cfg.sim.then_some(pattern))
        }
        PatternGen::Trace => unreachable!("GridSpec::validate rejects trace generators on grids"),
    };

    let mut out = Vec::with_capacity(cfg.strategies.len());
    for &strategy in &cfg.strategies {
        let model_s = sm.time(strategy, &inputs);
        let sim_s = pattern.as_ref().map(|p| {
            let schedule = build_schedule(strategy, &machine, p);
            sim::run(&machine, params, &schedule, strategy.sim_ppn(&machine)).total
        });
        let model_err = sim_s.and_then(|t| if t > 0.0 { Some((model_s - t).abs() / t) } else { None });
        out.push(CellResult {
            index: cell.index,
            gen: cell.gen,
            dest_nodes: cell.dest_nodes,
            gpus_per_node: cell.gpus_per_node,
            size: cell.size,
            strategy,
            label: strategy.label(),
            model_s,
            sim_s,
            model_err,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};

    fn small_config(threads: usize) -> SweepConfig {
        SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform, PatternGen::Random],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                sizes: vec![256, 4096],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 11,
            threads,
            sim: true,
            ..Default::default()
        }
    }

    fn cmp_cells(a: &[CellResult], b: &[CellResult]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.label, y.label);
            assert_eq!(x.model_s.to_bits(), y.model_s.to_bits(), "{} model", x.label);
            assert_eq!(x.sim_s.map(f64::to_bits), y.sim_s.map(f64::to_bits), "{} sim", x.label);
        }
    }

    #[test]
    fn results_cover_grid_times_strategies() {
        let cfg = small_config(2);
        let r = run_sweep(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len() * cfg.strategies.len());
        assert!(r.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        assert!(r.cells.iter().all(|c| c.sim_s.is_some()));
        // cells come back in grid order, strategies in Table 5 order
        for w in r.cells.windows(2) {
            assert!(w[0].index <= w[1].index);
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let r1 = run_sweep(&small_config(1)).unwrap();
        let r4 = run_sweep(&small_config(4)).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
    }

    #[test]
    fn same_seed_same_bits_different_seed_differs() {
        let r1 = run_sweep(&small_config(2)).unwrap();
        let r2 = run_sweep(&small_config(2)).unwrap();
        cmp_cells(&r1.cells, &r2.cells);
        let mut cfg = small_config(2);
        cfg.seed = 12;
        let r3 = run_sweep(&cfg).unwrap();
        // random-generator sim times must move with the seed
        let sim_of = |r: &SweepResult| -> Vec<u64> {
            r.cells.iter().filter(|c| c.gen == PatternGen::Random).filter_map(|c| c.sim_s.map(f64::to_bits)).collect()
        };
        assert_ne!(sim_of(&r1), sim_of(&r3), "seed must drive the random generator");
    }

    #[test]
    fn model_only_skips_sim() {
        let mut cfg = small_config(2);
        cfg.sim = false;
        let r = run_sweep(&cfg).unwrap();
        assert!(r.cells.iter().all(|c| c.sim_s.is_none() && c.model_err.is_none()));
    }

    #[test]
    fn strategy_filter_respected() {
        let mut cfg = small_config(1);
        cfg.strategies = vec![Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap()];
        let r = run_sweep(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len());
        assert!(r.cells.iter().all(|c| c.strategy.kind == StrategyKind::SplitMd));
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = small_config(1);
        cfg.strategies.clear();
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.sizes.clear();
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.machine = "bogus".into();
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn machine_preset_changes_model_times() {
        let mut base = small_config(1);
        base.sim = false;
        let lassen = run_sweep(&base).unwrap();
        let mut frontier = small_config(1);
        frontier.sim = false;
        frontier.machine = "frontier-like".into();
        let frontier = run_sweep(&frontier).unwrap();
        assert_eq!(lassen.cells.len(), frontier.cells.len());
        assert!(
            lassen.cells.iter().zip(&frontier.cells).any(|(a, b)| a.model_s.to_bits() != b.model_s.to_bits()),
            "the machine preset must reach the models"
        );
        // aliases resolve through the same registry
        let mut alias = small_config(1);
        alias.sim = false;
        alias.machine = "Frontier".into();
        let alias = run_sweep(&alias).unwrap();
        for (a, b) in frontier.cells.iter().zip(&alias.cells) {
            assert_eq!(a.model_s.to_bits(), b.model_s.to_bits());
        }
    }

    #[test]
    fn trace_sweep_covers_epochs_and_is_thread_invariant() {
        use crate::trace::scenarios::{synthesize, TraceScenario};
        let trace = synthesize(TraceScenario::HaloBurst, "lassen", 4, 1, 9).unwrap();
        let r1 = run_sweep_trace(&trace, &Strategy::all(), 1, false).unwrap();
        assert_eq!(r1.cells.len(), 4 * Strategy::all().len());
        assert!(r1.cells.iter().all(|c| c.gen == PatternGen::Trace));
        assert!(r1.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        // epoch regime labels: calm epochs are 2 KiB, burst epochs 64 KiB
        assert_eq!(r1.cells[0].size, 2048);
        assert_eq!(r1.cells[Strategy::all().len()].size, 1 << 16);
        // the winner timeline flips between calm and burst regimes
        let w = &r1.report.winners;
        assert_eq!(w.len(), 4);
        assert_ne!(w[0].winner, w[1].winner, "calm and burst regimes have different winners");
        assert_eq!(w[0].winner, w[2].winner);
        assert!(!r1.report.crossovers.is_empty());
        let r4 = run_sweep_trace(&trace, &Strategy::all(), 4, false).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
        // config echo labels the run as a trace sweep
        assert_eq!(r1.config.grid.gens, vec![PatternGen::Trace]);
        assert_eq!(r1.config.machine, "lassen");
        // empty strategy lists are rejected like grid sweeps
        assert!(run_sweep_trace(&trace, &[], 1, false).is_err());
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(3, 100), 3);
        assert_eq!(effective_threads(64, 2), 2);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(0, 0), 1);
    }

    #[test]
    fn cell_seed_spreads() {
        let s: std::collections::BTreeSet<u64> = (0..100).map(|i| cell_seed(42, i)).collect();
        assert_eq!(s.len(), 100);
    }
}
