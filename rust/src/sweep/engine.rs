//! The parallel sweep engine: fan the grid cells out over an in-tree
//! `std::thread` worker pool ([`crate::util::pool`]), evaluate every
//! (cell × strategy) pair through both the Table 6 closed-form models and
//! the discrete-event simulator, and collect results in a deterministic
//! order.
//!
//! Determinism contract: given the same [`SweepConfig`] (including `seed`),
//! two runs produce byte-identical emitter output regardless of thread
//! count or scheduling — cells are seeded by index and results land in a
//! pre-sized per-cell slot vector in grid order.
//!
//! Hot-path shape (see docs/PERFORMANCE.md): per cell the pattern is
//! materialized and lowered **once** ([`crate::sim::CompiledPattern`]);
//! each strategy builds its schedule from the lowered pattern, compiles it
//! into the worker's reused [`sim::Scratch`] arrays, and executes it
//! allocation-free. [`ExecMode::Reference`] retains the pre-compilation
//! per-strategy path (rebuild + hash-map executor) as the equivalence
//! oracle and the perf harness's naive baseline.
//!
//! Three composable, winner-preserving scale levers sit on top (all
//! default-off; defaults reproduce the legacy output byte for byte):
//!
//! - **Branch-and-bound pruning** (`prune`): per cell, the
//!   [`crate::model::BoundModel`] intervals rank strategies; the one with
//!   the least upper bound is simulated first and any strategy whose sound
//!   lower bound exceeds the best simulated time so far skips the
//!   simulator (`sim_pruned`). Model times are still computed for every
//!   strategy — winners, crossovers and regimes are model-derived and the
//!   simulated winner is never prunable, so reports are preserved.
//! - **Pattern reuse** (`reuse_patterns`): grid lines that differ only in
//!   message size share one unit-size lowering, rescaled exactly per cell
//!   ([`CompiledPattern::rescaled`]) instead of re-lowered.
//! - **Adaptive refinement** (`refine`): evaluate a coarse size lattice
//!   first and recursively subdivide only between neighbors whose model
//!   winners disagree — emitted cells keep their full-grid indices (and
//!   hence their seeds), so they are bit-identical to the exhaustive run.

use super::grid::{CellSpec, GridSpec, PatternGen};
use super::report::{analyze, SweepReport};
use crate::comm::{build_schedule, build_schedule_from, dedup, Strategy};
use crate::fault::FaultSpec;
use crate::model::{BoundModel, ModelInputs, StrategyModel};
use crate::params::{CompiledParams, MachineParams};
use crate::pattern::generators::{random_pattern, Scenario};
use crate::pattern::CommPattern;
use crate::sim::{self, CompiledPattern};
use crate::topology::{machines, Machine};
use crate::util::pool;
use crate::util::rng::Rng;
use std::time::Instant;

pub use crate::util::pool::effective_threads;
pub use crate::util::rng::index_seed as cell_seed;

/// Which executor evaluates the simulator leg of a sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Production path: one pattern lowering per cell, compiled schedules,
    /// zero-allocation executor with per-worker scratch reuse.
    Compiled,
    /// Retained naive path: full per-strategy schedule rebuild from the
    /// raw pattern plus the verbatim hash-map reference executor.
    /// Bit-identical results; used by golden-output tests and
    /// `hetcomm perf`'s baseline mode (a rebuild baseline, not a
    /// cycle-exact replica of the historical builders' cost).
    Reference,
}

/// Full sweep configuration: the grid plus run controls.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub grid: GridSpec,
    /// Strategies evaluated in every cell (default: all 8 of Table 5).
    pub strategies: Vec<Strategy>,
    /// Base seed; each cell derives its own deterministic sub-seed.
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Run the discrete-event simulator next to the models.
    pub sim: bool,
    /// Machine preset evaluated at every grid point (a
    /// [`machines::parse`] registry name; the node's GPU count still comes
    /// from the grid axis).
    pub machine: String,
    /// Branch-and-bound pruning: skip simulating strategies whose
    /// [`BoundModel`] lower bound exceeds the cell's best simulated time.
    /// Winner-preserving (model times are always computed; the simulated
    /// winner's bound can never exceed its own time). Default off.
    pub prune: bool,
    /// Reuse one unit-size pattern lowering across the size axis of each
    /// uniform, duplicate-free grid line (exact integer rescale instead of
    /// re-lowering). Bit-identical results; default off.
    pub reuse_patterns: bool,
    /// Adaptive grid refinement depth: 0 = exhaustive (default);
    /// `d > 0` starts on every `2^d`-th size per line and subdivides only
    /// between neighbors whose model winners disagree.
    pub refine: usize,
    /// Fault schedule applied fleet-wide ([`crate::fault`]): a sweep has no
    /// epochs, so the spec's *terminal* state degrades every grid machine
    /// (failed rails removed, slowdowns folded into the bands) and seeds a
    /// per-cell congestion pre-charge — the grid answers "what does the
    /// strategy space look like on the degraded fleet". `None` (default) or
    /// an all-identity spec reproduces the healthy output byte for byte.
    pub faults: Option<FaultSpec>,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            grid: GridSpec::default(),
            strategies: Strategy::all(),
            seed: 42,
            threads: 0,
            sim: true,
            machine: "lassen".into(),
            prune: false,
            reuse_patterns: false,
            refine: 0,
            faults: None,
        }
    }
}

/// One evaluated (cell × strategy) pair.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Index of the owning grid cell (groups the strategies of one cell).
    pub index: usize,
    pub gen: PatternGen,
    pub dest_nodes: usize,
    pub gpus_per_node: usize,
    /// NIC rails per node at this grid point (1 on legacy shapes).
    pub nics: usize,
    pub size: usize,
    pub strategy: Strategy,
    /// `strategy.label()`, precomputed for emitters.
    pub label: &'static str,
    /// Table 6 model prediction [s].
    pub model_s: f64,
    /// Discrete-event simulated time [s] (None when `sim` is off).
    pub sim_s: Option<f64>,
    /// Relative model error `|model - sim| / sim` when both are present.
    pub model_err: Option<f64>,
    /// True when branch-and-bound pruning skipped this strategy's
    /// simulation (`sim_s` is then None even though `sim` was on).
    pub sim_pruned: bool,
}

/// The sweep outcome: per-cell results plus the derived report.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config: SweepConfig,
    pub cells: Vec<CellResult>,
    pub report: SweepReport,
    /// Threads the pool actually used.
    pub threads_used: usize,
    /// Wall-clock seconds for the evaluation (excluded from emitter output
    /// so seeded runs stay byte-identical).
    pub elapsed_s: f64,
}

/// Run the sweep: validate, fan out, aggregate, analyze.
pub fn run_sweep(config: &SweepConfig) -> Result<SweepResult, String> {
    run_sweep_mode(config, ExecMode::Compiled)
}

/// [`run_sweep`] with an explicit executor mode (golden-output tests and
/// the perf harness pass [`ExecMode::Reference`]).
pub fn run_sweep_mode(config: &SweepConfig, mode: ExecMode) -> Result<SweepResult, String> {
    config.grid.validate()?;
    if config.strategies.is_empty() {
        return Err("no strategies selected".into());
    }
    let (arch, params) = machines::parse(&config.machine, 1)?;
    // Shape-pinned presets (frontier-4nic) carry their own NIC count: the
    // untouched default axis resolves to it, anything else conflicts.
    let mut config = config.clone();
    if machines::shape_pinned(&config.machine) {
        let pinned = arch.nics_per_node();
        if config.grid.nics == [1] {
            config.grid.nics = vec![pinned];
        } else if config.grid.nics != [pinned] {
            return Err(format!(
                "--nics conflicts with machine {:?}, whose shape pins {pinned} NICs/node",
                config.machine
            ));
        }
    }
    // Fault schedule: an identity spec is dropped outright (the config echo
    // and every cell stay byte-identical to a no-fault run); a real one is
    // validated against the *smallest* rail count on the grid so the
    // per-cell degradation below can never fail mid-pool.
    if config.faults.as_ref().is_some_and(|s| s.is_identity()) {
        config.faults = None;
    }
    if let Some(spec) = &config.faults {
        let min_rails = config.grid.nics.iter().copied().min().unwrap_or(1);
        spec.validate(min_rails).map_err(|e| format!("fault spec: {e}"))?;
    }
    let config = &config;
    let compiled_params = params.compile();
    let cells = config.grid.cells();
    let t0 = Instant::now();
    let threads = effective_threads(config.threads, cells.len());

    let cells_out = if config.refine > 0 {
        run_refined(config, &arch, &params, &compiled_params, &cells, mode, threads)
    } else {
        // Work units are grid *lines* (consecutive cells differing only in
        // size) when pattern reuse can share a lowering, single cells
        // otherwise — identical bits either way, cells() order preserved.
        let chunk = if config.reuse_patterns { line_len(&config.grid) } else { 1 };
        let lines: Vec<&[CellSpec]> = cells.chunks(chunk).collect();
        let results = pool::map_with(lines.len(), threads, sim::Scratch::new, |scratch, i| {
            eval_line(config, &arch, &params, &compiled_params, lines[i], mode, scratch)
        });
        results.into_iter().flatten().collect()
    };
    let report = analyze(&cells_out);
    Ok(SweepResult {
        config: config.clone(),
        cells: cells_out,
        report,
        threads_used: threads,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Length of one grid line: the run of consecutive cells sharing
/// (gen, dest, gpn, nics) and differing only in message size.
/// [`GridSpec::cells`] iterates sizes innermost, so lines tile the cell
/// vector exactly.
fn line_len(grid: &GridSpec) -> usize {
    let mut sizes = grid.sizes.clone();
    sizes.sort_unstable();
    sizes.dedup();
    sizes.len().max(1)
}

/// One rectangular plane of a flattened grid for [`refine_2d`]: `rows`
/// lattice rows of `cols` consecutive cells each, rows `row_stride` cells
/// apart, starting at `origin`. Degenerate planes (`rows == 1`) reduce the
/// driver to the size-axis-only refinement of PR 8.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PlaneGeom {
    pub origin: usize,
    pub rows: usize,
    pub row_stride: usize,
    pub cols: usize,
}

impl PlaneGeom {
    fn idx(&self, r: usize, c: usize) -> usize {
        self.origin + r * self.row_stride + c
    }
}

/// Joint 2-D boundary tracing shared by the point-to-point and collective
/// sweeps: start on a coarse `2^depth`-strided lattice of every plane
/// (both axes, endpoints always included), then recursively subdivide any
/// rectangle whose 4 corner model winners disagree, splitting each axis
/// with a gap > 1 at its midpoint. `eval` receives each wave of
/// not-yet-evaluated cell indices (sorted ascending); `winner` reads one
/// evaluated cell's model winner back out of `state`. Degenerate axes
/// (a single lattice pair) keep their collapsed coordinate, so single-row
/// planes behave exactly like 1-D size-axis refinement.
pub(crate) fn refine_2d<S, W: PartialEq>(
    planes: &[PlaneGeom],
    depth: usize,
    state: &mut S,
    mut eval: impl FnMut(&mut S, &[usize]),
    winner: impl Fn(&S, usize) -> W,
) {
    let stride = 1usize << depth.min(16);
    // lattice coordinates along one axis: every stride-th point plus the end
    let lattice = |n: usize| -> Vec<(usize, usize)> {
        let mut v: Vec<usize> = (0..n).step_by(stride).collect();
        if *v.last().expect("non-empty axis") != n - 1 {
            v.push(n - 1);
        }
        if v.len() == 1 {
            vec![(v[0], v[0])]
        } else {
            v.windows(2).map(|w| (w[0], w[1])).collect()
        }
    };

    // rectangles pending a corner check: (plane, r0, r1, c0, c1)
    let mut rects: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
    let mut wave: Vec<usize> = Vec::new();
    let mut evaluated: Vec<bool> = Vec::new();
    for (pi, p) in planes.iter().enumerate() {
        for &(r0, r1) in &lattice(p.rows) {
            for &(c0, c1) in &lattice(p.cols) {
                rects.push((pi, r0, r1, c0, c1));
            }
        }
    }
    loop {
        wave.extend(rects.iter().flat_map(|&(pi, r0, r1, c0, c1)| {
            let p = &planes[pi];
            [p.idx(r0, c0), p.idx(r0, c1), p.idx(r1, c0), p.idx(r1, c1)]
        }));
        wave.sort_unstable();
        wave.dedup();
        wave.retain(|&i| {
            if evaluated.len() <= i {
                evaluated.resize(i + 1, false);
            }
            !evaluated[i]
        });
        if !wave.is_empty() {
            eval(state, &wave);
            for &i in &wave {
                evaluated[i] = true;
            }
            wave.clear();
        }

        // subdivide every rectangle whose corner winners disagree and which
        // still has an axis gap to split; agreeing or unsplittable
        // rectangles are dropped
        let mut next = Vec::new();
        for &(pi, r0, r1, c0, c1) in &rects {
            let p = &planes[pi];
            let w0 = winner(state, p.idx(r0, c0));
            if winner(state, p.idx(r0, c1)) == w0
                && winner(state, p.idx(r1, c0)) == w0
                && winner(state, p.idx(r1, c1)) == w0
            {
                continue;
            }
            let (rsplit, csplit) = (r1 - r0 > 1, c1 - c0 > 1);
            if !rsplit && !csplit {
                continue;
            }
            let rs = if rsplit { vec![r0, (r0 + r1) / 2, r1] } else { vec![r0, r1] };
            let cs = if csplit { vec![c0, (c0 + c1) / 2, c1] } else { vec![c0, c1] };
            for rw in rs.windows(2) {
                for cw in cs.windows(2) {
                    next.push((pi, rw[0], rw[1], cw[0], cw[1]));
                }
            }
        }
        rects = next;
        if rects.is_empty() {
            break;
        }
    }
}

/// Adaptive refinement: evaluate a coarse lattice over each plane's joint
/// (destination-nodes × size) axes, then repeatedly subdivide rectangles
/// whose corner model winners disagree ([`refine_2d`]). Every evaluated
/// cell keeps its exhaustive-grid index (hence its seed), so coinciding
/// cells are bit-identical to the full sweep; skipped cells are simply
/// absent from the output.
#[allow(clippy::too_many_arguments)]
fn run_refined(
    config: &SweepConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cells: &[CellSpec],
    mode: ExecMode,
    threads: usize,
) -> Vec<CellResult> {
    let grid = &config.grid;
    let n_sizes = line_len(grid);
    let (n_dest, n_gpn, n_nics) = (grid.dest_nodes.len(), grid.gpus_per_node.len(), grid.nics.len());
    // cells() iterates gens -> dest -> gpn -> nics -> sizes
    let row_stride = n_gpn * n_nics * n_sizes;
    let mut planes = Vec::with_capacity(grid.gens.len() * n_gpn * n_nics);
    for gi in 0..grid.gens.len() {
        for g in 0..n_gpn {
            for k in 0..n_nics {
                planes.push(PlaneGeom {
                    origin: gi * n_dest * row_stride + (g * n_nics + k) * n_sizes,
                    rows: n_dest,
                    row_stride,
                    cols: n_sizes,
                });
            }
        }
    }

    let mut slots: Vec<Option<Vec<CellResult>>> = vec![None; cells.len()];
    refine_2d(
        &planes,
        config.refine,
        &mut slots,
        |slots, wave| {
            // group the wave into per-line runs so pattern reuse still applies
            let mut runs: Vec<&[usize]> = Vec::new();
            let mut start = 0;
            for i in 1..=wave.len() {
                if i == wave.len() || wave[i] / n_sizes != wave[start] / n_sizes {
                    runs.push(&wave[start..i]);
                    start = i;
                }
            }
            let eff = effective_threads(threads, runs.len());
            let results = pool::map_with(runs.len(), eff, sim::Scratch::new, |scratch, r| {
                let specs: Vec<CellSpec> = runs[r].iter().map(|&i| cells[i]).collect();
                eval_line(config, arch, params, compiled_params, &specs, mode, scratch)
            });
            let per_cell = config.strategies.len();
            for (run, flat) in runs.iter().zip(results) {
                for (&i, group) in run.iter().zip(flat.chunks(per_cell)) {
                    slots[i] = Some(group.to_vec());
                }
            }
        },
        |slots, i| {
            let group = slots[i].as_ref().expect("evaluated");
            // first-minimal-wins, matching report::analyze exactly
            group.iter().min_by(|a, b| a.model_s.partial_cmp(&b.model_s).unwrap()).expect("non-empty").label
        },
    );
    slots.into_iter().flatten().flatten().collect()
}

/// Evaluate one grid line (cells sharing everything but size). When the
/// line is uniform, duplicate-free and simulated in compiled mode with
/// `reuse_patterns` on, the pattern is lowered once at unit size and
/// rescaled exactly per cell; otherwise each cell takes the standard
/// [`eval_cell`] path. Bit-identical either way.
fn eval_line(
    cfg: &SweepConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cells: &[CellSpec],
    mode: ExecMode,
    scratch: &mut sim::Scratch,
) -> Vec<CellResult> {
    let reusable = cfg.reuse_patterns
        && cfg.sim
        && mode == ExecMode::Compiled
        && cells.len() > 1
        && cells[0].gen == PatternGen::Uniform
        && cfg.grid.dup_frac == 0.0;
    if !reusable {
        return cells
            .iter()
            .flat_map(|cell| eval_cell(cfg, arch, params, compiled_params, cell, mode, scratch))
            .collect();
    }

    let first = &cells[0];
    let mut machine = cfg.grid.machine_for_arch(arch, first.dest_nodes, first.gpus_per_node, first.nics);
    // fault schedule: the line's machine and bands degrade before lowering
    let fp = faulted_system(cfg, &mut machine, params);
    let (params, compiled_params) = match &fp {
        Some((dp, dcp)) => (dp, dcp),
        None => (params, compiled_params),
    };
    let ppn = machine.cores_per_node();
    let unit = Scenario { n_msgs: cfg.grid.n_msgs, msg_size: 1, n_dest: first.dest_nodes, dup_frac: 0.0 };
    let unit_pattern = unit.materialize(&machine);
    let unit_lowered = CompiledPattern::lower(&machine, &unit_pattern);

    let mut out = Vec::with_capacity(cells.len() * cfg.strategies.len());
    for cell in cells {
        debug_assert!(
            cell.gen == first.gen
                && cell.dest_nodes == first.dest_nodes
                && cell.gpus_per_node == first.gpus_per_node
                && cell.nics == first.nics,
            "a line varies only in size"
        );
        let sc = Scenario { msg_size: cell.size, ..unit };
        let pattern = sc.materialize(&machine);
        let lowered = unit_lowered.rescaled(&pattern, cell.size);
        let inputs = sc.inputs(&machine, ppn);
        out.extend(eval_strategies(
            cfg,
            &machine,
            params,
            compiled_params,
            cell,
            mode,
            scratch,
            &inputs,
            Some(&pattern),
            Some(&lowered),
        ));
    }
    out
}

/// Sweep a recorded workload trace instead of a generated grid: every
/// epoch becomes one cell (`index` = epoch index), evaluated for every
/// strategy through the Table 6 models on the epoch's *measured* pattern
/// statistics — and optionally the discrete-event simulator — on the
/// trace's own machine. The cell's `size` / `dest_nodes` labels are the
/// epoch's dominant regime coordinates (mean message size of the heaviest
/// node pair, node volume over pair volume), so the winner/crossover
/// report reads as a regime timeline of the recorded run.
///
/// Deterministic like [`run_sweep`]: epochs are fanned out over the pool
/// into pre-sized trace-order slots, so thread count never changes bits.
pub fn run_sweep_trace(
    trace: &crate::trace::Trace,
    strategies: &[Strategy],
    threads: usize,
    with_sim: bool,
) -> Result<SweepResult, String> {
    run_sweep_trace_mode(trace, strategies, threads, with_sim, ExecMode::Compiled)
}

/// [`run_sweep_trace`] with an explicit executor mode.
pub fn run_sweep_trace_mode(
    trace: &crate::trace::Trace,
    strategies: &[Strategy],
    threads: usize,
    with_sim: bool,
    mode: ExecMode,
) -> Result<SweepResult, String> {
    trace.validate()?;
    if strategies.is_empty() {
        return Err("no strategies selected".into());
    }
    let params = trace
        .params()
        .ok_or_else(|| format!("trace machine {:?} resolves to no registry parameters", trace.machine.name))?;
    let compiled_params = params.compile();
    let trace_nics = trace.machine.nics_per_node();
    let machine = &trace.machine;
    let t0 = Instant::now();
    let threads = effective_threads(threads, trace.epochs.len());
    // one stats pass serves the workers and the config echo below
    let epoch_stats = trace.epoch_stats();

    let results = pool::map_with(trace.epochs.len(), threads, sim::Scratch::new, |scratch, i| {
        let (epoch, stats) = (&trace.epochs[i], &epoch_stats[i]);
        eval_epoch(machine, &params, &compiled_params, strategies, epoch, stats, with_sim, mode, scratch)
    });
    let cells_out: Vec<CellResult> = results.into_iter().flatten().collect();
    let report = analyze(&cells_out);

    // Echo a synthetic config so the emitters can label the run; the grid
    // axes summarize the epochs (never validated or re-swept).
    let mut sizes: Vec<usize> = cells_out.iter().map(|c| c.size).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut dest_nodes: Vec<usize> = cells_out.iter().map(|c| c.dest_nodes).collect();
    dest_nodes.sort_unstable();
    dest_nodes.dedup();
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Trace],
            dest_nodes,
            gpus_per_node: vec![machine.gpus_per_node()],
            nics: vec![trace_nics],
            sizes,
            n_msgs: epoch_stats.iter().map(|s| s.total_internode_msgs).max().unwrap_or(0),
            dup_frac: 0.0,
        },
        strategies: strategies.to_vec(),
        seed: trace.seed,
        threads,
        sim: with_sim,
        machine: trace.machine.name.clone(),
        prune: false,
        reuse_patterns: false,
        refine: 0,
        faults: None,
    };
    Ok(SweepResult { config, cells: cells_out, report, threads_used: threads, elapsed_s: t0.elapsed().as_secs_f64() })
}

/// Degrade one grid machine in place under the sweep's fault schedule and
/// return the degraded parameters (raw + compiled); `None` when the config
/// carries no schedule. Infallible by construction: [`run_sweep_mode`]
/// validated the spec against the smallest rail count on the grid.
fn faulted_system(
    cfg: &SweepConfig,
    machine: &mut Machine,
    params: &MachineParams,
) -> Option<(MachineParams, CompiledParams)> {
    let spec = cfg.faults.as_ref()?;
    let (dm, dp) = spec
        .terminal_state()
        .degrade(machine, params)
        .expect("fault spec validated by run_sweep_mode");
    *machine = dm;
    let dcp = dp.compile();
    Some((dp, dcp))
}

/// Simulate one (schedule-source, strategy) pair under the selected
/// executor mode. `Compiled` builds from the once-per-cell lowered pattern
/// and runs the flat executor against the worker scratch; `Reference`
/// rebuilds from the raw pattern (a full per-strategy re-lowering — a
/// strict naive-rebuild baseline, not a cycle-exact replica of the
/// historical builders' cost) and runs the retained hash-map executor.
/// Outputs are bit-identical either way — including under a congestion
/// `pre`-charge, which both executors consume identically.
#[allow(clippy::too_many_arguments)]
fn sim_strategy(
    mode: ExecMode,
    machine: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    strategy: Strategy,
    pattern: &CommPattern,
    lowered: Option<&CompiledPattern>,
    pre: Option<&[f64]>,
    scratch: &mut sim::Scratch,
) -> f64 {
    let ppn = strategy.sim_ppn(machine);
    match mode {
        ExecMode::Compiled => {
            let lowered = lowered.expect("compiled mode lowers once per cell");
            let schedule = build_schedule_from(strategy, machine, lowered);
            scratch.run_total_with(machine, compiled_params, &schedule, ppn, pre)
        }
        ExecMode::Reference => {
            let schedule = build_schedule(strategy, machine, pattern);
            sim::run_reference_with(machine, params, &schedule, ppn, pre).total
        }
    }
}

/// Evaluate one trace epoch against every strategy (the trace analogue of
/// [`eval_cell`], with measured stats instead of grid-derived inputs).
/// `stats` must be the epoch's own precomputed pattern statistics.
#[allow(clippy::too_many_arguments)]
fn eval_epoch(
    machine: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    strategies: &[Strategy],
    epoch: &crate::trace::Epoch,
    stats: &crate::pattern::PatternStats,
    with_sim: bool,
    mode: ExecMode,
    scratch: &mut sim::Scratch,
) -> Vec<CellResult> {
    let sm = StrategyModel::new(machine, params);
    let dup = epoch.pattern.duplicate_fraction(machine);
    let inputs = ModelInputs {
        s_proc: stats.s_proc,
        s_node: stats.s_node,
        s_n2n: stats.s_n2n,
        m_p2n: stats.m_p2n,
        m_n2n: stats.m_n2n,
        m_std: stats.m_std,
        ppn: machine.cores_per_node(),
        nics: machine.nics_per_node(),
        dup_frac: dup,
    };
    let size = if stats.m_n2n > 0 { (stats.s_n2n / stats.m_n2n).max(1) } else { 1 };
    let dest_nodes = if stats.s_n2n > 0 { (stats.s_node / stats.s_n2n).max(1) } else { 1 };
    // lower once per epoch; reference mode pays its own per-strategy lowering
    let lowered = (with_sim && mode == ExecMode::Compiled).then(|| CompiledPattern::lower(machine, &epoch.pattern));
    let mut out = Vec::with_capacity(strategies.len());
    for &strategy in strategies {
        let model_s = sm.time(strategy, &inputs);
        let sim_s = with_sim.then(|| {
            sim_strategy(
                mode,
                machine,
                params,
                compiled_params,
                strategy,
                &epoch.pattern,
                lowered.as_ref(),
                None,
                scratch,
            )
        });
        let model_err = sim_s.and_then(|t| if t > 0.0 { Some((model_s - t).abs() / t) } else { None });
        out.push(CellResult {
            index: epoch.index,
            gen: PatternGen::Trace,
            dest_nodes,
            gpus_per_node: machine.gpus_per_node(),
            nics: machine.nics_per_node(),
            size,
            strategy,
            label: strategy.label(),
            model_s,
            sim_s,
            model_err,
            sim_pruned: false,
        });
    }
    out
}

/// Evaluate one grid cell: build and lower the pattern once, then model
/// (and optionally simulate) every strategy against it. `pub(crate)` so the
/// perf harness ([`crate::bench::perf`]) measures exactly the production
/// cell evaluation.
pub(crate) fn eval_cell(
    cfg: &SweepConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cell: &CellSpec,
    mode: ExecMode,
    scratch: &mut sim::Scratch,
) -> Vec<CellResult> {
    let mut machine = cfg.grid.machine_for_arch(arch, cell.dest_nodes, cell.gpus_per_node, cell.nics);
    // fault schedule: swap in the degraded system before anything reads it
    // (models, pattern lowering and simulator all see the survivors)
    let fp = faulted_system(cfg, &mut machine, params);
    let (params, compiled_params) = match &fp {
        Some((dp, dcp)) => (dp, dcp),
        None => (params, compiled_params),
    };
    // Model inputs use the full core count: only the Split models read
    // `ppn`, and Split enlists every core (matching `hetcomm model`).
    let ppn = machine.cores_per_node();

    let (inputs, pattern): (ModelInputs, Option<CommPattern>) = match cell.gen {
        PatternGen::Uniform => {
            let sc = Scenario {
                n_msgs: cfg.grid.n_msgs,
                msg_size: cell.size,
                n_dest: cell.dest_nodes,
                dup_frac: cfg.grid.dup_frac,
            };
            let pattern = cfg.sim.then(|| {
                let base = sc.materialize(&machine);
                if cfg.grid.dup_frac > 0.0 {
                    dedup::with_duplicate_fraction(&machine, &base, cfg.grid.dup_frac)
                } else {
                    base
                }
            });
            (sc.inputs(&machine, ppn), pattern)
        }
        PatternGen::Random => {
            let mut rng = Rng::new(cell_seed(cfg.seed, cell.index));
            let pattern = random_pattern(&machine, &mut rng, cfg.grid.n_msgs, cell.size, cfg.grid.dup_frac);
            let dup = pattern.duplicate_fraction(&machine);
            (pattern.model_inputs(&machine, ppn, dup), cfg.sim.then_some(pattern))
        }
        PatternGen::Trace => unreachable!("GridSpec::validate rejects trace generators on grids"),
    };

    // Lower once per cell: grouping, dedup and locality resolution are
    // shared by every strategy's schedule build. Reference mode skips this
    // and pays a full re-lowering per strategy instead.
    let lowered = match mode {
        ExecMode::Compiled => pattern.as_ref().map(|p| CompiledPattern::lower(&machine, p)),
        ExecMode::Reference => None,
    };
    eval_strategies(
        cfg,
        &machine,
        params,
        compiled_params,
        cell,
        mode,
        scratch,
        &inputs,
        pattern.as_ref(),
        lowered.as_ref(),
    )
}

/// Model every configured strategy for one cell and simulate the survivors.
/// Without `prune`, every strategy simulates (legacy behavior). With it,
/// the [`BoundModel`] seeds the search at the least upper bound, then
/// visits the rest in ascending-lower-bound order, skipping any strategy
/// whose sound lower bound exceeds the best simulated time so far. Model
/// times are computed for all strategies regardless, and results come back
/// in configuration order.
#[allow(clippy::too_many_arguments)]
fn eval_strategies(
    cfg: &SweepConfig,
    machine: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cell: &CellSpec,
    mode: ExecMode,
    scratch: &mut sim::Scratch,
    inputs: &ModelInputs,
    pattern: Option<&CommPattern>,
    lowered: Option<&CompiledPattern>,
) -> Vec<CellResult> {
    let sm = StrategyModel::new(machine, params);
    let n = cfg.strategies.len();
    let model_s: Vec<f64> = cfg.strategies.iter().map(|&s| sm.time(s, inputs)).collect();
    let mut sim_s: Vec<Option<f64>> = vec![None; n];
    let mut pruned = vec![false; n];

    if let Some(pattern) = pattern {
        // background congestion: seeded per-cell occupancy pre-charges the
        // NIC timelines of every simulated strategy in this cell alike
        let pre = cfg.faults.as_ref().and_then(|spec| {
            spec.terminal_state().precharge(spec.seed, cell.index, machine.num_nodes, machine.nics_per_node())
        });
        let run = |idx: usize, scratch: &mut sim::Scratch| {
            sim_strategy(
                mode,
                machine,
                params,
                compiled_params,
                cfg.strategies[idx],
                pattern,
                lowered,
                pre.as_deref(),
                scratch,
            )
        };
        if cfg.prune {
            let bm = BoundModel::new(machine, params);
            let bounds: Vec<_> = cfg.strategies.iter().map(|&s| bm.bounds(s, inputs)).collect();
            // seed: least upper bound (ties break to Table 5 order)
            let seed = (0..n)
                .min_by(|&a, &b| bounds[a].upper.total_cmp(&bounds[b].upper).then(a.cmp(&b)))
                .expect("non-empty strategy list");
            let mut best = run(seed, scratch);
            sim_s[seed] = Some(best);
            let mut order: Vec<usize> = (0..n).filter(|&i| i != seed).collect();
            order.sort_by(|&a, &b| bounds[a].lower.total_cmp(&bounds[b].lower).then(a.cmp(&b)));
            for idx in order {
                if bounds[idx].lower > best {
                    pruned[idx] = true;
                    continue;
                }
                let t = run(idx, scratch);
                if t < best {
                    best = t;
                }
                sim_s[idx] = Some(t);
            }
        } else {
            for (idx, slot) in sim_s.iter_mut().enumerate() {
                *slot = Some(run(idx, scratch));
            }
        }
    }

    let mut out = Vec::with_capacity(n);
    for (idx, &strategy) in cfg.strategies.iter().enumerate() {
        let model_err = sim_s[idx].and_then(|t| if t > 0.0 { Some((model_s[idx] - t).abs() / t) } else { None });
        out.push(CellResult {
            index: cell.index,
            gen: cell.gen,
            dest_nodes: cell.dest_nodes,
            gpus_per_node: cell.gpus_per_node,
            nics: cell.nics,
            size: cell.size,
            strategy,
            label: strategy.label(),
            model_s: model_s[idx],
            sim_s: sim_s[idx],
            model_err,
            sim_pruned: pruned[idx],
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};

    fn small_config(threads: usize) -> SweepConfig {
        SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform, PatternGen::Random],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1],
                sizes: vec![256, 4096],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 11,
            threads,
            sim: true,
            ..Default::default()
        }
    }

    fn cmp_cells(a: &[CellResult], b: &[CellResult]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.label, y.label);
            assert_eq!(x.model_s.to_bits(), y.model_s.to_bits(), "{} model", x.label);
            assert_eq!(x.sim_s.map(f64::to_bits), y.sim_s.map(f64::to_bits), "{} sim", x.label);
        }
    }

    #[test]
    fn results_cover_grid_times_strategies() {
        let cfg = small_config(2);
        let r = run_sweep(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len() * cfg.strategies.len());
        assert!(r.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        assert!(r.cells.iter().all(|c| c.sim_s.is_some()));
        // cells come back in grid order, strategies in Table 5 order
        for w in r.cells.windows(2) {
            assert!(w[0].index <= w[1].index);
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let r1 = run_sweep(&small_config(1)).unwrap();
        let r4 = run_sweep(&small_config(4)).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
    }

    #[test]
    fn same_seed_same_bits_different_seed_differs() {
        let r1 = run_sweep(&small_config(2)).unwrap();
        let r2 = run_sweep(&small_config(2)).unwrap();
        cmp_cells(&r1.cells, &r2.cells);
        let mut cfg = small_config(2);
        cfg.seed = 12;
        let r3 = run_sweep(&cfg).unwrap();
        // random-generator sim times must move with the seed
        let sim_of = |r: &SweepResult| -> Vec<u64> {
            r.cells.iter().filter(|c| c.gen == PatternGen::Random).filter_map(|c| c.sim_s.map(f64::to_bits)).collect()
        };
        assert_ne!(sim_of(&r1), sim_of(&r3), "seed must drive the random generator");
    }

    #[test]
    fn reference_mode_matches_compiled_bit_for_bit() {
        // The refactor's safety rail in miniature: the naive per-strategy
        // rebuild + hash-map executor and the compiled flat path must agree
        // on every bit (the full golden test lives in tests/golden_sweep.rs).
        let cfg = small_config(2);
        let fast = run_sweep_mode(&cfg, ExecMode::Compiled).unwrap();
        let slow = run_sweep_mode(&cfg, ExecMode::Reference).unwrap();
        cmp_cells(&fast.cells, &slow.cells);
    }

    #[test]
    fn model_only_skips_sim() {
        let mut cfg = small_config(2);
        cfg.sim = false;
        let r = run_sweep(&cfg).unwrap();
        assert!(r.cells.iter().all(|c| c.sim_s.is_none() && c.model_err.is_none()));
    }

    #[test]
    fn strategy_filter_respected() {
        let mut cfg = small_config(1);
        cfg.strategies = vec![Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap()];
        let r = run_sweep(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len());
        assert!(r.cells.iter().all(|c| c.strategy.kind == StrategyKind::SplitMd));
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = small_config(1);
        cfg.strategies.clear();
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.sizes.clear();
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.machine = "bogus".into();
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn machine_preset_changes_model_times() {
        let mut base = small_config(1);
        base.sim = false;
        let lassen = run_sweep(&base).unwrap();
        let mut frontier = small_config(1);
        frontier.sim = false;
        frontier.machine = "frontier-like".into();
        let frontier = run_sweep(&frontier).unwrap();
        assert_eq!(lassen.cells.len(), frontier.cells.len());
        assert!(
            lassen.cells.iter().zip(&frontier.cells).any(|(a, b)| a.model_s.to_bits() != b.model_s.to_bits()),
            "the machine preset must reach the models"
        );
        // aliases resolve through the same registry
        let mut alias = small_config(1);
        alias.sim = false;
        alias.machine = "Frontier".into();
        let alias = run_sweep(&alias).unwrap();
        for (a, b) in frontier.cells.iter().zip(&alias.cells) {
            assert_eq!(a.model_s.to_bits(), b.model_s.to_bits());
        }
    }

    #[test]
    fn nics_axis_reaches_models_and_sim() {
        // 4 rails must speed up injection-limited staged cells in both the
        // model and the simulator, and never slow anything down.
        let mut cfg = small_config(2);
        cfg.grid.sizes = vec![1 << 14];
        cfg.grid.gens = vec![PatternGen::Uniform];
        cfg.grid.n_msgs = 256;
        let one = run_sweep(&cfg).unwrap();
        cfg.grid.nics = vec![4];
        let four = run_sweep(&cfg).unwrap();
        assert_eq!(one.cells.len(), four.cells.len());
        assert!(four.cells.iter().all(|c| c.nics == 4));
        let mut model_moved = false;
        for (a, b) in one.cells.iter().zip(&four.cells) {
            assert!(b.model_s <= a.model_s * (1.0 + 1e-12), "{} model slowed down", a.label);
            model_moved |= b.model_s < a.model_s;
        }
        assert!(model_moved, "4 rails must relieve at least one staged model cell");
        let sim_moved = one
            .cells
            .iter()
            .zip(&four.cells)
            .any(|(a, b)| a.sim_s.zip(b.sim_s).is_some_and(|(x, y)| y < x));
        assert!(sim_moved, "4 rails must relieve at least one simulated cell");
    }

    #[test]
    fn pinned_machine_resolves_and_rejects_conflicts() {
        let mut cfg = small_config(1);
        cfg.sim = false;
        cfg.machine = "frontier-4nic".into();
        let r = run_sweep(&cfg).unwrap();
        assert_eq!(r.config.grid.nics, vec![4], "pinned preset must resolve the default axis");
        assert!(r.cells.iter().all(|c| c.nics == 4));
        cfg.grid.nics = vec![1, 4];
        let err = run_sweep(&cfg).unwrap_err();
        assert!(err.contains("pins"), "{err}");
    }

    #[test]
    fn trace_sweep_covers_epochs_and_is_thread_invariant() {
        use crate::trace::scenarios::{synthesize, TraceScenario};
        let trace = synthesize(TraceScenario::HaloBurst, "lassen", 4, 1, 9).unwrap();
        let r1 = run_sweep_trace(&trace, &Strategy::all(), 1, false).unwrap();
        assert_eq!(r1.cells.len(), 4 * Strategy::all().len());
        assert!(r1.cells.iter().all(|c| c.gen == PatternGen::Trace));
        assert!(r1.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        // epoch regime labels: calm epochs are 2 KiB, burst epochs 64 KiB
        assert_eq!(r1.cells[0].size, 2048);
        assert_eq!(r1.cells[Strategy::all().len()].size, 1 << 16);
        // the winner timeline flips between calm and burst regimes
        let w = &r1.report.winners;
        assert_eq!(w.len(), 4);
        assert_ne!(w[0].winner, w[1].winner, "calm and burst regimes have different winners");
        assert_eq!(w[0].winner, w[2].winner);
        assert!(!r1.report.crossovers.is_empty());
        let r4 = run_sweep_trace(&trace, &Strategy::all(), 4, false).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
        // config echo labels the run as a trace sweep
        assert_eq!(r1.config.grid.gens, vec![PatternGen::Trace]);
        assert_eq!(r1.config.machine, "lassen");
        // empty strategy lists are rejected like grid sweeps
        assert!(run_sweep_trace(&trace, &[], 1, false).is_err());
    }

    #[test]
    fn trace_sweep_reference_mode_matches() {
        use crate::trace::scenarios::{synthesize, TraceScenario};
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 3, 1, 5).unwrap();
        let fast = run_sweep_trace_mode(&trace, &Strategy::all(), 2, true, ExecMode::Compiled).unwrap();
        let slow = run_sweep_trace_mode(&trace, &Strategy::all(), 2, true, ExecMode::Reference).unwrap();
        cmp_cells(&fast.cells, &slow.cells);
        assert!(fast.cells.iter().all(|c| c.sim_s.is_some()));
    }

    #[test]
    fn fault_schedule_degrades_the_fleet_and_identity_is_free() {
        use crate::fault::{FaultEvent, FaultKind, FaultSpec};
        // identity schedules are dropped before evaluation: bytes match the
        // healthy run and the config echo carries no spec
        let healthy = run_sweep(&small_config(2)).unwrap();
        let mut cfg = small_config(2);
        cfg.faults = Some(FaultSpec::empty(3));
        let id = run_sweep(&cfg).unwrap();
        cmp_cells(&healthy.cells, &id.cells);
        assert!(id.config.faults.is_none(), "identity spec must vanish from the echo");

        // a real schedule (slowed rail + background congestion) only ever
        // hurts, and must hurt somewhere
        let mut cfg = small_config(2);
        cfg.grid.nics = vec![2];
        let healthy = run_sweep(&cfg).unwrap();
        let spec = FaultSpec {
            seed: 5,
            events: vec![
                FaultEvent { epoch: 0, kind: FaultKind::Slowdown { rail: 1, factor: 8.0 } },
                FaultEvent { epoch: 0, kind: FaultKind::Congestion { level: 1e-4 } },
            ],
        };
        cfg.faults = Some(spec.clone());
        let faulted = run_sweep(&cfg).unwrap();
        assert_eq!(healthy.cells.len(), faulted.cells.len());
        assert_eq!(faulted.config.faults.as_ref(), Some(&spec));
        let mut moved = false;
        for (h, f) in healthy.cells.iter().zip(&faulted.cells) {
            assert_eq!(h.label, f.label);
            assert_eq!(h.nics, f.nics, "axis labels stay healthy");
            assert!(f.model_s >= h.model_s * (1.0 - 1e-12), "{} model sped up under faults", h.label);
            if let (Some(hs), Some(fs)) = (h.sim_s, f.sim_s) {
                assert!(fs >= hs * (1.0 - 1e-12), "{} sim sped up under faults", h.label);
                moved |= fs > hs;
            }
        }
        assert!(moved, "the fault schedule must reach the simulator");
        // degraded runs stay deterministic and thread-invariant
        cfg.threads = 1;
        let faulted1 = run_sweep(&cfg).unwrap();
        cmp_cells(&faulted.cells, &faulted1.cells);

        // a schedule no machine on the grid survives is rejected up front
        let mut cfg = small_config(1);
        cfg.faults = Some(FaultSpec {
            seed: 1,
            events: vec![FaultEvent { epoch: 0, kind: FaultKind::RailDown { rail: 0 } }],
        });
        let err = run_sweep(&cfg).unwrap_err();
        assert!(err.contains("survive"), "{err}");
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(3, 100), 3);
        assert_eq!(effective_threads(64, 2), 2);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(0, 0), 1);
    }

    #[test]
    fn cell_seed_spreads() {
        let s: std::collections::BTreeSet<u64> = (0..100).map(|i| cell_seed(42, i)).collect();
        assert_eq!(s.len(), 100);
    }

    /// Pruning-friendly grid: many small messages make the Standard
    /// strategies' per-message floors dwarf the node-aware winners.
    fn prunable_config(threads: usize) -> SweepConfig {
        SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1],
                sizes: vec![64, 128, 256, 512, 1024],
                n_msgs: 256,
                dup_frac: 0.0,
            },
            seed: 7,
            threads,
            sim: true,
            ..Default::default()
        }
    }

    #[test]
    fn prune_preserves_everything_but_skipped_sims() {
        let full = run_sweep(&prunable_config(2)).unwrap();
        let mut cfg = prunable_config(2);
        cfg.prune = true;
        let pruned = run_sweep(&cfg).unwrap();
        assert_eq!(full.cells.len(), pruned.cells.len());
        let mut skipped = 0;
        for (a, b) in full.cells.iter().zip(&pruned.cells) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.label, b.label);
            // model times (and hence winners/crossovers/regimes) are untouched
            assert_eq!(a.model_s.to_bits(), b.model_s.to_bits(), "{} model", a.label);
            if b.sim_pruned {
                skipped += 1;
                assert!(b.sim_s.is_none(), "{} pruned but simulated", b.label);
            } else {
                // surviving sims are bit-identical to the full run
                assert_eq!(a.sim_s.map(f64::to_bits), b.sim_s.map(f64::to_bits), "{} sim", a.label);
            }
        }
        assert!(skipped > 0, "this grid must actually prune something");
        // soundness end-to-end: no pruned strategy could have won a cell's sim
        for group in pruned.cells.chunks(cfg.strategies.len()) {
            let best = group.iter().filter_map(|c| c.sim_s).fold(f64::INFINITY, f64::min);
            let full_group = &full.cells[group[0].index * cfg.strategies.len()..];
            for (c, f) in group.iter().zip(full_group) {
                if c.sim_pruned {
                    assert!(f.sim_s.unwrap() >= best, "{} pruned yet beat the incumbent", c.label);
                }
            }
        }
        // winner/crossover/regime reports are identical (the `pruned`
        // count is the only winner field allowed to move)
        let key = |w: &crate::sweep::CellWinner| (w.size, w.winner, w.sim_winner, w.model_s.to_bits());
        assert_eq!(
            full.report.winners.iter().map(key).collect::<Vec<_>>(),
            pruned.report.winners.iter().map(key).collect::<Vec<_>>()
        );
        assert_eq!(full.report.crossovers, pruned.report.crossovers);
        assert_eq!(full.report.regimes, pruned.report.regimes);
        // accounting matches the per-cell flags
        assert_eq!(pruned.report.prune.pruned, skipped);
        assert_eq!(pruned.report.prune.cells, full.report.winners.len());
        assert_eq!(pruned.report.prune.sim_evals + skipped, full.report.prune.sim_evals);
        assert_eq!(full.report.prune.pruned, 0);
    }

    #[test]
    fn prune_never_marks_without_flag() {
        let r = run_sweep(&small_config(2)).unwrap();
        assert!(r.cells.iter().all(|c| !c.sim_pruned));
    }

    #[test]
    fn pattern_reuse_is_bit_identical() {
        for base in [small_config(2), prunable_config(2)] {
            let off = run_sweep(&base).unwrap();
            let mut cfg = base;
            cfg.reuse_patterns = true;
            let on = run_sweep(&cfg).unwrap();
            cmp_cells(&off.cells, &on.cells);
            // thread invariance holds with line-granular work units too
            cfg.threads = 1;
            let on1 = run_sweep(&cfg).unwrap();
            cmp_cells(&on.cells, &on1.cells);
        }
    }

    #[test]
    fn refined_cells_match_exhaustive_where_they_coincide() {
        // 9-point size axis so depth 2 exercises two subdivision levels
        let mut base = prunable_config(2);
        base.grid.sizes = (6..15).map(|e| 1usize << e).collect();
        let exhaustive = run_sweep(&base).unwrap();
        let mut cfg = base;
        cfg.refine = 2;
        cfg.prune = true;
        cfg.reuse_patterns = true;
        let refined = run_sweep(&cfg).unwrap();
        assert!(refined.cells.len() <= exhaustive.cells.len());
        assert!(!refined.cells.is_empty());
        let per = cfg.strategies.len();
        // endpoints of every line are always present
        assert_eq!(refined.cells[0].index, 0);
        assert_eq!(refined.cells.last().unwrap().index, exhaustive.cells.last().unwrap().index);
        for group in refined.cells.chunks(per) {
            let full_group = &exhaustive.cells[group[0].index * per..group[0].index * per + per];
            for (r, f) in group.iter().zip(full_group) {
                assert_eq!(r.label, f.label);
                assert_eq!(r.model_s.to_bits(), f.model_s.to_bits(), "{} model", r.label);
                if !r.sim_pruned {
                    assert_eq!(r.sim_s.map(f64::to_bits), f.sim_s.map(f64::to_bits), "{} sim", r.label);
                }
            }
        }
        // the coarse pass plus subdivisions still finds every model winner
        // transition the exhaustive report sees (crossover sizes coincide)
        let xs = |r: &SweepResult| -> Vec<String> { r.report.crossovers.iter().map(|c| format!("{c:?}")).collect() };
        assert_eq!(xs(&exhaustive), xs(&refined), "refinement must resolve the crossover boundary");
    }

    #[test]
    fn refine_depth_larger_than_axis_still_covers_endpoints() {
        let mut cfg = small_config(1);
        cfg.refine = 30; // stride clamps; lattice degenerates to endpoints
        let r = run_sweep(&cfg).unwrap();
        assert!(!r.cells.is_empty());
        let idx: std::collections::BTreeSet<usize> = r.cells.iter().map(|c| c.index).collect();
        // both sizes of each 2-cell line are endpoints, so all cells evaluate
        assert_eq!(idx.len(), cfg.grid.cells().len());
    }
}
