//! Deterministic emitters for sweep results: JSON (machine-readable,
//! byte-identical across seeded runs), CSV (one row per cell × strategy)
//! and aligned text tables (the Figure 4.3 view). No `serde` in the
//! offline image — the JSON writer is hand-rolled with fixed float
//! formatting so output is reproducible bit-for-bit.

use super::engine::{CellResult, SweepResult};
use crate::bench::{fmt_secs, Table};
use std::fmt::Write as _;

/// Fixed-width scientific float formatting: deterministic and valid JSON.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9e}")
    } else {
        "null".to_string()
    }
}

fn opt_num(x: Option<f64>) -> String {
    match x {
        Some(v) => num(v),
        None => "null".to_string(),
    }
}

/// Minimal JSON string escaping (labels only contain ASCII, but stay safe).
/// Shared with the advisor's surface artifacts (`advisor::persist`).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// True when the sweep left the legacy single-rail shape: the emitters
/// then carry the NIC axis through every row. Default `[1]` grids emit the
/// historical `hetcomm.sweep.v1` bytes unchanged (the golden-diff gate).
fn shaped(result: &SweepResult) -> bool {
    result.config.grid.nics != [1]
}

/// True when the sweep ran with branch-and-bound pruning: the emitters
/// then carry `sim_pruned` / `pruned` fields and the prune summary.
/// Flag-less sweeps emit no prune fields at all (CI grep-gates this).
fn pruned(result: &SweepResult) -> bool {
    result.config.prune
}

/// True when refinement could actually skip cells. With at most two points
/// on both refinable axes (sizes, destination nodes) the initial lattice
/// already covers the grid, the run is byte-identical to an exhaustive
/// one, and it must serialize identically too — so the `refine` echo and
/// summary line are suppressed.
fn refined(result: &SweepResult) -> bool {
    let g = &result.config.grid;
    let mut sizes = g.sizes.clone();
    sizes.sort_unstable();
    sizes.dedup();
    result.config.refine > 0 && (sizes.len() > 2 || g.dest_nodes.len() > 2)
}

/// Serialize the full sweep result (config echo, cells, report) as JSON.
/// Wall-clock fields are deliberately excluded: two runs with the same
/// seed must produce byte-identical output.
pub fn to_json(result: &SweepResult) -> String {
    let cfg = &result.config;
    let shaped = shaped(result);
    let pruned = pruned(result);
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"hetcomm.sweep.v1\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&cfg.machine));
    let _ = writeln!(out, "  \"seed\": {},", cfg.seed);
    let _ = writeln!(out, "  \"n_msgs\": {},", cfg.grid.n_msgs);
    if shaped {
        let rails: Vec<String> = cfg.grid.nics.iter().map(|n| n.to_string()).collect();
        let _ = writeln!(out, "  \"nics\": [{}],", rails.join(", "));
    }
    let _ = writeln!(out, "  \"dup_frac\": {},", num(cfg.grid.dup_frac));
    let _ = writeln!(out, "  \"sim\": {},", cfg.sim);
    if refined(result) {
        let _ = writeln!(out, "  \"refine\": {},", cfg.refine);
    }
    // fault-sweep runs echo the schedule; healthy runs never mention it
    if let Some(spec) = &cfg.faults {
        let events: Vec<String> = spec
            .events
            .iter()
            .map(|e| format!("{{\"epoch\": {}, {}}}", e.epoch, crate::fault::persist::kind_fields(&e.kind)))
            .collect();
        let _ = writeln!(out, "  \"faults\": {{\"seed\": {}, \"events\": [{}]}},", spec.seed, events.join(", "));
    }

    out.push_str("  \"cells\": [\n");
    for (i, c) in result.cells.iter().enumerate() {
        let comma = if i + 1 < result.cells.len() { "," } else { "" };
        let rails = if shaped { format!("\"nics\": {}, ", c.nics) } else { String::new() };
        let skip = if pruned { format!(", \"sim_pruned\": {}", c.sim_pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"gen\": \"{}\", \"dest_nodes\": {}, \"gpus_per_node\": {}, {rails}\"size\": {}, \
             \"strategy\": \"{}\", \"model_s\": {}, \"sim_s\": {}, \"model_err\": {}{skip}}}{comma}",
            c.gen.label(),
            c.dest_nodes,
            c.gpus_per_node,
            c.size,
            esc(&c.label),
            num(c.model_s),
            opt_num(c.sim_s),
            opt_num(c.model_err),
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"winners\": [\n");
    for (i, w) in result.report.winners.iter().enumerate() {
        let comma = if i + 1 < result.report.winners.len() { "," } else { "" };
        let sim_winner = match &w.sim_winner {
            Some(s) => format!("\"{}\"", esc(s)),
            None => "null".to_string(),
        };
        let rails = if shaped { format!("\"nics\": {}, ", w.nics) } else { String::new() };
        let skip = if pruned { format!(", \"pruned\": {}", w.pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"gen\": \"{}\", \"dest_nodes\": {}, \"gpus_per_node\": {}, {rails}\"size\": {}, \
             \"winner\": \"{}\", \"staged\": {}, \"model_s\": {}, \"sim_winner\": {}{skip}}}{comma}",
            w.gen.label(),
            w.dest_nodes,
            w.gpus_per_node,
            w.size,
            esc(&w.winner),
            w.winner_staged,
            num(w.model_s),
            sim_winner,
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"crossovers\": [\n");
    for (i, x) in result.report.crossovers.iter().enumerate() {
        let comma = if i + 1 < result.report.crossovers.len() { "," } else { "" };
        let rails = if shaped { format!("\"nics\": {}, ", x.nics) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"gen\": \"{}\", \"dest_nodes\": {}, \"gpus_per_node\": {}, {rails}\
             \"size_before\": {}, \"size_after\": {}, \"from\": \"{}\", \"to\": \"{}\"}}{comma}",
            x.gen.label(),
            x.dest_nodes,
            x.gpus_per_node,
            x.size_before,
            x.size_after,
            esc(&x.from),
            esc(&x.to),
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"regimes\": [\n");
    for (i, g) in result.report.regimes.iter().enumerate() {
        let comma = if i + 1 < result.report.regimes.len() { "," } else { "" };
        let rails = if shaped { format!("\"nics\": {}, ", g.nics) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"gen\": \"{}\", \"dest_nodes\": {}, \"gpus_per_node\": {}, {rails}\"band\": \"{}\", \
             \"winner\": \"{}\", \"staged\": {}, \"total_model_s\": {}}}{comma}",
            g.gen.label(),
            g.dest_nodes,
            g.gpus_per_node,
            g.band,
            esc(&g.winner),
            g.winner_staged,
            num(g.total_model_s),
        );
    }
    out.push_str("  ],\n");

    let e = &result.report.model_error;
    let comma = if pruned { "," } else { "" };
    let _ = writeln!(
        out,
        "  \"model_error\": {{\"cells_with_sim\": {}, \"mean\": {}, \"max\": {}}}{comma}",
        e.cells_with_sim,
        num(e.mean),
        num(e.max)
    );
    if pruned {
        let p = &result.report.prune;
        let _ = writeln!(
            out,
            "  \"prune\": {{\"cells\": {}, \"sim_evals\": {}, \"pruned\": {}}}",
            p.cells, p.sim_evals, p.pruned
        );
    }
    out.push_str("}\n");
    out
}

/// One CSV row per (cell × strategy). Shaped sweeps (a non-default NIC
/// axis) gain a `nics` column; default grids keep the historical header.
pub fn to_csv(result: &SweepResult) -> String {
    let shaped = shaped(result);
    let pruned = pruned(result);
    let mut out = if shaped {
        String::from("gen,dest_nodes,gpus_per_node,nics,size,strategy,model_s,sim_s,model_err")
    } else {
        String::from("gen,dest_nodes,gpus_per_node,size,strategy,model_s,sim_s,model_err")
    };
    if pruned {
        out.push_str(",sim_pruned");
    }
    out.push('\n');
    for c in &result.cells {
        let rails = if shaped { format!("{},", c.nics) } else { String::new() };
        let skip = if pruned { format!(",{}", c.sim_pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "{},{},{},{rails}{},\"{}\",{},{},{}{skip}",
            c.gen.label(),
            c.dest_nodes,
            c.gpus_per_node,
            c.size,
            c.label.replace('"', "\"\""),
            num(c.model_s),
            c.sim_s.map(num).unwrap_or_default(),
            c.model_err.map(num).unwrap_or_default(),
        );
    }
    out
}

/// Human-readable view: one table per regime line (sizes × strategies,
/// modeled seconds, winner column), then the crossover and regime-winner
/// report and the model-error summary.
pub fn render_tables(result: &SweepResult) -> String {
    let mut out = String::new();
    let strategies = &result.config.strategies;
    let cells = &result.cells;
    let shaped = shaped(result);

    let mut i = 0;
    while i < cells.len() {
        // one regime line: same (gen, dest, gpn)
        let mut j = i + 1;
        while j < cells.len()
            && cells[j].gen == cells[i].gen
            && cells[j].dest_nodes == cells[i].dest_nodes
            && cells[j].gpus_per_node == cells[i].gpus_per_node
            && cells[j].nics == cells[i].nics
        {
            j += 1;
        }
        let line = &cells[i..j];
        let mut header: Vec<String> = vec!["size[B]".into()];
        header.extend(strategies.iter().map(|s| s.label().to_string()));
        header.push("model winner".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let rails = if shaped { format!(" · {} NICs/node", line[0].nics) } else { String::new() };
        let mut t = Table::new(
            format!(
                "{} · {} msgs -> {} nodes · {} GPUs/node{rails} · dup {:.0}%",
                line[0].gen,
                result.config.grid.n_msgs,
                line[0].dest_nodes,
                line[0].gpus_per_node,
                result.config.grid.dup_frac * 100.0
            ),
            &hdr,
        );
        let mut k = i;
        while k < j {
            let mut m = k + 1;
            while m < j && cells[m].index == cells[k].index {
                m += 1;
            }
            let group = &cells[k..m];
            let mut row = vec![group[0].size.to_string()];
            for s in strategies {
                match group.iter().find(|c| c.strategy == *s) {
                    Some(c) => row.push(fmt_secs(c.model_s)),
                    None => row.push(String::new()),
                }
            }
            let winner = result
                .report
                .winners
                .iter()
                .find(|w| {
                    w.gen == group[0].gen
                        && w.dest_nodes == group[0].dest_nodes
                        && w.gpus_per_node == group[0].gpus_per_node
                        && w.nics == group[0].nics
                        && w.size == group[0].size
                })
                .map(|w| w.winner.to_string())
                .unwrap_or_default();
            row.push(winner);
            t.row(row);
            k = m;
        }
        out.push_str(&t.render());
        i = j;
    }

    out.push_str("\nCrossover report (model winner changes with message size):\n");
    if result.report.crossovers.is_empty() {
        out.push_str("  (none within the swept sizes)\n");
    }
    for x in &result.report.crossovers {
        let rails = if shaped { format!(" · {} NICs", x.nics) } else { String::new() };
        let _ = writeln!(
            out,
            "  {} · {} nodes · {} GPUs/node{rails}: {} -> {} between {} B and {} B",
            x.gen, x.dest_nodes, x.gpus_per_node, x.from, x.to, x.size_before, x.size_after
        );
    }

    out.push_str("\nRegime winners (min total modeled time per band):\n");
    for g in &result.report.regimes {
        let rails = if shaped { format!(" · {} NICs", g.nics) } else { String::new() };
        let _ = writeln!(
            out,
            "  {} · {} nodes · {} GPUs/node{rails} · {:>5}: {} ({})",
            g.gen,
            g.dest_nodes,
            g.gpus_per_node,
            g.band,
            g.winner,
            fmt_secs(g.total_model_s).trim()
        );
    }

    let e = &result.report.model_error;
    if e.cells_with_sim > 0 {
        let _ = writeln!(
            out,
            "\nModel vs simulation over {} cells: mean rel. error {:.2}, max {:.2}",
            e.cells_with_sim, e.mean, e.max
        );
    }
    if pruned(result) {
        let p = &result.report.prune;
        let _ = writeln!(
            out,
            "\nBound-guided pruning: skipped {} of {} strategy simulations over {} cells",
            p.pruned,
            p.pruned + p.sim_evals,
            p.cells
        );
    }
    if refined(result) {
        let total = result.config.grid.cells().len();
        let _ = writeln!(
            out,
            "\nAdaptive refinement (depth {}): {} of {} grid cells evaluated",
            result.config.refine,
            result.report.prune.cells,
            total
        );
    }
    if let Some(spec) = &result.config.faults {
        let labels: Vec<String> = spec.events.iter().map(|e| e.kind.to_string()).collect();
        let _ = writeln!(out, "\nFault schedule (terminal state, fleet-wide): {}", labels.join(", "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::engine::{run_sweep, SweepConfig};
    use crate::sweep::grid::{GridSpec, PatternGen};

    fn tiny_result() -> crate::sweep::engine::SweepResult {
        let cfg = SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1],
                sizes: vec![1 << 10, 1 << 18],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 3,
            threads: 1,
            sim: true,
            ..Default::default()
        };
        run_sweep(&cfg).unwrap()
    }

    #[test]
    fn json_has_sections_and_no_wallclock() {
        let r = tiny_result();
        let j = to_json(&r);
        for key in ["\"schema\"", "\"cells\"", "\"winners\"", "\"crossovers\"", "\"regimes\"", "\"model_error\""] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(!j.contains("elapsed"), "wall-clock leaked into deterministic output");
        // balanced braces/brackets as a cheap well-formedness check
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn json_floats_fixed_width() {
        let r = tiny_result();
        let j = to_json(&r);
        assert!(j.contains("e-") || j.contains("e0"), "scientific notation expected: {j}");
        assert_eq!(num(1.0), "1.000000000e0");
        assert_eq!(num(f64::NAN), "null");
    }

    #[test]
    fn csv_row_count() {
        let r = tiny_result();
        let csv = to_csv(&r);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + r.cells.len());
        assert!(lines[0].starts_with("gen,dest_nodes"));
    }

    #[test]
    fn tables_mention_every_strategy_and_crossovers() {
        let r = tiny_result();
        let text = render_tables(&r);
        for s in &r.config.strategies {
            assert!(text.contains(s.label()), "missing {}", s.label());
        }
        assert!(text.contains("Crossover report"));
        assert!(text.contains("Regime winners"));
    }

    #[test]
    fn escaping() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("x\ny"), "x\\ny");
    }

    #[test]
    fn default_shape_emits_no_nics_fields() {
        // the golden byte contract: legacy single-rail sweeps serialize
        // exactly as before the shape layer existed
        let r = tiny_result();
        assert!(!to_json(&r).contains("nics"), "default grids must not leak the NIC axis");
        assert!(to_csv(&r).starts_with("gen,dest_nodes,gpus_per_node,size,"));
        assert!(!render_tables(&r).contains("NICs"));
    }

    #[test]
    fn default_runs_emit_no_prune_or_refine_fields() {
        // the byte contract the CI grep-gate enforces: flag-less sweeps
        // serialize exactly as before the pruning layer existed
        let r = tiny_result();
        let j = to_json(&r);
        for tok in ["sim_pruned", "\"pruned\"", "\"prune\"", "\"refine\"", "refinement"] {
            assert!(!j.contains(tok), "default JSON leaked {tok}");
        }
        assert!(!to_csv(&r).contains("sim_pruned"));
        let text = render_tables(&r);
        assert!(!text.contains("pruning") && !text.contains("refinement"));
    }

    #[test]
    fn fault_echo_only_appears_on_degraded_runs() {
        use crate::fault::{FaultEvent, FaultKind, FaultSpec};
        // healthy runs never mention the fault layer (CI grep-gate contract)
        let r = tiny_result();
        assert!(!to_json(&r).contains("fault"), "healthy JSON leaked the fault layer");
        assert!(!render_tables(&r).contains("Fault"));
        // degraded runs echo the schedule verbatim and label the tables
        let mut cfg = SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![2],
                sizes: vec![1 << 10],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 3,
            threads: 1,
            sim: false,
            ..Default::default()
        };
        cfg.faults = Some(FaultSpec {
            seed: 9,
            events: vec![
                FaultEvent { epoch: 0, kind: FaultKind::RailDown { rail: 1 } },
                FaultEvent { epoch: 1, kind: FaultKind::Congestion { level: 2e-4 } },
            ],
        });
        let r = run_sweep(&cfg).unwrap();
        let j = to_json(&r);
        assert!(j.contains("\"faults\": {\"seed\": 9, \"events\": "), "{j}");
        assert!(j.contains("\"kind\": \"rail-down\", \"rail\": 1"), "{j}");
        assert!(j.contains("\"kind\": \"congestion\", \"level\": 0.0002"), "{j}");
        let text = render_tables(&r);
        assert!(text.contains("Fault schedule"), "{text}");
        assert!(text.contains("rail-down(1)"), "{text}");
    }

    #[test]
    fn pruned_runs_carry_prune_fields_everywhere() {
        let mut cfg = SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1],
                sizes: vec![64, 256, 1024],
                n_msgs: 256,
                dup_frac: 0.0,
            },
            seed: 3,
            threads: 1,
            sim: true,
            ..Default::default()
        };
        cfg.prune = true;
        let r = run_sweep(&cfg).unwrap();
        let j = to_json(&r);
        assert!(j.contains("\"sim_pruned\": "), "{j}");
        assert!(j.contains("\"pruned\": "), "{j}");
        assert!(j.contains("\"prune\": {\"cells\": "), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let csv = to_csv(&r);
        assert!(csv.lines().next().unwrap().ends_with(",sim_pruned"));
        assert!(render_tables(&r).contains("Bound-guided pruning"));
        // refinement adds its own summary line and config echo
        cfg.refine = 1;
        let r = run_sweep(&cfg).unwrap();
        assert!(to_json(&r).contains("\"refine\": 1,"));
        assert!(render_tables(&r).contains("Adaptive refinement (depth 1)"));
    }

    #[test]
    fn refine_echo_suppressed_when_it_cannot_skip_cells() {
        // 1 dest value x 2 sizes: the lattice covers the whole grid, so a
        // refined run is exhaustive and must serialize byte-identically to
        // a flag-less one.
        let mut cfg = SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1],
                sizes: vec![1 << 10, 1 << 18],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 3,
            threads: 1,
            sim: true,
            ..Default::default()
        };
        let exhaustive = run_sweep(&cfg).unwrap();
        cfg.refine = 3;
        let noop = run_sweep(&cfg).unwrap();
        assert_eq!(to_json(&exhaustive), to_json(&noop));
        assert!(!render_tables(&noop).contains("Adaptive refinement"));
    }

    #[test]
    fn shaped_sweeps_carry_the_nic_axis_everywhere() {
        let mut cfg = SweepConfig {
            grid: GridSpec {
                gens: vec![PatternGen::Uniform],
                dest_nodes: vec![4],
                gpus_per_node: vec![4],
                nics: vec![1, 4],
                sizes: vec![1 << 10, 1 << 18],
                n_msgs: 32,
                dup_frac: 0.0,
            },
            seed: 3,
            threads: 1,
            sim: false,
            ..Default::default()
        };
        cfg.grid.n_msgs = 64;
        let r = run_sweep(&cfg).unwrap();
        let j = to_json(&r);
        assert!(j.contains("\"nics\": [1, 4]"), "{j}");
        assert!(j.contains("\"nics\": 1,") && j.contains("\"nics\": 4,"));
        let csv = to_csv(&r);
        assert!(csv.starts_with("gen,dest_nodes,gpus_per_node,nics,size,"));
        assert!(render_tables(&r).contains("NICs/node"));
        // still well-formed and deterministic
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j, to_json(&run_sweep(&cfg).unwrap()));
    }
}
