//! Persistent worker engine — the optimized iterative path.
//!
//! [`super::worker::DistSpmv::run`] spawns worker threads (and, in PJRT
//! mode, creates a PJRT client and compiles the artifact) *per call*. For
//! iterative workloads (power iteration, Krylov solves) that setup cost
//! dominates. The [`Engine`] keeps workers alive across iterations:
//!
//! - workers are spawned once; each builds its compute backend once;
//! - the leader drives iterations over command channels;
//! - each iteration performs the strategy-shaped halo exchange (same
//!   [`ExchangePlan`] data plane as the one-shot path) followed by local
//!   compute, optionally **overlapped**: the diag (local) SpMV runs while
//!   halo values are still in flight, then the offd product is added — the
//!   overlap the paper points to in Section 2.3 ("Lines 2 to 4 of
//!   Algorithm 2 can be overlapped with various pieces of the
//!   computation").
//!
//! §Perf (EXPERIMENTS.md) records the before/after against the one-shot
//! path.

use super::router::{ExchangePlan, Source};
use crate::comm::Strategy;
use crate::sparse::csr::{Csr, Ell};
use crate::sparse::PartitionedMatrix;
use crate::topology::Machine;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Commands from the leader to a worker.
enum Cmd {
    /// Run one iteration; `new_v` replaces the worker's owned slice first.
    Iterate { new_v: Option<Vec<f32>> },
    Shutdown,
}

/// Per-iteration result from one worker.
struct IterOut {
    part: usize,
    w_local: Vec<f32>,
    t_exchange: f64,
    t_compute: f64,
}

struct Packet {
    mid: u64,
    data: Vec<f32>,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub use_pjrt: bool,
    pub artifacts_dir: std::path::PathBuf,
    /// Overlap the diag SpMV with the halo exchange.
    pub overlap: bool,
    /// Bytes per exchanged vector element when the auto mode models the
    /// halo pattern (8 = the paper's double-precision payloads, matching
    /// `SpmvConfig::elem_size`; the in-tree demo data plane ships f32).
    pub elem_size: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { use_pjrt: false, artifacts_dir: "artifacts".into(), overlap: true, elem_size: 8 }
    }
}

/// Aggregate timing over an engine's lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub iterations: usize,
    /// Max-over-workers, summed over iterations.
    pub wall_exchange: f64,
    pub wall_compute: f64,
    /// Wall time of the exchange+compute critical path (overlap folds the
    /// diag product into the exchange window).
    pub wall_total: f64,
}

/// The persistent distributed-SpMV engine.
pub struct Engine {
    n: usize,
    nparts: usize,
    offsets: Vec<usize>,
    cmd_tx: Vec<Sender<Cmd>>,
    out_rx: Receiver<Result<IterOut>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// The halo pattern the exchange plan was built from (fixed for the
    /// engine's lifetime — partitions don't move).
    pattern: crate::pattern::CommPattern,
    /// Optional workload-trace recorder fed one snapshot per iteration.
    recorder: Option<crate::trace::TraceRecorder>,
    pub stats: EngineStats,
}

impl Engine {
    /// Build and launch: partitions are fixed for the engine's lifetime.
    pub fn new(
        a: &Csr,
        nparts: usize,
        machine: &Machine,
        strategy: Strategy,
        v0: &[f32],
        config: EngineConfig,
    ) -> Result<Engine> {
        Engine::from_partitioned(PartitionedMatrix::build(a, nparts), machine, strategy, v0, config)
    }

    /// Build from a prebuilt partitioning (shared with [`Engine::new_auto`],
    /// which derives the halo pattern from the same partitioning before the
    /// strategy is known — partitioning large matrices twice would dominate
    /// setup).
    fn from_partitioned(
        pm: PartitionedMatrix,
        machine: &Machine,
        strategy: Strategy,
        v0: &[f32],
        config: EngineConfig,
    ) -> Result<Engine> {
        let pattern = pm.comm_pattern(machine, config.elem_size);
        Engine::from_parts(pm, machine, strategy, v0, config, pattern)
    }

    /// The shared construction core; `pattern` must be the partitioning's
    /// own halo pattern (auto mode already derived it for the advisor
    /// query, so it is passed in rather than derived twice).
    fn from_parts(
        pm: PartitionedMatrix,
        machine: &Machine,
        strategy: Strategy,
        v0: &[f32],
        config: EngineConfig,
        pattern: crate::pattern::CommPattern,
    ) -> Result<Engine> {
        let n = pm.partition.n;
        let nparts = pm.parts.len();
        anyhow::ensure!(v0.len() == n, "v0 length mismatch");
        let plan = Arc::new(ExchangePlan::build(&pm, machine, strategy));
        plan.validate(&pm).map_err(|e| anyhow::anyhow!("invalid exchange plan: {e}"))?;

        let mut data_tx: Vec<Sender<Packet>> = Vec::with_capacity(nparts);
        let mut data_rx: Vec<Option<Receiver<Packet>>> = Vec::with_capacity(nparts);
        for _ in 0..nparts {
            let (tx, rx) = channel();
            data_tx.push(tx);
            data_rx.push(Some(rx));
        }
        let data_tx = Arc::new(data_tx);
        let barrier = Arc::new(std::sync::Barrier::new(nparts));
        let (out_tx, out_rx) = channel::<Result<IterOut>>();

        let mut cmd_tx = Vec::with_capacity(nparts);
        let mut handles = Vec::with_capacity(nparts);
        let offsets = pm.partition.offsets.clone();
        for p in 0..nparts {
            let (ctx, crx) = channel::<Cmd>();
            cmd_tx.push(ctx);
            let (r0, r1) = pm.partition.range(p);
            let blocks = &pm.parts[p];
            let state = WorkerState {
                part: p,
                diag: blocks.diag.to_ell(blocks.diag.max_row_nnz().max(1)),
                offd: blocks.offd.to_ell(blocks.offd.max_row_nnz().max(1)),
                v_local: v0[r0..r1].to_vec(),
                n_ghost: blocks.halo.len(),
            };
            let plan = Arc::clone(&plan);
            let data_tx = Arc::clone(&data_tx);
            let rx = data_rx[p].take().expect("one data receiver per worker");
            let barrier = Arc::clone(&barrier);
            let out_tx = out_tx.clone();
            let cfg = config.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(state, &plan, &data_tx, rx, &barrier, crx, out_tx, cfg)
            }));
        }

        Ok(Engine {
            n,
            nparts,
            offsets,
            cmd_tx,
            out_rx,
            handles,
            pattern,
            recorder: None,
            stats: EngineStats::default(),
        })
    }

    /// The halo communication pattern this engine exchanges every iteration.
    pub fn comm_pattern(&self) -> &crate::pattern::CommPattern {
        &self.pattern
    }

    /// Attach a workload-trace recorder: every [`Engine::iterate`] call
    /// observes the halo pattern (replacing any previous recorder).
    pub fn attach_recorder(&mut self, recorder: crate::trace::TraceRecorder) {
        self.recorder = Some(recorder);
    }

    /// Detach and return the recorder (if one was attached).
    pub fn take_recorder(&mut self) -> Option<crate::trace::TraceRecorder> {
        self.recorder.take()
    }

    /// `auto` strategy mode: derive the partitioned matrix's actual halo
    /// pattern, ask the advisor's compiled surface to rank the strategies
    /// for it, and build the engine with the winner — closing the loop from
    /// model to execution. Returns the engine and the chosen strategy.
    pub fn new_auto(
        a: &Csr,
        nparts: usize,
        machine: &Machine,
        surface: &crate::advisor::DecisionSurface,
        v0: &[f32],
        config: EngineConfig,
    ) -> Result<(Engine, Strategy)> {
        anyhow::ensure!(
            surface.machine == machine.name,
            "advisor surface was compiled for {:?} but the engine machine is {:?}",
            surface.machine,
            machine.name
        );
        // surfaces are shape-keyed: a 4-rail lassen surface must not pick
        // strategies for a single-rail lassen node
        anyhow::ensure!(
            surface.nics == machine.nics_per_node(),
            "advisor surface was compiled for {} NICs/node but the engine machine has {}",
            surface.nics,
            machine.nics_per_node()
        );
        let pm = PartitionedMatrix::build(a, nparts);
        let pattern = pm.comm_pattern(machine, config.elem_size);
        let stats = pattern.stats(machine);
        let query = crate::advisor::Pattern::from_stats(&stats, machine);
        let (strategy, _) = surface.lookup(&query).best();
        Ok((Engine::from_parts(pm, machine, strategy, v0, config, pattern)?, strategy))
    }

    /// Run one iteration: optionally scatter a new global vector first;
    /// returns the assembled `w = A·v`.
    pub fn iterate(&mut self, new_v: Option<&[f32]>) -> Result<Vec<f32>> {
        if let Some(v) = new_v {
            anyhow::ensure!(v.len() == self.n, "v length mismatch");
        }
        let t0 = Instant::now();
        for p in 0..self.nparts {
            let slice = new_v.map(|v| v[self.offsets[p]..self.offsets[p + 1]].to_vec());
            self.cmd_tx[p]
                .send(Cmd::Iterate { new_v: slice })
                .map_err(|_| anyhow::anyhow!("worker {p} command channel closed"))?;
        }
        let mut parts: Vec<Option<IterOut>> = (0..self.nparts).map(|_| None).collect();
        let mut t_ex = 0f64;
        let mut t_cp = 0f64;
        for _ in 0..self.nparts {
            let out = self
                .out_rx
                .recv_timeout(std::time::Duration::from_secs(60))
                .map_err(|e| anyhow::anyhow!("engine starved: {e}"))??;
            t_ex = t_ex.max(out.t_exchange);
            t_cp = t_cp.max(out.t_compute);
            let p = out.part;
            parts[p] = Some(out);
        }
        let mut w = Vec::with_capacity(self.n);
        for p in parts.into_iter() {
            w.extend(p.expect("every worker reported").w_local);
        }
        self.stats.iterations += 1;
        self.stats.wall_exchange += t_ex;
        self.stats.wall_compute += t_cp;
        self.stats.wall_total += t0.elapsed().as_secs_f64();
        if let Some(recorder) = self.recorder.as_mut() {
            recorder.observe(&self.pattern);
        }
        Ok(w)
    }

    /// Power iteration driven through the persistent engine.
    pub fn power_iterate(&mut self, v0: &[f32], iters: usize) -> Result<(Vec<f32>, f32)> {
        let mut v = v0.to_vec();
        let mut lambda = 0f32;
        let mut first = true;
        for _ in 0..iters {
            let w = if first { self.iterate(Some(&v))? } else { self.iterate(Some(&v))? };
            first = false;
            lambda = w.iter().fold(0f32, |m, x| m.max(x.abs()));
            anyhow::ensure!(lambda > 0.0, "power iteration collapsed to zero");
            v = w.iter().map(|x| x / lambda).collect();
        }
        Ok((v, lambda))
    }

    /// Shut workers down and join.
    pub fn shutdown(mut self) -> EngineStats {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.stats
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct WorkerState {
    part: usize,
    diag: Ell,
    offd: Ell,
    v_local: Vec<f32>,
    n_ghost: usize,
}

enum Backend {
    Rust,
    Pjrt(Box<PjrtBackend>),
}

/// PJRT backend with buffers padded once at startup.
struct PjrtBackend {
    exe: crate::runtime::Executable,
    diag_vals: Vec<f32>,
    diag_cols: Vec<i32>,
    offd_vals: Vec<f32>,
    offd_cols: Vec<i32>,
    v_local_pad: Vec<f32>,
    v_ghost_pad: Vec<f32>,
}

impl PjrtBackend {
    fn new(dir: &std::path::Path, st: &WorkerState) -> Result<PjrtBackend> {
        let spec = crate::runtime::fitting_spec(
            st.diag.nrows,
            st.diag.width.max(1),
            st.offd.width.max(1),
            st.n_ghost.max(1),
        )
        .with_context(|| {
            format!("no artifact fits rows={} dw={} ow={} ghost={}", st.diag.nrows, st.diag.width, st.offd.width, st.n_ghost)
        })?;
        let rt = crate::runtime::Runtime::new(dir)?;
        let exe = rt.load(&spec)?;
        let pad = |e: &Ell, rows: usize, width: usize| {
            let mut vals = vec![0f32; rows * width];
            let mut cols = vec![0i32; rows * width];
            for r in 0..e.nrows {
                for k in 0..e.width {
                    vals[r * width + k] = e.vals[r * e.width + k];
                    cols[r * width + k] = e.cols[r * e.width + k];
                }
            }
            (vals, cols)
        };
        let (diag_vals, diag_cols) = pad(&st.diag, spec.rows, spec.diag_width);
        let (offd_vals, offd_cols) = pad(&st.offd, spec.rows, spec.offd_width);
        let v_local_pad = vec![0f32; spec.rows];
        let v_ghost_pad = vec![0f32; spec.ghost];
        Ok(PjrtBackend { exe, diag_vals, diag_cols, offd_vals, offd_cols, v_local_pad, v_ghost_pad })
    }

    fn spmv(&mut self, v_local: &[f32], ghost: &[f32], n_out: usize) -> Result<Vec<f32>> {
        self.v_local_pad[..v_local.len()].copy_from_slice(v_local);
        self.v_ghost_pad[..ghost.len()].copy_from_slice(ghost);
        let mut w = self.exe.run_spmv(
            &self.diag_vals,
            &self.diag_cols,
            &self.offd_vals,
            &self.offd_cols,
            &self.v_local_pad,
            &self.v_ghost_pad,
        )?;
        w.truncate(n_out);
        Ok(w)
    }
}

fn assemble(source: &Source, v_local: &[f32], buffers: &HashMap<u64, Vec<f32>>) -> Vec<f32> {
    match source {
        Source::Owned(locals) => locals.iter().map(|&l| v_local[l]).collect(),
        Source::Buffers(refs) => refs.iter().map(|&(mid, off)| buffers[&mid][off]).collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    mut st: WorkerState,
    plan: &ExchangePlan,
    data_tx: &[Sender<Packet>],
    data_rx: Receiver<Packet>,
    barrier: &std::sync::Barrier,
    cmd_rx: Receiver<Cmd>,
    out_tx: Sender<Result<IterOut>>,
    cfg: EngineConfig,
) {
    // Build the compute backend ONCE (the §Perf fix: the one-shot path paid
    // this per iteration).
    let mut backend = if cfg.use_pjrt {
        match PjrtBackend::new(&cfg.artifacts_dir, &st) {
            Ok(b) => Backend::Pjrt(Box::new(b)),
            Err(e) => {
                let _ = out_tx.send(Err(e.context(format!("worker {} backend setup", st.part))));
                // Still participate in barriers? No: die; leader sees the error.
                return;
            }
        }
    } else {
        Backend::Rust
    };
    let mut ghost = vec![0f32; st.n_ghost];
    let mut buffers: HashMap<u64, Vec<f32>> = HashMap::new();

    while let Ok(cmd) = cmd_rx.recv() {
        let Cmd::Iterate { new_v } = cmd else { break };
        if let Some(v) = new_v {
            st.v_local = v;
        }
        buffers.clear();

        let t0 = Instant::now();
        let mut t_compute = 0f64;
        let mut w_diag: Option<Vec<f32>> = None;

        let run = (|| -> Result<()> {
            for (pi, phase) in plan.phases.iter().enumerate() {
                let me = &phase[st.part];
                for send in &me.sends {
                    let data = assemble(&send.source, &st.v_local, &buffers);
                    data_tx[send.to]
                        .send(Packet { mid: send.mid, data })
                        .map_err(|_| anyhow::anyhow!("worker {} send to {} failed", st.part, send.to))?;
                }
                // Overlap: after the *first* phase's sends are posted, the
                // diag product needs no remote data — compute it while the
                // exchange progresses (Algorithm 2 overlap).
                if cfg.overlap && pi == 0 && w_diag.is_none() {
                    let tc = Instant::now();
                    w_diag = Some(match &mut backend {
                        Backend::Rust => st.diag.spmv(&st.v_local),
                        // PJRT artifact fuses diag+offd; compute the diag
                        // share via the Rust kernel during overlap and use
                        // PJRT for the fused check-free path when not
                        // overlapping.
                        Backend::Pjrt(_) => st.diag.spmv(&st.v_local),
                    });
                    t_compute += tc.elapsed().as_secs_f64();
                }
                let mut missing: std::collections::BTreeSet<u64> =
                    me.recv_mids.iter().copied().filter(|mid| !buffers.contains_key(mid)).collect();
                while !missing.is_empty() {
                    let pkt = data_rx
                        .recv_timeout(std::time::Duration::from_secs(30))
                        .map_err(|e| anyhow::anyhow!("worker {} starved waiting for {missing:?}: {e}", st.part))?;
                    missing.remove(&pkt.mid);
                    buffers.insert(pkt.mid, pkt.data);
                }
            }
            for d in &plan.deliver[st.part] {
                ghost[d.ghost_pos] = buffers[&d.mid][d.offset];
            }
            barrier.wait();
            Ok(())
        })();

        if let Err(e) = run {
            let _ = out_tx.send(Err(e));
            return;
        }
        let t_exchange = t0.elapsed().as_secs_f64() - t_compute;

        let tc = Instant::now();
        let w_local: Result<Vec<f32>> = match (&mut backend, w_diag) {
            (Backend::Rust, Some(mut wd)) => {
                if st.n_ghost > 0 {
                    let wo = st.offd.spmv(&ghost);
                    for (a, b) in wd.iter_mut().zip(&wo) {
                        *a += b;
                    }
                }
                Ok(wd)
            }
            (Backend::Rust, None) => {
                let mut w = st.diag.spmv(&st.v_local);
                if st.n_ghost > 0 {
                    let wo = st.offd.spmv(&ghost);
                    for (a, b) in w.iter_mut().zip(&wo) {
                        *a += b;
                    }
                }
                Ok(w)
            }
            (Backend::Pjrt(p), Some(mut wd)) => {
                // overlapped diag (Rust) + offd through PJRT-padded arrays:
                // run the fused kernel with v_local zeroed to get offd only.
                let zeros = vec![0f32; st.v_local.len()];
                let vg = if ghost.is_empty() { vec![0.0] } else { ghost.clone() };
                match p.spmv(&zeros, &vg, st.diag.nrows) {
                    Ok(wo) => {
                        for (a, b) in wd.iter_mut().zip(&wo) {
                            *a += b;
                        }
                        Ok(wd)
                    }
                    Err(e) => Err(e),
                }
            }
            (Backend::Pjrt(p), None) => {
                let vg = if ghost.is_empty() { vec![0.0] } else { ghost.clone() };
                p.spmv(&st.v_local, &vg, st.diag.nrows)
            }
        };
        t_compute += tc.elapsed().as_secs_f64();

        match w_local {
            Ok(w) => {
                let _ = out_tx.send(Ok(IterOut { part: st.part, w_local: w, t_exchange, t_compute }));
            }
            Err(e) => {
                let _ = out_tx.send(Err(e.context(format!("worker {} compute", st.part))));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};
    use crate::sparse::gen;
    use crate::topology::machines::lassen;
    use crate::util::rng::Rng;

    fn strategy(kind: StrategyKind) -> Strategy {
        Strategy::new(kind, Transport::Staged).unwrap()
    }

    #[test]
    fn engine_matches_oracle() {
        let a = gen::stencil_27pt(6, 6, 6);
        let machine = lassen(2);
        let mut rng = Rng::new(3);
        let v: Vec<f32> = (0..a.nrows).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
        for kind in StrategyKind::ALL {
            let mut eng =
                Engine::new(&a, 8, &machine, strategy(kind), &v, EngineConfig::default()).unwrap();
            let w = eng.iterate(None).unwrap();
            let expect = a.spmv(&v);
            for (i, (x, y)) in expect.iter().zip(&w).enumerate() {
                assert!((x - y).abs() < 1e-3, "{kind:?} row {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn engine_overlap_equals_no_overlap() {
        let a = gen::stencil_27pt(6, 6, 8);
        let machine = lassen(2);
        let v: Vec<f32> = (0..a.nrows).map(|i| (i as f32).cos()).collect();
        let s = strategy(StrategyKind::ThreeStep);
        let mut e1 = Engine::new(&a, 8, &machine, s, &v, EngineConfig { overlap: true, ..Default::default() }).unwrap();
        let mut e2 = Engine::new(&a, 8, &machine, s, &v, EngineConfig { overlap: false, ..Default::default() }).unwrap();
        assert_eq!(e1.iterate(None).unwrap(), e2.iterate(None).unwrap());
    }

    #[test]
    fn engine_power_iteration() {
        let a = gen::stencil_5pt(10, 10);
        let machine = lassen(1);
        let v0 = vec![1f32; a.nrows];
        let mut eng = Engine::new(&a, 4, &machine, strategy(StrategyKind::SplitMd), &v0, EngineConfig::default()).unwrap();
        let (v, lambda) = eng.power_iterate(&v0, 40).unwrap();
        assert!(lambda > 4.0 && lambda < 8.0, "lambda {lambda}");
        let av = a.spmv(&v);
        let mut resid = 0f32;
        for (x, y) in av.iter().zip(&v) {
            resid = resid.max((x - lambda * y).abs());
        }
        assert!(resid < 0.3, "residual {resid}");
        let stats = eng.shutdown();
        assert_eq!(stats.iterations, 40);
        assert!(stats.wall_total > 0.0);
    }

    #[test]
    fn engine_new_vector_scatter() {
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(1);
        let v1 = vec![1f32; a.nrows];
        let v2: Vec<f32> = (0..a.nrows).map(|i| i as f32).collect();
        let mut eng = Engine::new(&a, 4, &machine, strategy(StrategyKind::Standard), &v1, EngineConfig::default()).unwrap();
        let w1 = eng.iterate(None).unwrap();
        assert_eq!(w1, a.spmv(&v1));
        let w2 = eng.iterate(Some(&v2)).unwrap();
        assert_eq!(w2, a.spmv(&v2));
        // switching back works too
        let w3 = eng.iterate(Some(&v1)).unwrap();
        assert_eq!(w3, w1);
    }

    #[test]
    fn engine_reuse_is_faster_than_oneshot_loop() {
        // The §Perf claim: N iterations through the persistent engine beat
        // N one-shot DistSpmv::run calls (thread spawn per call).
        use crate::coordinator::{DistSpmv, SpmvConfig};
        let a = gen::stencil_27pt(6, 6, 8);
        let machine = lassen(2);
        let v: Vec<f32> = (0..a.nrows).map(|i| (i as f32).sin()).collect();
        let s = strategy(StrategyKind::SplitMd);
        let iters = 10;

        let t0 = Instant::now();
        let mut eng = Engine::new(&a, 8, &machine, s, &v, EngineConfig::default()).unwrap();
        for _ in 0..iters {
            eng.iterate(None).unwrap();
        }
        let t_engine = t0.elapsed().as_secs_f64();
        drop(eng);

        let cfg = SpmvConfig { verify: false, ..Default::default() };
        let d = DistSpmv::new(&a, 8, &machine, s, cfg).unwrap();
        let t1 = Instant::now();
        for _ in 0..iters {
            d.run(&v, 1).unwrap();
        }
        let t_oneshot = t1.elapsed().as_secs_f64();

        assert!(
            t_engine < t_oneshot,
            "persistent engine {t_engine}s should beat one-shot loop {t_oneshot}s"
        );
    }

    #[test]
    fn engine_auto_picks_surface_winner_and_matches_oracle() {
        use crate::advisor::{DecisionSurface, Pattern, SurfaceAxes};
        let a = gen::stencil_27pt(6, 6, 6);
        let machine = lassen(2);
        let v: Vec<f32> = (0..a.nrows).map(|i| (i as f32).sin()).collect();
        let axes = SurfaceAxes {
            msgs: vec![16, 64, 256],
            sizes: vec![256, 4096, 65536],
            dest_nodes: vec![1, 4],
            gpus_per_node: vec![4],
        };
        let surface = DecisionSurface::compile("lassen", axes.clone(), 0.0).unwrap();
        let (mut eng, strategy) =
            Engine::new_auto(&a, 8, &machine, &surface, &v, EngineConfig::default()).unwrap();
        // the choice is exactly the surface's best for the derived query
        let pm = PartitionedMatrix::build(&a, 8);
        let stats = pm.comm_pattern(&machine, EngineConfig::default().elem_size).stats(&machine);
        let query = Pattern::from_stats(&stats, &machine);
        assert_eq!(strategy, surface.lookup(&query).best().0);
        // a surface compiled for another machine is rejected, not mis-served
        let frontier = DecisionSurface::compile("frontier-like", axes, 0.0).unwrap();
        assert!(Engine::new_auto(&a, 8, &machine, &frontier, &v, EngineConfig::default()).is_err());
        // and the engine still computes the right product with it
        let w = eng.iterate(None).unwrap();
        let expect = a.spmv(&v);
        for (i, (x, y)) in expect.iter().zip(&w).enumerate() {
            assert!((x - y).abs() < 1e-3, "row {i}: {x} vs {y}");
        }
    }

    #[test]
    fn engine_feeds_attached_recorder() {
        use crate::trace::TraceRecorder;
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(2);
        let v = vec![1f32; a.nrows];
        let mut eng =
            Engine::new(&a, 8, &machine, strategy(StrategyKind::ThreeStep), &v, EngineConfig::default()).unwrap();
        assert!(!eng.comm_pattern().is_empty(), "8 parts must exchange a halo");
        assert!(eng.take_recorder().is_none());
        eng.attach_recorder(TraceRecorder::new("unit", &machine, 5));
        for _ in 0..3 {
            eng.iterate(None).unwrap();
        }
        let expected = eng.comm_pattern().clone();
        let rec = eng.take_recorder().unwrap();
        assert_eq!(rec.iterations(), 3);
        let t = rec.finish().unwrap();
        assert_eq!(t.epochs.len(), 1, "a fixed partition coalesces to one epoch");
        assert_eq!(t.epochs[0].repeat, 3);
        assert_eq!(t.epochs[0].pattern, expected);
        // a detached recorder stops observing
        assert!(eng.take_recorder().is_none());
        eng.iterate(None).unwrap();
    }

    #[test]
    fn engine_rejects_bad_vector() {
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(1);
        let v = vec![1f32; a.nrows];
        let mut eng = Engine::new(&a, 4, &machine, strategy(StrategyKind::Standard), &v, EngineConfig::default()).unwrap();
        assert!(eng.iterate(Some(&vec![1.0; 5])).is_err());
    }
}
