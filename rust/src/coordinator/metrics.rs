//! Lightweight metrics: named wall-clock timers and counters with per-phase
//! breakdowns, shared across coordinator threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    timers: BTreeMap<String, Vec<f64>>,
    counters: BTreeMap<String, u64>,
}

/// RAII timer guard: records elapsed seconds on drop.
pub struct TimerGuard<'a> {
    metrics: &'a Metrics,
    name: String,
    start: Instant,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Start a named timer; elapsed time is recorded when the guard drops.
    pub fn timer(&self, name: impl Into<String>) -> TimerGuard<'_> {
        TimerGuard { metrics: self, name: name.into(), start: Instant::now() }
    }

    /// Record an explicit timing sample.
    pub fn record(&self, name: &str, seconds: f64) {
        self.inner.lock().unwrap().timers.entry(name.to_string()).or_default().push(seconds);
    }

    /// Increment a counter.
    pub fn count(&self, name: &str, by: u64) {
        *self.inner.lock().unwrap().counters.entry(name.to_string()).or_default() += by;
    }

    /// Sum of samples for a timer (0.0 when absent).
    pub fn total(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().timers.get(name).map(|v| v.iter().sum()).unwrap_or(0.0)
    }

    /// Number of samples for a timer.
    pub fn samples(&self, name: &str) -> usize {
        self.inner.lock().unwrap().timers.get(name).map(|v| v.len()).unwrap_or(0)
    }

    /// Counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Snapshot: (timer name → (count, total seconds)), (counter → value).
    pub fn snapshot(&self) -> (BTreeMap<String, (usize, f64)>, BTreeMap<String, u64>) {
        let inner = self.inner.lock().unwrap();
        let timers = inner.timers.iter().map(|(k, v)| (k.clone(), (v.len(), v.iter().sum()))).collect();
        (timers, inner.counters.clone())
    }

    /// Human-readable report.
    pub fn report(&self) -> String {
        let (timers, counters) = self.snapshot();
        let mut out = String::new();
        for (name, (n, total)) in timers {
            out.push_str(&format!("{name}: {n} samples, total {total:.6}s, mean {:.3e}s\n", total / n.max(1) as f64));
        }
        for (name, v) in counters {
            out.push_str(&format!("{name}: {v}\n"));
        }
        out
    }
}

impl Drop for TimerGuard<'_> {
    fn drop(&mut self) {
        self.metrics.record(&self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_records_on_drop() {
        let m = Metrics::new();
        {
            let _g = m.timer("phase");
            std::hint::black_box(0);
        }
        assert_eq!(m.samples("phase"), 1);
        assert!(m.total("phase") >= 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("msgs", 3);
        m.count("msgs", 4);
        assert_eq!(m.counter("msgs"), 7);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn snapshot_and_report() {
        let m = Metrics::new();
        m.record("x", 0.5);
        m.record("x", 1.5);
        m.count("c", 2);
        let (timers, counters) = m.snapshot();
        assert_eq!(timers["x"], (2, 2.0));
        assert_eq!(counters["c"], 2);
        assert!(m.report().contains("x: 2 samples"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.count("n", 1);
                        m.record("t", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
        assert_eq!(m.samples("t"), 400);
    }
}
