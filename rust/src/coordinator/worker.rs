//! The distributed-SpMV engine: per-GPU worker threads driven by a leader.
//!
//! Workers hold their part's diag/offd blocks (ELL) and either a PJRT
//! executable (the AOT JAX/Pallas kernel) or the in-Rust ELL fallback.
//! Each iteration: (1) halo exchange following the strategy's
//! [`ExchangePlan`] — real bytes through real channels; (2) local SpMV.
//! Wall time is measured per phase; the Lassen-calibrated simulated time of
//! the equivalent [`crate::comm::Schedule`] is attached for reporting.

use super::metrics::Metrics;
use super::router::{Deliver, ExchangePlan, Source};
use crate::comm::{build_schedule, Strategy, StrategyKind};
use crate::sim::{self, SimReport};
use crate::sparse::csr::{Csr, Ell};
use crate::sparse::PartitionedMatrix;
use crate::topology::Machine;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Configuration of a distributed SpMV run.
#[derive(Clone, Debug)]
pub struct SpmvConfig {
    /// Bytes per communicated vector value (8 = double precision, as in the
    /// paper's benchmarks).
    pub elem_size: usize,
    /// Execute local compute through the PJRT-loaded AOT artifact instead
    /// of the in-Rust ELL kernel.
    pub use_pjrt: bool,
    /// Artifact directory for PJRT mode.
    pub artifacts_dir: std::path::PathBuf,
    /// Verify each run against the serial CSR oracle.
    pub verify: bool,
}

impl Default for SpmvConfig {
    fn default() -> Self {
        SpmvConfig { elem_size: 8, use_pjrt: false, artifacts_dir: "artifacts".into(), verify: true }
    }
}

/// Report of one distributed run.
#[derive(Clone, Debug)]
pub struct SpmvRunReport {
    /// Result vector `w = A·v`.
    pub w: Vec<f32>,
    /// Real seconds spent in halo exchange (max over workers, summed over
    /// iterations).
    pub wall_exchange: f64,
    /// Real seconds in local compute (max over workers).
    pub wall_compute: f64,
    /// Simulated (Lassen-calibrated) exchange seconds for one iteration.
    pub sim_exchange_per_iter: f64,
    /// Messages per iteration in the exchange plan.
    pub msgs_per_iter: usize,
    /// Oracle verification outcome (None = not requested).
    pub verified: Option<bool>,
    /// Max |w − oracle| when verified.
    pub max_abs_err: f32,
}

/// One worker's static data.
struct WorkerData {
    part: usize,
    diag: Ell,
    offd: Ell,
    v_local: Vec<f32>,
    n_ghost: usize,
}

/// Message packet on the data plane.
struct Packet {
    mid: u64,
    data: Vec<f32>,
}

/// Local compute backend.
enum ComputeBackend {
    Rust,
    Pjrt(Box<PjrtCompute>),
}

/// Padded buffers + executable for PJRT execution.
struct PjrtCompute {
    exe: crate::runtime::Executable,
    diag_vals: Vec<f32>,
    diag_cols: Vec<i32>,
    offd_vals: Vec<f32>,
    offd_cols: Vec<i32>,
    rows: usize,
    ghost: usize,
}

impl PjrtCompute {
    /// Pad the worker's ELL blocks to the artifact's static shapes.
    fn new(artifacts_dir: &std::path::Path, wd: &WorkerData) -> Result<PjrtCompute> {
        let spec = crate::runtime::fitting_spec(
            wd.diag.nrows,
            wd.diag.width.max(1),
            wd.offd.width.max(1),
            wd.n_ghost.max(1),
        )
        .with_context(|| {
            format!(
                "no artifact fits rows={} dw={} ow={} ghost={}",
                wd.diag.nrows, wd.diag.width, wd.offd.width, wd.n_ghost
            )
        })?;
        let rt = crate::runtime::Runtime::new(artifacts_dir)?;
        let exe = rt.load(&spec)?;
        let pad_ell = |e: &Ell, rows: usize, width: usize| -> (Vec<f32>, Vec<i32>) {
            let mut vals = vec![0f32; rows * width];
            let mut cols = vec![0i32; rows * width];
            for r in 0..e.nrows {
                for k in 0..e.width {
                    vals[r * width + k] = e.vals[r * e.width + k];
                    cols[r * width + k] = e.cols[r * e.width + k];
                }
            }
            (vals, cols)
        };
        let (diag_vals, diag_cols) = pad_ell(&wd.diag, spec.rows, spec.diag_width);
        let (offd_vals, offd_cols) = pad_ell(&wd.offd, spec.rows, spec.offd_width);
        let (rows, ghost) = (spec.rows, spec.ghost);
        Ok(PjrtCompute { exe, diag_vals, diag_cols, offd_vals, offd_cols, rows, ghost })
    }

    fn spmv(&self, v_local: &[f32], ghost: &[f32], n_out: usize) -> Result<Vec<f32>> {
        let mut vl = vec![0f32; self.rows];
        vl[..v_local.len()].copy_from_slice(v_local);
        let mut vg = vec![0f32; self.ghost];
        vg[..ghost.len()].copy_from_slice(ghost);
        let mut w = self.exe.run_spmv(&self.diag_vals, &self.diag_cols, &self.offd_vals, &self.offd_cols, &vl, &vg)?;
        w.truncate(n_out);
        Ok(w)
    }
}

/// A distributed SpMV instance: matrix partitioned, plan compiled,
/// simulated clock attached.
pub struct DistSpmv {
    pub machine: Machine,
    pub strategy: Strategy,
    pub pm: Arc<PartitionedMatrix>,
    pub plan: Arc<ExchangePlan>,
    pub sim_report: SimReport,
    config: SpmvConfig,
    oracle: Option<Csr>,
    pub metrics: Arc<Metrics>,
}

impl DistSpmv {
    /// Partition `a` across `nparts` GPUs of `machine` and compile the
    /// exchange plan for `strategy`.
    pub fn new(a: &Csr, nparts: usize, machine: &Machine, strategy: Strategy, config: SpmvConfig) -> Result<DistSpmv> {
        anyhow::ensure!(nparts <= machine.total_gpus(), "{nparts} parts exceed {} GPUs", machine.total_gpus());
        let pm = PartitionedMatrix::build(a, nparts);
        let plan = ExchangePlan::build(&pm, machine, strategy);
        plan.validate(&pm).map_err(|e| anyhow::anyhow!("invalid exchange plan: {e}"))?;

        let pattern = pm.comm_pattern(machine, config.elem_size);
        let schedule = build_schedule(strategy, machine, &pattern);
        let ppn = match strategy.kind {
            StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
            _ => machine.gpus_per_node() * strategy.kind.ppg(),
        };
        let sim_report = sim::run(machine, &crate::params::lassen_params(), &schedule, ppn);

        let oracle = if config.verify { Some(a.clone()) } else { None };
        Ok(DistSpmv {
            machine: machine.clone(),
            strategy,
            pm: Arc::new(pm),
            plan: Arc::new(plan),
            sim_report,
            config,
            oracle,
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Run `iters` iterations of `w = A·v` with fixed `v` (the Section 5
    /// benchmark mode: the same communication pattern exercised
    /// repeatedly). Returns the assembled result and timing report.
    pub fn run(&self, v: &[f32], iters: usize) -> Result<SpmvRunReport> {
        anyhow::ensure!(v.len() == self.pm.partition.n, "v length mismatch");
        anyhow::ensure!(iters >= 1);
        let nparts = self.pm.partition.nparts();

        let mut worker_data = Vec::with_capacity(nparts);
        for p in 0..nparts {
            let (r0, r1) = self.pm.partition.range(p);
            let blocks = &self.pm.parts[p];
            worker_data.push(WorkerData {
                part: p,
                diag: blocks.diag.to_ell(blocks.diag.max_row_nnz().max(1)),
                offd: blocks.offd.to_ell(blocks.offd.max_row_nnz().max(1)),
                v_local: v[r0..r1].to_vec(),
                n_ghost: blocks.halo.len(),
            });
        }

        let mut senders: Vec<Sender<Packet>> = Vec::with_capacity(nparts);
        let mut receivers: Vec<Option<Receiver<Packet>>> = Vec::with_capacity(nparts);
        for _ in 0..nparts {
            let (tx, rx) = channel::<Packet>();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        let senders = Arc::new(senders);
        // Iteration barrier: message ids repeat every iteration, so a fast
        // worker must not launch iteration k+1 sends while a peer still
        // waits on iteration k (it would consume the id early and starve).
        let barrier = Arc::new(std::sync::Barrier::new(nparts));

        let mut outcomes: Vec<Result<(Vec<f32>, f64, f64)>> = Vec::with_capacity(nparts);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(nparts);
            for wd in worker_data {
                let plan = Arc::clone(&self.plan);
                let senders = Arc::clone(&senders);
                let rx = receivers[wd.part].take().expect("one receiver per worker");
                let barrier = Arc::clone(&barrier);
                let use_pjrt = self.config.use_pjrt;
                let dir = self.config.artifacts_dir.clone();
                handles.push(scope.spawn(move || worker_main(wd, &plan, &senders, rx, &barrier, iters, use_pjrt, &dir)));
            }
            for h in handles {
                outcomes.push(h.join().expect("worker panicked"));
            }
        });

        // Surface root-cause errors first: a worker that fails setup (e.g.
        // no artifact fits) makes its peers die with send/starvation
        // errors; report the setup failure, not the symptom.
        if outcomes.iter().any(|o| o.is_err()) {
            let mut errs: Vec<String> = outcomes.iter().filter_map(|o| o.as_ref().err()).map(|e| format!("{e:#}")).collect();
            errs.sort_by_key(|e| e.contains("send to") || e.contains("starved"));
            anyhow::bail!("distributed run failed: {}", errs.join(" | "));
        }
        let mut w = Vec::with_capacity(self.pm.partition.n);
        let mut wall_exchange = 0f64;
        let mut wall_compute = 0f64;
        for out in outcomes {
            let (w_local, t_ex, t_cp) = out?;
            w.extend(w_local);
            wall_exchange = wall_exchange.max(t_ex);
            wall_compute = wall_compute.max(t_cp);
        }
        self.metrics.record("run.exchange", wall_exchange);
        self.metrics.record("run.compute", wall_compute);

        let (verified, max_abs_err) = match &self.oracle {
            Some(a) => {
                let expect = a.spmv(v);
                let mut max_err = 0f32;
                for (x, y) in expect.iter().zip(&w) {
                    max_err = max_err.max((x - y).abs());
                }
                let scale = expect.iter().fold(1f32, |m, x| m.max(x.abs()));
                (Some(max_err <= 1e-4 * scale), max_err)
            }
            None => (None, 0.0),
        };

        Ok(SpmvRunReport {
            w,
            wall_exchange,
            wall_compute,
            sim_exchange_per_iter: self.sim_report.total,
            msgs_per_iter: self.plan.total_msgs(),
            verified,
            max_abs_err,
        })
    }

    /// Power iteration: `iters` steps of `v ← A·v / ‖A·v‖∞` — the e2e
    /// workload. Returns (final vector, dominant-eigenvalue estimate,
    /// per-iteration reports' aggregate wall times).
    pub fn power_iterate(&self, v0: &[f32], iters: usize) -> Result<(Vec<f32>, f32, f64, f64)> {
        let mut v = v0.to_vec();
        let mut lambda = 0f32;
        let mut t_ex = 0f64;
        let mut t_cp = 0f64;
        for _ in 0..iters {
            let rep = self.run(&v, 1)?;
            if let Some(false) = rep.verified {
                anyhow::bail!("verification failed during power iteration (max err {})", rep.max_abs_err);
            }
            lambda = rep.w.iter().fold(0f32, |m, x| m.max(x.abs()));
            anyhow::ensure!(lambda > 0.0, "power iteration collapsed to zero");
            v = rep.w.iter().map(|x| x / lambda).collect();
            t_ex += rep.wall_exchange;
            t_cp += rep.wall_compute;
        }
        Ok((v, lambda, t_ex, t_cp))
    }

    /// Total halo values exchanged per iteration.
    pub fn halo_values(&self) -> usize {
        self.pm.total_halo()
    }
}

fn assemble(source: &Source, v_local: &[f32], buffers: &HashMap<u64, Vec<f32>>) -> Vec<f32> {
    match source {
        Source::Owned(locals) => locals.iter().map(|&l| v_local[l]).collect(),
        Source::Buffers(refs) => refs.iter().map(|&(mid, off)| buffers[&mid][off]).collect(),
    }
}

fn deliver_ghost(deliveries: &[Deliver], buffers: &HashMap<u64, Vec<f32>>, ghost: &mut [f32]) {
    for d in deliveries {
        ghost[d.ghost_pos] = buffers[&d.mid][d.offset];
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    wd: WorkerData,
    plan: &ExchangePlan,
    senders: &[Sender<Packet>],
    rx: Receiver<Packet>,
    barrier: &std::sync::Barrier,
    iters: usize,
    use_pjrt: bool,
    artifacts_dir: &std::path::Path,
) -> Result<(Vec<f32>, f64, f64)> {
    let backend = if use_pjrt {
        ComputeBackend::Pjrt(Box::new(PjrtCompute::new(artifacts_dir, &wd)?))
    } else {
        ComputeBackend::Rust
    };
    let mut ghost = vec![0f32; wd.n_ghost];
    let mut buffers: HashMap<u64, Vec<f32>> = HashMap::new();
    let mut t_exchange = 0f64;
    let mut t_compute = 0f64;
    let mut w_local: Vec<f32> = Vec::new();

    for _iter in 0..iters {
        buffers.clear();
        let t0 = Instant::now();
        for phase in &plan.phases {
            let me = &phase[wd.part];
            for send in &me.sends {
                let data = assemble(&send.source, &wd.v_local, &buffers);
                senders[send.to]
                    .send(Packet { mid: send.mid, data })
                    .map_err(|_| anyhow::anyhow!("worker {} send to {} failed", wd.part, send.to))?;
            }
            // Collect this phase's expected messages (packets from later
            // phases cannot arrive before we send ours, but packets for
            // *this* phase may interleave arbitrarily).
            let mut missing: std::collections::BTreeSet<u64> =
                me.recv_mids.iter().copied().filter(|mid| !buffers.contains_key(mid)).collect();
            while !missing.is_empty() {
                let pkt = rx
                    .recv_timeout(std::time::Duration::from_secs(30))
                    .map_err(|e| anyhow::anyhow!("worker {} starved waiting for {missing:?}: {e}", wd.part))?;
                missing.remove(&pkt.mid);
                buffers.insert(pkt.mid, pkt.data);
            }
        }
        deliver_ghost(&plan.deliver[wd.part], &buffers, &mut ghost);
        barrier.wait(); // see barrier comment in DistSpmv::run
        t_exchange += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        w_local = match &backend {
            ComputeBackend::Rust => {
                let mut w = wd.diag.spmv(&wd.v_local);
                if wd.n_ghost > 0 {
                    let wo = wd.offd.spmv(&ghost);
                    for (a, b) in w.iter_mut().zip(&wo) {
                        *a += b;
                    }
                }
                w
            }
            ComputeBackend::Pjrt(p) => {
                // The artifact computes diag·v_local + offd·v_ghost in one
                // fused kernel; ghost padding slots are zero so they
                // contribute nothing.
                let mut vg = ghost.clone();
                if vg.is_empty() {
                    vg = vec![0.0];
                }
                p.spmv(&wd.v_local, &vg, wd.diag.nrows)?
            }
        };
        t_compute += t1.elapsed().as_secs_f64();
    }

    Ok((w_local, t_exchange, t_compute))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Transport;
    use crate::sparse::gen;
    use crate::topology::machines::lassen;
    use crate::util::rng::Rng;

    fn random_v(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect()
    }

    fn all_strategies() -> Vec<Strategy> {
        vec![
            Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::TwoStep, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap(),
        ]
    }

    #[test]
    fn distributed_matches_oracle_all_strategies() {
        let a = gen::stencil_27pt(6, 6, 6);
        let machine = lassen(2);
        let v = random_v(a.nrows, 7);
        for s in all_strategies() {
            let d = DistSpmv::new(&a, 8, &machine, s, SpmvConfig::default()).unwrap();
            let rep = d.run(&v, 1).unwrap();
            assert_eq!(rep.verified, Some(true), "{}: max err {}", s.label(), rep.max_abs_err);
        }
    }

    #[test]
    fn arrow_matrix_heavy_duplicates_verified() {
        let mut rng = Rng::new(3);
        let a = gen::arrow(320, 16, 4, &mut rng);
        let machine = lassen(2);
        let v = random_v(a.nrows, 11);
        for s in all_strategies() {
            let d = DistSpmv::new(&a, 8, &machine, s, SpmvConfig::default()).unwrap();
            let rep = d.run(&v, 1).unwrap();
            assert_eq!(rep.verified, Some(true), "{}: max err {}", s.label(), rep.max_abs_err);
        }
    }

    #[test]
    fn multiple_iterations_accumulate_time() {
        let a = gen::stencil_5pt(16, 16);
        let machine = lassen(1);
        let v = random_v(a.nrows, 5);
        let d = DistSpmv::new(&a, 4, &machine, all_strategies()[0], SpmvConfig::default()).unwrap();
        let r1 = d.run(&v, 1).unwrap();
        let r3 = d.run(&v, 3).unwrap();
        assert_eq!(r1.w, r3.w, "fixed-v iterations must be idempotent");
        assert!(r3.wall_exchange >= r1.wall_exchange * 0.5);
    }

    #[test]
    fn sim_report_attached() {
        let a = gen::stencil_27pt(4, 4, 8);
        let machine = lassen(2);
        let d = DistSpmv::new(&a, 8, &machine, all_strategies()[2], SpmvConfig::default()).unwrap();
        assert!(d.sim_report.total > 0.0);
        assert!(d.sim_report.internode_msgs > 0);
    }

    #[test]
    fn power_iteration_converges_on_spd() {
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(1);
        let d = DistSpmv::new(&a, 4, &machine, all_strategies()[0], SpmvConfig::default()).unwrap();
        let v0 = vec![1f32; a.nrows];
        let (v, lambda, _, _) = d.power_iterate(&v0, 30).unwrap();
        // 2D Laplacian dominant eigenvalue < 8, > 4; residual small-ish.
        assert!(lambda > 4.0 && lambda < 8.0, "lambda {lambda}");
        let av = a.spmv(&v);
        let mut resid = 0f32;
        for (x, y) in av.iter().zip(&v) {
            resid = resid.max((x - lambda * y).abs());
        }
        assert!(resid < 0.5, "residual {resid}");
    }

    #[test]
    fn mismatched_v_rejected() {
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(1);
        let d = DistSpmv::new(&a, 4, &machine, all_strategies()[0], SpmvConfig::default()).unwrap();
        assert!(d.run(&vec![0f32; 3], 1).is_err());
    }

    #[test]
    fn too_many_parts_rejected() {
        let a = gen::stencil_5pt(8, 8);
        let machine = lassen(1); // 4 GPUs
        assert!(DistSpmv::new(&a, 8, &machine, all_strategies()[0], SpmvConfig::default()).is_err());
    }
}
