//! L3 coordinator: the leader/worker distributed-SpMV engine.
//!
//! One worker thread per simulated GPU owns that part's matrix blocks and a
//! PJRT executable (or the in-Rust compute fallback); the leader drives
//! iterations. Every halo exchange *really moves bytes* between workers via
//! the strategy-shaped routing in [`router`], while the discrete-event
//! simulator provides the Lassen-calibrated clock for the same schedule.

pub mod engine;
pub mod metrics;
pub mod router;
pub mod worker;

pub use engine::{Engine, EngineConfig, EngineStats};
pub use router::ExchangePlan;
pub use worker::{DistSpmv, SpmvConfig, SpmvRunReport};
