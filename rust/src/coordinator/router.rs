//! Strategy-shaped halo-exchange routing for the real data plane.
//!
//! An [`ExchangePlan`] is compiled once per (partitioned matrix, machine,
//! strategy): it fixes, for every worker and every phase, which value
//! buffers to assemble and where to send them, entirely in terms of
//! precomputed index lists. At run time workers only gather f32 values and
//! ship them through channels — no index math on the hot path.
//!
//! The plan encodes each strategy's actual data path:
//! - **Standard** — one direct message per (src, dst) pair;
//! - **2-Step** — per (src GPU, dst node) union buffer to the rank-paired
//!   GPU, then on-node redistribution;
//! - **3-Step** — per (src node, dst node) gather onto the paired GPU, one
//!   inter-node buffer, on-node redistribution;
//! - **Split (MD/DD)** — like 3-Step but the node buffer is split into
//!   `message_cap` chunks scattered round-robin over the destination node's
//!   GPUs before redistribution.
//!
//! Duplicate data (a value needed by several GPUs on one node) crosses the
//! network once in every node-aware plan — the union buffers dedup it — and
//! is fanned back out during redistribution, exactly as in Section 2.3.

use crate::comm::plan as cplan;
use crate::comm::{Strategy, StrategyKind};
use crate::sparse::PartitionedMatrix;
use crate::topology::{GpuId, Machine, NodeId};
use std::collections::BTreeMap;

/// Where an outgoing payload's values come from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Source {
    /// Gather from the worker's owned vector slice at these local indices.
    Owned(Vec<usize>),
    /// Assemble from previously received buffers: (message id, offset)
    /// per value.
    Buffers(Vec<(u64, usize)>),
}

/// One planned send.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SendSpec {
    /// Globally unique message id.
    pub mid: u64,
    pub to: usize,
    pub source: Source,
}

/// Per-worker, per-phase actions.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerPhase {
    pub sends: Vec<SendSpec>,
    /// Message ids this worker must have received before the phase ends.
    pub recv_mids: Vec<u64>,
}

/// Deliver instruction: after the final phase, ghost slot `ghost_pos` takes
/// the value at `offset` of message `mid`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Deliver {
    pub mid: u64,
    pub offset: usize,
    pub ghost_pos: usize,
}

/// A complete exchange plan.
#[derive(Clone, Debug)]
pub struct ExchangePlan {
    pub strategy: Strategy,
    pub n_workers: usize,
    /// `phases[ph][w]` — worker w's actions in phase ph.
    pub phases: Vec<Vec<WorkerPhase>>,
    /// `deliver[w]` — how worker w fills its ghost vector at the end.
    pub deliver: Vec<Vec<Deliver>>,
}

/// Builder state: assigns message ids and tracks buffer composition
/// (mid → the sorted global indices its values correspond to).
struct Builder {
    n_workers: usize,
    phases: Vec<Vec<WorkerPhase>>,
    deliver: Vec<Vec<Deliver>>,
    contents: BTreeMap<u64, Vec<usize>>,
    next_mid: u64,
}

impl Builder {
    fn new(n_workers: usize, n_phases: usize) -> Builder {
        Builder {
            n_workers,
            phases: vec![vec![WorkerPhase::default(); n_workers]; n_phases],
            deliver: vec![Vec::new(); n_workers],
            contents: BTreeMap::new(),
            next_mid: 0,
        }
    }

    fn send(&mut self, phase: usize, from: usize, to: usize, source: Source, globals: Vec<usize>) -> u64 {
        let mid = self.next_mid;
        self.next_mid += 1;
        self.contents.insert(mid, globals);
        self.phases[phase][from].sends.push(SendSpec { mid, to, source });
        self.phases[phase][to].recv_mids.push(mid);
        mid
    }

    /// Composition source referencing `globals` inside buffer `mid`.
    fn from_buffer(&self, mid: u64, globals: &[usize]) -> Source {
        let contents = &self.contents[&mid];
        let refs = globals
            .iter()
            .map(|g| {
                let off = contents.binary_search(g).unwrap_or_else(|_| panic!("global {g} not in buffer {mid}"));
                (mid, off)
            })
            .collect();
        Source::Buffers(refs)
    }

    fn finish(self, strategy: Strategy) -> ExchangePlan {
        ExchangePlan { strategy, n_workers: self.n_workers, phases: self.phases, deliver: self.deliver }
    }
}

impl ExchangePlan {
    /// Compile a plan for `pm` on `machine` under `strategy`. Workers are
    /// GPUs `0..nparts`.
    pub fn build(pm: &PartitionedMatrix, machine: &Machine, strategy: Strategy) -> ExchangePlan {
        let nparts = pm.partition.nparts();
        assert!(nparts <= machine.total_gpus(), "{nparts} parts > {} GPUs", machine.total_gpus());
        match strategy.kind {
            StrategyKind::Standard => Self::build_standard(pm, strategy, nparts),
            StrategyKind::TwoStep => Self::build_two_step(pm, machine, strategy, nparts),
            StrategyKind::ThreeStep => Self::build_three_step(pm, machine, strategy, nparts),
            StrategyKind::SplitMd | StrategyKind::SplitDd => Self::build_split(pm, machine, strategy, nparts),
        }
    }

    fn deliver_from(b: &mut Builder, pm: &PartitionedMatrix, dst: usize, mid: u64, globals_in_buf: &[usize], needed: &[usize]) {
        // needed: global ids this dst must place into its ghost slots.
        let halo = &pm.parts[dst].halo;
        for g in needed {
            let off = globals_in_buf.binary_search(g).expect("needed global missing from buffer");
            let ghost_pos = halo.binary_search(g).expect("needed global missing from halo");
            b.deliver[dst].push(Deliver { mid, offset: off, ghost_pos });
        }
    }

    /// Global indices part `src` must ship to part `dst` (sorted).
    fn pair_globals(pm: &PartitionedMatrix, src: usize, dst: usize) -> Vec<usize> {
        let (o0, _) = pm.partition.range(src);
        pm.send_to[src].get(&dst).map(|ls| ls.iter().map(|&l| o0 + l).collect()).unwrap_or_default()
    }

    /// Union of globals part `src` ships to any part in `dsts` (sorted,
    /// deduped) — the node-aware unique buffer.
    fn union_globals(pm: &PartitionedMatrix, src: usize, dsts: &[usize]) -> Vec<usize> {
        let mut u: Vec<usize> = dsts.iter().flat_map(|&d| Self::pair_globals(pm, src, d)).collect();
        u.sort_unstable();
        u.dedup();
        u
    }

    /// Destination parts on each node receiving from `src`, keyed by node.
    fn dests_by_node(pm: &PartitionedMatrix, machine: &Machine, src: usize) -> BTreeMap<NodeId, Vec<usize>> {
        let mut by_node: BTreeMap<NodeId, Vec<usize>> = BTreeMap::new();
        for &d in pm.send_to[src].keys() {
            by_node.entry(machine.gpu_node(GpuId(d))).or_default().push(d);
        }
        by_node
    }

    fn build_standard(pm: &PartitionedMatrix, strategy: Strategy, nparts: usize) -> ExchangePlan {
        let mut b = Builder::new(nparts, 1);
        for src in 0..nparts {
            let dsts: Vec<usize> = pm.send_to[src].keys().copied().collect();
            for dst in dsts {
                let globals = Self::pair_globals(pm, src, dst);
                if globals.is_empty() {
                    continue;
                }
                let locals = pm.send_to[src][&dst].clone();
                let mid = b.send(0, src, dst, Source::Owned(locals), globals.clone());
                Self::deliver_from(&mut b, pm, dst, mid, &globals, &globals);
            }
        }
        b.finish(strategy)
    }

    fn build_two_step(pm: &PartitionedMatrix, machine: &Machine, strategy: Strategy, nparts: usize) -> ExchangePlan {
        let mut b = Builder::new(nparts, 2);
        let (so, _) = (0, 0);
        let _ = so;
        for src in 0..nparts {
            let src_node = machine.gpu_node(GpuId(src));
            for (node, dsts) in Self::dests_by_node(pm, machine, src) {
                if node == src_node {
                    // Intra-node: direct delivery in phase 0.
                    for &dst in &dsts {
                        let globals = Self::pair_globals(pm, src, dst);
                        if globals.is_empty() {
                            continue;
                        }
                        let locals = pm.send_to[src][&dst].clone();
                        let mid = b.send(0, src, dst, Source::Owned(locals), globals.clone());
                        Self::deliver_from(&mut b, pm, dst, mid, &globals, &globals);
                    }
                    continue;
                }
                // Step 1: union buffer to the rank-paired GPU on `node`.
                let union = Self::union_globals(pm, src, &dsts);
                if union.is_empty() {
                    continue;
                }
                let (o0, _) = pm.partition.range(src);
                let locals: Vec<usize> = union.iter().map(|&g| g - o0).collect();
                let pair = cplan::gpu_rank_pair(machine, GpuId(src), node).0;
                // The paired worker may not exist as a partition part (when
                // nparts < machine GPUs); fall back to the first part on the
                // node.
                let pair = if pair < nparts { pair } else { dsts[0] };
                let m1 = b.send(0, src, pair, Source::Owned(locals), union.clone());
                // Step 2: redistribution.
                for &dst in &dsts {
                    let globals = Self::pair_globals(pm, src, dst);
                    if globals.is_empty() {
                        continue;
                    }
                    let source = b.from_buffer(m1, &globals);
                    let m2 = b.send(1, pair, dst, source, globals.clone());
                    Self::deliver_from(&mut b, pm, dst, m2, &globals, &globals);
                }
            }
        }
        b.finish(strategy)
    }

    fn build_three_step(pm: &PartitionedMatrix, machine: &Machine, strategy: Strategy, nparts: usize) -> ExchangePlan {
        let mut b = Builder::new(nparts, 3);
        // group (src node -> dst node) contributions
        let mut pair_contribs: BTreeMap<(NodeId, NodeId), Vec<(usize, Vec<usize>)>> = BTreeMap::new(); // (k,l) -> [(src part, union globals)]
        let mut pair_dsts: BTreeMap<(NodeId, NodeId), Vec<usize>> = BTreeMap::new();
        for src in 0..nparts {
            let k = machine.gpu_node(GpuId(src));
            for (l, dsts) in Self::dests_by_node(pm, machine, src) {
                if l == k {
                    // Intra-node: direct in phase 0.
                    for &dst in &dsts {
                        let globals = Self::pair_globals(pm, src, dst);
                        if globals.is_empty() {
                            continue;
                        }
                        let locals = pm.send_to[src][&dst].clone();
                        let mid = b.send(0, src, dst, Source::Owned(locals), globals.clone());
                        Self::deliver_from(&mut b, pm, dst, mid, &globals, &globals);
                    }
                    continue;
                }
                let union = Self::union_globals(pm, src, &dsts);
                if !union.is_empty() {
                    pair_contribs.entry((k, l)).or_default().push((src, union));
                    let e = pair_dsts.entry((k, l)).or_default();
                    for d in dsts {
                        if !e.contains(&d) {
                            e.push(d);
                        }
                    }
                }
            }
        }

        for ((k, l), contribs) in &pair_contribs {
            let leader = {
                let g = cplan::paired_gpu(machine, *k, *l).0;
                if g < nparts { g } else { contribs[0].0 }
            };
            let recv = {
                let g = cplan::paired_gpu(machine, *l, *k).0;
                if g < nparts { g } else { pair_dsts[&(*k, *l)][0] }
            };
            // Phase 0: gather contributions on the leader.
            let mut gathered: Vec<(u64, Vec<usize>, usize)> = Vec::new(); // (mid or self, globals, src part)
            for (src, union) in contribs {
                if *src == leader {
                    gathered.push((u64::MAX, union.clone(), *src));
                } else {
                    let (o0, _) = pm.partition.range(*src);
                    let locals: Vec<usize> = union.iter().map(|&g| g - o0).collect();
                    let mid = b.send(0, *src, leader, Source::Owned(locals), union.clone());
                    gathered.push((mid, union.clone(), *src));
                }
            }
            // Phase 1: one inter-node buffer, concatenated in gather order.
            let mut buf_globals: Vec<usize> = Vec::new();
            let mut buf_source: Vec<(u64, usize)> = Vec::new();
            let mut owned_locals: Vec<usize> = Vec::new();
            let leader_offset = pm.partition.range(leader).0;
            let all_owned = gathered.iter().all(|(mid, _, _)| *mid == u64::MAX);
            for (mid, globals, _src) in &gathered {
                for (i, &g) in globals.iter().enumerate() {
                    buf_globals.push(g);
                    if *mid == u64::MAX {
                        owned_locals.push(g - leader_offset);
                    } else {
                        buf_source.push((*mid, i));
                    }
                }
            }
            // Mixed owned+buffer sources need the buffer route: re-ship the
            // leader's own contribution through a self-send in phase 0 so the
            // phase-1 source is uniform.
            let source = if all_owned {
                Source::Owned(owned_locals)
            } else if owned_locals.is_empty() {
                Source::Buffers(buf_source)
            } else {
                // self-send the owned part first
                let own: Vec<usize> = gathered
                    .iter()
                    .filter(|(mid, _, _)| *mid == u64::MAX)
                    .flat_map(|(_, g, _)| g.clone())
                    .collect();
                let self_mid = b.send(0, leader, leader, Source::Owned(own.iter().map(|&g| g - leader_offset).collect()), own.clone());
                let mut refs: Vec<(u64, usize)> = Vec::with_capacity(buf_globals.len());
                for (mid, globals, _src) in &gathered {
                    for (i, &g) in globals.iter().enumerate() {
                        if *mid == u64::MAX {
                            let off = own.binary_search(&g).unwrap();
                            refs.push((self_mid, off));
                        } else {
                            refs.push((*mid, i));
                        }
                    }
                }
                Source::Buffers(refs)
            };
            let inter = b.send(1, leader, recv, source, buf_globals.clone());

            // Phase 2: redistribution to destination parts. Buffer may hold a
            // global more than once (two src GPUs owning different rows never
            // collide, but the same global from one src appears once per
            // contribution); binary search needs sorted uniqueness, so build
            // a lookup map instead.
            let mut lookup: BTreeMap<usize, usize> = BTreeMap::new();
            for (i, &g) in buf_globals.iter().enumerate() {
                lookup.entry(g).or_insert(i);
            }
            for &dst in &pair_dsts[&(*k, *l)] {
                // globals needed by dst from any src on node k
                let mut needed: Vec<usize> = contribs
                    .iter()
                    .flat_map(|(src, _)| Self::pair_globals(pm, *src, dst))
                    .collect();
                needed.sort_unstable();
                needed.dedup();
                if needed.is_empty() {
                    continue;
                }
                let refs: Vec<(u64, usize)> = needed.iter().map(|g| (inter, lookup[g])).collect();
                let mid = b.send(2, recv, dst, Source::Buffers(refs), needed.clone());
                Self::deliver_from(&mut b, pm, dst, mid, &needed, &needed);
            }
        }
        b.finish(strategy)
    }

    fn build_split(pm: &PartitionedMatrix, machine: &Machine, strategy: Strategy, nparts: usize) -> ExchangePlan {
        let mut b = Builder::new(nparts, 3);
        let cap_values = (strategy.message_cap / 8).max(1); // cap is in bytes; values are f64-equivalent 8 B in the paper

        for src in 0..nparts {
            let k = machine.gpu_node(GpuId(src));
            for (l, dsts) in Self::dests_by_node(pm, machine, src) {
                if l == k {
                    for &dst in &dsts {
                        let globals = Self::pair_globals(pm, src, dst);
                        if globals.is_empty() {
                            continue;
                        }
                        let locals = pm.send_to[src][&dst].clone();
                        let mid = b.send(0, src, dst, Source::Owned(locals), globals.clone());
                        Self::deliver_from(&mut b, pm, dst, mid, &globals, &globals);
                    }
                    continue;
                }
                let union = Self::union_globals(pm, src, &dsts);
                if union.is_empty() {
                    continue;
                }
                let (o0, _) = pm.partition.range(src);
                // Node GPUs on the destination node that exist as workers.
                let node_gpus: Vec<usize> =
                    machine.node_gpus(l).into_iter().map(|g| g.0).filter(|&g| g < nparts).collect();
                debug_assert!(!node_gpus.is_empty());
                // Phase 1 (== phase index 0..1): chunks scattered round-robin
                // over destination-node GPUs.
                let mut chunk_mids: Vec<(u64, Vec<usize>, usize)> = Vec::new(); // (mid, globals, recv gpu)
                for (ci, chunk) in union.chunks(cap_values).enumerate() {
                    let recv = node_gpus[ci % node_gpus.len()];
                    let locals: Vec<usize> = chunk.iter().map(|&g| g - o0).collect();
                    let mid = b.send(1, src, recv, Source::Owned(locals), chunk.to_vec());
                    chunk_mids.push((mid, chunk.to_vec(), recv));
                }
                // Phase 2: each chunk receiver forwards the values each dst
                // part needs from its chunk.
                for &dst in &dsts {
                    let needed = Self::pair_globals(pm, src, dst);
                    if needed.is_empty() {
                        continue;
                    }
                    for (mid, chunk_globals, recv) in &chunk_mids {
                        let mine: Vec<usize> =
                            needed.iter().copied().filter(|g| chunk_globals.binary_search(g).is_ok()).collect();
                        if mine.is_empty() {
                            continue;
                        }
                        if *recv == dst {
                            // Already on the destination worker: deliver
                            // directly from the chunk buffer.
                            Self::deliver_from(&mut b, pm, dst, *mid, chunk_globals, &mine);
                            continue;
                        }
                        let refs: Vec<(u64, usize)> =
                            mine.iter().map(|g| (*mid, chunk_globals.binary_search(g).unwrap())).collect();
                        let m2 = b.send(2, *recv, dst, Source::Buffers(refs), mine.clone());
                        Self::deliver_from(&mut b, pm, dst, m2, &mine, &mine);
                    }
                }
            }
        }
        b.finish(strategy)
    }

    /// Total messages across phases.
    pub fn total_msgs(&self) -> usize {
        self.phases.iter().flat_map(|ws| ws.iter()).map(|w| w.sends.len()).sum()
    }

    /// Sanity check: every ghost slot of every worker receives exactly one
    /// delivery. Returns Err(description) on violation.
    pub fn validate(&self, pm: &PartitionedMatrix) -> Result<(), String> {
        for (w, dels) in self.deliver.iter().enumerate() {
            let mut hit = vec![0usize; pm.parts[w].halo.len()];
            for d in dels {
                if d.ghost_pos >= hit.len() {
                    return Err(format!("worker {w}: ghost_pos {} out of range {}", d.ghost_pos, hit.len()));
                }
                hit[d.ghost_pos] += 1;
            }
            if let Some(pos) = hit.iter().position(|&h| h == 0) {
                return Err(format!("worker {w}: ghost slot {pos} never delivered"));
            }
            if let Some(pos) = hit.iter().position(|&h| h > 1) {
                return Err(format!("worker {w}: ghost slot {pos} delivered {}x", hit[pos]));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Transport;
    use crate::sparse::gen;
    use crate::topology::machines::lassen;

    fn strategies() -> Vec<Strategy> {
        vec![
            Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::TwoStep, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap(),
            Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap(),
        ]
    }

    #[test]
    fn all_strategies_validate_stencil() {
        let a = gen::stencil_27pt(6, 6, 6);
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, 8);
        for s in strategies() {
            let plan = ExchangePlan::build(&pm, &machine, s);
            plan.validate(&pm).unwrap_or_else(|e| panic!("{}: {e}", s.label()));
        }
    }

    #[test]
    fn all_strategies_validate_arrow() {
        let mut rng = crate::util::rng::Rng::new(2);
        let a = gen::arrow(400, 16, 4, &mut rng);
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, 8);
        for s in strategies() {
            let plan = ExchangePlan::build(&pm, &machine, s);
            plan.validate(&pm).unwrap_or_else(|e| panic!("{}: {e}", s.label()));
        }
    }

    #[test]
    fn standard_message_count_is_pair_count() {
        let a = gen::stencil_5pt(12, 12);
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, 8);
        let plan = ExchangePlan::build(&pm, &machine, strategies()[0]);
        let pairs: usize = pm.send_to.iter().map(|m| m.values().filter(|v| !v.is_empty()).count()).sum();
        assert_eq!(plan.total_msgs(), pairs);
    }

    #[test]
    fn three_step_one_internode_buffer_per_pair() {
        let a = gen::stencil_27pt(8, 4, 4);
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, 8);
        let plan =
            ExchangePlan::build(&pm, &machine, Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap());
        // phase 1 sends = inter-node buffers; stencil partitioned over 2
        // nodes has node0<->node1 traffic in both directions.
        let inter: usize = plan.phases[1].iter().map(|w| w.sends.len()).sum();
        assert_eq!(inter, 2);
    }

    #[test]
    fn split_chunks_capped() {
        let a = gen::stencil_27pt(8, 8, 4);
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, 8);
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap().with_cap(256);
        let plan = ExchangePlan::build(&pm, &machine, s);
        plan.validate(&pm).unwrap();
        let cap_values = 256 / 8;
        for wp in &plan.phases[1] {
            for send in &wp.sends {
                if let Source::Owned(ls) = &send.source {
                    assert!(ls.len() <= cap_values, "chunk {} > cap {cap_values}", ls.len());
                }
            }
        }
        // smaller cap -> more *inter-node* messages (phase 1 chunks) than
        // 3-step's single buffer per node pair (its phase 1).
        let plan3 =
            ExchangePlan::build(&pm, &machine, Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap());
        let inter = |p: &ExchangePlan| p.phases[1].iter().map(|w| w.sends.len()).sum::<usize>();
        assert!(inter(&plan) > inter(&plan3), "split {} !> 3-step {}", inter(&plan), inter(&plan3));
    }

    #[test]
    fn single_node_all_local() {
        let a = gen::stencil_5pt(10, 10);
        let machine = lassen(1);
        let pm = PartitionedMatrix::build(&a, 4);
        for s in strategies() {
            let plan = ExchangePlan::build(&pm, &machine, s);
            plan.validate(&pm).unwrap();
            // everything is intra-node: phases beyond 0 carry nothing
            for ph in plan.phases.iter().skip(1) {
                assert!(ph.iter().all(|w| w.sends.is_empty()), "{}", s.label());
            }
        }
    }
}
