//! The `hetcomm perf` self-benchmark harness: two suites, both on
//! deterministic seeded workloads.
//!
//! The **sweep** suite (default) measures the simulator hot paths the
//! ROADMAP treats as product metrics:
//!
//! - **sweep-compiled** — the production sweep cell loop (pattern lowered
//!   once per cell, compiled schedules, zero-allocation executor) in
//!   evaluated (cell × strategy) pairs per second;
//! - **sweep-reference** — the same cells through the retained naive path
//!   (per-strategy schedule rebuild + hash-map executor), the baseline the
//!   compiled path must beat by `--min-speedup`;
//! - **sweep-exhaustive** / **sweep-pruned** — the full production sweep
//!   on a pruning-friendly grid (many small messages) without and with
//!   `--prune --reuse-patterns`; the harness hard-errors if the pruned
//!   leg's winner/crossover/regime reports or model bits drift from the
//!   exhaustive run, and the pruned row carries the measured prune rate;
//! - **schedule-compile** — schedule build + SoA lowering throughput;
//! - **advise-burst** — cached advisor queries per second
//!   ([`AdvisorService::bench_burst`]).
//!
//! The **advise** suite (`--suite advise`) measures the serving engine on
//! a four-tenant fleet (lassen, frontier-like, frontier-4nic, delta-like):
//!
//! - **advise-burst** — steady-state snapshot reads: the seeded pool
//!   burst with per-query p50/p99 and the memo hit rate;
//! - **advise-miss** — a distinct-heavy stream through per-query
//!   [`AdvisorService::advise`], the mostly-uncached interpolation
//!   reference the batched path is priced against;
//! - **advise-batch** — the same stream through
//!   [`AdvisorService::advise_batch`]; the harness errors out unless the
//!   batched answers' digest matches the per-query leg bit for bit;
//! - **advise-simd** — the same stream with the four-wide lane
//!   interpolator forced on ([`AdvisorService::advise_batch_with`], the
//!   `simd` feature's default path); bit-identity with the per-query leg
//!   is a hard error, so its throughput delta over `advise-batch` is the
//!   measured lane speedup;
//! - **advise-publish** — full recalibrate → compile → publish
//!   round-trips on a separate service (timing only, answers unpinned).
//!
//! `speedup_vs_reference` is compiled-over-reference throughput in the
//! sweep suite and batched-over-per-query throughput in the advise suite.
//!
//! The emitted report is a versioned `hetcomm.bench.v1` JSON artifact. Its
//! *deterministic projection* (everything except wall-clock fields, which
//! `timing: false` emits as `null`) is byte-identical across runs and
//! machines for a fixed seed: work counts and FNV-1a checksums over the
//! result bits pin the *answers*, while throughput fields track the *time
//! to answer*. A suite only pins the checksums it computes; the others are
//! `null`. `BENCH_sweep.json` and `BENCH_advise.json` at the repo root
//! seed the committed performance trajectories (see docs/PERFORMANCE.md).

use crate::advisor::{AdvisorService, DecisionSurface, RankedStrategies, SurfaceAxes};
use crate::comm::{build_schedule_from, Strategy};
use crate::pattern::generators::Scenario;
use crate::sim::{self, CompiledPattern};
use crate::sweep::engine::eval_cell;
use crate::sweep::{effective_threads, ExecMode, GridSpec, PatternGen, SweepConfig};
use crate::topology::machines;
use crate::util::json::{fmt_f64, Json};
use crate::util::pool;
use crate::util::stats::percentile_sorted;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

/// Versioned schema id of the emitted artifact.
pub const SCHEMA: &str = "hetcomm.bench.v1";
/// Schema version (bump on breaking report-shape changes).
pub const VERSION: u64 = 1;

/// Which benchmark family a run measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// Simulator hot paths (sweep/schedule/burst) — the default.
    Sweep,
    /// The advisor serving engine (burst/miss/batch/publish).
    Advise,
}

impl Suite {
    pub fn parse(s: &str) -> Option<Suite> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sweep" => Some(Suite::Sweep),
            "advise" => Some(Suite::Advise),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Suite::Sweep => "sweep",
            Suite::Advise => "advise",
        }
    }
}

/// The artifact's `mode` string: suite plus workload size. The sweep suite
/// keeps its original shorthand ("quick"/"full") for baseline continuity.
fn mode_str(suite: Suite, quick: bool) -> &'static str {
    match (suite, quick) {
        (Suite::Sweep, true) => "quick",
        (Suite::Sweep, false) => "full",
        (Suite::Advise, true) => "advise-quick",
        (Suite::Advise, false) => "advise-full",
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct PerfConfig {
    /// Small CI-sized workload instead of the full one.
    pub quick: bool,
    /// Base seed for every seeded workload in the run.
    pub seed: u64,
    /// Worker threads (0 = all cores).
    pub threads: usize,
    /// Benchmark family to run.
    pub suite: Suite,
}

impl Default for PerfConfig {
    fn default() -> PerfConfig {
        PerfConfig { quick: true, seed: 42, threads: 0, suite: Suite::Sweep }
    }
}

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub name: &'static str,
    /// Work items evaluated (cell×strategy pairs, schedules, queries).
    pub items: usize,
    pub elapsed_s: f64,
    pub items_per_sec: f64,
    /// Per-item latency percentiles [s] (per *cell* for the sweeps).
    pub p50_s: f64,
    pub p99_s: f64,
    /// Advisor cache hit rate (advise-burst only).
    pub cache_hit_rate: Option<f64>,
    /// Fraction of strategy simulations skipped by bounds (sweep-pruned
    /// only). Deterministic, so it survives the `timing: false` projection.
    pub prune_rate: Option<f64>,
}

/// The full harness outcome.
#[derive(Clone, Debug)]
pub struct PerfReport {
    pub quick: bool,
    pub seed: u64,
    pub suite: Suite,
    /// Worker threads actually used (a measured property, not part of the
    /// deterministic projection).
    pub threads: usize,
    pub machine: String,
    /// Workload shape echoed for the artifact.
    pub cells: usize,
    pub strategies: usize,
    pub passes: usize,
    pub schedule_iters: usize,
    pub advise_queries: usize,
    /// FNV-1a checksums over the deterministic result bits; a suite pins
    /// only the ones it computes (`None` renders as `null`).
    pub checksum_sweep: Option<u64>,
    pub checksum_schedules: Option<u64>,
    pub checksum_advise: Option<u64>,
    pub results: Vec<BenchRow>,
    /// Fast-path throughput over its reference: compiled/reference for the
    /// sweep suite, batched/per-query for the advise suite.
    pub speedup_vs_reference: f64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_word(mut h: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv_str(mut h: u64, s: &str) -> u64 {
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The pruning-friendly grid for the pruned-vs-exhaustive legs: uniform
/// patterns, many small messages. Here the Standard strategies' per-message
/// floors sit far above the node-aware winners, so their (n_msgs-transfer)
/// simulations — the most expensive in every cell — are provably skippable.
fn prune_grid(quick: bool) -> GridSpec {
    GridSpec {
        gens: vec![PatternGen::Uniform],
        dest_nodes: if quick { vec![4] } else { vec![4, 8] },
        gpus_per_node: vec![4],
        nics: vec![1],
        sizes: if quick {
            vec![1 << 6, 1 << 7, 1 << 8, 1 << 9, 1 << 10]
        } else {
            vec![1 << 6, 1 << 7, 1 << 8, 1 << 9, 1 << 10, 1 << 11, 1 << 12, 1 << 13]
        },
        n_msgs: 256,
        dup_frac: 0.0,
    }
}

fn perf_grid(quick: bool) -> GridSpec {
    if quick {
        GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1 << 8, 1 << 12, 1 << 16],
            n_msgs: 64,
            dup_frac: 0.0,
        }
    } else {
        GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 8],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1 << 6, 1 << 10, 1 << 14, 1 << 18],
            n_msgs: 256,
            dup_frac: 0.0,
        }
    }
}

/// One timed sweep over the workload grid in the given executor mode.
/// Returns (checksum over result bits, per-cell latencies, elapsed seconds).
fn sweep_bench(config: &SweepConfig, mode: ExecMode, threads: usize, passes: usize) -> (u64, Vec<f64>, f64) {
    let (arch, params) = machines::parse(&config.machine, 1).expect("perf machine is registered");
    let compiled_params = params.compile();
    let cells = config.grid.cells();
    let mut checksum = FNV_OFFSET;
    let mut latencies = Vec::with_capacity(cells.len() * passes);
    let mut elapsed = 0.0f64;
    for pass in 0..passes {
        let t0 = Instant::now();
        let out = pool::map_with(cells.len(), threads, sim::Scratch::new, |scratch, i| {
            let t = Instant::now();
            let rows = eval_cell(config, &arch, &params, &compiled_params, &cells[i], mode, scratch);
            (rows, t.elapsed().as_secs_f64())
        });
        elapsed += t0.elapsed().as_secs_f64();
        for (rows, latency) in out {
            latencies.push(latency);
            if pass == 0 {
                // the checksum pins one pass; later passes must reproduce it
                for row in rows {
                    checksum = fnv_word(checksum, row.model_s.to_bits());
                    checksum = fnv_word(checksum, row.sim_s.map(f64::to_bits).unwrap_or(0));
                }
            }
        }
    }
    (checksum, latencies, elapsed)
}

fn row_from(name: &'static str, items: usize, elapsed_s: f64, latencies: &mut [f64]) -> BenchRow {
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    BenchRow {
        name,
        items,
        elapsed_s,
        items_per_sec: if elapsed_s > 0.0 { items as f64 / elapsed_s } else { f64::INFINITY },
        p50_s: percentile_sorted(latencies, 50.0),
        p99_s: percentile_sorted(latencies, 99.0),
        cache_hit_rate: None,
        prune_rate: None,
    }
}

/// Run the configured suite. Both suites double as equivalence checks:
/// the sweep suite fails if the compiled and reference sweeps ever
/// disagree on a result bit, the advise suite fails if the batched
/// interpolator's answers ever drift from the per-query path's.
pub fn run_perf(config: &PerfConfig) -> Result<PerfReport, String> {
    match config.suite {
        Suite::Sweep => run_sweep_suite(config),
        Suite::Advise => run_advise_suite(config),
    }
}

fn run_sweep_suite(config: &PerfConfig) -> Result<PerfReport, String> {
    let grid = perf_grid(config.quick);
    let cells = grid.cells().len();
    let strategies = Strategy::all().len();
    // enough passes to amortize scheduler noise on small CI runners — the
    // --min-speedup gate compares two wall-clock rates of this workload
    let passes = if config.quick { 3 } else { 4 };
    let schedule_iters = if config.quick { 50 } else { 200 };
    let advise_queries = if config.quick { 2000 } else { 20_000 };
    let threads = effective_threads(config.threads, cells);
    let sweep_config = SweepConfig {
        grid: grid.clone(),
        strategies: Strategy::all(),
        seed: config.seed,
        threads,
        sim: true,
        machine: "lassen".into(),
        ..Default::default()
    };

    // --- sweep: compiled vs naive per-strategy-rebuild reference ---
    let (sum_fast, mut lat_fast, t_fast) = sweep_bench(&sweep_config, ExecMode::Compiled, threads, passes);
    let (sum_ref, mut lat_ref, t_ref) = sweep_bench(&sweep_config, ExecMode::Reference, threads, passes);
    if sum_fast != sum_ref {
        return Err(format!(
            "compiled and reference sweeps disagree: checksum {sum_fast:#018x} != {sum_ref:#018x} — the hot path changed an answer"
        ));
    }
    let pair_items = cells * strategies * passes;
    let fast_row = row_from("sweep-compiled", pair_items, t_fast, &mut lat_fast);
    let ref_row = row_from("sweep-reference", pair_items, t_ref, &mut lat_ref);
    let speedup = if fast_row.items_per_sec.is_finite() && ref_row.items_per_sec > 0.0 {
        fast_row.items_per_sec / ref_row.items_per_sec
    } else {
        f64::INFINITY
    };

    // --- bound-guided pruning vs exhaustive on the pruning-friendly grid ---
    let exhaustive_config = SweepConfig {
        grid: prune_grid(config.quick),
        strategies: Strategy::all(),
        seed: config.seed,
        threads,
        sim: true,
        machine: "lassen".into(),
        ..Default::default()
    };
    let pruned_config =
        SweepConfig { prune: true, reuse_patterns: true, ..exhaustive_config.clone() };
    let prune_cells = exhaustive_config.grid.cells().len();
    let mut t_ex = 0.0f64;
    let mut t_pr = 0.0f64;
    let mut lat_ex = Vec::with_capacity(passes);
    let mut lat_pr = Vec::with_capacity(passes);
    let mut prune_rate = 0.0f64;
    for pass in 0..passes {
        let ex = crate::sweep::run_sweep(&exhaustive_config)?;
        let pr = crate::sweep::run_sweep(&pruned_config)?;
        t_ex += ex.elapsed_s;
        t_pr += pr.elapsed_s;
        lat_ex.push(ex.elapsed_s / prune_cells as f64);
        lat_pr.push(pr.elapsed_s / prune_cells as f64);
        if pass == 0 {
            // winner preservation is a correctness gate, not a best effort:
            // any drift in the derived reports or the model bits is an error
            let winner_key = |w: &crate::sweep::CellWinner| (w.size, w.winner, w.sim_winner, w.model_s.to_bits());
            if ex.report.winners.iter().map(winner_key).ne(pr.report.winners.iter().map(winner_key))
                || ex.report.crossovers != pr.report.crossovers
                || ex.report.regimes != pr.report.regimes
            {
                return Err("pruned sweep changed a winner/crossover/regime report — bounds are unsound".into());
            }
            if ex
                .cells
                .iter()
                .zip(&pr.cells)
                .any(|(a, b)| a.model_s.to_bits() != b.model_s.to_bits())
            {
                return Err("pruned sweep changed a model bit".into());
            }
            let sims = pr.report.prune.pruned + pr.report.prune.sim_evals;
            prune_rate = if sims > 0 { pr.report.prune.pruned as f64 / sims as f64 } else { 0.0 };
        }
    }
    let prune_items = prune_cells * strategies * passes;
    let ex_row = row_from("sweep-exhaustive", prune_items, t_ex, &mut lat_ex);
    let mut pr_row = row_from("sweep-pruned", prune_items, t_pr, &mut lat_pr);
    pr_row.prune_rate = Some(prune_rate);

    // --- schedule build + lowering throughput ---
    let (arch, params) = machines::parse("lassen", 1).expect("lassen is registered");
    let compiled_params = params.compile();
    let machine = grid.machine_for_arch(&arch, 4, 4, 1);
    let scenario = Scenario { n_msgs: grid.n_msgs, msg_size: 4096, n_dest: 4, dup_frac: 0.0 };
    let pattern = scenario.materialize(&machine);
    let lowered = CompiledPattern::lower(&machine, &pattern);
    let mut scratch = sim::Scratch::new();
    let mut checksum_schedules = FNV_OFFSET;
    let mut sched_lat = Vec::with_capacity(schedule_iters);
    let t0 = Instant::now();
    for iter in 0..schedule_iters {
        let t = Instant::now();
        for s in Strategy::all() {
            let schedule = build_schedule_from(s, &machine, &lowered);
            scratch.schedule.lower_into(&machine, &compiled_params, &schedule, s.sim_ppn(&machine));
            if iter == 0 {
                for &d in &scratch.schedule.x_dur {
                    checksum_schedules = fnv_word(checksum_schedules, d.to_bits());
                }
            }
        }
        sched_lat.push(t.elapsed().as_secs_f64() / strategies as f64);
    }
    let t_sched = t0.elapsed().as_secs_f64();
    let sched_row = row_from("schedule-compile", schedule_iters * strategies, t_sched, &mut sched_lat);

    // --- advisor burst ---
    let axes = if config.quick {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![1 << 8, 1 << 12, 1 << 16],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    } else {
        SurfaceAxes::default_axes()
    };
    let surface = DecisionSurface::compile("lassen", axes, 0.0)?;
    let service = AdvisorService::new(vec![surface]);
    let burst = service.bench_burst(advise_queries, config.seed, config.threads)?;
    let mut checksum_advise = FNV_OFFSET;
    for (label, count) in &burst.winners {
        checksum_advise = fnv_str(checksum_advise, label);
        checksum_advise = fnv_word(checksum_advise, *count as u64);
    }
    let advise_row = BenchRow {
        name: "advise-burst",
        items: burst.queries,
        elapsed_s: burst.elapsed_s,
        items_per_sec: if burst.elapsed_s > 0.0 { burst.queries as f64 / burst.elapsed_s } else { f64::INFINITY },
        p50_s: burst.p50_s,
        p99_s: burst.p99_s,
        cache_hit_rate: Some(burst.cache.hit_rate()),
        prune_rate: None,
    };

    Ok(PerfReport {
        quick: config.quick,
        seed: config.seed,
        suite: Suite::Sweep,
        threads,
        machine: "lassen".into(),
        cells,
        strategies,
        passes,
        schedule_iters,
        advise_queries,
        checksum_sweep: Some(sum_fast),
        checksum_schedules: Some(checksum_schedules),
        checksum_advise: Some(checksum_advise),
        results: vec![fast_row, ref_row, ex_row, pr_row, sched_row, advise_row],
        speedup_vs_reference: speedup,
    })
}

/// Machines the advise suite serves, spanning the registry's shapes:
/// 2-socket single-rail, 1-socket single-rail (two bandwidth classes), and
/// the shape-pinned 4-rail preset.
const FLEET: [&str; 4] = ["lassen", "frontier-like", "frontier-4nic", "delta-like"];

fn advise_axes(quick: bool) -> SurfaceAxes {
    if quick {
        SurfaceAxes {
            msgs: vec![64, 256],
            sizes: vec![1 << 8, 1 << 12, 1 << 16],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
        }
    } else {
        SurfaceAxes::default_axes()
    }
}

/// A fresh four-tenant service; each leg gets its own so memo state never
/// leaks between benchmarks.
fn fleet_service(quick: bool) -> Result<AdvisorService, String> {
    let surfaces = FLEET
        .iter()
        .map(|m| DecisionSurface::compile(m, advise_axes(quick), 0.0))
        .collect::<Result<Vec<_>, String>>()?;
    Ok(AdvisorService::new(surfaces))
}

/// FNV-1a over the full ranked answers — every (strategy, time-bits) pair
/// in query order, so any reordering or drifted bit moves the digest.
fn ranked_digest(answers: &[Arc<RankedStrategies>]) -> u64 {
    let mut h = FNV_OFFSET;
    for a in answers {
        for (s, t) in &a.ranked {
            h = fnv_str(h, s.label());
            h = fnv_word(h, t.to_bits());
        }
    }
    h
}

fn run_advise_suite(config: &PerfConfig) -> Result<PerfReport, String> {
    let advise_queries = if config.quick { 4000 } else { 40_000 };
    let threads = effective_threads(config.threads, advise_queries);

    // --- steady-state burst: seeded pool traffic, mostly memo hits ---
    let burst_service = fleet_service(config.quick)?;
    let burst = burst_service.bench_burst(advise_queries, config.seed, config.threads)?;
    let burst_row = BenchRow {
        name: "advise-burst",
        items: burst.queries,
        elapsed_s: burst.elapsed_s,
        items_per_sec: if burst.elapsed_s > 0.0 { burst.queries as f64 / burst.elapsed_s } else { f64::INFINITY },
        p50_s: burst.p50_s,
        p99_s: burst.p99_s,
        cache_hit_rate: Some(burst.cache.hit_rate()),
        prune_rate: None,
    };

    // --- per-query reference: a distinct-heavy stream, advised one at a
    // time on a fresh service (mostly interpolation, few repeats) ---
    let miss_service = fleet_service(config.quick)?;
    let queries = miss_service.seeded_queries(advise_queries, config.seed);
    let mut answers = Vec::with_capacity(queries.len());
    let mut miss_lat = Vec::with_capacity(queries.len());
    let t0 = Instant::now();
    for q in &queries {
        let t = Instant::now();
        answers.push(miss_service.advise(q)?);
        miss_lat.push(t.elapsed().as_secs_f64());
    }
    let t_miss = t0.elapsed().as_secs_f64();
    let sum_single = ranked_digest(&answers);
    let mut miss_row = row_from("advise-miss", queries.len(), t_miss, &mut miss_lat);
    miss_row.cache_hit_rate = Some(miss_service.cache_stats().hit_rate());

    // --- batched path: the same stream through advise_batch, sliced into
    // serving-sized batches; answers must match the per-query leg bit for
    // bit (the perf harness doubles as the equivalence check) ---
    let batch_service = fleet_service(config.quick)?;
    let batch_size = 256;
    let mut batch_answers = Vec::with_capacity(queries.len());
    let mut batch_lat = Vec::with_capacity(queries.len().div_ceil(batch_size));
    let t0 = Instant::now();
    for slice in queries.chunks(batch_size) {
        let t = Instant::now();
        let got = batch_service.advise_batch(slice, config.threads);
        batch_lat.push(t.elapsed().as_secs_f64() / slice.len() as f64);
        for a in got {
            batch_answers.push(a?);
        }
    }
    let t_batch = t0.elapsed().as_secs_f64();
    let sum_batch = ranked_digest(&batch_answers);
    if sum_single != sum_batch {
        return Err(format!(
            "batched interpolation changed an answer: per-query digest {sum_single:#018x} != batched {sum_batch:#018x}"
        ));
    }
    let mut batch_row = row_from("advise-batch", queries.len(), t_batch, &mut batch_lat);
    batch_row.cache_hit_rate = Some(batch_service.cache_stats().hit_rate());

    // --- lane-vectorized batched path: the same stream with the four-wide
    // interpolator forced on (the `simd` feature's path, runnable from any
    // build); bit-identity with the per-query leg is a hard error, so the
    // measured speedup is guaranteed to be a pure throughput delta ---
    let simd_service = fleet_service(config.quick)?;
    let mut simd_answers = Vec::with_capacity(queries.len());
    let mut simd_lat = Vec::with_capacity(queries.len().div_ceil(batch_size));
    let t0 = Instant::now();
    for slice in queries.chunks(batch_size) {
        let t = Instant::now();
        let got = simd_service.advise_batch_with(slice, config.threads, true);
        simd_lat.push(t.elapsed().as_secs_f64() / slice.len() as f64);
        for a in got {
            simd_answers.push(a?);
        }
    }
    let t_simd = t0.elapsed().as_secs_f64();
    let sum_simd = ranked_digest(&simd_answers);
    if sum_single != sum_simd {
        return Err(format!(
            "lane interpolation changed an answer: per-query digest {sum_single:#018x} != lanes {sum_simd:#018x}"
        ));
    }
    let mut simd_row = row_from("advise-simd", queries.len(), t_simd, &mut simd_lat);
    simd_row.cache_hit_rate = Some(simd_service.cache_stats().hit_rate());

    // --- publish cost: full recalibrate -> compile -> publish round-trips
    // on a separate service; timing only, so the drifted parameters never
    // touch the checksummed legs ---
    let publish_service = fleet_service(config.quick)?;
    let publishes = if config.quick { 8 } else { 32 };
    let mut pub_lat = Vec::with_capacity(publishes);
    let t0 = Instant::now();
    for i in 0..publishes {
        let name = FLEET[i % FLEET.len()];
        let (_, params) = machines::parse(name, 1)?;
        let drift = 1.0 + 0.01 * (i + 1) as f64;
        let t = Instant::now();
        publish_service.recalibrate(name, &params.scaled(drift, 1.0), 1, 1 << 30)?;
        pub_lat.push(t.elapsed().as_secs_f64());
    }
    let t_pub = t0.elapsed().as_secs_f64();
    let pub_row = row_from("advise-publish", publishes, t_pub, &mut pub_lat);

    let speedup = if batch_row.items_per_sec.is_finite() && miss_row.items_per_sec > 0.0 {
        batch_row.items_per_sec / miss_row.items_per_sec
    } else {
        f64::INFINITY
    };
    // the burst's winner histogram plus the per-query answer digest — the
    // full deterministic surface of the suite
    let mut checksum_advise = FNV_OFFSET;
    for (label, count) in &burst.winners {
        checksum_advise = fnv_str(checksum_advise, label);
        checksum_advise = fnv_word(checksum_advise, *count as u64);
    }
    checksum_advise = fnv_word(checksum_advise, sum_single);

    Ok(PerfReport {
        quick: config.quick,
        seed: config.seed,
        suite: Suite::Advise,
        threads,
        machine: format!("fleet-{}", FLEET.len()),
        cells: advise_axes(config.quick).len() * FLEET.len(),
        strategies: Strategy::all().len(),
        passes: 1,
        schedule_iters: 0,
        advise_queries,
        checksum_sweep: None,
        checksum_schedules: None,
        checksum_advise: Some(checksum_advise),
        results: vec![burst_row, miss_row, batch_row, simd_row, pub_row],
        speedup_vs_reference: speedup,
    })
}

fn hex(x: Option<u64>) -> String {
    match x {
        Some(v) => format!("\"{v:#018x}\""),
        None => "null".to_string(),
    }
}

fn opt_num(x: f64, timing: bool) -> String {
    if timing {
        fmt_f64(x)
    } else {
        "null".to_string()
    }
}

/// Serialize a report as `hetcomm.bench.v1` JSON. With `timing: false`
/// every wall-clock-derived field (and the thread count) is emitted as
/// `null`, yielding the byte-deterministic projection CI diffes.
pub fn report_to_json(r: &PerfReport, timing: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"version\": {VERSION},");
    let _ = writeln!(out, "  \"mode\": \"{}\",", mode_str(r.suite, r.quick));
    let _ = writeln!(out, "  \"machine\": \"{}\",", r.machine);
    // string seed: u64 values above 2^53 do not survive a JSON f64
    // round-trip (same convention as hetcomm.trace.v1)
    let _ = writeln!(out, "  \"seed\": \"{}\",", r.seed);
    let _ = writeln!(out, "  \"threads\": {},", if timing { r.threads.to_string() } else { "null".into() });
    let _ = writeln!(
        out,
        "  \"workload\": {{\"cells\": {}, \"strategies\": {}, \"passes\": {}, \"schedule_iters\": {}, \"advise_queries\": {}}},",
        r.cells, r.strategies, r.passes, r.schedule_iters, r.advise_queries
    );
    let _ = writeln!(
        out,
        "  \"checksums\": {{\"sweep\": {}, \"schedules\": {}, \"advise\": {}}},",
        hex(r.checksum_sweep),
        hex(r.checksum_schedules),
        hex(r.checksum_advise)
    );
    out.push_str("  \"results\": [\n");
    for (i, row) in r.results.iter().enumerate() {
        let comma = if i + 1 < r.results.len() { "," } else { "" };
        let hit = match row.cache_hit_rate {
            Some(h) if timing => fmt_f64(h),
            Some(_) => "null".to_string(),
            None => "null".to_string(),
        };
        // the prune rate is a deterministic answer, not a wall-clock
        // measurement: it survives the timing-free projection
        let prune = match row.prune_rate {
            Some(p) => fmt_f64(p),
            None => "null".to_string(),
        };
        let _ = writeln!(
            out,
            "    {{\"name\": \"{}\", \"items\": {}, \"elapsed_s\": {}, \"items_per_sec\": {}, \
             \"p50_s\": {}, \"p99_s\": {}, \"cache_hit_rate\": {}, \"prune_rate\": {}}}{comma}",
            row.name,
            row.items,
            opt_num(row.elapsed_s, timing),
            opt_num(row.items_per_sec, timing),
            opt_num(row.p50_s, timing),
            opt_num(row.p99_s, timing),
            hit,
            prune,
        );
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"speedup_vs_reference\": {}", opt_num(r.speedup_vs_reference, timing));
    out.push_str("}\n");
    out
}

/// Validate a parsed artifact against the `hetcomm.bench.v1` schema.
/// Returns the (mode, seed) pair so callers can decide checksum
/// comparability.
pub fn validate_artifact(doc: &Json) -> Result<(String, u64), String> {
    let schema = doc.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("schema {schema:?} is not {SCHEMA:?}"));
    }
    let version = doc.field("version")?.as_usize()?;
    if version as u64 != VERSION {
        return Err(format!("version {version} is not {VERSION}"));
    }
    let mode = doc.field("mode")?.as_str()?.to_string();
    // string seed (u64 > 2^53 is unsafe through the f64 JSON number path)
    let seed = doc
        .field("seed")?
        .as_str()?
        .parse::<u64>()
        .map_err(|e| format!("seed must be a u64 string: {e}"))?;
    let workload = doc.field("workload")?;
    for key in ["cells", "strategies", "passes", "schedule_iters", "advise_queries"] {
        workload.field(key)?.as_usize()?;
    }
    let checksums = doc.field("checksums")?;
    for key in ["sweep", "schedules", "advise"] {
        let v = checksums.field(key)?;
        if !matches!(v, Json::Null | Json::Str(_)) {
            return Err(format!("checksum {key:?} must be a hex string or null"));
        }
    }
    let results = doc.field("results")?.as_arr()?;
    if results.is_empty() {
        return Err("empty results".into());
    }
    for row in results {
        row.field("name")?.as_str()?;
        row.field("items")?.as_usize()?;
        for key in ["elapsed_s", "items_per_sec", "p50_s", "p99_s"] {
            if !matches!(row.field(key)?, Json::Null | Json::Num(_)) {
                return Err(format!("result field {key:?} must be a number or null"));
            }
        }
    }
    doc.field("speedup_vs_reference")?;
    Ok((mode, seed))
}

fn checksum_of(doc: &Json, key: &str) -> Result<Option<u64>, String> {
    match doc.field("checksums")?.field(key)? {
        Json::Null => Ok(None),
        Json::Str(s) => {
            let trimmed = s.trim_start_matches("0x");
            u64::from_str_radix(trimmed, 16).map(Some).map_err(|e| format!("bad checksum {s:?}: {e}"))
        }
        other => Err(format!("checksum {key:?}: unexpected {other:?}")),
    }
}

/// Compare a fresh report against a committed baseline artifact.
///
/// - Schema/version must validate.
/// - Checksums and throughput are only compared when the baseline's
///   (mode, seed) matches this run — different modes are different
///   workloads, so cross-mode rates are meaningless.
/// - When comparable and the baseline pins checksums, they must match bit
///   for bit (behavioral regressions fail fast, on any machine).
/// - When comparable and the baseline pins NO checksum at all (the
///   committed growth-seed projection), one explicit notice is returned —
///   `seed projection (null checksums) — throughput not compared` — with
///   the refresh workflow, and nothing is gated.
/// - When comparable and the baseline carries throughput numbers, the
///   current run must stay above `(1 - max_regression) ×` the baseline per
///   benchmark (machine-dependent; disable with `max_regression >= 1`).
///
/// Returns human-readable comparison notes on success.
pub fn compare_baseline(
    current: &PerfReport,
    baseline_text: &str,
    max_regression: f64,
) -> Result<Vec<String>, String> {
    let doc = Json::parse(baseline_text).map_err(|e| format!("baseline is not valid JSON: {e}"))?;
    let (mode, seed) = validate_artifact(&doc)?;
    let mut notes = Vec::new();
    let comparable = mode == mode_str(current.suite, current.quick) && seed == current.seed;

    if comparable {
        let mut pinned_count = 0usize;
        let mut key_notes = Vec::new();
        for (key, ours) in [
            ("sweep", current.checksum_sweep),
            ("schedules", current.checksum_schedules),
            ("advise", current.checksum_advise),
        ] {
            match (checksum_of(&doc, key)?, ours) {
                (Some(pinned), Some(ours)) if pinned != ours => {
                    return Err(format!(
                        "checksum {key:?} drifted: baseline {pinned:#018x}, current {ours:#018x} — the answers changed"
                    ));
                }
                (Some(_), Some(_)) => {
                    pinned_count += 1;
                    key_notes.push(format!("checksum {key}: matches baseline"));
                }
                (Some(pinned), None) => {
                    return Err(format!(
                        "checksum {key:?} is pinned in the baseline ({pinned:#018x}) but this suite does not compute it"
                    ));
                }
                (None, _) => key_notes
                    .push(format!("checksum {key}: unpinned in baseline (refresh with `hetcomm perf --quick --out`)")),
            }
        }
        // A baseline pinning NOTHING is the committed growth-seed
        // projection: no measured bits to gate on at all. Say so once,
        // explicitly, instead of three per-key shrugs and a skip per row.
        if pinned_count == 0 {
            notes.push(
                "baseline is a seed projection (null checksums) — throughput not compared; refresh it with \
                 `hetcomm perf --quick --out BENCH_<suite>.json` (see docs/PERFORMANCE.md)"
                    .to_string(),
            );
            return Ok(notes);
        }
        notes.extend(key_notes);
    } else {
        // Different (mode, seed) means a different workload: neither the
        // checksums nor per-item throughput are meaningfully comparable
        // (quick and full differ ~4x in per-cell cost alone).
        notes.push(format!(
            "baseline (mode {mode}, seed {seed}) does not match this run; shape/schema validated only"
        ));
        return Ok(notes);
    }

    for row in doc.field("results")?.as_arr()? {
        let name = row.field("name")?.as_str()?;
        let base_rate = match row.field("items_per_sec")? {
            Json::Num(x) => *x,
            _ => {
                notes.push(format!("{name}: baseline carries no throughput (seed artifact); skipped"));
                continue;
            }
        };
        let Some(cur) = current.results.iter().find(|r| r.name == name) else {
            notes.push(format!("{name}: not in current run; skipped"));
            continue;
        };
        let floor = base_rate * (1.0 - max_regression);
        if cur.items_per_sec < floor {
            return Err(format!(
                "{name}: {:.1} items/s fell below {:.1} ({}% regression floor of baseline {:.1})",
                cur.items_per_sec,
                floor,
                (max_regression * 100.0).round(),
                base_rate
            ));
        }
        notes.push(format!("{name}: {:.1} items/s vs baseline {:.1} — ok", cur.items_per_sec, base_rate));
    }
    Ok(notes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PerfConfig {
        PerfConfig { quick: true, seed: 7, threads: 2, suite: Suite::Sweep }
    }

    fn tiny_advise() -> PerfConfig {
        PerfConfig { suite: Suite::Advise, ..tiny() }
    }

    #[test]
    fn perf_runs_and_self_verifies() {
        let r = run_perf(&tiny()).unwrap();
        let names: Vec<&str> = r.results.iter().map(|row| row.name).collect();
        let expected = [
            "sweep-compiled", "sweep-reference", "sweep-exhaustive", "sweep-pruned", "schedule-compile", "advise-burst",
        ];
        assert_eq!(names, expected);
        assert!(r.results.iter().all(|row| row.items > 0));
        assert!(r.speedup_vs_reference.is_finite() && r.speedup_vs_reference > 0.0);
        assert!(r.results[5].cache_hit_rate.unwrap() > 0.5);
        // the pruned leg must actually skip simulations on its grid, and
        // only that row carries a prune rate
        let pruned = r.results.iter().find(|row| row.name == "sweep-pruned").unwrap();
        assert!(pruned.prune_rate.unwrap() > 0.0, "prune rate {:?}", pruned.prune_rate);
        assert!(r.results.iter().filter(|row| row.prune_rate.is_some()).count() == 1);
    }

    #[test]
    fn deterministic_projection_is_byte_stable() {
        let a = run_perf(&tiny()).unwrap();
        let b = run_perf(&tiny()).unwrap();
        assert_eq!(report_to_json(&a, false), report_to_json(&b, false));
        // and thread count must not change the answers either
        let c = run_perf(&PerfConfig { threads: 1, ..tiny() }).unwrap();
        assert_eq!(a.checksum_sweep, c.checksum_sweep);
        assert_eq!(a.checksum_schedules, c.checksum_schedules);
        assert_eq!(a.checksum_advise, c.checksum_advise);
    }

    #[test]
    fn seed_moves_the_checksums() {
        let a = run_perf(&tiny()).unwrap();
        let b = run_perf(&PerfConfig { seed: 8, ..tiny() }).unwrap();
        assert_ne!(a.checksum_sweep, b.checksum_sweep, "random-generator cells must follow the seed");
    }

    #[test]
    fn emitted_artifact_validates_and_round_trips() {
        let r = run_perf(&tiny()).unwrap();
        for timing in [true, false] {
            let text = report_to_json(&r, timing);
            let doc = Json::parse(&text).unwrap();
            let (mode, seed) = validate_artifact(&doc).unwrap();
            assert_eq!((mode.as_str(), seed), ("quick", 7));
        }
        // string seeds survive the JSON round-trip even above 2^53
        let mut big = r.clone();
        big.seed = u64::MAX;
        let doc = Json::parse(&report_to_json(&big, false)).unwrap();
        assert_eq!(validate_artifact(&doc).unwrap().1, u64::MAX);
    }

    #[test]
    fn mismatched_mode_or_seed_skips_rate_comparisons() {
        let r = run_perf(&tiny()).unwrap();
        // a baseline from a different seed must neither fail nor enforce
        // cross-workload throughput floors — shape validation only
        let mut other = r.clone();
        other.seed = 8;
        for row in &mut other.results {
            row.items_per_sec *= 1000.0; // would trip the floor if compared
        }
        let notes = compare_baseline(&r, &report_to_json(&other, true), 0.5).unwrap();
        assert_eq!(notes.len(), 1, "{notes:?}");
        assert!(notes[0].contains("does not match"));
    }

    #[test]
    fn baseline_comparison_checks_checksums_and_throughput() {
        let r = run_perf(&tiny()).unwrap();
        // self-comparison with timing pins both checksums and throughput
        let notes = compare_baseline(&r, &report_to_json(&r, true), 0.5).unwrap();
        assert!(notes.iter().any(|n| n.contains("matches baseline")));
        // a tampered checksum must fail
        let pinned = format!("{:#018x}", r.checksum_sweep.unwrap());
        let tampered = report_to_json(&r, true).replace(&pinned, "0xdeadbeefdeadbeef");
        assert!(compare_baseline(&r, &tampered, 0.5).unwrap_err().contains("drifted"));
        // timing-free baselines validate shape and skip regressions
        let notes = compare_baseline(&r, &report_to_json(&r, false), 0.5).unwrap();
        assert!(notes.iter().any(|n| n.contains("skipped")));
        // garbage is rejected
        assert!(compare_baseline(&r, "{}", 0.5).is_err());
    }

    #[test]
    fn null_checksum_baseline_gets_the_seed_projection_notice() {
        // the committed growth-seed baselines pin nothing: the comparison
        // must say so once, explicitly, with the refresh workflow
        let r = run_perf(&tiny()).unwrap();
        let mut projection = r.clone();
        projection.checksum_sweep = None;
        projection.checksum_schedules = None;
        projection.checksum_advise = None;
        let notes = compare_baseline(&r, &report_to_json(&projection, false), 0.5).unwrap();
        assert_eq!(notes.len(), 1, "{notes:?}");
        assert!(notes[0].contains("seed projection (null checksums)"), "{notes:?}");
        assert!(notes[0].contains("throughput not compared"), "{notes:?}");
        assert!(notes[0].contains("hetcomm perf --quick --out"), "{notes:?}");
        assert!(notes[0].contains("docs/PERFORMANCE.md"), "{notes:?}");
    }

    #[test]
    fn advise_suite_runs_and_self_verifies() {
        let r = run_perf(&tiny_advise()).unwrap();
        let names: Vec<&str> = r.results.iter().map(|row| row.name).collect();
        assert_eq!(names, ["advise-burst", "advise-miss", "advise-batch", "advise-simd", "advise-publish"]);
        assert!(r.results.iter().all(|row| row.items > 0));
        assert_eq!(r.machine, "fleet-4");
        assert_eq!(r.cells, 4 * 12, "four tenants x the quick 12-cell lattice");
        // the suite pins only its own checksum
        assert!(r.checksum_sweep.is_none() && r.checksum_schedules.is_none());
        assert!(r.checksum_advise.is_some());
        assert!(r.speedup_vs_reference.is_finite() && r.speedup_vs_reference > 0.0);
        // the pool burst is memo-dominated; the distinct-heavy leg is not
        let burst_hits = r.results[0].cache_hit_rate.unwrap();
        let miss_hits = r.results[1].cache_hit_rate.unwrap();
        // threads=2: concurrent first touches of a pool key can each miss,
        // so the floor is looser than the single-threaded CI gate's 0.9
        assert!(burst_hits > 0.8, "burst hit rate {burst_hits}");
        assert!(miss_hits < burst_hits, "distinct-heavy leg must hit less than the pool burst");
    }

    #[test]
    fn advise_projection_is_byte_stable_and_thread_invariant() {
        let a = run_perf(&tiny_advise()).unwrap();
        let b = run_perf(&tiny_advise()).unwrap();
        assert_eq!(report_to_json(&a, false), report_to_json(&b, false));
        let c = run_perf(&PerfConfig { threads: 1, ..tiny_advise() }).unwrap();
        assert_eq!(a.checksum_advise, c.checksum_advise, "advise answers must not depend on thread count");
        assert_ne!(
            a.checksum_advise,
            run_perf(&PerfConfig { seed: 8, ..tiny_advise() }).unwrap().checksum_advise,
            "seeded queries must follow the seed"
        );
    }

    #[test]
    fn advise_artifacts_validate_and_stay_suite_scoped() {
        let r = run_perf(&tiny_advise()).unwrap();
        let doc = Json::parse(&report_to_json(&r, false)).unwrap();
        let (mode, seed) = validate_artifact(&doc).unwrap();
        assert_eq!((mode.as_str(), seed), ("advise-quick", 7));
        // self-comparison: the advise checksum matches, the sweep ones are
        // unpinned nulls rather than errors
        let notes = compare_baseline(&r, &report_to_json(&r, true), 0.5).unwrap();
        assert!(notes.iter().any(|n| n.contains("checksum advise: matches baseline")), "{notes:?}");
        assert!(notes.iter().any(|n| n.contains("checksum sweep: unpinned")), "{notes:?}");
        // a sweep baseline is a different workload: shape-validated only
        let sweep = run_perf(&tiny()).unwrap();
        let notes = compare_baseline(&r, &report_to_json(&sweep, true), 0.5).unwrap();
        assert_eq!(notes.len(), 1, "{notes:?}");
        assert!(notes[0].contains("does not match"));
    }
}
