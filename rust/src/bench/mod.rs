//! In-tree benchmark harness (no `criterion` in the offline image).
//!
//! Provides wall-clock timing with warmup, summary statistics and aligned
//! table printing used by every `rust/benches/*` target, plus the
//! [`perf`] self-benchmark harness behind `hetcomm perf` (seeded hot-path
//! throughput with a committed `BENCH_sweep.json` trajectory). Benchmarks
//! of *simulated* quantities (the paper's figures) print model/simulator
//! seconds; benchmarks of the coordinator hot path print real wall time.

pub mod perf;

use crate::util::stats::Summary;
use std::time::Instant;

/// Time `f` for `iters` iterations after `warmup` warmup calls; returns
/// per-iteration seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

/// Measure and summarize.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, f: F) -> Summary {
    Summary::of(&time_fn(warmup, iters, f))
}

/// A result table with aligned columns, printed in the style of the
/// paper's figures (one row per size, one column per strategy/series).
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table { title: title.into(), header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds in engineering notation (the paper's figures are log-log
/// in seconds).
pub fn fmt_secs(t: f64) -> String {
    if !t.is_finite() {
        return "inf".into();
    }
    if t == 0.0 {
        return "0".into();
    }
    format!("{t:9.3e}")
}

/// Format byte counts compactly.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.1}GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1}MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_returns_iters_samples() {
        let samples = time_fn(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["size", "time"]);
        t.row(vec!["1024".into(), "3.2e-6".into()]);
        t.row(vec!["8".into(), "1.1e-7".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("1024"));
        let lines: Vec<&str> = r.lines().filter(|l| !l.is_empty()).collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.0MiB");
        assert!(fmt_secs(1.234e-5).contains("e-5"));
        assert_eq!(fmt_secs(0.0), "0");
    }
}
