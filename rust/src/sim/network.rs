//! Simulated micro-benchmarks: ping-pong, node-pong and memcpy splitting —
//! the BenchPress experiments behind Figures 2.5, 2.6, 3.1 and Tables 2–4.

use crate::comm::{CopyKind, CopyOp, Loc, Phase, Schedule, Xfer};
use crate::params::{Endpoint, MachineParams};
use crate::sim::exec;
use crate::topology::{GpuId, Locality, Machine, ProcId};

/// One-way ping-pong time between two processes (or GPUs) at a given
/// locality — the Figure 2.5 experiment. (A real ping-pong halves a round
/// trip; in simulation the one-way time is direct.)
pub fn pingpong(params: &MachineParams, ep: Endpoint, loc: Locality, bytes: usize) -> f64 {
    params.msg_time(ep, loc, bytes)
}

/// Node-pong (Figure 2.6): `total_bytes` moved from node 0 to node 1,
/// split evenly across `ppn` process pairs, all active simultaneously.
/// Returns the simulated completion time of the slowest pair.
pub fn nodepong(machine: &Machine, params: &MachineParams, total_bytes: usize, ppn: usize) -> f64 {
    assert!(machine.num_nodes >= 2, "nodepong needs 2 nodes");
    assert!(ppn >= 1 && ppn <= machine.cores_per_node());
    let share = total_bytes.div_ceil(ppn);
    let mut phase = Phase::new("nodepong");
    for i in 0..ppn {
        phase.xfers.push(Xfer {
            src: Loc::Host(ProcId(i)),
            dst: Loc::Host(ProcId(ppn + i)),
            bytes: share,
            tag: i as u32,
        });
    }
    let sched = Schedule { strategy_label: format!("nodepong-ppn{ppn}"), phases: vec![phase] };
    exec::run(machine, params, &sched, ppn).total
}

/// Memcpy-split experiment (Figure 3.1): copy `total_bytes` from one GPU
/// using `nprocs` simultaneous host processes. Durations come straight from
/// the Table 3 classes (1 vs 4 processes).
pub fn memcpy_split(machine: &Machine, params: &MachineParams, dir: CopyKind, total_bytes: usize, nprocs: usize) -> f64 {
    let ppg = nprocs.clamp(1, 4);
    let mut phase = Phase::new("memcpy");
    phase.copies.push(CopyOp { gpu: GpuId(0), proc: ProcId(0), bytes: total_bytes, dir, nprocs: ppg });
    let sched = Schedule { strategy_label: format!("memcpy-np{nprocs}"), phases: vec![phase] };
    exec::run(machine, params, &sched, machine.gpus_per_node()).total
}

/// The ppn that minimizes node-pong time for a given volume — the circled
/// minima of Figure 2.6.
pub fn best_ppn(machine: &Machine, params: &MachineParams, total_bytes: usize, ppn_choices: &[usize]) -> usize {
    *ppn_choices
        .iter()
        .min_by(|&&a, &&b| {
            nodepong(machine, params, total_bytes, a)
                .partial_cmp(&nodepong(machine, params, total_bytes, b))
                .unwrap()
        })
        .expect("non-empty ppn choices")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    #[test]
    fn pingpong_ordering_small_messages() {
        // Figure 2.5: for small messages, on-socket < on-node < off-node.
        let p = lassen_params();
        let s = 64;
        let a = pingpong(&p, Endpoint::Cpu, Locality::OnSocket, s);
        let b = pingpong(&p, Endpoint::Cpu, Locality::OnNode, s);
        let c = pingpong(&p, Endpoint::Cpu, Locality::OffNode, s);
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn pingpong_network_competitive_large_messages() {
        // Figure 2.5's observation: for large messages the network path is
        // competitive with (even faster than) the on-node path on Lassen.
        let p = lassen_params();
        let s = 1 << 20;
        let on_node = pingpong(&p, Endpoint::Cpu, Locality::OnNode, s);
        let off_node = pingpong(&p, Endpoint::Cpu, Locality::OffNode, s);
        assert!(off_node < on_node, "off-node {off_node} should beat on-node {on_node} at 1 MiB");
    }

    #[test]
    fn nodepong_splitting_helps_large_volumes() {
        // Figure 2.6: splitting a large volume across many processes beats
        // one process.
        let m = lassen(2);
        let p = lassen_params();
        let total = 1 << 22; // 4 MiB
        let t1 = nodepong(&m, &p, total, 1);
        let t8 = nodepong(&m, &p, total, 8);
        assert!(t8 < t1, "ppn=8 {t8} !< ppn=1 {t1}");
    }

    #[test]
    fn nodepong_splitting_useless_tiny_volumes() {
        // Tiny volumes are latency-dominated: splitting across 32 procs
        // buys nothing meaningful (Figure 2.6's minima sit at low ppn for
        // small sizes; concurrent sends make the simulated times close).
        let m = lassen(2);
        let p = lassen_params();
        let total = 512;
        let t1 = nodepong(&m, &p, total, 1);
        let t32 = nodepong(&m, &p, total, 32);
        assert!(t32 > 0.6 * t1, "ppn=32 {t32} should not be much faster than ppn=1 {t1}");
        // ...whereas at 4 MiB splitting wins clearly (bounded by the NIC
        // injection floor, so ~1.8x on Lassen parameters).
        let big = 1 << 22;
        assert!(nodepong(&m, &p, big, 32) * 1.5 < nodepong(&m, &p, big, 1));
    }

    #[test]
    fn best_ppn_monotone_in_volume() {
        let m = lassen(2);
        let p = lassen_params();
        let choices = [1, 2, 4, 8, 16, 32, 40];
        let small = best_ppn(&m, &p, 1 << 9, &choices);
        let large = best_ppn(&m, &p, 1 << 23, &choices);
        assert!(small <= large, "best ppn should not shrink with volume: {small} vs {large}");
        assert!(large >= 4, "large volumes want many processes, got {large}");
    }

    #[test]
    fn memcpy_split_four_proc_wins_h2d_large() {
        // Figure 3.1 / Table 3: H2D 4-proc copies beat 1-proc only via
        // byte-sharing... with Lassen's measured betas the 1-proc H2D beta
        // (1.85e-11) is so low that 4-proc (5.52e-10 per proc-share) loses.
        // The observed "no benefit beyond 4 procs" shows as a latency
        // penalty here; verify the qualitative Table 3 relationship.
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 24;
        let t1 = memcpy_split(&m, &p, CopyKind::D2H, s, 1);
        let t4 = memcpy_split(&m, &p, CopyKind::D2H, s, 4);
        // D2H: 1-proc beta 1.96e-11 vs 4-proc share beta 1.5e-10/4 = 3.75e-11
        // per byte -> 1 proc stays ahead; both finite and ordered sanely.
        assert!(t1 > 0.0 && t4 > 0.0);
        assert!(t4 < 2.0 * t1, "4-proc should be within 2x of 1-proc at 16 MiB");
    }

    #[test]
    fn memcpy_nprocs_clamped() {
        let m = lassen(2);
        let p = lassen_params();
        // nprocs > 4 uses the 4-proc class rather than panicking.
        let t8 = memcpy_split(&m, &p, CopyKind::H2D, 1 << 16, 8);
        let t4 = memcpy_split(&m, &p, CopyKind::H2D, 1 << 16, 4);
        assert_eq!(t8, t4);
    }
}
