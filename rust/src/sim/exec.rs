//! Greedy list-scheduling discrete-event executor for communication
//! schedules.

use crate::comm::{CopyKind, Loc, Phase, Schedule};
use crate::params::{CopyDir, Endpoint, MachineParams};
use crate::topology::{Locality, Machine};
use std::collections::HashMap;

/// Simulated timing of one schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub strategy_label: String,
    /// (phase label, seconds) in execution order.
    pub phase_times: Vec<(String, f64)>,
    /// End-to-end simulated seconds (sum of phases — phases are barriers).
    pub total: f64,
    /// Peak bytes injected into the network by any single node.
    pub max_node_injected: usize,
    /// Total inter-node messages.
    pub internode_msgs: usize,
}

/// Resource availability keyed by an opaque id.
#[derive(Default)]
struct Avail {
    t: HashMap<u64, f64>,
}

impl Avail {
    fn get(&self, k: u64) -> f64 {
        *self.t.get(&k).unwrap_or(&0.0)
    }

    fn set(&mut self, k: u64, v: f64) {
        self.t.insert(k, v);
    }
}

// Resource-id packing: kind tag in the top bits.
const KIND_PROC: u64 = 1 << 60;
const KIND_GPU: u64 = 2 << 60;
const KIND_NIC: u64 = 3 << 60;
const KIND_COPY: u64 = 4 << 60;

fn loc_key(loc: Loc) -> u64 {
    match loc {
        Loc::Host(p) => KIND_PROC | p.0 as u64,
        Loc::Gpu(g) => KIND_GPU | g.0 as u64,
    }
}

/// Execute a schedule, returning simulated times.
///
/// `ppn` is the number of host processes per node in this run — it fixes
/// process→node/socket mapping for locality decisions.
pub fn run(machine: &Machine, params: &MachineParams, schedule: &Schedule, ppn: usize) -> SimReport {
    let mut avail = Avail::default();
    let mut phase_times = Vec::with_capacity(schedule.phases.len());
    let mut clock = 0.0f64;
    let mut injected: HashMap<usize, usize> = HashMap::new();
    let mut internode_msgs = 0usize;

    for phase in &schedule.phases {
        let end = run_phase(machine, params, phase, ppn, clock, &mut avail, &mut injected, &mut internode_msgs);
        phase_times.push((phase.label.to_string(), end - clock));
        clock = end;
    }

    SimReport {
        strategy_label: schedule.strategy_label.clone(),
        phase_times,
        total: clock,
        max_node_injected: injected.values().copied().max().unwrap_or(0),
        internode_msgs,
    }
}

fn locality(machine: &Machine, a: Loc, b: Loc, ppn: usize) -> Locality {
    let node = |l: Loc| match l {
        Loc::Gpu(g) => machine.gpu_node(g).0,
        Loc::Host(p) => machine.proc_node(p, ppn).0,
    };
    let socket = |l: Loc| match l {
        Loc::Gpu(g) => machine.gpu_socket(g),
        Loc::Host(p) => machine.proc_socket(p, ppn),
    };
    if node(a) != node(b) {
        Locality::OffNode
    } else if socket(a) != socket(b) {
        Locality::OnNode
    } else {
        Locality::OnSocket
    }
}

#[allow(clippy::too_many_arguments)]
fn run_phase(
    machine: &Machine,
    params: &MachineParams,
    phase: &Phase,
    ppn: usize,
    start: f64,
    avail: &mut Avail,
    injected: &mut HashMap<usize, usize>,
    internode_msgs: &mut usize,
) -> f64 {
    let mut phase_end = start;

    // Point-to-point transfers, in listed order (builders list them in the
    // paper's step order; concurrent ops on distinct resources overlap).
    for x in &phase.xfers {
        if x.bytes == 0 {
            continue;
        }
        let loc = locality(machine, x.src, x.dst, ppn);
        // Endpoint kind: device-aware if either endpoint is a GPU.
        let ep = match (x.src, x.dst) {
            (Loc::Gpu(_), _) | (_, Loc::Gpu(_)) => Endpoint::Gpu,
            _ => Endpoint::Cpu,
        };
        let duration = params.msg_time(ep, loc, x.bytes);
        let sk = loc_key(x.src);
        let dk = loc_key(x.dst);
        let mut ready = start.max(avail.get(sk)).max(avail.get(dk));
        if loc == Locality::OffNode {
            // NIC injection: the source node's NIC serializes at R_N.
            let node = match x.src {
                Loc::Gpu(g) => machine.gpu_node(g).0,
                Loc::Host(p) => machine.proc_node(p, ppn).0,
            };
            let nk = KIND_NIC | node as u64;
            ready = ready.max(avail.get(nk));
            let nic_busy = x.bytes as f64 * params.inv_rn;
            avail.set(nk, ready + nic_busy);
            *injected.entry(node).or_default() += x.bytes;
            *internode_msgs += 1;
        }
        let done = ready + duration;
        avail.set(sk, done);
        avail.set(dk, done);
        phase_end = phase_end.max(done);
    }

    // Host↔device copies: serialized per GPU copy engine and per proc.
    for c in &phase.copies {
        let dir = match c.dir {
            CopyKind::D2H => CopyDir::D2H,
            CopyKind::H2D => CopyDir::H2D,
        };
        let duration = params.memcpy_time(dir, c.bytes, c.nprocs);
        let gk = KIND_COPY | c.gpu.0 as u64;
        let pk = KIND_PROC | c.proc.0 as u64;
        let ready = start.max(avail.get(gk)).max(avail.get(pk));
        let done = ready + duration;
        avail.set(gk, done);
        avail.set(pk, done);
        // The GPU compute queue is not blocked by async copies; only the
        // copy engine and the initiating process are.
        phase_end = phase_end.max(done);
    }

    phase_end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule, Strategy, StrategyKind, Transport, Xfer};
    use crate::params::lassen_params;
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::{machines::lassen, GpuId, ProcId};

    fn single_xfer_schedule(src: Loc, dst: Loc, bytes: usize) -> Schedule {
        Schedule {
            strategy_label: "test".into(),
            phases: vec![Phase { label: "p", xfers: vec![Xfer { src, dst, bytes, tag: 0 }], copies: vec![] }],
        }
    }

    #[test]
    fn single_message_matches_postal() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let sched = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), s);
        let rep = run(&m, &p, &sched, 4);
        let expect = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        assert!((rep.total - expect).abs() < 1e-15, "{} vs {expect}", rep.total);
        assert_eq!(rep.internode_msgs, 1);
        assert_eq!(rep.max_node_injected, s);
    }

    #[test]
    fn gpu_message_uses_gpu_params() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let sched = single_xfer_schedule(Loc::Gpu(GpuId(0)), Loc::Gpu(GpuId(4)), s);
        let rep = run(&m, &p, &sched, 4);
        let expect = p.msg_time(Endpoint::Gpu, Locality::OffNode, s);
        assert!((rep.total - expect).abs() < 1e-15);
    }

    #[test]
    fn independent_transfers_overlap() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 10; // small: NIC not limiting
        let mut phase = Phase::new("p");
        for i in 0..4 {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(i)),
                dst: Loc::Host(ProcId(4 + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        // 4 disjoint src/dst pairs: all overlap (NIC time for 4 KiB total is
        // negligible vs per-message latency).
        assert!((rep.total - one).abs() / one < 0.2, "total {} vs one {}", rep.total, one);
    }

    #[test]
    fn same_source_serializes() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 10;
        let mut phase = Phase::new("p");
        for i in 0..4 {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(0)),
                dst: Loc::Host(ProcId(4 + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        assert!(rep.total > 3.9 * one, "4 sends from one proc must serialize: {} vs {}", rep.total, one);
    }

    #[test]
    fn nic_limits_heavy_injection() {
        // Many processes each sending large messages from one node: the
        // NIC occupancy (bytes / R_N) must dominate -> emergent max-rate.
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 20;
        let ppn = 40;
        let mut phase = Phase::new("p");
        for i in 0..ppn {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(i)),
                dst: Loc::Host(ProcId(ppn + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, ppn);
        let nic_floor = (ppn * s) as f64 * p.inv_rn;
        assert!(rep.total >= nic_floor * 0.99, "total {} must respect NIC floor {nic_floor}", rep.total);
        assert_eq!(rep.max_node_injected, ppn * s);
    }

    #[test]
    fn phases_are_barriers() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let x1 = Xfer { src: Loc::Host(ProcId(0)), dst: Loc::Host(ProcId(4)), bytes: s, tag: 0 };
        let x2 = Xfer { src: Loc::Host(ProcId(1)), dst: Loc::Host(ProcId(5)), bytes: s, tag: 1 };
        let two_phase = Schedule {
            strategy_label: "t".into(),
            phases: vec![
                Phase { label: "a", xfers: vec![x1.clone()], copies: vec![] },
                Phase { label: "b", xfers: vec![x2.clone()], copies: vec![] },
            ],
        };
        let one_phase = Schedule {
            strategy_label: "t".into(),
            phases: vec![Phase { label: "a", xfers: vec![x1, x2], copies: vec![] }],
        };
        let t2 = run(&m, &p, &two_phase, 4).total;
        let t1 = run(&m, &p, &one_phase, 4).total;
        assert!(t2 > t1 * 1.5, "barrier must serialize phases: {t2} vs {t1}");
        let rep = run(&m, &p, &two_phase, 4);
        assert_eq!(rep.phase_times.len(), 2);
        assert!((rep.phase_times[0].1 + rep.phase_times[1].1 - rep.total).abs() < 1e-15);
    }

    #[test]
    fn copies_serialize_per_gpu() {
        let m = lassen(2);
        let p = lassen_params();
        let mut phase = Phase::new("c");
        for _ in 0..2 {
            phase.copies.push(crate::comm::CopyOp {
                gpu: GpuId(0),
                proc: ProcId(0),
                bytes: 1 << 20,
                dir: CopyKind::D2H,
                nprocs: 1,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.memcpy_time(CopyDir::D2H, 1 << 20, 1);
        assert!((rep.total - 2.0 * one).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_transfers_free() {
        let m = lassen(2);
        let p = lassen_params();
        let sched = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), 0);
        assert_eq!(run(&m, &p, &sched, 4).total, 0.0);
    }

    #[test]
    fn three_step_beats_standard_many_small_messages() {
        // The paper's core qualitative claim at schedule level: with many
        // small messages between two nodes, 3-step's single buffer beats
        // standard's per-message injection (device-aware).
        let m = lassen(2);
        let p = lassen_params();
        let mut msgs = Vec::new();
        for i in 0..64 {
            msgs.push(Msg::new(GpuId(i % 4), GpuId(4 + (i % 4)), 1024));
        }
        let pat = CommPattern::new(msgs);
        let std = build_schedule(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &m, &pat);
        let three = build_schedule(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap(), &m, &pat);
        let t_std = run(&m, &p, &std, 4).total;
        let t_three = run(&m, &p, &three, 4).total;
        assert!(t_three < t_std, "3-step {t_three} !< standard {t_std}");
    }
}
