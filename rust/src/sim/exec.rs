//! Greedy list-scheduling discrete-event executor for communication
//! schedules.
//!
//! Two executors share one semantics:
//!
//! - [`run_compiled`] — the production hot path: walks a
//!   [`CompiledSchedule`]'s flat SoA arrays against dense `Vec<f64>`
//!   resource timelines held in a reusable [`ExecScratch`]. The inner loop
//!   performs no hash-map operations and no heap allocation (after the
//!   scratch warms up to the largest machine seen).
//! - [`run_reference`] — the retained reference implementation (hash-map
//!   availability, per-call locality/protocol resolution). It is the
//!   pre-compilation executor kept verbatim as the equivalence oracle for
//!   `rust/tests/prop_sim.rs`, the golden-output tests, and the
//!   `hetcomm perf` reference mode.
//!
//! [`run`] keeps the historical convenience signature (compile + execute in
//! one call) and is bit-for-bit identical to [`run_reference`].

use crate::comm::{CopyKind, Loc, Phase, Schedule};
use crate::params::{CopyDir, Endpoint, MachineParams};
use crate::sim::compiled::{CompiledSchedule, NO_NIC};
use crate::topology::{Locality, Machine};
use std::collections::HashMap;

/// Simulated timing of one schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub strategy_label: String,
    /// (phase label, seconds) in execution order.
    pub phase_times: Vec<(&'static str, f64)>,
    /// End-to-end simulated seconds (sum of phases — phases are barriers).
    pub total: f64,
    /// Peak bytes injected into the network by any single node.
    pub max_node_injected: usize,
    /// Total inter-node messages.
    pub internode_msgs: usize,
}

/// The scalar outcome of one compiled execution (phase times stay in the
/// scratch; everything here is `Copy` so the hot loop returns no heap data).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimTotals {
    pub total: f64,
    pub max_node_injected: usize,
    pub internode_msgs: usize,
}

/// Reusable executor state: dense per-resource availability timelines,
/// per-node injected-byte counters and the per-phase time buffer. One per
/// worker thread, reused across every (cell × strategy) evaluation.
#[derive(Clone, Debug, Default)]
pub struct ExecScratch {
    avail: Vec<f64>,
    injected: Vec<usize>,
    /// (phase label, seconds) of the most recent [`run_compiled`] call.
    pub phase_times: Vec<(&'static str, f64)>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }
}

/// Execute a compiled schedule. Zero-allocation: only resizes the scratch
/// when this machine is larger than any seen before.
pub fn run_compiled(cs: &CompiledSchedule, scratch: &mut ExecScratch) -> SimTotals {
    run_compiled_with(cs, scratch, None)
}

/// Execute a compiled schedule with NIC rail timelines pre-charged:
/// `precharge[node * rails + rail]` seconds of seeded background occupancy
/// (the fault layer's congestion injector, [`crate::fault`]) are written
/// into the NIC availability slots before the first phase, so crossing
/// traffic queues behind the background load exactly as it would behind
/// earlier same-rail transfers. `None` — and any all-zero slice — executes
/// bit-identically to [`run_compiled`].
pub fn run_compiled_with(cs: &CompiledSchedule, scratch: &mut ExecScratch, precharge: Option<&[f64]>) -> SimTotals {
    scratch.avail.clear();
    scratch.avail.resize(cs.n_resources as usize, 0.0);
    if let Some(pre) = precharge {
        let base = cs.nic_base as usize;
        let n = pre.len().min(cs.nic_count as usize);
        scratch.avail[base..base + n].copy_from_slice(&pre[..n]);
    }
    scratch.injected.clear();
    scratch.injected.resize(cs.n_nodes as usize, 0);
    scratch.phase_times.clear();

    let avail = &mut scratch.avail;
    let injected = &mut scratch.injected;
    let mut clock = 0.0f64;
    let mut internode_msgs = 0usize;
    let mut x0 = 0usize;
    let mut c0 = 0usize;

    for pi in 0..cs.phase_labels.len() {
        let start = clock;
        let mut phase_end = start;
        let x1 = cs.phase_xfer_end[pi] as usize;
        let c1 = cs.phase_copy_end[pi] as usize;

        // Point-to-point transfers, in listed order (builders list them in
        // the paper's step order; distinct-resource ops overlap).
        for i in x0..x1 {
            let sk = cs.x_src[i] as usize;
            let dk = cs.x_dst[i] as usize;
            let mut ready = start.max(avail[sk]).max(avail[dk]);
            let nic = cs.x_nic[i];
            if nic != NO_NIC {
                // NIC injection: the source node's NIC serializes at R_N.
                let nk = nic as usize;
                ready = ready.max(avail[nk]);
                avail[nk] = ready + cs.x_nic_busy[i];
                injected[cs.x_node[i] as usize] += cs.x_bytes[i];
                internode_msgs += 1;
            }
            let done = ready + cs.x_dur[i];
            avail[sk] = done;
            avail[dk] = done;
            phase_end = phase_end.max(done);
        }

        // Host↔device copies: serialized per GPU copy engine and per proc.
        // The GPU compute queue is not blocked by async copies; only the
        // copy engine and the initiating process are.
        for i in c0..c1 {
            let gk = cs.c_engine[i] as usize;
            let pk = cs.c_proc[i] as usize;
            let ready = start.max(avail[gk]).max(avail[pk]);
            let done = ready + cs.c_dur[i];
            avail[gk] = done;
            avail[pk] = done;
            phase_end = phase_end.max(done);
        }

        scratch.phase_times.push((cs.phase_labels[pi], phase_end - start));
        clock = phase_end;
        x0 = x1;
        c0 = c1;
    }

    SimTotals {
        total: clock,
        max_node_injected: injected.iter().copied().max().unwrap_or(0),
        internode_msgs,
    }
}

/// Execute a schedule, returning simulated times.
///
/// `ppn` is the number of host processes per node in this run — it fixes
/// process→node/socket mapping for locality decisions. Convenience wrapper:
/// compiles the parameters and schedule, executes the compiled form, and
/// assembles a full [`SimReport`]. Hot loops should hold a
/// [`crate::sim::Scratch`] and a precompiled [`CompiledParams`] instead.
pub fn run(machine: &Machine, params: &MachineParams, schedule: &Schedule, ppn: usize) -> SimReport {
    let compiled = params.compile();
    let mut scratch = crate::sim::Scratch::new();
    scratch.run_report(machine, &compiled, schedule, ppn)
}

/// Node-local NIC rail an inter-node transfer injects through — the single
/// home of the rail-assignment policy, called by both the reference
/// executor and the schedule lowering ([`crate::sim::compiled`]):
///
/// - device-aware traffic (GPU source) follows the shape's GPU↔NIC
///   affinity map ([`Machine::gpu_rail`]);
/// - staged traffic (host source) round-robins the sending socket's rails
///   by destination node pair ([`Machine::proc_rail`]).
///
/// A pure function of `(machine, src, dst, ppn)`: deterministic, invariant
/// under message reordering, and identically 0 on single-rail shapes (the
/// pre-shape-layer NIC).
pub(crate) fn rail(machine: &Machine, src: Loc, dst: Loc, ppn: usize) -> usize {
    let dst_node = match dst {
        Loc::Gpu(g) => machine.gpu_node(g),
        Loc::Host(p) => machine.proc_node(p, ppn),
    };
    match src {
        Loc::Gpu(g) => machine.gpu_rail(g),
        Loc::Host(p) => machine.proc_rail(p, ppn, dst_node),
    }
}

/// Locality of two endpoints under `ppn` processes per node — the single
/// home of the locality rule, called by both the reference executor and
/// the schedule lowering ([`crate::sim::compiled`]).
pub(crate) fn locality(machine: &Machine, a: Loc, b: Loc, ppn: usize) -> Locality {
    let node = |l: Loc| match l {
        Loc::Gpu(g) => machine.gpu_node(g).0,
        Loc::Host(p) => machine.proc_node(p, ppn).0,
    };
    let socket = |l: Loc| match l {
        Loc::Gpu(g) => machine.gpu_socket(g),
        Loc::Host(p) => machine.proc_socket(p, ppn),
    };
    if node(a) != node(b) {
        Locality::OffNode
    } else if socket(a) != socket(b) {
        Locality::OnNode
    } else {
        Locality::OnSocket
    }
}

// ---------------------------------------------------------------------------
// Retained reference implementation (pre-compilation executor, verbatim).
// ---------------------------------------------------------------------------

/// Resource availability keyed by an opaque id.
#[derive(Default)]
struct Avail {
    t: HashMap<u64, f64>,
}

impl Avail {
    fn get(&self, k: u64) -> f64 {
        *self.t.get(&k).unwrap_or(&0.0)
    }

    fn set(&mut self, k: u64, v: f64) {
        self.t.insert(k, v);
    }
}

// Resource-id packing: kind tag in the top bits.
const KIND_PROC: u64 = 1 << 60;
const KIND_GPU: u64 = 2 << 60;
const KIND_NIC: u64 = 3 << 60;
const KIND_COPY: u64 = 4 << 60;

fn loc_key(loc: Loc) -> u64 {
    match loc {
        Loc::Host(p) => KIND_PROC | p.0 as u64,
        Loc::Gpu(g) => KIND_GPU | g.0 as u64,
    }
}

/// The reference executor: hash-map availability, per-transfer locality,
/// protocol and rail resolution. Semantically (and bit-for-bit) equal to
/// [`run`] / [`run_compiled`] — the two executors evolve in lockstep (the
/// shape layer taught both about NIC rails) and `rust/tests/prop_sim.rs` /
/// `prop_topology.rs` hold them together. On single-rail shapes the NIC
/// keys and occupancies reduce to the historical one-NIC-per-node values
/// exactly.
pub fn run_reference(machine: &Machine, params: &MachineParams, schedule: &Schedule, ppn: usize) -> SimReport {
    run_reference_with(machine, params, schedule, ppn, None)
}

/// [`run_reference`] with the same NIC congestion pre-charge as
/// [`run_compiled_with`]: `precharge[node * rails + rail]` seconds seed the
/// rail's availability before the first phase. Bit-for-bit equal to the
/// compiled executor under the same pre-charge (`prop_sim.rs`).
pub fn run_reference_with(
    machine: &Machine,
    params: &MachineParams,
    schedule: &Schedule,
    ppn: usize,
    precharge: Option<&[f64]>,
) -> SimReport {
    let mut avail = Avail::default();
    if let Some(pre) = precharge {
        for (i, &t) in pre.iter().enumerate() {
            avail.set(KIND_NIC | i as u64, t);
        }
    }
    let mut phase_times = Vec::with_capacity(schedule.phases.len());
    let mut clock = 0.0f64;
    let mut injected: HashMap<usize, usize> = HashMap::new();
    let mut internode_msgs = 0usize;

    for phase in &schedule.phases {
        let end = run_phase(machine, params, phase, ppn, clock, &mut avail, &mut injected, &mut internode_msgs);
        phase_times.push((phase.label, end - clock));
        clock = end;
    }

    SimReport {
        strategy_label: schedule.strategy_label.clone(),
        phase_times,
        total: clock,
        max_node_injected: injected.values().copied().max().unwrap_or(0),
        internode_msgs,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_phase(
    machine: &Machine,
    params: &MachineParams,
    phase: &Phase,
    ppn: usize,
    start: f64,
    avail: &mut Avail,
    injected: &mut HashMap<usize, usize>,
    internode_msgs: &mut usize,
) -> f64 {
    let mut phase_end = start;

    // Point-to-point transfers, in listed order (builders list them in the
    // paper's step order; concurrent ops on distinct resources overlap).
    for x in &phase.xfers {
        if x.bytes == 0 {
            continue;
        }
        let loc = locality(machine, x.src, x.dst, ppn);
        // Endpoint kind: device-aware if either endpoint is a GPU.
        let ep = match (x.src, x.dst) {
            (Loc::Gpu(_), _) | (_, Loc::Gpu(_)) => Endpoint::Gpu,
            _ => Endpoint::Cpu,
        };
        let duration = params.msg_time(ep, loc, x.bytes);
        let sk = loc_key(x.src);
        let dk = loc_key(x.dst);
        let mut ready = start.max(avail.get(sk)).max(avail.get(dk));
        if loc == Locality::OffNode {
            // NIC injection: the assigned rail of the source node's shape
            // serializes at its band rate (single-rail shapes: rail 0 at
            // R_N — the historical per-node NIC key and occupancy exactly).
            let node = match x.src {
                Loc::Gpu(g) => machine.gpu_node(g).0,
                Loc::Host(p) => machine.proc_node(p, ppn).0,
            };
            let r = rail(machine, x.src, x.dst, ppn);
            let nk = KIND_NIC | (node * machine.nics_per_node() + r) as u64;
            ready = ready.max(avail.get(nk));
            let nic_busy = params.nic_busy(r, x.bytes);
            avail.set(nk, ready + nic_busy);
            *injected.entry(node).or_default() += x.bytes;
            *internode_msgs += 1;
        }
        let done = ready + duration;
        avail.set(sk, done);
        avail.set(dk, done);
        phase_end = phase_end.max(done);
    }

    // Host↔device copies: serialized per GPU copy engine and per proc.
    for c in &phase.copies {
        let dir = match c.dir {
            CopyKind::D2H => CopyDir::D2H,
            CopyKind::H2D => CopyDir::H2D,
        };
        let duration = params.memcpy_time(dir, c.bytes, c.nprocs);
        let gk = KIND_COPY | c.gpu.0 as u64;
        let pk = KIND_PROC | c.proc.0 as u64;
        let ready = start.max(avail.get(gk)).max(avail.get(pk));
        let done = ready + duration;
        avail.set(gk, done);
        avail.set(pk, done);
        // The GPU compute queue is not blocked by async copies; only the
        // copy engine and the initiating process are.
        phase_end = phase_end.max(done);
    }

    phase_end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule, Strategy, StrategyKind, Transport, Xfer};
    use crate::params::lassen_params;
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::{machines::lassen, GpuId, ProcId};

    fn single_xfer_schedule(src: Loc, dst: Loc, bytes: usize) -> Schedule {
        Schedule {
            strategy_label: "test".into(),
            phases: vec![Phase { label: "p", xfers: vec![Xfer { src, dst, bytes, tag: 0 }], copies: vec![] }],
        }
    }

    #[test]
    fn single_message_matches_postal() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let sched = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), s);
        let rep = run(&m, &p, &sched, 4);
        let expect = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        assert!((rep.total - expect).abs() < 1e-15, "{} vs {expect}", rep.total);
        assert_eq!(rep.internode_msgs, 1);
        assert_eq!(rep.max_node_injected, s);
    }

    #[test]
    fn gpu_message_uses_gpu_params() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let sched = single_xfer_schedule(Loc::Gpu(GpuId(0)), Loc::Gpu(GpuId(4)), s);
        let rep = run(&m, &p, &sched, 4);
        let expect = p.msg_time(Endpoint::Gpu, Locality::OffNode, s);
        assert!((rep.total - expect).abs() < 1e-15);
    }

    #[test]
    fn independent_transfers_overlap() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 10; // small: NIC not limiting
        let mut phase = Phase::new("p");
        for i in 0..4 {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(i)),
                dst: Loc::Host(ProcId(4 + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        // 4 disjoint src/dst pairs: all overlap (NIC time for 4 KiB total is
        // negligible vs per-message latency).
        assert!((rep.total - one).abs() / one < 0.2, "total {} vs one {}", rep.total, one);
    }

    #[test]
    fn same_source_serializes() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 10;
        let mut phase = Phase::new("p");
        for i in 0..4 {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(0)),
                dst: Loc::Host(ProcId(4 + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
        assert!(rep.total > 3.9 * one, "4 sends from one proc must serialize: {} vs {}", rep.total, one);
    }

    #[test]
    fn nic_limits_heavy_injection() {
        // Many processes each sending large messages from one node: the
        // NIC occupancy (bytes / R_N) must dominate -> emergent max-rate.
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 20;
        let ppn = 40;
        let mut phase = Phase::new("p");
        for i in 0..ppn {
            phase.xfers.push(Xfer {
                src: Loc::Host(ProcId(i)),
                dst: Loc::Host(ProcId(ppn + i)),
                bytes: s,
                tag: i as u32,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, ppn);
        let nic_floor = (ppn * s) as f64 * p.inv_rn;
        assert!(rep.total >= nic_floor * 0.99, "total {} must respect NIC floor {nic_floor}", rep.total);
        assert_eq!(rep.max_node_injected, ppn * s);
    }

    #[test]
    fn phases_are_barriers() {
        let m = lassen(2);
        let p = lassen_params();
        let s = 1 << 12;
        let x1 = Xfer { src: Loc::Host(ProcId(0)), dst: Loc::Host(ProcId(4)), bytes: s, tag: 0 };
        let x2 = Xfer { src: Loc::Host(ProcId(1)), dst: Loc::Host(ProcId(5)), bytes: s, tag: 1 };
        let two_phase = Schedule {
            strategy_label: "t".into(),
            phases: vec![
                Phase { label: "a", xfers: vec![x1.clone()], copies: vec![] },
                Phase { label: "b", xfers: vec![x2.clone()], copies: vec![] },
            ],
        };
        let one_phase = Schedule {
            strategy_label: "t".into(),
            phases: vec![Phase { label: "a", xfers: vec![x1, x2], copies: vec![] }],
        };
        let t2 = run(&m, &p, &two_phase, 4).total;
        let t1 = run(&m, &p, &one_phase, 4).total;
        assert!(t2 > t1 * 1.5, "barrier must serialize phases: {t2} vs {t1}");
        let rep = run(&m, &p, &two_phase, 4);
        assert_eq!(rep.phase_times.len(), 2);
        assert!((rep.phase_times[0].1 + rep.phase_times[1].1 - rep.total).abs() < 1e-15);
    }

    #[test]
    fn copies_serialize_per_gpu() {
        let m = lassen(2);
        let p = lassen_params();
        let mut phase = Phase::new("c");
        for _ in 0..2 {
            phase.copies.push(crate::comm::CopyOp {
                gpu: GpuId(0),
                proc: ProcId(0),
                bytes: 1 << 20,
                dir: CopyKind::D2H,
                nprocs: 1,
            });
        }
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let rep = run(&m, &p, &sched, 4);
        let one = p.memcpy_time(CopyDir::D2H, 1 << 20, 1);
        assert!((rep.total - 2.0 * one).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_transfers_free() {
        let m = lassen(2);
        let p = lassen_params();
        let sched = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), 0);
        assert_eq!(run(&m, &p, &sched, 4).total, 0.0);
    }

    #[test]
    fn three_step_beats_standard_many_small_messages() {
        // The paper's core qualitative claim at schedule level: with many
        // small messages between two nodes, 3-step's single buffer beats
        // standard's per-message injection (device-aware).
        let m = lassen(2);
        let p = lassen_params();
        let mut msgs = Vec::new();
        for i in 0..64 {
            msgs.push(Msg::new(GpuId(i % 4), GpuId(4 + (i % 4)), 1024));
        }
        let pat = CommPattern::new(msgs);
        let std = build_schedule(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &m, &pat);
        let three = build_schedule(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap(), &m, &pat);
        let t_std = run(&m, &p, &std, 4).total;
        let t_three = run(&m, &p, &three, 4).total;
        assert!(t_three < t_std, "3-step {t_three} !< standard {t_std}");
    }

    #[test]
    fn compiled_matches_reference_on_strategy_schedules() {
        use crate::pattern::generators::random_pattern;
        use crate::util::rng::Rng;
        let m = lassen(3);
        let p = lassen_params();
        let mut rng = Rng::new(77);
        let pattern = random_pattern(&m, &mut rng, 96, 1 << 16, 0.25);
        for s in Strategy::all() {
            let sched = build_schedule(s, &m, &pattern);
            let ppn = s.sim_ppn(&m);
            let fast = run(&m, &p, &sched, ppn);
            let slow = run_reference(&m, &p, &sched, ppn);
            assert_eq!(fast.total.to_bits(), slow.total.to_bits(), "{}", sched.strategy_label);
            assert_eq!(fast.max_node_injected, slow.max_node_injected);
            assert_eq!(fast.internode_msgs, slow.internode_msgs);
            assert_eq!(fast.phase_times.len(), slow.phase_times.len());
            for (a, b) in fast.phase_times.iter().zip(&slow.phase_times) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1.to_bits(), b.1.to_bits());
            }
        }
    }

    #[test]
    fn out_of_range_copy_gpu_matches_reference() {
        // The reference copy path never resolves the GPU's node, so copy
        // ids beyond the machine are tolerated there; the dense executor
        // must size its copy-engine block accordingly and agree.
        let m = lassen(1); // 4 GPUs total
        let p = lassen_params();
        let mut phase = Phase::new("c");
        phase.copies.push(crate::comm::CopyOp {
            gpu: GpuId(7),
            proc: ProcId(0),
            bytes: 1 << 16,
            dir: CopyKind::D2H,
            nprocs: 1,
        });
        let sched = Schedule { strategy_label: "t".into(), phases: vec![phase] };
        let fast = run(&m, &p, &sched, 4);
        let slow = run_reference(&m, &p, &sched, 4);
        assert_eq!(fast.total.to_bits(), slow.total.to_bits());
        assert_eq!(fast.max_node_injected, slow.max_node_injected);
    }

    #[test]
    fn precharge_delays_crossing_traffic_only() {
        let m = lassen(2);
        let p = lassen_params();
        let cp = p.compile();
        let s = 1 << 12;
        let crossing = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), s);
        let local = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(1)), s);
        // one slot per (node, rail); charge node 0's rails heavily
        let rails = m.nics_per_node();
        let mut pre = vec![0.0; m.num_nodes * rails];
        for r in 0..rails {
            pre[r] = 1.0e-3;
        }
        let mut scratch = crate::sim::Scratch::new();
        let base = scratch.run_total(&m, &cp, &crossing, 4);
        let charged = scratch.run_total_with(&m, &cp, &crossing, 4, Some(&pre));
        assert!((charged - (base + 1.0e-3)).abs() < 1e-12, "crossing traffic queues behind the background load");
        // on-node traffic never touches a NIC timeline: bit-identical
        let l0 = scratch.run_total(&m, &cp, &local, 4);
        let l1 = scratch.run_total_with(&m, &cp, &local, 4, Some(&pre));
        assert_eq!(l0.to_bits(), l1.to_bits());
    }

    #[test]
    fn precharge_zero_and_none_are_bit_identical() {
        use crate::pattern::generators::random_pattern;
        use crate::util::rng::Rng;
        let m = lassen(3);
        let p = lassen_params();
        let cp = p.compile();
        let mut rng = Rng::new(2024);
        let pattern = random_pattern(&m, &mut rng, 64, 1 << 15, 0.25);
        let zeros = vec![0.0; m.num_nodes * m.nics_per_node()];
        let mut scratch = crate::sim::Scratch::new();
        for s in Strategy::all() {
            let sched = build_schedule(s, &m, &pattern);
            let ppn = s.sim_ppn(&m);
            let a = scratch.run_total(&m, &cp, &sched, ppn);
            let b = scratch.run_total_with(&m, &cp, &sched, ppn, Some(&zeros));
            let c = scratch.run_total_with(&m, &cp, &sched, ppn, None);
            assert_eq!(a.to_bits(), b.to_bits(), "{}", sched.strategy_label);
            assert_eq!(a.to_bits(), c.to_bits(), "{}", sched.strategy_label);
        }
    }

    #[test]
    fn precharged_compiled_matches_precharged_reference() {
        use crate::pattern::generators::random_pattern;
        use crate::util::rng::Rng;
        let m = lassen(3);
        let p = lassen_params();
        let cp = p.compile();
        let mut rng = Rng::new(99);
        let pattern = random_pattern(&m, &mut rng, 96, 1 << 16, 0.25);
        let n = m.num_nodes * m.nics_per_node();
        let pre: Vec<f64> = (0..n).map(|i| rng.f64() * 2.0e-4 + (i % 2) as f64 * 1.0e-5).collect();
        let mut scratch = crate::sim::Scratch::new();
        for s in Strategy::all() {
            let sched = build_schedule(s, &m, &pattern);
            let ppn = s.sim_ppn(&m);
            let fast = scratch.run_totals_with(&m, &cp, &sched, ppn, Some(&pre));
            let slow = run_reference_with(&m, &p, &sched, ppn, Some(&pre));
            assert_eq!(fast.total.to_bits(), slow.total.to_bits(), "{}", sched.strategy_label);
            assert_eq!(fast.max_node_injected, slow.max_node_injected);
            assert_eq!(fast.internode_msgs, slow.internode_msgs);
        }
    }

    #[test]
    fn scratch_reuse_is_bit_stable() {
        let m = lassen(2);
        let cp = lassen_params().compile();
        let s1 = single_xfer_schedule(Loc::Host(ProcId(0)), Loc::Host(ProcId(4)), 1 << 12);
        let s2 = single_xfer_schedule(Loc::Gpu(GpuId(0)), Loc::Gpu(GpuId(4)), 1 << 18);
        let mut scratch = crate::sim::Scratch::new();
        let a1 = scratch.run_total(&m, &cp, &s1, 4);
        let b1 = scratch.run_total(&m, &cp, &s2, 4);
        // interleave again: prior state must not leak through the scratch
        let a2 = scratch.run_total(&m, &cp, &s1, 4);
        let b2 = scratch.run_total(&m, &cp, &s2, 4);
        assert_eq!(a1.to_bits(), a2.to_bits());
        assert_eq!(b1.to_bits(), b2.to_bits());
    }
}
