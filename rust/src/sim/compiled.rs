//! Compiled forms of the simulation hot path.
//!
//! Two lowerings live here, one per hot axis of the sweep loop:
//!
//! - [`CompiledPattern`] lowers a [`CommPattern`] against a machine **once
//!   per cell**: every message's node pair is resolved, inter-node messages
//!   are grouped by ordered node pair with their dedup aggregates
//!   (unique-bytes-per-source, full-delivery-per-destination, dominant
//!   senders) precomputed, and the staging/delivery volumes every staged
//!   builder needs are summed up front. All 8 Table 5 strategies then build
//!   their schedules from this one lowering
//!   ([`crate::comm::build_schedule_from`]), so pattern grouping, duplicate
//!   elimination and locality resolution stop being per-strategy work.
//!
//! - [`CompiledSchedule`] lowers a built [`Schedule`] against
//!   (machine, [`CompiledParams`], ppn) into flat SoA arrays: dense `u32`
//!   resource ids (process / GPU / NIC rail / copy engine — the NIC block
//!   holds one timeline per (node, rail) of the machine's
//!   [`crate::topology::NodeShape`]), precomputed postal durations and
//!   per-rail NIC occupancies, byte counts and phase offsets. The
//!   executor ([`crate::sim::exec::run_compiled`]) then walks plain arrays —
//!   no hash maps, no enum matching, no allocation. `lower_into` reuses the
//!   arrays across calls so a worker thread compiles schedules all sweep
//!   long without touching the allocator (after warm-up growth).
//!
//! Both lowerings are *pure reshapes*: the simulated times they produce are
//! bit-for-bit identical to the retained reference executor
//! ([`crate::sim::exec::run_reference`]), which `rust/tests/prop_sim.rs`
//! asserts on randomized schedules.

use crate::comm::{plan, CopyKind, Loc, Schedule};
use crate::params::{CompiledParams, CopyDir, Endpoint};
use crate::pattern::{CommPattern, Msg};
use crate::topology::{GpuId, Locality, Machine, NodeId};
use std::collections::BTreeMap;

/// Sentinel resource index: "this transfer does not cross the NIC".
pub const NO_NIC: u32 = u32::MAX;

/// One inter-node message group of a lowered pattern: everything the
/// strategy builders derive per (source node, destination node).
#[derive(Clone, Debug, PartialEq)]
pub struct PairGroup {
    pub src_node: NodeId,
    pub dst_node: NodeId,
    /// The group's messages, in pattern order (matches
    /// [`plan::group_by_node_pair`]).
    pub msgs: Vec<Msg>,
    /// Unique bytes per source GPU after duplicate elimination, in GPU-id
    /// order ([`plan::unique_bytes_by_src`]).
    pub unique_by_src: Vec<(GpuId, usize)>,
    /// Total unique bytes of the group ([`plan::unique_bytes`]).
    pub unique_total: usize,
    /// Full delivery bytes per destination GPU, in GPU-id order
    /// ([`plan::bytes_by_dst`]).
    pub by_dst: Vec<(GpuId, usize)>,
    /// For each `by_dst` entry, the sender contributing the largest share
    /// (2-Step redistribution routing; ties broken toward the lowest id).
    pub dominant_src: Vec<GpuId>,
}

/// A [`CommPattern`] lowered against a machine once per cell and shared by
/// every strategy's schedule builder.
#[derive(Clone, Debug)]
pub struct CompiledPattern<'p> {
    pub pattern: &'p CommPattern,
    /// Inter-node groups in ordered-(src, dst)-node order.
    pub groups: Vec<PairGroup>,
    /// Intra-node messages with their original pattern indices (data-plane
    /// tags), in pattern order.
    pub intra: Vec<(u32, Msg)>,
    /// Per-GPU outgoing bytes over *all* messages (Standard's staging
    /// volumes — no dedup), in GPU-id order.
    pub out_bytes_all: Vec<(GpuId, usize)>,
    /// Per-GPU incoming bytes over *all* messages, in GPU-id order.
    pub in_bytes_all: Vec<(GpuId, usize)>,
    /// Per-GPU staged volume after duplicate elimination plus intra-node
    /// payloads (the 3-Step / Split D2H staging volumes), in GPU-id order.
    /// (2-Step rebuilds its own map from the group aggregates instead: its
    /// historical builder skips GPUs whose only payloads are zero-byte,
    /// while this precompute keeps them — identical on any real pattern.)
    pub stage_out_unique: Vec<(GpuId, usize)>,
    /// Per-GPU full delivery volume (duplicates expanded) plus intra-node
    /// payloads (the 3-Step / Split H2D volumes), in GPU-id order.
    pub deliver_in_full: Vec<(GpuId, usize)>,
}

impl<'p> CompiledPattern<'p> {
    /// Lower a pattern: group, dedup and classify once for all strategies.
    pub fn lower(machine: &Machine, pattern: &'p CommPattern) -> CompiledPattern<'p> {
        let raw_groups = plan::group_by_node_pair(machine, pattern);
        let mut groups = Vec::with_capacity(raw_groups.len());
        let mut stage_out: BTreeMap<GpuId, usize> = BTreeMap::new();
        let mut deliver_in: BTreeMap<GpuId, usize> = BTreeMap::new();
        for ((src_node, dst_node), msgs) in raw_groups {
            let unique_by_src: Vec<(GpuId, usize)> = plan::unique_bytes_by_src(&msgs).into_iter().collect();
            let unique_total = plan::unique_bytes(&msgs);
            let by_dst: Vec<(GpuId, usize)> = plan::bytes_by_dst(&msgs).into_iter().collect();
            let dominant_src = by_dst.iter().map(|&(dst, _)| dominant_sender(&msgs, dst)).collect();
            for &(g, b) in &unique_by_src {
                *stage_out.entry(g).or_default() += b;
            }
            for &(g, b) in &by_dst {
                *deliver_in.entry(g).or_default() += b;
            }
            groups.push(PairGroup { src_node, dst_node, msgs, unique_by_src, unique_total, by_dst, dominant_src });
        }

        let mut intra = Vec::new();
        let mut out_all: BTreeMap<GpuId, usize> = BTreeMap::new();
        let mut in_all: BTreeMap<GpuId, usize> = BTreeMap::new();
        for (i, m) in pattern.msgs.iter().enumerate() {
            *out_all.entry(m.src).or_default() += m.bytes;
            *in_all.entry(m.dst).or_default() += m.bytes;
            if machine.gpu_node(m.src) == machine.gpu_node(m.dst) {
                *stage_out.entry(m.src).or_default() += m.bytes;
                *deliver_in.entry(m.dst).or_default() += m.bytes;
                intra.push((i as u32, *m));
            }
        }

        CompiledPattern {
            pattern,
            groups,
            intra,
            out_bytes_all: out_all.into_iter().collect(),
            in_bytes_all: in_all.into_iter().collect(),
            stage_out_unique: stage_out.into_iter().collect(),
            deliver_in_full: deliver_in.into_iter().collect(),
        }
    }

    /// Re-target this lowering at `pattern`, which must share its topology
    /// (same messages, sources, destinations and dup groups, in order) with
    /// every byte count multiplied by `scale` — the sweep's
    /// `--reuse-patterns` fast path, where neighboring grid cells differ
    /// only in message size.
    ///
    /// Grouping, locality, dedup classification and dominant-sender choice
    /// are all invariant under a uniform positive byte scale: group
    /// membership depends only on node pairs, every byte aggregate is a sum
    /// (so it scales exactly in integer arithmetic), and the dominant-sender
    /// `max_by_key((bytes, Reverse(src)))` order is preserved because
    /// `b -> b·scale` is strictly monotone (ties stay ties). The result is
    /// therefore identical — field for field — to
    /// `CompiledPattern::lower(machine, pattern)`.
    pub fn rescaled<'q>(&self, pattern: &'q CommPattern, scale: usize) -> CompiledPattern<'q> {
        debug_assert!(scale > 0, "rescaled needs a positive scale");
        debug_assert_eq!(pattern.msgs.len(), self.pattern.msgs.len(), "rescaled patterns must share topology");
        debug_assert!(
            pattern
                .msgs
                .iter()
                .zip(&self.pattern.msgs)
                .all(|(a, b)| a.src == b.src && a.dst == b.dst && a.dup_group == b.dup_group && a.bytes == b.bytes * scale),
            "rescaled pattern must be the unit pattern with bytes x scale"
        );
        let mul_pairs = |v: &[(GpuId, usize)]| v.iter().map(|&(g, b)| (g, b * scale)).collect();
        let groups = self
            .groups
            .iter()
            .map(|g| PairGroup {
                src_node: g.src_node,
                dst_node: g.dst_node,
                msgs: g.msgs.iter().map(|m| Msg { bytes: m.bytes * scale, ..*m }).collect(),
                unique_by_src: mul_pairs(&g.unique_by_src),
                unique_total: g.unique_total * scale,
                by_dst: mul_pairs(&g.by_dst),
                dominant_src: g.dominant_src.clone(),
            })
            .collect();
        CompiledPattern {
            pattern,
            groups,
            intra: self.intra.iter().map(|&(i, m)| (i, Msg { bytes: m.bytes * scale, ..m })).collect(),
            out_bytes_all: mul_pairs(&self.out_bytes_all),
            in_bytes_all: mul_pairs(&self.in_bytes_all),
            stage_out_unique: mul_pairs(&self.stage_out_unique),
            deliver_in_full: mul_pairs(&self.deliver_in_full),
        }
    }
}

/// The sender contributing the largest share of a destination's bytes
/// (ties toward the lowest GPU id — matches the 2-Step builder's historical
/// `max_by_key((bytes, Reverse(src)))` rule).
fn dominant_sender(msgs: &[Msg], dst: GpuId) -> GpuId {
    let mut by_src: BTreeMap<GpuId, usize> = BTreeMap::new();
    for m in msgs.iter().filter(|m| m.dst == dst) {
        *by_src.entry(m.src).or_default() += m.bytes;
    }
    by_src
        .into_iter()
        .max_by_key(|&(src, b)| (b, std::cmp::Reverse(src.0)))
        .map(|(s, _)| s)
        .expect("dst present in group")
}

/// A [`Schedule`] lowered into flat SoA arrays the zero-allocation executor
/// walks directly. Reused across cells via [`CompiledSchedule::lower_into`].
#[derive(Clone, Debug, Default)]
pub struct CompiledSchedule {
    /// Phase labels, in execution order.
    pub phase_labels: Vec<&'static str>,
    /// Exclusive end offset of each phase's transfers in the `x_*` arrays.
    pub phase_xfer_end: Vec<u32>,
    /// Exclusive end offset of each phase's copies in the `c_*` arrays.
    pub phase_copy_end: Vec<u32>,

    /// Transfer source resource index.
    pub x_src: Vec<u32>,
    /// Transfer destination resource index.
    pub x_dst: Vec<u32>,
    /// NIC resource index ([`NO_NIC`] when the transfer stays on-node).
    pub x_nic: Vec<u32>,
    /// Source node index (injected-bytes accounting; valid when crossing).
    pub x_node: Vec<u32>,
    /// Payload bytes.
    pub x_bytes: Vec<usize>,
    /// Precomputed postal duration [s].
    pub x_dur: Vec<f64>,
    /// Precomputed NIC occupancy `bytes / R_N` [s] (0 when on-node).
    pub x_nic_busy: Vec<f64>,

    /// Copy-engine resource index per copy.
    pub c_engine: Vec<u32>,
    /// Initiating-process resource index per copy.
    pub c_proc: Vec<u32>,
    /// Precomputed copy duration [s].
    pub c_dur: Vec<f64>,

    /// Total dense resource slots (procs ++ GPUs ++ NICs ++ copy engines).
    pub n_resources: u32,
    /// Dense node slots for injected-bytes accounting.
    pub n_nodes: u32,
    /// First NIC slot of the dense layout — the fault layer's congestion
    /// pre-charge ([`crate::sim::exec::run_compiled_with`]) seeds the
    /// `nic_count` timelines starting here, laid out `node * rails + rail`.
    pub nic_base: u32,
    /// Number of NIC slots in the dense layout.
    pub nic_count: u32,
}

impl CompiledSchedule {
    /// Lower a schedule, allocating fresh arrays.
    pub fn lower(machine: &Machine, params: &CompiledParams, schedule: &Schedule, ppn: usize) -> CompiledSchedule {
        let mut cs = CompiledSchedule::default();
        cs.lower_into(machine, params, schedule, ppn);
        cs
    }

    /// Lower a schedule into `self`, reusing the existing arrays (clears
    /// them, keeps capacity) — the allocation-free compile step of the
    /// sweep hot loop.
    pub fn lower_into(&mut self, machine: &Machine, params: &CompiledParams, schedule: &Schedule, ppn: usize) {
        self.phase_labels.clear();
        self.phase_xfer_end.clear();
        self.phase_copy_end.clear();
        self.x_src.clear();
        self.x_dst.clear();
        self.x_nic.clear();
        self.x_node.clear();
        self.x_bytes.clear();
        self.x_dur.clear();
        self.x_nic_busy.clear();
        self.c_engine.clear();
        self.c_proc.clear();
        self.c_dur.clear();

        // Pass 1: the dense resource layout. Process ids normally fall in
        // [0, num_nodes * ppn) and copy GPU ids in [0, total_gpus), but the
        // reference executor tolerates any id on those paths (it keyed a
        // hash map, and the copy path never resolves the GPU's node), so
        // size from what the schedule actually touches. Transfer GPU ids
        // are bounds-checked by `Machine::gpu_node` on both executors.
        let mut max_proc = machine.num_nodes * ppn;
        let mut max_node = machine.num_nodes;
        let mut max_copy_gpu = machine.total_gpus();
        for phase in &schedule.phases {
            for x in &phase.xfers {
                for loc in [x.src, x.dst] {
                    if let Loc::Host(p) = loc {
                        max_proc = max_proc.max(p.0 + 1);
                        max_node = max_node.max(p.0 / ppn + 1);
                    }
                }
            }
            for c in &phase.copies {
                max_proc = max_proc.max(c.proc.0 + 1);
                max_copy_gpu = max_copy_gpu.max(c.gpu.0 + 1);
            }
        }
        let gpus = machine.total_gpus();
        let rails = machine.nics_per_node();
        let proc_base = 0usize;
        let gpu_base = proc_base + max_proc;
        let nic_base = gpu_base + gpus;
        // one occupancy timeline per (node, rail) — the shape sizes the NIC
        // block; single-rail shapes collapse to the historical one-per-node
        let copy_base = nic_base + max_node * rails;
        self.n_resources = (copy_base + max_copy_gpu) as u32;
        self.n_nodes = max_node as u32;
        self.nic_base = nic_base as u32;
        self.nic_count = (max_node * rails) as u32;

        let res = |loc: Loc| -> u32 {
            match loc {
                Loc::Host(p) => (proc_base + p.0) as u32,
                Loc::Gpu(g) => (gpu_base + g.0) as u32,
            }
        };
        let src_node_of = |loc: Loc| -> usize {
            match loc {
                Loc::Gpu(g) => machine.gpu_node(g).0,
                Loc::Host(p) => machine.proc_node(p, ppn).0,
            }
        };

        // Pass 2: classify and cost every operation. The locality rule
        // itself lives in one place ([`crate::sim::exec`]'s `locality`),
        // shared with the reference executor.
        for phase in &schedule.phases {
            self.phase_labels.push(phase.label);
            for x in &phase.xfers {
                if x.bytes == 0 {
                    continue; // zero-byte transfers are free in the reference too
                }
                let loc = crate::sim::exec::locality(machine, x.src, x.dst, ppn);
                let ep = match (x.src, x.dst) {
                    (Loc::Gpu(_), _) | (_, Loc::Gpu(_)) => Endpoint::Gpu,
                    _ => Endpoint::Cpu,
                };
                let (nic, node, nic_busy) = if loc == Locality::OffNode {
                    let sn = src_node_of(x.src);
                    // rail assignment shares one home with the reference
                    // executor ([`crate::sim::exec`]'s `rail`): GPU sources
                    // follow the shape's affinity map, host sources
                    // round-robin their socket's rails by node pair
                    let r = crate::sim::exec::rail(machine, x.src, x.dst, ppn);
                    ((nic_base + sn * rails + r) as u32, sn as u32, params.nic_busy(r, x.bytes))
                } else {
                    (NO_NIC, 0, 0.0)
                };
                self.x_src.push(res(x.src));
                self.x_dst.push(res(x.dst));
                self.x_nic.push(nic);
                self.x_node.push(node);
                self.x_bytes.push(x.bytes);
                self.x_dur.push(params.msg_time(ep, loc, x.bytes));
                self.x_nic_busy.push(nic_busy);
            }
            for c in &phase.copies {
                let dir = match c.dir {
                    CopyKind::D2H => CopyDir::D2H,
                    CopyKind::H2D => CopyDir::H2D,
                };
                self.c_engine.push((copy_base + c.gpu.0) as u32);
                self.c_proc.push((proc_base + c.proc.0) as u32);
                self.c_dur.push(params.memcpy_time(dir, c.bytes, c.nprocs));
            }
            self.phase_xfer_end.push(self.x_src.len() as u32);
            self.phase_copy_end.push(self.c_engine.len() as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule, Strategy, Transport};
    use crate::params::lassen_params;
    use crate::pattern::generators::random_pattern;
    use crate::topology::machines::lassen;
    use crate::util::rng::Rng;

    fn pattern_on(machine: &Machine, seed: u64, n: usize) -> CommPattern {
        let mut rng = Rng::new(seed);
        random_pattern(machine, &mut rng, n, 1 << 14, 0.25)
    }

    #[test]
    fn lowered_pattern_matches_plan_helpers() {
        let m = lassen(3);
        let p = pattern_on(&m, 7, 64);
        let cp = CompiledPattern::lower(&m, &p);
        let raw = plan::group_by_node_pair(&m, &p);
        assert_eq!(cp.groups.len(), raw.len());
        for (g, (&(k, l), msgs)) in cp.groups.iter().zip(raw.iter()) {
            assert_eq!((g.src_node, g.dst_node), (k, l));
            assert_eq!(&g.msgs, msgs);
            assert_eq!(g.unique_by_src, plan::unique_bytes_by_src(msgs).into_iter().collect::<Vec<_>>());
            assert_eq!(g.unique_total, plan::unique_bytes(msgs));
            assert_eq!(g.by_dst, plan::bytes_by_dst(msgs).into_iter().collect::<Vec<_>>());
            assert_eq!(g.by_dst.len(), g.dominant_src.len());
        }
        // intra list covers exactly the non-crossing messages with their tags
        let intra_count = p.msgs.iter().filter(|x| m.gpu_node(x.src) == m.gpu_node(x.dst)).count();
        assert_eq!(cp.intra.len(), intra_count);
        for &(i, msg) in &cp.intra {
            assert_eq!(p.msgs[i as usize], msg);
        }
        // staging identities: unique inter-node + intra == stage_out_unique
        let total_unique: usize = cp.groups.iter().map(|g| g.unique_total).sum();
        let total_intra: usize = cp.intra.iter().map(|&(_, m)| m.bytes).sum();
        let staged: usize = cp.stage_out_unique.iter().map(|&(_, b)| b).sum();
        assert_eq!(staged, total_unique + total_intra);
    }

    #[test]
    fn lowered_schedule_shapes_and_offsets() {
        let m = lassen(2);
        let p = pattern_on(&m, 11, 48);
        let params = lassen_params().compile();
        for s in Strategy::all() {
            let sched = build_schedule(s, &m, &p);
            let ppn = s.sim_ppn(&m);
            let cs = CompiledSchedule::lower(&m, &params, &sched, ppn);
            assert_eq!(cs.phase_labels.len(), sched.phases.len());
            assert_eq!(cs.phase_xfer_end.len(), sched.phases.len());
            let nonzero: usize = sched.phases.iter().flat_map(|ph| &ph.xfers).filter(|x| x.bytes > 0).count();
            assert_eq!(cs.x_src.len(), nonzero);
            let copies: usize = sched.phases.iter().map(|ph| ph.copies.len()).sum();
            assert_eq!(cs.c_engine.len(), copies);
            // offsets are monotone and end at the array lengths
            assert!(cs.phase_xfer_end.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(*cs.phase_xfer_end.last().unwrap_or(&0) as usize, cs.x_src.len());
            assert_eq!(*cs.phase_copy_end.last().unwrap_or(&0) as usize, cs.c_engine.len());
            // every resource index is in range
            for &r in cs.x_src.iter().chain(&cs.x_dst).chain(&cs.c_engine).chain(&cs.c_proc) {
                assert!(r < cs.n_resources);
            }
            for &nic in &cs.x_nic {
                assert!(nic == NO_NIC || nic < cs.n_resources);
            }
        }
    }

    #[test]
    fn rescaled_matches_direct_lowering() {
        use crate::pattern::generators::Scenario;
        let m = lassen(6);
        for scale in [1usize, 2, 300, 1 << 14] {
            let unit = Scenario { n_msgs: 48, msg_size: 1, n_dest: 5, dup_frac: 0.0 }.materialize(&m);
            let scaled = Scenario { n_msgs: 48, msg_size: scale, n_dest: 5, dup_frac: 0.0 }.materialize(&m);
            let from_unit = CompiledPattern::lower(&m, &unit).rescaled(&scaled, scale);
            let direct = CompiledPattern::lower(&m, &scaled);
            assert_eq!(from_unit.groups, direct.groups, "scale {scale}");
            assert_eq!(from_unit.intra, direct.intra);
            assert_eq!(from_unit.out_bytes_all, direct.out_bytes_all);
            assert_eq!(from_unit.in_bytes_all, direct.in_bytes_all);
            assert_eq!(from_unit.stage_out_unique, direct.stage_out_unique);
            assert_eq!(from_unit.deliver_in_full, direct.deliver_in_full);
        }
    }

    #[test]
    fn lower_into_reuses_capacity() {
        let m = lassen(2);
        let p = pattern_on(&m, 3, 64);
        let params = lassen_params().compile();
        let s = Strategy::new(crate::comm::StrategyKind::Standard, Transport::Staged).unwrap();
        let sched = build_schedule(s, &m, &p);
        let mut cs = CompiledSchedule::lower(&m, &params, &sched, s.sim_ppn(&m));
        let cap = cs.x_src.capacity();
        let first = cs.x_dur.clone();
        cs.lower_into(&m, &params, &sched, s.sim_ppn(&m));
        assert_eq!(cs.x_src.capacity(), cap, "relowering the same schedule must not grow");
        assert_eq!(cs.x_dur, first, "relowering must be deterministic");
    }
}
