//! Discrete-event cluster simulator — the testbed substitute.
//!
//! The simulator executes a communication [`crate::comm::Schedule`] against
//! a [`crate::topology::Machine`] with the paper's measured
//! [`crate::params::MachineParams`]:
//!
//! - every endpoint (host process or GPU) is a serial resource — its
//!   transfers and copies queue;
//! - every node's NIC is a rate-limited resource — inter-node transfers
//!   occupy it for `bytes / R_N`, which reproduces the max-rate injection
//!   limit of Eq. (2.2) *emergently* when many processes inject at once;
//! - each transfer's duration is the postal time (Eq. 2.1) with the
//!   (α, β) row selected by endpoint kind, locality and per-message
//!   protocol, exactly as in Section 3;
//! - copies use the Table 3 `cudaMemcpyAsync` parameters, serialized per
//!   GPU copy engine;
//! - phases are barriers, matching the step structure of Section 2.3.
//!
//! [`exec::run`] returns per-phase and total simulated times.

pub mod exec;
pub mod network;

pub use exec::{run, SimReport};
