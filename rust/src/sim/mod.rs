//! Discrete-event cluster simulator — the testbed substitute.
//!
//! The simulator executes a communication [`crate::comm::Schedule`] against
//! a [`crate::topology::Machine`] with the paper's measured
//! [`crate::params::MachineParams`]:
//!
//! - every endpoint (host process or GPU) is a serial resource — its
//!   transfers and copies queue;
//! - every NIC *rail* of the node shape
//!   ([`crate::topology::NodeShape`]) is a rate-limited resource —
//!   inter-node transfers occupy their assigned rail for its band time
//!   (`bytes / R_N` on the default homogeneous bands), which reproduces
//!   the max-rate injection limit of Eq. (2.2) — generalized to
//!   `nic_count · R_N` on multi-rail nodes — *emergently* when many
//!   processes inject at once;
//! - each transfer's duration is the postal time (Eq. 2.1) with the
//!   (α, β) row selected by endpoint kind, locality and per-message
//!   protocol, exactly as in Section 3;
//! - copies use the Table 3 `cudaMemcpyAsync` parameters, serialized per
//!   GPU copy engine;
//! - phases are barriers, matching the step structure of Section 2.3.
//!
//! The hot path is split into *compile* and *execute* stages
//! (see [`compiled`] and docs/PERFORMANCE.md): schedules are lowered once
//! into flat SoA arrays with precomputed durations and dense resource ids,
//! then executed allocation-free against a reusable [`Scratch`].
//! [`exec::run`] keeps the one-call convenience API; sweep-scale callers
//! hold a [`Scratch`] per worker thread instead.

pub mod compiled;
pub mod exec;
pub mod network;

pub use compiled::{CompiledPattern, CompiledSchedule};
pub use exec::{run, run_reference, run_reference_with, ExecScratch, SimReport, SimTotals};

use crate::comm::Schedule;
use crate::params::CompiledParams;
use crate::topology::Machine;

/// Per-worker simulation buffers: a reusable [`CompiledSchedule`] (the
/// compile stage's output arrays) plus the executor's [`ExecScratch`].
/// Create one per thread and reuse it across cells — after warm-up the hot
/// loop performs no heap allocation.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    pub schedule: CompiledSchedule,
    pub exec: ExecScratch,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Lower `schedule` into the reused buffers and execute it, returning
    /// the end-to-end simulated seconds (the sweep hot path).
    pub fn run_total(&mut self, machine: &Machine, params: &CompiledParams, schedule: &Schedule, ppn: usize) -> f64 {
        self.run_totals(machine, params, schedule, ppn).total
    }

    /// Like [`Scratch::run_total`] but returns all scalar outcomes.
    pub fn run_totals(
        &mut self,
        machine: &Machine,
        params: &CompiledParams,
        schedule: &Schedule,
        ppn: usize,
    ) -> SimTotals {
        self.run_totals_with(machine, params, schedule, ppn, None)
    }

    /// [`Scratch::run_total`] with the fault layer's NIC congestion
    /// pre-charge (`precharge[node * rails + rail]` seconds of seeded
    /// background occupancy; see [`exec::run_compiled_with`]).
    pub fn run_total_with(
        &mut self,
        machine: &Machine,
        params: &CompiledParams,
        schedule: &Schedule,
        ppn: usize,
        precharge: Option<&[f64]>,
    ) -> f64 {
        self.run_totals_with(machine, params, schedule, ppn, precharge).total
    }

    /// [`Scratch::run_totals`] with the NIC congestion pre-charge.
    pub fn run_totals_with(
        &mut self,
        machine: &Machine,
        params: &CompiledParams,
        schedule: &Schedule,
        ppn: usize,
        precharge: Option<&[f64]>,
    ) -> SimTotals {
        self.schedule.lower_into(machine, params, schedule, ppn);
        exec::run_compiled_with(&self.schedule, &mut self.exec, precharge)
    }

    /// Full report (allocates the report itself; the execution is still the
    /// compiled path). Bit-for-bit equal to [`exec::run_reference`].
    pub fn run_report(
        &mut self,
        machine: &Machine,
        params: &CompiledParams,
        schedule: &Schedule,
        ppn: usize,
    ) -> SimReport {
        let totals = self.run_totals(machine, params, schedule, ppn);
        SimReport {
            strategy_label: schedule.strategy_label.clone(),
            phase_times: self.exec.phase_times.clone(),
            total: totals.total,
            max_node_injected: totals.max_node_injected,
            internode_msgs: totals.internode_msgs,
        }
    }
}
