//! 3-Step node-aware communication (Section 2.3.1, Figure 2.3).
//!
//! All data on node `k` destined for node `l` is gathered in one buffer on
//! the process paired with `l` (Step 1), shipped in a single inter-node
//! message to the paired process on `l` (Step 2), and redistributed to the
//! final destination processes on-node (Step 3). Both standard-communication
//! redundancies are eliminated: one message per node pair, duplicate data
//! shipped once.
//!
//! Intra-node logical messages ride the local exchange concurrently with
//! the gather phase.

use super::plan;
use super::{CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, Transport, Xfer};
use crate::sim::CompiledPattern;
use crate::topology::{GpuId, Machine};

const AGG: u32 = u32::MAX;

pub fn schedule(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    match strategy.transport {
        Transport::DeviceAware => device_aware(strategy, machine, pattern),
        Transport::Staged => staged(strategy, machine, pattern),
    }
}

fn device_aware(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    let mut gather = Phase::new("gather");
    let mut internode = Phase::new("inter-node");
    let mut redist = Phase::new("redistribute");

    for group in &pattern.groups {
        let (k, l) = (group.src_node, group.dst_node);
        let pg_src = plan::paired_gpu(machine, k, l);
        let pg_dst = plan::paired_gpu(machine, l, k);
        // Step 1: contributing GPUs forward their unique bytes to the
        // paired GPU.
        for &(src, bytes) in &group.unique_by_src {
            if src != pg_src && bytes > 0 {
                gather.xfers.push(Xfer { src: Loc::Gpu(src), dst: Loc::Gpu(pg_src), bytes, tag: AGG });
            }
        }
        // Step 2: one buffer per node pair.
        if group.unique_total > 0 {
            internode.xfers.push(Xfer {
                src: Loc::Gpu(pg_src),
                dst: Loc::Gpu(pg_dst),
                bytes: group.unique_total,
                tag: AGG,
            });
        }
        // Step 3: full delivery to each destination GPU.
        for &(dst, bytes) in &group.by_dst {
            if dst != pg_dst && bytes > 0 {
                redist.xfers.push(Xfer { src: Loc::Gpu(pg_dst), dst: Loc::Gpu(dst), bytes, tag: AGG });
            }
        }
    }

    // Local exchange: intra-node logical messages go direct, concurrent
    // with the gather step.
    for &(i, m) in &pattern.intra {
        gather.xfers.push(Xfer { src: Loc::Gpu(m.src), dst: Loc::Gpu(m.dst), bytes: m.bytes, tag: i });
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [gather, internode, redist].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

fn staged(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    let ppg = 1;
    let ppn = machine.gpus_per_node() * ppg;
    let host = |g: GpuId| machine.gpu_host_proc(g, ppg);

    let mut d2h = Phase::new("d2h");
    let mut gather = Phase::new("gather");
    let mut internode = Phase::new("inter-node");
    let mut redist = Phase::new("redistribute");
    let mut h2d = Phase::new("h2d");

    // D2H: each sending GPU stages its unique inter-node bytes plus its
    // intra-node payloads (precomputed once per cell); local exchange at
    // host level runs concurrent with gather.
    for &(i, m) in &pattern.intra {
        gather.xfers.push(Xfer { src: Loc::Host(host(m.src)), dst: Loc::Host(host(m.dst)), bytes: m.bytes, tag: i });
    }
    for &(g, bytes) in &pattern.stage_out_unique {
        d2h.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::D2H, nprocs: 1 });
    }

    for group in &pattern.groups {
        let (k, l) = (group.src_node, group.dst_node);
        let pp_src = plan::paired_proc(machine, k, l, ppn);
        let pp_dst = plan::paired_proc(machine, l, k, ppn);
        // Step 1: gather on the paired process.
        for &(src, bytes) in &group.unique_by_src {
            let hp = host(src);
            if hp != pp_src && bytes > 0 {
                gather.xfers.push(Xfer { src: Loc::Host(hp), dst: Loc::Host(pp_src), bytes, tag: AGG });
            }
        }
        // Step 2: single inter-node buffer.
        if group.unique_total > 0 {
            internode.xfers.push(Xfer {
                src: Loc::Host(pp_src),
                dst: Loc::Host(pp_dst),
                bytes: group.unique_total,
                tag: AGG,
            });
        }
        // Step 3: on-node redistribution, full volumes.
        for &(dst, bytes) in &group.by_dst {
            let hp = host(dst);
            if hp != pp_dst && bytes > 0 {
                redist.xfers.push(Xfer { src: Loc::Host(pp_dst), dst: Loc::Host(hp), bytes, tag: AGG });
            }
        }
    }

    for &(g, bytes) in &pattern.deliver_in_full {
        h2d.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::H2D, nprocs: 1 });
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [d2h, gather, internode, redist, h2d].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule as schedule_of, StrategyKind};
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::machines::lassen;

    fn schedule(s: Strategy, m: &Machine, p: &CommPattern) -> Schedule {
        schedule_of(s, m, p)
    }

    fn strat(t: Transport) -> Strategy {
        Strategy::new(StrategyKind::ThreeStep, t).unwrap()
    }

    fn pattern() -> CommPattern {
        CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 100),
            Msg::new(GpuId(1), GpuId(5), 200),
            Msg::new(GpuId(2), GpuId(6), 300),
            Msg::new(GpuId(5), GpuId(0), 150),
        ])
    }

    #[test]
    fn one_internode_message_per_node_pair() {
        let m = lassen(2);
        for t in [Transport::DeviceAware, Transport::Staged] {
            let sched = schedule(strat(t), &m, &pattern());
            // node0->node1 and node1->node0: exactly 2 inter-node transfers.
            let ppn = 4;
            assert_eq!(sched.internode_msgs(&m, ppn), 2, "{t}");
            assert_eq!(sched.internode_bytes(&m, ppn), 750, "{t}");
        }
    }

    #[test]
    fn duplicate_data_crosses_once() {
        let m = lassen(2);
        let mut a = Msg::new(GpuId(0), GpuId(4), 500);
        a.dup_group = 3;
        let mut b = Msg::new(GpuId(0), GpuId(5), 500);
        b.dup_group = 3;
        let p = CommPattern::new(vec![a, b]);
        let sched = schedule(strat(Transport::DeviceAware), &m, &p);
        assert_eq!(sched.internode_bytes(&m, 4), 500); // shipped once
        // but redistribution delivers to both GPUs
        let redist = sched.phases.last().unwrap();
        assert_eq!(redist.xfers.iter().map(|x| x.bytes).sum::<usize>() , 500 + 500 - 500 /* one dst is the paired gpu? */ );
    }

    #[test]
    fn staged_has_copies_da_does_not() {
        let m = lassen(2);
        let s = schedule(strat(Transport::Staged), &m, &pattern());
        assert!(s.phases.iter().any(|p| !p.copies.is_empty()));
        let d = schedule(strat(Transport::DeviceAware), &m, &pattern());
        assert!(d.phases.iter().all(|p| p.copies.is_empty()));
    }

    #[test]
    fn staged_copy_bytes_match_traffic() {
        let m = lassen(2);
        let s = schedule(strat(Transport::Staged), &m, &pattern());
        let d2h: usize = s.phases[0].copies.iter().map(|c| c.bytes).sum();
        let h2d: usize = s.phases.last().unwrap().copies.iter().map(|c| c.bytes).sum();
        assert_eq!(d2h, 750);
        assert_eq!(h2d, 750);
    }

    #[test]
    fn intranode_messages_direct() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(1), 64)]);
        let sched = schedule(strat(Transport::DeviceAware), &m, &p);
        assert_eq!(sched.phases.len(), 1);
        assert_eq!(sched.phases[0].xfers.len(), 1);
        assert_eq!(sched.internode_msgs(&m, 4), 0);
    }

    #[test]
    fn gather_excludes_paired_gpu_self_send() {
        let m = lassen(2);
        // gpu0 is paired_gpu(node0, node1) (rel=0); its own data needs no
        // gather hop.
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), 100)]);
        let sched = schedule(strat(Transport::DeviceAware), &m, &p);
        let gather_phase = sched.phases.iter().find(|ph| ph.label == "gather");
        assert!(gather_phase.is_none() || gather_phase.unwrap().xfers.is_empty());
    }

    #[test]
    fn empty_pattern() {
        let m = lassen(2);
        let sched = schedule(strat(Transport::Staged), &m, &CommPattern::default());
        assert!(sched.phases.is_empty());
    }
}
