//! Split node-aware communication (Section 2.3.3, Algorithms 1–2,
//! Figure 2.7) — staged-through-host only (Table 5).
//!
//! Inter-node volumes are conglomerated per destination node, split into
//! `message_cap`-byte chunks (with the cap raised to `⌈total/PPN⌉` when the
//! split would exceed the on-node process count), distributed over *all*
//! available on-node CPU cores, injected into the network, and redistributed
//! on the receiving node. Send duties are assigned from the last local rank
//! backwards and receive duties from rank 0 forwards, in descending size
//! order (Algorithm 1, line 18), keeping every core active.
//!
//! - **Split+MD**: one host process per GPU stages data, then *multiple*
//!   on-node messages distribute it (extra on-node hops, cheap copies).
//! - **Split+DD**: four host processes per GPU copy concurrently via
//!   duplicate device pointers (fewer distribution hops, pricier copies —
//!   the 4-proc class of Table 3).

use super::plan;
use super::{CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, StrategyKind, Transport, Xfer};
use crate::sim::CompiledPattern;
use crate::topology::{GpuId, Machine, NodeId, ProcId};
use std::collections::BTreeMap;

const AGG: u32 = u32::MAX;

pub fn schedule(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    assert_eq!(strategy.transport, Transport::Staged, "Split has no device-aware variant");
    let ppg = match strategy.kind {
        StrategyKind::SplitMd => 1,
        StrategyKind::SplitDd => 4,
        other => panic!("split::schedule called with {other}"),
    };
    // Split enlists every CPU core on the node (40 on Lassen).
    let ppn = machine.cores_per_node();
    let host = |g: GpuId| plan::gpu_host_proc_in(machine, g, ppn, ppg);

    let mut d2h = Phase::new("d2h");
    let mut local_s = Phase::new("local-scatter");
    let mut global = Phase::new("inter-node");
    let mut local_r = Phase::new("local-redistribute");
    let mut h2d = Phase::new("h2d");

    // ---- Per sending node: chunking (Algorithm 1 lines 10-17). ----
    // unique volume per (src node, dst node) and per (src gpu, dst node),
    // straight from the per-cell pattern lowering
    let mut vol_by_pair: BTreeMap<NodeId, BTreeMap<NodeId, usize>> = BTreeMap::new();
    let mut vol_by_gpu_dest: BTreeMap<(NodeId, NodeId), &[(GpuId, usize)]> = BTreeMap::new();
    for group in &pattern.groups {
        let (k, l) = (group.src_node, group.dst_node);
        *vol_by_pair.entry(k).or_default().entry(l).or_default() += group.unique_total;
        vol_by_gpu_dest.insert((k, l), &group.unique_by_src);
    }

    // chunks per sending node, with sender-rank assignment (from the back).
    let mut chunks_by_src_node: BTreeMap<NodeId, Vec<(plan::Chunk, ProcId)>> = BTreeMap::new();
    for (&k, vols) in &vol_by_pair {
        let chunks = plan::split_chunks(k, vols, strategy.message_cap, ppn);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.bytes).collect();
        let ranks = plan::assign_ranks(&sizes, ppn, false);
        let assigned: Vec<(plan::Chunk, ProcId)> =
            chunks.into_iter().zip(ranks).map(|(c, r)| (c, ProcId(k.0 * ppn + r))).collect();
        chunks_by_src_node.insert(k, assigned);
    }

    // receive-rank assignment per destination node (from the front).
    let mut inbound: BTreeMap<NodeId, Vec<(NodeId, usize)>> = BTreeMap::new(); // dst -> [(src node, chunk bytes)] indices align with chunk lists
    let mut recv_proc: BTreeMap<(NodeId, usize), ProcId> = BTreeMap::new(); // (src node, chunk idx) -> recv proc
    for (&k, chunks) in &chunks_by_src_node {
        for (i, (c, _)) in chunks.iter().enumerate() {
            inbound.entry(c.dst_node).or_default().push((k, i));
            let _ = i;
        }
    }
    for (&l, entries) in &inbound {
        let sizes: Vec<usize> = entries.iter().map(|&(k, i)| chunks_by_src_node[&k][i].0.bytes).collect();
        let ranks = plan::assign_ranks(&sizes, ppn, true);
        for (&(k, i), r) in entries.iter().zip(ranks) {
            recv_proc.insert((k, i), ProcId(l.0 * ppn + r));
        }
    }

    // ---- Staging copies (D2H) + delivery copies (H2D): the per-cell
    // lowering already summed unique staging and full delivery volumes. ----
    // Intra-node messages: host-level local exchange concurrent with the
    // scatter phase.
    for &(i, m) in &pattern.intra {
        local_s.xfers.push(Xfer { src: Loc::Host(host(m.src)), dst: Loc::Host(host(m.dst)), bytes: m.bytes, tag: i });
    }
    for &(g, bytes) in &pattern.stage_out_unique {
        d2h.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::D2H, nprocs: ppg });
    }
    for &(g, bytes) in &pattern.deliver_in_full {
        h2d.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::H2D, nprocs: ppg });
    }

    // ---- local_Scomm: move chunk payloads from staging procs to their
    // assigned sender procs (greedy proration of GPU contributions over
    // chunks, per (k,l) pair). ----
    for (&k, chunks) in &chunks_by_src_node {
        // walk each destination's gpu contributions against its chunks
        let mut by_dest: BTreeMap<NodeId, Vec<(usize, plan::Chunk, ProcId)>> = BTreeMap::new();
        for (i, &(c, p)) in chunks.iter().enumerate() {
            by_dest.entry(c.dst_node).or_default().push((i, c, p));
        }
        for (&l, dest_chunks) in &by_dest {
            let contribs = vol_by_gpu_dest[&(k, l)];
            let mut ci = 0usize; // chunk cursor
            let mut chunk_rem = dest_chunks[0].1.bytes;
            for &(g, mut b) in contribs {
                let staging = host(g);
                while b > 0 {
                    let take = b.min(chunk_rem);
                    let sender = dest_chunks[ci].2;
                    if sender != staging {
                        local_s.xfers.push(Xfer { src: Loc::Host(staging), dst: Loc::Host(sender), bytes: take, tag: AGG });
                    }
                    b -= take;
                    chunk_rem -= take;
                    if chunk_rem == 0 && ci + 1 < dest_chunks.len() {
                        ci += 1;
                        chunk_rem = dest_chunks[ci].1.bytes;
                    }
                }
            }
        }
    }

    // ---- global_comm: one inter-node transfer per chunk. ----
    for (&k, chunks) in &chunks_by_src_node {
        for (i, &(c, sender)) in chunks.iter().enumerate() {
            let recv = recv_proc[&(k, i)];
            global.xfers.push(Xfer { src: Loc::Host(sender), dst: Loc::Host(recv), bytes: c.bytes, tag: AGG });
        }
    }

    // ---- local_Rcomm: deliver full per-dst-GPU volumes from the chunk
    // receive procs (greedy proration; duplicate expansion folds into the
    // final chunk of each (k,l)). ----
    for group in &pattern.groups {
        let (k, l) = (group.src_node, group.dst_node);
        let deliveries = &group.by_dst;
        let pair_chunks: Vec<(usize, ProcId)> = chunks_by_src_node[&k]
            .iter()
            .enumerate()
            .filter(|(_, (c, _))| c.dst_node == l)
            .map(|(i, (c, _))| (c.bytes, recv_proc[&(k, i)]))
            .collect();
        debug_assert!(!pair_chunks.is_empty());
        let mut ci = 0usize;
        let mut chunk_rem = pair_chunks[0].0;
        for &(g, mut need) in deliveries {
            let dst_host = host(g);
            while need > 0 {
                let last = ci + 1 == pair_chunks.len();
                let take = if last { need } else { need.min(chunk_rem) };
                let src_proc = pair_chunks[ci].1;
                if src_proc != dst_host {
                    local_r.xfers.push(Xfer { src: Loc::Host(src_proc), dst: Loc::Host(dst_host), bytes: take, tag: AGG });
                }
                need -= take;
                if !last {
                    chunk_rem -= take;
                    if chunk_rem == 0 {
                        ci += 1;
                        chunk_rem = pair_chunks[ci].0;
                    }
                }
            }
        }
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [d2h, local_s, global, local_r, h2d].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_schedule as schedule_of;
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::machines::lassen;

    fn schedule(s: Strategy, m: &Machine, p: &CommPattern) -> Schedule {
        schedule_of(s, m, p)
    }

    fn md() -> Strategy {
        Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap()
    }

    fn dd() -> Strategy {
        Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap()
    }

    #[test]
    fn small_volumes_conglomerate_per_node() {
        let m = lassen(3);
        // 6 small messages node0 -> node1, 2 -> node2; all below cap.
        let p = CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 100),
            Msg::new(GpuId(0), GpuId(5), 100),
            Msg::new(GpuId(1), GpuId(6), 100),
            Msg::new(GpuId(2), GpuId(7), 100),
            Msg::new(GpuId(3), GpuId(8), 100),
            Msg::new(GpuId(3), GpuId(9), 100),
        ]);
        let sched = schedule(md(), &m, &p);
        assert_eq!(sched.internode_msgs(&m, 40), 2, "one conglomerated msg per dest node");
        assert_eq!(sched.internode_bytes(&m, 40), 600);
    }

    #[test]
    fn large_volume_splits_at_cap() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), 40_000)]);
        let sched = schedule(md(), &m, &p);
        // 40000 / 8192 -> 5 chunks
        assert_eq!(sched.internode_msgs(&m, 40), 5);
        assert_eq!(sched.internode_bytes(&m, 40), 40_000);
        // every inter-node xfer obeys the (possibly raised) cap
        for ph in sched.phases.iter().filter(|p| p.label == "inter-node") {
            for x in &ph.xfers {
                assert!(x.bytes <= 8192, "chunk {} exceeds cap", x.bytes);
            }
        }
    }

    #[test]
    fn cap_raised_when_chunks_exceed_ppn() {
        let m = lassen(2);
        let total = 8192 * 100; // would be 100 chunks at the default cap
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), total)]);
        let sched = schedule(md(), &m, &p);
        let n = sched.internode_msgs(&m, 40);
        assert!(n <= 40, "chunk count {n} must be <= ppn after cap raise");
        assert_eq!(sched.internode_bytes(&m, 40), total);
    }

    #[test]
    fn senders_spread_across_ranks() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), 8192 * 10)]);
        let sched = schedule(md(), &m, &p);
        let senders: std::collections::BTreeSet<_> = sched
            .phases
            .iter()
            .filter(|ph| ph.label == "inter-node")
            .flat_map(|ph| &ph.xfers)
            .map(|x| x.src)
            .collect();
        assert!(senders.len() >= 5, "expected distribution across ranks, got {}", senders.len());
    }

    #[test]
    fn dd_uses_four_proc_copies() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), 10_000)]);
        let s_md = schedule(md(), &m, &p);
        let s_dd = schedule(dd(), &m, &p);
        assert!(s_md.phases[0].copies.iter().all(|c| c.nprocs == 1));
        assert!(s_dd.phases[0].copies.iter().all(|c| c.nprocs == 4));
    }

    #[test]
    fn dd_fewer_scatter_messages() {
        let m = lassen(2);
        let p = CommPattern::new(vec![Msg::new(GpuId(0), GpuId(4), 8192 * 12)]);
        let count = |s: &Schedule| {
            s.phases.iter().filter(|p| p.label == "local-scatter").flat_map(|p| &p.xfers).count()
        };
        let md_n = count(&schedule(md(), &m, &p));
        let dd_n = count(&schedule(dd(), &m, &p));
        // DD stages through 4 procs whose blocks already cover 4 sender
        // ranks; scatter count should not exceed MD's.
        assert!(dd_n <= md_n, "dd {dd_n} > md {md_n}");
    }

    #[test]
    fn delivery_conserves_full_bytes() {
        let m = lassen(2);
        let mut a = Msg::new(GpuId(0), GpuId(4), 9000);
        a.dup_group = 1;
        let mut b = Msg::new(GpuId(0), GpuId(5), 9000);
        b.dup_group = 1;
        let p = CommPattern::new(vec![a, b]);
        let sched = schedule(md(), &m, &p);
        // network carries unique 9000; h2d delivers full 18000
        assert_eq!(sched.internode_bytes(&m, 40), 9000);
        let h2d: usize = sched.phases.last().unwrap().copies.iter().map(|c| c.bytes).sum();
        assert_eq!(h2d, 18_000);
    }

    #[test]
    fn empty_pattern() {
        let m = lassen(2);
        assert!(schedule(md(), &m, &CommPattern::default()).phases.is_empty());
    }

    #[test]
    #[should_panic(expected = "no device-aware")]
    fn device_aware_rejected() {
        let m = lassen(2);
        let bogus = Strategy { kind: StrategyKind::SplitMd, transport: Transport::DeviceAware, message_cap: 8192 };
        schedule(bogus, &m, &CommPattern::default());
    }
}
