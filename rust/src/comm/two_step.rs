//! 2-Step node-aware communication (Section 2.3.2, Figure 2.4).
//!
//! Each process sends the data needed by a receiving node directly to its
//! *paired* process on that node (equal local rank: P0→P4, P1→P5, …), then
//! the receiving node redistributes on-node. Duplicate data is eliminated
//! (each process ships a given payload to a node once); message redundancy
//! remains — every (process, destination node) pair costs one message.

use super::plan;
use super::{CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, Transport, Xfer};
use crate::sim::CompiledPattern;
use crate::topology::{GpuId, Machine, NodeId};
use std::collections::BTreeMap;

const AGG: u32 = u32::MAX;

pub fn schedule(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    match strategy.transport {
        Transport::DeviceAware => device_aware(strategy, machine, pattern),
        Transport::Staged => staged(strategy, machine, pattern),
    }
}

/// Unique bytes per (source GPU → destination node), the Step-1 message
/// payloads. A (src, dst-node) pair lives in exactly one pair group (the
/// source's node is fixed), so this is a re-keyed view of the lowered
/// groups' per-source aggregates.
fn per_src_payloads(pattern: &CompiledPattern) -> BTreeMap<(GpuId, NodeId), usize> {
    let mut out: BTreeMap<(GpuId, NodeId), usize> = BTreeMap::new();
    for group in &pattern.groups {
        for &(src, bytes) in &group.unique_by_src {
            if bytes > 0 {
                *out.entry((src, group.dst_node)).or_default() += bytes;
            }
        }
    }
    out
}

// The Step-2 redistribution source: payloads from node `k` land on the
// GPUs (or their hosts) paired with the senders; the redistribution fan-out
// is approximated from the *receiving pair* of each sender. For timing
// purposes each delivery is emitted from the paired receiver of the sender
// that contributed the largest share — precomputed per group as
// `dominant_src` during pattern lowering.

fn device_aware(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    let mut send = Phase::new("pair-send");
    let mut redist = Phase::new("redistribute");

    for ((src, l), bytes) in per_src_payloads(pattern) {
        let pair = plan::gpu_rank_pair(machine, src, l);
        send.xfers.push(Xfer { src: Loc::Gpu(src), dst: Loc::Gpu(pair), bytes, tag: AGG });
    }
    for group in &pattern.groups {
        for (&(dst, bytes), &dom) in group.by_dst.iter().zip(&group.dominant_src) {
            if bytes == 0 {
                continue;
            }
            let via = plan::gpu_rank_pair(machine, dom, machine.gpu_node(dst));
            if via != dst {
                redist.xfers.push(Xfer { src: Loc::Gpu(via), dst: Loc::Gpu(dst), bytes, tag: AGG });
            }
        }
    }
    for &(i, m) in &pattern.intra {
        send.xfers.push(Xfer { src: Loc::Gpu(m.src), dst: Loc::Gpu(m.dst), bytes: m.bytes, tag: i });
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [send, redist].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

fn staged(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    let ppg = 1;
    let host = |g: GpuId| machine.gpu_host_proc(g, ppg);
    let ppn = machine.gpus_per_node() * ppg;

    let mut d2h = Phase::new("d2h");
    let mut send = Phase::new("pair-send");
    let mut redist = Phase::new("redistribute");
    let mut h2d = Phase::new("h2d");

    // 2-Step historically derives its staging/delivery volumes from its own
    // emission loops (so a GPU with only zero-byte inter-node payloads gets
    // no copy at all), which differs from the shared
    // `stage_out_unique`/`deliver_in_full` precompute exactly on zero-byte
    // messages. Rebuild the maps from the lowered aggregates — the dedup
    // and grouping work stays shared — to keep the emitted schedule
    // bit-identical to the pre-refactor builder even on degenerate input.
    let mut stage_out: BTreeMap<GpuId, usize> = BTreeMap::new();
    let mut deliver_in: BTreeMap<GpuId, usize> = BTreeMap::new();

    for ((src, l), bytes) in per_src_payloads(pattern) {
        let pair = plan::rank_pair(machine, host(src), l, ppn);
        send.xfers.push(Xfer { src: Loc::Host(host(src)), dst: Loc::Host(pair), bytes, tag: AGG });
        *stage_out.entry(src).or_default() += bytes;
    }
    for group in &pattern.groups {
        for (&(dst, bytes), &dom) in group.by_dst.iter().zip(&group.dominant_src) {
            if bytes == 0 {
                continue;
            }
            let via = plan::rank_pair(machine, host(dom), machine.gpu_node(dst), ppn);
            if via != host(dst) {
                redist.xfers.push(Xfer { src: Loc::Host(via), dst: Loc::Host(host(dst)), bytes, tag: AGG });
            }
            *deliver_in.entry(dst).or_default() += bytes;
        }
    }
    for &(i, m) in &pattern.intra {
        send.xfers.push(Xfer { src: Loc::Host(host(m.src)), dst: Loc::Host(host(m.dst)), bytes: m.bytes, tag: i });
        *stage_out.entry(m.src).or_default() += m.bytes;
        *deliver_in.entry(m.dst).or_default() += m.bytes;
    }

    for (&g, &bytes) in &stage_out {
        d2h.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::D2H, nprocs: 1 });
    }
    for (&g, &bytes) in &deliver_in {
        h2d.copies.push(CopyOp { gpu: g, proc: host(g), bytes, dir: CopyKind::H2D, nprocs: 1 });
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [d2h, send, redist, h2d].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule as schedule_of, StrategyKind};
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::machines::lassen;

    fn schedule(s: Strategy, m: &Machine, p: &CommPattern) -> Schedule {
        schedule_of(s, m, p)
    }

    fn strat(t: Transport) -> Strategy {
        Strategy::new(StrategyKind::TwoStep, t).unwrap()
    }

    fn pattern() -> CommPattern {
        CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 100),
            Msg::new(GpuId(0), GpuId(5), 200),
            Msg::new(GpuId(1), GpuId(4), 300),
        ])
    }

    #[test]
    fn one_message_per_src_per_dest_node() {
        let m = lassen(2);
        let sched = schedule(strat(Transport::DeviceAware), &m, &pattern());
        // GPUs 0 and 1 each send once to node 1: 2 inter-node messages
        // (vs 3 for standard, 1 for 3-step).
        assert_eq!(sched.internode_msgs(&m, 4), 2);
        assert_eq!(sched.internode_bytes(&m, 4), 600);
    }

    #[test]
    fn pairing_preserves_local_rank() {
        let m = lassen(2);
        let sched = schedule(strat(Transport::DeviceAware), &m, &pattern());
        for x in &sched.phases[0].xfers {
            if let (Loc::Gpu(s), Loc::Gpu(d)) = (x.src, x.dst) {
                assert_eq!(m.gpu_local(s), m.gpu_local(d), "2-step pairs equal local ranks");
            }
        }
    }

    #[test]
    fn duplicate_payload_sent_once_per_node() {
        let m = lassen(2);
        let mut a = Msg::new(GpuId(0), GpuId(4), 400);
        a.dup_group = 9;
        let mut b = Msg::new(GpuId(0), GpuId(5), 400);
        b.dup_group = 9;
        let p = CommPattern::new(vec![a, b]);
        let sched = schedule(strat(Transport::DeviceAware), &m, &p);
        assert_eq!(sched.internode_bytes(&m, 4), 400);
        // redistribution still delivers 800 total on-node (one dst is the
        // pair itself).
        let redist: usize =
            sched.phases.iter().filter(|p| p.label == "redistribute").flat_map(|p| &p.xfers).map(|x| x.bytes).sum();
        assert!(redist >= 400);
    }

    #[test]
    fn staged_copies_balance() {
        let m = lassen(2);
        let sched = schedule(strat(Transport::Staged), &m, &pattern());
        let d2h: usize = sched.phases[0].copies.iter().map(|c| c.bytes).sum();
        let h2d: usize = sched.phases.last().unwrap().copies.iter().map(|c| c.bytes).sum();
        assert_eq!(d2h, 600);
        assert_eq!(h2d, 600);
    }

    #[test]
    fn two_step_more_msgs_than_three_step_fewer_than_standard() {
        let m = lassen(2);
        let p = CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 10),
            Msg::new(GpuId(0), GpuId(5), 10),
            Msg::new(GpuId(1), GpuId(6), 10),
            Msg::new(GpuId(2), GpuId(7), 10),
            Msg::new(GpuId(2), GpuId(4), 10),
        ]);
        let std_s = schedule_of(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &m, &p);
        let two_s = schedule(strat(Transport::DeviceAware), &m, &p);
        let three_s = schedule_of(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap(), &m, &p);
        let ppn = 4;
        assert_eq!(std_s.internode_msgs(&m, ppn), 5);
        assert_eq!(two_s.internode_msgs(&m, ppn), 3); // gpus 0,1,2 once each
        assert_eq!(three_s.internode_msgs(&m, ppn), 1);
    }

    #[test]
    fn empty_pattern() {
        let m = lassen(2);
        assert!(schedule(strat(Transport::Staged), &m, &CommPattern::default()).phases.is_empty());
    }
}
