//! Shared planning helpers for the strategy schedule builders: message
//! grouping, process pairing, host-process placement and message-cap
//! chunking (the reusable pieces of Algorithms 1–2).

use crate::pattern::{CommPattern, Msg};
use crate::topology::{GpuId, Machine, NodeId, ProcId};
use std::collections::BTreeMap;

/// Messages grouped by (source node, destination node), inter-node only.
pub type NodePairGroups = BTreeMap<(NodeId, NodeId), Vec<Msg>>;

/// Group the inter-node messages of a pattern by ordered node pair.
pub fn group_by_node_pair(machine: &Machine, pattern: &CommPattern) -> NodePairGroups {
    let mut groups: NodePairGroups = BTreeMap::new();
    for m in pattern.internode(machine) {
        let key = (machine.gpu_node(m.src), machine.gpu_node(m.dst));
        groups.entry(key).or_default().push(*m);
    }
    groups
}

/// Unique payload bytes of a message set after removing duplicate data:
/// messages sharing `(src, dup_group)` (group != NO_DUP) carry identical
/// bytes, counted once. This is the Section 2.3 "data redundancy" that
/// node-aware strategies eliminate *per destination node*; callers group by
/// destination node before calling.
pub fn unique_bytes(msgs: &[Msg]) -> usize {
    let mut seen: std::collections::BTreeSet<(GpuId, u32)> = std::collections::BTreeSet::new();
    let mut total = 0usize;
    for m in msgs {
        if m.dup_group == Msg::NO_DUP || seen.insert((m.src, m.dup_group)) {
            total += m.bytes;
        }
    }
    total
}

/// Unique bytes per source GPU within a message set (for gather-phase
/// sizing).
pub fn unique_bytes_by_src(msgs: &[Msg]) -> BTreeMap<GpuId, usize> {
    let mut seen: std::collections::BTreeSet<(GpuId, u32)> = std::collections::BTreeSet::new();
    let mut by_src: BTreeMap<GpuId, usize> = BTreeMap::new();
    for m in msgs {
        if m.dup_group == Msg::NO_DUP || seen.insert((m.src, m.dup_group)) {
            *by_src.entry(m.src).or_default() += m.bytes;
        }
    }
    by_src
}

/// Total bytes each destination GPU must finally receive (redistribution
/// sizing — duplicates are *delivered* to every requester even when shipped
/// across the network once).
pub fn bytes_by_dst(msgs: &[Msg]) -> BTreeMap<GpuId, usize> {
    let mut by_dst: BTreeMap<GpuId, usize> = BTreeMap::new();
    for m in msgs {
        *by_dst.entry(m.dst).or_default() += m.bytes;
    }
    by_dst
}

/// Host process of a GPU when the node runs `ppn` processes and `ppg` of
/// them serve each GPU, placed on the GPU's socket. Returns the first of
/// the `ppg` block.
///
/// With `ppn = gpus_per_node * ppg` this coincides with
/// [`Machine::gpu_host_proc`]; with larger `ppn` (Split enlisting all
/// cores), GPU processes sit at the start of each socket's block.
pub fn gpu_host_proc_in(machine: &Machine, g: GpuId, ppn: usize, ppg: usize) -> ProcId {
    let node = machine.gpu_node(g).0;
    let socket_local = machine.gpu_socket(g) % machine.sockets_per_node;
    let within = machine.gpu_local(g) % machine.gpus_per_socket;
    let pps = ppn / machine.sockets_per_node;
    assert!(within * ppg < pps, "socket {socket_local} cannot host {ppg} procs/GPU with pps {pps}");
    ProcId(node * ppn + socket_local * pps + within * ppg)
}

/// The `ppg` host processes of a GPU under the [`gpu_host_proc_in`] layout.
pub fn gpu_host_procs_in(machine: &Machine, g: GpuId, ppn: usize, ppg: usize) -> Vec<ProcId> {
    let first = gpu_host_proc_in(machine, g, ppn, ppg).0;
    (first..first + ppg).map(ProcId).collect()
}

/// 3-Step pairing: on node `k`, the host process responsible for traffic
/// with node `l` (the "paired process"). Distinct remote nodes map to
/// distinct local ranks modulo `ppn`, keeping every process active
/// (Section 2.3.1).
pub fn paired_proc(_machine: &Machine, k: NodeId, l: NodeId, ppn: usize) -> ProcId {
    debug_assert!(k != l, "pairing a node with itself");
    // Skip `l == k` collisions by folding the remote node index into
    // [0, num_nodes-1) relative to k, then take it modulo ppn.
    let rel = if l.0 > k.0 { l.0 - 1 } else { l.0 };
    ProcId(k.0 * ppn + rel % ppn)
}

/// 3-Step pairing on GPUs (device-aware): the GPU on node `k` paired with
/// node `l`.
pub fn paired_gpu(machine: &Machine, k: NodeId, l: NodeId) -> GpuId {
    debug_assert!(k != l);
    let gpn = machine.gpus_per_node();
    let rel = if l.0 > k.0 { l.0 - 1 } else { l.0 };
    GpuId(k.0 * gpn + rel % gpn)
}

/// 2-Step pairing: local rank `r` on node `k` is paired with local rank `r`
/// on node `l` (P0→P4, P1→P5, … in Figure 2.4).
pub fn rank_pair(_machine: &Machine, src: ProcId, l: NodeId, ppn: usize) -> ProcId {
    let local = src.0 % ppn;
    ProcId(l.0 * ppn + local)
}

/// 2-Step pairing on GPUs (device-aware).
pub fn gpu_rank_pair(machine: &Machine, src: GpuId, l: NodeId) -> GpuId {
    let gpn = machine.gpus_per_node();
    GpuId(l.0 * gpn + machine.gpu_local(src))
}

/// A chunk of a node-pair's inter-node volume after message-cap splitting
/// (Algorithm 1 lines 12–17).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub src_node: NodeId,
    pub dst_node: NodeId,
    pub bytes: usize,
}

/// Apply the Algorithm 1 message-cap rule to one *sending* node `k`:
///
/// - `vol_per_dest[l]` = unique inter-node bytes node `k` must ship to `l`;
/// - if the max single-destination volume is below `message_cap`, each
///   destination's data is conglomerated into one message (line 13);
/// - otherwise the cap is raised to `ceil(total / ppn)` when the split
///   would exceed `ppn` messages (lines 15–16), and each destination's data
///   is split into `<= cap`-byte chunks (line 17).
pub fn split_chunks(k: NodeId, vol_per_dest: &BTreeMap<NodeId, usize>, message_cap: usize, ppn: usize) -> Vec<Chunk> {
    let total: usize = vol_per_dest.values().sum();
    let max_single = vol_per_dest.values().copied().max().unwrap_or(0);
    let mut chunks = Vec::new();
    if max_single < message_cap {
        // Conglomerate: one message per destination node.
        for (&l, &v) in vol_per_dest {
            if v > 0 {
                chunks.push(Chunk { src_node: k, dst_node: l, bytes: v });
            }
        }
        return chunks;
    }
    let mut cap = message_cap;
    if total.div_ceil(cap) > ppn {
        cap = total.div_ceil(ppn);
    }
    for (&l, &v) in vol_per_dest {
        let mut rem = v;
        while rem > 0 {
            let c = rem.min(cap);
            chunks.push(Chunk { src_node: k, dst_node: l, bytes: c });
            rem -= c;
        }
    }
    chunks
}

/// Algorithm 1 line 18: assign chunk *receives* to local ranks 0,1,2,… in
/// descending size order, and *sends* to ranks ppn-1, ppn-2, … (ascending
/// from the back), so send and receive duties overlap minimally and every
/// process stays active. Returns (chunk index → local rank).
pub fn assign_ranks(sizes: &[usize], ppn: usize, from_front: bool) -> Vec<usize> {
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    // descending by size; stable tiebreak on index for determinism
    order.sort_by(|&a, &b| sizes[b].cmp(&sizes[a]).then(a.cmp(&b)));
    let mut assignment = vec![0usize; sizes.len()];
    for (pos, &chunk_idx) in order.iter().enumerate() {
        let rank = pos % ppn;
        assignment[chunk_idx] = if from_front { rank } else { ppn - 1 - rank };
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::machines::lassen;

    #[test]
    fn group_by_pair_partitions_internode() {
        let m = lassen(3);
        let p = CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 10),
            Msg::new(GpuId(1), GpuId(5), 20),
            Msg::new(GpuId(0), GpuId(8), 30),
            Msg::new(GpuId(0), GpuId(1), 99), // intra-node, excluded
        ]);
        let g = group_by_node_pair(&m, &p);
        assert_eq!(g.len(), 2);
        assert_eq!(g[&(NodeId(0), NodeId(1))].len(), 2);
        assert_eq!(g[&(NodeId(0), NodeId(2))].len(), 1);
    }

    #[test]
    fn unique_bytes_dedups_groups() {
        let mut a = Msg::new(GpuId(0), GpuId(4), 100);
        a.dup_group = 1;
        let mut b = Msg::new(GpuId(0), GpuId(5), 100);
        b.dup_group = 1;
        let c = Msg::new(GpuId(1), GpuId(6), 50);
        assert_eq!(unique_bytes(&[a, b, c]), 150);
        assert_eq!(bytes_by_dst(&[a, b, c]).values().sum::<usize>(), 250);
    }

    #[test]
    fn host_proc_layout_split_ppn() {
        let m = lassen(2);
        // ppn=40, ppg=1: gpu0,1 socket0 -> procs 0,1; gpu2,3 socket1 -> 20,21.
        assert_eq!(gpu_host_proc_in(&m, GpuId(0), 40, 1), ProcId(0));
        assert_eq!(gpu_host_proc_in(&m, GpuId(1), 40, 1), ProcId(1));
        assert_eq!(gpu_host_proc_in(&m, GpuId(2), 40, 1), ProcId(20));
        assert_eq!(gpu_host_proc_in(&m, GpuId(3), 40, 1), ProcId(21));
        // node 1
        assert_eq!(gpu_host_proc_in(&m, GpuId(4), 40, 1), ProcId(40));
        // matches Machine::gpu_host_proc when ppn = gpn*ppg
        for g in 0..8 {
            assert_eq!(gpu_host_proc_in(&m, GpuId(g), 4, 1), m.gpu_host_proc(GpuId(g), 1));
        }
    }

    #[test]
    fn pairing_distinct_and_in_node() {
        let m = lassen(5);
        let ppn = 4;
        let k = NodeId(2);
        let mut seen = std::collections::BTreeSet::new();
        for l in [0usize, 1, 3, 4] {
            let p = paired_proc(&m, k, NodeId(l), ppn);
            assert_eq!(p.0 / ppn, 2, "paired proc must live on node k");
            seen.insert(p);
        }
        assert_eq!(seen.len(), 4, "4 remote nodes -> 4 distinct local procs at ppn=4");
    }

    #[test]
    fn rank_pairing_preserves_local_rank() {
        let m = lassen(3);
        let p = rank_pair(&m, ProcId(5), NodeId(2), 4); // local rank 1 on node 1
        assert_eq!(p, ProcId(9)); // local rank 1 on node 2
        let g = gpu_rank_pair(&m, GpuId(5), NodeId(2));
        assert_eq!(g, GpuId(9));
    }

    #[test]
    fn chunks_conglomerate_small() {
        let mut vols = BTreeMap::new();
        vols.insert(NodeId(1), 100);
        vols.insert(NodeId(2), 200);
        let ch = split_chunks(NodeId(0), &vols, 8192, 40);
        assert_eq!(ch.len(), 2);
        assert_eq!(ch.iter().map(|c| c.bytes).sum::<usize>(), 300);
    }

    #[test]
    fn chunks_split_large_at_cap() {
        let mut vols = BTreeMap::new();
        vols.insert(NodeId(1), 20_000);
        let ch = split_chunks(NodeId(0), &vols, 8192, 40);
        assert_eq!(ch.len(), 3); // 8192 + 8192 + 3616
        assert_eq!(ch.iter().map(|c| c.bytes).sum::<usize>(), 20_000);
        assert!(ch.iter().all(|c| c.bytes <= 8192));
    }

    #[test]
    fn cap_raised_when_exceeding_ppn() {
        // total = 100 * 8192, cap 8192 -> 100 chunks > ppn 40
        // raised cap = ceil(819200/40) = 20480.
        let mut vols = BTreeMap::new();
        vols.insert(NodeId(1), 819_200);
        let ch = split_chunks(NodeId(0), &vols, 8192, 40);
        assert_eq!(ch.iter().map(|c| c.bytes).sum::<usize>(), 819_200);
        assert!(ch.len() <= 40);
        assert!(ch.iter().all(|c| c.bytes <= 20_480));
    }

    #[test]
    fn zero_volume_no_chunks() {
        let mut vols = BTreeMap::new();
        vols.insert(NodeId(1), 0);
        assert!(split_chunks(NodeId(0), &vols, 8192, 40).is_empty());
    }

    #[test]
    fn assign_ranks_descending_front_and_back() {
        let sizes = vec![10, 40, 20, 30];
        // descending order: idx 1 (40), 3 (30), 2 (20), 0 (10)
        let front = assign_ranks(&sizes, 8, true);
        assert_eq!(front, vec![3, 0, 2, 1]);
        let back = assign_ranks(&sizes, 8, false);
        assert_eq!(back, vec![4, 7, 5, 6]);
    }

    #[test]
    fn assign_ranks_wraps_modulo_ppn() {
        let sizes = vec![5; 10];
        let a = assign_ranks(&sizes, 4, true);
        assert!(a.iter().all(|&r| r < 4));
        // all ranks used
        let used: std::collections::BTreeSet<_> = a.iter().collect();
        assert_eq!(used.len(), 4);
    }
}
