//! Standard communication (Section 2.3, Figure 2.2): every logical message
//! travels the network individually — both redundancies intact.
//!
//! - **Device-aware**: one GPU→GPU transfer per message, single phase.
//! - **Staged-through-host**: D2H copies, one host→host transfer per
//!   message, H2D copies.

use super::{CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, Transport, Xfer};
use crate::sim::CompiledPattern;
use crate::topology::Machine;

pub fn schedule(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    match strategy.transport {
        Transport::DeviceAware => device_aware(strategy, pattern),
        Transport::Staged => staged(strategy, machine, pattern),
    }
}

fn device_aware(strategy: Strategy, pattern: &CompiledPattern) -> Schedule {
    let mut phase = Phase::new("p2p");
    for (i, m) in pattern.pattern.msgs.iter().enumerate() {
        phase.xfers.push(Xfer { src: Loc::Gpu(m.src), dst: Loc::Gpu(m.dst), bytes: m.bytes, tag: i as u32 });
    }
    Schedule { strategy_label: strategy.label().to_string(), phases: vec![phase] }
}

fn staged(strategy: Strategy, machine: &Machine, pattern: &CompiledPattern) -> Schedule {
    let ppg = 1;

    // Phase 1: each sending GPU copies its full outgoing payload to host
    // (no duplicate elimination — standard ships everything).
    let mut d2h = Phase::new("d2h");
    for &(g, bytes) in &pattern.out_bytes_all {
        d2h.copies.push(CopyOp { gpu: g, proc: machine.gpu_host_proc(g, ppg), bytes, dir: CopyKind::D2H, nprocs: 1 });
    }

    // Phase 2: host→host transfer per logical message.
    let mut p2p = Phase::new("p2p");
    for (i, m) in pattern.pattern.msgs.iter().enumerate() {
        p2p.xfers.push(Xfer {
            src: Loc::Host(machine.gpu_host_proc(m.src, ppg)),
            dst: Loc::Host(machine.gpu_host_proc(m.dst, ppg)),
            bytes: m.bytes,
            tag: i as u32,
        });
    }

    // Phase 3: each receiving GPU copies its inbound payload from host.
    let mut h2d = Phase::new("h2d");
    for &(g, bytes) in &pattern.in_bytes_all {
        h2d.copies.push(CopyOp { gpu: g, proc: machine.gpu_host_proc(g, ppg), bytes, dir: CopyKind::H2D, nprocs: 1 });
    }

    Schedule {
        strategy_label: strategy.label().to_string(),
        phases: [d2h, p2p, h2d].into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{build_schedule as schedule_of, StrategyKind};
    use crate::pattern::{CommPattern, Msg};
    use crate::topology::{machines::lassen, GpuId};

    fn schedule(s: Strategy, m: &Machine, p: &CommPattern) -> Schedule {
        schedule_of(s, m, p)
    }

    fn pattern() -> CommPattern {
        CommPattern::new(vec![
            Msg::new(GpuId(0), GpuId(4), 100),
            Msg::new(GpuId(0), GpuId(5), 200),
            Msg::new(GpuId(1), GpuId(4), 300),
            Msg::new(GpuId(2), GpuId(3), 50), // intra-node
        ])
    }

    #[test]
    fn device_aware_one_xfer_per_msg() {
        let m = lassen(2);
        let s = Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap();
        let sched = schedule(s, &m, &pattern());
        assert_eq!(sched.phases.len(), 1);
        assert_eq!(sched.phases[0].xfers.len(), 4);
        assert_eq!(sched.total_xfer_bytes(), 650);
        assert!(sched.phases[0].copies.is_empty());
    }

    #[test]
    fn staged_copies_and_p2p() {
        let m = lassen(2);
        let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
        let sched = schedule(s, &m, &pattern());
        assert_eq!(sched.phases.len(), 3);
        // d2h: gpus 0 (300 B), 1 (300 B), 2 (50 B)
        let d2h = &sched.phases[0];
        assert_eq!(d2h.copies.len(), 3);
        assert_eq!(d2h.copies.iter().map(|c| c.bytes).sum::<usize>(), 650);
        // p2p: 4 host-level transfers
        assert_eq!(sched.phases[1].xfers.len(), 4);
        // h2d: gpus 3,4,5 receive
        assert_eq!(sched.phases[2].copies.len(), 3);
        assert_eq!(sched.phases[2].copies.iter().map(|c| c.bytes).sum::<usize>(), 650);
    }

    #[test]
    fn staged_internode_msgs_counted() {
        let m = lassen(2);
        let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
        let sched = schedule(s, &m, &pattern());
        assert_eq!(sched.internode_msgs(&m, 4), 3);
        assert_eq!(sched.internode_bytes(&m, 4), 600);
    }

    #[test]
    fn empty_pattern_empty_schedule() {
        let m = lassen(2);
        let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
        let sched = schedule(s, &m, &CommPattern::default());
        assert!(sched.phases.iter().all(|p| p.is_empty()));
    }
}
