//! Communication strategies (Section 2.3, Table 5) as message-*schedule*
//! generators.
//!
//! A strategy consumes a [`crate::pattern::CommPattern`] (who must deliver
//! what to whom, GPU-to-GPU) and produces a [`Schedule`]: an ordered list of
//! *phases*, each a set of point-to-point [`Xfer`]s (or host↔device
//! [`CopyOp`]s) that may proceed concurrently. Phases are barriers — a
//! transfer in phase `k+1` may depend on data landed in phase `k`.
//!
//! The same schedule drives both backends:
//! - the **discrete-event simulator** ([`crate::sim`]) costs it with the
//!   paper's measured Lassen parameters, and
//! - the **coordinator** ([`crate::coordinator`]) really executes it between
//!   worker threads, moving actual bytes.

pub mod dedup;
pub mod plan;
pub mod split;
pub mod standard;
pub mod three_step;
pub mod two_step;

use crate::pattern::CommPattern;
use crate::topology::{GpuId, Machine, ProcId};

/// The five strategies of Table 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StrategyKind {
    Standard,
    ThreeStep,
    TwoStep,
    SplitMd,
    SplitDd,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 5] =
        [StrategyKind::Standard, StrategyKind::ThreeStep, StrategyKind::TwoStep, StrategyKind::SplitMd, StrategyKind::SplitDd];

    /// Host processes per GPU the strategy assumes (Section 4: every
    /// strategy uses one host process per GPU except Split+DD's four).
    pub fn ppg(&self) -> usize {
        match self {
            StrategyKind::SplitDd => 4,
            _ => 1,
        }
    }

    /// Whether a device-aware variant exists (Table 5: Split strategies are
    /// staged-through-host only).
    pub fn supports_device_aware(&self) -> bool {
        !matches!(self, StrategyKind::SplitMd | StrategyKind::SplitDd)
    }

    /// Parse a user-facing kind name (CLI filters, config files).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.trim().to_ascii_lowercase().replace('_', "-").as_str() {
            "standard" | "std" => Some(StrategyKind::Standard),
            "3-step" | "three-step" | "3step" => Some(StrategyKind::ThreeStep),
            "2-step" | "two-step" | "2step" => Some(StrategyKind::TwoStep),
            "split-md" | "split+md" | "splitmd" => Some(StrategyKind::SplitMd),
            "split-dd" | "split+dd" | "splitdd" => Some(StrategyKind::SplitDd),
            _ => None,
        }
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StrategyKind::Standard => write!(f, "Standard"),
            StrategyKind::ThreeStep => write!(f, "3-Step"),
            StrategyKind::TwoStep => write!(f, "2-Step"),
            StrategyKind::SplitMd => write!(f, "Split+MD"),
            StrategyKind::SplitDd => write!(f, "Split+DD"),
        }
    }
}

/// How inter-node data leaves the GPU (Section 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Transport {
    /// Copy to host, send CPU↔CPU, copy to device.
    Staged,
    /// CUDA-aware / GPUDirect: GPU buffers handed straight to MPI.
    DeviceAware,
}

impl Transport {
    /// Parse a user-facing transport name.
    pub fn parse(s: &str) -> Option<Transport> {
        match s.trim().to_ascii_lowercase().replace('_', "-").as_str() {
            "staged" => Some(Transport::Staged),
            "device-aware" | "deviceaware" | "da" => Some(Transport::DeviceAware),
            _ => None,
        }
    }
}

impl std::fmt::Display for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Transport::Staged => write!(f, "staged"),
            Transport::DeviceAware => write!(f, "device-aware"),
        }
    }
}

/// A strategy configuration: kind × transport (validated combination).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Strategy {
    pub kind: StrategyKind,
    pub transport: Transport,
    /// Split message cap in bytes (Algorithm 1); ignored by non-Split kinds.
    pub message_cap: usize,
}

impl Strategy {
    /// Construct, validating the Table 5 matrix. Default message cap is the
    /// Lassen rendezvous switch point (8 KiB), as in [16].
    pub fn new(kind: StrategyKind, transport: Transport) -> anyhow::Result<Strategy> {
        if transport == Transport::DeviceAware && !kind.supports_device_aware() {
            anyhow::bail!("{kind} has no device-aware variant (Table 5)");
        }
        Ok(Strategy { kind, transport, message_cap: 8192 })
    }

    pub fn with_cap(mut self, cap: usize) -> Strategy {
        assert!(cap > 0, "message cap must be positive");
        self.message_cap = cap;
        self
    }

    /// All valid (kind, transport) combinations of Table 5, in paper order.
    pub fn all() -> Vec<Strategy> {
        let mut out = Vec::new();
        for kind in StrategyKind::ALL {
            out.push(Strategy::new(kind, Transport::Staged).unwrap());
            if kind.supports_device_aware() {
                out.push(Strategy::new(kind, Transport::DeviceAware).unwrap());
            }
        }
        out
    }

    /// The user-facing strategy label. `&'static str`: the Table 5 matrix
    /// is closed (8 valid combinations), so hot structs and emitters can
    /// carry labels without per-row allocation.
    pub fn label(&self) -> &'static str {
        match (self.kind, self.transport) {
            (StrategyKind::Standard, Transport::Staged) => "Standard (staged)",
            (StrategyKind::Standard, Transport::DeviceAware) => "Standard (device-aware)",
            (StrategyKind::ThreeStep, Transport::Staged) => "3-Step (staged)",
            (StrategyKind::ThreeStep, Transport::DeviceAware) => "3-Step (device-aware)",
            (StrategyKind::TwoStep, Transport::Staged) => "2-Step (staged)",
            (StrategyKind::TwoStep, Transport::DeviceAware) => "2-Step (device-aware)",
            (StrategyKind::SplitMd, Transport::Staged) => "Split+MD (staged)",
            (StrategyKind::SplitMd, Transport::DeviceAware) => "Split+MD (device-aware)",
            (StrategyKind::SplitDd, Transport::Staged) => "Split+DD (staged)",
            (StrategyKind::SplitDd, Transport::DeviceAware) => "Split+DD (device-aware)",
        }
    }

    /// Parse a [`Strategy::label`] back into a strategy (the inverse used by
    /// the advisor's surface artifacts): `"Split+MD (staged)"`,
    /// `"3-Step (device-aware)"`, …
    pub fn parse_label(s: &str) -> Option<Strategy> {
        let (kind_s, rest) = s.trim().split_once('(')?;
        let transport_s = rest.trim().strip_suffix(')')?;
        let kind = StrategyKind::parse(kind_s)?;
        let transport = Transport::parse(transport_s)?;
        Strategy::new(kind, transport).ok()
    }

    /// Host processes per node a simulated run of this strategy uses: Split
    /// enlists every CPU core on the node (Section 2.3.3); everything else
    /// runs `ppg` processes per GPU. This fixes the process→node/socket
    /// mapping the simulator needs for locality decisions.
    pub fn sim_ppn(&self, machine: &Machine) -> usize {
        match self.kind {
            StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
            _ => machine.gpus_per_node() * self.kind.ppg(),
        }
    }
}

/// Endpoint of a transfer: either a GPU buffer or a host process buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Loc {
    Gpu(GpuId),
    Host(ProcId),
}

/// One point-to-point transfer within a phase.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Xfer {
    pub src: Loc,
    pub dst: Loc,
    pub bytes: usize,
    /// Stable tag identifying the payload for the data-plane executor
    /// (indexes into the pattern's message list; u32::MAX for synthetic
    /// aggregation buffers).
    pub tag: u32,
}

/// A host↔device copy within a phase (staging legs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CopyOp {
    pub gpu: GpuId,
    pub proc: ProcId,
    pub bytes: usize,
    pub dir: CopyKind,
    /// Number of processes concurrently copying from this GPU (1 or 4);
    /// selects the Table 3 parameter class.
    pub nprocs: usize,
}

/// Copy direction (device→host when staging sends, host→device on receipt).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CopyKind {
    D2H,
    H2D,
}

/// One phase: operations that may run concurrently; the phase completes when
/// all of them do (matching the paper's step-wise strategy descriptions).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Phase {
    pub label: &'static str,
    pub xfers: Vec<Xfer>,
    pub copies: Vec<CopyOp>,
}

impl Phase {
    pub fn new(label: &'static str) -> Phase {
        Phase { label, xfers: Vec::new(), copies: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.xfers.is_empty() && self.copies.is_empty()
    }
}

/// A complete communication schedule: ordered phases.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Schedule {
    pub strategy_label: String,
    pub phases: Vec<Phase>,
}

impl Schedule {
    /// Total bytes moved across all point-to-point transfers (staging copies
    /// excluded).
    pub fn total_xfer_bytes(&self) -> usize {
        self.phases.iter().flat_map(|p| &p.xfers).map(|x| x.bytes).sum()
    }

    /// Total inter-node bytes (requires the machine for locality).
    pub fn internode_bytes(&self, machine: &Machine, ppn: usize) -> usize {
        self.phases
            .iter()
            .flat_map(|p| &p.xfers)
            .filter(|x| is_internode(machine, x, ppn))
            .map(|x| x.bytes)
            .sum()
    }

    /// Number of inter-node messages.
    pub fn internode_msgs(&self, machine: &Machine, ppn: usize) -> usize {
        self.phases.iter().flat_map(|p| &p.xfers).filter(|x| is_internode(machine, x, ppn)).count()
    }
}

fn loc_node(machine: &Machine, loc: Loc, ppn: usize) -> crate::topology::NodeId {
    match loc {
        Loc::Gpu(g) => machine.gpu_node(g),
        Loc::Host(p) => machine.proc_node(p, ppn),
    }
}

/// True when a transfer crosses nodes.
pub fn is_internode(machine: &Machine, x: &Xfer, ppn: usize) -> bool {
    loc_node(machine, x.src, ppn) != loc_node(machine, x.dst, ppn)
}

/// Strategy = schedule generator. `ppn` is the number of host processes per
/// node the run uses (fixed by `kind.ppg() * machine.gpus_per_node()` for
/// GPU-attached processes, but Split may enlist up to all cores).
pub trait ScheduleGen {
    fn schedule(&self, machine: &Machine, pattern: &CommPattern) -> Schedule;
}

/// Build the schedule for any strategy configuration.
///
/// Convenience wrapper: lowers the pattern
/// ([`crate::sim::CompiledPattern`]) and builds from the lowered form.
/// Sweep-scale callers evaluating several strategies on one pattern should
/// lower once and call [`build_schedule_from`] per strategy instead — the
/// grouping, duplicate-elimination and locality work is shared.
pub fn build_schedule(strategy: Strategy, machine: &Machine, pattern: &CommPattern) -> Schedule {
    let compiled = crate::sim::CompiledPattern::lower(machine, pattern);
    build_schedule_from(strategy, machine, &compiled)
}

/// Build the schedule for any strategy configuration from a pattern lowered
/// once per cell ([`crate::sim::CompiledPattern::lower`]).
pub fn build_schedule_from(strategy: Strategy, machine: &Machine, pattern: &crate::sim::CompiledPattern) -> Schedule {
    match strategy.kind {
        StrategyKind::Standard => standard::schedule(strategy, machine, pattern),
        StrategyKind::ThreeStep => three_step::schedule(strategy, machine, pattern),
        StrategyKind::TwoStep => two_step::schedule(strategy, machine, pattern),
        StrategyKind::SplitMd | StrategyKind::SplitDd => split::schedule(strategy, machine, pattern),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_matrix() {
        assert!(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).is_ok());
        assert!(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).is_ok());
        assert!(Strategy::new(StrategyKind::TwoStep, Transport::DeviceAware).is_ok());
        assert!(Strategy::new(StrategyKind::SplitMd, Transport::DeviceAware).is_err());
        assert!(Strategy::new(StrategyKind::SplitDd, Transport::DeviceAware).is_err());
        assert_eq!(Strategy::all().len(), 8); // 5 staged + 3 device-aware
    }

    #[test]
    fn ppg_values() {
        assert_eq!(StrategyKind::SplitDd.ppg(), 4);
        assert_eq!(StrategyKind::SplitMd.ppg(), 1);
        assert_eq!(StrategyKind::Standard.ppg(), 1);
    }

    #[test]
    fn default_cap_is_rendezvous_switch() {
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        assert_eq!(s.message_cap, 8192);
        assert_eq!(s.with_cap(4096).message_cap, 4096);
    }

    #[test]
    fn labels_readable() {
        let s = Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap();
        assert_eq!(s.label(), "3-Step (device-aware)");
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(&kind.to_string()), Some(kind));
        }
        assert_eq!(StrategyKind::parse("three-step"), Some(StrategyKind::ThreeStep));
        assert_eq!(StrategyKind::parse("SPLIT_MD"), Some(StrategyKind::SplitMd));
        assert_eq!(StrategyKind::parse("bogus"), None);
    }

    #[test]
    fn label_roundtrips_through_parse_label() {
        for s in Strategy::all() {
            assert_eq!(Strategy::parse_label(&s.label()), Some(s), "{}", s.label());
        }
        let split = Strategy::new(StrategyKind::SplitMd, Transport::Staged).ok();
        assert_eq!(Strategy::parse_label("split_md (STAGED)"), split);
        assert!(Strategy::parse_label("Split+MD (device-aware)").is_none(), "Table 5 rejects Split DA");
        assert!(Strategy::parse_label("Split+MD").is_none());
        assert!(Strategy::parse_label("bogus (staged)").is_none());
    }

    #[test]
    fn transport_parse() {
        assert_eq!(Transport::parse("staged"), Some(Transport::Staged));
        assert_eq!(Transport::parse("Device-Aware"), Some(Transport::DeviceAware));
        assert_eq!(Transport::parse("device_aware"), Some(Transport::DeviceAware));
        assert_eq!(Transport::parse("wire"), None);
    }

    #[test]
    fn sim_ppn_per_strategy() {
        let m = crate::topology::machines::lassen(2);
        let split = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        assert_eq!(split.sim_ppn(&m), 40);
        let dd = Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap();
        assert_eq!(dd.sim_ppn(&m), 40);
        let std = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
        assert_eq!(std.sim_ppn(&m), 4);
    }
}
