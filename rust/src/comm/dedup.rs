//! Duplicate-data analysis (Section 2.3's data redundancy, Section 4.6's
//! 25%-duplicate scenarios).
//!
//! The heavy lifting lives in [`super::plan::unique_bytes`]; this module
//! provides pattern-level transforms used by benchmarks and tests.

use crate::pattern::{CommPattern, Msg};
use crate::topology::Machine;

/// Rewrite a pattern so that a target `frac` of each GPU's inter-node bytes
/// is duplicated: messages are grouped per (src, destination node) and
/// assigned shared dup groups until the requested fraction of bytes is
/// marked. Used by the Figure 4.3 bottom-row scenarios.
pub fn with_duplicate_fraction(machine: &Machine, pattern: &CommPattern, frac: f64) -> CommPattern {
    assert!((0.0..1.0).contains(&frac), "frac must be in [0,1)");
    if frac == 0.0 {
        return pattern.clone();
    }
    let mut msgs = pattern.msgs.clone();
    let total: usize = pattern.internode(machine).map(|m| m.bytes).sum();
    let want = (total as f64 * frac) as usize;
    let mut marked = 0usize;
    let mut group: u32 = 0;
    // Group inter-node messages by (src GPU, destination node, size); pair
    // messages within each family — the second of each pair becomes the
    // redundant copy — until the requested byte fraction is marked.
    let mut families: std::collections::BTreeMap<(usize, usize, usize), Vec<usize>> = std::collections::BTreeMap::new();
    for (i, m) in msgs.iter().enumerate() {
        if machine.gpu_node(m.src) != machine.gpu_node(m.dst) {
            families.entry((m.src.0, machine.gpu_node(m.dst).0, m.bytes)).or_default().push(i);
        }
    }
    'outer: for members in families.values() {
        for pair in members.chunks(2) {
            if marked >= want {
                break 'outer;
            }
            if let [a, b] = *pair {
                msgs[a].dup_group = group;
                msgs[b].dup_group = group;
                group += 1;
                marked += msgs[b].bytes;
            }
        }
    }
    CommPattern::new(msgs)
}

/// The pattern with duplicate messages dropped entirely (keeps the first of
/// each (src, group, dst-node) family) — the "ideal" post-dedup traffic used
/// to sanity-check strategy schedules.
pub fn stripped(machine: &Machine, pattern: &CommPattern) -> CommPattern {
    let mut seen = std::collections::BTreeSet::new();
    let msgs = pattern
        .msgs
        .iter()
        .filter(|m| {
            m.dup_group == Msg::NO_DUP || seen.insert((m.src, m.dup_group, machine.gpu_node(m.dst)))
        })
        .copied()
        .collect();
    CommPattern::new(msgs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::generators::Scenario;
    use crate::topology::machines::lassen;
    use crate::topology::GpuId;

    #[test]
    fn marks_roughly_requested_fraction() {
        let m = lassen(17);
        let sc = Scenario { n_msgs: 256, msg_size: 2048, n_dest: 4, dup_frac: 0.0 };
        let p = sc.materialize(&m);
        let p25 = with_duplicate_fraction(&m, &p, 0.25);
        let f = p25.duplicate_fraction(&m);
        // Each dup pair marks one redundant copy = half the pair's bytes;
        // achievable granularity is one message.
        assert!(f > 0.10 && f <= 0.26, "got {f}");
    }

    #[test]
    fn zero_frac_is_identity() {
        let m = lassen(5);
        let sc = Scenario { n_msgs: 32, msg_size: 512, n_dest: 4, dup_frac: 0.0 };
        let p = sc.materialize(&m);
        assert_eq!(with_duplicate_fraction(&m, &p, 0.0), p);
    }

    #[test]
    fn stripped_removes_redundant_copies() {
        let m = lassen(2);
        let mut a = crate::pattern::Msg::new(GpuId(0), GpuId(4), 100);
        a.dup_group = 0;
        let mut b = crate::pattern::Msg::new(GpuId(0), GpuId(5), 100);
        b.dup_group = 0;
        let c = crate::pattern::Msg::new(GpuId(1), GpuId(4), 70);
        let p = CommPattern::new(vec![a, b, c]);
        let s = stripped(&m, &p);
        assert_eq!(s.msgs.len(), 2);
        assert_eq!(s.total_bytes(), 170);
    }

    #[test]
    fn stripped_keeps_cross_node_copies() {
        // Same dup group to *different* destination nodes must survive —
        // dedup happens per node, not globally.
        let m = lassen(3);
        let mut a = crate::pattern::Msg::new(GpuId(0), GpuId(4), 100);
        a.dup_group = 0;
        let mut b = crate::pattern::Msg::new(GpuId(0), GpuId(8), 100);
        b.dup_group = 0;
        let p = CommPattern::new(vec![a, b]);
        assert_eq!(stripped(&m, &p).msgs.len(), 2);
    }
}
