//! Trace recording from live runs.
//!
//! A [`TraceRecorder`] observes one [`crate::pattern::CommPattern`] per
//! iteration and coalesces consecutive identical patterns into a single
//! [`Epoch`] with a bumped repeat count — a stationary workload records as
//! one plateau however long it runs. The coordinator's persistent engine
//! carries an optional recorder
//! ([`crate::coordinator::Engine::attach_recorder`]) and feeds it from
//! every `iterate` call; [`record_spmv`] packages the whole loop for the
//! SuiteSparse-proxy suite ([`crate::sparse::suite`]), which is how
//! `hetcomm replay --record` produces `hetcomm.trace.v1` artifacts from
//! real halo exchanges.

use super::{Epoch, Trace};
use crate::comm::{Strategy, StrategyKind, Transport};
use crate::coordinator::{Engine, EngineConfig};
use crate::pattern::CommPattern;
use crate::sparse::suite;
use crate::topology::Machine;

/// Accumulates per-iteration pattern snapshots into trace epochs.
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    scenario: String,
    seed: u64,
    machine: Machine,
    epochs: Vec<Epoch>,
}

impl TraceRecorder {
    /// Start a recorder for a run on `machine`. `scenario` is the
    /// provenance label stored in the trace; `seed` records the run's seed.
    pub fn new(scenario: &str, machine: &Machine, seed: u64) -> TraceRecorder {
        TraceRecorder { scenario: scenario.to_string(), seed, machine: machine.clone(), epochs: Vec::new() }
    }

    /// Observe one iteration's pattern: extends the current epoch when the
    /// pattern is unchanged, otherwise opens a new one.
    pub fn observe(&mut self, pattern: &CommPattern) {
        self.observe_tagged(pattern, "iter");
    }

    /// [`TraceRecorder::observe`] with an explicit tag for the epoch a new
    /// pattern would open (coalescing ignores the tag: a repeat of the
    /// current pattern never splits an epoch).
    pub fn observe_tagged(&mut self, pattern: &CommPattern, tag: &str) {
        if let Some(last) = self.epochs.last_mut() {
            if last.pattern == *pattern {
                last.repeat += 1;
                return;
            }
        }
        let index = self.epochs.len();
        self.epochs.push(Epoch { index, tag: tag.to_string(), repeat: 1, pattern: pattern.clone(), faults: vec![] });
    }

    /// Epochs recorded so far.
    pub fn len(&self) -> usize {
        self.epochs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.epochs.is_empty()
    }

    /// Iterations observed so far.
    pub fn iterations(&self) -> usize {
        self.epochs.iter().map(|e| e.repeat).sum()
    }

    /// Finish recording; fails on an empty recorder (a valid trace holds at
    /// least one epoch).
    pub fn finish(self) -> Result<Trace, String> {
        let trace = Trace { scenario: self.scenario, seed: self.seed, machine: self.machine, epochs: self.epochs };
        trace.validate()?;
        Ok(trace)
    }
}

/// Record a distributed-SpMV run: build the SuiteSparse structural proxy,
/// drive `iters` iterations through the persistent engine (real data plane)
/// with a recorder attached, and return the captured trace. The partition
/// is fixed for the run, so the trace coalesces to a single stationary
/// epoch — the control case for adaptive replay.
pub fn record_spmv(
    matrix: &str,
    scale: usize,
    gpus: usize,
    machine: &Machine,
    iters: usize,
    seed: u64,
) -> Result<Trace, String> {
    let info = suite::info(matrix)
        .ok_or_else(|| format!("unknown matrix {matrix:?}; known: {:?}", suite::MATRICES.map(|m| m.name)))?;
    if iters == 0 {
        return Err("need at least one iteration to record".into());
    }
    let mat = suite::proxy(info, scale);
    let v0: Vec<f32> = (0..mat.nrows).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let strategy = Strategy::new(StrategyKind::SplitMd, Transport::Staged).expect("staged always valid");
    let mut engine = Engine::new(&mat, gpus, machine, strategy, &v0, EngineConfig::default())
        .map_err(|e| format!("engine setup: {e:#}"))?;
    engine.attach_recorder(TraceRecorder::new(&format!("spmv:{}", info.name), machine, seed));
    for _ in 0..iters {
        engine.iterate(None).map_err(|e| format!("iteration failed: {e:#}"))?;
    }
    let recorder = engine.take_recorder().expect("recorder attached above");
    recorder.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::generators::Scenario;
    use crate::topology::machines::lassen;

    #[test]
    fn recorder_coalesces_identical_patterns() {
        let machine = lassen(5);
        let a = Scenario { n_msgs: 16, msg_size: 512, n_dest: 2, dup_frac: 0.0 }.materialize(&machine);
        let b = Scenario { n_msgs: 32, msg_size: 256, n_dest: 4, dup_frac: 0.0 }.materialize(&machine);
        let mut rec = TraceRecorder::new("test", &machine, 1);
        assert!(rec.is_empty());
        rec.observe(&a);
        rec.observe(&a);
        rec.observe_tagged(&b, "grew");
        rec.observe(&b);
        rec.observe(&a);
        assert_eq!(rec.len(), 3);
        assert_eq!(rec.iterations(), 5);
        let t = rec.finish().unwrap();
        assert_eq!(t.epochs[0].repeat, 2);
        assert_eq!(t.epochs[1].tag, "grew");
        assert_eq!(t.epochs[2].repeat, 1);
        assert_eq!(t.epochs[2].pattern, a);
    }

    #[test]
    fn empty_recorder_fails_to_finish() {
        let machine = lassen(2);
        assert!(TraceRecorder::new("empty", &machine, 0).finish().is_err());
    }

    #[test]
    fn spmv_recording_is_one_stationary_epoch() {
        let machine = lassen(2);
        let t = record_spmv("thermal2", 2048, 8, &machine, 3, 9).unwrap();
        assert_eq!(t.scenario, "spmv:thermal2");
        assert_eq!(t.epochs.len(), 1, "fixed partition must coalesce");
        assert_eq!(t.epochs[0].repeat, 3);
        assert!(!t.epochs[0].pattern.is_empty(), "8 parts on 2 nodes must exchange a halo");
        assert!(t.drifts().iter().all(|&d| d == 0.0));
    }
}
