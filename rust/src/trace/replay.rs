//! Replay a trace through the Table 6 models under a static or adaptive
//! strategy policy, quantifying the win from online re-selection.
//!
//! Every epoch is costed for *all* Table 5 strategies (the static
//! baselines come for free), then the policy picks the strategy actually
//! "run" for that epoch:
//!
//! - **static** — one fixed strategy for the whole trace;
//! - **adaptive (exact)** — at epoch 0 and whenever the drift *from the
//!   last advice point* exceeds the threshold (slow per-epoch creep
//!   accumulates against the anchor and still triggers), re-rank the
//!   Table 6 models on the epoch's measured pattern statistics and take
//!   the argmin (ties keep Table 5 order);
//! - **adaptive (surface)** — same trigger, but the advice comes from a
//!   compiled [`crate::advisor::DecisionSurface`] lookup (the serving-path
//!   advisor; interpolation can be slightly suboptimal off-lattice, which
//!   is why the report costs the pick with the exact model either way).
//!
//! Because each epoch is a plateau (the pattern inside is constant), an
//! adaptive run that re-advises at every boundary accrues the pointwise
//! minimum cost — provably ≤ every static strategy's total. The per-epoch
//! report records drift, advice points, switches and cumulative time; the
//! summary compares against the best and worst static totals. Reports are
//! deterministic: byte-identical JSON for byte-identical traces.
//!
//! [`replay_with_faults`] layers the fault subsystem ([`crate::fault`]) on
//! top: as scheduled events fire, the machine and parameters degrade, the
//! simulator observes each epoch under the degraded shape plus seeded
//! congestion, and the adaptive policy gains an *external-drift* trigger —
//! an observed-vs-predicted cost residual that fires even when the pattern
//! statistics are stationary — plus a [`Resilience`] section quantifying
//! per-strategy loss under each fault class and the policy's recovery
//! latency. With no (or an all-identity) schedule the output is
//! byte-identical to [`replay`].

use super::{drift_between, DEFAULT_DRIFT_THRESHOLD, Trace};
use crate::advisor::{DecisionSurface, Pattern};
use crate::bench::{fmt_secs, Table};
use crate::comm::{build_schedule_from, Strategy};
use crate::fault::{FaultSpec, FaultState};
use crate::model::StrategyModel;
use crate::params::{CompiledParams, MachineParams};
use crate::sim::{self, CompiledPattern};
use crate::sweep::emit::esc;
use crate::topology::Machine;
use crate::util::json::fmt_f64;
use std::fmt::Write as _;

/// Strategy policy for a replay run.
#[derive(Clone, Debug)]
pub enum ReplayMode<'a> {
    /// One fixed strategy for every epoch.
    Static(Strategy),
    /// Re-advise on drift; `surface` switches the advisor from the exact
    /// Table 6 ranking (None) to a compiled decision surface.
    Adaptive { surface: Option<&'a DecisionSurface> },
}

impl ReplayMode<'_> {
    fn label(&self) -> String {
        match self {
            ReplayMode::Static(s) => format!("static:{}", s.label()),
            ReplayMode::Adaptive { surface: None } => "adaptive:model".to_string(),
            ReplayMode::Adaptive { surface: Some(_) } => "adaptive:surface".to_string(),
        }
    }
}

/// Replay configuration beyond the policy.
#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    /// Drift (|log₂| units, [`drift_between`]) above which adaptive mode
    /// re-advises.
    pub drift_threshold: f64,
    /// Also run each epoch's chosen schedule through the discrete-event
    /// simulator (slower; fills [`EpochRow::sim_s`]).
    pub sim: bool,
}

impl Default for ReplayConfig {
    fn default() -> ReplayConfig {
        ReplayConfig { drift_threshold: DEFAULT_DRIFT_THRESHOLD, sim: false }
    }
}

/// One epoch of the replay report.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochRow {
    pub index: usize,
    pub tag: String,
    pub repeat: usize,
    /// Drift from the policy's current reference stats: the last advice
    /// point under the adaptive policy, the trace start under a static
    /// policy (0 for epoch 0). The *consecutive-epoch* drift lives in the
    /// trace artifact ([`crate::trace::Trace::drifts`]), not here.
    pub drift: f64,
    /// Whether the advisor was consulted at this epoch.
    pub advised: bool,
    /// Strategy in effect.
    pub strategy: Strategy,
    /// The exact per-epoch argmin (reference, regardless of policy).
    pub best: Strategy,
    /// Modeled seconds per iteration under the strategy in effect.
    pub per_iter_s: f64,
    /// `per_iter_s × repeat`.
    pub epoch_s: f64,
    /// Running total after this epoch.
    pub cum_s: f64,
    /// Simulated seconds per iteration (when [`ReplayConfig::sim`], or
    /// always under a fault schedule — the observation stream).
    pub sim_s: Option<f64>,
    /// Labels of the fault events firing at this epoch (fault-aware replay
    /// only; the key stays out of the JSON when `None`, keeping healthy
    /// reports byte-identical).
    pub fault: Option<String>,
    /// External-drift residual: |log₂(observed/predicted)| of the incumbent
    /// strategy's cost, relative to the same ratio at the last advice point
    /// (fault-aware replay, epochs after the first advice).
    pub residual: Option<f64>,
}

/// A strategy change at an advice point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SwitchEvent {
    pub epoch: usize,
    pub from: Strategy,
    pub to: Strategy,
}

/// Total modeled seconds of one static strategy over the whole trace.
#[derive(Clone, Debug, PartialEq)]
pub struct StaticTotal {
    pub strategy: Strategy,
    pub total_s: f64,
}

/// One strategy's whole-trace *simulated* cost, healthy versus under a
/// fault schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct StrategyLoss {
    pub strategy: Strategy,
    /// Simulated total with no faults (the counterfactual baseline).
    pub healthy_s: f64,
    /// Simulated total under the schedule.
    pub faulted_s: f64,
    /// Relative loss `(faulted − healthy) / healthy`.
    pub loss: f64,
}

/// Counterfactual losses with only one fault class of the schedule active.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassLoss {
    /// Fault class name ([`FaultKind::class`](crate::fault::FaultKind::class)).
    pub class: &'static str,
    /// Per-strategy losses, Table 5 order.
    pub losses: Vec<StrategyLoss>,
}

/// The resilience section of a fault-aware replay report: how much each
/// strategy loses to the injected degradation, which strategy is sturdiest,
/// and how fast the adaptive policy reacted.
#[derive(Clone, Debug, PartialEq)]
pub struct Resilience {
    /// Loss under the full schedule, Table 5 order.
    pub overall: Vec<StrategyLoss>,
    /// Counterfactual losses per fault class present in the schedule, in
    /// first-appearance order.
    pub classes: Vec<ClassLoss>,
    /// First-wins argmin of overall loss (ties keep Table 5 order).
    pub most_robust: Strategy,
    /// Epochs from the first fault to the policy's first switch at or after
    /// it; `None` when the policy never switched after the fault (static
    /// modes, or a degradation that leaves the incumbent optimal).
    pub recovery_epochs: Option<usize>,
}

/// The replay outcome.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub scenario: String,
    pub machine: String,
    /// Policy label (`"static:…"`, `"adaptive:model"`, `"adaptive:surface"`).
    pub mode: String,
    pub drift_threshold: f64,
    /// Total iterations replayed.
    pub iterations: usize,
    pub rows: Vec<EpochRow>,
    /// Every Table 5 strategy's static total, in Table 5 order.
    pub statics: Vec<StaticTotal>,
    /// Cumulative modeled time of the replayed policy.
    pub total_s: f64,
    pub best_static: StaticTotal,
    pub worst_static: StaticTotal,
    pub switches: Vec<SwitchEvent>,
    /// `(best_static − total) / best_static`; negative when the policy
    /// loses to the best static strategy, 0 for an empty denominator.
    pub win_vs_best_static: f64,
    pub win_vs_worst_static: f64,
    /// Robustness accounting — present only under a non-identity fault
    /// schedule, so healthy reports keep their exact historical bytes.
    pub resilience: Option<Resilience>,
}

/// Replay `trace` under `mode`. Costs are the Table 6 models evaluated on
/// each epoch's measured pattern statistics (`ppn` = all cores, matching
/// `hetcomm model` / `sweep`); the trace machine's registry parameters are
/// required ([`Trace::params`]). Equivalent to [`replay_with_faults`] with
/// no schedule; a trace that *embeds* fault events replays them either way.
pub fn replay(trace: &Trace, mode: &ReplayMode, config: &ReplayConfig) -> Result<ReplayReport, String> {
    replay_with_faults(trace, mode, config, None)
}

/// Fault-aware replay: run `trace` under `mode` while `faults` (or a
/// schedule already embedded in the trace epochs) degrades the system.
///
/// As events fire the machine shape and parameters in force degrade
/// ([`FaultState::degrade`]) and the models re-rank on the degraded system;
/// every epoch is also simulated on it (with seeded congestion pre-charge),
/// and the adaptive policy gains an external-drift trigger: the incumbent's
/// observed/predicted cost ratio, anchored at the last advice point, firing
/// the advisor when it moves more than the drift threshold even though the
/// pattern statistics are stationary. Surface-driven advice re-keys onto a
/// degraded-shape sibling surface ([`DecisionSurface::resized_nics`]).
/// `None` or an all-identity schedule reproduces [`replay`] byte for byte.
pub fn replay_with_faults(
    trace_in: &Trace,
    mode: &ReplayMode,
    config: &ReplayConfig,
    faults: Option<&FaultSpec>,
) -> Result<ReplayReport, String> {
    trace_in.validate()?;
    // merge an external schedule into the epochs (so the replayed trace is
    // self-describing), or pick up one the trace already embeds
    let attached = match faults {
        Some(spec) => {
            if trace_in.epochs.iter().any(|e| !e.faults.is_empty()) {
                return Err(
                    "trace already embeds a fault schedule; drop --faults or replay the healthy trace".into()
                );
            }
            Some(spec.attach(trace_in)?)
        }
        None => None,
    };
    let trace = attached.as_ref().unwrap_or(trace_in);
    let spec = match faults {
        Some(s) => Some(s.clone()),
        None => trace.fault_spec(),
    }
    .filter(|s| !s.is_identity());
    let params = trace
        .params()
        .ok_or_else(|| format!("trace machine {:?} resolves to no registry parameters", trace.machine.name))?;
    if let ReplayMode::Adaptive { surface: Some(surface) } = mode {
        surface.validate()?;
        if surface.machine != trace.machine.name {
            return Err(format!(
                "surface was compiled for {:?} but the trace ran on {:?}",
                surface.machine, trace.machine.name
            ));
        }
        // surfaces are shape-keyed: the rail counts must agree or every
        // re-advise would rank strategies under the wrong injection limit
        if surface.nics != trace.machine.nics_per_node() {
            return Err(format!(
                "surface was compiled for {} NICs/node but the trace machine has {}",
                surface.nics,
                trace.machine.nics_per_node()
            ));
        }
    }
    if !config.drift_threshold.is_finite() || config.drift_threshold < 0.0 {
        return Err(format!("drift threshold {} must be finite and >= 0", config.drift_threshold));
    }

    let machine = &trace.machine;
    let ppn = machine.cores_per_node();
    let all = Strategy::all();
    // simulator leg: compile the band tables once and reuse one scratch
    // across every epoch (allocation-free inner loop)
    let compiled_params = config.sim.then(|| params.compile());
    let mut scratch = sim::Scratch::new();

    // fault machinery: the system actually in force (degraded machine,
    // params and their compiled bands) plus the adaptive policy's *belief* —
    // the system it last advised under — and the observed/predicted ratio
    // anchored at that advice, against which the external-drift residual of
    // later epochs is measured
    let mut state = FaultState::default();
    let mut cur_machine = machine.clone();
    let mut cur_params = params.clone();
    let mut cur_cp: Option<CompiledParams> = spec.as_ref().map(|_| cur_params.compile());
    let mut belief: Option<(Machine, MachineParams)> = None;
    let mut anchor_ratio: Option<f64> = None;
    let mut sibling: Option<DecisionSurface> = None;

    let mut statics: Vec<StaticTotal> = all.iter().map(|&s| StaticTotal { strategy: s, total_s: 0.0 }).collect();
    let mut rows: Vec<EpochRow> = Vec::with_capacity(trace.epochs.len());
    let mut switches = Vec::new();
    let mut total_s = 0f64;
    // drift reference: the stats at the last advice point (so sub-threshold
    // creep accumulates); static mode keeps the trace-start reference
    let mut anchor_stats = None;
    let mut current: Option<Strategy> = None;

    for epoch in &trace.epochs {
        // fire this epoch's fault events: the system in force degrades for
        // the rest of the run (events persist, there is no repair)
        let mut fault = None;
        if spec.is_some() && !epoch.faults.is_empty() {
            for k in &epoch.faults {
                state.apply(k);
            }
            let (dm, dp) = state.degrade(machine, &params)?;
            cur_machine = dm;
            cur_params = dp;
            cur_cp = Some(cur_params.compile());
            fault = Some(epoch.faults.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(", "));
        }
        let pre = spec
            .as_ref()
            .and_then(|s| state.precharge(s.seed, epoch.index, cur_machine.num_nodes, cur_machine.nics_per_node()));

        // pattern statistics stay keyed to the healthy machine: rail loss
        // moves no GPUs between nodes, so the message taxonomy is invariant
        let stats = epoch.pattern.stats(machine);
        let dup = epoch.pattern.duplicate_fraction(machine);
        // assemble the inputs from the stats already in hand (the
        // `model_inputs` convenience would recompute them); the models rank
        // under the system in force, degraded rails and all
        let sm = StrategyModel::new(&cur_machine, &cur_params);
        let inputs = crate::model::ModelInputs {
            s_proc: stats.s_proc,
            s_node: stats.s_node,
            s_n2n: stats.s_n2n,
            m_p2n: stats.m_p2n,
            m_n2n: stats.m_n2n,
            m_std: stats.m_std,
            ppn,
            nics: cur_machine.nics_per_node(),
            dup_frac: dup,
        };
        let times = sm.all_times(&inputs);
        let rep = epoch.repeat as f64;
        for (k, &(_, t)) in times.iter().enumerate() {
            statics[k].total_s += t * rep;
        }
        // first-wins argmin: ties keep Table 5 order, matching the
        // surface's `best_index`
        let mut best = times[0].0;
        let mut best_t = times[0].1;
        for &(s, t) in &times[1..] {
            if t < best_t {
                best = s;
                best_t = t;
            }
        }

        let drift = anchor_stats.as_ref().map(|p| drift_between(p, &stats)).unwrap_or(0.0);

        // external drift: simulate the incumbent on the system in force and
        // compare against the belief-model prediction. Subtracting the
        // anchor ratio cancels the constant model-vs-simulator bias, so on a
        // stationary pattern the residual only moves when the *hardware*
        // does — the signal pattern drift cannot see.
        let mut residual = None;
        let mut incumbent_obs = None;
        if let (Some(cp), Some(cur_s), Some((bm, bp)), Some(anchor)) =
            (cur_cp.as_ref(), current, belief.as_ref(), anchor_ratio)
        {
            let obs = sim_epoch(&mut scratch, &cur_machine, cp, cur_s, &epoch.pattern, pre.as_deref());
            incumbent_obs = Some(obs);
            let bsm = StrategyModel::new(bm, bp);
            let binputs = crate::model::ModelInputs { nics: bm.nics_per_node(), ..inputs };
            let pred = bsm.time(cur_s, &binputs);
            if obs > 0.0 && pred > 0.0 {
                residual = Some(((obs / pred).log2() - anchor).abs());
            }
        }

        let (advised, strategy) = match mode {
            ReplayMode::Static(s) => (false, *s),
            ReplayMode::Adaptive { surface } => {
                let trigger = current.is_none()
                    || drift > config.drift_threshold
                    || residual.is_some_and(|r| r > config.drift_threshold);
                if trigger {
                    let pick = match surface {
                        None => best,
                        Some(surface) => {
                            let q = Pattern::from_stats(&stats, machine);
                            let nics_now = cur_machine.nics_per_node();
                            if nics_now == surface.nics {
                                surface.lookup(&q).best().0
                            } else {
                                // shape-keyed advice: serve the degraded
                                // shape from a sibling surface, compiled on
                                // first use and cached until the next rail
                                // failure changes the count again
                                if sibling.as_ref().map(|s| s.nics) != Some(nics_now) {
                                    sibling = surface.resized_nics(nics_now).ok();
                                }
                                match sibling.as_ref() {
                                    Some(s) => s.lookup(&q).best().0,
                                    None => best,
                                }
                            }
                        }
                    };
                    (true, pick)
                } else {
                    (false, current.expect("non-trigger implies a prior advice"))
                }
            }
        };
        if advised {
            if let Some(prev) = current {
                if prev != strategy {
                    switches.push(SwitchEvent { epoch: epoch.index, from: prev, to: strategy });
                }
            }
        }
        let per_iter_s = times
            .iter()
            .find(|(s, _)| *s == strategy)
            .map(|&(_, t)| t)
            .ok_or_else(|| format!("strategy {} is not in the Table 5 set", strategy.label()))?;
        let epoch_s = per_iter_s * rep;
        total_s += epoch_s;
        // simulator observation: under a fault schedule the simulator always
        // runs on the system in force (it is the sensor feeding the
        // residual, and the advice point refreshes the belief + anchor);
        // otherwise only on `--sim`, exactly as before
        let sim_s = if let Some(cp) = cur_cp.as_ref() {
            let obs = match incumbent_obs {
                Some(o) if current == Some(strategy) => o,
                _ => sim_epoch(&mut scratch, &cur_machine, cp, strategy, &epoch.pattern, pre.as_deref()),
            };
            if advised || anchor_ratio.is_none() {
                belief = Some((cur_machine.clone(), cur_params.clone()));
                anchor_ratio = (obs > 0.0 && per_iter_s > 0.0).then(|| (obs / per_iter_s).log2());
            }
            Some(obs)
        } else {
            compiled_params.as_ref().map(|cp| sim_epoch(&mut scratch, machine, cp, strategy, &epoch.pattern, None))
        };
        rows.push(EpochRow {
            index: epoch.index,
            tag: epoch.tag.clone(),
            repeat: epoch.repeat,
            drift,
            advised,
            strategy,
            best,
            per_iter_s,
            epoch_s,
            cum_s: total_s,
            sim_s,
            fault,
            residual,
        });
        // the reference only moves when the advisor was (re-)consulted; the
        // trace start anchors epoch 0 for every policy
        if advised || anchor_stats.is_none() {
            anchor_stats = Some(stats);
        }
        current = Some(strategy);
    }

    let resilience = match spec.as_ref() {
        Some(s) => Some(compute_resilience(trace, machine, &params, s, &all, &switches, &mut scratch)?),
        None => None,
    };

    // first-wins extrema: ties keep Table 5 order
    let mut best_static = statics[0].clone();
    let mut worst_static = statics[0].clone();
    for s in &statics[1..] {
        if s.total_s < best_static.total_s {
            best_static = s.clone();
        }
        if s.total_s > worst_static.total_s {
            worst_static = s.clone();
        }
    }
    let win = |baseline: f64| if baseline > 0.0 { (baseline - total_s) / baseline } else { 0.0 };
    Ok(ReplayReport {
        scenario: trace.scenario.clone(),
        machine: trace.machine.name.clone(),
        mode: mode.label(),
        drift_threshold: config.drift_threshold,
        iterations: trace.iterations(),
        rows,
        win_vs_best_static: win(best_static.total_s),
        win_vs_worst_static: win(worst_static.total_s),
        statics,
        total_s,
        best_static,
        worst_static,
        switches,
        resilience,
    })
}

/// Simulate one epoch's schedule on a (possibly degraded) system with an
/// optional congestion pre-charge; returns seconds per iteration.
fn sim_epoch(
    scratch: &mut sim::Scratch,
    machine: &Machine,
    cp: &CompiledParams,
    strategy: Strategy,
    pattern: &crate::pattern::CommPattern,
    pre: Option<&[f64]>,
) -> f64 {
    let lowered = CompiledPattern::lower(machine, pattern);
    let schedule = build_schedule_from(strategy, machine, &lowered);
    scratch.run_total_with(machine, cp, &schedule, strategy.sim_ppn(machine), pre)
}

/// Whole-trace simulated seconds of one static strategy under a fault
/// schedule (`None` = the healthy counterfactual). Events are taken from
/// the *spec*, not the trace epochs, so class-restricted sub-specs replay a
/// trace whose epochs embed the full schedule.
fn sim_trace_total(
    trace: &Trace,
    machine: &Machine,
    params: &MachineParams,
    spec: Option<&FaultSpec>,
    strategy: Strategy,
    scratch: &mut sim::Scratch,
) -> Result<f64, String> {
    let mut state = FaultState::default();
    let mut cur_machine = machine.clone();
    let mut cur_cp = params.compile();
    let mut total = 0f64;
    for epoch in &trace.epochs {
        if let Some(s) = spec {
            let mut changed = false;
            for e in s.events.iter().filter(|e| e.epoch == epoch.index) {
                state.apply(&e.kind);
                changed = true;
            }
            if changed {
                let (dm, dp) = state.degrade(machine, params)?;
                cur_machine = dm;
                cur_cp = dp.compile();
            }
        }
        let pre = spec.and_then(|s| {
            state.precharge(s.seed, epoch.index, cur_machine.num_nodes, cur_machine.nics_per_node())
        });
        let t = sim_epoch(scratch, &cur_machine, &cur_cp, strategy, &epoch.pattern, pre.as_deref());
        total += t * epoch.repeat as f64;
    }
    Ok(total)
}

/// Robustness accounting for a fault-aware replay: per-strategy simulated
/// loss under the full schedule and under each fault class alone, the
/// sturdiest strategy, and the adaptive policy's reaction latency.
fn compute_resilience(
    trace: &Trace,
    machine: &Machine,
    params: &MachineParams,
    spec: &FaultSpec,
    all: &[Strategy],
    switches: &[SwitchEvent],
    scratch: &mut sim::Scratch,
) -> Result<Resilience, String> {
    let healthy: Vec<f64> = all
        .iter()
        .map(|&s| sim_trace_total(trace, machine, params, None, s, scratch))
        .collect::<Result<_, _>>()?;
    let loss_vec = |sub: &FaultSpec, scratch: &mut sim::Scratch| -> Result<Vec<StrategyLoss>, String> {
        all.iter()
            .zip(&healthy)
            .map(|(&s, &h)| {
                let f = sim_trace_total(trace, machine, params, Some(sub), s, scratch)?;
                let loss = if h > 0.0 { (f - h) / h } else { 0.0 };
                Ok(StrategyLoss { strategy: s, healthy_s: h, faulted_s: f, loss })
            })
            .collect()
    };
    let overall = loss_vec(spec, scratch)?;
    let classes = spec
        .classes()
        .into_iter()
        .map(|c| Ok(ClassLoss { class: c, losses: loss_vec(&spec.restricted_to_class(c), scratch)? }))
        .collect::<Result<Vec<_>, String>>()?;
    let mut most_robust = overall[0].strategy;
    let mut best_loss = overall[0].loss;
    for l in &overall[1..] {
        if l.loss < best_loss {
            most_robust = l.strategy;
            best_loss = l.loss;
        }
    }
    let recovery_epochs =
        spec.first_epoch().and_then(|f0| switches.iter().find(|sw| sw.epoch >= f0).map(|sw| sw.epoch - f0));
    Ok(Resilience { overall, classes, most_robust, recovery_epochs })
}

/// Serialize a replay report as deterministic JSON (shortest-round-trip
/// floats; no wall-clock fields, so equal traces emit equal bytes).
pub fn report_to_json(r: &ReplayReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"hetcomm.replay.v1\",");
    let _ = writeln!(out, "  \"scenario\": \"{}\",", esc(&r.scenario));
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&r.machine));
    let _ = writeln!(out, "  \"mode\": \"{}\",", esc(&r.mode));
    let _ = writeln!(out, "  \"drift_threshold\": {},", fmt_f64(r.drift_threshold));
    let _ = writeln!(out, "  \"iterations\": {},", r.iterations);
    out.push_str("  \"epochs\": [\n");
    for (i, row) in r.rows.iter().enumerate() {
        let comma = if i + 1 < r.rows.len() { "," } else { "" };
        let sim = match row.sim_s {
            Some(t) => fmt_f64(t),
            None => "null".to_string(),
        };
        // fault-only keys: absent on healthy rows, so a no-fault report
        // keeps its exact historical bytes
        let mut extra = String::new();
        if let Some(f) = &row.fault {
            let _ = write!(extra, " \"fault\": \"{}\",", esc(f));
        }
        if let Some(res) = row.residual {
            let _ = write!(extra, " \"residual\": {},", fmt_f64(res));
        }
        let _ = writeln!(
            out,
            "    {{\"index\": {}, \"tag\": \"{}\", \"repeat\": {}, \"drift\": {}, \"advised\": {},{extra} \
             \"strategy\": \"{}\", \"best\": \"{}\", \"per_iter_s\": {}, \"epoch_s\": {}, \"cum_s\": {}, \
             \"sim_s\": {}}}{comma}",
            row.index,
            esc(&row.tag),
            row.repeat,
            fmt_f64(row.drift),
            row.advised,
            esc(&row.strategy.label()),
            esc(&row.best.label()),
            fmt_f64(row.per_iter_s),
            fmt_f64(row.epoch_s),
            fmt_f64(row.cum_s),
            sim,
        );
    }
    out.push_str("  ],\n");
    out.push_str("  \"statics\": [\n");
    for (i, s) in r.statics.iter().enumerate() {
        let comma = if i + 1 < r.statics.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"strategy\": \"{}\", \"total_s\": {}}}{comma}",
            esc(&s.strategy.label()),
            fmt_f64(s.total_s)
        );
    }
    out.push_str("  ],\n");
    out.push_str("  \"switches\": [\n");
    for (i, sw) in r.switches.iter().enumerate() {
        let comma = if i + 1 < r.switches.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"epoch\": {}, \"from\": \"{}\", \"to\": \"{}\"}}{comma}",
            sw.epoch,
            esc(&sw.from.label()),
            esc(&sw.to.label())
        );
    }
    out.push_str("  ],\n");
    if let Some(res) = &r.resilience {
        out.push_str("  \"resilience\": {\n");
        let _ = writeln!(out, "    \"most_robust\": \"{}\",", esc(&res.most_robust.label()));
        match res.recovery_epochs {
            Some(e) => {
                let _ = writeln!(out, "    \"recovery_epochs\": {e},");
            }
            None => out.push_str("    \"recovery_epochs\": null,\n"),
        }
        let loss_row = |l: &StrategyLoss| {
            format!(
                "{{\"strategy\": \"{}\", \"healthy_s\": {}, \"faulted_s\": {}, \"loss\": {}}}",
                esc(&l.strategy.label()),
                fmt_f64(l.healthy_s),
                fmt_f64(l.faulted_s),
                fmt_f64(l.loss)
            )
        };
        out.push_str("    \"overall\": [\n");
        for (i, l) in res.overall.iter().enumerate() {
            let comma = if i + 1 < res.overall.len() { "," } else { "" };
            let _ = writeln!(out, "      {}{comma}", loss_row(l));
        }
        out.push_str("    ],\n");
        out.push_str("    \"classes\": [\n");
        for (i, c) in res.classes.iter().enumerate() {
            let comma = if i + 1 < res.classes.len() { "," } else { "" };
            let losses: Vec<String> = c.losses.iter().map(|l| loss_row(l)).collect();
            let _ = writeln!(out, "      {{\"class\": \"{}\", \"losses\": [{}]}}{comma}", esc(c.class), losses.join(", "));
        }
        out.push_str("    ]\n");
        out.push_str("  },\n");
    }
    let _ = writeln!(out, "  \"total_s\": {},", fmt_f64(r.total_s));
    let _ = writeln!(
        out,
        "  \"best_static\": {{\"strategy\": \"{}\", \"total_s\": {}}},",
        esc(&r.best_static.strategy.label()),
        fmt_f64(r.best_static.total_s)
    );
    let _ = writeln!(
        out,
        "  \"worst_static\": {{\"strategy\": \"{}\", \"total_s\": {}}},",
        esc(&r.worst_static.strategy.label()),
        fmt_f64(r.worst_static.total_s)
    );
    let _ = writeln!(out, "  \"win_vs_best_static\": {},", fmt_f64(r.win_vs_best_static));
    let _ = writeln!(out, "  \"win_vs_worst_static\": {}", fmt_f64(r.win_vs_worst_static));
    out.push_str("}\n");
    out
}

/// Render a replay report as aligned text tables.
pub fn render_report(r: &ReplayReport) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        format!("Replay: {} on {} ({}, threshold {})", r.scenario, r.machine, r.mode, r.drift_threshold),
        &["epoch", "tag", "iters", "drift", "advised", "strategy", "per-iter", "cum", "sim/iter"],
    );
    for row in &r.rows {
        t.row(vec![
            row.index.to_string(),
            row.tag.clone(),
            row.repeat.to_string(),
            format!("{:.2}", row.drift),
            if row.advised { "yes".into() } else { String::new() },
            row.strategy.label().to_string(),
            fmt_secs(row.per_iter_s),
            fmt_secs(row.cum_s),
            row.sim_s.map(fmt_secs).unwrap_or_default(),
        ]);
    }
    out.push_str(&t.render());
    let mut b = Table::new("Static baselines (whole trace)", &["strategy", "total"]);
    for s in &r.statics {
        b.row(vec![s.strategy.label().to_string(), fmt_secs(s.total_s)]);
    }
    out.push('\n');
    out.push_str(&b.render());
    let _ = writeln!(
        out,
        "\nreplayed {} iterations over {} epochs: total {}",
        r.iterations,
        r.rows.len(),
        fmt_secs(r.total_s).trim()
    );
    let _ = writeln!(
        out,
        "best static  {} ({}) -> win {:+.2}%",
        r.best_static.strategy.label(),
        fmt_secs(r.best_static.total_s).trim(),
        r.win_vs_best_static * 100.0
    );
    let _ = writeln!(
        out,
        "worst static {} ({}) -> win {:+.2}%",
        r.worst_static.strategy.label(),
        fmt_secs(r.worst_static.total_s).trim(),
        r.win_vs_worst_static * 100.0
    );
    for sw in &r.switches {
        let _ = writeln!(out, "switch at epoch {}: {} -> {}", sw.epoch, sw.from.label(), sw.to.label());
    }
    if r.switches.is_empty() {
        let _ = writeln!(out, "no strategy switches");
    }
    if let Some(res) = &r.resilience {
        for row in r.rows.iter().filter(|row| row.fault.is_some()) {
            let _ = writeln!(out, "fault at epoch {}: {}", row.index, row.fault.as_deref().unwrap_or(""));
        }
        let mut rt = Table::new(
            "Resilience (simulated whole-trace cost)".to_string(),
            &["strategy", "healthy", "faulted", "loss"],
        );
        for l in &res.overall {
            rt.row(vec![
                l.strategy.label().to_string(),
                fmt_secs(l.healthy_s),
                fmt_secs(l.faulted_s),
                format!("{:+.2}%", l.loss * 100.0),
            ]);
        }
        out.push('\n');
        out.push_str(&rt.render());
        for c in &res.classes {
            let mut worst = &c.losses[0];
            for l in &c.losses[1..] {
                if l.loss > worst.loss {
                    worst = l;
                }
            }
            let _ = writeln!(
                out,
                "class {}: worst hit {} ({:+.2}%)",
                c.class,
                worst.strategy.label(),
                worst.loss * 100.0
            );
        }
        let _ = writeln!(out, "most robust strategy: {}", res.most_robust.label());
        match res.recovery_epochs {
            Some(e) => {
                let _ = writeln!(out, "adaptive recovery: first post-fault switch after {e} epoch(s)");
            }
            None => {
                let _ = writeln!(out, "adaptive recovery: no post-fault switch");
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};
    use crate::trace::scenarios::{synthesize, TraceScenario};

    fn adaptive() -> ReplayMode<'static> {
        ReplayMode::Adaptive { surface: None }
    }

    #[test]
    fn adaptive_never_loses_to_any_static() {
        for sc in TraceScenario::ALL {
            let trace = synthesize(sc, "lassen", 5, 0, 42).unwrap();
            let r = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
            for s in &r.statics {
                assert!(
                    r.total_s <= s.total_s * (1.0 + 1e-12),
                    "{sc}: adaptive {} loses to static {} {}",
                    r.total_s,
                    s.strategy.label(),
                    s.total_s
                );
            }
            assert!(r.win_vs_best_static >= -1e-12, "{sc}: win {}", r.win_vs_best_static);
            // rows carry a consistent running total
            let mut cum = 0.0;
            for row in &r.rows {
                cum += row.epoch_s;
                assert_eq!(row.cum_s.to_bits(), cum.to_bits());
            }
        }
    }

    #[test]
    fn static_mode_reproduces_its_baseline_total() {
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        for strategy in Strategy::all() {
            let r = replay(&trace, &ReplayMode::Static(strategy), &ReplayConfig::default()).unwrap();
            let baseline = r.statics.iter().find(|s| s.strategy == strategy).unwrap();
            assert_eq!(r.total_s.to_bits(), baseline.total_s.to_bits(), "{}", strategy.label());
            assert!(r.switches.is_empty());
            assert!(r.rows.iter().all(|row| !row.advised));
        }
    }

    #[test]
    fn huge_threshold_freezes_the_first_choice() {
        let trace = synthesize(TraceScenario::Rebalance, "lassen", 3, 0, 42).unwrap();
        let frozen = replay(&trace, &adaptive(), &ReplayConfig { drift_threshold: 1e9, ..Default::default() }).unwrap();
        assert!(frozen.switches.is_empty());
        assert_eq!(frozen.rows.iter().filter(|r| r.advised).count(), 1, "only epoch 0 advises");
        let first = frozen.rows[0].strategy;
        let static_run = replay(&trace, &ReplayMode::Static(first), &ReplayConfig::default()).unwrap();
        assert_eq!(frozen.total_s.to_bits(), static_run.total_s.to_bits());
        // the default threshold re-advises at both rebalance boundaries
        let live = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        assert_eq!(live.rows.iter().filter(|r| r.advised).count(), 3);
    }

    #[test]
    fn sim_mode_fills_per_epoch_sim_times() {
        let trace = synthesize(TraceScenario::HaloBurst, "lassen", 3, 1, 42).unwrap();
        let r = replay(&trace, &adaptive(), &ReplayConfig { sim: true, ..Default::default() }).unwrap();
        assert!(r.rows.iter().all(|row| row.sim_s.is_some_and(|t| t.is_finite() && t > 0.0)));
        let dry = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        assert!(dry.rows.iter().all(|row| row.sim_s.is_none()));
        // the sim leg never changes the modeled accounting
        assert_eq!(r.total_s.to_bits(), dry.total_s.to_bits());
    }

    #[test]
    fn report_emitters_are_deterministic_and_complete() {
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        let r1 = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        let r2 = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        let (j1, j2) = (report_to_json(&r1), report_to_json(&r2));
        assert_eq!(j1, j2);
        assert!(j1.contains("hetcomm.replay.v1"));
        assert!(j1.contains("\"switches\""));
        let txt = render_report(&r1);
        assert!(txt.contains("best static"));
        assert!(txt.contains("switch at epoch"));
    }

    #[test]
    fn zero_fault_replay_is_byte_identical() {
        use crate::fault::FaultEvent;
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        let base = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        let with_none = replay_with_faults(&trace, &adaptive(), &ReplayConfig::default(), None).unwrap();
        // an all-identity schedule must change nothing either
        let identity = FaultSpec {
            seed: 7,
            events: vec![
                FaultEvent { epoch: 0, kind: crate::fault::FaultKind::Slowdown { rail: 0, factor: 1.0 } },
                FaultEvent { epoch: 1, kind: crate::fault::FaultKind::Congestion { level: 0.0 } },
            ],
        };
        let with_id = replay_with_faults(&trace, &adaptive(), &ReplayConfig::default(), Some(&identity)).unwrap();
        let j = report_to_json(&base);
        assert_eq!(j, report_to_json(&with_none));
        assert_eq!(j, report_to_json(&with_id));
        assert!(!j.contains("resilience") && !j.contains("fault"), "healthy report must not mention faults");
        assert_eq!(render_report(&base), render_report(&with_id));
    }

    #[test]
    fn rail_failure_triggers_external_drift_recovery() {
        use crate::fault::{FaultEvent, FaultKind};
        let trace = synthesize(TraceScenario::Stationary, "frontier-4nic", 8, 1, 11).unwrap();
        let spec = FaultSpec {
            seed: 3,
            events: vec![
                FaultEvent { epoch: 3, kind: FaultKind::RailDown { rail: 1 } },
                FaultEvent { epoch: 3, kind: FaultKind::Congestion { level: 2e-3 } },
            ],
        };
        let r = replay_with_faults(&trace, &adaptive(), &ReplayConfig::default(), Some(&spec)).unwrap();
        // the fault annotates its epoch and the sim sensor runs everywhere
        assert!(r.rows[3].fault.as_deref().unwrap().contains("rail-down(1)"));
        assert!(r.rows.iter().all(|row| row.sim_s.is_some_and(|t| t.is_finite() && t > 0.0)));
        // the stationary pattern never drifts...
        assert!(r.rows.iter().all(|row| row.drift == 0.0));
        // ...but the hardware does: the residual jumps past the threshold
        // at the fault epoch and the advisor is re-consulted
        let res = r.rows[3].residual.expect("incumbent residual at the fault epoch");
        assert!(res > DEFAULT_DRIFT_THRESHOLD, "external drift must fire: residual {res}");
        assert!(r.rows[3].advised, "residual past the threshold must re-advise");
        let resil = r.resilience.as_ref().expect("fault replay reports resilience");
        assert_eq!(resil.overall.len(), Strategy::all().len());
        assert!(
            resil.overall.iter().all(|l| l.faulted_s + 1e-12 >= l.healthy_s),
            "degradation never speeds a strategy up: {:?}",
            resil.overall
        );
        assert!(resil.overall.iter().any(|l| l.loss > 0.0), "the schedule must cost something");
        let classes: Vec<&str> = resil.classes.iter().map(|c| c.class).collect();
        assert_eq!(classes, ["rail-down", "congestion"]);
        // recovery bookkeeping agrees with the switch log
        let expected = r.switches.iter().find(|sw| sw.epoch >= 3).map(|sw| sw.epoch - 3);
        assert_eq!(resil.recovery_epochs, expected);
        // deterministic end to end
        let again = replay_with_faults(&trace, &adaptive(), &ReplayConfig::default(), Some(&spec)).unwrap();
        assert_eq!(report_to_json(&r), report_to_json(&again));
        let txt = render_report(&r);
        assert!(txt.contains("most robust strategy") && txt.contains("fault at epoch 3"));
    }

    #[test]
    fn embedded_and_external_schedules_agree_and_never_stack() {
        use crate::fault::{FaultEvent, FaultKind};
        let trace = synthesize(TraceScenario::Stationary, "frontier-4nic", 5, 1, 11).unwrap();
        // slowdown-only: congestion draws would differ (external specs seed
        // the pre-charge from the spec, embedded ones from the trace seed)
        let spec = FaultSpec {
            seed: 3,
            events: vec![FaultEvent { epoch: 2, kind: FaultKind::Slowdown { rail: 0, factor: 8.0 } }],
        };
        let external = replay_with_faults(&trace, &adaptive(), &ReplayConfig::default(), Some(&spec)).unwrap();
        let embedded_trace = spec.attach(&trace).unwrap();
        let embedded = replay(&embedded_trace, &adaptive(), &ReplayConfig::default()).unwrap();
        assert_eq!(report_to_json(&external), report_to_json(&embedded));
        // a trace that already carries a schedule refuses a second one
        let err = replay_with_faults(&embedded_trace, &adaptive(), &ReplayConfig::default(), Some(&spec));
        assert!(err.unwrap_err().contains("already embeds"));
    }

    #[test]
    fn mismatched_surface_and_bad_threshold_rejected() {
        use crate::advisor::{DecisionSurface, SurfaceAxes};
        let trace = synthesize(TraceScenario::Stationary, "lassen", 2, 1, 1).unwrap();
        let foreign = DecisionSurface::compile("frontier-like", SurfaceAxes::default_axes(), 0.0).unwrap();
        let err = replay(&trace, &ReplayMode::Adaptive { surface: Some(&foreign) }, &ReplayConfig::default());
        assert!(err.unwrap_err().contains("compiled for"));
        let bad = replay(&trace, &adaptive(), &ReplayConfig { drift_threshold: -1.0, ..Default::default() });
        assert!(bad.is_err());
    }
}
