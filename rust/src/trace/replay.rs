//! Replay a trace through the Table 6 models under a static or adaptive
//! strategy policy, quantifying the win from online re-selection.
//!
//! Every epoch is costed for *all* Table 5 strategies (the static
//! baselines come for free), then the policy picks the strategy actually
//! "run" for that epoch:
//!
//! - **static** — one fixed strategy for the whole trace;
//! - **adaptive (exact)** — at epoch 0 and whenever the drift *from the
//!   last advice point* exceeds the threshold (slow per-epoch creep
//!   accumulates against the anchor and still triggers), re-rank the
//!   Table 6 models on the epoch's measured pattern statistics and take
//!   the argmin (ties keep Table 5 order);
//! - **adaptive (surface)** — same trigger, but the advice comes from a
//!   compiled [`crate::advisor::DecisionSurface`] lookup (the serving-path
//!   advisor; interpolation can be slightly suboptimal off-lattice, which
//!   is why the report costs the pick with the exact model either way).
//!
//! Because each epoch is a plateau (the pattern inside is constant), an
//! adaptive run that re-advises at every boundary accrues the pointwise
//! minimum cost — provably ≤ every static strategy's total. The per-epoch
//! report records drift, advice points, switches and cumulative time; the
//! summary compares against the best and worst static totals. Reports are
//! deterministic: byte-identical JSON for byte-identical traces.

use super::{drift_between, DEFAULT_DRIFT_THRESHOLD, Trace};
use crate::advisor::{DecisionSurface, Pattern};
use crate::bench::{fmt_secs, Table};
use crate::comm::{build_schedule_from, Strategy};
use crate::model::StrategyModel;
use crate::sim::{self, CompiledPattern};
use crate::sweep::emit::esc;
use crate::util::json::fmt_f64;
use std::fmt::Write as _;

/// Strategy policy for a replay run.
#[derive(Clone, Debug)]
pub enum ReplayMode<'a> {
    /// One fixed strategy for every epoch.
    Static(Strategy),
    /// Re-advise on drift; `surface` switches the advisor from the exact
    /// Table 6 ranking (None) to a compiled decision surface.
    Adaptive { surface: Option<&'a DecisionSurface> },
}

impl ReplayMode<'_> {
    fn label(&self) -> String {
        match self {
            ReplayMode::Static(s) => format!("static:{}", s.label()),
            ReplayMode::Adaptive { surface: None } => "adaptive:model".to_string(),
            ReplayMode::Adaptive { surface: Some(_) } => "adaptive:surface".to_string(),
        }
    }
}

/// Replay configuration beyond the policy.
#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    /// Drift (|log₂| units, [`drift_between`]) above which adaptive mode
    /// re-advises.
    pub drift_threshold: f64,
    /// Also run each epoch's chosen schedule through the discrete-event
    /// simulator (slower; fills [`EpochRow::sim_s`]).
    pub sim: bool,
}

impl Default for ReplayConfig {
    fn default() -> ReplayConfig {
        ReplayConfig { drift_threshold: DEFAULT_DRIFT_THRESHOLD, sim: false }
    }
}

/// One epoch of the replay report.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochRow {
    pub index: usize,
    pub tag: String,
    pub repeat: usize,
    /// Drift from the policy's current reference stats: the last advice
    /// point under the adaptive policy, the trace start under a static
    /// policy (0 for epoch 0). The *consecutive-epoch* drift lives in the
    /// trace artifact ([`crate::trace::Trace::drifts`]), not here.
    pub drift: f64,
    /// Whether the advisor was consulted at this epoch.
    pub advised: bool,
    /// Strategy in effect.
    pub strategy: Strategy,
    /// The exact per-epoch argmin (reference, regardless of policy).
    pub best: Strategy,
    /// Modeled seconds per iteration under the strategy in effect.
    pub per_iter_s: f64,
    /// `per_iter_s × repeat`.
    pub epoch_s: f64,
    /// Running total after this epoch.
    pub cum_s: f64,
    /// Simulated seconds per iteration (when [`ReplayConfig::sim`]).
    pub sim_s: Option<f64>,
}

/// A strategy change at an advice point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SwitchEvent {
    pub epoch: usize,
    pub from: Strategy,
    pub to: Strategy,
}

/// Total modeled seconds of one static strategy over the whole trace.
#[derive(Clone, Debug, PartialEq)]
pub struct StaticTotal {
    pub strategy: Strategy,
    pub total_s: f64,
}

/// The replay outcome.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub scenario: String,
    pub machine: String,
    /// Policy label (`"static:…"`, `"adaptive:model"`, `"adaptive:surface"`).
    pub mode: String,
    pub drift_threshold: f64,
    /// Total iterations replayed.
    pub iterations: usize,
    pub rows: Vec<EpochRow>,
    /// Every Table 5 strategy's static total, in Table 5 order.
    pub statics: Vec<StaticTotal>,
    /// Cumulative modeled time of the replayed policy.
    pub total_s: f64,
    pub best_static: StaticTotal,
    pub worst_static: StaticTotal,
    pub switches: Vec<SwitchEvent>,
    /// `(best_static − total) / best_static`; negative when the policy
    /// loses to the best static strategy, 0 for an empty denominator.
    pub win_vs_best_static: f64,
    pub win_vs_worst_static: f64,
}

/// Replay `trace` under `mode`. Costs are the Table 6 models evaluated on
/// each epoch's measured pattern statistics (`ppn` = all cores, matching
/// `hetcomm model` / `sweep`); the trace machine's registry parameters are
/// required ([`Trace::params`]).
pub fn replay(trace: &Trace, mode: &ReplayMode, config: &ReplayConfig) -> Result<ReplayReport, String> {
    trace.validate()?;
    let params = trace
        .params()
        .ok_or_else(|| format!("trace machine {:?} resolves to no registry parameters", trace.machine.name))?;
    if let ReplayMode::Adaptive { surface: Some(surface) } = mode {
        surface.validate()?;
        if surface.machine != trace.machine.name {
            return Err(format!(
                "surface was compiled for {:?} but the trace ran on {:?}",
                surface.machine, trace.machine.name
            ));
        }
        // surfaces are shape-keyed: the rail counts must agree or every
        // re-advise would rank strategies under the wrong injection limit
        if surface.nics != trace.machine.nics_per_node() {
            return Err(format!(
                "surface was compiled for {} NICs/node but the trace machine has {}",
                surface.nics,
                trace.machine.nics_per_node()
            ));
        }
    }
    if !config.drift_threshold.is_finite() || config.drift_threshold < 0.0 {
        return Err(format!("drift threshold {} must be finite and >= 0", config.drift_threshold));
    }

    let machine = &trace.machine;
    let sm = StrategyModel::new(machine, &params);
    let ppn = machine.cores_per_node();
    let all = Strategy::all();
    // simulator leg: compile the band tables once and reuse one scratch
    // across every epoch (allocation-free inner loop)
    let compiled_params = config.sim.then(|| params.compile());
    let mut scratch = sim::Scratch::new();

    let mut statics: Vec<StaticTotal> = all.iter().map(|&s| StaticTotal { strategy: s, total_s: 0.0 }).collect();
    let mut rows: Vec<EpochRow> = Vec::with_capacity(trace.epochs.len());
    let mut switches = Vec::new();
    let mut total_s = 0f64;
    // drift reference: the stats at the last advice point (so sub-threshold
    // creep accumulates); static mode keeps the trace-start reference
    let mut anchor_stats = None;
    let mut current: Option<Strategy> = None;

    for epoch in &trace.epochs {
        let stats = epoch.pattern.stats(machine);
        let dup = epoch.pattern.duplicate_fraction(machine);
        // assemble the inputs from the stats already in hand (the
        // `model_inputs` convenience would recompute them)
        let inputs = crate::model::ModelInputs {
            s_proc: stats.s_proc,
            s_node: stats.s_node,
            s_n2n: stats.s_n2n,
            m_p2n: stats.m_p2n,
            m_n2n: stats.m_n2n,
            m_std: stats.m_std,
            ppn,
            nics: machine.nics_per_node(),
            dup_frac: dup,
        };
        let times = sm.all_times(&inputs);
        let rep = epoch.repeat as f64;
        for (k, &(_, t)) in times.iter().enumerate() {
            statics[k].total_s += t * rep;
        }
        // first-wins argmin: ties keep Table 5 order, matching the
        // surface's `best_index`
        let mut best = times[0].0;
        let mut best_t = times[0].1;
        for &(s, t) in &times[1..] {
            if t < best_t {
                best = s;
                best_t = t;
            }
        }

        let drift = anchor_stats.as_ref().map(|p| drift_between(p, &stats)).unwrap_or(0.0);
        let (advised, strategy) = match mode {
            ReplayMode::Static(s) => (false, *s),
            ReplayMode::Adaptive { surface } => {
                let trigger = current.is_none() || drift > config.drift_threshold;
                if trigger {
                    let pick = match surface {
                        None => best,
                        Some(surface) => surface.lookup(&Pattern::from_stats(&stats, machine)).best().0,
                    };
                    (true, pick)
                } else {
                    (false, current.expect("non-trigger implies a prior advice"))
                }
            }
        };
        if advised {
            if let Some(prev) = current {
                if prev != strategy {
                    switches.push(SwitchEvent { epoch: epoch.index, from: prev, to: strategy });
                }
            }
        }
        let per_iter_s = times
            .iter()
            .find(|(s, _)| *s == strategy)
            .map(|&(_, t)| t)
            .ok_or_else(|| format!("strategy {} is not in the Table 5 set", strategy.label()))?;
        let epoch_s = per_iter_s * rep;
        total_s += epoch_s;
        let sim_s = compiled_params.as_ref().map(|cp| {
            let lowered = CompiledPattern::lower(machine, &epoch.pattern);
            let schedule = build_schedule_from(strategy, machine, &lowered);
            scratch.run_total(machine, cp, &schedule, strategy.sim_ppn(machine))
        });
        rows.push(EpochRow {
            index: epoch.index,
            tag: epoch.tag.clone(),
            repeat: epoch.repeat,
            drift,
            advised,
            strategy,
            best,
            per_iter_s,
            epoch_s,
            cum_s: total_s,
            sim_s,
        });
        // the reference only moves when the advisor was (re-)consulted; the
        // trace start anchors epoch 0 for every policy
        if advised || anchor_stats.is_none() {
            anchor_stats = Some(stats);
        }
        current = Some(strategy);
    }

    // first-wins extrema: ties keep Table 5 order
    let mut best_static = statics[0].clone();
    let mut worst_static = statics[0].clone();
    for s in &statics[1..] {
        if s.total_s < best_static.total_s {
            best_static = s.clone();
        }
        if s.total_s > worst_static.total_s {
            worst_static = s.clone();
        }
    }
    let win = |baseline: f64| if baseline > 0.0 { (baseline - total_s) / baseline } else { 0.0 };
    Ok(ReplayReport {
        scenario: trace.scenario.clone(),
        machine: trace.machine.name.clone(),
        mode: mode.label(),
        drift_threshold: config.drift_threshold,
        iterations: trace.iterations(),
        rows,
        win_vs_best_static: win(best_static.total_s),
        win_vs_worst_static: win(worst_static.total_s),
        statics,
        total_s,
        best_static,
        worst_static,
        switches,
    })
}

/// Serialize a replay report as deterministic JSON (shortest-round-trip
/// floats; no wall-clock fields, so equal traces emit equal bytes).
pub fn report_to_json(r: &ReplayReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"hetcomm.replay.v1\",");
    let _ = writeln!(out, "  \"scenario\": \"{}\",", esc(&r.scenario));
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&r.machine));
    let _ = writeln!(out, "  \"mode\": \"{}\",", esc(&r.mode));
    let _ = writeln!(out, "  \"drift_threshold\": {},", fmt_f64(r.drift_threshold));
    let _ = writeln!(out, "  \"iterations\": {},", r.iterations);
    out.push_str("  \"epochs\": [\n");
    for (i, row) in r.rows.iter().enumerate() {
        let comma = if i + 1 < r.rows.len() { "," } else { "" };
        let sim = match row.sim_s {
            Some(t) => fmt_f64(t),
            None => "null".to_string(),
        };
        let _ = writeln!(
            out,
            "    {{\"index\": {}, \"tag\": \"{}\", \"repeat\": {}, \"drift\": {}, \"advised\": {}, \
             \"strategy\": \"{}\", \"best\": \"{}\", \"per_iter_s\": {}, \"epoch_s\": {}, \"cum_s\": {}, \
             \"sim_s\": {}}}{comma}",
            row.index,
            esc(&row.tag),
            row.repeat,
            fmt_f64(row.drift),
            row.advised,
            esc(&row.strategy.label()),
            esc(&row.best.label()),
            fmt_f64(row.per_iter_s),
            fmt_f64(row.epoch_s),
            fmt_f64(row.cum_s),
            sim,
        );
    }
    out.push_str("  ],\n");
    out.push_str("  \"statics\": [\n");
    for (i, s) in r.statics.iter().enumerate() {
        let comma = if i + 1 < r.statics.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"strategy\": \"{}\", \"total_s\": {}}}{comma}",
            esc(&s.strategy.label()),
            fmt_f64(s.total_s)
        );
    }
    out.push_str("  ],\n");
    out.push_str("  \"switches\": [\n");
    for (i, sw) in r.switches.iter().enumerate() {
        let comma = if i + 1 < r.switches.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"epoch\": {}, \"from\": \"{}\", \"to\": \"{}\"}}{comma}",
            sw.epoch,
            esc(&sw.from.label()),
            esc(&sw.to.label())
        );
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"total_s\": {},", fmt_f64(r.total_s));
    let _ = writeln!(
        out,
        "  \"best_static\": {{\"strategy\": \"{}\", \"total_s\": {}}},",
        esc(&r.best_static.strategy.label()),
        fmt_f64(r.best_static.total_s)
    );
    let _ = writeln!(
        out,
        "  \"worst_static\": {{\"strategy\": \"{}\", \"total_s\": {}}},",
        esc(&r.worst_static.strategy.label()),
        fmt_f64(r.worst_static.total_s)
    );
    let _ = writeln!(out, "  \"win_vs_best_static\": {},", fmt_f64(r.win_vs_best_static));
    let _ = writeln!(out, "  \"win_vs_worst_static\": {}", fmt_f64(r.win_vs_worst_static));
    out.push_str("}\n");
    out
}

/// Render a replay report as aligned text tables.
pub fn render_report(r: &ReplayReport) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        format!("Replay: {} on {} ({}, threshold {})", r.scenario, r.machine, r.mode, r.drift_threshold),
        &["epoch", "tag", "iters", "drift", "advised", "strategy", "per-iter", "cum", "sim/iter"],
    );
    for row in &r.rows {
        t.row(vec![
            row.index.to_string(),
            row.tag.clone(),
            row.repeat.to_string(),
            format!("{:.2}", row.drift),
            if row.advised { "yes".into() } else { String::new() },
            row.strategy.label().to_string(),
            fmt_secs(row.per_iter_s),
            fmt_secs(row.cum_s),
            row.sim_s.map(fmt_secs).unwrap_or_default(),
        ]);
    }
    out.push_str(&t.render());
    let mut b = Table::new("Static baselines (whole trace)", &["strategy", "total"]);
    for s in &r.statics {
        b.row(vec![s.strategy.label().to_string(), fmt_secs(s.total_s)]);
    }
    out.push('\n');
    out.push_str(&b.render());
    let _ = writeln!(
        out,
        "\nreplayed {} iterations over {} epochs: total {}",
        r.iterations,
        r.rows.len(),
        fmt_secs(r.total_s).trim()
    );
    let _ = writeln!(
        out,
        "best static  {} ({}) -> win {:+.2}%",
        r.best_static.strategy.label(),
        fmt_secs(r.best_static.total_s).trim(),
        r.win_vs_best_static * 100.0
    );
    let _ = writeln!(
        out,
        "worst static {} ({}) -> win {:+.2}%",
        r.worst_static.strategy.label(),
        fmt_secs(r.worst_static.total_s).trim(),
        r.win_vs_worst_static * 100.0
    );
    for sw in &r.switches {
        let _ = writeln!(out, "switch at epoch {}: {} -> {}", sw.epoch, sw.from.label(), sw.to.label());
    }
    if r.switches.is_empty() {
        let _ = writeln!(out, "no strategy switches");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StrategyKind, Transport};
    use crate::trace::scenarios::{synthesize, TraceScenario};

    fn adaptive() -> ReplayMode<'static> {
        ReplayMode::Adaptive { surface: None }
    }

    #[test]
    fn adaptive_never_loses_to_any_static() {
        for sc in TraceScenario::ALL {
            let trace = synthesize(sc, "lassen", 5, 0, 42).unwrap();
            let r = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
            for s in &r.statics {
                assert!(
                    r.total_s <= s.total_s * (1.0 + 1e-12),
                    "{sc}: adaptive {} loses to static {} {}",
                    r.total_s,
                    s.strategy.label(),
                    s.total_s
                );
            }
            assert!(r.win_vs_best_static >= -1e-12, "{sc}: win {}", r.win_vs_best_static);
            // rows carry a consistent running total
            let mut cum = 0.0;
            for row in &r.rows {
                cum += row.epoch_s;
                assert_eq!(row.cum_s.to_bits(), cum.to_bits());
            }
        }
    }

    #[test]
    fn static_mode_reproduces_its_baseline_total() {
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        for strategy in Strategy::all() {
            let r = replay(&trace, &ReplayMode::Static(strategy), &ReplayConfig::default()).unwrap();
            let baseline = r.statics.iter().find(|s| s.strategy == strategy).unwrap();
            assert_eq!(r.total_s.to_bits(), baseline.total_s.to_bits(), "{}", strategy.label());
            assert!(r.switches.is_empty());
            assert!(r.rows.iter().all(|row| !row.advised));
        }
    }

    #[test]
    fn huge_threshold_freezes_the_first_choice() {
        let trace = synthesize(TraceScenario::Rebalance, "lassen", 3, 0, 42).unwrap();
        let frozen = replay(&trace, &adaptive(), &ReplayConfig { drift_threshold: 1e9, ..Default::default() }).unwrap();
        assert!(frozen.switches.is_empty());
        assert_eq!(frozen.rows.iter().filter(|r| r.advised).count(), 1, "only epoch 0 advises");
        let first = frozen.rows[0].strategy;
        let static_run = replay(&trace, &ReplayMode::Static(first), &ReplayConfig::default()).unwrap();
        assert_eq!(frozen.total_s.to_bits(), static_run.total_s.to_bits());
        // the default threshold re-advises at both rebalance boundaries
        let live = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        assert_eq!(live.rows.iter().filter(|r| r.advised).count(), 3);
    }

    #[test]
    fn sim_mode_fills_per_epoch_sim_times() {
        let trace = synthesize(TraceScenario::HaloBurst, "lassen", 3, 1, 42).unwrap();
        let r = replay(&trace, &adaptive(), &ReplayConfig { sim: true, ..Default::default() }).unwrap();
        assert!(r.rows.iter().all(|row| row.sim_s.is_some_and(|t| t.is_finite() && t > 0.0)));
        let dry = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        assert!(dry.rows.iter().all(|row| row.sim_s.is_none()));
        // the sim leg never changes the modeled accounting
        assert_eq!(r.total_s.to_bits(), dry.total_s.to_bits());
    }

    #[test]
    fn report_emitters_are_deterministic_and_complete() {
        let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        let r1 = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        let r2 = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
        let (j1, j2) = (report_to_json(&r1), report_to_json(&r2));
        assert_eq!(j1, j2);
        assert!(j1.contains("hetcomm.replay.v1"));
        assert!(j1.contains("\"switches\""));
        let txt = render_report(&r1);
        assert!(txt.contains("best static"));
        assert!(txt.contains("switch at epoch"));
    }

    #[test]
    fn mismatched_surface_and_bad_threshold_rejected() {
        use crate::advisor::{DecisionSurface, SurfaceAxes};
        let trace = synthesize(TraceScenario::Stationary, "lassen", 2, 1, 1).unwrap();
        let foreign = DecisionSurface::compile("frontier-like", SurfaceAxes::default_axes(), 0.0).unwrap();
        let err = replay(&trace, &ReplayMode::Adaptive { surface: Some(&foreign) }, &ReplayConfig::default());
        assert!(err.unwrap_err().contains("compiled for"));
        let bad = replay(&trace, &adaptive(), &ReplayConfig { drift_threshold: -1.0, ..Default::default() });
        assert!(bad.is_err());
    }
}
