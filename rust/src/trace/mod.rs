//! Trace-driven workload replay — the time axis of the model study.
//!
//! The paper characterizes *static* communication patterns: one snapshot of
//! who sends what to whom, one regime, one winning strategy. Real irregular
//! workloads (AMR refinement fronts, progressively sparsifying operators,
//! rebalancing after node failure, bursty halo growth) *drift* across
//! regimes mid-run — which is exactly where re-selecting the strategy
//! online pays off. This module records, synthesizes and replays such
//! evolving workloads:
//!
//! - a [`Trace`] is a versioned sequence of [`Epoch`]s, each a
//!   [`crate::pattern::CommPattern`] snapshot plus a repeat count (how many
//!   iterations the pattern persisted) — the `hetcomm.trace.v1` artifact of
//!   [`persist`];
//! - [`record::TraceRecorder`] captures epochs from live runs: the
//!   coordinator's persistent engine observes its halo pattern every
//!   [`crate::coordinator::Engine::iterate`] call, and
//!   [`record::record_spmv`] drives a SuiteSparse-proxy SpMV through it;
//! - [`scenarios`] synthesizes evolving workloads (AMR-style refinement
//!   fronts, progressive sparsification, node-failure rebalance, bursty
//!   halo growth) on top of [`crate::pattern::generators`];
//! - [`mod@replay`] drives each epoch through the Table 6 models (and
//!   optionally the discrete-event simulator) under a static strategy or an
//!   *adaptive* advisor that re-advises whenever the pattern drifts past a
//!   threshold, reporting per-epoch strategy switches and the cumulative
//!   win against the best and worst static strategies.
//!
//! Exposed on the CLI as `hetcomm replay` (`--scenario`, `--record`,
//! `--trace`, `--adaptive`, `--strategy`, `--surface`); `hetcomm sweep
//! --trace` accepts a recorded trace as the pattern source. Everything is
//! deterministic under a fixed seed: two runs produce byte-identical trace
//! artifacts and replay reports.

pub mod persist;
pub mod record;
pub mod replay;
pub mod scenarios;

use crate::params::MachineParams;
use crate::pattern::{CommPattern, PatternStats};
use crate::topology::{machines, Machine};

pub use record::TraceRecorder;
pub use replay::{replay, replay_with_faults, ReplayMode, ReplayReport, Resilience};
pub use scenarios::{synthesize, TraceScenario};

/// Default drift threshold for adaptive replay: re-advise when any tracked
/// pattern statistic moves by more than a quarter of a binary order of
/// magnitude (~19%) between epochs.
pub const DEFAULT_DRIFT_THRESHOLD: f64 = 0.25;

/// One plateau of a workload: a communication pattern that stayed fixed for
/// `repeat` consecutive iterations.
#[derive(Clone, Debug, PartialEq)]
pub struct Epoch {
    /// Position in the trace (contiguous from 0).
    pub index: usize,
    /// Free-form provenance label (`"level2"`, `"burst"`, `"spmv"`, …).
    pub tag: String,
    /// Iterations this pattern persisted (>= 1).
    pub repeat: usize,
    /// The GPU→GPU payload multiset of one iteration.
    pub pattern: CommPattern,
    /// Fault events firing at the start of this epoch
    /// ([`crate::fault::FaultSpec::attach`]); empty on healthy traces, and
    /// absent from the artifact when empty (`trace.v1` byte compatibility).
    pub faults: Vec<crate::fault::FaultKind>,
}

/// A recorded or synthesized workload: the machine it ran on plus the
/// sequence of pattern plateaus, in time order.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Scenario or provenance name (`"amr-drift"`, `"spmv:audikw_1"`, …).
    pub scenario: String,
    /// Seed the trace was generated under (provenance; recorded traces keep
    /// the seed of the run that produced them).
    pub seed: u64,
    /// The machine the pattern's GPU ids index into.
    pub machine: Machine,
    pub epochs: Vec<Epoch>,
}

impl Trace {
    /// Structural sanity (used after artifact loads and before replay);
    /// returns a user-facing message on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.epochs.is_empty() {
            return Err("trace has no epochs".into());
        }
        if self.machine.num_nodes == 0
            || self.machine.sockets_per_node == 0
            || self.machine.cores_per_socket == 0
            || self.machine.gpus_per_socket == 0
        {
            return Err(format!("degenerate trace machine {:?}", self.machine.name));
        }
        let total_gpus = self.machine.total_gpus();
        for (k, e) in self.epochs.iter().enumerate() {
            if e.index != k {
                return Err(format!("epoch {k} carries index {} (must be contiguous from 0)", e.index));
            }
            if e.repeat == 0 {
                return Err(format!("epoch {k} has repeat 0"));
            }
            for (i, m) in e.pattern.msgs.iter().enumerate() {
                if m.src.0 >= total_gpus || m.dst.0 >= total_gpus {
                    return Err(format!(
                        "epoch {k} msg {i}: endpoint outside the {total_gpus}-GPU machine ({} -> {})",
                        m.src.0, m.dst.0
                    ));
                }
                if m.src == m.dst {
                    return Err(format!("epoch {k} msg {i}: self-message on GPU {}", m.src.0));
                }
                if m.bytes == 0 {
                    return Err(format!("epoch {k} msg {i}: zero-byte message"));
                }
            }
            let rails = self.machine.nics_per_node();
            for f in &e.faults {
                f.validate(rails).map_err(|err| format!("epoch {k}: {err}"))?;
            }
        }
        Ok(())
    }

    /// The fault schedule embedded in the epochs, reassembled as a
    /// [`crate::fault::FaultSpec`] (seeded by the trace seed); `None` when
    /// the trace is healthy.
    pub fn fault_spec(&self) -> Option<crate::fault::FaultSpec> {
        let events: Vec<crate::fault::FaultEvent> = self
            .epochs
            .iter()
            .flat_map(|e| {
                e.faults.iter().map(move |kind| crate::fault::FaultEvent { epoch: e.index, kind: kind.clone() })
            })
            .collect();
        if events.is_empty() {
            None
        } else {
            Some(crate::fault::FaultSpec { seed: self.seed, events })
        }
    }

    /// Total iterations across all epochs.
    pub fn iterations(&self) -> usize {
        self.epochs.iter().map(|e| e.repeat).sum()
    }

    /// Table 7 statistics of every epoch against the trace machine.
    pub fn epoch_stats(&self) -> Vec<PatternStats> {
        self.epochs.iter().map(|e| e.pattern.stats(&self.machine)).collect()
    }

    /// Per-epoch drift from the previous epoch ([`drift_between`]); epoch 0
    /// is 0 by convention.
    pub fn drifts(&self) -> Vec<f64> {
        Trace::drifts_from(&self.epoch_stats())
    }

    /// [`Trace::drifts`] over precomputed per-epoch statistics — callers
    /// that already hold [`Trace::epoch_stats`] (the artifact emitter and
    /// parser) avoid a second full-pattern pass.
    pub fn drifts_from(stats: &[PatternStats]) -> Vec<f64> {
        let mut out = vec![0.0; stats.len()];
        for k in 1..stats.len() {
            out[k] = drift_between(&stats[k - 1], &stats[k]);
        }
        out
    }

    /// Modeling parameters for the trace machine: an exact registry match
    /// ([`machines::parse`]), or the longest registry prefix of the name
    /// (recorded sweep machines carry shape suffixes like `"lassen-g4"`).
    pub fn params(&self) -> Option<MachineParams> {
        if let Ok((_, p)) = machines::parse(&self.machine.name, 1) {
            return Some(p);
        }
        machines::NAMES
            .iter()
            .filter(|n| self.machine.name.starts_with(*n))
            .max_by_key(|n| n.len())
            .and_then(|n| machines::parse(n, 1).ok())
            .map(|(_, p)| p)
    }
}

/// Drift between two pattern snapshots: the largest absolute log₂ change
/// across the regime-defining statistics (inter-node message count and
/// volume, node and node-pair injection, per-process message count,
/// destination spread). `+1` smoothing keeps empty patterns finite; 1.0
/// means "some statistic roughly doubled or halved".
pub fn drift_between(prev: &PatternStats, cur: &PatternStats) -> f64 {
    let pairs = [
        (prev.total_internode_msgs, cur.total_internode_msgs),
        (prev.total_internode_bytes, cur.total_internode_bytes),
        (prev.s_node, cur.s_node),
        (prev.s_n2n, cur.s_n2n),
        (prev.m_std, cur.m_std),
        (prev.m_p2n, cur.m_p2n),
    ];
    let mut worst = 0f64;
    for (a, b) in pairs {
        // larger-over-smaller keeps the measure exactly symmetric (an
        // |log2(a/b)| of the raw ratio can differ from |log2(b/a)| by an
        // ulp, which would break the bit-exact artifact self-check under
        // trace reversal)
        let (hi, lo) = if a >= b { (a + 1, b + 1) } else { (b + 1, a + 1) };
        let d = ((hi as f64) / (lo as f64)).log2();
        worst = worst.max(d);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::generators::Scenario;
    use crate::pattern::Msg;
    use crate::topology::machines::lassen;
    use crate::topology::GpuId;

    fn scenario_trace() -> Trace {
        let machine = lassen(17);
        let epochs = [(64usize, 4096usize, 4usize), (128, 2048, 8)]
            .iter()
            .enumerate()
            .map(|(k, &(n_msgs, msg_size, n_dest))| Epoch {
                index: k,
                tag: format!("e{k}"),
                repeat: 2,
                pattern: Scenario { n_msgs, msg_size, n_dest, dup_frac: 0.0 }.materialize(&machine),
                faults: vec![],
            })
            .collect();
        Trace { scenario: "test".into(), seed: 7, machine, epochs }
    }

    #[test]
    fn valid_trace_passes_and_counts() {
        let t = scenario_trace();
        t.validate().unwrap();
        assert_eq!(t.iterations(), 4);
        assert_eq!(t.epoch_stats().len(), 2);
    }

    #[test]
    fn validation_rejects_structural_faults() {
        let mut t = scenario_trace();
        t.epochs[1].index = 5;
        assert!(t.validate().unwrap_err().contains("contiguous"));

        let mut t = scenario_trace();
        t.epochs[0].repeat = 0;
        assert!(t.validate().unwrap_err().contains("repeat"));

        let mut t = scenario_trace();
        t.epochs.clear();
        assert!(t.validate().is_err());

        let mut t = scenario_trace();
        let gpus = t.machine.total_gpus();
        t.epochs[0].pattern.push(Msg::new(GpuId(0), GpuId(gpus), 8));
        assert!(t.validate().unwrap_err().contains("outside"));

        let mut t = scenario_trace();
        t.epochs[0].pattern.push(Msg::new(GpuId(3), GpuId(3), 8));
        assert!(t.validate().unwrap_err().contains("self-message"));
    }

    #[test]
    fn drift_is_symmetric_zero_on_identity_and_scales() {
        let t = scenario_trace();
        let stats = t.epoch_stats();
        assert_eq!(drift_between(&stats[0], &stats[0]), 0.0);
        let fwd = drift_between(&stats[0], &stats[1]);
        let back = drift_between(&stats[1], &stats[0]);
        assert_eq!(fwd, back);
        // 64 -> 128 msgs roughly doubles the message statistics
        assert!(fwd > 0.9 && fwd < 1.1, "drift {fwd}");
        assert_eq!(t.drifts()[0], 0.0);
        assert_eq!(t.drifts()[1], fwd);
    }

    #[test]
    fn params_resolve_registry_and_shape_suffixed_names() {
        let mut t = scenario_trace();
        assert!(t.params().is_some());
        t.machine.name = "lassen-g4".into();
        assert!(t.params().is_some());
        t.machine.name = "frontier-like-g8".into();
        assert!(t.params().is_some());
        t.machine.name = "mystery".into();
        assert!(t.params().is_none());
    }
}
