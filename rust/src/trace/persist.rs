//! Versioned JSON artifacts for workload traces (`hetcomm.trace.v1`).
//!
//! An epoch's messages are stored verbatim as `[src, dst, bytes,
//! dup_group]` quadruples; every artifact additionally carries *derived*
//! drift metadata per epoch (the regime-defining Table 7 statistics and the
//! drift from the previous epoch). The metadata is self-checking: the
//! parser recomputes it from the message lists and rejects an artifact
//! whose stored values disagree bit for bit, so hand-edited or truncated
//! traces fail loudly instead of replaying under a mislabeled regime.
//! Emit∘parse∘emit is the identity on bytes ([`crate::util::json`]).
//!
//! Epochs carrying fault events ([`crate::fault`]) add an optional
//! `"faults"` key spelled exactly like `hetcomm.faults.v1` events; the key
//! is omitted when empty, so healthy traces are byte-identical to
//! pre-fault-layer artifacts.

use super::{Epoch, Trace};
use crate::pattern::{CommPattern, Msg};
use crate::sweep::emit::esc;
use crate::topology::{GpuId, Machine};
use crate::util::json::{fmt_f64, fmt_usize_list as usize_list, Json};
use std::fmt::Write as _;

/// Artifact schema identifier; bump on layout changes.
pub const SCHEMA: &str = "hetcomm.trace.v1";

/// Serialize a trace as a versioned JSON artifact.
pub fn to_json(trace: &Trace) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"scenario\": \"{}\",", esc(&trace.scenario));
    // the seed is a string: u64 values above 2^53 would not survive a
    // JSON-number round trip through f64
    let _ = writeln!(out, "  \"seed\": \"{}\",", trace.seed);
    let m = &trace.machine;
    // Shape key, three spellings: single-rail machines keep the historical
    // five-field object (byte-identical artifacts); machines on the
    // canonical spread layout append just `nics`; anything else (custom
    // GPU↔NIC affinity) persists the full resource graph so the reloaded
    // trace replays on exactly the recorded shape.
    let canonical =
        m.shape == crate::topology::NodeShape::spread(m.sockets_per_node.max(1), m.nics_per_node(), m.gpus_per_node());
    let rails = if canonical && m.nics_per_node() == 1 {
        String::new()
    } else if canonical {
        format!(", \"nics\": {}", m.nics_per_node())
    } else {
        format!(
            ", \"nics_per_socket\": {}, \"gpu_nic\": {}",
            usize_list(&m.shape.nics_per_socket),
            usize_list(&m.shape.gpu_nic)
        )
    };
    let _ = writeln!(
        out,
        "  \"machine\": {{\"name\": \"{}\", \"num_nodes\": {}, \"sockets_per_node\": {}, \
         \"cores_per_socket\": {}, \"gpus_per_socket\": {}{rails}}},",
        esc(&m.name),
        m.num_nodes,
        m.sockets_per_node,
        m.cores_per_socket,
        m.gpus_per_socket
    );
    out.push_str("  \"epochs\": [\n");
    let stats = trace.epoch_stats();
    let drifts = Trace::drifts_from(&stats);
    for (k, e) in trace.epochs.iter().enumerate() {
        let st = &stats[k];
        out.push_str("    {");
        let _ = write!(out, "\"index\": {}, \"tag\": \"{}\", \"repeat\": {},", e.index, esc(&e.tag), e.repeat);
        // fault events are emitted only when present, so healthy traces
        // stay byte-identical to pre-fault-layer artifacts
        if !e.faults.is_empty() {
            out.push_str(" \"faults\": [");
            for (i, f) in e.faults.iter().enumerate() {
                let comma = if i + 1 < e.faults.len() { ", " } else { "" };
                let _ = write!(out, "{{{}}}{comma}", crate::fault::persist::kind_fields(f));
            }
            out.push_str("],");
        }
        let _ = write!(
            out,
            " \"drift\": {}, \"stats\": {{\"msgs\": {}, \"bytes\": {}, \"s_node\": {}, \"s_n2n\": {}, \
             \"m_std\": {}, \"m_p2n\": {}}},",
            fmt_f64(drifts[k]),
            st.total_internode_msgs,
            st.total_internode_bytes,
            st.s_node,
            st.s_n2n,
            st.m_std,
            st.m_p2n
        );
        out.push_str(" \"msgs\": [");
        for (i, msg) in e.pattern.msgs.iter().enumerate() {
            let comma = if i + 1 < e.pattern.msgs.len() { ", " } else { "" };
            let _ = write!(out, "[{}, {}, {}, {}]{comma}", msg.src.0, msg.dst.0, msg.bytes, msg.dup_group);
        }
        let comma = if k + 1 < trace.epochs.len() { "," } else { "" };
        let _ = writeln!(out, "]}}{comma}");
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write an artifact to disk.
pub fn save(trace: &Trace, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(trace)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate an artifact from disk.
pub fn load(path: &str) -> Result<Trace, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate an artifact, including the drift-metadata self-check.
pub fn parse_json(text: &str) -> Result<Trace, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("unsupported trace schema {schema:?} (expected {SCHEMA:?})"));
    }
    let m = value.field("machine")?;
    let sockets_per_node = m.field("sockets_per_node")?.as_usize()?;
    let gpus_per_socket = m.field("gpus_per_socket")?.as_usize()?;
    // optional shape keys (see `to_json`): the full resource graph when
    // present, a spread rail count otherwise, single-rail when absent
    let shape = if let Ok(per_socket) = m.field("nics_per_socket") {
        let shape = crate::topology::NodeShape {
            nics_per_socket: per_socket.as_usize_list()?,
            gpu_nic: m.field("gpu_nic")?.as_usize_list()?,
        };
        shape
            .validate(sockets_per_node.max(1), sockets_per_node * gpus_per_socket)
            .map_err(|e| format!("trace machine shape invalid: {e}"))?;
        shape
    } else {
        let nics = match m.field("nics") {
            Ok(v) => v.as_usize()?.max(1),
            Err(_) => 1,
        };
        crate::topology::NodeShape::spread(sockets_per_node.max(1), nics, sockets_per_node * gpus_per_socket)
    };
    let machine = Machine {
        name: m.field("name")?.as_str()?.to_string(),
        num_nodes: m.field("num_nodes")?.as_usize()?,
        sockets_per_node,
        cores_per_socket: m.field("cores_per_socket")?.as_usize()?,
        gpus_per_socket,
        shape,
    };
    let mut epochs = Vec::new();
    let mut declared: Vec<(f64, [usize; 6])> = Vec::new();
    for e in value.field("epochs")?.as_arr()? {
        let mut msgs = Vec::new();
        for q in e.field("msgs")?.as_arr()? {
            let quad = q.as_usize_list()?;
            if quad.len() != 4 {
                return Err(format!("message quadruple has {} fields (expected 4)", quad.len()));
            }
            if quad[3] > u32::MAX as usize {
                return Err(format!("dup_group {} exceeds u32", quad[3]));
            }
            msgs.push(Msg { src: GpuId(quad[0]), dst: GpuId(quad[1]), bytes: quad[2], dup_group: quad[3] as u32 });
        }
        let st = e.field("stats")?;
        declared.push((
            e.field("drift")?.as_f64()?,
            [
                st.field("msgs")?.as_usize()?,
                st.field("bytes")?.as_usize()?,
                st.field("s_node")?.as_usize()?,
                st.field("s_n2n")?.as_usize()?,
                st.field("m_std")?.as_usize()?,
                st.field("m_p2n")?.as_usize()?,
            ],
        ));
        let faults = match e.field("faults") {
            Ok(v) => v
                .as_arr()?
                .iter()
                .map(crate::fault::persist::parse_kind)
                .collect::<Result<Vec<_>, String>>()?,
            Err(_) => vec![],
        };
        epochs.push(Epoch {
            index: e.field("index")?.as_usize()?,
            tag: e.field("tag")?.as_str()?.to_string(),
            repeat: e.field("repeat")?.as_usize()?,
            pattern: CommPattern::new(msgs),
            faults,
        });
    }
    let seed_text = value.field("seed")?.as_str()?;
    let trace = Trace {
        scenario: value.field("scenario")?.as_str()?.to_string(),
        seed: seed_text.parse::<u64>().map_err(|_| format!("invalid seed {seed_text:?}"))?,
        machine,
        epochs,
    };
    trace.validate()?;

    // Self-check: the stored drift metadata must match what the message
    // lists imply (bit for bit — the emitter derives it the same way).
    let stats = trace.epoch_stats();
    let drifts = Trace::drifts_from(&stats);
    for (k, (drift, decl)) in declared.iter().enumerate() {
        let st = &stats[k];
        let actual = [st.total_internode_msgs, st.total_internode_bytes, st.s_node, st.s_n2n, st.m_std, st.m_p2n];
        if actual != *decl {
            return Err(format!("epoch {k}: stored stats {decl:?} disagree with the message list {actual:?}"));
        }
        if drift.to_bits() != drifts[k].to_bits() {
            return Err(format!("epoch {k}: stored drift {drift} disagrees with recomputed {}", drifts[k]));
        }
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::generators::Scenario;
    use crate::topology::machines::lassen;

    fn tiny_trace() -> Trace {
        let machine = lassen(9);
        let epochs = [(32usize, 1024usize, 4usize), (64, 4096, 8)]
            .iter()
            .enumerate()
            .map(|(k, &(n_msgs, msg_size, n_dest))| Epoch {
                index: k,
                tag: format!("e\"{k}\""),
                repeat: k + 1,
                pattern: Scenario { n_msgs, msg_size, n_dest, dup_frac: 0.0 }.materialize(&machine),
                faults: vec![],
            })
            .collect();
        Trace { scenario: "tiny \\ test".into(), seed: 11, machine, epochs }
    }

    #[test]
    fn artifact_roundtrips_bit_for_bit() {
        let trace = tiny_trace();
        let json = to_json(&trace);
        assert!(json.contains(SCHEMA));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(trace, parsed);
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn save_load_roundtrip() {
        let trace = tiny_trace();
        let path = std::env::temp_dir().join("hetcomm-trace-test.json");
        let path = path.to_str().unwrap();
        save(&trace, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(trace, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fault_epochs_roundtrip_and_healthy_traces_stay_clean() {
        use crate::fault::FaultKind;
        let healthy = to_json(&tiny_trace());
        assert!(!healthy.contains("faults"), "healthy artifacts must not mention faults");

        let mut trace = tiny_trace();
        trace.epochs[1].faults =
            vec![FaultKind::RailDown { rail: 0 }, FaultKind::Congestion { level: 2.5e-4 }];
        let json = to_json(&trace);
        assert!(json.contains("\"faults\": [{\"kind\": \"rail-down\", \"rail\": 0}"));
        let parsed = parse_json(&json).unwrap();
        assert_eq!(trace, parsed);
        assert_eq!(json, to_json(&parsed));
        // the embedded schedule reassembles as a spec seeded by the trace
        let spec = parsed.fault_spec().unwrap();
        assert_eq!(spec.seed, trace.seed);
        assert_eq!(spec.events.len(), 2);
        assert!(spec.events.iter().all(|e| e.epoch == 1));
        assert_eq!(parse_json(&healthy).unwrap().fault_spec(), None);
        // out-of-range fault rails are rejected by trace validation
        let bad = json.replacen("\"rail\": 0", "\"rail\": 9", 1);
        assert!(parse_json(&bad).unwrap_err().contains("rail"));
    }

    #[test]
    fn wrong_schema_rejected() {
        let json = to_json(&tiny_trace()).replace(SCHEMA, "hetcomm.trace.v999");
        assert!(parse_json(&json).unwrap_err().contains("unsupported"));
    }

    #[test]
    fn tampered_metadata_rejected() {
        let json = to_json(&tiny_trace());
        // corrupt a message size without touching the stored stats
        let tampered = json.replacen("[0, 4, 1024,", "[0, 4, 999,", 1);
        assert_ne!(json, tampered, "replacement must hit a message quadruple");
        assert!(parse_json(&tampered).unwrap_err().contains("disagree"));
        // corrupt the drift field
        let t2 = json.replacen("\"drift\": 0,", "\"drift\": 0.5,", 1);
        assert_ne!(json, t2);
        assert!(parse_json(&t2).unwrap_err().contains("drift"));
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{}").is_err());
        assert!(parse_json("{\"schema\": \"hetcomm.trace.v1\"}").is_err());
        // structurally valid JSON, structurally invalid trace
        let bad_epoch = to_json(&tiny_trace()).replacen("\"repeat\": 1,", "\"repeat\": 0,", 1);
        assert!(parse_json(&bad_epoch).is_err());
    }
}
