//! Synthetic evolving-workload generators, layered on
//! [`crate::pattern::generators::Scenario`].
//!
//! Each scenario is a schedule of regime plateaus `(messages, size,
//! destination nodes)` materialized into explicit per-epoch
//! [`crate::pattern::CommPattern`]s on a registry machine. The schedules
//! are closed-form — the regime trajectory is the scenario's *identity* —
//! while the seed deterministically shuffles the message order within each
//! epoch (pattern statistics are order-invariant, so replay results depend
//! only on the schedule; trace bytes depend on the seed).
//!
//! The trajectories are chosen to cross the paper's regime boundaries:
//! `amr-drift` walks from the large-message regime (device-aware wins,
//! Figure 4.3 right edge) into the many-small-messages regime (staged
//! node-aware Split wins), so adaptive replay must switch strategies
//! mid-trace to stay optimal.

use super::{Epoch, Trace};
use crate::pattern::generators::Scenario;
use crate::topology::machines;
use crate::util::rng::Rng;

/// The built-in evolving scenarios.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceScenario {
    /// AMR-style refinement front: each level doubles the message count,
    /// quarters the message size and spreads the halo to more neighbor
    /// nodes — large-size regime to many-small-messages regime.
    AmrDrift,
    /// Progressive sparsification: message count and size decay together.
    Sparsify,
    /// Node-failure rebalance: a healthy 16-destination halo loses four
    /// nodes, then re-spreads the volume over the survivors.
    Rebalance,
    /// Bursty halo growth: calm epochs punctuated by 32× message-size
    /// bursts — the strategy choice must flip back and forth.
    HaloBurst,
    /// Control: a single regime held for the whole trace.
    Stationary,
}

impl TraceScenario {
    pub const ALL: [TraceScenario; 5] = [
        TraceScenario::AmrDrift,
        TraceScenario::Sparsify,
        TraceScenario::Rebalance,
        TraceScenario::HaloBurst,
        TraceScenario::Stationary,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            TraceScenario::AmrDrift => "amr-drift",
            TraceScenario::Sparsify => "sparsify",
            TraceScenario::Rebalance => "rebalance",
            TraceScenario::HaloBurst => "halo-burst",
            TraceScenario::Stationary => "stationary",
        }
    }

    /// Parse a user-facing scenario name.
    pub fn parse(s: &str) -> Option<TraceScenario> {
        let canon = s.trim().to_ascii_lowercase().replace('_', "-");
        TraceScenario::ALL.iter().copied().find(|sc| sc.label() == canon)
    }

    /// Iterations a plateau holds by default (CLI `--repeat 0`).
    fn default_repeat(&self, tag: &str) -> usize {
        match self {
            TraceScenario::AmrDrift => 3,
            TraceScenario::Sparsify => 2,
            TraceScenario::Rebalance => 4,
            // bursts are short-lived; calm periods linger
            TraceScenario::HaloBurst => {
                if tag == "burst" {
                    1
                } else {
                    2
                }
            }
            TraceScenario::Stationary => 3,
        }
    }

    /// The plateau schedule: `(n_msgs, msg_size, dest_nodes, tag)` per
    /// epoch. All values sit on the advisor's default lattice so
    /// surface-driven and exact-model advice agree on these traces.
    fn schedule(&self, epochs: usize) -> Vec<(usize, usize, usize, String)> {
        let n = epochs.max(1);
        (0..n)
            .map(|k| match self {
                TraceScenario::AmrDrift => {
                    let msgs = (32usize << k.min(4)).min(512);
                    let size = ((1usize << 18) >> (2 * k).min(8)).max(1 << 10);
                    let dest = (4usize << k.min(2)).min(16);
                    (msgs, size, dest, format!("level{k}"))
                }
                TraceScenario::Sparsify => {
                    let msgs = (512usize >> k.min(5)).max(16);
                    let size = (8192usize >> k.min(7)).max(64);
                    (msgs, size, 16, format!("stage{k}"))
                }
                TraceScenario::Rebalance => {
                    if 3 * k < n {
                        (256, 8192, 16, "healthy".to_string())
                    } else if 3 * k < 2 * n {
                        (240, 8192, 12, "failover".to_string())
                    } else {
                        // survivors absorb the lost nodes' share: 16/12 of
                        // the per-message volume
                        (240, 8192 * 16 / 12, 12, "respread".to_string())
                    }
                }
                TraceScenario::HaloBurst => {
                    if k % 2 == 1 {
                        (128, 1 << 16, 8, "burst".to_string())
                    } else {
                        (128, 2048, 8, "calm".to_string())
                    }
                }
                TraceScenario::Stationary => (256, 8192, 16, "steady".to_string()),
            })
            .collect()
    }
}

impl std::fmt::Display for TraceScenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Deterministic per-epoch shuffle seed (splitmix-style index mixing).
fn epoch_seed(base: u64, index: usize) -> u64 {
    let mut z = base ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// Synthesize a scenario trace on a registry machine preset.
///
/// `epochs` is the plateau count (the schedules saturate, so any count is
/// valid); `repeat` overrides the per-plateau iteration count (0 keeps the
/// scenario default). Deterministic: the same arguments produce the same
/// trace, byte for byte.
pub fn synthesize(
    scenario: TraceScenario,
    machine_name: &str,
    epochs: usize,
    repeat: usize,
    seed: u64,
) -> Result<Trace, String> {
    let (arch, _) = machines::parse(machine_name, 1)?;
    // 16 destinations max across all schedules; one extra node hosts the
    // sender (the Figure 4.3 shape).
    let machine = machines::with_shape(&arch, 17, arch.gpus_per_node());
    let mut trace_epochs = Vec::with_capacity(epochs.max(1));
    for (k, (n_msgs, msg_size, n_dest, tag)) in scenario.schedule(epochs).into_iter().enumerate() {
        let mut pattern = Scenario { n_msgs, msg_size, n_dest, dup_frac: 0.0 }.materialize(&machine);
        let mut rng = Rng::new(epoch_seed(seed, k));
        rng.shuffle(&mut pattern.msgs);
        let rep = if repeat > 0 { repeat } else { scenario.default_repeat(&tag) };
        trace_epochs.push(Epoch { index: k, tag, repeat: rep, pattern, faults: vec![] });
    }
    let trace = Trace { scenario: scenario.label().to_string(), seed, machine, epochs: trace_epochs };
    trace.validate()?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::persist;

    #[test]
    fn scenario_parse_roundtrip() {
        for sc in TraceScenario::ALL {
            assert_eq!(TraceScenario::parse(sc.label()), Some(sc), "{sc}");
        }
        assert_eq!(TraceScenario::parse("AMR_DRIFT"), Some(TraceScenario::AmrDrift));
        assert_eq!(TraceScenario::parse("bogus"), None);
    }

    #[test]
    fn synthesis_is_deterministic_and_seed_moves_bytes_not_stats() {
        let a = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        let b = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
        assert_eq!(persist::to_json(&a), persist::to_json(&b));
        let c = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 43).unwrap();
        assert_ne!(persist::to_json(&a), persist::to_json(&c), "seed must shuffle message order");
        // ...but the regime statistics are order-invariant
        let (sa, sc) = (a.epoch_stats(), c.epoch_stats());
        assert_eq!(sa, sc);
    }

    #[test]
    fn amr_drift_crosses_regimes() {
        let t = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 7).unwrap();
        assert_eq!(t.epochs.len(), 5);
        let stats = t.epoch_stats();
        // message count grows 16x while the per-message size shrinks 256x
        assert_eq!(stats[0].total_internode_msgs, 32);
        assert_eq!(stats[4].total_internode_msgs, 512);
        assert_eq!(stats[0].s_n2n / stats[0].m_n2n, 1 << 18);
        assert_eq!(stats[4].s_n2n / stats[4].m_n2n, 1 << 10);
        // every boundary drifts well past the default threshold
        for (k, d) in t.drifts().iter().enumerate().skip(1) {
            assert!(*d > 0.9, "epoch {k} drift {d}");
        }
    }

    #[test]
    fn stationary_never_drifts() {
        let t = synthesize(TraceScenario::Stationary, "lassen", 4, 2, 7).unwrap();
        assert_eq!(t.iterations(), 8);
        assert!(t.drifts().iter().all(|&d| d == 0.0));
    }

    #[test]
    fn halo_burst_alternates() {
        let t = synthesize(TraceScenario::HaloBurst, "lassen", 5, 0, 7).unwrap();
        let tags: Vec<&str> = t.epochs.iter().map(|e| e.tag.as_str()).collect();
        assert_eq!(tags, ["calm", "burst", "calm", "burst", "calm"]);
        assert_eq!(t.epochs[0].repeat, 2);
        assert_eq!(t.epochs[1].repeat, 1);
        let d = t.drifts();
        assert!(d[1] > 3.0 && d[2] > 3.0, "bursts must drift hard: {d:?}");
    }

    #[test]
    fn rebalance_thirds_and_other_machines() {
        let t = synthesize(TraceScenario::Rebalance, "lassen", 3, 0, 7).unwrap();
        let tags: Vec<&str> = t.epochs.iter().map(|e| e.tag.as_str()).collect();
        assert_eq!(tags, ["healthy", "failover", "respread"]);
        // boundaries drift past the default threshold but stay gentle
        let d = t.drifts();
        assert!(d[1] > 0.25 && d[1] < 0.6, "failover drift {}", d[1]);
        assert!(d[2] > 0.25 && d[2] < 0.6, "respread drift {}", d[2]);
        // scenarios synthesize on every registry preset
        for name in machines::NAMES {
            let t = synthesize(TraceScenario::Sparsify, name, 4, 0, 1).unwrap();
            t.validate().unwrap();
        }
    }
}
