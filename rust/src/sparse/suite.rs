//! SuiteSparse structural proxies (Section 5's test matrices).
//!
//! The paper benchmarks the largest SuiteSparse matrices; this offline image
//! has no network, so we generate *structural proxies*: synthetic matrices
//! whose communication-relevant statistics (scaled row count, nnz density,
//! bandwidth / arrowhead / blocky structure) follow the originals. A real
//! `.mtx` file, when present, is loaded instead ([`load_or_proxy`]).
//!
//! Scaling: the originals are O(1M) rows; the proxies default to a
//! `scale` divisor (rows / scale) preserving structure, since the induced
//! *pattern shape* (who talks to whom) is partition-relative.

use super::csr::Csr;
use super::gen;
use crate::util::rng::Rng;

/// Paper-reported structural statistics of one test matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatrixInfo {
    pub name: &'static str,
    /// Rows in the original SuiteSparse matrix.
    pub full_rows: usize,
    /// Nonzeros in the original.
    pub full_nnz: usize,
    /// Structure family used for the proxy.
    pub family: Family,
}

/// Structural family of a proxy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Dense head rows/cols + band (audikw_1).
    Arrow,
    /// Long narrow band (thermal2).
    Banded,
    /// Blocky 3D FEM (Serena, Geo_1438).
    Block3d,
    /// Wide stencil-like mesh (ldoor, bone010).
    Mesh3d,
}

/// The Section 5 matrix set.
pub const MATRICES: [MatrixInfo; 6] = [
    MatrixInfo { name: "audikw_1", full_rows: 943_695, full_nnz: 77_651_847, family: Family::Arrow },
    MatrixInfo { name: "Serena", full_rows: 1_391_349, full_nnz: 64_131_971, family: Family::Block3d },
    MatrixInfo { name: "ldoor", full_rows: 952_203, full_nnz: 42_493_817, family: Family::Mesh3d },
    MatrixInfo { name: "thermal2", full_rows: 1_228_045, full_nnz: 8_580_313, family: Family::Banded },
    MatrixInfo { name: "bone010", full_rows: 986_703, full_nnz: 47_851_783, family: Family::Mesh3d },
    MatrixInfo { name: "Geo_1438", full_rows: 1_437_960, full_nnz: 60_236_322, family: Family::Block3d },
];

/// Look up a matrix by name.
pub fn info(name: &str) -> Option<&'static MatrixInfo> {
    MATRICES.iter().find(|m| m.name == name)
}

/// Generate the structural proxy at `rows ≈ full_rows / scale`.
///
/// Deterministic per (name, scale).
pub fn proxy(m: &MatrixInfo, scale: usize) -> Csr {
    assert!(scale >= 1);
    let n = (m.full_rows / scale).max(256);
    let avg_row = (m.full_nnz as f64 / m.full_rows as f64).round() as usize;
    let mut rng = Rng::new(seed_of(m.name));
    match m.family {
        Family::Arrow => {
            // heavy first ~1% rows/cols + band holding most of the nnz
            let head = (n / 100).max(8);
            let band = (avg_row / 2).max(2);
            gen::arrow(n, head, band, &mut rng)
        }
        Family::Banded => {
            let band = (avg_row).max(2);
            gen::banded(n, band, &mut rng)
        }
        Family::Block3d => {
            let bs = 32;
            let nb = (n / bs).max(4);
            // fill tuned to land near the original density
            let fill = (avg_row as f64 / (3.0 * bs as f64)).min(0.9);
            gen::random_block(nb, bs, 0.25, fill, &mut rng)
        }
        Family::Mesh3d => {
            // 27-point stencil on a cube of matching size
            let side = (n as f64).cbrt().round() as usize;
            gen::stencil_27pt(side.max(4), side.max(4), side.max(4))
        }
    }
}

/// Load the real `.mtx` from `dir` when present, otherwise build the proxy.
pub fn load_or_proxy(m: &MatrixInfo, dir: &std::path::Path, scale: usize) -> Csr {
    let path = dir.join(format!("{}.mtx", m.name));
    if path.exists() {
        match super::mm::read(&path) {
            Ok(a) => return a,
            Err(e) => {
                crate::log_warn!("failed to read {}: {e}; falling back to proxy", path.display());
            }
        }
    }
    proxy(m, scale)
}

fn seed_of(name: &str) -> u64 {
    // FNV-1a for deterministic per-name seeds.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_matrices_proxy_build() {
        for m in &MATRICES {
            let a = proxy(m, 64);
            assert!(a.nrows >= 256, "{}: rows {}", m.name, a.nrows);
            assert_eq!(a.nrows, a.ncols);
            assert!(a.nnz() > a.nrows, "{}: too sparse", m.name);
        }
    }

    #[test]
    fn audikw_proxy_has_heavy_head() {
        let m = info("audikw_1").unwrap();
        let a = proxy(m, 64);
        let head = a.nrows / 100;
        let head_nnz: usize = (0..head).map(|r| a.row(r).0.len()).sum();
        let tail_nnz: usize = (a.nrows - head..a.nrows).map(|r| a.row(r).0.len()).sum();
        assert!(head_nnz > 3 * tail_nnz, "head {head_nnz} vs tail {tail_nnz}");
    }

    #[test]
    fn thermal2_proxy_low_density() {
        // thermal2 is an order of magnitude sparser than audikw_1.
        let t = proxy(info("thermal2").unwrap(), 64);
        let a = proxy(info("audikw_1").unwrap(), 64);
        let t_avg = t.nnz() as f64 / t.nrows as f64;
        let a_avg = a.nnz() as f64 / a.nrows as f64;
        assert!(t_avg < a_avg, "thermal2 avg row {t_avg} !< audikw {a_avg}");
    }

    #[test]
    fn proxies_deterministic() {
        let m = info("Serena").unwrap();
        assert_eq!(proxy(m, 128), proxy(m, 128));
    }

    #[test]
    fn info_lookup() {
        assert!(info("audikw_1").is_some());
        assert!(info("bogus").is_none());
        assert_eq!(MATRICES.len(), 6);
    }

    #[test]
    fn load_or_proxy_falls_back() {
        let m = info("ldoor").unwrap();
        let a = load_or_proxy(m, std::path::Path::new("/nonexistent"), 128);
        assert!(a.nrows > 0);
    }
}
