//! Compressed sparse row (CSR) matrices, the serial SpMV oracle, and the
//! padded ELL format consumed by the Pallas kernel (L1).

/// CSR sparse matrix over f32 (the GPU-side value type).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Row pointers, `len == nrows + 1`.
    pub rowptr: Vec<usize>,
    /// Column indices, in-row order not required but generators sort them.
    pub colidx: Vec<usize>,
    pub values: Vec<f32>,
}

/// ELLPACK (padded) matrix: every row stores exactly `width` entries;
/// padding uses column 0 with value 0.0. This is the TPU-friendly layout —
/// fixed row width turns the irregular CSR loop into dense (rows × width)
/// blocks (see DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    pub nrows: usize,
    pub ncols: usize,
    pub width: usize,
    /// Row-major `(nrows, width)` column indices (padded with 0).
    pub cols: Vec<i32>,
    /// Row-major `(nrows, width)` values (padded with 0.0).
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build from triplets; duplicates are summed, rows sorted by column.
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(usize, usize, f32)]) -> Csr {
        for &(r, c, _) in triplets {
            assert!(r < nrows && c < ncols, "triplet ({r},{c}) out of bounds {nrows}x{ncols}");
        }
        let mut per_row: Vec<Vec<(usize, f32)>> = vec![Vec::new(); nrows];
        for &(r, c, v) in triplets {
            per_row[r].push((c, v));
        }
        let mut rowptr = Vec::with_capacity(nrows + 1);
        let mut colidx = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        rowptr.push(0);
        for row in &mut per_row {
            row.sort_by_key(|&(c, _)| c);
            // merge duplicates
            let mut merged: Vec<(usize, f32)> = Vec::with_capacity(row.len());
            for &(c, v) in row.iter() {
                match merged.last_mut() {
                    Some(last) if last.0 == c => last.1 += v,
                    _ => merged.push((c, v)),
                }
            }
            for (c, v) in merged {
                colidx.push(c);
                values.push(v);
            }
            rowptr.push(colidx.len());
        }
        Csr { nrows, ncols, rowptr, colidx, values }
    }

    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Non-zero density `nnz / (nrows * ncols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// Entries of one row as (col, value) slices.
    pub fn row(&self, r: usize) -> (&[usize], &[f32]) {
        let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
        (&self.colidx[a..b], &self.values[a..b])
    }

    /// Maximum row population (the natural ELL width).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows).map(|r| self.rowptr[r + 1] - self.rowptr[r]).max().unwrap_or(0)
    }

    /// Serial SpMV oracle: `w = A · v` in f64 accumulation (the correctness
    /// reference for every distributed run).
    pub fn spmv(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.ncols, "SpMV dimension mismatch");
        let mut w = vec![0f32; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut acc = 0f64;
            for (&c, &a) in cols.iter().zip(vals) {
                acc += a as f64 * v[c] as f64;
            }
            w[r] = acc as f32;
        }
        w
    }

    /// Convert to ELL with the given width (>= max_row_nnz). Rows with
    /// fewer entries are padded with (col 0, 0.0).
    pub fn to_ell(&self, width: usize) -> Ell {
        assert!(width >= self.max_row_nnz(), "ELL width {width} < max row nnz {}", self.max_row_nnz());
        let mut cols = vec![0i32; self.nrows * width];
        let mut vals = vec![0f32; self.nrows * width];
        for r in 0..self.nrows {
            let (rc, rv) = self.row(r);
            for (k, (&c, &v)) in rc.iter().zip(rv).enumerate() {
                cols[r * width + k] = c as i32;
                vals[r * width + k] = v;
            }
        }
        Ell { nrows: self.nrows, ncols: self.ncols, width, cols, vals }
    }

    /// Extract the sub-matrix of rows `[r0, r1)` restricted to columns in
    /// `[c0, c1)`, with column indices rebased to the slice (the "diag
    /// block" extraction of Section 2.4.1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.nrows && c0 <= c1 && c1 <= self.ncols);
        let mut rowptr = Vec::with_capacity(r1 - r0 + 1);
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        rowptr.push(0);
        for r in r0..r1 {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if c >= c0 && c < c1 {
                    colidx.push(c - c0);
                    values.push(v);
                }
            }
            rowptr.push(colidx.len());
        }
        Csr { nrows: r1 - r0, ncols: c1 - c0, rowptr, colidx, values }
    }

    /// Extract rows `[r0, r1)` keeping only columns *outside* `[c0, c1)`,
    /// remapped through a gather list: returns (matrix over gathered
    /// columns, sorted global column ids) — the "offd block + halo indices"
    /// of Section 2.4.1.
    pub fn offd_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> (Csr, Vec<usize>) {
        let mut needed: Vec<usize> = Vec::new();
        for r in r0..r1 {
            let (cols, _) = self.row(r);
            for &c in cols {
                if c < c0 || c >= c1 {
                    needed.push(c);
                }
            }
        }
        needed.sort_unstable();
        needed.dedup();
        let lookup: std::collections::BTreeMap<usize, usize> =
            needed.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        let mut rowptr = Vec::with_capacity(r1 - r0 + 1);
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        rowptr.push(0);
        for r in r0..r1 {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if c < c0 || c >= c1 {
                    colidx.push(lookup[&c]);
                    values.push(v);
                }
            }
            rowptr.push(colidx.len());
        }
        (Csr { nrows: r1 - r0, ncols: needed.len().max(1), rowptr, colidx, values }, needed)
    }

    /// Structural transpose pattern: for each column, which rows touch it.
    pub fn column_rows(&self) -> Vec<Vec<usize>> {
        let mut by_col: Vec<Vec<usize>> = vec![Vec::new(); self.ncols];
        for r in 0..self.nrows {
            let (cols, _) = self.row(r);
            for &c in cols {
                by_col[c].push(r);
            }
        }
        by_col
    }
}

impl Ell {
    /// Dense-logic SpMV over the padded layout (mirrors the Pallas kernel's
    /// arithmetic exactly, including reading v[0] for padded slots times
    /// 0.0).
    pub fn spmv(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.ncols);
        let mut w = vec![0f32; self.nrows];
        for r in 0..self.nrows {
            let mut acc = 0f32;
            for k in 0..self.width {
                let c = self.cols[r * self.width + k] as usize;
                acc += self.vals[r * self.width + k] * v[c];
            }
            w[r] = acc;
        }
        w
    }

    /// Fraction of stored slots that are padding.
    pub fn padding_fraction(&self) -> f64 {
        let nnz: usize = self.vals.iter().filter(|&&v| v != 0.0).count();
        1.0 - nnz as f64 / (self.nrows * self.width).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [ 2 0 1 ]
        // [ 0 3 0 ]
        // [ 4 0 5 ]
        Csr::from_triplets(3, 3, &[(0, 0, 2.0), (0, 2, 1.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)])
    }

    #[test]
    fn from_triplets_sorted_rows() {
        let a = Csr::from_triplets(2, 3, &[(0, 2, 1.0), (0, 0, 2.0), (1, 1, 3.0)]);
        assert_eq!(a.row(0).0, &[0, 2]);
        assert_eq!(a.row(0).1, &[2.0, 1.0]);
    }

    #[test]
    fn duplicates_summed() {
        let a = Csr::from_triplets(1, 2, &[(0, 1, 1.5), (0, 1, 2.5)]);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.row(0).1, &[4.0]);
    }

    #[test]
    fn spmv_oracle() {
        let a = small();
        let w = a.spmv(&[1.0, 2.0, 3.0]);
        assert_eq!(w, vec![5.0, 6.0, 19.0]);
    }

    #[test]
    fn ell_matches_csr() {
        let a = small();
        let e = a.to_ell(a.max_row_nnz());
        let v = [1.0f32, 2.0, 3.0];
        assert_eq!(a.spmv(&v), e.spmv(&v));
        assert_eq!(e.width, 2);
    }

    #[test]
    fn ell_padding_fraction() {
        let a = small();
        let e = a.to_ell(4);
        // 5 nnz of 12 slots
        assert!((e.padding_fraction() - (1.0 - 5.0 / 12.0)).abs() < 1e-12);
    }

    #[test]
    fn slice_diag_block() {
        let a = small();
        let d = a.slice(1, 3, 1, 3);
        assert_eq!(d.nrows, 2);
        assert_eq!(d.ncols, 2);
        // row 1 restricted to cols1-2: entry (1,1)=3 -> local (0,0)
        assert_eq!(d.row(0).0, &[0]);
        assert_eq!(d.row(0).1, &[3.0]);
        // row 2: (2,2)=5 -> local (1,1)
        assert_eq!(d.row(1).0, &[1]);
    }

    #[test]
    fn offd_block_and_halo() {
        let a = small();
        // rows 1..3, owned cols 1..3: offd entries are col 0 (rows 2)
        let (o, halo) = a.offd_block(1, 3, 1, 3);
        assert_eq!(halo, vec![0]);
        assert_eq!(o.nrows, 2);
        assert_eq!(o.row(0).0.len(), 0);
        assert_eq!(o.row(1).0, &[0]);
        assert_eq!(o.row(1).1, &[4.0]);
    }

    #[test]
    fn diag_offd_recompose_spmv() {
        // diag·v_local + offd·v_halo == full SpMV on the row slice.
        let a = small();
        let v = [1.0f32, 2.0, 3.0];
        let full = a.spmv(&v);
        let d = a.slice(1, 3, 1, 3);
        let (o, halo) = a.offd_block(1, 3, 1, 3);
        let v_local = &v[1..3];
        let v_halo: Vec<f32> = halo.iter().map(|&c| v[c]).collect();
        let wd = d.spmv(v_local);
        let wo = o.spmv(if v_halo.is_empty() { &[0.0] } else { &v_halo });
        let combined: Vec<f32> = wd.iter().zip(&wo).map(|(a, b)| a + b).collect();
        assert_eq!(combined, full[1..3]);
    }

    #[test]
    fn column_rows_transpose() {
        let a = small();
        let cr = a.column_rows();
        assert_eq!(cr[0], vec![0, 2]);
        assert_eq!(cr[1], vec![1]);
        assert_eq!(cr[2], vec![0, 2]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn triplet_bounds_checked() {
        Csr::from_triplets(2, 2, &[(2, 0, 1.0)]);
    }
}
