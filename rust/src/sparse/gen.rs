//! Structured sparse-matrix generators: stencils, banded, arrow and
//! random-block matrices — the synthetic building blocks behind the
//! SuiteSparse structural proxies in [`super::suite`].

use super::csr::Csr;
use crate::util::rng::Rng;

/// 2D 5-point Laplacian stencil on an `nx × ny` grid.
pub fn stencil_5pt(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut t = Vec::with_capacity(5 * n);
    for j in 0..ny {
        for i in 0..nx {
            let r = j * nx + i;
            t.push((r, r, 4.0f32));
            if i > 0 {
                t.push((r, r - 1, -1.0));
            }
            if i + 1 < nx {
                t.push((r, r + 1, -1.0));
            }
            if j > 0 {
                t.push((r, r - nx, -1.0));
            }
            if j + 1 < ny {
                t.push((r, r + nx, -1.0));
            }
        }
    }
    Csr::from_triplets(n, n, &t)
}

/// 3D 27-point stencil on an `nx × ny × nz` grid (the paper's
/// unstructured-mesh-like communication pattern; heavier halos than 7-pt).
pub fn stencil_27pt(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let idx = |i: usize, j: usize, k: usize| (k * ny + j) * nx + i;
    let mut t = Vec::with_capacity(27 * n);
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                let r = idx(i, j, k);
                for dk in -1i64..=1 {
                    for dj in -1i64..=1 {
                        for di in -1i64..=1 {
                            let (ii, jj, kk) = (i as i64 + di, j as i64 + dj, k as i64 + dk);
                            if ii < 0 || jj < 0 || kk < 0 || ii >= nx as i64 || jj >= ny as i64 || kk >= nz as i64 {
                                continue;
                            }
                            let c = idx(ii as usize, jj as usize, kk as usize);
                            let v = if c == r { 26.0 } else { -1.0 };
                            t.push((r, c, v));
                        }
                    }
                }
            }
        }
    }
    Csr::from_triplets(n, n, &t)
}

/// Banded matrix: `band` off-diagonals on each side with deterministic
/// pseudo-random values (thermal2-like long thin band structure).
pub fn banded(n: usize, band: usize, rng: &mut Rng) -> Csr {
    let mut t = Vec::new();
    for r in 0..n {
        t.push((r, r, 2.0 + rng.f64() as f32));
        let lo = r.saturating_sub(band);
        let hi = (r + band + 1).min(n);
        for c in lo..hi {
            if c != r && rng.bool(0.6) {
                t.push((r, c, -(rng.f64() as f32) - 0.1));
            }
        }
    }
    Csr::from_triplets(n, n, &t)
}

/// Arrow matrix: dense band plus heavy first `head` rows *and* columns —
/// the audikw_1-like structure ("high number of nonzero entries in the top
/// rows and first columns", Section 4.5) that generates worst-case on-node
/// and inter-node communication.
pub fn arrow(n: usize, head: usize, band: usize, rng: &mut Rng) -> Csr {
    assert!(head < n);
    let mut t = Vec::new();
    for r in 0..n {
        t.push((r, r, 4.0f32));
        // local band
        let lo = r.saturating_sub(band);
        let hi = (r + band + 1).min(n);
        for c in lo..hi {
            if c != r && rng.bool(0.5) {
                t.push((r, c, -0.5));
            }
        }
        // arrow head: couplings to the first `head` rows/cols
        if r >= head {
            for h in 0..head {
                if rng.bool(0.4) {
                    t.push((r, h, -0.25));
                    t.push((h, r, -0.25));
                }
            }
        }
    }
    Csr::from_triplets(n, n, &t)
}

/// Block-random matrix: `nb × nb` blocks of size `bs`, each nonzero with
/// probability `block_p`, filled at `fill` density (Serena/Geo-like blocky
/// structure from 3D FEM meshes).
pub fn random_block(nb: usize, bs: usize, block_p: f64, fill: f64, rng: &mut Rng) -> Csr {
    let n = nb * bs;
    let mut t = Vec::new();
    for bi in 0..nb {
        for bj in 0..nb {
            let coupled = bi == bj || rng.bool(block_p * decay(bi, bj));
            if !coupled {
                continue;
            }
            for i in 0..bs {
                let r = bi * bs + i;
                for j in 0..bs {
                    let c = bj * bs + j;
                    if r == c {
                        t.push((r, c, 4.0));
                    } else if rng.bool(fill) {
                        t.push((r, c, -0.1 - rng.f64() as f32));
                    }
                }
            }
        }
    }
    Csr::from_triplets(n, n, &t)
}

/// Coupling probability decays with block distance (meshes are local).
fn decay(bi: usize, bj: usize) -> f64 {
    let d = bi.abs_diff(bj) as f64;
    1.0 / (1.0 + d * d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stencil5_shape_and_symmetry() {
        let a = stencil_5pt(4, 3);
        assert_eq!(a.nrows, 12);
        // interior point has 5 entries
        let (cols, _) = a.row(5); // (1,1)
        assert_eq!(cols.len(), 5);
        // corner has 3
        assert_eq!(a.row(0).0.len(), 3);
        // row sums: 4 - (#neighbors) >= 0
        for r in 0..a.nrows {
            let s: f32 = a.row(r).1.iter().sum();
            assert!(s >= 0.0);
        }
    }

    #[test]
    fn stencil27_interior_degree() {
        let a = stencil_27pt(4, 4, 4);
        assert_eq!(a.nrows, 64);
        // interior point (1,1,1) -> 27 entries
        let r = (1 * 4 + 1) * 4 + 1;
        assert_eq!(a.row(r).0.len(), 27);
        // corner -> 8
        assert_eq!(a.row(0).0.len(), 8);
    }

    #[test]
    fn banded_within_band() {
        let mut rng = Rng::new(3);
        let a = banded(100, 5, &mut rng);
        for r in 0..a.nrows {
            for &c in a.row(r).0 {
                assert!(c.abs_diff(r) <= 5);
            }
        }
    }

    #[test]
    fn arrow_head_rows_heavy() {
        let mut rng = Rng::new(5);
        let a = arrow(500, 20, 3, &mut rng);
        let head_avg: f64 = (0..20).map(|r| a.row(r).0.len()).sum::<usize>() as f64 / 20.0;
        let tail_avg: f64 = (400..500).map(|r| a.row(r).0.len()).sum::<usize>() as f64 / 100.0;
        assert!(head_avg > 3.0 * tail_avg, "head {head_avg} vs tail {tail_avg}");
    }

    #[test]
    fn random_block_diagonal_present() {
        let mut rng = Rng::new(7);
        let a = random_block(8, 16, 0.3, 0.2, &mut rng);
        assert_eq!(a.nrows, 128);
        for r in 0..a.nrows {
            let (cols, vals) = a.row(r);
            let pos = cols.iter().position(|&c| c == r).expect("diagonal");
            assert_eq!(vals[pos], 4.0);
        }
    }

    #[test]
    fn generators_deterministic() {
        let a1 = banded(50, 3, &mut Rng::new(11));
        let a2 = banded(50, 3, &mut Rng::new(11));
        assert_eq!(a1, a2);
    }
}
