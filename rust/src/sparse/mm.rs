//! Matrix Market I/O — reads the real SuiteSparse files when available
//! (coordinate format, general/symmetric, real/integer/pattern) and writes
//! matrices back out for inspection.

use super::csr::Csr;
use std::io::{BufRead, Write};
use std::path::Path;

/// Matrix Market errors.
#[derive(Debug, thiserror::Error)]
pub enum MmError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("unsupported format: {0}")]
    Unsupported(String),
}

/// Read a Matrix Market coordinate file into CSR.
pub fn read(path: impl AsRef<Path>) -> Result<Csr, MmError> {
    let file = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(file))
}

/// Read from any buffered reader (testable without files).
pub fn read_from<R: BufRead>(reader: R) -> Result<Csr, MmError> {
    let mut lines = reader.lines().enumerate();

    // Header: %%MatrixMarket matrix coordinate <field> <symmetry>
    let (_, header) = lines.next().ok_or(MmError::Parse { line: 1, msg: "empty file".into() })?;
    let header = header?;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_lowercase()).collect();
    if h.len() < 5 || !h[0].starts_with("%%matrixmarket") || h[1] != "matrix" {
        return Err(MmError::Parse { line: 1, msg: format!("bad header {header:?}") });
    }
    if h[2] != "coordinate" {
        return Err(MmError::Unsupported(format!("format {} (only coordinate)", h[2])));
    }
    let field = h[3].clone();
    if !matches!(field.as_str(), "real" | "integer" | "pattern") {
        return Err(MmError::Unsupported(format!("field {field}")));
    }
    let symmetry = h[4].clone();
    if !matches!(symmetry.as_str(), "general" | "symmetric") {
        return Err(MmError::Unsupported(format!("symmetry {symmetry}")));
    }

    // Size line (skipping comments).
    let mut size: Option<(usize, usize, usize)> = None;
    let mut triplets: Vec<(usize, usize, f32)> = Vec::new();
    for (i, line) in lines {
        let line = line?;
        let lineno = i + 1;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = trimmed.split_whitespace().collect();
        match size {
            None => {
                if toks.len() != 3 {
                    return Err(MmError::Parse { line: lineno, msg: format!("bad size line {trimmed:?}") });
                }
                let parse = |t: &str| -> Result<usize, MmError> {
                    t.parse().map_err(|_| MmError::Parse { line: lineno, msg: format!("bad size {t:?}") })
                };
                size = Some((parse(toks[0])?, parse(toks[1])?, parse(toks[2])?));
                triplets.reserve(size.unwrap().2);
            }
            Some((nrows, ncols, _)) => {
                if toks.len() < 2 {
                    return Err(MmError::Parse { line: lineno, msg: format!("bad entry {trimmed:?}") });
                }
                let r: usize = toks[0]
                    .parse::<usize>()
                    .map_err(|_| MmError::Parse { line: lineno, msg: format!("bad row {:?}", toks[0]) })?;
                let c: usize = toks[1]
                    .parse::<usize>()
                    .map_err(|_| MmError::Parse { line: lineno, msg: format!("bad col {:?}", toks[1]) })?;
                if r == 0 || c == 0 || r > nrows || c > ncols {
                    return Err(MmError::Parse { line: lineno, msg: format!("entry ({r},{c}) out of bounds") });
                }
                let v: f32 = if field == "pattern" {
                    1.0
                } else {
                    toks.get(2)
                        .ok_or(MmError::Parse { line: lineno, msg: "missing value".into() })?
                        .parse()
                        .map_err(|_| MmError::Parse { line: lineno, msg: format!("bad value {:?}", toks[2]) })?
                };
                triplets.push((r - 1, c - 1, v));
                if symmetry == "symmetric" && r != c {
                    triplets.push((c - 1, r - 1, v));
                }
            }
        }
    }
    let (nrows, ncols, _) = size.ok_or(MmError::Parse { line: 0, msg: "missing size line".into() })?;
    Ok(Csr::from_triplets(nrows, ncols, &triplets))
}

/// Write a CSR matrix as Matrix Market coordinate/real/general.
pub fn write(path: impl AsRef<Path>, a: &Csr) -> Result<(), MmError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by hetcomm")?;
    writeln!(f, "{} {} {}", a.nrows, a.ncols, a.nnz())?;
    for r in 0..a.nrows {
        let (cols, vals) = a.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            writeln!(f, "{} {} {}", r + 1, c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn read_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 3\n1 1 2.0\n2 2 3.0\n3 1 4.5\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nrows, 3);
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.row(2).1, &[4.5]);
    }

    #[test]
    fn read_symmetric_expands() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n2 1 5.0\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nnz(), 3); // (0,0), (1,0), (0,1)
        assert_eq!(a.row(0).0, &[0, 1]);
    }

    #[test]
    fn read_pattern_ones() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.row(0).1, &[1.0]);
    }

    #[test]
    fn rejects_array_format() {
        let text = "%%MatrixMarket matrix array real general\n2 2\n1.0\n";
        assert!(matches!(read_from(Cursor::new(text)), Err(MmError::Unsupported(_))));
    }

    #[test]
    fn rejects_out_of_bounds() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(matches!(read_from(Cursor::new(text)), Err(MmError::Parse { .. })));
    }

    #[test]
    fn one_based_indexing() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(read_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let a = crate::sparse::gen::stencil_5pt(5, 5);
        let path = std::env::temp_dir().join("hetcomm_mm_roundtrip.mtx");
        write(&path, &a).unwrap();
        let b = read(&path).unwrap();
        assert_eq!(a, b);
        let _ = std::fs::remove_file(&path);
    }
}
