//! Sparse-matrix substrate: CSR/ELL storage, Matrix Market I/O, structured
//! generators, SuiteSparse structural proxies, and the row-wise partitioner
//! that induces the distributed-SpMV communication patterns (Section 2.4).

pub mod csr;
pub mod gen;
pub mod mm;
pub mod partition;
pub mod suite;

pub use csr::{Csr, Ell};
pub use partition::{PartitionedMatrix, Partition};
