//! Row-wise partitioning of a sparse matrix across GPUs and the induced
//! halo-exchange communication pattern (Section 2.4.1, Figure 2.8).
//!
//! Rows (and the matching vector entries) are distributed in contiguous
//! blocks. Each part's rows split into the **diag block** (columns owned by
//! the part) and the **offd block** (columns owned elsewhere); the offd
//! column set is the part's *halo* — the vector values that must be
//! communicated before the local SpMV can complete.
//!
//! [`PartitionedMatrix::comm_pattern`] converts the halo requirements into a
//! [`CommPattern`], with exact duplicate-data classes: source values needed
//! by several GPUs on one node share a `dup_group`, so node-aware schedules
//! ship them across the network once (Section 2.3).

use super::csr::Csr;
use crate::pattern::{CommPattern, Msg};
use crate::topology::{GpuId, Machine};
use std::collections::BTreeMap;

/// Contiguous row partition over `nparts` parts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub n: usize,
    pub offsets: Vec<usize>,
}

impl Partition {
    /// Balanced contiguous partition: first `n % nparts` parts get one
    /// extra row.
    pub fn balanced(n: usize, nparts: usize) -> Partition {
        assert!(nparts > 0 && n >= nparts, "cannot split {n} rows into {nparts} parts");
        let base = n / nparts;
        let extra = n % nparts;
        let mut offsets = Vec::with_capacity(nparts + 1);
        let mut acc = 0;
        offsets.push(0);
        for p in 0..nparts {
            acc += base + usize::from(p < extra);
            offsets.push(acc);
        }
        Partition { n, offsets }
    }

    pub fn nparts(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Row range `[start, end)` of part `p`.
    pub fn range(&self, p: usize) -> (usize, usize) {
        (self.offsets[p], self.offsets[p + 1])
    }

    pub fn size(&self, p: usize) -> usize {
        self.offsets[p + 1] - self.offsets[p]
    }

    /// Largest part size (the static shape the AOT kernel is padded to).
    pub fn max_size(&self) -> usize {
        (0..self.nparts()).map(|p| self.size(p)).max().unwrap_or(0)
    }

    /// Owning part of a row (binary search).
    pub fn owner(&self, row: usize) -> usize {
        assert!(row < self.n, "row {row} out of range {}", self.n);
        match self.offsets.binary_search(&row) {
            Ok(p) if p < self.nparts() => p,
            Ok(p) => p - 1, // row == n boundary can't happen (asserted), p == nparts means last offset
            Err(p) => p - 1,
        }
    }
}

/// One part's local view: diag/offd blocks plus halo metadata.
#[derive(Clone, Debug)]
pub struct PartBlocks {
    /// Diagonal block over owned columns (local indices).
    pub diag: Csr,
    /// Off-diagonal block over gathered halo columns (ghost indices).
    pub offd: Csr,
    /// Sorted global column ids backing the ghost indices.
    pub halo: Vec<usize>,
    /// Receive lists: owner part → global indices (sorted; ghost position =
    /// index into `halo`).
    pub recv_from: BTreeMap<usize, Vec<usize>>,
}

/// A matrix partitioned row-wise across `nparts` GPUs.
#[derive(Clone, Debug)]
pub struct PartitionedMatrix {
    pub partition: Partition,
    pub parts: Vec<PartBlocks>,
    /// Send lists: for each part, destination part → *local* row indices of
    /// the owned vector entries to ship.
    pub send_to: Vec<BTreeMap<usize, Vec<usize>>>,
}

impl PartitionedMatrix {
    /// Partition `a` into `nparts` contiguous row blocks.
    pub fn build(a: &Csr, nparts: usize) -> PartitionedMatrix {
        assert_eq!(a.nrows, a.ncols, "SpMV partitioning expects a square matrix");
        let partition = Partition::balanced(a.nrows, nparts);
        let mut parts = Vec::with_capacity(nparts);
        let mut send_to: Vec<BTreeMap<usize, Vec<usize>>> = vec![BTreeMap::new(); nparts];

        for p in 0..nparts {
            let (r0, r1) = partition.range(p);
            let diag = a.slice(r0, r1, r0, r1);
            let (offd, halo) = a.offd_block(r0, r1, r0, r1);
            let mut recv_from: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for &col in &halo {
                let owner = partition.owner(col);
                recv_from.entry(owner).or_default().push(col);
            }
            for (&owner, cols) in &recv_from {
                let (o0, _) = partition.range(owner);
                send_to[owner].entry(p).or_default().extend(cols.iter().map(|&c| c - o0));
            }
            parts.push(PartBlocks { diag, offd, halo, recv_from });
        }

        PartitionedMatrix { partition, parts, send_to }
    }

    /// The induced halo-exchange communication pattern. `elem_size` is the
    /// per-value payload in bytes (8 for double-precision vectors, as in the
    /// paper's benchmarks). Duplicate classes are exact: for each
    /// (source GPU, destination node), halo values requested by multiple
    /// GPUs share a `dup_group`.
    pub fn comm_pattern(&self, machine: &Machine, elem_size: usize) -> CommPattern {
        assert!(self.partition.nparts() <= machine.total_gpus(), "partition has more parts than machine GPUs");
        let nparts = self.partition.nparts();
        let mut msgs = Vec::new();
        let mut next_group: u32 = 0;

        // For each source part: destination parts grouped by node, then
        // indices grouped by requester set.
        for src in 0..nparts {
            let mut by_node: BTreeMap<usize, Vec<usize>> = BTreeMap::new(); // node -> dst parts
            for &dst in self.send_to[src].keys() {
                by_node.entry(machine.gpu_node(GpuId(dst)).0).or_default().push(dst);
            }
            for (_node, dsts) in by_node {
                if dsts.len() == 1 {
                    let dst = dsts[0];
                    let count = self.send_to[src][&dst].len();
                    if count > 0 {
                        msgs.push(Msg::new(GpuId(src), GpuId(dst), count * elem_size));
                    }
                    continue;
                }
                // Requester-set classes over this node's destinations.
                let mut class_of: BTreeMap<usize, u64> = BTreeMap::new(); // local idx -> bitmask over dsts
                for (bit, &dst) in dsts.iter().enumerate() {
                    for &li in &self.send_to[src][&dst] {
                        *class_of.entry(li).or_default() |= 1 << bit;
                    }
                }
                let mut class_counts: BTreeMap<u64, usize> = BTreeMap::new();
                for &mask in class_of.values() {
                    *class_counts.entry(mask).or_default() += 1;
                }
                for (mask, count) in class_counts {
                    let bytes = count * elem_size;
                    let requesters: Vec<usize> =
                        dsts.iter().enumerate().filter(|(b, _)| mask & (1 << b) != 0).map(|(_, &d)| d).collect();
                    let group = if requesters.len() > 1 {
                        let g = next_group;
                        next_group += 1;
                        g
                    } else {
                        Msg::NO_DUP
                    };
                    for dst in requesters {
                        msgs.push(Msg { src: GpuId(src), dst: GpuId(dst), bytes, dup_group: group });
                    }
                }
            }
        }
        CommPattern::new(msgs)
    }

    /// Distributed SpMV against the serial oracle, executed part by part —
    /// validates that diag/offd splitting plus halo exchange reproduces the
    /// full product. (The runtime coordinator does the same thing across
    /// worker threads with PJRT executables.)
    pub fn spmv(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.partition.n);
        let mut w = Vec::with_capacity(self.partition.n);
        for p in 0..self.partition.nparts() {
            let (r0, r1) = self.partition.range(p);
            let blocks = &self.parts[p];
            let v_local = &v[r0..r1];
            let v_halo: Vec<f32> = blocks.halo.iter().map(|&c| v[c]).collect();
            let mut wp = blocks.diag.spmv(v_local);
            if !blocks.halo.is_empty() {
                let wo = blocks.offd.spmv(&v_halo);
                for (a, b) in wp.iter_mut().zip(&wo) {
                    *a += b;
                }
            }
            w.extend(wp);
        }
        w
    }

    /// Total halo values communicated (sum over parts of halo sizes).
    pub fn total_halo(&self) -> usize {
        self.parts.iter().map(|p| p.halo.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::topology::machines::lassen;
    use crate::util::rng::Rng;

    #[test]
    fn balanced_partition_covers() {
        let p = Partition::balanced(10, 3);
        assert_eq!(p.offsets, vec![0, 4, 7, 10]);
        assert_eq!(p.size(0), 4);
        assert_eq!(p.max_size(), 4);
        for row in 0..10 {
            let o = p.owner(row);
            let (a, b) = p.range(o);
            assert!(row >= a && row < b, "row {row} owner {o}");
        }
    }

    #[test]
    fn owner_at_boundaries() {
        let p = Partition::balanced(12, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(2), 0);
        assert_eq!(p.owner(3), 1);
        assert_eq!(p.owner(11), 3);
    }

    #[test]
    fn partitioned_spmv_matches_oracle() {
        let a = gen::stencil_5pt(8, 8);
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..64).map(|_| rng.f64() as f32).collect();
        let expect = a.spmv(&v);
        for nparts in [1, 2, 4, 8] {
            let pm = PartitionedMatrix::build(&a, nparts);
            let got = pm.spmv(&v);
            for (x, y) in expect.iter().zip(&got) {
                assert!((x - y).abs() < 1e-4, "nparts {nparts}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn send_recv_lists_consistent() {
        let a = gen::stencil_27pt(4, 4, 4);
        let pm = PartitionedMatrix::build(&a, 4);
        for p in 0..4 {
            for (&owner, cols) in &pm.parts[p].recv_from {
                let (o0, _) = pm.partition.range(owner);
                let sends = &pm.send_to[owner][&p];
                assert_eq!(sends.len(), cols.len());
                for (&g, &l) in cols.iter().zip(sends) {
                    assert_eq!(g, o0 + l, "global/local index mismatch");
                }
            }
        }
    }

    #[test]
    fn halo_sorted_dedup() {
        let a = gen::stencil_5pt(6, 6);
        let pm = PartitionedMatrix::build(&a, 3);
        for part in &pm.parts {
            assert!(part.halo.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn comm_pattern_bytes_match_halo() {
        let a = gen::stencil_5pt(10, 10);
        let machine = lassen(1);
        let pm = PartitionedMatrix::build(&a, 4);
        let pat = pm.comm_pattern(&machine, 8);
        // Total *delivered* bytes must equal total halo values × 8.
        assert_eq!(pat.total_bytes(), pm.total_halo() * 8);
    }

    #[test]
    fn comm_pattern_dup_classes() {
        // A column needed by two parts on the same node gets a dup group.
        let machine = lassen(1); // all 4 GPUs on one node
        // Matrix where column 0 is needed by every row (arrow-like).
        let mut t = vec![(0usize, 0usize, 2.0f32)];
        for r in 1..8 {
            t.push((r, r, 2.0));
            t.push((r, 0, 1.0));
        }
        let a = Csr::from_triplets(8, 8, &t);
        let pm = PartitionedMatrix::build(&a, 4);
        let pat = pm.comm_pattern(&machine, 8);
        // parts 1,2,3 need col 0 from part 0; same node -> one dup class
        let dup_msgs: Vec<_> = pat.msgs.iter().filter(|m| m.dup_group != Msg::NO_DUP).collect();
        assert_eq!(dup_msgs.len(), 3);
        assert!(dup_msgs.iter().all(|m| m.dup_group == dup_msgs[0].dup_group));
        assert!(pat.duplicate_fraction(&machine) == 0.0, "intra-node messages carry no network duplicates");
    }

    #[test]
    fn comm_pattern_dup_across_nodes_split() {
        // Same requirement spread over 2 nodes: classes are per node.
        let machine = lassen(2); // parts 0-3 node0, 4-7 node1
        let mut t = vec![(0usize, 0usize, 2.0f32)];
        for r in 1..16 {
            t.push((r, r, 2.0));
            t.push((r, 0, 1.0));
        }
        let a = Csr::from_triplets(16, 16, &t);
        let pm = PartitionedMatrix::build(&a, 8);
        let pat = pm.comm_pattern(&machine, 8);
        let f = pat.duplicate_fraction(&machine);
        // node1 has 4 requesters of col 0 from part 0: 3 of 4 inter-node
        // messages are redundant.
        assert!((f - 0.75).abs() < 1e-12, "got {f}");
    }

    #[test]
    fn single_part_no_comm() {
        let a = gen::stencil_5pt(4, 4);
        let pm = PartitionedMatrix::build(&a, 1);
        let machine = lassen(1);
        assert!(pm.comm_pattern(&machine, 8).is_empty());
        assert_eq!(pm.total_halo(), 0);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn non_square_rejected() {
        let a = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]);
        PartitionedMatrix::build(&a, 2);
    }
}
