//! Seeded, deterministic fault and degradation injection.
//!
//! The paper's models — and the whole Table 6 / [`crate::topology::NodeShape`]
//! stack — assume healthy, uncontended hardware. This module makes the
//! unhealthy cases first-class so the adaptive replay policy can be tested
//! against *external* drift (ROADMAP item 5(b)): a [`FaultSpec`] schedules
//! per-epoch [`FaultEvent`]s of three classes,
//!
//! - **rail failure** ([`FaultKind::RailDown`]): a NIC rail goes down for
//!   the rest of the run. The node shape degrades
//!   ([`NodeShape::degraded`](crate::topology::NodeShape::degraded)):
//!   surviving rails are renumbered densely, GPU↔NIC affinity and the host
//!   round-robin remap onto the survivors through the *same* policy homes
//!   every executor already uses (`sim::exec::rail` reads the shape, so no
//!   second mapping exists to drift out of sync).
//! - **bandwidth degradation** ([`FaultKind::Slowdown`]): a rail becomes
//!   `factor`× slower. The per-rail injection bands
//!   ([`MachineParams::nic_bands`]) carry the slowdown into both executors,
//!   and the model-side aggregate `1/R_N` becomes the surviving rails' mean
//!   inverse rate, so the staged models' rails divisor keeps reproducing the
//!   summed injection capacity.
//! - **background congestion** ([`FaultKind::Congestion`]): seeded occupancy
//!   pre-charges every (node, rail) NIC timeline before the schedule runs
//!   ([`FaultState::precharge`]), consumed identically by `run_compiled`
//!   and `run_reference`.
//!
//! Events *persist* from their start epoch (no self-repair), so the state at
//! epoch `e` is the accumulation of every event with `epoch <= e`
//! ([`FaultSpec::state_at`]). Everything is deterministic: the same spec,
//! seed and trace produce byte-identical replay output, and an identity spec
//! ([`FaultSpec::is_identity`]) leaves every output byte-identical to a run
//! without faults (the zero-fault safety rail gated in CI).
//!
//! Specs are persisted as versioned `hetcomm.faults.v1` artifacts
//! ([`persist`]) and enter the CLI through `replay --faults` and
//! `sweep --faults` (docs/FORMATS.md).

pub mod persist;

use crate::params::{AlphaBeta, MachineParams};
use crate::topology::Machine;
use crate::util::rng::{index_seed, Rng};
use std::collections::{BTreeMap, BTreeSet};

/// Salt mixed into the spec seed for congestion pre-charge draws, so the
/// occupancy stream never collides with pattern-generator streams that share
/// the base seed.
const CONGESTION_SALT: u64 = 0xFA17_1E57_C0C0_57E5;

/// One fault class instance (the event minus its start epoch — the form
/// embedded into trace epochs).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// NIC rail `rail` (healthy node-local id) fails permanently.
    RailDown { rail: usize },
    /// Rail `rail` becomes `factor`× slower (`factor >= 1`, multiplying the
    /// rail's injection band α and β). Repeated slowdowns compound.
    Slowdown { rail: usize, factor: f64 },
    /// Background traffic pre-charges every (node, rail) NIC timeline with
    /// seeded occupancy uniform in `[0, 2·level)` seconds (mean `level`).
    /// Repeated events add their levels.
    Congestion { level: f64 },
}

impl FaultKind {
    /// The fault class name (the `kind` tag of the JSON encodings).
    pub fn class(&self) -> &'static str {
        match self {
            FaultKind::RailDown { .. } => "rail-down",
            FaultKind::Slowdown { .. } => "slowdown",
            FaultKind::Congestion { .. } => "congestion",
        }
    }

    /// Whether the event changes nothing (slowdown by 1×, zero congestion).
    pub fn is_identity(&self) -> bool {
        match *self {
            FaultKind::RailDown { .. } => false,
            FaultKind::Slowdown { factor, .. } => factor == 1.0,
            FaultKind::Congestion { level } => level == 0.0,
        }
    }

    /// Structural sanity against a healthy rail count (`rails == 0` skips
    /// the range check for contexts that do not know the machine yet).
    pub fn validate(&self, rails: usize) -> Result<(), String> {
        match *self {
            FaultKind::RailDown { rail } | FaultKind::Slowdown { rail, .. } if rails > 0 && rail >= rails => {
                Err(format!("fault names rail {rail}, node has {rails}"))
            }
            FaultKind::Slowdown { factor, .. } if !factor.is_finite() || factor < 1.0 => {
                Err(format!("slowdown factor must be finite and >= 1, got {factor}"))
            }
            FaultKind::Congestion { level } if !level.is_finite() || level < 0.0 => {
                Err(format!("congestion level must be finite and >= 0, got {level}"))
            }
            _ => Ok(()),
        }
    }
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::RailDown { rail } => write!(f, "rail-down({rail})"),
            FaultKind::Slowdown { rail, factor } => write!(f, "slowdown({rail}x{factor})"),
            FaultKind::Congestion { level } => write!(f, "congestion({level})"),
        }
    }
}

/// A scheduled fault: active from `epoch` (inclusive) to the end of the run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub epoch: usize,
    pub kind: FaultKind,
}

/// A deterministic fault schedule: the `hetcomm.faults.v1` artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Seed for the congestion occupancy injectors (rail failures and
    /// slowdowns are deterministic without it).
    pub seed: u64,
    /// Scheduled events, in any order; accumulation sorts by epoch.
    pub events: Vec<FaultEvent>,
}

impl FaultSpec {
    /// A spec with no events — the identity under every operation.
    pub fn empty(seed: u64) -> FaultSpec {
        FaultSpec { seed, events: Vec::new() }
    }

    /// Validate every event against a healthy rail count (`rails == 0`
    /// skips range checks) and require at least one surviving rail.
    pub fn validate(&self, rails: usize) -> Result<(), String> {
        for e in &self.events {
            e.kind.validate(rails)?;
        }
        if rails > 0 && self.terminal_state().down.len() >= rails {
            return Err(format!("fault spec downs all {rails} rails; at least one must survive"));
        }
        Ok(())
    }

    /// The accumulated fault state at `epoch`: every event with
    /// `event.epoch <= epoch` applied (events persist once active).
    pub fn state_at(&self, epoch: usize) -> FaultState {
        let mut state = FaultState::default();
        for e in &self.events {
            if e.epoch <= epoch {
                state.apply(&e.kind);
            }
        }
        state
    }

    /// The state after every event has fired.
    pub fn terminal_state(&self) -> FaultState {
        self.events.iter().map(|e| e.epoch).max().map(|last| self.state_at(last)).unwrap_or_default()
    }

    /// Whether the spec changes nothing at any epoch. Events only
    /// accumulate (there is no repair), so an identity terminal state means
    /// every intermediate state is the identity too.
    pub fn is_identity(&self) -> bool {
        self.events.iter().all(|e| e.kind.is_identity())
    }

    /// Epoch of the first non-identity event, if any.
    pub fn first_epoch(&self) -> Option<usize> {
        self.events.iter().filter(|e| !e.kind.is_identity()).map(|e| e.epoch).min()
    }

    /// Distinct fault classes present, in first-appearance order.
    pub fn classes(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for e in &self.events {
            if !e.kind.is_identity() && !out.contains(&e.kind.class()) {
                out.push(e.kind.class());
            }
        }
        out
    }

    /// The sub-spec keeping only one fault class (for per-class resilience
    /// counterfactuals). The seed is shared so congestion draws match.
    pub fn restricted_to_class(&self, class: &str) -> FaultSpec {
        FaultSpec { seed: self.seed, events: self.events.iter().filter(|e| e.kind.class() == class).cloned().collect() }
    }

    /// Embed the schedule into a trace's epochs (each event rides on its
    /// start epoch), so the trace itself carries the fault timeline.
    pub fn attach(&self, trace: &crate::trace::Trace) -> Result<crate::trace::Trace, String> {
        self.validate(trace.machine.nics_per_node())?;
        let mut out = trace.clone();
        for e in &self.events {
            let epoch = out
                .epochs
                .get_mut(e.epoch)
                .ok_or_else(|| format!("fault event at epoch {}, trace has {}", e.epoch, out.epochs.len()))?;
            epoch.faults.push(e.kind.clone());
        }
        Ok(out)
    }
}

/// The accumulated degradation in force at one epoch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultState {
    /// Failed rails (healthy node-local ids).
    pub down: BTreeSet<usize>,
    /// Compounded slowdown factor per rail (healthy ids; absent = 1×).
    pub slow: BTreeMap<usize, f64>,
    /// Summed background-congestion level [s].
    pub congestion: f64,
}

impl FaultState {
    /// Fold one more event into the state.
    pub fn apply(&mut self, kind: &FaultKind) {
        match *kind {
            FaultKind::RailDown { rail } => {
                self.down.insert(rail);
            }
            FaultKind::Slowdown { rail, factor } => {
                *self.slow.entry(rail).or_insert(1.0) *= factor;
            }
            FaultKind::Congestion { level } => self.congestion += level,
        }
    }

    /// Whether the state changes nothing.
    pub fn is_identity(&self) -> bool {
        self.down.is_empty() && self.slow.iter().all(|(_, &f)| f == 1.0) && self.congestion == 0.0
    }

    /// The degraded system: the machine with failed rails removed from its
    /// shape (survivors renumbered densely, affinity remapped) and the
    /// parameters with per-rail slowdowns folded into the injection bands.
    ///
    /// When bands become heterogeneous (explicit `nic_bands`), the
    /// model-side aggregate `inv_rn` is recomputed as the surviving rails'
    /// mean inverse rate — `nics / Σ_r (1/β_r)` — so the staged models'
    /// division by the rail count keeps equaling the summed injection
    /// capacity. Pure rail-down states on homogeneous bands leave `inv_rn`
    /// bit-identical (the survivors are unchanged rails). Congestion does
    /// not appear here at all: it is a simulator-timeline effect
    /// ([`FaultState::precharge`]), invisible to the closed-form models.
    pub fn degrade(&self, machine: &Machine, params: &MachineParams) -> Result<(Machine, MachineParams), String> {
        if self.down.is_empty() && self.slow.iter().all(|(_, &f)| f == 1.0) {
            return Ok((machine.clone(), params.clone()));
        }
        let rails = machine.nics_per_node();
        for &r in self.down.iter().chain(self.slow.keys()) {
            if r >= rails {
                return Err(format!("fault names rail {r}, machine {:?} has {rails}", machine.name));
            }
        }
        let down: Vec<usize> = self.down.iter().copied().collect();
        let shape = machine.shape.degraded(&down)?;
        let mut degraded = machine.clone();
        degraded.shape = shape;

        // Surviving rails' bands in their new (dense) order, slowdowns
        // applied. Keeping the table empty when it would only restate the
        // homogeneous default preserves the bit-exact legacy injection path.
        let bands: Vec<AlphaBeta> = (0..rails)
            .filter(|r| !self.down.contains(r))
            .map(|r| {
                let f = self.slow.get(&r).copied().unwrap_or(1.0);
                let b = params.nic_band(r);
                AlphaBeta::new(b.alpha * f, b.beta * f)
            })
            .collect();
        let mut out = params.clone();
        let heterogeneous = !params.nic_bands.is_empty() || self.slow.iter().any(|(_, &f)| f != 1.0);
        if heterogeneous {
            let capacity: f64 = bands.iter().map(|b| 1.0 / b.beta).sum();
            if !(capacity.is_finite() && capacity > 0.0) {
                return Err("degraded rails have no finite injection capacity".into());
            }
            out.inv_rn = bands.len() as f64 / capacity;
            out.nic_bands = bands;
        } else {
            out.nic_bands = Vec::new();
        }
        Ok((degraded, out))
    }

    /// Seeded background-occupancy pre-charge for every (node, rail) NIC
    /// timeline — `None` when the state carries no congestion. Entry
    /// `node * rails + rail` is uniform in `[0, 2·level)` seconds. `stream`
    /// separates draws per epoch (or per sweep cell) so occupancy evolves
    /// over a run while staying deterministic.
    pub fn precharge(&self, seed: u64, stream: usize, nodes: usize, rails: usize) -> Option<Vec<f64>> {
        if self.congestion <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(index_seed(seed ^ CONGESTION_SALT, stream));
        Some((0..nodes * rails).map(|_| rng.f64() * 2.0 * self.congestion).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::machines;

    fn spec() -> FaultSpec {
        FaultSpec {
            seed: 9,
            events: vec![
                FaultEvent { epoch: 2, kind: FaultKind::Congestion { level: 1.5e-4 } },
                FaultEvent { epoch: 3, kind: FaultKind::RailDown { rail: 1 } },
                FaultEvent { epoch: 5, kind: FaultKind::Slowdown { rail: 0, factor: 4.0 } },
                FaultEvent { epoch: 6, kind: FaultKind::Slowdown { rail: 0, factor: 2.0 } },
            ],
        }
    }

    #[test]
    fn states_accumulate_and_persist() {
        let s = spec();
        assert!(s.state_at(1).is_identity());
        assert_eq!(s.state_at(2).congestion, 1.5e-4);
        assert!(s.state_at(2).down.is_empty());
        let at4 = s.state_at(4);
        assert!(at4.down.contains(&1));
        assert_eq!(at4.congestion, 1.5e-4);
        // slowdowns compound: 4x then 2x = 8x
        assert_eq!(s.state_at(6).slow.get(&0), Some(&8.0));
        assert_eq!(s.terminal_state(), s.state_at(6));
        assert_eq!(s.first_epoch(), Some(2));
        assert_eq!(s.classes(), vec!["congestion", "rail-down", "slowdown"]);
    }

    #[test]
    fn identity_specs_detected() {
        assert!(FaultSpec::empty(1).is_identity());
        let s = FaultSpec {
            seed: 1,
            events: vec![
                FaultEvent { epoch: 0, kind: FaultKind::Slowdown { rail: 0, factor: 1.0 } },
                FaultEvent { epoch: 1, kind: FaultKind::Congestion { level: 0.0 } },
            ],
        };
        assert!(s.is_identity());
        assert!(s.first_epoch().is_none());
        assert!(s.classes().is_empty());
        assert!(!spec().is_identity());
    }

    #[test]
    fn validate_rejects_bad_events() {
        let mut s = spec();
        s.validate(4).unwrap();
        s.validate(0).unwrap(); // unknown rail count: range checks skipped
        assert!(s.validate(1).unwrap_err().contains("rail 1"));
        s.events.push(FaultEvent { epoch: 0, kind: FaultKind::Slowdown { rail: 0, factor: 0.5 } });
        assert!(s.validate(4).unwrap_err().contains("factor"));
        s.events.pop();
        s.events.push(FaultEvent { epoch: 0, kind: FaultKind::Congestion { level: f64::NAN } });
        assert!(s.validate(4).unwrap_err().contains("congestion"));
        // downing every rail is rejected
        let all = FaultSpec {
            seed: 1,
            events: (0..2).map(|r| FaultEvent { epoch: 0, kind: FaultKind::RailDown { rail: r } }).collect(),
        };
        assert!(all.validate(2).unwrap_err().contains("survive"));
    }

    #[test]
    fn class_restriction_partitions() {
        let s = spec();
        let down = s.restricted_to_class("rail-down");
        assert_eq!(down.events.len(), 1);
        assert_eq!(down.seed, s.seed);
        let slow = s.restricted_to_class("slowdown");
        assert_eq!(slow.events.len(), 2);
        let total: usize = s.classes().iter().map(|c| s.restricted_to_class(c).events.len()).sum();
        assert_eq!(total, s.events.len());
    }

    #[test]
    fn degrade_rail_down_shrinks_shape_only() {
        let (machine, params) = machines::parse("frontier-4nic", 2).unwrap();
        let mut state = FaultState::default();
        state.apply(&FaultKind::RailDown { rail: 2 });
        let (dm, dp) = state.degrade(&machine, &params).unwrap();
        assert_eq!(dm.nics_per_node(), 3);
        dm.shape.validate(dm.sockets_per_node, dm.gpus_per_node()).unwrap();
        // homogeneous bands stay implicit and the model rate is untouched
        assert!(dp.nic_bands.is_empty());
        assert_eq!(dp.inv_rn.to_bits(), params.inv_rn.to_bits());
        // everything else is untouched
        assert_eq!(dm.num_nodes, machine.num_nodes);
        assert_eq!(dp.cpu, params.cpu);
    }

    #[test]
    fn degrade_slowdown_reaches_bands_and_aggregate_rate() {
        let (machine, params) = machines::parse("frontier-4nic", 2).unwrap();
        let mut state = FaultState::default();
        state.apply(&FaultKind::Slowdown { rail: 1, factor: 4.0 });
        let (dm, dp) = state.degrade(&machine, &params).unwrap();
        assert_eq!(dm.nics_per_node(), 4);
        assert_eq!(dp.nic_bands.len(), 4);
        assert_eq!(dp.nic_bands[1].beta, params.inv_rn * 4.0);
        assert_eq!(dp.nic_bands[0].beta, params.inv_rn);
        // aggregate: 4 rails at rates (1, 1/4, 1, 1)/inv_rn -> mean inverse
        let capacity = (3.0 + 0.25) / params.inv_rn;
        assert!((dp.inv_rn - 4.0 / capacity).abs() < 1e-25);
        assert!(dp.inv_rn > params.inv_rn, "slowdown must lower the aggregate rate");
    }

    #[test]
    fn degrade_combined_drops_failed_rail_bands() {
        let (machine, params) = machines::parse("frontier-4nic", 2).unwrap();
        let mut state = FaultState::default();
        state.apply(&FaultKind::RailDown { rail: 0 });
        state.apply(&FaultKind::Slowdown { rail: 2, factor: 2.0 });
        let (dm, dp) = state.degrade(&machine, &params).unwrap();
        assert_eq!(dm.nics_per_node(), 3);
        assert_eq!(dp.nic_bands.len(), 3);
        // surviving order: healthy rails 1, 2, 3 -> new 0, 1, 2
        assert_eq!(dp.nic_bands[1].beta, params.inv_rn * 2.0);
        assert_eq!(dp.nic_bands[0].beta, params.inv_rn);
        assert_eq!(dp.nic_bands[2].beta, params.inv_rn);
        // slowdown on a failed rail is a no-op for the survivors
        let mut moot = FaultState::default();
        moot.apply(&FaultKind::RailDown { rail: 0 });
        moot.apply(&FaultKind::Slowdown { rail: 0, factor: 8.0 });
        let (_, mp) = moot.degrade(&machine, &params).unwrap();
        assert!(mp.nic_bands.iter().all(|b| b.beta == params.inv_rn));
    }

    #[test]
    fn degrade_identity_and_errors() {
        let (machine, params) = machines::parse("lassen", 2).unwrap();
        let state = FaultState { congestion: 1e-3, ..Default::default() };
        let (dm, dp) = state.degrade(&machine, &params).unwrap();
        assert_eq!(dm, machine);
        assert_eq!(dp, params);
        let mut bad = FaultState::default();
        bad.apply(&FaultKind::RailDown { rail: 7 });
        assert!(bad.degrade(&machine, &params).unwrap_err().contains("rail 7"));
        let mut all = FaultState::default();
        all.apply(&FaultKind::RailDown { rail: 0 });
        assert!(all.degrade(&machine, &params).is_err(), "last rail cannot fail");
    }

    #[test]
    fn precharge_is_seeded_bounded_and_gated() {
        let state = FaultState { congestion: 2.0e-4, ..Default::default() };
        let a = state.precharge(7, 3, 4, 2).unwrap();
        let b = state.precharge(7, 3, 4, 2).unwrap();
        assert_eq!(a.len(), 8);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.iter().all(|&x| (0.0..2.0 * 2.0e-4).contains(&x)));
        assert!(a.iter().any(|&x| x > 0.0));
        // different stream, different draws
        let c = state.precharge(7, 4, 4, 2).unwrap();
        assert!(a.iter().zip(&c).any(|(x, y)| x.to_bits() != y.to_bits()));
        assert!(FaultState::default().precharge(7, 3, 4, 2).is_none());
    }
}
