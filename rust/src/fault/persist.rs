//! Versioned artifact layer for [`FaultSpec`]: schema `hetcomm.faults.v1`.
//!
//! Same contract as the other artifact layers ([`crate::advisor::persist`],
//! [`crate::trace::persist`]): floats are written with [`fmt_f64`]
//! (shortest-round-trip `Display`) so emit∘parse∘emit is the identity on
//! artifact bytes, seeds are strings (u64s above 2^53 would not survive a
//! JSON-number round trip), and every parse path returns a descriptive
//! `Err` — never a panic — on truncated, corrupted or type-confused input.
//! Hand-rolled on [`crate::util::json`]; no `serde` in the offline image.

use super::{FaultEvent, FaultKind, FaultSpec};
use crate::util::json::{fmt_f64, Json};
use std::fmt::Write as _;

/// Schema tag of the fault-spec artifact.
pub const SCHEMA: &str = "hetcomm.faults.v1";

/// The `"kind": ...` tail of one event object — shared with the trace
/// emitter so epoch-embedded faults and standalone specs spell identically.
pub(crate) fn kind_fields(kind: &FaultKind) -> String {
    match kind {
        FaultKind::RailDown { rail } => format!("\"kind\": \"rail-down\", \"rail\": {rail}"),
        FaultKind::Slowdown { rail, factor } => {
            format!("\"kind\": \"slowdown\", \"rail\": {rail}, \"factor\": {}", fmt_f64(*factor))
        }
        FaultKind::Congestion { level } => format!("\"kind\": \"congestion\", \"level\": {}", fmt_f64(*level)),
    }
}

/// Parse one event object's kind fields (shared with the trace parser).
pub(crate) fn parse_kind(v: &Json) -> Result<FaultKind, String> {
    let kind = v.field("kind")?.as_str()?;
    match kind {
        "rail-down" => Ok(FaultKind::RailDown { rail: v.field("rail")?.as_usize()? }),
        "slowdown" => {
            Ok(FaultKind::Slowdown { rail: v.field("rail")?.as_usize()?, factor: v.field("factor")?.as_f64()? })
        }
        "congestion" => Ok(FaultKind::Congestion { level: v.field("level")?.as_f64()? }),
        other => Err(format!("unknown fault kind {other:?} (want rail-down, slowdown or congestion)")),
    }
}

/// Serialize a fault spec.
pub fn to_json(spec: &FaultSpec) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"seed\": \"{}\",", spec.seed);
    out.push_str("  \"events\": [\n");
    for (i, e) in spec.events.iter().enumerate() {
        let comma = if i + 1 < spec.events.len() { "," } else { "" };
        let _ = writeln!(out, "    {{\"epoch\": {}, {}}}{comma}", e.epoch, kind_fields(&e.kind));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write a fault-spec artifact to disk.
pub fn save(spec: &FaultSpec, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(spec)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate a fault-spec artifact from disk (`rails == 0` skips
/// rail-range checks; callers re-validate against the actual machine).
pub fn load(path: &str) -> Result<FaultSpec, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

/// Parse and validate a `hetcomm.faults.v1` artifact.
pub fn parse_json(text: &str) -> Result<FaultSpec, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("unsupported fault spec schema {schema:?} (want {SCHEMA:?})"));
    }
    let seed_str = value.field("seed")?.as_str()?;
    let seed = seed_str.parse::<u64>().map_err(|_| format!("expected a u64 seed string, found {seed_str:?}"))?;
    let events = value
        .field("events")?
        .as_arr()?
        .iter()
        .map(|v| Ok(FaultEvent { epoch: v.field("epoch")?.as_usize()?, kind: parse_kind(v)? }))
        .collect::<Result<Vec<_>, String>>()?;
    let spec = FaultSpec { seed, events };
    spec.validate(0)?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FaultSpec {
        FaultSpec {
            seed: 42,
            events: vec![
                FaultEvent { epoch: 2, kind: FaultKind::Congestion { level: 1.5e-4 } },
                FaultEvent { epoch: 3, kind: FaultKind::RailDown { rail: 1 } },
                FaultEvent { epoch: 5, kind: FaultKind::Slowdown { rail: 0, factor: 4.0 } },
            ],
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let spec = sample();
        let json = to_json(&spec);
        let parsed = parse_json(&json).unwrap();
        assert_eq!(spec, parsed);
        // emit . parse . emit is the identity on artifact bytes
        assert_eq!(json, to_json(&parsed));
        // empty specs round-trip too
        let empty = FaultSpec::empty(7);
        assert_eq!(parse_json(&to_json(&empty)).unwrap(), empty);
    }

    #[test]
    fn save_load_roundtrip() {
        let spec = sample();
        let path = std::env::temp_dir().join("hetcomm-faults-test.json");
        let path = path.to_str().unwrap();
        save(&spec, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(spec, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        let json = to_json(&sample());

        let wrong_schema = json.replacen("hetcomm.faults.v1", "hetcomm.faults.v9", 1);
        assert!(parse_json(&wrong_schema).unwrap_err().contains("schema"));

        let bad_seed = json.replacen("\"seed\": \"42\"", "\"seed\": \"many\"", 1);
        assert!(parse_json(&bad_seed).unwrap_err().contains("seed"));

        let bad_kind = json.replacen("rail-down", "rail-sideways", 1);
        assert!(parse_json(&bad_kind).unwrap_err().contains("rail-sideways"));

        let bad_factor = json.replacen("\"factor\": 4", "\"factor\": 0.25", 1);
        assert!(parse_json(&bad_factor).unwrap_err().contains("factor"));

        let truncated = &json[..json.len() / 2];
        assert!(parse_json(truncated).is_err());

        let type_confused = json.replacen("\"rail\": 1", "\"rail\": \"one\"", 1);
        assert!(parse_json(&type_confused).is_err());
    }
}
