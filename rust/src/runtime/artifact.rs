//! Artifact registry: the static shapes shared between `python/compile/`
//! (which lowers and serializes) and the Rust runtime (which loads and
//! feeds buffers). Shapes must match exactly — XLA executables are
//! shape-monomorphic.

/// Specification of one AOT artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Logical name, e.g. `spmv_local_512x32`.
    pub name: String,
    /// Local rows per GPU partition (padded).
    pub rows: usize,
    /// ELL width of the diag block.
    pub diag_width: usize,
    /// ELL width of the offd block.
    pub offd_width: usize,
    /// Ghost (halo) vector length (padded).
    pub ghost: usize,
}

impl ArtifactSpec {
    pub fn new(rows: usize, diag_width: usize, offd_width: usize, ghost: usize) -> ArtifactSpec {
        ArtifactSpec {
            name: format!("spmv_local_r{rows}_d{diag_width}_o{offd_width}_g{ghost}"),
            rows,
            diag_width,
            offd_width,
            ghost,
        }
    }

    pub fn file_name(&self) -> String {
        format!("{}.hlo.txt", self.name)
    }
}

/// The canonical local-SpMV artifact shapes built by `make artifacts`.
/// Keep in sync with `python/compile/aot.py::SHAPES`.
pub const SPMV_SHAPES: [(usize, usize, usize, usize); 3] = [
    // (rows, diag_width, offd_width, ghost)
    (256, 32, 16, 256),
    (512, 32, 16, 512),
    (1024, 32, 16, 1024),
];

/// Specs for the canonical shapes.
pub fn spmv_specs() -> Vec<ArtifactSpec> {
    SPMV_SHAPES.iter().map(|&(r, d, o, g)| ArtifactSpec::new(r, d, o, g)).collect()
}

/// The default local-SpMV artifact (mid shape).
pub const SPMV_LOCAL: (usize, usize, usize, usize) = SPMV_SHAPES[1];

/// Pick the smallest canonical spec that fits the given requirements, if
/// any.
pub fn fitting_spec(rows: usize, diag_width: usize, offd_width: usize, ghost: usize) -> Option<ArtifactSpec> {
    SPMV_SHAPES
        .iter()
        .filter(|&&(r, d, o, g)| rows <= r && diag_width <= d && offd_width <= o && ghost <= g)
        .min_by_key(|&&(r, _, _, _)| r)
        .map(|&(r, d, o, g)| ArtifactSpec::new(r, d, o, g))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_stable() {
        let s = ArtifactSpec::new(512, 32, 16, 512);
        assert_eq!(s.file_name(), "spmv_local_r512_d32_o16_g512.hlo.txt");
    }

    #[test]
    fn fitting_spec_picks_smallest() {
        let s = fitting_spec(300, 20, 10, 100).unwrap();
        assert_eq!(s.rows, 512);
        let s = fitting_spec(100, 32, 16, 256).unwrap();
        assert_eq!(s.rows, 256);
    }

    #[test]
    fn fitting_spec_none_when_too_big() {
        assert!(fitting_spec(4096, 32, 16, 512).is_none());
        assert!(fitting_spec(512, 64, 16, 512).is_none());
    }

    #[test]
    fn specs_cover_table() {
        assert_eq!(spmv_specs().len(), SPMV_SHAPES.len());
    }
}
