//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text
//! emitted by `python/compile/aot.py`) and executes them on the CPU PJRT
//! client from the Rust hot path. Python never runs at request time.
//!
//! Interchange format is HLO **text**: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` bindings are not part of the offline build image, so the PJRT
//! client is gated behind the `pjrt` cargo feature. Without it this module
//! exposes an API-compatible stub whose [`Runtime::load`] fails with a clear
//! message — every caller (coordinator workers, the `e2e` subcommand, the
//! runtime integration tests) already degrades gracefully on that error.

pub mod artifact;

pub use artifact::{fitting_spec, spmv_specs, ArtifactSpec, SPMV_LOCAL, SPMV_SHAPES};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::ArtifactSpec;
    use anyhow::{Context, Result};
    use std::path::{Path, PathBuf};

    /// A compiled model executable bound to a PJRT client.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub spec: ArtifactSpec,
    }

    /// The PJRT runtime: one CPU client, many compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn artifacts_dir(&self) -> &Path {
            &self.artifacts_dir
        }

        /// Load and compile one artifact by spec.
        pub fn load(&self, spec: &ArtifactSpec) -> Result<Executable> {
            let path = self.artifacts_dir.join(spec.file_name());
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", spec.name))?;
            Ok(Executable { exe, spec: spec.clone() })
        }

        /// True when every artifact in `specs` exists on disk.
        pub fn artifacts_present(&self, specs: &[ArtifactSpec]) -> bool {
            specs.iter().all(|s| self.artifacts_dir.join(s.file_name()).exists())
        }
    }

    impl Executable {
        /// Execute the local-SpMV artifact. Calling convention (must match
        /// `python/compile/model.py::local_spmv`): positional arguments
        /// `(diag_vals f32[r,dw], diag_cols i32[r,dw], offd_vals f32[r,ow],
        /// offd_cols i32[r,ow], v_local f32[r], v_ghost f32[g])`, returning a
        /// 1-tuple `(w f32[r],)`.
        #[allow(clippy::too_many_arguments)]
        pub fn run_spmv(
            &self,
            diag_vals: &[f32],
            diag_cols: &[i32],
            offd_vals: &[f32],
            offd_cols: &[i32],
            v_local: &[f32],
            v_ghost: &[f32],
        ) -> Result<Vec<f32>> {
            let s = &self.spec;
            anyhow::ensure!(diag_vals.len() == s.rows * s.diag_width, "diag_vals shape");
            anyhow::ensure!(offd_vals.len() == s.rows * s.offd_width, "offd_vals shape");
            anyhow::ensure!(v_local.len() == s.rows, "v_local shape");
            anyhow::ensure!(v_ghost.len() == s.ghost, "v_ghost shape");
            let r = s.rows as i64;
            let dw = s.diag_width as i64;
            let ow = s.offd_width as i64;
            let args = [
                xla::Literal::vec1(diag_vals).reshape(&[r, dw])?,
                xla::Literal::vec1(diag_cols).reshape(&[r, dw])?,
                xla::Literal::vec1(offd_vals).reshape(&[r, ow])?,
                xla::Literal::vec1(offd_cols).reshape(&[r, ow])?,
                xla::Literal::vec1(v_local),
                xla::Literal::vec1(v_ghost),
            ];
            let result = self.exe.execute::<xla::Literal>(&args).context("executing PJRT spmv")?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let out = result.to_tuple1().context("unpacking 1-tuple result")?;
            Ok(out.to_vec::<f32>().context("reading f32 output")?)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use super::ArtifactSpec;
    use anyhow::Result;
    use std::path::{Path, PathBuf};

    const UNAVAILABLE: &str = "hetcomm was built without the `pjrt` feature; \
        PJRT execution is unavailable (enable `--features pjrt` with the vendored xla bindings)";

    /// Stub executable: same API as the PJRT-backed one, never constructed
    /// in practice because [`Runtime::load`] fails first.
    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    /// Stub runtime: artifact presence checks work (they only touch the
    /// filesystem); loading or executing reports the missing feature.
    pub struct Runtime {
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
            Ok(Runtime { artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without `pjrt`)".to_string()
        }

        pub fn artifacts_dir(&self) -> &Path {
            &self.artifacts_dir
        }

        pub fn load(&self, spec: &ArtifactSpec) -> Result<Executable> {
            anyhow::bail!("cannot load artifact {}: {UNAVAILABLE}", spec.name)
        }

        pub fn artifacts_present(&self, specs: &[ArtifactSpec]) -> bool {
            specs.iter().all(|s| self.artifacts_dir.join(s.file_name()).exists())
        }
    }

    impl Executable {
        #[allow(clippy::too_many_arguments)]
        pub fn run_spmv(
            &self,
            _diag_vals: &[f32],
            _diag_cols: &[i32],
            _offd_vals: &[f32],
            _offd_cols: &[i32],
            _v_local: &[f32],
            _v_ghost: &[f32],
        ) -> Result<Vec<f32>> {
            anyhow::bail!("{UNAVAILABLE}")
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_runtime_degrades_gracefully() {
            let rt = Runtime::new("/nonexistent").unwrap();
            assert!(rt.platform().contains("unavailable"));
            assert!(!rt.artifacts_present(&crate::runtime::spmv_specs()));
            let err = rt.load(&ArtifactSpec::new(256, 32, 16, 256)).unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{Executable, Runtime};
