//! Locality-aware collective operations built from the point-to-point
//! staging primitives.
//!
//! The paper's eight strategies characterize *irregular* point-to-point
//! exchange; the same node-aware machinery (aggregate on-node, ship once
//! per node pair, redistribute on arrival) composes directly into
//! collectives — exactly how mpi-advance's `MPIX_Alltoall` and SparseComm's
//! socket-split communicator hierarchy are built. This layer:
//!
//! - synthesizes collective communication patterns ([`CollectiveSpec`]:
//!   alltoall, alltoallv with seeded irregular counts, allgather) as plain
//!   [`crate::pattern::CommPattern`]s, so everything downstream
//!   (pattern statistics, [`crate::sim::CompiledPattern`] lowering, both
//!   simulator executors, NodeShape rail assignment) is reused verbatim;
//! - lowers each collective through three algorithm variants
//!   ([`CollectiveAlgorithm`]: `standard` direct pairwise, `pairwise`
//!   ordered exchange, `locality` three-phase gather → node-pair exchange →
//!   redistribute) into per-stage patterns ([`lower`]);
//! - costs each variant by composing the existing Table 6 closed-form
//!   pieces ([`model`]) and by end-to-end discrete-event simulation of the
//!   lowered schedules;
//! - sweeps the (collective × algorithm × nodes × gpn × size) grid with
//!   the standard seeded deterministic JSON/CSV + winner/crossover
//!   reports ([`sweep`], [`emit`], [`report`]), and compiles collective
//!   decision surfaces for the advisor ([`surface`], [`persist`]).

pub mod bounds;
pub mod emit;
pub mod lower;
pub mod model;
pub mod persist;
pub mod report;
pub mod surface;
pub mod sweep;

pub use bounds::ColBoundModel;
pub use lower::{lower, owner, recv_owner, sim_schedule, Lowering, Stage};
pub use model::algorithm_time;
pub use report::{analyze, CollectiveReport, CollectiveWinner, ColCrossover, ColRegimeWinner};
pub use surface::CollectiveSurface;
pub use sweep::{run_collective, CollectiveCell, CollectiveConfig, CollectiveGrid, CollectiveResult};

use crate::pattern::{CommPattern, Msg};
use crate::topology::{GpuId, Machine};
use crate::util::rng::{index_seed, Rng};

/// The collective operations of this layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Collective {
    /// Every process ships one equal block to every other process.
    Alltoall,
    /// Alltoall with seeded irregular per-pair byte counts (the FFT
    /// transpose / graph exchange shape).
    Alltoallv,
    /// Every process ships the *same* block to every other process —
    /// node-aware algorithms send it across the network once per node.
    Allgather,
}

impl Collective {
    pub const ALL: [Collective; 3] = [Collective::Alltoall, Collective::Alltoallv, Collective::Allgather];

    /// The user-facing collective name (CLI flags, artifacts).
    pub fn label(&self) -> &'static str {
        match self {
            Collective::Alltoall => "alltoall",
            Collective::Alltoallv => "alltoallv",
            Collective::Allgather => "allgather",
        }
    }

    /// Parse a user-facing collective name.
    pub fn parse(s: &str) -> Option<Collective> {
        match s.trim().to_ascii_lowercase().as_str() {
            "alltoall" | "a2a" => Some(Collective::Alltoall),
            "alltoallv" | "a2av" => Some(Collective::Alltoallv),
            "allgather" | "ag" => Some(Collective::Allgather),
            _ => None,
        }
    }
}

impl std::fmt::Display for Collective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// How a collective is decomposed into point-to-point stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CollectiveAlgorithm {
    /// Direct pairwise pattern: every logical message travels individually.
    Standard,
    /// Ordered exchange: round `r` pairs each node with the node `r` hops
    /// ahead, serializing the rounds (barriers between them).
    Pairwise,
    /// Three-phase node-aware staging (the `MPIX_Alltoall` shape): on-node
    /// gather to the node-pair owner, one aggregated exchange per node
    /// pair, on-node redistribute on arrival.
    Locality,
}

impl CollectiveAlgorithm {
    pub const ALL: [CollectiveAlgorithm; 3] =
        [CollectiveAlgorithm::Standard, CollectiveAlgorithm::Pairwise, CollectiveAlgorithm::Locality];

    /// The user-facing algorithm name (CLI flags, artifacts).
    pub fn label(&self) -> &'static str {
        match self {
            CollectiveAlgorithm::Standard => "standard",
            CollectiveAlgorithm::Pairwise => "pairwise",
            CollectiveAlgorithm::Locality => "locality",
        }
    }

    /// Parse a user-facing algorithm name.
    pub fn parse(s: &str) -> Option<CollectiveAlgorithm> {
        match s.trim().to_ascii_lowercase().replace('_', "-").as_str() {
            "standard" | "std" => Some(CollectiveAlgorithm::Standard),
            "pairwise" | "pw" => Some(CollectiveAlgorithm::Pairwise),
            "locality" | "locality-aware" | "loc" => Some(CollectiveAlgorithm::Locality),
            _ => None,
        }
    }
}

impl std::fmt::Display for CollectiveAlgorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// One collective operation instance: which collective, the per-pair block
/// size, and the seed that fixes alltoallv's irregular counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollectiveSpec {
    pub collective: Collective,
    /// Bytes each process ships to each peer (alltoall/allgather exactly;
    /// alltoallv jitters around it per ordered pair).
    pub block_bytes: usize,
    /// Seed for the irregular alltoallv counts. A pure function of
    /// `(seed, src, dst)` — independent of message enumeration order.
    pub seed: u64,
}

impl CollectiveSpec {
    pub fn new(collective: Collective, block_bytes: usize, seed: u64) -> CollectiveSpec {
        assert!(block_bytes > 0, "collective block size must be positive");
        CollectiveSpec { collective, block_bytes, seed }
    }

    /// Payload bytes for the ordered pair `src → dst` out of `total`
    /// processes. Alltoallv draws uniformly from `[block/2, 2·block)`
    /// keyed by the pair, so shuffling process enumeration cannot change
    /// any pair's size.
    pub fn pair_bytes(&self, src: GpuId, dst: GpuId, total: usize) -> usize {
        match self.collective {
            Collective::Alltoall | Collective::Allgather => self.block_bytes,
            Collective::Alltoallv => {
                let lo = (self.block_bytes / 2).max(1);
                let hi = (self.block_bytes * 2).max(lo + 1);
                let mut r = Rng::new(index_seed(self.seed, src.0 * total + dst.0));
                r.usize_in(lo, hi)
            }
        }
    }

    /// Materialize the *direct* communication pattern: one logical message
    /// per ordered process pair. Allgather messages from one source carry
    /// identical data, marked via `dup_group` so node-aware accounting
    /// (and the locality lowering) may ship them once per destination node.
    pub fn materialize(&self, machine: &Machine) -> CommPattern {
        let total = machine.total_gpus();
        let mut msgs = Vec::with_capacity(total * (total - 1));
        for src in 0..total {
            for dst in 0..total {
                if src == dst {
                    continue;
                }
                let (src, dst) = (GpuId(src), GpuId(dst));
                let bytes = self.pair_bytes(src, dst, total);
                let mut m = Msg::new(src, dst, bytes);
                if self.collective == Collective::Allgather {
                    m.dup_group = src.0 as u32;
                }
                msgs.push(m);
            }
        }
        CommPattern::new(msgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::machines::lassen;

    #[test]
    fn labels_roundtrip() {
        for c in Collective::ALL {
            assert_eq!(Collective::parse(c.label()), Some(c));
        }
        for a in CollectiveAlgorithm::ALL {
            assert_eq!(CollectiveAlgorithm::parse(a.label()), Some(a));
        }
        assert_eq!(Collective::parse("A2AV"), Some(Collective::Alltoallv));
        assert_eq!(CollectiveAlgorithm::parse("locality-aware"), Some(CollectiveAlgorithm::Locality));
        assert_eq!(Collective::parse("bogus"), None);
        assert_eq!(CollectiveAlgorithm::parse("bogus"), None);
    }

    #[test]
    fn alltoall_is_complete_and_uniform() {
        let m = lassen(2);
        let spec = CollectiveSpec::new(Collective::Alltoall, 1024, 7);
        let p = spec.materialize(&m);
        let n = m.total_gpus();
        assert_eq!(p.msgs.len(), n * (n - 1));
        assert!(p.msgs.iter().all(|msg| msg.bytes == 1024 && msg.src != msg.dst));
        assert_eq!(p.total_bytes(), 1024 * n * (n - 1));
    }

    #[test]
    fn alltoallv_sizes_jitter_deterministically() {
        let m = lassen(2);
        let spec = CollectiveSpec::new(Collective::Alltoallv, 1024, 7);
        let a = spec.materialize(&m);
        let b = spec.materialize(&m);
        assert_eq!(a, b, "same seed must give identical patterns");
        assert!(a.msgs.iter().all(|msg| (512..2048).contains(&msg.bytes)));
        // genuinely irregular: not all pairs equal
        assert!(a.msgs.iter().any(|msg| msg.bytes != a.msgs[0].bytes));
        let other = CollectiveSpec::new(Collective::Alltoallv, 1024, 8).materialize(&m);
        assert_ne!(a, other, "different seeds must differ");
    }

    #[test]
    fn allgather_marks_duplicates_per_source() {
        let m = lassen(4);
        let spec = CollectiveSpec::new(Collective::Allgather, 2048, 1);
        let p = spec.materialize(&m);
        assert!(p.msgs.iter().all(|msg| msg.dup_group == msg.src.0 as u32));
        // a source's (gpn) messages into one remote node are all duplicates
        // of one block: fraction = (gpn - 1) / gpn
        let f = p.duplicate_fraction(&m);
        let gpn = m.gpus_per_node() as f64;
        assert!((f - (gpn - 1.0) / gpn).abs() < 1e-12, "dup fraction {f}");
    }

    #[test]
    fn pair_bytes_independent_of_enumeration() {
        let spec = CollectiveSpec::new(Collective::Alltoallv, 4096, 99);
        let a = spec.pair_bytes(GpuId(3), GpuId(11), 16);
        // recomputing in any order yields the same size for the pair
        let _ = spec.pair_bytes(GpuId(11), GpuId(3), 16);
        let _ = spec.pair_bytes(GpuId(0), GpuId(1), 16);
        assert_eq!(spec.pair_bytes(GpuId(3), GpuId(11), 16), a);
    }
}
