//! Closed-form costing of lowered collectives from the Table 6 pieces.
//!
//! Each [`Stage`] is costed on staged transport from the existing model
//! primitives, nothing new is fitted:
//!
//! - the **inter-node leg** is the Standard (staged) network term — the
//!   max-rate model of Eq. (2.2) ([`crate::model::maxrate::MaxRate`]) with
//!   the (α, β) row selected by the stage's per-message size and the
//!   injection term divided over the shape's NIC rails — evaluated on the
//!   stage pattern's own Table 7 statistics;
//! - the **on-node leg** serializes each endpoint's stage messages with
//!   the Table 2 on-socket/on-node rows (the postal model, Eq. 2.1);
//! - the **staging legs** are `T_copy` (Eq. 4.5) on the busiest GPU's
//!   stage send/receive volumes.
//!
//! Within a stage the two legs proceed on disjoint resources (NIC vs
//! on-node links), so a stage costs `max(inter, intra) + copies`; stages
//! are barriers and sum. The pairwise algorithm keeps payloads host-resident
//! across rounds, so it pays the copy legs once and one network term per
//! round.

use super::lower::Lowering;
use super::CollectiveAlgorithm;
use crate::model::{copy, maxrate::MaxRate};
use crate::params::{Endpoint, MachineParams};
use crate::pattern::CommPattern;
use crate::topology::{GpuId, Locality, Machine};
use std::collections::BTreeMap;

/// The Standard (staged) network term of Table 6 on one stage pattern:
/// max-rate (Eq. 2.2) with the per-message protocol row and the rails
/// divisor. Zero when the stage has no inter-node messages.
pub fn net_time(machine: &Machine, params: &MachineParams, pattern: &CommPattern) -> f64 {
    let st = pattern.stats(machine);
    if st.m_std == 0 {
        return 0.0;
    }
    let per_msg = st.s_proc.div_ceil(st.m_std);
    let ab = params.ab_for(Endpoint::Cpu, Locality::OffNode, per_msg);
    let mr = MaxRate { alpha: ab.alpha, rb: 1.0 / ab.beta, rn: params.rn() };
    mr.time_node_rails(st.m_std, st.s_proc, st.s_node, machine.nics_per_node())
}

/// Busiest-endpoint serialization of a stage's on-node messages: each
/// endpoint sends (receives) its messages back to back at the Table 2
/// on-socket / on-node host rows.
pub fn intra_serial(machine: &Machine, params: &MachineParams, pattern: &CommPattern) -> f64 {
    let mut send: BTreeMap<GpuId, f64> = BTreeMap::new();
    let mut recv: BTreeMap<GpuId, f64> = BTreeMap::new();
    for m in pattern.intranode(machine) {
        let t = params.msg_time(Endpoint::Cpu, machine.gpu_locality(m.src, m.dst), m.bytes);
        *send.entry(m.src).or_default() += t;
        *recv.entry(m.dst).or_default() += t;
    }
    let worst = |m: &BTreeMap<GpuId, f64>| m.values().fold(0.0f64, |a, &b| a.max(b));
    worst(&send).max(worst(&recv))
}

/// `T_copy` (Eq. 4.5) on the busiest GPU's stage send and receive volumes
/// (staged transport moves every payload through the host, both
/// localities).
pub fn copy_legs(machine: &Machine, params: &MachineParams, pattern: &CommPattern) -> f64 {
    let _ = machine;
    if pattern.is_empty() {
        return 0.0;
    }
    let (out_max, in_max) = peak_volumes(pattern.msgs.iter().map(|m| (m.src, m.dst, m.bytes)));
    copy::t_copy(params, out_max, in_max, 1)
}

pub(crate) fn peak_volumes(msgs: impl Iterator<Item = (GpuId, GpuId, usize)>) -> (usize, usize) {
    let mut out: BTreeMap<GpuId, usize> = BTreeMap::new();
    let mut inn: BTreeMap<GpuId, usize> = BTreeMap::new();
    for (src, dst, bytes) in msgs {
        *out.entry(src).or_default() += bytes;
        *inn.entry(dst).or_default() += bytes;
    }
    (out.values().copied().max().unwrap_or(0), inn.values().copied().max().unwrap_or(0))
}

/// Modeled seconds for one stage: concurrent inter-/on-node legs plus the
/// stage's staging copies.
pub fn stage_time(machine: &Machine, params: &MachineParams, pattern: &CommPattern) -> f64 {
    net_time(machine, params, pattern).max(intra_serial(machine, params, pattern)) + copy_legs(machine, params, pattern)
}

/// Modeled end-to-end seconds for a lowered collective (the closed-form
/// twin of simulating [`super::lower::sim_schedule`]).
pub fn algorithm_time(machine: &Machine, params: &MachineParams, lowering: &Lowering) -> f64 {
    match lowering.algorithm {
        CollectiveAlgorithm::Standard | CollectiveAlgorithm::Locality => {
            lowering.stages.iter().map(|s| stage_time(machine, params, &s.pattern)).sum()
        }
        CollectiveAlgorithm::Pairwise => {
            // one up-front D2H + one final H2D over the union of rounds
            let (out_max, in_max) = peak_volumes(
                lowering.stages.iter().flat_map(|s| s.pattern.msgs.iter().map(|m| (m.src, m.dst, m.bytes))),
            );
            let copies = if out_max + in_max > 0 { copy::t_copy(params, out_max, in_max, 1) } else { 0.0 };
            copies
                + lowering
                    .stages
                    .iter()
                    .map(|s| {
                        let inter = net_time(machine, params, &s.pattern);
                        if inter > 0.0 {
                            inter
                        } else {
                            intra_serial(machine, params, &s.pattern)
                        }
                    })
                    .sum::<f64>()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{lower, Collective, CollectiveAlgorithm, CollectiveSpec};
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    fn time_of(c: Collective, alg: CollectiveAlgorithm, nodes: usize, block: usize) -> f64 {
        let m = lassen(nodes);
        let p = lassen_params();
        let direct = CollectiveSpec::new(c, block, 42).materialize(&m);
        algorithm_time(&m, &p, &lower(c, alg, &m, &direct))
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() / b.abs().max(1e-300) < 1e-9
    }

    #[test]
    fn matches_independent_transcription() {
        // Spot values from the offline transcription of this composition
        // (same params, same synthesis, same lowering — Python, EXPERIMENTS
        // workflow). Guards against drift in any piece of the chain.
        let cases = [
            (Collective::Alltoall, CollectiveAlgorithm::Standard, 4, 512, 5.7601126827e-5),
            (Collective::Alltoall, CollectiveAlgorithm::Pairwise, 4, 512, 6.0661586827e-5),
            (Collective::Alltoall, CollectiveAlgorithm::Locality, 4, 512, 9.1422573037e-5),
        ];
        for (c, a, nodes, block, expect) in cases {
            let got = time_of(c, a, nodes, block);
            assert!(close(got, expect), "{c} {a} n={nodes} s={block}: got {got:e}, expected {expect:e}");
        }
    }

    #[test]
    fn all_algorithms_positive_finite() {
        for c in Collective::ALL {
            for a in CollectiveAlgorithm::ALL {
                for nodes in [2, 4, 8] {
                    for block in [512, 8192, 131072] {
                        let t = time_of(c, a, nodes, block);
                        assert!(t.is_finite() && t > 0.0, "{c} {a} n={nodes} s={block} -> {t}");
                    }
                }
            }
        }
    }

    #[test]
    fn locality_wins_high_node_count_small_messages() {
        // The headline regime: many nodes, small blocks — standard
        // collapses under the inter-node message count, locality ships one
        // aggregated message per node pair.
        for c in Collective::ALL {
            let std_t = time_of(c, CollectiveAlgorithm::Standard, 32, 512);
            let loc_t = time_of(c, CollectiveAlgorithm::Locality, 32, 512);
            assert!(loc_t < std_t, "{c}: locality {loc_t:e} !< standard {std_t:e} at 32 nodes x 512 B");
        }
    }

    #[test]
    fn standard_wins_few_nodes_large_messages() {
        // The opposite regime: bandwidth-bound, the extra staging hops and
        // copies of locality cost more than the saved latencies.
        for c in Collective::ALL {
            let std_t = time_of(c, CollectiveAlgorithm::Standard, 2, 524288);
            let loc_t = time_of(c, CollectiveAlgorithm::Locality, 2, 524288);
            assert!(std_t < loc_t, "{c}: standard {std_t:e} !< locality {loc_t:e} at 2 nodes x 512 KiB");
        }
    }

    #[test]
    fn gate_cell_margin() {
        // The CI regime gate: locality-aware alltoallv beats standard at
        // the high-node-count / small-size cell by >= 3%.
        let std_t = time_of(Collective::Alltoallv, CollectiveAlgorithm::Standard, 32, 512);
        let loc_t = time_of(Collective::Alltoallv, CollectiveAlgorithm::Locality, 32, 512);
        let margin = (std_t - loc_t) / std_t;
        assert!(margin >= 0.03, "gate margin {margin:.3} < 0.03 (std {std_t:e}, loc {loc_t:e})");
    }

    #[test]
    fn allgather_dedup_widens_locality_win() {
        // Allgather's duplicate blocks cross the network once per node
        // under locality — its advantage over standard must exceed the
        // alltoall one at the same cell.
        let adv = |c: Collective| {
            let s = time_of(c, CollectiveAlgorithm::Standard, 16, 8192);
            let l = time_of(c, CollectiveAlgorithm::Locality, 16, 8192);
            (s - l) / s
        };
        assert!(adv(Collective::Allgather) > adv(Collective::Alltoall));
    }

    #[test]
    fn empty_pattern_costs_nothing() {
        let m = lassen(2);
        let p = lassen_params();
        let empty = CommPattern::default();
        assert_eq!(net_time(&m, &p, &empty), 0.0);
        assert_eq!(intra_serial(&m, &p, &empty), 0.0);
        assert_eq!(copy_legs(&m, &p, &empty), 0.0);
    }
}
