//! Versioned artifact layer for [`CollectiveSurface`]: schema
//! `hetcomm.colsurface.v1`.
//!
//! Same contract as [`crate::advisor::persist`]: floats are written with
//! [`fmt_f64`] (shortest-round-trip `Display`), so a loaded surface
//! reproduces the compiled one bit for bit and emit∘parse∘emit is the
//! identity on artifact bytes. Hand-rolled on the shared
//! [`crate::util::json`] substrate — no `serde` in the offline image.

use super::surface::CollectiveSurface;
use super::{Collective, CollectiveAlgorithm};
use crate::sweep::emit::esc;
use crate::util::json::{fmt_f64, fmt_usize_list, Json};
use std::fmt::Write as _;

/// Schema tag of the collective surface artifact.
pub const SCHEMA: &str = "hetcomm.colsurface.v1";

/// Serialize a compiled collective surface.
pub fn to_json(surface: &CollectiveSurface) -> String {
    let labels = |items: &[String]| {
        let quoted: Vec<String> = items.iter().map(|l| format!("\"{}\"", esc(l))).collect();
        format!("[{}]", quoted.join(", "))
    };
    let collectives: Vec<String> = surface.collectives.iter().map(|c| c.label().to_string()).collect();
    let algorithms: Vec<String> = surface.algorithms.iter().map(|a| a.label().to_string()).collect();
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&surface.machine));
    let _ = writeln!(out, "  \"gpus_per_node\": {},", surface.gpus_per_node);
    // string, not number: u64 seeds above 2^53 would not survive a
    // JSON-number round trip (the hetcomm.trace.v1 convention)
    let _ = writeln!(out, "  \"seed\": \"{}\",", surface.seed);
    let _ = writeln!(out, "  \"collectives\": {},", labels(&collectives));
    let _ = writeln!(out, "  \"algorithms\": {},", labels(&algorithms));
    let _ = writeln!(out, "  \"nodes\": {},", fmt_usize_list(&surface.nodes));
    let _ = writeln!(out, "  \"sizes\": {},", fmt_usize_list(&surface.sizes));
    out.push_str("  \"cells\": [\n");
    for (i, cell) in surface.cells.iter().enumerate() {
        let times: Vec<String> = cell.iter().map(|&t| fmt_f64(t)).collect();
        let comma = if i + 1 < surface.cells.len() { "," } else { "" };
        let _ = writeln!(out, "    [{}]{comma}", times.join(", "));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write a surface artifact to disk.
pub fn save(surface: &CollectiveSurface, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(surface)).map_err(|e| format!("cannot write {path}: {e}"))
}

/// Load and validate a surface artifact from disk.
pub fn load(path: &str) -> Result<CollectiveSurface, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_json(&text)
}

fn as_seed(v: &Json) -> Result<u64, String> {
    let s = v.as_str()?;
    s.parse::<u64>().map_err(|_| format!("expected a u64 seed string, found {s:?}"))
}

/// Parse and validate a `hetcomm.colsurface.v1` artifact.
pub fn parse_json(text: &str) -> Result<CollectiveSurface, String> {
    let value = Json::parse(text)?;
    let schema = value.field("schema")?.as_str()?;
    if schema != SCHEMA {
        return Err(format!("unsupported collective surface schema {schema:?} (want {SCHEMA:?})"));
    }
    let collectives = value
        .field("collectives")?
        .as_arr()?
        .iter()
        .map(|v| {
            let label = v.as_str()?;
            Collective::parse(label).ok_or_else(|| format!("unknown collective {label:?}"))
        })
        .collect::<Result<Vec<_>, String>>()?;
    let algorithms = value
        .field("algorithms")?
        .as_arr()?
        .iter()
        .map(|v| {
            let label = v.as_str()?;
            CollectiveAlgorithm::parse(label).ok_or_else(|| format!("unknown collective algorithm {label:?}"))
        })
        .collect::<Result<Vec<_>, String>>()?;
    let cells = value
        .field("cells")?
        .as_arr()?
        .iter()
        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<f64>, String>>())
        .collect::<Result<Vec<_>, String>>()?;
    let surface = CollectiveSurface {
        machine: value.field("machine")?.as_str()?.to_string(),
        gpus_per_node: value.field("gpus_per_node")?.as_usize()?,
        seed: as_seed(value.field("seed")?)?,
        collectives,
        nodes: value.field("nodes")?.as_usize_list()?,
        sizes: value.field("sizes")?.as_usize_list()?,
        algorithms,
        cells,
    };
    surface.validate()?;
    Ok(surface)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CollectiveSurface {
        CollectiveSurface::compile("lassen", 4, vec![2, 32], vec![512, 1 << 19], 42).unwrap()
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let surface = tiny();
        let json = to_json(&surface);
        let parsed = parse_json(&json).unwrap();
        assert_eq!(surface, parsed);
        for (a, b) in surface.cells.iter().zip(&parsed.cells) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // emit . parse . emit is the identity on artifact bytes
        assert_eq!(json, to_json(&parsed));
    }

    #[test]
    fn save_load_roundtrip() {
        let surface = tiny();
        let path = std::env::temp_dir().join("hetcomm-colsurface-test.json");
        let path = path.to_str().unwrap();
        save(&surface, path).unwrap();
        let loaded = load(path).unwrap();
        assert_eq!(surface, loaded);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupt_artifacts_rejected() {
        let surface = tiny();
        let json = to_json(&surface);

        let wrong_schema = json.replacen("hetcomm.colsurface.v1", "hetcomm.colsurface.v9", 1);
        assert!(parse_json(&wrong_schema).unwrap_err().contains("schema"));

        let bad_seed = json.replacen("\"seed\": \"42\"", "\"seed\": \"forty-two\"", 1);
        assert!(parse_json(&bad_seed).unwrap_err().contains("seed"));

        let bad_label = json.replacen("\"pairwise\"", "\"bogus\"", 1);
        assert!(parse_json(&bad_label).unwrap_err().contains("bogus"));

        let truncated = &json[..json.len() / 2];
        assert!(parse_json(truncated).is_err());

        // dropping a cell breaks the lattice shape check
        let mut short = surface.clone();
        short.cells.pop();
        assert!(parse_json(&to_json(&short)).unwrap_err().contains("cells"));

        // a poisoned time breaks the finite-positive check
        let mut poisoned = surface.clone();
        poisoned.cells[0][0] = -1.0;
        assert!(parse_json(&to_json(&poisoned)).is_err());
    }

    #[test]
    fn lookup_after_reload_matches_compile() {
        let surface = tiny();
        let loaded = parse_json(&to_json(&surface)).unwrap();
        let a = surface.lookup(super::super::Collective::Alltoallv, 32, 512).unwrap();
        let b = loaded.lookup(super::super::Collective::Alltoallv, 32, 512).unwrap();
        assert_eq!(a, b);
    }
}
